"""OnlineFleet: replica-parallel online serving (repro.serve.fleet).

The fleet's contract is *bit-exactness*: replica r of an ``OnlineFleet(K)``
must reproduce a standalone ``OnlineSession`` given the same RNG key and
offer stream — drained TA banks, monitoring aux and inference alike — on
both kernel backends. The mesh cases additionally pin that sharding the
replica axis over a device mesh changes nothing (they run on whatever
devices exist; CI re-runs them under a forced 4-host-device topology,
see .github/workflows/ci.yml `multidevice`).
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TMConfig, init_runtime, init_state
from repro.core.online import OnlineSession
from repro.data import iris
from repro.serve.fleet import OnlineFleet


def _cfg(backend="ref"):
    return TMConfig(n_features=16, max_classes=3, max_clauses=16,
                    n_states=16, backend=backend)


def _offer_streams(K, n, stride=7):
    """Distinct per-replica offer streams over the iris rows."""
    xs, ys = iris.load()
    return [
        [(xs[(i + stride * r) % len(xs)], int(ys[(i + stride * r) % len(xs)]))
         for i in range(n)]
        for r in range(K)
    ]


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("K", [1, 3, 8])
def test_fleet_drain_bitwise_identical_to_sessions(K, backend):
    """OnlineFleet(K) == K independent OnlineSessions, bit for bit."""
    cfg = _cfg(backend)
    rt = init_runtime(cfg, s=3.0, T=15)
    seeds = [100 + r for r in range(K)]
    streams = _offer_streams(K, 20)

    sessions = [
        OnlineSession(cfg, init_state(cfg), rt, buffer_capacity=32,
                      chunk=8, seed=seeds[r])
        for r in range(K)
    ]
    fleet = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=K,
                        buffer_capacity=32, chunk=8, seed=seeds)

    for i in range(20):
        for r in range(K):
            x, y = streams[r][i]
            assert sessions[r].offer(x, y)
            assert fleet.offer(r, x, y)

    want_trained = [s.learn_available(20) for s in sessions]
    got_trained = fleet.drain(20)
    assert list(got_trained) == want_trained == [20] * K

    want = np.stack([np.asarray(s.ss.tm.ta_state) for s in sessions])
    np.testing.assert_array_equal(want, np.asarray(fleet.ss.tm.ta_state))

    # fleet inference == per-session inference (one fused contraction)
    xs, _ = iris.load()
    preds = fleet.infer(xs[:12])
    for r in range(K):
        np.testing.assert_array_equal(preds[r], sessions[r].infer(xs[:12]))


def test_fleet_uneven_streams_and_budgets_match_sessions():
    """Replicas that exhaust their buffer or budget early retire exactly
    like standalone sessions (no RNG burn, bitwise state parity), across
    multiple drain rounds."""
    cfg = _cfg()
    rt = init_runtime(cfg, s=3.0, T=15)
    K = 3
    seeds = [7, 8, 9]
    counts = [5, 16, 11]          # uneven buffered rows per replica
    budgets = [3, 30, 11]         # uneven per-replica drain budgets
    streams = _offer_streams(K, max(counts))

    sessions = [
        OnlineSession(cfg, init_state(cfg), rt, buffer_capacity=32,
                      chunk=4, seed=seeds[r])
        for r in range(K)
    ]
    fleet = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=K,
                        buffer_capacity=32, chunk=4, seed=seeds)
    for r in range(K):
        for i in range(counts[r]):
            sessions[r].offer(*streams[r][i])
            fleet.offer(r, *streams[r][i])

    want = [sessions[r].learn_available(budgets[r]) for r in range(K)]
    got = fleet.drain(np.asarray(budgets))
    assert list(got) == want == [3, 16, 11]

    # second round: offer more and drain again — RNG streams must still agree
    for r in range(K):
        for i in range(4):
            sessions[r].offer(*streams[r][i])
            fleet.offer(r, *streams[r][i])
    want2 = [sessions[r].learn_available(10) for r in range(K)]
    got2 = fleet.drain(10)
    assert list(got2) == want2
    want_ta = np.stack([np.asarray(s.ss.tm.ta_state) for s in sessions])
    np.testing.assert_array_equal(want_ta, np.asarray(fleet.ss.tm.ta_state))
    np.testing.assert_array_equal(
        fleet.buffered, [s.buffered for s in sessions]
    )


def test_fleet_per_replica_hyperparameters_match_sessions():
    """rt.s/T as [K] vectors: every member learns under its own (s, T),
    bit-identical to sessions with those scalar runtimes."""
    cfg = _cfg()
    K = 3
    s_vals, T_vals = [1.375, 3.0, 5.0], [5, 15, 10]
    seeds = [41, 42, 43]
    streams = _offer_streams(K, 16)

    sessions = [
        OnlineSession(cfg, init_state(cfg),
                      init_runtime(cfg, s=s_vals[r], T=T_vals[r]),
                      buffer_capacity=32, chunk=8, seed=seeds[r])
        for r in range(K)
    ]
    rt = init_runtime(cfg)._replace(
        s=jnp.asarray(s_vals, jnp.float32), T=jnp.asarray(T_vals, jnp.int32)
    )
    fleet = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=K,
                        buffer_capacity=32, chunk=8, seed=seeds)
    for i in range(16):
        for r in range(K):
            sessions[r].offer(*streams[r][i])
            fleet.offer(r, *streams[r][i])
    for s in sessions:
        s.learn_available(16)
    fleet.drain(16)
    want = np.stack([np.asarray(s.ss.tm.ta_state) for s in sessions])
    np.testing.assert_array_equal(want, np.asarray(fleet.ss.tm.ta_state))


def test_fleet_monitoring_aux_matches_sessions():
    """drain(on_chunk=) surfaces ChunkAux with leading [K] — bitwise equal
    to each session's per-chunk aux, and compiled out when absent."""
    cfg = _cfg()
    rt = init_runtime(cfg, s=3.0, T=15)
    K = 3
    seeds = [1, 2, 3]
    streams = _offer_streams(K, 12)

    per_session: list = []
    sessions = []
    for r in range(K):
        s = OnlineSession(cfg, init_state(cfg), rt, buffer_capacity=32,
                          chunk=4, seed=seeds[r])
        sessions.append(s)
    fleet = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=K,
                        buffer_capacity=32, chunk=4, seed=seeds)
    for i in range(12):
        for r in range(K):
            sessions[r].offer(*streams[r][i])
            fleet.offer(r, *streams[r][i])

    for r in range(K):
        chunks: list = []
        sessions[r].learn_available(12, on_chunk=chunks.append)
        per_session.append(chunks)
    fleet_chunks: list = []
    fleet.drain(12, on_chunk=fleet_chunks.append)

    assert len(fleet_chunks) == len(per_session[0]) == 3  # 12 points / chunk 4
    for c, fc in enumerate(fleet_chunks):
        for r in range(K):
            want = per_session[r][c]
            got = jax.tree.map(lambda a: np.asarray(a)[r], fc)
            for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
                np.testing.assert_array_equal(np.asarray(w), g)

    # without the hook, monitoring is compiled out and state is unchanged
    fleet2 = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=K,
                         buffer_capacity=32, chunk=4, seed=seeds)
    for i in range(12):
        for r in range(K):
            fleet2.offer(r, *streams[r][i])
    fleet2.drain(12)
    np.testing.assert_array_equal(
        np.asarray(fleet.ss.tm.ta_state), np.asarray(fleet2.ss.tm.ta_state)
    )


def test_fleet_backpressure_counts():
    cfg = _cfg()
    rt = init_runtime(cfg, s=3.0, T=15)
    fleet = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=2,
                        buffer_capacity=4, chunk=2, seed=0)
    xs, ys = iris.load()
    for i in range(4):
        assert fleet.offer(0, xs[i], int(ys[i]))
    assert not fleet.offer(0, xs[4], int(ys[4]))   # replica 0 full
    assert fleet.offer(1, xs[4], int(ys[4]))       # replica 1 untouched
    np.testing.assert_array_equal(fleet.dropped, [1, 0])
    np.testing.assert_array_equal(fleet.buffered, [4, 1])


def test_fleet_adapt_manager_per_replica_rollback():
    """TMFleetAdaptManager: a member whose accuracy collapses rolls back to
    ITS known-good bank; healthy members keep serving untouched."""
    from repro.core.tm import TMState
    from repro.serve.online_adapt import TMFleetAdaptManager, TMOnlineAdaptConfig

    cfg = _cfg()
    rt = init_runtime(cfg, s=3.0, T=15)
    xs, ys = iris.load()
    K = 3
    m = TMFleetAdaptManager(
        cfg, init_state(cfg), rt, xs[100:], ys[100:], n_replicas=K,
        oc=TMOnlineAdaptConfig(analyze_every=4, rollback_threshold=0.1,
                               buffer_capacity=16, chunk=4),
        seed=[5, 6, 7],
    )
    base = m.offline_train(xs[:80], ys[:80], n_epochs=10)
    assert base.shape == (K,)

    # Poison replica 0's TA bank (simulate corruption / bad adaptation):
    # next analysis must roll ONLY replica 0 back to its known-good bank.
    poisoned = np.asarray(m.fleet.ss.tm.ta_state).copy()
    poisoned[0] = np.asarray(init_state(cfg).ta_state)
    m.fleet.ss = m.fleet.ss._replace(
        tm=TMState(ta_state=jnp.asarray(poisoned))
    )
    accs = None
    for i in range(4):   # analyze_every=4 points per replica
        accs = m.observe_rows(np.asarray(xs[80 + i]), int(ys[80 + i]))
    assert accs is not None
    np.testing.assert_array_equal(m.rollbacks, [1, 0, 0])
    # replica 0's bank was restored BEFORE the post-rollback online points…
    assert float(m.analyze()[0]) >= float(base[0]) - 0.1
    # …and healthy replicas were never rolled back
    assert m.history[-1][1].shape == (K,)


def test_fleet_adapt_manager_per_replica_cadence():
    """Per-replica analysis counters: only members fed enough traffic hit
    their cadence; their counters reset independently."""
    from repro.serve.online_adapt import TMFleetAdaptManager, TMOnlineAdaptConfig

    cfg = _cfg()
    rt = init_runtime(cfg, s=3.0, T=15)
    xs, ys = iris.load()
    K = 3
    m = TMFleetAdaptManager(
        cfg, init_state(cfg), rt, xs[100:], ys[100:], n_replicas=K,
        oc=TMOnlineAdaptConfig(analyze_every=3, rollback_threshold=0.5,
                               buffer_capacity=16, chunk=4),
        seed=0,
    )
    m.offline_train(xs[:40], ys[:40], n_epochs=2)
    mask = np.array([True, True, False])   # starve replica 2
    out = None
    for i in range(3):
        out = m.observe_rows(np.asarray(xs[i]), int(ys[i]), mask)
    assert out is not None                  # replicas 0/1 hit cadence
    np.testing.assert_array_equal(m._since, [0, 0, 0])  # 2 never consumed
    # starved member then fed alone: fires after ITS OWN 3 points
    mask2 = np.array([False, False, True])
    assert m.observe_rows(np.asarray(xs[3]), int(ys[3]), mask2) is None
    assert m.observe_rows(np.asarray(xs[4]), int(ys[4]), mask2) is None
    assert m.observe_rows(np.asarray(xs[5]), int(ys[5]), mask2) is not None


# ---------------------------------------------------------------------------
# Hypothesis: arbitrary offer/drain/infer interleavings keep invariants.
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency (requirements-dev.txt)
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.one_of(
            st.tuples(st.just("offer"), st.integers(0, 2), st.integers(0, 149)),
            st.tuples(st.just("drain"), st.integers(0, 12), st.just(0)),
            st.tuples(st.just("infer"), st.just(0), st.just(0)),
        ),
        max_size=25,
    )

    @settings(max_examples=15, deadline=None)
    @given(ops_seq=_ops, seed=st.integers(0, 2**31 - 1))
    def test_fleet_interleaving_invariants(ops_seq, seed):
        """Any interleaving of offer/drain/infer across replicas keeps
        per-replica buffer counts in sync with a host-side FIFO model, the
        TA plane at its int8 dtype, and every state in [1, 2N] (the
        hardware's [-N, N) counter range shifted to 1-based)."""
        cfg = _cfg()
        cap, K = 6, 3
        rt = init_runtime(cfg, s=3.0, T=15)
        fleet = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=K,
                            buffer_capacity=cap, chunk=4, seed=seed)
        xs, ys = iris.load()
        counts = [0] * K
        dtype0 = np.asarray(fleet.ss.tm.ta_state).dtype
        assert dtype0 == np.int8
        for op, a, b in ops_seq:
            if op == "offer":
                ok = fleet.offer(a, xs[b], int(ys[b]))
                assert ok == (counts[a] < cap)
                if counts[a] < cap:
                    counts[a] += 1
            elif op == "drain":
                trained = fleet.drain(a)
                for r in range(K):
                    assert trained[r] == min(a, counts[r])
                    counts[r] -= int(trained[r])
            else:
                preds = fleet.infer(xs[:5])
                assert preds.shape == (K, 5)
                assert ((preds >= 0) & (preds < cfg.max_classes)).all()
            np.testing.assert_array_equal(fleet.buffered, counts)
            ta = np.asarray(fleet.ss.tm.ta_state)
            assert ta.dtype == dtype0
            assert ta.min() >= 1 and ta.max() <= 2 * cfg.n_states


# ---------------------------------------------------------------------------
# Mesh cases (run on whatever devices exist; the CI `multidevice` job forces
# XLA_FLAGS=--xla_force_host_platform_device_count=4 so they exercise a real
# 4-device sharding of the replica axis).
# ---------------------------------------------------------------------------


def _data_mesh():
    from jax.sharding import Mesh

    return Mesh(np.array(jax.devices()), ("data",))


def test_fleet_mesh_sharded_bitwise_equal_to_unsharded():
    """Sharding the fleet's replica axis over the mesh changes nothing:
    drained TA banks and inference are bitwise equal to the local fleet."""
    cfg = _cfg()
    rt = init_runtime(cfg, s=3.0, T=15)
    K = 8  # divisible by 1, 2, 4 devices
    seeds = list(range(K))
    streams = _offer_streams(K, 12)

    runs = []
    for mesh in (None, _data_mesh()):
        fleet = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=K,
                            buffer_capacity=16, chunk=4, seed=seeds,
                            mesh=mesh)
        for i in range(12):
            for r in range(K):
                fleet.offer(r, *streams[r][i])
        trained = fleet.drain(12)
        assert list(trained) == [12] * K
        runs.append((np.asarray(fleet.ss.tm.ta_state),
                     fleet.infer(iris.load()[0][:10])))
    np.testing.assert_array_equal(runs[0][0], runs[1][0])
    np.testing.assert_array_equal(runs[0][1], runs[1][1])


def test_replica_shardings_grid_major_device_local():
    """With n_replicas pinned, ONLY the full-R (grid-major) axis shards;
    per-data-stream leaves (D < R, even when divisible) replicate onto all
    devices so the kernels' r % D gather never crosses devices."""
    from jax.sharding import PartitionSpec as PS

    from repro.distributed import sharding as shard_mod

    mesh = _data_mesh()
    n_dev = len(jax.devices())
    R = 8 * n_dev
    tree = {
        "state": jax.ShapeDtypeStruct((R, 3, 16, 32), jnp.int8),   # full R
        "stream": jax.ShapeDtypeStruct((R // 2, 30, 16), bool),    # D | R
        "keys": jax.ShapeDtypeStruct((R // 2, 2), jnp.uint32),     # D | R
        "scalar": jax.ShapeDtypeStruct((), jnp.float32),
    }
    sh = shard_mod.replica_shardings(tree, mesh, n_replicas=R)
    assert sh["state"].spec == PS("data")
    assert sh["stream"].spec == PS()   # replicated: gather stays local
    assert sh["keys"].spec == PS()
    assert sh["scalar"].spec == PS()
    # the old no-n_replicas form guessed by divisibility — exactly the
    # D | R stream scattering the grid-major rule exists to prevent —
    # and is now a hard error (deprecated through PR 8)
    with pytest.raises(TypeError, match="n_replicas"):
        shard_mod.replica_shardings(tree, mesh)


def test_crossval_mesh_sharded_sweep_bitwise_equal():
    """CrossValRun(mesh=...) on however many devices exist == meshless run
    (the 4-device variant is what the multidevice CI job pins)."""
    from repro.data import blocks
    from repro.eval.crossval import CrossValRun

    cfg = _cfg()
    osets, _ = blocks.iris_paper_sets(n_orderings=4)
    kw = dict(n_epochs=3, seed=0)
    base = CrossValRun(cfg).sweep(
        osets.offline_x, osets.offline_y,
        osets.validation_x, osets.validation_y,
        (1.375, 3.0), (5, 15), **kw,
    )  # R = 2*2*4 = 16: divisible by 1/2/4 devices
    sharded = CrossValRun(cfg, mesh=_data_mesh()).sweep(
        osets.offline_x, osets.offline_y,
        osets.validation_x, osets.validation_y,
        (1.375, 3.0), (5, 15), **kw,
    )
    np.testing.assert_array_equal(
        np.asarray(base.val_accuracy), np.asarray(sharded.val_accuracy)
    )


FORCED_MESH_SCRIPT = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh, PartitionSpec as PS
    assert len(jax.devices()) == 4, jax.devices()

    from repro.core import TMConfig, init_runtime, init_state
    from repro.data import blocks, iris
    from repro.distributed import sharding as shard_mod
    from repro.eval.crossval import CrossValRun
    from repro.serve.fleet import OnlineFleet

    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=16)
    mesh = Mesh(np.array(jax.devices()), ("data",))

    # grid-major axis device-local: full-R leaves shard, D-streams replicate
    sh = shard_mod.replica_shardings(
        {"ta": jax.ShapeDtypeStruct((16, 3, 16, 32), jnp.int8),
         "stream": jax.ShapeDtypeStruct((4, 30, 16), bool)},
        mesh, n_replicas=16)
    assert sh["ta"].spec == PS("data"), sh["ta"]
    assert sh["stream"].spec == PS(), sh["stream"]

    # mesh-sharded sweep == single-device sweep, bitwise
    osets, _ = blocks.iris_paper_sets(n_orderings=4)
    kw = dict(n_epochs=3, seed=0)
    args = (osets.offline_x, osets.offline_y,
            osets.validation_x, osets.validation_y, (1.375, 3.0), (5, 15))
    base = CrossValRun(cfg).sweep(*args, **kw)
    sharded = CrossValRun(cfg, mesh=mesh).sweep(*args, **kw)
    np.testing.assert_array_equal(
        np.asarray(base.val_accuracy), np.asarray(sharded.val_accuracy))

    # mesh-sharded fleet == single-device fleet, bitwise
    xs, ys = iris.load()
    rt = init_runtime(cfg, s=3.0, T=15)
    tas = []
    for m in (None, mesh):
        fleet = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=8,
                            buffer_capacity=16, chunk=4,
                            seed=list(range(8)), mesh=m)
        for i in range(8):
            fleet.offer_rows(
                np.stack([xs[(i + 7 * r) % 150] for r in range(8)]),
                np.asarray([int(ys[(i + 7 * r) % 150]) for r in range(8)]))
        fleet.drain(8)
        tas.append(np.asarray(fleet.ss.tm.ta_state))
    np.testing.assert_array_equal(tas[0], tas[1])
    print("OK")
""")


def test_forced_4_device_mesh_subprocess():
    """Sweep + fleet on a forced 4-host-device mesh are bitwise equal to
    the 1-device runs (subprocess: XLA device count is fixed at import)."""
    import os

    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    r = subprocess.run([sys.executable, "-c", FORCED_MESH_SCRIPT],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
