"""Wide-datapath (MNIST-scale) suite: parity and end-to-end serving.

Everything else in tests/ runs at iris width (f=16); this file pins the
scale path: the generated booleanized digit workload at 14x14 (f=196,
tier-1) and the full 28x28 (f=784, ``-m slow``) through

* the sweep engine, asserted bitwise ref <-> pallas per cell,
* TMService end to end — submit -> tick -> serve, including a §5.3.2
  rollback — on both kernel backends, with rows flowing straight from the
  generator into the service (no host-side reshaping anywhere), and the
  two backends' tick trajectories asserted bitwise identical.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import tm_mnist
from repro.core import init_state
from repro.core.tm import TMState
from repro.data import mnist
from repro.eval.crossval import CrossValRun
from repro.serve import AdaptPolicy, ServiceConfig, TMService

FAST_SIDE = 14
SLOW_SIDE = 28


def _cfg(side, backend="ref"):
    params = tm_mnist.config_for_side(side)
    return dataclasses.replace(params.tm, backend=backend), params


# ---------------------------------------------------------------------------
# sweep-cell parity: ref <-> pallas, bitwise, at width
# ---------------------------------------------------------------------------


def _sweep_cell(side, backend, n_orderings=2, n_epochs=1):
    from repro.data import blocks

    cfg, params = _cfg(side, backend)
    xs, ys = mnist.load(side=side)
    osets, _ = blocks.paper_sets(xs, ys, n_orderings)
    res = CrossValRun(cfg).sweep(
        jnp.asarray(osets.offline_x), jnp.asarray(osets.offline_y),
        jnp.asarray(osets.validation_x), jnp.asarray(osets.validation_y),
        (params.s_offline,), (params.T,), n_epochs=n_epochs, seed=0,
    )
    return np.asarray(res.val_accuracy)


def test_sweep_cell_ref_pallas_bitwise_fast():
    """f=196: one sweep cell per ordering, identical across backends."""
    a = _sweep_cell(FAST_SIDE, "ref")
    b = _sweep_cell(FAST_SIDE, "pallas")
    np.testing.assert_array_equal(a, b)
    assert a.shape == (1, 1, 2)


@pytest.mark.slow
def test_sweep_cell_ref_pallas_bitwise_full_width():
    """f=784: the full MNIST-width sweep cell, identical across backends."""
    a = _sweep_cell(SLOW_SIDE, "ref")
    b = _sweep_cell(SLOW_SIDE, "pallas")
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# TMService end to end at width: submit -> tick -> serve (+ §5.3.2 rollback)
# ---------------------------------------------------------------------------


def _service(side, backend, K=2, packed=False):
    cfg, params = _cfg(side, backend)
    tr_x, tr_y, te_x, te_y = mnist.splits(60, 40, seed=5, side=side)
    svc = TMService(
        cfg, init_state(cfg),
        ServiceConfig(replicas=K, buffer_capacity=32, chunk=8,
                      s=params.s_online, T=params.T, seed=[3, 4][:K],
                      packed=packed,
                      policy=AdaptPolicy(analyze_every=8,
                                         rollback_threshold=0.1)),
        eval_x=te_x, eval_y=te_y,
    )
    return svc, (tr_x, tr_y, te_x, te_y)


def _drive(svc, tr_x, tr_y, n=16):
    """Identical labelled traffic through submit -> tick; returns reports."""
    reports = []
    for i in range(n):
        svc.submit_rows(tr_x[i % len(tr_x)], int(tr_y[i % len(tr_y)]))
        if (i + 1) % svc.chunk == 0:
            reports.append(svc.tick())
    return reports


def _e2e_rollback(side, backend):
    svc, (tr_x, tr_y, te_x, te_y) = _service(side, backend)
    base = svc.offline_train(tr_x, tr_y, n_epochs=4)
    assert base.shape == (2,)
    assert float(base.min()) > 0.3          # learnt something at width

    # Poison member 0's bank; member 1 keeps serving untouched (§5.3.2
    # isolation). The next due analysis must roll member 0 back.
    cfg = svc.cfg
    poisoned = np.asarray(svc.ss.tm.ta_state).copy()
    poisoned[0] = np.asarray(init_state(cfg).ta_state)
    svc.ss = svc.ss._replace(tm=TMState(ta_state=jnp.asarray(poisoned)))

    reports = _drive(svc, tr_x, tr_y, n=16)
    fired = [r for r in reports if r.accuracy is not None]
    assert fired, "no analysis became due"
    assert svc.rollbacks.tolist() == [1, 0]
    assert any(r.rolled_back.tolist() == [True, False] for r in fired)

    # serve: fleet inference straight off the generator's rows.
    preds = svc.serve(te_x)
    assert preds.shape == (2, len(te_x))
    acc_served = (preds[1] == np.asarray(te_y)).mean()
    assert float(acc_served) >= float(base[1]) - 0.15
    # rolled-back member recovered to its known-good neighborhood
    assert float(svc.analyze()[0]) >= float(base[0]) - 0.1
    return svc


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_service_end_to_end_rollback_fast(backend):
    """f=196 submit -> tick -> serve with a §5.3.2 rollback, per backend."""
    _e2e_rollback(FAST_SIDE, backend)


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_service_end_to_end_rollback_full_width(backend):
    """f=784: the same end-to-end flow at the full MNIST width."""
    _e2e_rollback(SLOW_SIDE, backend)


def _tick_trajectory(side, backend, packed=False):
    svc, (tr_x, tr_y, _, _) = _service(side, backend, packed=packed)
    svc.offline_train(tr_x[:20], tr_y[:20], n_epochs=2)
    reports = _drive(svc, tr_x, tr_y, n=16)
    return svc, reports


def _assert_tick_parity(side):
    ref_svc, ref_rep = _tick_trajectory(side, "ref")
    pal_svc, pal_rep = _tick_trajectory(side, "pallas")
    np.testing.assert_array_equal(
        np.asarray(ref_svc.ss.tm.ta_state),
        np.asarray(pal_svc.ss.tm.ta_state),
    )
    np.testing.assert_array_equal(ref_svc.steps, pal_svc.steps)
    assert len(ref_rep) == len(pal_rep)
    for a, b in zip(ref_rep, pal_rep):
        np.testing.assert_array_equal(a.trained, b.trained)
        if a.accuracy is None:
            assert b.accuracy is None
        else:
            np.testing.assert_array_equal(a.accuracy, b.accuracy)


def test_service_tick_ref_pallas_bitwise_fast():
    """f=196: whole tick trajectories bitwise identical across backends."""
    _assert_tick_parity(FAST_SIDE)


@pytest.mark.slow
def test_service_tick_ref_pallas_bitwise_full_width():
    """f=784: whole tick trajectories bitwise identical across backends."""
    _assert_tick_parity(SLOW_SIDE)


# ---------------------------------------------------------------------------
# packed datapath parity: whole service trajectories, packed vs unpacked
# ---------------------------------------------------------------------------


def _assert_packed_parity(side, backend):
    """ServiceConfig(packed=True) == packed=False bit for bit: trained TA
    states, tick reports (counts AND accuracies), and served predictions.

    The packed service stores uint32 rows in buffer + staging and runs
    every inference/analysis pass through the AND+popcount kernels; the
    unpacked trajectory is the §13 parity oracle.
    """
    base_svc, base_rep = _tick_trajectory(side, backend, packed=False)
    pk_svc, pk_rep = _tick_trajectory(side, backend, packed=True)
    # packed storage really is words: ~8-32x smaller ring rows
    assert pk_svc.ss.buf.data_x.dtype == jnp.uint32
    assert base_svc.ss.buf.data_x.dtype == jnp.bool_
    assert pk_svc.ss.buf.data_x.shape[-1] < base_svc.ss.buf.data_x.shape[-1]
    np.testing.assert_array_equal(
        np.asarray(base_svc.ss.tm.ta_state), np.asarray(pk_svc.ss.tm.ta_state)
    )
    np.testing.assert_array_equal(base_svc.steps, pk_svc.steps)
    assert len(base_rep) == len(pk_rep)
    for a, b in zip(base_rep, pk_rep):
        np.testing.assert_array_equal(a.trained, b.trained)
        if a.accuracy is None:
            assert b.accuracy is None
        else:
            np.testing.assert_array_equal(a.accuracy, b.accuracy)
    _, (_, _, te_x, _) = _cfg(side, backend), mnist.splits(
        60, 40, seed=5, side=side
    )
    np.testing.assert_array_equal(base_svc.serve(te_x), pk_svc.serve(te_x))
    np.testing.assert_array_equal(base_svc.analyze(), pk_svc.analyze())


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_service_packed_parity_word_tail(backend):
    """f=49 (side 7, NOT a multiple of 32): tail-word masking through the
    whole service trajectory, per backend."""
    _assert_packed_parity(7, backend)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_service_packed_parity_fast(backend):
    """f=196: packed == unpacked service trajectories, per backend."""
    _assert_packed_parity(FAST_SIDE, backend)


@pytest.mark.slow
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_service_packed_parity_full_width(backend):
    """f=784: packed == unpacked at the full MNIST width, per backend."""
    _assert_packed_parity(SLOW_SIDE, backend)
