"""BatchRouter ingress properties: nothing lost, nothing reordered.

The router defers device enqueues (host-side staging, packed block
flushes), so the property that matters is conservation + FIFO order per
replica under ARBITRARY interleavings of submit / submit_rows / flush /
drain / tick: every accepted datapoint reaches its replica's ring buffer
exactly once, in submission order, and every rejected one is a counted
backpressure drop. Rows are tagged with a unique id encoded in the
feature bits so reordering cannot hide.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TMConfig, init_runtime, init_state
from repro.serve import AdaptPolicy, ServiceConfig, TMService

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency (requirements-dev.txt)
    HAVE_HYPOTHESIS = False

K, CAP, BLOCK, CHUNK, F = 3, 6, 3, 4, 16


def _make_service(seed=0):
    cfg = TMConfig(n_features=F, max_classes=3, max_clauses=16, n_states=16)
    return TMService(cfg, init_state(cfg), ServiceConfig(
        replicas=K, buffer_capacity=CAP, chunk=CHUNK, ingress_block=BLOCK,
        s=3.0, T=15, seed=seed,
    ))


def _row(uid: int):
    """A unique datapoint: uid's bits as features (16 bits = plenty)."""
    x = np.array([(uid >> b) & 1 for b in range(F)], dtype=bool)
    return x, uid % 3


def _uid(x: np.ndarray) -> int:
    return int(sum(int(v) << b for b, v in enumerate(x)))


def _device_queue(svc, r):
    """Replica r's ring-buffer content, oldest first, as uids."""
    buf = svc.ss.buf
    data_x = np.asarray(buf.data_x[r])
    head = int(np.asarray(buf.head[r]))
    size = int(np.asarray(buf.size[r]))
    return [_uid(data_x[(head + i) % CAP]) for i in range(size)]


class _Model:
    """Host-side reference: per-replica FIFO + conservation counters."""

    def __init__(self):
        self.queue = [[] for _ in range(K)]   # accepted, not yet trained
        self.submitted = np.zeros(K, dtype=np.int64)
        self.dropped = np.zeros(K, dtype=np.int64)
        self.trained = np.zeros(K, dtype=np.int64)

    def submit(self, r, uid) -> bool:
        self.submitted[r] += 1
        if len(self.queue[r]) >= CAP:
            self.dropped[r] += 1
            return False
        self.queue[r].append(uid)
        return True

    def drain(self, budget):
        out = []
        for r in range(K):
            n = min(int(budget[r]), len(self.queue[r]))
            del self.queue[r][:n]
            self.trained[r] += n
            out.append(n)
        return np.asarray(out)


def _check(svc, model):
    """Conservation + order invariants (order checked on device after a
    forced flush so staged rows are visible in the ring)."""
    np.testing.assert_array_equal(svc.buffered,
                                  [len(q) for q in model.queue])
    np.testing.assert_array_equal(svc.dropped, model.dropped)
    # conservation: every submitted point is trained, queued or dropped
    np.testing.assert_array_equal(
        model.submitted,
        model.trained + svc.buffered + model.dropped,
    )
    svc.flush()
    for r in range(K):
        assert _device_queue(svc, r) == model.queue[r], (
            f"replica {r}: device ring diverged from FIFO model"
        )


if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(0, K - 1)),
            st.tuples(st.just("submit_rows"),
                      st.integers(1, 2 ** K - 1)),     # nonempty mask bits
            st.tuples(st.just("flush"), st.just(0)),
            st.tuples(st.just("drain"), st.integers(0, 2 * CAP)),
            st.tuples(st.just("tick"), st.integers(0, CHUNK)),
        ),
        max_size=30,
    )

    @settings(max_examples=20, deadline=None)
    @given(ops_seq=_ops, seed=st.integers(0, 2 ** 31 - 1))
    def test_router_no_loss_no_reorder(ops_seq, seed):
        """Arbitrary submit/submit_rows/flush/drain/tick interleavings:
        per-replica FIFO order and datapoint conservation always hold."""
        svc = _make_service(seed)
        model = _Model()
        uid = 0
        for op, arg in ops_seq:
            if op == "submit":
                uid += 1
                x, y = _row(uid)
                assert svc.submit(arg, x, y) == model.submit(arg, uid)
            elif op == "submit_rows":
                uid += 1
                x, y = _row(uid)
                mask = np.array([(arg >> r) & 1 for r in range(K)],
                                dtype=bool)
                got = svc.submit_rows(x, y, mask)
                want = np.array([model.submit(r, uid) if mask[r] else False
                                 for r in range(K)])
                np.testing.assert_array_equal(got, want)
            elif op == "flush":
                svc.flush()
            elif op == "drain":
                np.testing.assert_array_equal(svc.drain(arg),
                                              model.drain([arg] * K))
            else:  # tick (no eval set: drains + cadence only)
                rep = svc.tick(arg)
                np.testing.assert_array_equal(rep.trained,
                                              model.drain([arg] * K))
                assert rep.accuracy is None
        _check(svc, model)


def test_router_block_flush_counts():
    """Auto-flush fires when a staging lane fills: N submits per replica
    cost ceil(N / B_ingress) dispatches, and explicit flush is a no-op
    when nothing is staged."""
    svc = _make_service()
    uid = 0
    for _ in range(BLOCK):        # fill every lane exactly once
        uid += 1
        x, y = _row(uid)
        svc.submit_rows(x, y)
    assert svc.router.flushes == 1      # lanes hit BLOCK -> one dispatch
    np.testing.assert_array_equal(svc.router.staged, [0] * K)
    svc.flush()
    assert svc.router.flushes == 1      # nothing staged: no dispatch
    uid += 1
    x, y = _row(uid)
    svc.submit(0, x, y)
    svc.flush()
    assert svc.router.flushes == 2
    np.testing.assert_array_equal(svc.buffered, [BLOCK + 1, BLOCK, BLOCK])


def test_router_rejects_against_mirror_not_device():
    """Acceptance is decided host-side: a full buffer (device + staged)
    rejects synchronously even though no device dispatch happened yet."""
    svc = _make_service()
    for i in range(CAP):
        x, y = _row(i + 1)
        assert svc.submit(0, x, y)
    x, y = _row(99)
    assert not svc.submit(0, x, y)            # full purely from staging
    np.testing.assert_array_equal(svc.dropped, [1, 0, 0])
    svc.drain(2)                               # frees two slots
    assert svc.submit(0, x, y)
    np.testing.assert_array_equal(svc.buffered, [CAP - 1, 0, 0])


def test_submit_rows_broadcast_contract():
    """The old offer_rows broadcast rules survive the router: [f] and
    [1, f] features (and scalar / [1] labels) fan out to all K replicas."""
    svc = _make_service()
    x, y = _row(5)
    for xs, ys in [(x, y), (x[None], np.asarray([y])),
                   (np.broadcast_to(x, (K, F)), np.full(K, y))]:
        np.testing.assert_array_equal(svc.submit_rows(xs, ys), [True] * K)
    svc.flush()
    for r in range(K):
        assert _device_queue(svc, r) == [5, 5, 5]


def test_mirror_survives_on_chunk_exception():
    """A callback raising mid-drain leaves device state, occupancy mirror
    and acceptance accounting consistent (no phantom backpressure)."""
    svc = _make_service()
    for i in range(CAP):
        x, y = _row(i + 1)
        assert svc.submit(0, x, y)

    class Boom(Exception):
        pass

    calls = []

    def boom(aux):
        calls.append(aux)
        raise Boom

    with pytest.raises(Boom):
        svc.drain(CAP, on_chunk=boom)   # CHUNK < CAP: raises on chunk 1
    assert len(calls) == 1
    consumed = CHUNK                     # exactly one chunk landed
    np.testing.assert_array_equal(svc.buffered, [CAP - consumed, 0, 0])
    np.testing.assert_array_equal(
        svc.buffered[0], int(np.asarray(svc.ss.buf.size[0]))
    )
    x, y = _row(99)
    assert svc.submit(0, x, y)           # no phantom backpressure
    assert svc.drain(2 * CAP)[0] == CAP - consumed + 1


def test_service_config_validates_port_lengths():
    """Per-replica s/T sequences must match `replicas` at construction,
    like the seed check — not fail deep in the first drained kernel."""
    from repro.core import TMConfig, init_state
    from repro.serve import ServiceConfig, TMService

    cfg = TMConfig(n_features=F, max_classes=3, max_clauses=16, n_states=16)
    for bad in (dict(s=[1.0, 2.0]), dict(T=[5, 15])):
        with pytest.raises(ValueError, match="per-replica"):
            TMService(cfg, init_state(cfg),
                      ServiceConfig(replicas=4, **bad))


def test_service_requires_eval_set_for_analysis():
    svc = _make_service()
    with pytest.raises(ValueError):
        svc.analyze()
    # but tick without an eval set is a plain drain (no analysis)
    rep = svc.tick(2)
    assert rep.accuracy is None
    assert isinstance(svc.policy, AdaptPolicy)
    assert jnp.ndim(svc.rt.s) == 0
