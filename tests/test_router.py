"""BatchRouter ingress properties: nothing lost, nothing reordered.

The router defers device enqueues (host-side staging, packed block
flushes), so the property that matters is conservation + FIFO order per
replica under ARBITRARY interleavings of submit / submit_rows / flush /
drain / tick: every accepted datapoint reaches its replica's ring buffer
exactly once, in submission order, and every rejected one is a counted
backpressure drop. Rows are tagged with a unique id encoded in the
feature bits so reordering cannot hide.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TMConfig, init_state
from repro.serve import AdaptPolicy, ServiceConfig, TMService

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency (requirements-dev.txt)
    HAVE_HYPOTHESIS = False

K, CAP, BLOCK, CHUNK, F = 3, 6, 3, 4, 16


def _make_service(seed=0):
    cfg = TMConfig(n_features=F, max_classes=3, max_clauses=16, n_states=16)
    return TMService(cfg, init_state(cfg), ServiceConfig(
        replicas=K, buffer_capacity=CAP, chunk=CHUNK, ingress_block=BLOCK,
        s=3.0, T=15, seed=seed,
    ))


def _row(uid: int):
    """A unique datapoint: uid's bits as features (16 bits = plenty)."""
    x = np.array([(uid >> b) & 1 for b in range(F)], dtype=bool)
    return x, uid % 3


def _uid(x: np.ndarray) -> int:
    return int(sum(int(v) << b for b, v in enumerate(x)))


def _device_queue(svc, r):
    """Replica r's ring-buffer content, oldest first, as uids."""
    buf = svc.ss.buf
    data_x = np.asarray(buf.data_x[r])
    head = int(np.asarray(buf.head[r]))
    size = int(np.asarray(buf.size[r]))
    return [_uid(data_x[(head + i) % CAP]) for i in range(size)]


class _Model:
    """Host-side reference: per-replica FIFO + conservation counters."""

    def __init__(self):
        self.queue = [[] for _ in range(K)]   # accepted, not yet trained
        self.submitted = np.zeros(K, dtype=np.int64)
        self.dropped = np.zeros(K, dtype=np.int64)
        self.trained = np.zeros(K, dtype=np.int64)

    def submit(self, r, uid) -> bool:
        self.submitted[r] += 1
        if len(self.queue[r]) >= CAP:
            self.dropped[r] += 1
            return False
        self.queue[r].append(uid)
        return True

    def drain(self, budget):
        out = []
        for r in range(K):
            n = min(int(budget[r]), len(self.queue[r]))
            del self.queue[r][:n]
            self.trained[r] += n
            out.append(n)
        return np.asarray(out)


def _check(svc, model):
    """Conservation + order invariants (order checked on device after a
    forced flush so staged rows are visible in the ring)."""
    np.testing.assert_array_equal(svc.buffered,
                                  [len(q) for q in model.queue])
    np.testing.assert_array_equal(svc.dropped, model.dropped)
    # conservation: every submitted point is trained, queued or dropped
    np.testing.assert_array_equal(
        model.submitted,
        model.trained + svc.buffered + model.dropped,
    )
    svc.flush()
    for r in range(K):
        assert _device_queue(svc, r) == model.queue[r], (
            f"replica {r}: device ring diverged from FIFO model"
        )


if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(0, K - 1)),
            st.tuples(st.just("submit_rows"),
                      st.integers(1, 2 ** K - 1)),     # nonempty mask bits
            st.tuples(st.just("flush"), st.just(0)),
            st.tuples(st.just("drain"), st.integers(0, 2 * CAP)),
            st.tuples(st.just("tick"), st.integers(0, CHUNK)),
        ),
        max_size=30,
    )

    @settings(max_examples=20, deadline=None)
    @given(ops_seq=_ops, seed=st.integers(0, 2 ** 31 - 1))
    def test_router_no_loss_no_reorder(ops_seq, seed):
        """Arbitrary submit/submit_rows/flush/drain/tick interleavings:
        per-replica FIFO order and datapoint conservation always hold."""
        svc = _make_service(seed)
        model = _Model()
        uid = 0
        for op, arg in ops_seq:
            if op == "submit":
                uid += 1
                x, y = _row(uid)
                assert svc.submit(arg, x, y) == model.submit(arg, uid)
            elif op == "submit_rows":
                uid += 1
                x, y = _row(uid)
                mask = np.array([(arg >> r) & 1 for r in range(K)],
                                dtype=bool)
                got = svc.submit_rows(x, y, mask)
                want = np.array([model.submit(r, uid) if mask[r] else False
                                 for r in range(K)])
                np.testing.assert_array_equal(got, want)
            elif op == "flush":
                svc.flush()
            elif op == "drain":
                np.testing.assert_array_equal(svc.drain(arg),
                                              model.drain([arg] * K))
            else:  # tick (no eval set: drains + cadence only)
                rep = svc.tick(arg)
                np.testing.assert_array_equal(rep.trained,
                                              model.drain([arg] * K))
                assert rep.accuracy is None
        _check(svc, model)


def test_router_block_flush_counts():
    """Auto-flush fires when a staging lane fills: N submits per replica
    cost ceil(N / B_ingress) dispatches, and explicit flush is a no-op
    when nothing is staged."""
    svc = _make_service()
    uid = 0
    for _ in range(BLOCK):        # fill every lane exactly once
        uid += 1
        x, y = _row(uid)
        svc.submit_rows(x, y)
    assert svc.router.flushes == 1      # lanes hit BLOCK -> one dispatch
    np.testing.assert_array_equal(svc.router.staged, [0] * K)
    svc.flush()
    assert svc.router.flushes == 1      # nothing staged: no dispatch
    uid += 1
    x, y = _row(uid)
    svc.submit(0, x, y)
    svc.flush()
    assert svc.router.flushes == 2
    np.testing.assert_array_equal(svc.buffered, [BLOCK + 1, BLOCK, BLOCK])


def test_router_rejects_against_mirror_not_device():
    """Acceptance is decided host-side: a full buffer (device + staged)
    rejects synchronously even though no device dispatch happened yet."""
    svc = _make_service()
    for i in range(CAP):
        x, y = _row(i + 1)
        assert svc.submit(0, x, y)
    x, y = _row(99)
    assert not svc.submit(0, x, y)            # full purely from staging
    np.testing.assert_array_equal(svc.dropped, [1, 0, 0])
    svc.drain(2)                               # frees two slots
    assert svc.submit(0, x, y)
    np.testing.assert_array_equal(svc.buffered, [CAP - 1, 0, 0])


def test_submit_rows_broadcast_contract():
    """The old offer_rows broadcast rules survive the router: [f] and
    [1, f] features (and scalar / [1] labels) fan out to all K replicas."""
    svc = _make_service()
    x, y = _row(5)
    for xs, ys in [(x, y), (x[None], np.asarray([y])),
                   (np.broadcast_to(x, (K, F)), np.full(K, y))]:
        np.testing.assert_array_equal(svc.submit_rows(xs, ys), [True] * K)
    svc.flush()
    for r in range(K):
        assert _device_queue(svc, r) == [5, 5, 5]


def test_mirror_survives_on_chunk_exception():
    """A callback raising mid-drain leaves device state, occupancy mirror
    and acceptance accounting consistent (no phantom backpressure)."""
    svc = _make_service()
    for i in range(CAP):
        x, y = _row(i + 1)
        assert svc.submit(0, x, y)

    class Boom(Exception):
        pass

    calls = []

    def boom(aux):
        calls.append(aux)
        raise Boom

    with pytest.raises(Boom):
        svc.drain(CAP, on_chunk=boom)   # CHUNK < CAP: raises on chunk 1
    assert len(calls) == 1
    consumed = CHUNK                     # exactly one chunk landed
    np.testing.assert_array_equal(svc.buffered, [CAP - consumed, 0, 0])
    np.testing.assert_array_equal(
        svc.buffered[0], int(np.asarray(svc.ss.buf.size[0]))
    )
    x, y = _row(99)
    assert svc.submit(0, x, y)           # no phantom backpressure
    assert svc.drain(2 * CAP)[0] == CAP - consumed + 1


def test_take_block_returns_stable_double_buffered_arrays():
    """Regression (the tentpole's prerequisite bug): take_block used to
    return the LIVE staging arrays and reset counts in place, so any stage
    call racing a flush-in-progress wrote into the block being
    transferred. With double buffering the taken block must stay frozen
    while producers keep staging."""
    from repro.serve.router import BatchRouter

    r = BatchRouter(K, F, capacity=CAP, block=BLOCK)
    dev = np.zeros(K, dtype=np.int64)
    full = np.ones(K, dtype=bool)
    for uid in (1, 2):
        x, y = _row(uid)
        acc, blocked = r.stage_rows(np.broadcast_to(x, (K, F)),
                                    np.full(K, y), full, dev)
        assert acc.all() and not blocked.any()
    xs, ys, counts = r.take_block()
    snap_x, snap_y = xs.copy(), ys.copy()
    np.testing.assert_array_equal(counts, [2] * K)
    # producers keep staging DURING the (simulated) transfer
    for uid in (7, 8, 9):
        x, y = _row(uid)
        r.stage_rows(np.broadcast_to(x, (K, F)), np.full(K, y), full, dev)
    np.testing.assert_array_equal(xs, snap_x)   # taken block untouched
    np.testing.assert_array_equal(ys, snap_y)
    # the swap alternates blocks: the next take hands over the new rows
    xs2, _, counts2 = r.take_block()
    np.testing.assert_array_equal(counts2, [3] * K)
    assert _uid(xs2[0, 0]) == 7 and _uid(xs2[0, 2]) == 9


def test_take_lanes_scopes_to_named_replicas():
    """take_lanes pulls ONLY the named lanes (the scoped-flush path for
    TMService.evict): other lanes stay staged, no block swap happens,
    and the taken rows come out in submission order."""
    from repro.serve.router import BatchRouter

    r = BatchRouter(K, F, capacity=CAP, block=BLOCK)
    dev = np.zeros(K, dtype=np.int64)
    full = np.ones(K, dtype=bool)
    for uid in (1, 2):
        x, y = _row(uid)
        acc, _ = r.stage_rows(np.broadcast_to(x, (K, F)),
                              np.full(K, y), full, dev)
        assert acc.all()
    taken = r.take_lanes([2, 0])
    assert taken is not None
    xs, ys, counts = taken
    np.testing.assert_array_equal(counts, [2, 2])
    for lane in range(2):
        assert [_uid(xs[lane, c]) for c in range(2)] == [1, 2]
    np.testing.assert_array_equal(r.staged, [0, 2, 0])   # lane 1 untouched
    assert r.flushes == 0                                # no block swap
    assert r.take_lanes([0, 2]) is None                  # now empty
    xs2, _, counts2 = r.take_block()                     # lane 1 still there
    np.testing.assert_array_equal(counts2, [0, 2, 0])
    assert _uid(xs2[1, 0]) == 1


if HAVE_HYPOTHESIS:
    _stage_take_ops = st.lists(
        st.one_of(
            st.tuples(st.just("stage"), st.integers(1, 2 ** K - 1)),
            st.tuples(st.just("take"), st.just(0)),
        ),
        max_size=40,
    )

    @settings(max_examples=30, deadline=None)
    @given(ops_seq=_stage_take_ops)
    def test_router_stage_take_interleaving(ops_seq):
        """Arbitrary stage/take interleavings through the double-buffered
        blocks: per replica, the concatenation of taken blocks is exactly
        the accepted rows in submission order — nothing lost, duplicated,
        or reordered."""
        from repro.serve.router import BatchRouter

        r = BatchRouter(K, F, capacity=10 ** 6, block=BLOCK)
        dev = np.zeros(K, dtype=np.int64)
        staged = [[] for _ in range(K)]   # accepted, not yet taken
        taken = [[] for _ in range(K)]
        uid = 0
        for op, arg in ops_seq:
            if op == "stage":
                uid += 1
                x, y = _row(uid)
                mask = np.array([(arg >> i) & 1 for i in range(K)],
                                dtype=bool)
                acc, blocked = r.stage_rows(
                    np.broadcast_to(x, (K, F)), np.full(K, y), mask, dev
                )
                # lane-full replicas block (capacity is huge: never drop)
                np.testing.assert_array_equal(acc | blocked, mask)
                for i in np.nonzero(acc)[0]:
                    staged[i].append(uid)
            else:
                blk = r.take_block()
                if blk is None:
                    assert not any(staged), "rows staged but take gave None"
                    continue
                xs, ys, counts = blk
                for i in range(K):
                    got = [_uid(xs[i, c]) for c in range(int(counts[i]))]
                    taken[i].extend(got)
                    assert staged[i][:len(got)] == got, (
                        f"replica {i}: taken block out of order"
                    )
                    del staged[i][:len(got)]
        while (blk := r.take_block()) is not None:
            xs, ys, counts = blk
            for i in range(K):
                taken[i].extend(_uid(xs[i, c])
                                for c in range(int(counts[i])))
                del staged[i][:int(counts[i])]
        assert not any(staged)   # conservation: everything staged came out


def _make_packed_service(seed=0):
    cfg = TMConfig(n_features=F, max_classes=3, max_clauses=16, n_states=16)
    return TMService(cfg, init_state(cfg), ServiceConfig(
        replicas=K, buffer_capacity=CAP, chunk=CHUNK, ingress_block=BLOCK,
        s=3.0, T=15, seed=seed, packed=True,
    ))


def test_packed_submit_routes_prepacked_uint32_rows():
    """On a packed service, already-packed uint32 word rows pass through
    the staging boundary verbatim — previously `asarray(xs, dtype=bool)`
    silently mangled them into all-ones rows."""
    from repro.kernels.packing import pack_bits_np

    svc_bool, svc_words = _make_packed_service(), _make_packed_service()
    for uid in (5, 9, 1034):
        x, y = _row(uid)
        a = svc_bool.submit_rows(x, y)
        b = svc_words.submit_rows(pack_bits_np(x[None])[0], y)
        np.testing.assert_array_equal(a, b)
    svc_bool.flush(), svc_words.flush()
    for name in ("data_x", "data_y", "head", "size"):
        np.testing.assert_array_equal(
            np.asarray(getattr(svc_bool.ss.buf, name)),
            np.asarray(getattr(svc_words.ss.buf, name)),
        )
    assert np.asarray(svc_words.ss.buf.data_x).dtype == np.uint32


def test_unpacked_submit_rejects_uint32_rows():
    """uint32 rows into an UNPACKED service are a hard error, not a
    silent astype(bool) mangle."""
    svc = _make_service()
    x, y = _row(3)
    packed_row = np.zeros(1, dtype=np.uint32)
    packed_row[0] = 3
    with pytest.raises(TypeError, match="packed"):
        svc.submit_rows(packed_row, y)
    np.testing.assert_array_equal(svc.buffered, [0] * K)   # nothing staged
    assert svc.submit_rows(x, y).all()                     # bool path fine


def test_service_history_limit_bounds_growth():
    """A long-running service's analysis history is a memory leak at
    traffic scale; history_limit keeps only the most recent entries."""
    cfg = TMConfig(n_features=F, max_classes=3, max_clauses=16, n_states=16)
    from repro.data import iris  # noqa: F401  (not needed; uid rows do)

    xs = np.stack([_row(i + 1)[0] for i in range(8)])
    ys = np.asarray([_row(i + 1)[1] for i in range(8)], dtype=np.int32)

    def build(limit):
        return TMService(cfg, init_state(cfg), ServiceConfig(
            replicas=K, buffer_capacity=CAP, chunk=CHUNK, s=3.0, T=15,
            history_limit=limit,
        ), eval_x=xs, eval_y=ys)

    unbounded, bounded = build(None), build(3)
    for _ in range(7):
        unbounded.analyze(), bounded.analyze()
    assert len(unbounded.history) == 7          # legacy behavior
    assert len(bounded.history) == 3            # bounded at the knob
    # the kept entries are the most recent ones, still in order
    for (s_u, a_u), (s_b, a_b) in zip(unbounded.history[-3:],
                                      bounded.history):
        np.testing.assert_array_equal(s_u, s_b)
        np.testing.assert_array_equal(a_u, a_b)
    with pytest.raises(ValueError, match="history_limit"):
        build(0)


def test_service_config_validates_port_lengths():
    """Per-replica s/T sequences must match `replicas` at construction,
    like the seed check — not fail deep in the first drained kernel."""
    from repro.core import TMConfig, init_state
    from repro.serve import ServiceConfig, TMService

    cfg = TMConfig(n_features=F, max_classes=3, max_clauses=16, n_states=16)
    for bad in (dict(s=[1.0, 2.0]), dict(T=[5, 15])):
        with pytest.raises(ValueError, match="per-replica"):
            TMService(cfg, init_state(cfg),
                      ServiceConfig(replicas=4, **bad))


def test_service_requires_eval_set_for_analysis():
    svc = _make_service()
    with pytest.raises(ValueError):
        svc.analyze()
    # but tick without an eval set is a plain drain (no analysis)
    rep = svc.tick(2)
    assert rep.accuracy is None
    assert isinstance(svc.policy, AdaptPolicy)
    assert jnp.ndim(svc.rt.s) == 0
