"""System-operation FSM (Fig 3) + the paper's three use cases at reduced scale.

These are integration tests: small ordering counts / cycles so they run in
seconds on 1 CPU core; the full-scale runs live in benchmarks/.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TMConfig, init_runtime, init_state
from repro.core import faults as faults_mod
from repro.core import manager as mgr
from repro.data import blocks

CFG = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=50)


def _sets_for(o, sets, offline_limit=None):
    n_off = sets.offline_x.shape[1]
    off_valid = (
        np.arange(n_off) < offline_limit if offline_limit is not None
        else np.ones(n_off, dtype=bool)
    )
    return mgr.Sets(
        offline_x=jnp.asarray(sets.offline_x[o]),
        offline_y=jnp.asarray(sets.offline_y[o]),
        offline_valid=jnp.asarray(off_valid),
        validation_x=jnp.asarray(sets.validation_x[o]),
        validation_y=jnp.asarray(sets.validation_y[o]),
        validation_valid=jnp.ones(sets.validation_x.shape[1], dtype=bool),
        online_x=jnp.asarray(sets.online_x[o]),
        online_y=jnp.asarray(sets.online_y[o]),
        online_valid=jnp.ones(sets.online_x.shape[1], dtype=bool),
    )


@pytest.fixture(scope="module")
def iris_sets():
    sets, _ = blocks.iris_paper_sets(n_orderings=3)
    return sets


def test_fig3_flow_shapes(iris_sets):
    sys_cfg = mgr.SystemConfig(n_offline_epochs=3, n_online_cycles=4)
    sets = _sets_for(0, iris_sets, offline_limit=20)
    schedule = mgr.make_schedule(online_s=1.0)
    st, accs, activity = mgr.run_system(
        CFG, sys_cfg, init_state(CFG), init_runtime(CFG, s=1.375, T=15),
        sets, schedule, jax.random.PRNGKey(0),
    )
    assert accs.shape == (5, 3) and activity.shape == (4,)
    assert np.all(np.isfinite(np.asarray(accs)))
    assert np.all((np.asarray(accs) >= 0) & (np.asarray(accs) <= 1))


def test_usecase1_online_learning_improves_accuracy(iris_sets):
    """§5.1: online learning on labelled data raises val/online accuracy."""
    sys_cfg = mgr.SystemConfig(n_offline_epochs=10, n_online_cycles=8)
    gains = []
    for o in range(3):
        sets = _sets_for(o, iris_sets, offline_limit=20)
        st, accs, _ = mgr.run_system(
            CFG, sys_cfg, init_state(CFG), init_runtime(CFG, s=1.375, T=15),
            sets, mgr.make_schedule(online_s=1.0), jax.random.PRNGKey(o),
        )
        accs = np.asarray(accs)
        gains.append(accs[-1, 1] - accs[0, 1])  # validation-set gain
    assert np.mean(gains) > 0.02, f"mean val gain {np.mean(gains)}"


def test_usecase2_class_introduction_recovers(iris_sets):
    """§5.2: class filtered out, introduced at cycle 3; accuracy recovers."""
    sys_cfg = mgr.SystemConfig(n_offline_epochs=10, n_online_cycles=10)
    schedule = mgr.make_schedule(
        online_s=1.0, filtered_class=0, introduce_at_cycle=3
    )
    sets = _sets_for(0, iris_sets)
    st, accs, _ = mgr.run_system(
        CFG, sys_cfg, init_state(CFG), init_runtime(CFG, s=1.375, T=15),
        sets, schedule, jax.random.PRNGKey(0),
    )
    accs = np.asarray(accs)
    # Pre-introduction rows measured on filtered sets; post on full sets.
    dip = accs[4, 1]      # first analysis after introduction (cycle idx 3)
    final = accs[-1, 1]
    assert final >= dip - 0.02, f"no recovery: dip={dip} final={final}"
    assert np.isfinite(accs).all()


def test_usecase3_fault_mitigation(iris_sets):
    """§5.3: 20% stuck-at-0 at cycle 3 — online learning recovers accuracy."""
    sys_cfg = mgr.SystemConfig(n_offline_epochs=10, n_online_cycles=12)
    and_m, or_m = faults_mod.even_spread_stuck_at(CFG, 0.2, 0)
    sets = _sets_for(0, iris_sets, offline_limit=20)

    def run(online_enabled):
        schedule = mgr.make_schedule(
            online_s=1.0, online_enabled=online_enabled,
            fault_masks=(jnp.asarray(and_m), jnp.asarray(or_m)),
            inject_at_cycle=3,
        )
        _, accs, _ = mgr.run_system(
            CFG, sys_cfg, init_state(CFG), init_runtime(CFG, s=1.375, T=15),
            sets, schedule, jax.random.PRNGKey(0),
        )
        return np.asarray(accs)

    with_online = run(True)
    without = run(False)
    # Online learning must end at least as well as frozen-after-fault.
    assert with_online[-1, 1] >= without[-1, 1] - 0.02
    # The frozen system cannot improve after the fault (sanity on the harness).
    assert np.allclose(without[5:, 1], without[5, 1])


def test_orderings_vmap_matches_loop(iris_sets):
    """run_orderings (vmapped CV) == per-ordering run_system loop."""
    sys_cfg = mgr.SystemConfig(n_offline_epochs=2, n_online_cycles=2)
    schedule = mgr.make_schedule(online_s=1.0)
    O = 3
    sets_list = [_sets_for(o, iris_sets, offline_limit=20) for o in range(O)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *sets_list)
    states = jax.vmap(lambda _: init_state(CFG))(jnp.arange(O))
    keys = jax.random.split(jax.random.PRNGKey(9), O)
    rt = init_runtime(CFG, s=1.375, T=15)

    _, accs_v, _ = mgr.run_orderings(
        CFG, sys_cfg, states, rt, stacked, schedule, keys
    )
    for o in range(O):
        _, accs_o, _ = mgr.run_system(
            CFG, sys_cfg, init_state(CFG), rt, sets_list[o], schedule, keys[o]
        )
        np.testing.assert_allclose(
            np.asarray(accs_v)[o], np.asarray(accs_o), atol=1e-6
        )
