"""Statistical paper-faithfulness tests (paper §5, Figures 4-9).

Seeded small-scale runs of the paper's three use cases through the
replica-parallel engine (CrossValRun.system), asserting tolerance bands on
the behaviours the figures claim rather than exact values:

* Fig 4  — online learning on labelled data after a limited (20-of-30)
  offline set: accuracy ordering (offline-set accuracy starts highest) and
  validation/online gains exceeding the offline gain.
* Fig 5/6/7 — class introduction at runtime: a frozen system drops and
  stays down, an online system dips then recovers.
* Fig 8/9 — stuck-at-0 fault injection (core/faults.py): a frozen system's
  accuracy drops and stays down; online learning ends clearly above it.

The tier-1 bands were calibrated over seeds 0..4 at this scale and are
asserted for seeds {0, 1, 2} in CI; ``-m slow`` re-runs the same claims at
the benchmark scale (24 orderings, 16 cycles, the paper's injection cycle).
Every run is deterministic: same seed -> same curves, bit for bit.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.tm_iris import CONFIG as TM_SYS
from repro.core import faults as faults_mod
from repro.core import manager as mgr
from repro.core import tm as tm_mod
from repro.data import blocks
from repro.eval.crossval import CrossValRun, replicate_state

CFG = TM_SYS.tm
SEEDS = [0, 1, 2]
FAULT_FRACTION = 0.75  # stuck-at-0 spread wide enough to dent iris accuracy


@functools.lru_cache(maxsize=4)
def _sets(n_orderings: int, offline_limit):
    osets, _ = blocks.iris_paper_sets(n_orderings=n_orderings)
    O, n_off = osets.offline_y.shape
    train_valid = np.ones((O, n_off), dtype=bool)
    if offline_limit is not None:
        train_valid[:, offline_limit:] = False
    return mgr.Sets(
        offline_x=jnp.asarray(osets.offline_x),
        offline_y=jnp.asarray(osets.offline_y),
        offline_valid=jnp.ones((O, n_off), dtype=bool),
        validation_x=jnp.asarray(osets.validation_x),
        validation_y=jnp.asarray(osets.validation_y),
        validation_valid=jnp.ones(osets.validation_y.shape, dtype=bool),
        online_x=jnp.asarray(osets.online_x),
        online_y=jnp.asarray(osets.online_y),
        online_valid=jnp.ones(osets.online_y.shape, dtype=bool),
        offline_train_valid=jnp.asarray(train_valid),
    ), O


def _mean_curves(schedule, *, seed, n_orderings=6, n_cycles=8,
                 offline_limit=20):
    """Mean accuracy curves [1 + n_cycles, 3] over orderings via the engine."""
    sets, O = _sets(n_orderings, offline_limit)
    sys_cfg = mgr.SystemConfig(
        n_offline_epochs=TM_SYS.n_offline_epochs, n_online_cycles=n_cycles
    )
    rt = tm_mod.init_runtime(CFG, s=TM_SYS.s_offline, T=TM_SYS.T)
    states = replicate_state(CFG, O)
    keys = jax.random.split(jax.random.PRNGKey(seed), O)
    res = CrossValRun(CFG).system(sys_cfg, states, rt, sets, schedule, keys)
    return np.asarray(res.accuracies).mean(axis=0)


# One schedule object per scenario, shared across seeds so the compiled
# system program is traced once per (schedule, scale).
SCHED_FIG4 = mgr.make_schedule(online_s=1.0)
SCHED_FIG5 = mgr.make_schedule(online_s=1.0, filtered_class=0)


def _sched_intro(introduce_at, online):
    return mgr.make_schedule(
        online_s=1.0, filtered_class=0, introduce_at_cycle=introduce_at,
        online_enabled=online,
    )


def _sched_fault(inject_at, online):
    and_m, or_m = faults_mod.even_spread_stuck_at(CFG, FAULT_FRACTION, 0)
    return mgr.make_schedule(
        online_s=1.0, fault_masks=(jnp.asarray(and_m), jnp.asarray(or_m)),
        inject_at_cycle=inject_at, online_enabled=online,
    )


SCHED_FIG6 = _sched_intro(3, online=False)
SCHED_FIG7 = _sched_intro(3, online=True)
SCHED_FIG8 = _sched_fault(3, online=False)
SCHED_FIG9 = _sched_fault(3, online=True)


@pytest.mark.parametrize("seed", SEEDS)
def test_fig4_limited_data_accuracy_ordering(seed):
    c = _mean_curves(SCHED_FIG4, seed=seed)
    start_off, start_val, start_onl = c[0]
    gain_off, gain_val, gain_onl = c[-1] - c[0]

    # Starting ordering (paper: 83% offline > 79.5% validation/online): the
    # trained-on set leads the held-out sets.
    assert start_off >= start_val + 0.05, (start_off, start_val)
    assert 0.70 <= start_val <= 0.90, start_val
    assert 0.70 <= start_onl <= 0.90, start_onl

    # Online learning lifts the held-out sets more than the offline set
    # (paper: ~+12% val/online vs ~+5% offline at full scale).
    assert gain_val >= 0.02, gain_val
    assert gain_onl >= 0.02, gain_onl
    assert gain_val >= gain_off + 0.02, (gain_val, gain_off)
    assert gain_onl >= gain_off + 0.02, (gain_onl, gain_off)
    assert c[-1, 1] >= 0.84, c[-1, 1]


@pytest.mark.parametrize("seed", SEEDS)
def test_fig567_class_introduction_recovery(seed):
    intro = 3
    c5 = _mean_curves(SCHED_FIG5, seed=seed, offline_limit=None)
    c6 = _mean_curves(SCHED_FIG6, seed=seed, offline_limit=None)
    c7 = _mean_curves(SCHED_FIG7, seed=seed, offline_limit=None)

    # Fig 5 baseline: with the class filtered forever, the 2-class problem
    # stays solved (no spurious degradation from the over-provisioned slot).
    assert c5[-1, 1] >= c5[0, 1] - 0.02, (c5[0, 1], c5[-1, 1])

    # Fig 6: introduction with online learning DISABLED — the validation
    # accuracy drops hard at the first post-introduction analysis...
    drop = c6[intro + 1, 1] - c6[intro, 1]
    assert drop <= -0.15, drop
    # ...and stays down (a frozen machine cannot learn the new class).
    np.testing.assert_allclose(c6[intro + 1:, 1], c6[intro + 1, 1], atol=1e-6)
    assert c6[-1, 1] <= 0.70, c6[-1, 1]

    # Fig 7: with online learning the machine dips then RECOVERS.
    dip = c7[intro + 1, 1]
    assert c7[-1, 1] >= dip + 0.02, (dip, c7[-1, 1])
    assert c7[-1, 1] >= 0.82, c7[-1, 1]
    assert c7[-1, 1] >= c6[-1, 1] + 0.15, (c7[-1, 1], c6[-1, 1])


@pytest.mark.parametrize("seed", SEEDS)
def test_fig89_fault_drop_then_recover(seed):
    inject = 3
    c8 = _mean_curves(SCHED_FIG8, seed=seed)
    c9 = _mean_curves(SCHED_FIG9, seed=seed)

    # Fig 8: frozen system — accuracy drops at the first post-injection
    # analysis and stays down.
    drop = c8[inject + 1, 1] - c8[inject, 1]
    assert drop <= -0.05, drop
    np.testing.assert_allclose(c8[inject + 1:, 1], c8[inject + 1, 1], atol=1e-6)

    # Fig 9: online system — dips at injection, then ends clearly above the
    # frozen system (the paper's mitigation claim).
    dip = c9[inject + 1, 1] - c9[inject, 1]
    assert dip <= -0.04, dip
    assert c9[-1, 1] >= c9[inject + 1, 1] - 0.06  # no further decay
    assert c9[-1, 1] >= c8[-1, 1] + 0.04, (c9[-1, 1], c8[-1, 1])


# --------------------------------------------------------------------------
# Full-scale variants (benchmark scale: 24 orderings, 16 cycles, the
# paper's injection/introduction cycle 5). `pytest -m slow`.
# --------------------------------------------------------------------------

SLOW = dict(n_orderings=24, n_cycles=16)


@pytest.mark.slow
def test_fig4_full_scale():
    c = _mean_curves(SCHED_FIG4, seed=0, **SLOW)
    start_off, start_val, start_onl = c[0]
    gain_off, gain_val, gain_onl = c[-1] - c[0]
    assert start_off >= start_val + 0.05
    assert 0.72 <= start_val <= 0.88
    assert gain_val >= 0.04 and gain_onl >= 0.04
    assert gain_val >= gain_off + 0.03
    assert c[-1, 1] >= 0.85


@pytest.mark.slow
def test_fig567_full_scale():
    intro = 5
    sched6 = _sched_intro(intro, online=False)
    sched7 = _sched_intro(intro, online=True)
    c6 = _mean_curves(sched6, seed=0, offline_limit=None, **SLOW)
    c7 = _mean_curves(sched7, seed=0, offline_limit=None, **SLOW)
    assert c6[intro + 1, 1] - c6[intro, 1] <= -0.15
    np.testing.assert_allclose(c6[intro + 1:, 1], c6[intro + 1, 1], atol=1e-6)
    assert c7[-1, 1] >= c7[intro + 1, 1] + 0.02
    assert c7[-1, 1] >= c6[-1, 1] + 0.15


@pytest.mark.slow
def test_fig89_full_scale():
    inject = 5
    sched8 = _sched_fault(inject, online=False)
    sched9 = _sched_fault(inject, online=True)
    c8 = _mean_curves(sched8, seed=0, **SLOW)
    c9 = _mean_curves(sched9, seed=0, **SLOW)
    assert c8[inject + 1, 1] - c8[inject, 1] <= -0.05
    np.testing.assert_allclose(c8[inject + 1:, 1], c8[inject + 1, 1], atol=1e-6)
    assert c9[-1, 1] >= c8[-1, 1] + 0.04
