"""Residency + durable state: K logical replicas on R device slots.

Three contracts (DESIGN.md §15), all bitwise:

* an evicted/reactivated replica's trajectory equals its always-resident
  twin's (the per-replica independence of the replicated drain makes the
  slot a replica sits in irrelevant);
* save -> restore -> continue equals never stopping (TA banks, RNG keys,
  ring buffers, policy FSM), packed and unpacked, both backends;
* no datapoint is lost or reordered per replica under arbitrary
  submit/tick/save/restore/evict/activate interleavings (extends the
  test_router.py FIFO-model property to the residency layer).

Plus the §5.3.2 regression the residency work surfaced: AdaptPolicy's
first due analysis with ``best_state=None`` (no offline-train baseline)
used to crash in ``_select_replicas`` with a pytree structure mismatch.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TMConfig, init_state
from repro.serve import AdaptPolicy, ServiceConfig, TMService

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency (requirements-dev.txt)
    HAVE_HYPOTHESIS = False

K, CAP, BLOCK, CHUNK, F = 6, 8, 4, 4, 16

_RNG = np.random.default_rng(42)
EVAL_X = _RNG.random((24, F)) > 0.5
EVAL_Y = _RNG.integers(0, 3, 24)


def _cfg(backend="ref"):
    return TMConfig(n_features=F, max_classes=3, max_clauses=16,
                    n_states=16, backend=backend)


def _service(resident=None, *, packed=False, backend="ref", seed=7,
             with_eval=True, analyze_every=8, batched=True):
    cfg = _cfg(backend)
    sc = ServiceConfig(
        replicas=K, buffer_capacity=CAP, chunk=CHUNK, ingress_block=BLOCK,
        packed=packed, s=3.0, T=15, seed=seed, resident=resident,
        batched_moves=batched,
        policy=AdaptPolicy(analyze_every=analyze_every,
                           rollback_threshold=0.1),
    )
    kw = (dict(eval_x=EVAL_X, eval_y=EVAL_Y) if with_eval else {})
    return TMService(cfg, init_state(cfg), sc, **kw)


def _drive(svc, n, seed, tick_every=4):
    r = np.random.default_rng(seed)
    for i in range(n):
        svc.submit_rows(r.random(F) > 0.5, int(r.integers(0, 3)))
        if i % tick_every == tick_every - 1:
            svc.tick()
    svc.flush()


def _state_leaves(svc):
    return [np.asarray(l)
            for l in jax.tree.leaves((svc.ss, svc.rng_keys, svc.steps,
                                      svc.since_analysis, svc.rollbacks))]


def _assert_same_state(a, b, msg=""):
    for la, lb in zip(_state_leaves(a), _state_leaves(b)):
        np.testing.assert_array_equal(la, lb, err_msg=msg)
    np.testing.assert_array_equal(a._ps.best, b._ps.best, err_msg=msg)


# ---------------------------------------------------------------------------
# The best_state=None first-due regression (§5.3.2 without a baseline)
# ---------------------------------------------------------------------------


def test_adapt_policy_first_due_without_baseline():
    """A policy initialized WITHOUT offline_train/snapshot (best_state is
    None) must survive its first due analysis: the first improve is an
    unconditional snapshot, not a _select_replicas pytree crash."""
    pol = AdaptPolicy(analyze_every=4, rollback_threshold=0.1)
    ps = pol.init(3)
    assert ps.best_state is None
    cfg = _cfg()
    tm = jax.tree.map(lambda a: jnp.broadcast_to(a, (3,) + a.shape),
                      init_state(cfg))
    ps.since[:] = 4
    due = pol.due(ps)
    assert due.all()
    acc = np.asarray([0.5, 0.4, 0.6], dtype=np.float32)
    tm2, rolled = pol.apply(ps, due, acc, tm)  # pre-fix: crashed here
    assert not rolled.any()
    np.testing.assert_array_equal(ps.best, acc.astype(np.float64))
    assert ps.best_state is not None
    # ... and the snapshot is live: a later collapse rolls back to it
    ps.since[:] = 4
    bad = np.asarray([0.1, 0.4, 0.6], dtype=np.float32)
    tm3, rolled = pol.apply(ps, pol.due(ps), bad, tm2)
    assert rolled.tolist() == [True, False, False]
    np.testing.assert_array_equal(
        np.asarray(tm3.ta_state[0]), np.asarray(ps.best_state.ta_state[0])
    )


def test_service_cold_start_first_due_analysis():
    """A fresh service (already-trained state handed in, never calling
    offline_train) ticks through its first due analysis without a
    baseline: best_state starts None and the first improve snapshots."""
    svc = _service(analyze_every=8)
    assert svc._ps.best_state is None
    reported = None
    r = np.random.default_rng(0)
    for i in range(24):
        svc.submit_rows(r.random(F) > 0.5, int(r.integers(0, 3)))
        rep = svc.tick()
        if rep.accuracy is not None:
            reported = rep
    assert reported is not None, "never reached a due analysis"
    assert svc._ps.best_state is not None
    assert not np.isnan(svc._ps.best).any()


# ---------------------------------------------------------------------------
# Residency: twin-bitwise, explicit evict/activate, serve_replicas
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("packed", [False, True])
def test_residency_twin_bitwise(packed):
    """K=6 on 2 slots vs an always-resident fleet driven with budgets
    masked by `buffered > 0` (the residency drain's sweep criterion):
    every replica's full trajectory — TA bank, RNG key, ring buffer,
    step, policy FSM — is bitwise identical, across many evictions."""
    twin = _service(None, packed=packed)
    res = _service(2, packed=packed)
    r = np.random.default_rng(3)
    for i in range(40):
        x, y = r.random(F) > 0.5, int(r.integers(0, 3))
        twin.submit_rows(x, y)
        res.submit_rows(x, y)
        if i % 4 == 3:
            res.flush()
            mask = res.buffered > 0
            res.tick()
            twin.tick(np.where(mask, twin.chunk, 0))
    assert res._res.evictions > 10, "traffic never contended the slots"
    _assert_same_state(twin, res)
    # prediction parity on the (identical) per-replica states
    xs = _RNG.random((5, F)) > 0.5
    np.testing.assert_array_equal(
        twin.serve_replicas([0, 3, 5], xs), res.serve_replicas([0, 3, 5], xs)
    )


def test_explicit_evict_activate_roundtrip():
    svc = _service(3, with_eval=False)
    _drive(svc, 20, seed=5)
    before = _state_leaves(svc)
    buffered = svc.buffered.copy()
    svc.evict(np.arange(K))          # spill everything (<= R at a time)
    assert svc.resident.sum() == 0
    np.testing.assert_array_equal(svc.buffered, buffered)  # nothing lost
    svc.activate([4, 1, 0])
    assert set(np.nonzero(svc.resident)[0]) == {0, 1, 4}
    for la, lb in zip(before, _state_leaves(svc)):
        np.testing.assert_array_equal(la, lb)


def test_serve_replicas_matches_full_serve():
    svc = _service(None, with_eval=False)
    _drive(svc, 20, seed=9)
    xs = _RNG.random((7, F)) > 0.5
    full = svc.serve(xs)
    np.testing.assert_array_equal(svc.serve_replicas([5, 0, 2], xs),
                                  full[[5, 0, 2]])


def test_residency_rejects_wholesale_state_and_full_serve():
    svc = _service(2, with_eval=False)
    # the refusal must name BOTH ways out: serve_replicas for named
    # members, and the 'resident' knob to cover the fleet
    with pytest.raises(ValueError) as ei:
        svc.serve(_RNG.random((2, F)) > 0.5)
    assert "serve_replicas" in str(ei.value)
    assert "resident" in str(ei.value)
    with pytest.raises(ValueError, match="restore"):
        svc.ss = svc.ss
    with pytest.raises(ValueError, match="resident"):
        _service(2, with_eval=False).offline_train(EVAL_X, EVAL_Y, 1)
    with pytest.raises(ValueError, match="scalar s/T"):
        cfg = _cfg()
        TMService(cfg, init_state(cfg), ServiceConfig(
            replicas=K, resident=2, s=[3.0] * K, T=15, seed=0))


def test_residency_policy_rollback_matches_twin():
    """The §5.3.2 FSM under residency (host-side best banks) transitions
    identically to the always-resident policy, including rollbacks."""
    twin = _service(None, analyze_every=4)
    res = _service(2, analyze_every=4)
    r = np.random.default_rng(17)
    for i in range(60):
        x, y = r.random(F) > 0.5, int(r.integers(0, 3))
        twin.submit_rows(x, y)
        res.submit_rows(x, y)
        res.flush()
        mask = res.buffered > 0
        res.tick()
        twin.tick(np.where(mask, twin.chunk, 0))
    np.testing.assert_array_equal(twin.rollbacks, res.rollbacks)
    np.testing.assert_array_equal(twin._ps.since, res._ps.since)
    np.testing.assert_array_equal(twin._ps.best, res._ps.best)
    if twin._ps.best_state is not None:
        np.testing.assert_array_equal(
            np.asarray(twin._ps.best_state.ta_state), res._best_host
        )
    _assert_same_state(twin, res)


def test_sharded_residency_matches_unsharded_twin():
    """The resident plane sharded grid-major over whatever devices exist
    (the CI `multidevice` job forces 4 host devices) runs the full
    evict/activate lifecycle bitwise equal to an UNSHARDED always-
    resident fleet — extending the sharded-vs-1-device assertion to the
    residency layer."""
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()), ("data",))
    cfg = _cfg()
    sc = ServiceConfig(
        replicas=K, buffer_capacity=CAP, chunk=CHUNK, ingress_block=BLOCK,
        s=3.0, T=15, seed=7, resident=len(jax.devices()), mesh=mesh,
        policy=AdaptPolicy(analyze_every=8, rollback_threshold=0.1),
    )
    res = TMService(cfg, init_state(cfg), sc, eval_x=EVAL_X, eval_y=EVAL_Y)
    twin = _service(None)
    r = np.random.default_rng(3)
    for i in range(32):
        x, y = r.random(F) > 0.5, int(r.integers(0, 3))
        twin.submit_rows(x, y)
        res.submit_rows(x, y)
        if i % 4 == 3:
            res.flush()
            mask = res.buffered > 0
            res.tick()
            twin.tick(np.where(mask, twin.chunk, 0))
    assert res._res.evictions > 0
    _assert_same_state(twin, res)


# ---------------------------------------------------------------------------
# Batched moves (§17): multi-cohort superblocks, scoped evict, auto slots
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("packed", [False, True])
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_multicohort_batched_matches_sync_oracle(backend, packed):
    """EVERY lane hot on 2 slots (hot-lane count = 3x resident, so each
    flush and each drain sweep runs 3 cohorts through the coalesced
    superblock path): the batched datapath — fused activate+enqueue,
    deferred spill settlement — lands bitwise on PR 8's synchronous
    per-cohort oracle AND on the always-resident twin."""
    batched = _service(2, packed=packed, backend=backend)
    oracle = _service(2, packed=packed, backend=backend, batched=False)
    twin = _service(None, packed=packed, backend=backend)
    assert batched._batched and not oracle._batched
    r = np.random.default_rng(11)
    for i in range(10):
        for _ in range(2):   # all K lanes hot every round
            x, y = r.random(F) > 0.5, int(r.integers(0, 3))
            for svc in (batched, oracle, twin):
                svc.submit_rows(x, y)
        for svc in (batched, oracle, twin):
            svc.tick(2)
    assert batched._res.evictions > 10, "slots were never contended"
    _assert_same_state(oracle, batched, "batched diverged from oracle")
    _assert_same_state(twin, batched, "batched diverged from twin")


def test_scoped_evict_leaves_other_lanes_staged():
    """evict() lands ONLY the named replicas' staged rows (take_lanes),
    not the whole fleet's: other lanes stay staged (no block swap, no
    flush dispatch), and the evicted member's rows are in its spilled
    ring — nothing lost, nothing reordered."""
    svc = _service(2, with_eval=False)
    r = np.random.default_rng(2)
    for _ in range(3):
        svc.submit_rows(r.random(F) > 0.5, int(r.integers(0, 3)))
    staged_before = svc.router.staged
    assert (staged_before == 3).all()
    buffered_before = svc.buffered.copy()
    flushes_before = svc.router.flushes
    svc.evict([1])
    assert not svc.resident[1]
    staged = svc.router.staged
    assert staged[1] == 0, "the evicted lane must land before the spill"
    np.testing.assert_array_equal(
        staged[[0, 2, 3, 4, 5]], staged_before[[0, 2, 3, 4, 5]]
    )
    assert svc.router.flushes == flushes_before, "scoped path swapped a block"
    np.testing.assert_array_equal(svc.buffered, buffered_before)
    # the landed rows really are in the spilled snapshot's ring
    assert int(np.asarray(svc.ss.buf.size)[1]) == 3


def test_auto_resident_grow_shrink_trajectory():
    """resident='auto': dense traffic grows the plane (the EWMA active
    set no longer fits), sparse traffic shrinks it back through the
    hysteresis band — and the trajectory stays bitwise equal to the
    always-resident twin across every re-partition."""
    auto = _service("auto")
    twin = _service(None)
    assert auto.n_resident == 2        # ceil(K / 4) initial slots
    r = np.random.default_rng(23)

    def step(n_lanes):
        mask = np.zeros(K, dtype=bool)
        mask[:n_lanes] = True
        x, y = r.random(F) > 0.5, int(r.integers(0, 3))
        auto.submit_rows(x, y, mask)
        twin.submit_rows(x, y, mask)
        auto.flush()
        drive = auto.buffered > 0
        auto.tick()
        twin.tick(np.where(drive, twin.chunk, 0))

    for _ in range(8):
        step(K)                        # dense: every lane active
    grown = auto.n_resident
    assert grown > 2, "dense traffic never grew the plane"
    for _ in range(12):
        step(1)                        # sparse: one active lane
    assert auto.n_resident < grown, "sparse traffic never shrank the plane"
    assert auto.repartitions >= 2
    _assert_same_state(twin, auto, "trajectory changed across re-partitions")


def test_auto_resident_save_restore_continuation_bitwise(tmp_path):
    """save -> restore -> continue stays bitwise under resident='auto':
    the checkpoint is residency-agnostic, the restored service re-sizes
    on its own traffic, and neither side's trajectory moves."""
    svc = _service("auto")
    _drive(svc, 20, seed=5)
    svc.save(str(tmp_path))
    svc.load(str(tmp_path))
    other = TMService.restore(str(tmp_path), eval_x=EVAL_X, eval_y=EVAL_Y)
    assert other.sc.resident == "auto" and other._auto
    _assert_same_state(svc, other, "restore changed state")
    _drive(svc, 30, seed=11)
    _drive(other, 30, seed=11)
    _assert_same_state(svc, other, "post-restore trajectories diverged")


# ---------------------------------------------------------------------------
# Durable state: the save -> restore -> continue oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("packed", [False, True])
@pytest.mark.parametrize("resident", [None, 3])
def test_save_restore_continuation_bitwise(packed, resident, tmp_path):
    """save -> restore -> continue == never stopping, bitwise: TA banks,
    RNG keys, ring buffers, steps, policy FSM, analysis history."""
    svc = _service(resident, packed=packed)
    _drive(svc, 20, seed=5)
    svc.save(str(tmp_path))
    # realign the writer's residency partitioning with the reader's
    # (first-R resident — partitioning is NOT part of the logical state)
    svc.load(str(tmp_path))
    other = TMService.restore(str(tmp_path), eval_x=EVAL_X, eval_y=EVAL_Y)
    assert other.sc.packed == packed and other.sc.resident == resident
    _assert_same_state(svc, other, "restore changed state")
    assert len(other.history) == len(svc.history)
    _drive(svc, 30, seed=11)
    _drive(other, 30, seed=11)
    _assert_same_state(svc, other, "post-restore trajectories diverged")
    np.testing.assert_array_equal(svc.rollbacks, other.rollbacks)
    np.testing.assert_array_equal(svc.dropped, other.dropped)


@pytest.mark.parametrize("packed", [False, True])
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_save_restore_continuation_backends(backend, packed, tmp_path):
    """The round-trip oracle on both kernel backends, packed and
    unpacked (the pallas cases are what the kernels-pallas CI job pins):
    trajectories AND served predictions stay bitwise."""
    svc = _service(2, backend=backend, packed=packed)
    _drive(svc, 12, seed=5)
    svc.save(str(tmp_path))
    svc.load(str(tmp_path))
    other = TMService.restore(str(tmp_path), eval_x=EVAL_X, eval_y=EVAL_Y)
    _drive(svc, 12, seed=11)
    _drive(other, 12, seed=11)
    _assert_same_state(svc, other, f"{backend} restore diverged")
    xs = _RNG.random((4, F)) > 0.5
    np.testing.assert_array_equal(svc.serve_replicas(np.arange(K), xs),
                                  other.serve_replicas(np.arange(K), xs))


def test_restore_migrates_across_resident_budgets(tmp_path):
    """One checkpoint, any device budget: the assembled logical fleet is
    identical restored fully-resident, at R=1, or at the saved R."""
    svc = _service(3)
    _drive(svc, 25, seed=5)
    svc.save(str(tmp_path))
    restored = [TMService.restore(str(tmp_path), resident=r,
                                  eval_x=EVAL_X, eval_y=EVAL_Y)
                for r in (None, 1, 3)]
    assert [s.n_resident for s in restored] == [K, 1, 3]
    for other in restored[1:]:
        _assert_same_state(restored[0], other, "migration changed state")


def test_save_flushes_staged_ingress(tmp_path):
    """Rows staged but not yet flushed at save time are in the saved
    rings — a checkpoint never loses accepted traffic."""
    svc = _service(2, with_eval=False)
    svc.submit_rows(np.ones(F, dtype=bool), 1)
    assert svc.router.staged.sum() > 0 or svc.buffered.sum() > 0
    svc.save(str(tmp_path))
    other = TMService.restore(str(tmp_path))
    np.testing.assert_array_equal(other.buffered, [1] * K)


def test_fleet_save_restore_passthrough(tmp_path):
    """The OnlineFleet shim checkpoints and rebuilds through the service
    surface; continuation stays bitwise."""
    from repro.core import init_runtime
    from repro.serve import OnlineFleet

    cfg = _cfg()
    rt = init_runtime(cfg, s=3.0, T=15)
    fleet = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=4, seed=3)
    r = np.random.default_rng(0)
    for _ in range(10):
        fleet.offer_rows(r.random(F) > 0.5, int(r.integers(0, 3)))
        fleet.drain(2)
    fleet.save(str(tmp_path))
    other = OnlineFleet.restore(str(tmp_path))
    for _ in range(10):
        x, y = r.random(F) > 0.5, int(r.integers(0, 3))
        fleet.offer_rows(x, y)
        other.offer_rows(x, y)
        np.testing.assert_array_equal(fleet.drain(2), other.drain(2))
    for la, lb in zip(jax.tree.leaves(fleet.ss), jax.tree.leaves(other.ss)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_restore_rejects_mismatched_shape(tmp_path):
    svc = _service(2, with_eval=False)
    svc.save(str(tmp_path))
    wrong = _service(None, packed=True, with_eval=False)
    with pytest.raises(ValueError, match="packed"):
        wrong.load(str(tmp_path))


# ---------------------------------------------------------------------------
# Hypothesis: arbitrary interleavings (FIFO model + always-resident twin)
# ---------------------------------------------------------------------------


def _row(uid: int):
    x = np.array([(uid >> b) & 1 for b in range(F)], dtype=bool)
    return x, uid % 3


def _uid(x: np.ndarray) -> int:
    return int(sum(int(v) << b for b, v in enumerate(x)))


def _rings(svc):
    """Per-replica assembled ring content, oldest first, as uids."""
    buf = svc.ss.buf
    out = []
    for r in range(K):
        data_x = np.asarray(buf.data_x[r])
        head = int(np.asarray(buf.head[r]))
        size = int(np.asarray(buf.size[r]))
        out.append([_uid(data_x[(head + i) % CAP]) for i in range(size)])
    return out


class _Model:
    """Host-side reference: per-replica FIFO + conservation counters."""

    def __init__(self):
        self.queue = [[] for _ in range(K)]
        self.dropped = np.zeros(K, dtype=np.int64)

    def submit(self, uid, mask):
        ok = np.zeros(K, dtype=bool)
        for r in range(K):
            if not mask[r]:
                continue
            if len(self.queue[r]) >= CAP:
                self.dropped[r] += 1
            else:
                self.queue[r].append(uid)
                ok[r] = True
        return ok

    def drain(self, budget):
        for r in range(K):
            del self.queue[r][:min(int(budget[r]), len(self.queue[r]))]


if HAVE_HYPOTHESIS:
    _ops = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(1, 2 ** K - 1)),
            st.tuples(st.just("flush"), st.just(0)),
            st.tuples(st.just("tick"), st.integers(0, CHUNK)),
            st.tuples(st.just("evict"), st.integers(0, K - 1)),
            st.tuples(st.just("activate"), st.integers(0, K - 1)),
            st.tuples(st.just("saverestore"), st.just(0)),
        ),
        max_size=25,
    )

    @settings(max_examples=15, deadline=None)
    @given(ops_seq=_ops, seed=st.integers(0, 2 ** 31 - 1))
    def test_residency_interleavings_no_divergence_no_loss(ops_seq, seed):
        """Arbitrary submit/flush/tick/evict/activate/save/restore
        interleavings: (1) every replica's trajectory stays bitwise equal
        to its never-evicted twin's, (2) per-replica FIFO order and
        conservation hold on the assembled rings."""
        res = _service(2, seed=seed, with_eval=False)
        twin = _service(None, seed=seed, with_eval=False)
        model = _Model()
        uid = 0
        with tempfile.TemporaryDirectory() as ckdir:
            for op, arg in ops_seq:
                if op == "submit":
                    uid += 1
                    x, y = _row(uid)
                    mask = np.array([(arg >> r) & 1 for r in range(K)],
                                    dtype=bool)
                    got = res.submit_rows(x, y, mask)
                    np.testing.assert_array_equal(
                        got, twin.submit_rows(x, y, mask))
                    np.testing.assert_array_equal(
                        got, model.submit(uid, mask))
                elif op == "flush":
                    res.flush()
                    twin.flush()
                elif op == "tick":
                    res.flush()
                    twin.flush()
                    mask = res.buffered > 0
                    trained = res.tick(arg).trained
                    budget = np.where(mask, arg, 0)
                    np.testing.assert_array_equal(
                        trained, twin.tick(budget).trained)
                    model.drain(budget)
                elif op == "evict":
                    res.evict([arg])
                    twin.flush()  # evict flushes staged ingress first
                elif op == "activate":
                    res.activate([arg])
                else:  # saverestore: self round-trip mid-stream
                    res.save(ckdir)
                    res.load(ckdir)
                    twin.flush()  # save flushes staged ingress first
            np.testing.assert_array_equal(res.buffered, twin.buffered)
            np.testing.assert_array_equal(res.dropped, model.dropped)
            np.testing.assert_array_equal(
                res.buffered, [len(q) for q in model.queue])
            _assert_same_state(twin, res, "twin diverged")
            assert _rings(res) == model.queue, "ring diverged from FIFO"
