"""Batch-first kernel path + backend dispatch layer.

The batch contract (DESIGN.md §8): ``clause_eval_batch(include, lits_B)``
must equal stacking the per-sample kernel over rows bit-for-bit, on every
backend, for every shape — including the awkward ones (B=1, B=257, L not a
multiple of the 128-lane tile).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    TMConfig, init_runtime, init_state, predict, predict_batch,
)
from repro.core import accuracy as acc_mod
from repro.core import online as online_mod
from repro.core import tm as tm_mod
from repro.kernels import dispatch, ops, ref

# (C, J, L, B) — odd shapes on purpose: batch of 1, batch over the lane
# count (257), literal axes that straddle the 128-lane tile boundary.
BATCH_SHAPES = [
    (1, 2, 5, 1),
    (3, 16, 32, 7),
    (2, 6, 17, 257),
    (4, 33, 129, 33),
    (3, 16, 200, 128),
]


def _rand_case(shape, seed=None):
    C, J, L, B = shape
    rng = np.random.default_rng(seed if seed is not None else hash(shape) % 2**31)
    include = jnp.asarray(rng.random((C, J, L)) < 0.3)
    lits = jnp.asarray(rng.random((B, L)) < 0.5)
    return include, lits


@pytest.mark.parametrize("shape", BATCH_SHAPES)
@pytest.mark.parametrize("training", [True, False])
def test_clause_eval_batch_matches_per_sample_loop(shape, training):
    include, lits = _rand_case(shape)
    want = ref.clause_eval_loop(include, lits, training=training)
    for backend in ("ref", "pallas"):
        kb = dispatch.resolve(backend)
        got = kb.clause_eval_batch(include, lits, training=training)
        np.testing.assert_array_equal(
            np.asarray(want), np.asarray(got), err_msg=f"backend={backend}"
        )


@pytest.mark.parametrize("shape", BATCH_SHAPES[:3])
def test_clause_eval_batch_ref_pallas_bit_parity(shape):
    include, lits = _rand_case(shape, seed=11)
    for training in (True, False):
        a = ref.clause_eval_batch(include, lits, training=training)
        b = ops.clause_eval_batch(include, lits, training=training)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_clause_eval_batch_empty_clause_convention():
    include = jnp.zeros((2, 4, 32), dtype=bool)  # every clause empty
    lits = jnp.asarray(np.random.default_rng(0).random((5, 32)) < 0.5)
    for backend in ("ref", "pallas"):
        kb = dispatch.resolve(backend)
        assert bool(jnp.all(kb.clause_eval_batch(include, lits, training=True)))
        assert not bool(jnp.any(kb.clause_eval_batch(include, lits, training=False)))


def test_dispatch_registry_names_and_auto():
    assert set(dispatch.available()) >= {"ref", "pallas", "auto"}
    assert dispatch.resolve("ref").name == "ref"
    assert dispatch.resolve("pallas").name == "pallas"
    # TM_BACKEND (the CI kernel-parity job) overrides auto-resolution;
    # otherwise auto means pallas on TPU, ref elsewhere.
    expect = os.environ.get(
        "TM_BACKEND", "pallas" if jax.default_backend() == "tpu" else "ref"
    )
    assert dispatch.resolve("auto").name == expect
    with pytest.raises(ValueError):
        dispatch.resolve("no-such-backend")


def test_dispatch_register_custom_backend():
    calls = {"n": 0}

    def factory():
        base = dispatch.resolve("ref")
        calls["n"] += 1
        return base._replace(name="custom")

    dispatch.register("custom", factory)
    try:
        assert dispatch.resolve("custom").name == "custom"
        dispatch.resolve("custom")
        assert calls["n"] == 1  # factory result is cached
        cfg = TMConfig(n_features=4, max_classes=2, max_clauses=4,
                       backend="custom")
        assert cfg.backend == "custom"
    finally:
        dispatch._FACTORIES.pop("custom", None)
        dispatch._CACHE.pop("custom", None)


def test_config_rejects_unknown_backend_accepts_auto():
    with pytest.raises(ValueError):
        TMConfig(n_features=4, max_classes=2, max_clauses=4, backend="nope")
    cfg = TMConfig(n_features=4, max_classes=2, max_clauses=4, backend="auto")
    assert cfg.backend == "auto"


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_predict_batch_bitwise_matches_vmap_of_predict(backend):
    """The acceptance contract: batch-first serving == per-sample serving."""
    from repro.data import iris

    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=50,
                   backend=backend)
    st = init_state(cfg, jax.random.PRNGKey(2))
    rt = init_runtime(cfg)
    xs, _ = iris.load()
    xs = jnp.asarray(xs)
    batched = predict_batch(cfg, st, rt, xs)
    vmapped = jax.vmap(lambda x: predict(cfg, st, rt, x))(xs)
    np.testing.assert_array_equal(np.asarray(batched), np.asarray(vmapped))


def test_analyze_matches_per_sample_predictions():
    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=50)
    st = init_state(cfg, jax.random.PRNGKey(3))
    rt = init_runtime(cfg)
    rng = np.random.default_rng(4)
    xs = jnp.asarray(rng.random((40, 16)) < 0.5)
    ys = jnp.asarray(rng.integers(0, 3, 40), dtype=jnp.int32)
    valid = jnp.asarray(rng.random(40) < 0.8)
    preds = jax.vmap(lambda x: predict(cfg, st, rt, x))(xs)
    ok = (np.asarray(preds) == np.asarray(ys)) & np.asarray(valid)
    want = ok.sum() / max(np.asarray(valid).sum(), 1)
    got = float(acc_mod.analyze(cfg, st, rt, xs, ys, valid))
    assert abs(got - want) < 1e-6


def test_consume_many_matches_serial_updates():
    """_consume_many == a hand loop of train_update over the same keys."""
    from repro.core import feedback as fb_mod
    from repro.data import buffer as buf_mod
    from repro.data import iris

    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=50)
    st = init_state(cfg, jax.random.PRNGKey(5))
    rt = init_runtime(cfg, s=3.0, T=15)
    xs, ys = iris.load()
    K = 8
    buf = buf_mod.make(16, cfg.n_features)
    for i in range(K):
        buf, ok = buf_mod.push(
            buf, jnp.asarray(xs[i], dtype=bool), jnp.int32(ys[i])
        )
        assert bool(ok)
    ss = online_mod.SessionState(tm=st, buf=buf, step=jnp.int32(0))

    key = jax.random.PRNGKey(9)
    out, n, aux = online_mod._consume_many(cfg, K, ss, rt, jnp.int32(K), key)
    assert int(n) == K and int(out.buf.size) == 0

    ref_tm = st
    for i, kk in enumerate(jax.random.split(key, K)):
        ref_tm, _, _ = fb_mod.train_update(
            cfg, ref_tm, rt, jnp.asarray(xs[i], dtype=bool),
            jnp.int32(ys[i]), kk
        )
    np.testing.assert_array_equal(
        np.asarray(out.tm.ta_state), np.asarray(ref_tm.ta_state)
    )
    assert aux.valid.shape == (K,) and bool(jnp.all(aux.valid))


def test_consume_many_respects_limit_and_empty_buffer():
    from repro.data import buffer as buf_mod
    from repro.data import iris

    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=50)
    st = init_state(cfg, jax.random.PRNGKey(6))
    rt = init_runtime(cfg, s=3.0, T=15)
    xs, ys = iris.load()
    buf = buf_mod.make(16, cfg.n_features)
    for i in range(5):
        buf, _ = buf_mod.push(
            buf, jnp.asarray(xs[i], dtype=bool), jnp.int32(ys[i])
        )
    ss = online_mod.SessionState(tm=st, buf=buf, step=jnp.int32(0))
    key = jax.random.PRNGKey(10)

    # limit < buffered: stops at the limit, leaves the rest buffered
    out, n, _ = online_mod._consume_many(cfg, 8, ss, rt, jnp.int32(3), key)
    assert int(n) == 3 and int(out.buf.size) == 2
    # chunk > buffered: consumes what exists, TM state untouched afterwards
    out2, n2, aux2 = online_mod._consume_many(
        cfg, 8, out, rt, jnp.int32(8), key
    )
    assert int(n2) == 2 and int(out2.buf.size) == 0
    assert not bool(jnp.any(aux2.valid[2:]))


def test_online_session_chunked_learn_counts():
    from repro.data import iris

    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=50)
    sess = online_mod.OnlineSession(
        cfg, init_state(cfg), init_runtime(cfg, s=3.0, T=15),
        buffer_capacity=64, chunk=8,
    )
    xs, ys = iris.load()
    for i in range(20):
        assert sess.offer(xs[i], int(ys[i]))
    assert sess.learn_available(13) == 13      # crosses a partial chunk
    assert sess.buffered == 7
    assert sess.learn_available(100) == 7      # drains to empty
    assert sess.learn_available(4) == 0        # empty buffer trains nothing
    assert int(sess.ss.step) == 20


def test_tm_online_adapt_manager_serves_and_rolls_back():
    from repro.data import iris
    from repro.serve.online_adapt import TMOnlineAdaptConfig, TMOnlineAdaptManager

    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=50)
    rt = init_runtime(cfg, s=3.0, T=15)
    xs, ys = iris.load()
    mgr = TMOnlineAdaptManager(
        cfg, init_state(cfg), rt, xs[100:], ys[100:],
        TMOnlineAdaptConfig(analyze_every=16, rollback_threshold=0.05,
                            chunk=8),
    )
    base = mgr.offline_train(xs[:100], ys[:100], n_epochs=5)
    assert 0.0 <= base <= 1.0
    preds = mgr.serve(xs[:10])
    assert preds.shape == (10,)
    # Poisoned labels: shuffled ys force degradation -> rollback fires.
    rng = np.random.default_rng(0)
    for i in range(200):
        j = i % 100
        mgr.observe(xs[j], int(rng.integers(0, 3)))
        if mgr.rollbacks:
            break
    assert mgr.rollbacks >= 1
    assert len(mgr.history) >= 2


def test_forward_batch_matches_forward_rows():
    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=50)
    st = init_state(cfg, jax.random.PRNGKey(8))
    rt = init_runtime(cfg, n_active_clauses=8)
    rng = np.random.default_rng(12)
    xs = jnp.asarray(rng.random((9, 16)) < 0.5)
    for training in (True, False):
        cl_b, votes_b = tm_mod.forward_batch(cfg, st, rt, xs, training=training)
        for i in range(9):
            cl, votes = tm_mod.forward(cfg, st, rt, xs[i], training=training)
            np.testing.assert_array_equal(np.asarray(cl_b[i]), np.asarray(cl))
            np.testing.assert_array_equal(
                np.asarray(votes_b[i]), np.asarray(votes)
            )
