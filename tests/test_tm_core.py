"""TM core behaviour: datapath, over-provisioning, faults, runtime ports."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    TMConfig, init_runtime, init_state, forward, predict_batch,
    train_epochs, train_step,
)
from repro.core import accuracy as acc_mod
from repro.core import faults as faults_mod
from repro.data import iris


def small_cfg(**kw):
    d = dict(n_features=16, max_classes=3, max_clauses=16, n_states=50)
    d.update(kw)
    return TMConfig(**d)


def test_init_state_boundary():
    cfg = small_cfg()
    st = init_state(cfg)
    assert st.ta_state.shape == (3, 16, 32)
    assert np.all(np.asarray(st.ta_state) == cfg.n_states)  # all-exclude start
    st2 = init_state(cfg, jax.random.PRNGKey(0))
    v = np.asarray(st2.ta_state)
    assert set(np.unique(v)) <= {cfg.n_states, cfg.n_states + 1}


def test_forward_shapes_and_empty_convention():
    cfg = small_cfg()
    st, rt = init_state(cfg), init_runtime(cfg)
    x = jnp.zeros((16,), dtype=bool)
    clauses_tr, votes_tr = forward(cfg, st, rt, x, training=True)
    clauses_inf, votes_inf = forward(cfg, st, rt, x, training=False)
    assert clauses_tr.shape == (3, 16) and votes_tr.shape == (3,)
    # All-exclude init => every clause empty: 1 in training, 0 in inference.
    assert bool(jnp.all(clauses_tr))
    assert not bool(jnp.any(clauses_inf))
    assert int(jnp.sum(jnp.abs(votes_tr))) == 0  # polarities cancel (8+, 8-)


def test_clause_mask_gates_votes():
    """Over-provisioned clauses (§3.1.1) must not vote until enabled."""
    cfg = small_cfg()
    st = init_state(cfg, jax.random.PRNGKey(1))
    rt_full = init_runtime(cfg)
    rt_half = init_runtime(cfg, n_active_clauses=8)
    x = jnp.asarray(np.random.default_rng(0).random(16) < 0.5)
    cl_full, _ = forward(cfg, st, rt_full, x, training=True)
    cl_half, _ = forward(cfg, st, rt_half, x, training=True)
    assert not bool(jnp.any(cl_half[:, 8:]))
    np.testing.assert_array_equal(
        np.asarray(cl_full[:, :8]), np.asarray(cl_half[:, :8])
    )


def test_class_mask_excludes_from_prediction():
    cfg = small_cfg()
    st = init_state(cfg, jax.random.PRNGKey(2))
    rt = init_runtime(cfg, n_active_classes=2)
    xs = jnp.asarray(np.random.default_rng(1).random((20, 16)) < 0.5)
    preds = np.asarray(predict_batch(cfg, st, rt, xs))
    assert preds.max() < 2  # class 2 is over-provisioned, never predicted


def test_fault_masks_force_actions():
    """§3.1.2: AND=0 forces action 0; OR=1 forces action 1."""
    from repro.core import tm as tm_mod

    cfg = small_cfg()
    st = init_state(cfg, jax.random.PRNGKey(3))
    rt = init_runtime(cfg)
    # stuck-at-0 everywhere
    rt0 = rt._replace(ta_and_mask=jnp.zeros_like(rt.ta_and_mask))
    assert not bool(jnp.any(tm_mod.ta_actions(cfg, st, rt0)))
    # stuck-at-1 everywhere
    rt1 = rt._replace(ta_or_mask=jnp.ones_like(rt.ta_or_mask))
    assert bool(jnp.all(tm_mod.ta_actions(cfg, st, rt1)))


def test_even_spread_fault_fraction():
    cfg = small_cfg()
    and_m, or_m = faults_mod.even_spread_stuck_at(cfg, 0.2, 0)
    frac = 1.0 - and_m.mean()
    assert abs(frac - 0.2) < 0.01
    assert or_m.sum() == 0
    and_m1, or_m1 = faults_mod.even_spread_stuck_at(cfg, 0.2, 1)
    assert and_m1.all() and abs(or_m1.mean() - 0.2) < 0.01


def test_runtime_s_T_change_no_recompile():
    """s/T are traced runtime ports: changing them must not retrace."""
    cfg = small_cfg()
    st, rt = init_state(cfg, jax.random.PRNGKey(0)), init_runtime(cfg)
    xs, ys = iris.load()
    x, y = jnp.asarray(xs[0]), jnp.asarray(ys[0])

    traces = 0

    @jax.jit
    def step(st, rt, x, y, k):
        nonlocal traces
        traces += 1
        return train_step(cfg, st, rt, x, y, k)

    k = jax.random.PRNGKey(0)
    step(st, rt, x, y, k)
    step(st, rt._replace(s=jnp.float32(2.5), T=jnp.int32(7)), x, y, k)
    assert traces == 1


def test_training_learns_iris():
    cfg = small_cfg()
    # T must be attainable by the vote range: with J=16 clauses the class sum
    # lives in [-8, 8], so T=15 can never be reached and the feedback
    # probability (T - v)/2T never anneals — the machine churns at ~0.87.
    # T=5 (also what hpsearch_grid selects on this setup) converges.
    rt = init_runtime(cfg, s=3.0, T=5)
    xs, ys = iris.load()
    st = train_epochs(cfg, init_state(cfg), rt, jnp.asarray(xs), jnp.asarray(ys),
                      jax.random.PRNGKey(0), 10)
    acc = float(acc_mod.analyze(cfg, st, rt, jnp.asarray(xs), jnp.asarray(ys)))
    assert acc > 0.9, f"train accuracy {acc} too low"


def test_valid_mask_rows_are_skipped():
    """Masked rows must leave state untouched (class filter substrate)."""
    from repro.core import train_datapoints

    cfg = small_cfg()
    rt = init_runtime(cfg, s=3.0, T=15)
    st0 = init_state(cfg, jax.random.PRNGKey(5))
    xs, ys = iris.load()
    xs, ys = jnp.asarray(xs[:10]), jnp.asarray(ys[:10])
    key = jax.random.PRNGKey(1)
    none_valid = jnp.zeros((10,), dtype=bool)
    st1, _ = train_datapoints(cfg, st0, rt, xs, ys, key, valid=none_valid)
    np.testing.assert_array_equal(np.asarray(st0.ta_state), np.asarray(st1.ta_state))
