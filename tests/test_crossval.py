"""Replica-parallel cross-validation engine (repro.eval.crossval).

The engine's contract is *bit-exactness*: the fused sweep must reproduce the
per-cell reference (``hpsearch._one_cell``) and the legacy vmap-of-scan
program exactly, not approximately — any drift means the replica plane no
longer implements the paper's machine. The fast tests run a subsample
grid; ``-m slow`` runs the paper's full 120-ordering iris sweep.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# CI's kernel-parity job re-runs this suite with TM_BACKEND=pallas so the
# engine itself is exercised through the Pallas kernels (interpret mode).
ENV_BACKEND = os.environ.get("TM_BACKEND", "ref")

from repro.core import feedback as fb_mod
from repro.core import hpsearch
from repro.core import manager as mgr
from repro.core import tm as tm_mod
from repro.core.tm import TMConfig
from repro.data import blocks
from repro.eval.crossval import CrossValRun, grid_layout, replicate_state

CFG = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=16,
               backend=ENV_BACKEND)


@pytest.fixture(scope="module")
def iris_osets():
    osets, _ = blocks.iris_paper_sets(n_orderings=6)
    return osets


def _loop_one_cell(cfg, osets, s_values, T_values, n_epochs, seed):
    """The reference semantics: one `_one_cell` per (s, T, ordering)."""
    O = osets.offline_x.shape[0]
    keys = jax.random.split(jax.random.PRNGKey(seed), O)
    out = np.zeros((len(s_values), len(T_values), O), np.float32)
    for si, s in enumerate(s_values):
        for ti, T in enumerate(T_values):
            for o in range(O):
                out[si, ti, o] = hpsearch._one_cell(
                    cfg, jnp.float32(s), jnp.int32(T),
                    jnp.asarray(osets.offline_x[o]),
                    jnp.asarray(osets.offline_y[o]),
                    jnp.asarray(osets.validation_x[o]),
                    jnp.asarray(osets.validation_y[o]),
                    keys[o], n_epochs,
                )
    return out


def test_sweep_bitwise_identical_to_one_cell_loop(iris_osets):
    """CrossValRun.sweep == looping hpsearch._one_cell, bit for bit."""
    s_values, T_values = (1.375, 3.0), (5, 15)
    res = CrossValRun(CFG).sweep(
        iris_osets.offline_x, iris_osets.offline_y,
        iris_osets.validation_x, iris_osets.validation_y,
        s_values, T_values, n_epochs=4, seed=0,
    )
    want = _loop_one_cell(CFG, iris_osets, s_values, T_values, 4, 0)
    np.testing.assert_array_equal(want, np.asarray(res.val_accuracy))
    # mean over orderings, reduced by the same device op as the engine
    np.testing.assert_array_equal(
        np.asarray(jnp.mean(jnp.asarray(want), axis=-1)),
        np.asarray(res.mean_accuracy),
    )


def test_sweep_bitwise_identical_to_legacy_vmap(iris_osets):
    """Engine == the pre-replica vmap-of-scan grid program, bit for bit."""
    s_values, T_values = (1.375, 2.0, 3.0), (5, 10, 15)
    keys = jax.random.split(jax.random.PRNGKey(0), 6)
    want = hpsearch.grid_search_device(
        CFG,
        jnp.asarray(s_values, jnp.float32), jnp.asarray(T_values, jnp.int32),
        (jnp.asarray(iris_osets.offline_x), jnp.asarray(iris_osets.offline_y)),
        (jnp.asarray(iris_osets.validation_x),
         jnp.asarray(iris_osets.validation_y)),
        keys, 4,
    )
    res = CrossValRun(CFG).sweep(
        iris_osets.offline_x, iris_osets.offline_y,
        iris_osets.validation_x, iris_osets.validation_y,
        s_values, T_values, n_epochs=4, seed=0,
    )
    np.testing.assert_array_equal(np.asarray(want), np.asarray(res.val_accuracy))


def test_grid_search_is_thin_engine_caller(iris_osets):
    """hpsearch.grid_search returns engine results in the GridResult shape."""
    gr = hpsearch.grid_search(
        CFG, (1.375, 3.0), (5, 15),
        iris_osets.offline_x, iris_osets.offline_y,
        iris_osets.validation_x, iris_osets.validation_y,
        n_epochs=4, seed=0,
    )
    res = CrossValRun(CFG).sweep(
        iris_osets.offline_x, iris_osets.offline_y,
        iris_osets.validation_x, iris_osets.validation_y,
        (1.375, 3.0), (5, 15), n_epochs=4, seed=0,
    )
    np.testing.assert_array_equal(
        np.asarray(gr.val_accuracy), np.asarray(res.val_accuracy)
    )
    s, T, acc = hpsearch.best(gr)
    assert s in (1.375, 3.0) and T in (5, 15) and 0.0 <= acc <= 1.0


def test_grid_layout_is_grid_major_ordering_minor():
    s_rep, T_rep = grid_layout((1.0, 2.0), (5, 10, 15), 4)
    R = 2 * 3 * 4
    assert s_rep.shape == T_rep.shape == (R,)
    for r in range(R):
        si, rest = divmod(r, 3 * 4)
        ti, _o = divmod(rest, 4)
        assert float(s_rep[r]) == (1.0, 2.0)[si]
        assert int(T_rep[r]) == (5, 10, 15)[ti]


def test_sweep_offline_valid_mask(iris_osets):
    """offline_valid restricts training rows exactly like train_epochs'
    valid mask (the §5.1 limited-data budget)."""
    O = iris_osets.offline_x.shape[0]
    n = iris_osets.offline_x.shape[1]
    valid = np.zeros((O, n), dtype=bool)
    valid[:, :20] = True
    res = CrossValRun(CFG).sweep(
        iris_osets.offline_x, iris_osets.offline_y,
        iris_osets.validation_x, iris_osets.validation_y,
        (1.375,), (15,), n_epochs=3, seed=1, offline_valid=valid,
    )
    # reference: single replica trained on the first 20 rows only
    keys = jax.random.split(jax.random.PRNGKey(1), O)
    rt = tm_mod.init_runtime(CFG, s=1.375, T=15)
    st = fb_mod.train_epochs(
        CFG, tm_mod.init_state(CFG), rt,
        jnp.asarray(iris_osets.offline_x[0]),
        jnp.asarray(iris_osets.offline_y[0]),
        keys[0], 3, valid=jnp.asarray(valid[0]),
    )
    from repro.core import accuracy as acc_mod

    want = acc_mod.analyze(
        CFG, st, rt,
        jnp.asarray(iris_osets.validation_x[0]),
        jnp.asarray(iris_osets.validation_y[0]),
    )
    assert float(want) == float(res.val_accuracy[0, 0, 0])


def test_sweep_with_mesh_sharding(iris_osets):
    """A mesh-sharded sweep (replica axis over the data mesh axis) is
    bit-identical to the unsharded program."""
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    base = CrossValRun(CFG).sweep(
        iris_osets.offline_x, iris_osets.offline_y,
        iris_osets.validation_x, iris_osets.validation_y,
        (1.375, 3.0), (5, 15), n_epochs=3, seed=0,
    )
    sharded = CrossValRun(CFG, mesh=mesh).sweep(
        iris_osets.offline_x, iris_osets.offline_y,
        iris_osets.validation_x, iris_osets.validation_y,
        (1.375, 3.0), (5, 15), n_epochs=3, seed=0,
    )
    np.testing.assert_array_equal(
        np.asarray(base.val_accuracy), np.asarray(sharded.val_accuracy)
    )


def test_replica_shardings_specs():
    from jax.sharding import Mesh, PartitionSpec as PS

    from repro.distributed import sharding as shard_mod

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    tree = {
        "state": jax.ShapeDtypeStruct((8, 3, 16, 32), jnp.int8),
        "scalar": jax.ShapeDtypeStruct((), jnp.float32),
    }
    sh = shard_mod.replica_shardings(tree, mesh, n_replicas=8)
    assert sh["state"].spec == PS("data")
    assert sh["scalar"].spec == PS()
    # the old no-n_replicas form sharded ANY divisible leading dim
    # (scattering D | R stream leaves) — now a hard error
    with pytest.raises(TypeError, match="n_replicas"):
        shard_mod.replica_shardings(tree, mesh)


def test_replicate_state_matches_init():
    st = replicate_state(CFG, 5)
    base = tm_mod.init_state(CFG)
    assert st.ta_state.shape == (5,) + base.ta_state.shape
    for r in range(5):
        np.testing.assert_array_equal(
            np.asarray(st.ta_state[r]), np.asarray(base.ta_state)
        )


def test_system_engine_matches_run_system_loop(iris_osets):
    """CrossValRun.system == per-ordering run_system, bit for bit (the
    engine behind manager.run_orderings)."""
    O = 3
    sys_cfg = mgr.SystemConfig(n_offline_epochs=2, n_online_cycles=3)
    schedule = mgr.make_schedule(online_s=1.0)
    n_off = iris_osets.offline_x.shape[1]

    def sets_for(o):
        return mgr.Sets(
            offline_x=jnp.asarray(iris_osets.offline_x[o]),
            offline_y=jnp.asarray(iris_osets.offline_y[o]),
            offline_valid=jnp.ones(n_off, dtype=bool),
            validation_x=jnp.asarray(iris_osets.validation_x[o]),
            validation_y=jnp.asarray(iris_osets.validation_y[o]),
            validation_valid=jnp.ones(
                iris_osets.validation_x.shape[1], dtype=bool),
            online_x=jnp.asarray(iris_osets.online_x[o]),
            online_y=jnp.asarray(iris_osets.online_y[o]),
            online_valid=jnp.ones(iris_osets.online_x.shape[1], dtype=bool),
        )

    sets_list = [sets_for(o) for o in range(O)]
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *sets_list)
    states = replicate_state(CFG, O)
    keys = jax.random.split(jax.random.PRNGKey(3), O)
    rt = tm_mod.init_runtime(CFG, s=1.375, T=15)

    res = CrossValRun(CFG).system(sys_cfg, states, rt, stacked, schedule, keys)
    assert res.replicas == O
    for o in range(O):
        _, accs_o, act_o = mgr.run_system(
            CFG, sys_cfg, tm_mod.init_state(CFG), rt, sets_list[o],
            schedule, keys[o],
        )
        np.testing.assert_array_equal(
            np.asarray(res.accuracies[o]), np.asarray(accs_o)
        )
        np.testing.assert_array_equal(
            np.asarray(res.activity[o]), np.asarray(act_o)
        )


def test_analyze_sets_replicated_matches_separate_calls(iris_osets):
    """The fused single-contraction analysis pass == three separate
    analyze_replicated calls, bit for bit, including a grid axis (H > 1)
    and validity masks (the ROADMAP system-path fusion)."""
    from repro.core import accuracy as acc_mod

    O = iris_osets.offline_x.shape[0]
    R = 2 * O  # grid-major: two (s, T) cells per ordering
    s_rep, T_rep = grid_layout((1.375, 3.0), (15,), O)
    rt = tm_mod.init_runtime(CFG)._replace(s=s_rep, T=T_rep)
    # non-trivial banks: train the whole grid for one epoch
    keys = jax.random.split(jax.random.PRNGKey(5), O)
    state = fb_mod.train_epochs_replicated(
        CFG, replicate_state(CFG, R), rt,
        jnp.asarray(iris_osets.offline_x), jnp.asarray(iris_osets.offline_y),
        keys, 1,
    )

    n_val = iris_osets.validation_x.shape[1]
    val_valid = jnp.asarray(
        np.arange(n_val)[None, :] < (n_val - np.arange(O))[:, None]
    )
    sets = [
        (jnp.asarray(iris_osets.offline_x),
         jnp.asarray(iris_osets.offline_y), None),
        (jnp.asarray(iris_osets.validation_x),
         jnp.asarray(iris_osets.validation_y), val_valid),
        (jnp.asarray(iris_osets.online_x),
         jnp.asarray(iris_osets.online_y), None),
    ]
    fused = acc_mod.analyze_sets_replicated(CFG, state, rt, sets)
    want = jnp.stack([
        acc_mod.analyze_replicated(CFG, state, rt, x, y, v)
        for x, y, v in sets
    ], axis=-1)
    assert fused.shape == (R, 3)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(fused))


@pytest.mark.slow
def test_full_iris_sweep_bitwise_identical_to_one_cell_loop():
    """Acceptance: the paper's full 5-block sweep — ALL 120 orderings x a
    3x3 (s, T) grid — through CrossValRun equals looping _one_cell exactly."""
    osets, _ = blocks.iris_paper_sets(n_orderings=120)
    s_values, T_values = (1.375, 2.0, 3.0), (5, 10, 15)
    res = CrossValRun(CFG).sweep(
        osets.offline_x, osets.offline_y,
        osets.validation_x, osets.validation_y,
        s_values, T_values, n_epochs=10, seed=0,
    )
    assert res.replicas == 3 * 3 * 120
    want = _loop_one_cell(CFG, osets, s_values, T_values, 10, 0)
    np.testing.assert_array_equal(want, np.asarray(res.val_accuracy))
    np.testing.assert_array_equal(
        np.asarray(jnp.mean(jnp.asarray(want), axis=-1)),
        np.asarray(res.mean_accuracy),
    )
