"""Traffic harness properties (DESIGN.md §14).

Three layers, matching serve/traffic.py's three pieces:

* the scenario compiler is a pure function — same arguments, same
  scripts — and each schedule knob (class introduction, drift, bursts,
  label delay) provably shapes the stream;
* the threaded end-to-end invariant: N producer threads hammering a
  small-capacity service while the consumer ticks must preserve
  per-replica FIFO order on the device ring, conserve every offer
  (accepted + dropped == submitted; trained + buffered == accepted) and
  survive lane-full backpressure without deadlock or crash;
* the bitwise replay contract: a recorded threaded run, replayed through
  a fresh identical service from one thread, lands on the *same* TA
  banks, RNG keys, step counters and policy state — threading may change
  when work happens, never what is computed. Checked on the unpacked and
  packed datapaths, with and without a mid-run §5.3 fault injection.
"""
import threading

import numpy as np
import pytest

from repro.core import TMConfig, init_state
from repro.serve import (
    SCENARIOS,
    Scenario,
    ServiceConfig,
    TMService,
    make_script,
    make_scripts,
    replay_single_caller,
    run_threaded,
)
from repro.serve.service import AdaptPolicy
from repro.serve.traffic import fingerprint, fingerprints_equal, slo_summary

K, F, NC = 2, 16, 3


def _dataset(n=24, seed=7):
    rng = np.random.default_rng(seed)
    return (rng.integers(0, 2, size=(n, F)).astype(bool),
            rng.integers(0, NC, size=n).astype(np.int32))


def _traffic_service(seed=0, packed=False):
    """A service sized so a small threaded run exercises analyses and
    (for the fault test) §5.3 injection without drops."""
    cfg = TMConfig(n_features=F, max_classes=NC, max_clauses=8, n_states=16)
    ex, ey = _dataset(n=16, seed=99)
    return TMService(
        cfg, init_state(cfg),
        ServiceConfig(
            replicas=K, buffer_capacity=256, chunk=8, ingress_block=4,
            packed=packed, s=3.0, T=15, seed=seed,
            policy=AdaptPolicy(analyze_every=16),
        ),
        eval_x=ex, eval_y=ey,
    )


# ---------------------------------------------------------------------------
# Scenario compiler.
# ---------------------------------------------------------------------------


def test_make_script_deterministic_per_producer():
    xs, ys = _dataset()
    sc = SCENARIOS["bursty_drift"]
    a = make_script(sc, xs, ys, NC, producer=1, seed=3)
    b = make_script(sc, xs, ys, NC, producer=1, seed=3)
    np.testing.assert_array_equal(a.x, b.x)
    np.testing.assert_array_equal(a.y, b.y)
    np.testing.assert_array_equal(a.gap_s, b.gap_s)
    # distinct producers draw distinct streams from the same seed
    c = make_script(sc, xs, ys, NC, producer=2, seed=3)
    assert not np.array_equal(a.x, c.x)


def test_make_script_schedule_knobs():
    xs, ys = _dataset(n=64)
    sc = Scenario(name="t", points=80, burst=8, burst_gap_s=0.001,
                  label_delay=5, introduce_class=2, introduce_at=0.5,
                  drift_at=0.75, drift_shift=1)
    s = make_script(sc, xs, ys, NC, producer=0, seed=0)
    assert len(s) == 80 and s.label_delay == 5
    intro_end, drift_start = 40, 60
    # §5.2 class introduction: the class is absent before the intro point.
    # Submitted labels may be drifted, so check the *source* rows: every
    # picked row's true label, recoverable because rows are drawn intact.
    undrifted = s.y[:drift_start]
    assert not (undrifted[:intro_end] == 2).any()
    assert (undrifted[intro_end:drift_start] == 2).any()
    # drift: submitted labels shift by 1 mod NC from the drift point on
    drifted = s.y[drift_start:]
    assert ((drifted >= 0) & (drifted < NC)).all()
    # burst gaps sit exactly at non-zero burst boundaries
    slots = np.arange(80)
    expect = np.zeros(80, dtype=np.float32)
    expect[(slots > 0) & (slots % 8 == 0)] = 0.001
    np.testing.assert_array_equal(s.gap_s, expect)


def test_drift_relabels_against_undrifted_twin():
    xs, ys = _dataset(n=64)
    base = Scenario(name="base", points=40)
    drif = Scenario(name="drif", points=40, drift_at=0.5, drift_shift=1)
    a = make_script(base, xs, ys, NC, producer=0, seed=0)
    b = make_script(drif, xs, ys, NC, producer=0, seed=0)
    np.testing.assert_array_equal(a.x, b.x)          # same picks
    np.testing.assert_array_equal(a.y[:20], b.y[:20])
    np.testing.assert_array_equal((a.y[20:] + 1) % NC, b.y[20:])


def test_run_threaded_rejects_script_count_mismatch():
    svc = _traffic_service()
    xs, ys = _dataset()
    scripts = make_scripts(SCENARIOS["steady"], xs, ys, NC, K + 1)
    with pytest.raises(ValueError, match="producer scripts"):
        run_threaded(svc, scripts, scenario=SCENARIOS["steady"])


# ---------------------------------------------------------------------------
# Threaded end-to-end invariant (small capacity -> real backpressure).
# ---------------------------------------------------------------------------


def test_threaded_producers_fifo_and_conservation():
    """N producer threads vs the consumer tick loop on a service small
    enough that lanes fill and buffers overflow: per-replica FIFO order
    must survive on the device ring, and every offer must be accounted
    accepted + dropped == submitted, trained + buffered == accepted."""
    CAP, BLOCK, CHUNK, N = 6, 3, 4, 120
    cfg = TMConfig(n_features=F, max_classes=NC, max_clauses=8, n_states=16)
    svc = TMService(cfg, init_state(cfg), ServiceConfig(
        replicas=K, buffer_capacity=CAP, chunk=CHUNK, ingress_block=BLOCK,
        s=3.0, T=15, seed=0,
    ))

    def _uid_row(uid):
        return np.array([(uid >> b) & 1 for b in range(F)], dtype=bool)

    def _uid(x):
        return int(sum(int(v) << b for b, v in enumerate(x)))

    accepted_uids = [[] for _ in range(K)]
    errors = []
    barrier = threading.Barrier(K + 1)

    def producer(p):
        try:
            barrier.wait()
            for i in range(N):
                uid = p * N + i + 1          # globally unique, never 0
                if svc.submit(p, _uid_row(uid), uid % NC):
                    accepted_uids[p].append(uid)
        except BaseException as e:
            errors.append(e)

    threads = [threading.Thread(target=producer, args=(p,), daemon=True)
               for p in range(K)]
    for t in threads:
        t.start()
    barrier.wait()
    while any(t.is_alive() for t in threads):
        svc.tick()
    for t in threads:
        t.join()
    assert not errors, errors

    accepted = np.asarray([len(a) for a in accepted_uids], dtype=np.int64)
    # conservation against offers
    np.testing.assert_array_equal(accepted + svc.dropped,
                                  np.full(K, N, dtype=np.int64))
    trained = svc.steps.astype(np.int64)
    np.testing.assert_array_equal(accepted, trained + svc.buffered)
    # per-replica FIFO: whatever is still queued must be exactly the
    # accepted tail, in acceptance order, on the device ring
    svc.flush()
    buf = svc.ss.buf
    for r in range(K):
        head = int(np.asarray(buf.head[r]))
        size = int(np.asarray(buf.size[r]))
        ring = [_uid(np.asarray(buf.data_x[r][(head + i) % CAP]))
                for i in range(size)]
        assert ring == accepted_uids[r][int(trained[r]):], (
            f"replica {r}: device ring diverged from accepted FIFO tail"
        )


# ---------------------------------------------------------------------------
# Bitwise single-caller replay.
# ---------------------------------------------------------------------------


def _roundtrip(scenario, *, packed=False, seed=5):
    xs, ys = _dataset(n=32)
    scripts = make_scripts(scenario, xs, ys, NC, K, seed=11)
    live = _traffic_service(seed=seed, packed=packed)
    result = run_threaded(live, scripts, scenario=scenario, pace=0.0)
    assert result.conserved()
    twin = _traffic_service(seed=seed, packed=packed)
    replay_single_caller(twin, scripts, result, scenario=scenario)
    return live, twin, result


def test_replay_matches_threaded_steady():
    sc = Scenario(name="steady", points=48, probe_every=4)
    live, twin, result = _roundtrip(sc)
    assert result.offers == K * 48 and result.probes > 0
    assert fingerprints_equal(fingerprint(live), fingerprint(twin))
    s = slo_summary(result)
    assert s["conserved"] and s["offers_per_s"] > 0
    for k in ("submit_p50_s", "submit_p99_s", "serve_p50_s", "serve_p99_s"):
        assert s[k] >= 0.0


def test_replay_matches_threaded_fault_injected():
    sc = Scenario(name="fault", points=32, fault_at=24, fault_fraction=0.25,
                  fault_stuck=1, probe_every=0)
    live, twin, result = _roundtrip(sc)
    assert result.fault_tick is not None
    # the injection really landed: stuck-at-1 OR mask is non-trivial
    assert bool(np.asarray(live.rt.ta_or_mask).any())
    assert bool(np.asarray(twin.rt.ta_or_mask).any())
    assert fingerprints_equal(fingerprint(live), fingerprint(twin))


def test_replay_matches_threaded_packed():
    sc = Scenario(name="steady", points=32, probe_every=8)
    live, twin, result = _roundtrip(sc, packed=True)
    assert result.conserved()
    assert fingerprints_equal(fingerprint(live), fingerprint(twin))


def test_replay_diverges_for_different_seed():
    """The oracle has teeth: a replay against a differently-seeded twin
    must NOT fingerprint-match (RNG keys differ from construction)."""
    sc = Scenario(name="steady", points=16, probe_every=0)
    xs, ys = _dataset(n=32)
    scripts = make_scripts(sc, xs, ys, NC, K, seed=11)
    live = _traffic_service(seed=5)
    result = run_threaded(live, scripts, scenario=sc, pace=0.0)
    twin = _traffic_service(seed=6)
    replay_single_caller(twin, scripts, result, scenario=sc)
    assert not fingerprints_equal(fingerprint(live), fingerprint(twin))
