"""Optimizer, checkpointing, fault-tolerant loop, gradient compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed import collectives
from repro.train import checkpoint as ckpt
from repro.train import loop as loop_mod
from repro.train import optimizer as opt
from repro.train.train_step import TrainState


def _params(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "a": {"w": jax.random.normal(k, (8, 4)), "b": jnp.zeros((4,))},
        "head": jax.random.normal(jax.random.fold_in(k, 1), (4, 2)),
    }


def test_adamw_reduces_quadratic():
    target = jax.tree.map(lambda p: p * 0 + 1.0, _params())
    params = _params()
    oc = opt.OptConfig(lr=0.05, warmup_steps=1, total_steps=200,
                       weight_decay=0.0)
    state = opt.init(oc, params)

    def loss(p):
        return sum(jnp.sum((a - b) ** 2)
                   for a, b in zip(jax.tree.leaves(p), jax.tree.leaves(target)))

    l0 = float(loss(params))
    for _ in range(100):
        grads = jax.grad(loss)(params)
        params, state, m = opt.apply(oc, state, params, grads)
    assert float(loss(params)) < 0.2 * l0
    assert int(state.step) == 100


def test_schedule_warmup_and_cosine():
    oc = opt.OptConfig(lr=1.0, warmup_steps=10, total_steps=110)
    lrs = [float(opt.schedule_lr(oc, jnp.int32(s))) for s in (0, 9, 10, 110)]
    assert lrs[0] < 0.2 and abs(lrs[2] - 1.0) < 0.01
    assert lrs[3] < 0.01  # cosine decayed to ~0


def test_clip_by_global_norm():
    g = {"w": jnp.full((4,), 10.0)}
    clipped, gn = opt.clip_by_global_norm(g, 1.0)
    assert abs(float(gn) - 20.0) < 1e-3
    norm = float(jnp.linalg.norm(clipped["w"]))
    assert abs(norm - 1.0) < 1e-3


def test_checkpoint_roundtrip(tmp_path):
    tree = {"p": _params(), "step": jnp.int32(7),
            "nested": (jnp.arange(3), [jnp.ones((2, 2))])}
    ckpt.save(str(tmp_path), 7, tree, extra={"note": "x"})
    got, manifest = ckpt.restore(str(tmp_path), tree)
    assert manifest["step"] == 7 and manifest["extra"]["note"] == "x"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_service_leaf_dtypes_roundtrip(tmp_path):
    """Every leaf dtype a TMService checkpoint carries restores bit for
    bit: int8 TA banks, uint32 packed words, bool rows, int32 steps,
    int64/float64 host policy counters (incl. nan)."""
    rng = np.random.default_rng(0)
    tree = {
        "ta": jnp.asarray(rng.integers(-99, 99, (2, 4, 8), dtype=np.int8)),
        "words": jnp.asarray(rng.integers(0, 2**32, (3, 5),
                                          dtype=np.uint32)),
        "rows": jnp.asarray(rng.random((4, 16)) > 0.5),
        "step": jnp.arange(4, dtype=jnp.int32),
        "since": np.arange(4, dtype=np.int64) * 2**40,
        "best": np.asarray([0.5, np.nan, 1.0, np.nan], dtype=np.float64),
        "acc": np.asarray([0.25, 0.75], dtype=np.float32),
    }
    ckpt.save(str(tmp_path), 1, tree)
    got, _ = ckpt.restore(str(tmp_path), tree, device=False)
    for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(tree)[0],
        jax.tree_util.tree_flatten_with_path(got)[0],
    ):
        a, b = np.asarray(a), np.asarray(b)
        assert b.dtype == a.dtype, (pa, a.dtype, b.dtype)
        np.testing.assert_array_equal(a, b, err_msg=str(pa))


def test_checkpoint_typed_prng_keys_roundtrip(tmp_path):
    """Typed PRNG key arrays route through key_data/wrap_key_data (a bare
    np.asarray rejects their custom dtype); raw uint32 keys pass as-is."""
    tree = {
        "typed": jax.random.key(0),
        "batch": jax.random.split(jax.random.key(1), 4),
        "raw": jax.random.PRNGKey(2),
    }
    ckpt.save(str(tmp_path), 1, tree)
    got, manifest = ckpt.restore(str(tmp_path), tree)
    assert manifest["key_impls"]  # typed keys were detected and recorded
    for name in ("typed", "batch"):
        assert jnp.issubdtype(got[name].dtype, jax.dtypes.prng_key)
        np.testing.assert_array_equal(
            np.asarray(jax.random.key_data(got[name])),
            np.asarray(jax.random.key_data(tree[name])),
        )
    np.testing.assert_array_equal(np.asarray(got["raw"]),
                                  np.asarray(tree["raw"]))
    assert got["raw"].dtype == jnp.uint32
    # the restored typed key drives the SAME randomness
    np.testing.assert_array_equal(
        np.asarray(jax.random.uniform(got["typed"], (3,))),
        np.asarray(jax.random.uniform(tree["typed"], (3,))),
    )


def test_checkpoint_read_manifest(tmp_path):
    ckpt.save(str(tmp_path), 3, {"x": jnp.arange(4)}, extra={"k": "v"})
    man = ckpt.read_manifest(str(tmp_path))
    assert man["step"] == 3 and man["extra"]["k"] == "v"
    with pytest.raises(FileNotFoundError):
        ckpt.read_manifest(str(tmp_path / "nope"))


def test_checkpoint_keep_k_and_latest(tmp_path):
    tree = {"x": jnp.arange(4)}
    for s in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, tree, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 2 and ckpt.latest_step(str(tmp_path)) == 4


def test_checkpoint_reshard_on_load(tmp_path):
    """Elastic restart: restore under explicit (new) shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as PS

    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(str(tmp_path), 1, tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": NamedSharding(mesh, PS("data"))}
    got, _ = ckpt.restore(str(tmp_path), tree, shardings=sh)
    assert got["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(tree["w"]))


def test_loop_nan_fault_triggers_restore(tmp_path):
    """Watchdog: consecutive NaN steps roll back to the last checkpoint."""
    params = {"w": jnp.ones((2,))}
    state = TrainState(params=params,
                       opt=opt.init(opt.OptConfig(), params), compress=None)
    lc = loop_mod.LoopConfig(total_steps=8, checkpoint_every=2,
                             checkpoint_dir=str(tmp_path), max_faults=2)

    calls = {"n": 0}

    def step_fn(st, batch):
        calls["n"] += 1
        nan_step = calls["n"] in (5, 6)  # two consecutive faults
        loss = jnp.float32(np.nan) if nan_step else jnp.float32(1.0)
        new_opt = st.opt._replace(step=st.opt.step + 1)
        return TrainState(st.params, new_opt, None), {"loss": loss}

    data = iter(lambda: {"x": jnp.zeros(())}, None)
    _, report = loop_mod.run(lc, state, step_fn, data, log=lambda s: None)
    assert report.restores == 1
    assert [e[1] for e in report.fault_events] == ["nan_loss", "nan_loss"]


def test_loop_straggler_detection(tmp_path):
    import time as _t

    params = {"w": jnp.ones((2,))}
    state = TrainState(params=params,
                       opt=opt.init(opt.OptConfig(), params), compress=None)
    lc = loop_mod.LoopConfig(total_steps=6, checkpoint_every=100,
                             checkpoint_dir=str(tmp_path),
                             straggler_factor=3.0)

    calls = {"n": 0}

    def step_fn(st, batch):
        calls["n"] += 1
        _t.sleep(0.25 if calls["n"] == 5 else 0.01)
        new_opt = st.opt._replace(step=st.opt.step + 1)
        return TrainState(st.params, new_opt, None), {"loss": jnp.float32(1.0)}

    data = iter(lambda: {}, None)
    _, report = loop_mod.run(lc, state, step_fn, data, log=lambda s: None)
    assert len(report.straggler_steps) >= 1


def test_grad_compression_error_feedback():
    """Quantisation error is carried, not lost: sum of dequantised grads over
    repeated identical inputs converges to the true sum."""
    g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal(64) * 1e-3,
                          jnp.float32)}
    state = collectives.init_state(g)
    total = jnp.zeros_like(g["w"])
    for _ in range(50):
        dq, state, _ = collectives.compress_grads(g, state)
        total = total + dq["w"]
    err = float(jnp.max(jnp.abs(total - 50 * g["w"])))
    scale = float(jnp.max(jnp.abs(g["w"])))
    assert err < 2 * scale  # bounded residual, no divergence


def test_grad_compression_int8_range():
    g = {"w": jnp.asarray([[1000.0, -1000.0, 0.5]])}
    q, scale = collectives._quantize_int8(g["w"])
    assert q.dtype == jnp.int8
    assert int(q.max()) <= 127 and int(q.min()) >= -127
