"""Serving engine, TM online session, LM online-adaptation manager."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ShapeConfig
from repro.models import params as P
from repro.models import transformer
from repro.serve.engine import Engine, EngineConfig


def test_engine_matches_manual_greedy_decode():
    """Engine.generate == hand-rolled forward argmax loop (teacher forcing
    on its own outputs)."""
    cfg = configs.get_smoke_config("granite_8b")
    specs = transformer.model_specs(cfg)
    prm = P.materialize(specs, jax.random.PRNGKey(0), jnp.float32)
    B, S0, new = 2, 6, 5
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (B, S0)).astype(np.int32)

    eng = Engine(cfg, prm, EngineConfig(max_seq=S0 + new, batch_slots=B))
    got = eng.generate(prompts, new)

    # reference: full forward re-run per emitted token
    toks = jnp.asarray(prompts)
    want = []
    for i in range(new):
        logits, _ = transformer.forward(cfg, prm, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        want.append(np.asarray(nxt))
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    want = np.stack(want, axis=1)
    np.testing.assert_array_equal(got, want)


def test_tm_online_session_buffers_and_learns():
    from repro.core import TMConfig, init_runtime, init_state
    from repro.core.online import OnlineSession
    from repro.data import iris
    from repro.data.memory import ROMSource

    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=16)
    sess = OnlineSession(cfg, init_state(cfg), init_runtime(cfg, s=3.0, T=15),
                         buffer_capacity=32)
    xs, ys = iris.load()
    src = ROMSource(xs, ys)
    accepted = sess.fill_from(src, 32)
    assert accepted == 32 and sess.buffered == 32
    assert not sess.offer(xs[0], int(ys[0]))  # full -> backpressure
    trained = sess.learn_available(100)
    assert trained == 32 and sess.buffered == 0
    # after consuming 4 full passes the model classifies better than chance
    for _ in range(4):
        sess.fill_from(src, 32)
        sess.learn_available(32)
    acc = float(np.mean(sess.infer(xs) == ys))
    assert acc > 0.5


def test_tm_online_session_on_chunk_monitoring():
    """learn_available's on_chunk hook surfaces ChunkAux (Fig. 3 analysis)
    without a second inference pass — and without it monitoring stays off."""
    from repro.core import TMConfig, init_runtime, init_state
    from repro.core import tm as tm_mod
    from repro.core.online import OnlineSession
    from repro.data import iris

    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=16)
    xs, ys = iris.load()
    chunks = []

    sess = OnlineSession(cfg, init_state(cfg), init_runtime(cfg, s=3.0, T=15),
                         buffer_capacity=64, chunk=16, seed=7)
    for i in range(40):
        sess.offer(xs[i], int(ys[i]))
    trained = sess.learn_available(40, on_chunk=chunks.append)
    assert trained == 40
    # 40 points through chunk=16 -> 16 + 16 + 8-valid chunks
    assert [int(c.valid.sum()) for c in chunks] == [16, 16, 8]
    for c in chunks:
        # correct rows must be flagged valid; activity only on valid rows
        assert not np.any(np.asarray(c.correct) & ~np.asarray(c.valid))
        assert np.all(np.asarray(c.activity)[~np.asarray(c.valid)] == 0.0)

    # The last chunk's predictions are made under the post-chunk state, which
    # is the session's current state: they must match a fresh predict_batch.
    last = chunks[-1]
    valid = np.asarray(last.valid)
    rows = np.asarray(xs[32:40], dtype=bool)
    want = np.asarray(
        tm_mod.predict_batch(cfg, sess.ss.tm, sess.rt, jnp.asarray(rows))
    )
    np.testing.assert_array_equal(
        np.asarray(last.predicted)[valid][: len(rows)], want
    )

    # Same drain without the hook: monitoring compiled out, same final state.
    sess2 = OnlineSession(cfg, init_state(cfg), init_runtime(cfg, s=3.0, T=15),
                          buffer_capacity=64, chunk=16, seed=7)
    for i in range(40):
        sess2.offer(xs[i], int(ys[i]))
    assert sess2.learn_available(40) == 40
    np.testing.assert_array_equal(
        np.asarray(sess.ss.tm.ta_state), np.asarray(sess2.ss.tm.ta_state)
    )


def test_online_adapt_rollback(tmp_path):
    """Fig-3 FSM for LMs: degraded eval loss triggers checkpoint rollback."""
    from repro.serve.online_adapt import OnlineAdaptConfig, OnlineAdaptManager
    from repro.train import optimizer as opt_mod
    from repro.train import train_step as ts_mod

    cfg = configs.get_smoke_config("gemma3_1b")
    specs = transformer.model_specs(cfg)
    prm = P.materialize(specs, jax.random.PRNGKey(0), jnp.float32)
    tc = ts_mod.TrainConfig(opt=opt_mod.OptConfig(lr=1e-3, warmup_steps=1,
                                                  total_steps=1000))
    state = ts_mod.init_state(tc, prm)
    oc = OnlineAdaptConfig(analyze_every=2, rollback_threshold=0.05,
                           checkpoint_dir=str(tmp_path))
    m = OnlineAdaptManager(cfg, tc, state, oc)

    from repro.models import stubs
    shape = ShapeConfig("t", 32, 2, "train")
    good = stubs.synthetic_batch(cfg, shape, seed=1)
    evalb = stubs.synthetic_batch(cfg, shape, seed=2)
    m.offline_train([good, good], evalb)
    base_loss = m.history[-1][1]

    # poison online batches with a huge-lr-like effect: feed garbage labels
    # by shuffling tokens (distribution shift raises eval loss)
    bad = dict(good)
    bad["tokens"] = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab_size, (2, 32)),
        jnp.int32)
    tc_bad = dataclasses.replace(tc, opt=dataclasses.replace(tc.opt, lr=0.5))
    m._update = jax.jit(lambda s, b: ts_mod.train_step(cfg, tc_bad, s, b))
    for _ in range(6):
        m.online_step(bad, evalb)
    assert m.rollbacks >= 1, (m.history, base_loss)
