"""Serving engine, TM online session, LM online-adaptation manager."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeConfig
from repro.models import params as P
from repro.models import transformer
from repro.serve.engine import Engine, EngineConfig


def test_engine_matches_manual_greedy_decode():
    """Engine.generate == hand-rolled forward argmax loop (teacher forcing
    on its own outputs)."""
    cfg = configs.get_smoke_config("granite_8b")
    specs = transformer.model_specs(cfg)
    prm = P.materialize(specs, jax.random.PRNGKey(0), jnp.float32)
    B, S0, new = 2, 6, 5
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, (B, S0)).astype(np.int32)

    eng = Engine(cfg, prm, EngineConfig(max_seq=S0 + new, batch_slots=B))
    got = eng.generate(prompts, new)

    # reference: full forward re-run per emitted token
    toks = jnp.asarray(prompts)
    want = []
    for i in range(new):
        logits, _ = transformer.forward(cfg, prm, {"tokens": toks})
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        want.append(np.asarray(nxt))
        toks = jnp.concatenate([toks, nxt[:, None]], axis=1)
    want = np.stack(want, axis=1)
    np.testing.assert_array_equal(got, want)


def test_tm_online_session_buffers_and_learns():
    from repro.core import TMConfig, init_runtime, init_state
    from repro.core.online import OnlineSession
    from repro.data import iris
    from repro.data.memory import ROMSource

    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=16)
    sess = OnlineSession(cfg, init_state(cfg), init_runtime(cfg, s=3.0, T=15),
                         buffer_capacity=32)
    xs, ys = iris.load()
    src = ROMSource(xs, ys)
    accepted = sess.fill_from(src, 32)
    assert accepted == 32 and sess.buffered == 32
    assert not sess.offer(xs[0], int(ys[0]))  # full -> backpressure
    trained = sess.learn_available(100)
    assert trained == 32 and sess.buffered == 0
    # after consuming 4 full passes the model classifies better than chance
    for _ in range(4):
        sess.fill_from(src, 32)
        sess.learn_available(32)
    acc = float(np.mean(sess.infer(xs) == ys))
    assert acc > 0.5


def test_online_adapt_rollback(tmp_path):
    """Fig-3 FSM for LMs: degraded eval loss triggers checkpoint rollback."""
    from repro.serve.online_adapt import OnlineAdaptConfig, OnlineAdaptManager
    from repro.train import optimizer as opt_mod
    from repro.train import train_step as ts_mod

    cfg = configs.get_smoke_config("gemma3_1b")
    specs = transformer.model_specs(cfg)
    prm = P.materialize(specs, jax.random.PRNGKey(0), jnp.float32)
    tc = ts_mod.TrainConfig(opt=opt_mod.OptConfig(lr=1e-3, warmup_steps=1,
                                                  total_steps=1000))
    state = ts_mod.init_state(tc, prm)
    oc = OnlineAdaptConfig(analyze_every=2, rollback_threshold=0.05,
                           checkpoint_dir=str(tmp_path))
    m = OnlineAdaptManager(cfg, tc, state, oc)

    from repro.models import stubs
    shape = ShapeConfig("t", 32, 2, "train")
    good = stubs.synthetic_batch(cfg, shape, seed=1)
    evalb = stubs.synthetic_batch(cfg, shape, seed=2)
    m.offline_train([good, good], evalb)
    base_loss = m.history[-1][1]

    # poison online batches with a huge-lr-like effect: feed garbage labels
    # by shuffling tokens (distribution shift raises eval loss)
    bad = dict(good)
    bad["tokens"] = jnp.asarray(
        np.random.default_rng(3).integers(0, cfg.vocab_size, (2, 32)),
        jnp.int32)
    tc_bad = dataclasses.replace(tc, opt=dataclasses.replace(tc.opt, lr=0.5))
    m._update = jax.jit(lambda s, b: ts_mod.train_step(cfg, tc_bad, s, b))
    for _ in range(6):
        m.online_step(bad, evalb)
    assert m.rollbacks >= 1, (m.history, base_loss)
