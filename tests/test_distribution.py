"""Sharding rules, autoshard hints, HLO cost model, small-mesh pjit run."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.distributed import autoshard
from repro.distributed import sharding as shd
from repro.models.params import PSpec
from repro.roofline import hlo_cost


def _mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_partition_rules():
    mesh = _mesh()
    pol = shd.ShardingPolicy(fsdp=False)
    ps = shd.spec_partition(PSpec((100, 64), ("vocab", "embed")), mesh, pol)
    assert ps == PS("model", None)  # vocab -> model (divisible by 1)
    # non-divisible falls back to replication, never crashes
    mesh16 = jax.make_mesh((1,), ("model",))
    pol = shd.ShardingPolicy(fsdp=False)
    ps = shd.spec_partition(PSpec((7, 3), ("kv_heads", "head_dim")), mesh16, pol)
    assert ps == PS("model", None) or ps == PS(None, None)


def test_fsdp_shards_largest_free_dim():
    # AbstractMesh: rule evaluation needs only mesh.shape, not real devices
    mesh = jax.sharding.AbstractMesh((("data", 2), ("model", 16)))
    pol = shd.ShardingPolicy()
    ps = shd.spec_partition(PSpec((128, 64), ("embed", "ff")), mesh, pol)
    assert ps == PS("data", "model")  # ff -> TP; fsdp picks embed over data


def test_spec_partition_nondivisible_replicates():
    mesh = jax.sharding.AbstractMesh((("model", 16),))
    pol = shd.ShardingPolicy(fsdp=False)
    ps = shd.spec_partition(PSpec((7, 3), ("kv_heads", "head_dim")), mesh, pol)
    assert ps == PS(None, None)


def test_autoshard_hint_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = autoshard.hint(x, "data", None)
    assert y is x


def test_autoshard_settings():
    mesh = jax.make_mesh((1,), ("data",))
    assert autoshard.setting("moe_expert_axis", "model") == "model"
    with autoshard.use(mesh, moe_expert_axis="data"):
        assert autoshard.setting("moe_expert_axis", "model") == "data"


SYNTH_HLO = textwrap.dedent("""\
    HloModule test

    %cond (p: (s32[], f32[8,8])) -> pred[] {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %c = s32[] constant(5)
      ROOT %lt = pred[] compare(%i, %c), direction=LT
    }

    %body (p: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
      %p = (s32[], f32[8,8]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %x = f32[8,8]{1,0} get-tuple-element(%p), index=1
      %d = f32[8,8]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[8,8]{1,0} all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%sum
      %one = s32[] constant(1)
      %ni = s32[] add(%i, %one)
      ROOT %t = (s32[], f32[8,8]) tuple(%ni, %ar)
    }

    ENTRY %main (a: f32[8,8]) -> f32[8,8] {
      %a = f32[8,8]{1,0} parameter(0)
      %zero = s32[] constant(0)
      %init = (s32[], f32[8,8]) tuple(%zero, %a)
      %w = (s32[], f32[8,8]) while(%init), condition=%cond, body=%body
      ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
    }
""")


def test_hlo_cost_trip_count_multiplies():
    c = hlo_cost.analyze(SYNTH_HLO, n_devices=4)
    # dot: 2*8*8*8 = 1024 flops x 5 iterations
    assert abs(c.dot_flops - 5 * 1024) < 1e-6
    # all-reduce: 8*8*4 bytes, group 4 -> wire 2*(3/4)*256 = 384 x 5
    assert abs(c.wire_bytes_by_op["all-reduce"] - 5 * 384) < 1e-6


def test_hlo_cost_known_trip_count_annotation():
    hlo = SYNTH_HLO.replace(
        'condition=%cond, body=%body',
        'condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"7"}}')
    c = hlo_cost.analyze(hlo, n_devices=4)
    assert abs(c.dot_flops - 7 * 1024) < 1e-6


SMALL_MESH_SCRIPT = textwrap.dedent("""\
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro import configs
    from repro.configs.base import ShapeConfig
    from repro.distributed import autoshard, sharding as shd
    from repro.models import params as P, stubs, transformer

    cfg = configs.get_smoke_config("granite_8b")
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    policy = shd.ShardingPolicy()
    specs = transformer.model_specs(cfg)
    prm = P.materialize(specs, jax.random.PRNGKey(0), jnp.float32)
    p_shard = shd.param_shardings(specs, mesh, policy)
    prm_sharded = jax.tree.map(jax.device_put, prm, p_shard)
    batch = stubs.synthetic_batch(cfg, ShapeConfig("t", 32, 4, "train"))
    b_shard = shd.batch_shardings(batch, mesh, policy)
    batch = jax.tree.map(jax.device_put, batch, b_shard)

    with mesh, autoshard.use(mesh):
        loss_sharded, _ = jax.jit(
            lambda p, b: transformer.loss_fn(cfg, p, b))(prm_sharded, batch)
    loss_local, _ = transformer.loss_fn(cfg, prm, jax.device_get(batch) | {})
    err = abs(float(loss_sharded) - float(loss_local))
    assert err < 1e-3, (float(loss_sharded), float(loss_local))

    # autoshard hint: divisible dim gets sharded, non-divisible replicates
    from jax.sharding import PartitionSpec as PS
    with autoshard.use(mesh):
        y = autoshard.hint(jnp.ones((8, 4)), "data", None)
        assert y.sharding.spec == PS("data", None), y.sharding
        y2 = autoshard.hint(jnp.ones((3, 4)), "data", None)  # 3 % 4 != 0
    print("OK", float(loss_sharded))
""")


def test_pjit_small_mesh_matches_single_device():
    """8-device SPMD loss == single-device loss (subprocess: own XLA_FLAGS)."""
    import os

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SMALL_MESH_SCRIPT],
                       capture_output=True, text=True, timeout=600, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK" in r.stdout
