"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle.

Shape/dtype sweeps + bit-exactness, per the kernel contract in DESIGN.md §8.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, packing, ref

SHAPES = [
    (1, 2, 5),      # degenerate
    (3, 16, 32),    # the paper's iris machine
    (2, 6, 17),     # non-aligned everything
    (3, 8, 31),     # one under the packed-word boundary (tail masking)
    (3, 8, 33),     # one over it (multi-word + tail)
    (10, 100, 200), # MNIST-ish TM
    (4, 33, 129),   # one over tile boundaries
    (2, 6, 513),    # one over the BLK_L literal-block boundary — exercises
                    # the multi-block accumulation path in tier-1
]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("training", [True, False])
def test_clause_eval_matches_ref(shape, training):
    C, J, L = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    include = jnp.asarray(rng.random((C, J, L)) < 0.3)
    lits = jnp.asarray(rng.random((L,)) < 0.5)
    want = ref.clause_eval(include, lits, training=training)
    got = ops.clause_eval(include, lits, training=training)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_clause_eval_all_excluded_is_empty():
    include = jnp.zeros((2, 4, 32), dtype=bool)
    lits = jnp.ones((32,), dtype=bool)
    assert bool(jnp.all(ops.clause_eval(include, lits, training=True)))
    assert not bool(jnp.any(ops.clause_eval(include, lits, training=False)))


def test_clause_eval_single_violation_kills_clause():
    L = 32
    include = jnp.zeros((1, 2, L), dtype=bool).at[0, 0, 7].set(True)
    lits = jnp.ones((L,), dtype=bool).at[7].set(False)
    out = ops.clause_eval(include, lits, training=True)
    assert not bool(out[0, 0])  # included literal is 0 -> clause 0
    assert bool(out[0, 1])      # empty clause in training -> 1


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("policy", ["standard", "hardware"])
@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int16])
def test_feedback_matches_ref(shape, policy, dtype):
    C, J, L = shape
    n_states = 50 if dtype == jnp.int8 else 5000
    rng = np.random.default_rng(hash((shape, policy)) % 2**31)
    ta = jnp.asarray(rng.integers(1, 2 * n_states + 1, (C, J, L)), dtype=dtype)
    lits = jnp.asarray(rng.random((L,)) < 0.5)
    c_out = jnp.asarray(rng.random((C, J)) < 0.5)
    t1 = jnp.asarray(rng.random((C, J)) < 0.5)
    t2 = jnp.asarray(rng.random((C, J)) < 0.3) & ~t1
    u = jnp.asarray(rng.random((C, J, L)), dtype=jnp.float32)
    for boost in (True, False):
        kw = dict(s=jnp.float32(1.375), n_states=n_states, s_policy=policy,
                  boost_true_positive=boost)
        want = ref.feedback_step(ta, lits, c_out, t1, t2, u, **kw)
        got = ops.feedback_step(ta, lits, c_out, t1, t2, u, **kw)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_feedback_states_stay_in_bounds():
    C, J, L, n = 2, 8, 32, 10
    rng = np.random.default_rng(3)
    ta = jnp.asarray(rng.integers(1, 2 * n + 1, (C, J, L)), dtype=jnp.int8)
    lits = jnp.ones((L,), dtype=bool)
    ones = jnp.ones((C, J), dtype=bool)
    u = jnp.zeros((C, J, L), dtype=jnp.float32)  # every draw fires
    out = ops.feedback_step(
        ta, lits, ones, ones, jnp.zeros_like(ones), u,
        s=jnp.float32(1.0), n_states=n, s_policy="standard",
        boost_true_positive=True,
    )
    o = np.asarray(out)
    assert o.min() >= 1 and o.max() <= 2 * n


# Packed-kernel parity (DESIGN.md §13). The packed kernels are layout-
# agnostic — any include/literal pair packed with the SAME word layout and
# zero include tails works — so here the literal axis packs contiguously
# (pack_bits over L), exercising tail masking at L = 31/33 and multi-word
# accumulation at L = 513 directly against the unpacked oracle.


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("mod", [ref, ops], ids=["ref", "pallas"])
def test_clause_eval_batch_packed_matches_unpacked_oracle(shape, mod):
    C, J, L = shape
    rng = np.random.default_rng(hash(("packed",) + shape) % 2**31)
    include = jnp.asarray(rng.random((C, J, L)) < 0.3)
    lits = jnp.asarray(rng.random((9, L)) < 0.5)
    inc_p = packing.pack_bits(include)
    lit_p = packing.pack_bits(lits)
    for training in (True, False):
        want = ref.clause_eval_batch(include, lits, training=training)
        got = mod.clause_eval_batch_packed(inc_p, lit_p, training=training)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


# Replica-parallel shapes: (R, D, C, J, L) with odd sizes that straddle the
# int8 32x128 tile boundaries, plus grid-sharing layouts (D < R).
REP_SHAPES = [
    (1, 1, 1, 2, 5),       # degenerate single replica
    (3, 1, 2, 6, 17),      # one data stream shared by 3 grid cells
    (6, 3, 3, 16, 32),     # the iris machine, 2x3 grid-over-orderings
    (2, 2, 2, 8, 31),      # one under the packed-word boundary
    (5, 5, 2, 7, 33),      # replicas == data streams (system path), odd L
    (4, 2, 4, 33, 129),    # one over both tile boundaries
    (4, 2, 2, 6, 513),     # one over the BLK_L literal-block boundary
]


def _rep_inputs(R, D, C, J, L, seed=0):
    rng = np.random.default_rng(seed)
    return {
        "include": jnp.asarray(rng.random((R, C, J, L)) < 0.3),
        "lits": jnp.asarray(rng.random((D, L)) < 0.5),
        "rng": rng,
    }


@pytest.mark.parametrize("shape", REP_SHAPES)
@pytest.mark.parametrize("mod", [ref, ops], ids=["ref", "pallas"])
def test_clause_eval_replicated_matches_stacked(shape, mod):
    R, D, C, J, L = shape
    inp = _rep_inputs(*shape, seed=hash(shape) % 2**31)
    for training in (True, False):
        want = jnp.stack([
            ref.clause_eval(inp["include"][r], inp["lits"][r % D],
                            training=training)
            for r in range(R)
        ])
        got = mod.clause_eval_replicated(
            inp["include"], inp["lits"], training=training
        )
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("shape", REP_SHAPES)
@pytest.mark.parametrize("mod", [ref, ops], ids=["ref", "pallas"])
def test_clause_eval_batch_replicated_matches_stacked(shape, mod):
    R, D, C, J, L = shape
    rng = np.random.default_rng(hash(shape) % 2**31)
    include = jnp.asarray(rng.random((R, C, J, L)) < 0.3)
    lits = jnp.asarray(rng.random((D, 5, L)) < 0.5)
    for training in (True, False):
        want = jnp.stack([
            ref.clause_eval_batch(include[r], lits[r % D], training=training)
            for r in range(R)
        ])
        got = mod.clause_eval_batch_replicated(include, lits, training=training)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("shape", REP_SHAPES)
@pytest.mark.parametrize("mod", [ref, ops], ids=["ref", "pallas"])
def test_clause_eval_batch_replicated_packed_matches_unpacked(shape, mod):
    R, D, C, J, L = shape
    rng = np.random.default_rng(hash(("packed",) + shape) % 2**31)
    include = jnp.asarray(rng.random((R, C, J, L)) < 0.3)
    lits = jnp.asarray(rng.random((D, 5, L)) < 0.5)
    inc_p = packing.pack_bits(include)
    lit_p = packing.pack_bits(lits)
    for training in (True, False):
        want = ref.clause_eval_batch_replicated(
            include, lits, training=training
        )
        got = mod.clause_eval_batch_replicated_packed(
            inc_p, lit_p, training=training
        )
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("shape", REP_SHAPES)
@pytest.mark.parametrize("policy", ["standard", "hardware"])
@pytest.mark.parametrize("dtype", [jnp.int8, jnp.int16])
@pytest.mark.parametrize("mod", [ref, ops], ids=["ref", "pallas"])
def test_feedback_replicated_matches_stacked(shape, policy, dtype, mod):
    """feedback_step_replicated == stacking per-replica feedback_step calls,
    bit for bit, on both backends (pallas in interpret mode)."""
    R, D, C, J, L = shape
    n_states = 50 if dtype == jnp.int8 else 5000
    rng = np.random.default_rng(hash((shape, policy)) % 2**31)
    ta = jnp.asarray(rng.integers(1, 2 * n_states + 1, (R, C, J, L)), dtype=dtype)
    lits = jnp.asarray(rng.random((D, L)) < 0.5)
    c_out = jnp.asarray(rng.random((R, C, J)) < 0.5)
    t1 = jnp.asarray(rng.random((R, C, J)) < 0.5)
    t2 = jnp.asarray(rng.random((R, C, J)) < 0.3) & ~t1
    u = jnp.asarray(rng.random((D, C, J, L)), dtype=jnp.float32)
    s = jnp.asarray(1.0 + 5.0 * rng.random(R), dtype=jnp.float32)
    for boost in (True, False):
        kw = dict(n_states=n_states, s_policy=policy, boost_true_positive=boost)
        want = jnp.stack([
            ref.feedback_step(ta[r], lits[r % D], c_out[r], t1[r], t2[r],
                              u[r % D], s=s[r], **kw)
            for r in range(R)
        ])
        got = mod.feedback_step_replicated(
            ta, lits, c_out, t1, t2, u, s=s, **kw
        )
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_feedback_replicated_rejects_bad_data_axis():
    ta = jnp.ones((4, 1, 2, 8), dtype=jnp.int8)
    lits = jnp.zeros((3, 8), dtype=bool)  # 3 does not divide 4
    with pytest.raises(ValueError, match="must divide"):
        ref.feedback_step_replicated(
            ta, lits, jnp.zeros((4, 1, 2), bool), jnp.zeros((4, 1, 2), bool),
            jnp.zeros((4, 1, 2), bool), jnp.zeros((3, 1, 2, 8), jnp.float32),
            s=jnp.ones(4), n_states=3, s_policy="standard",
            boost_true_positive=True,
        )


def test_end_to_end_backend_parity():
    """Full TM training is bit-exact between ref and pallas backends."""
    from repro.core import TMConfig, init_runtime, init_state, train_epochs
    from repro.data import iris

    xs, ys = iris.load()
    key = jax.random.PRNGKey(7)
    outs = {}
    for backend in ("ref", "pallas"):
        cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16,
                       n_states=50, backend=backend)
        rt = init_runtime(cfg, s=1.375, T=15)
        st = train_epochs(cfg, init_state(cfg), rt,
                          jnp.asarray(xs[:30]), jnp.asarray(ys[:30]), key, 2)
        outs[backend] = np.asarray(st.ta_state)
    np.testing.assert_array_equal(outs["ref"], outs["pallas"])
