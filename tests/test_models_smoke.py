"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each of the 10 assigned archs: one forward + one train step (grad +
SGD update) asserting output shapes and no NaNs, one decode step, and a
prefill->decode == full-forward consistency check (MoE archs checked with
drop-free capacity, since capacity truncation legitimately differs between
batch shapes).
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import ShapeConfig
from repro.models import params as P
from repro.models import stubs, transformer

TRAIN_SHAPE = ShapeConfig("smoke_train", 32, 2, "train")
DECODE_SHAPE = ShapeConfig("smoke_decode", 32, 2, "decode")


def _setup(arch, **replace):
    cfg = configs.get_smoke_config(arch)
    if replace:
        cfg = dataclasses.replace(cfg, **replace)
    specs = transformer.model_specs(cfg)
    prm = P.materialize(specs, jax.random.PRNGKey(0), jnp.float32)
    return cfg, prm


_DESCENT_STEP = 0.005   # SGD step of the descent check (in- and subprocess)

_DESCENT_SCRIPT = textwrap.dedent("""\
    import jax, jax.numpy as jnp
    from repro import configs
    from repro.configs.base import ShapeConfig
    from repro.models import params as P
    from repro.models import stubs, transformer

    arch = {arch!r}
    cfg = configs.get_smoke_config(arch)
    prm = P.materialize(transformer.model_specs(cfg),
                        jax.random.PRNGKey(0), jnp.float32)
    batch = stubs.synthetic_batch(cfg, ShapeConfig(*{shape!r}))
    (loss, _), grads = jax.value_and_grad(
        lambda p: transformer.loss_fn(cfg, p, batch), has_aux=True
    )(prm)
    prm2 = jax.tree.map(lambda p, g: p - {step!r} * g, prm, grads)
    loss2, _ = transformer.loss_fn(cfg, prm2, batch)
    assert float(loss2) < float(loss), (float(loss2), float(loss))
    print("DESCENT_OK")
""")


def _assert_descends_in_fresh_process(arch: str):
    """Ground-truth re-check of the one-SGD-step descent in a clean process.

    The in-process check flakes ~1-in-2 on FULL-suite runs on some boxes:
    this container's XLA CPU occasionally compiles/evaluates f32 numerics
    that shift loss ~0.1-0.5% with accumulated process state, exceeding
    some archs' one-step descent margin (diagnosed in CHANGES.md PR 3;
    robustified assertions were tried and reverted — occasionally-wrong
    gradients can't be absorbed by a margin). The check is deterministic
    in a fresh process, so a genuine regression still fails here.
    """
    env = dict(os.environ)
    src = os.path.join(os.path.dirname(os.path.dirname(__file__)), "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    script = _DESCENT_SCRIPT.format(
        arch=arch, shape=dataclasses.astuple(TRAIN_SHAPE),
        step=_DESCENT_STEP,
    )
    r = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True, text=True, timeout=600, env=env,
    )
    assert r.returncode == 0 and "DESCENT_OK" in r.stdout, (
        f"{arch}: one-step descent fails even in a fresh process "
        f"(a real regression, not the known full-suite numerics flake):\n"
        f"{r.stdout}{r.stderr}"
    )


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg, prm = _setup(arch)
    batch = stubs.synthetic_batch(cfg, TRAIN_SHAPE)

    logits, aux = transformer.forward(cfg, prm, batch)
    S = TRAIN_SHAPE.seq_len
    assert logits.shape == (2, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    (loss, parts), grads = jax.value_and_grad(
        lambda p: transformer.loss_fn(cfg, p, batch), has_aux=True
    )(prm)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0

    # one SGD step must reduce loss on the same batch (sanity of gradients)
    prm2 = jax.tree.map(lambda p, g: p - _DESCENT_STEP * g, prm, grads)
    loss2, _ = transformer.loss_fn(cfg, prm2, batch)
    if not float(loss2) < float(loss):
        # Known process-state-dependent XLA CPU numerics flake: the descent
        # margin is only trustworthy in a fresh process. Isolate and
        # re-verify there; fail only if the clean process also fails.
        _assert_descends_in_fresh_process(arch)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_step(arch):
    cfg, prm = _setup(arch)
    batch = stubs.synthetic_batch(cfg, DECODE_SHAPE)
    cache = batch.pop("cache")
    logits, new_cache = transformer.decode_step(cfg, prm, batch, cache)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure is preserved (scan/unrolled trees line up)
    assert jax.tree.structure(cache) == jax.tree.structure(new_cache)


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    """decode(prefill(x[:S]), x[S]) == forward(x[:S+1])[S] for every family."""
    kw = {}
    cfg0 = configs.get_smoke_config(arch)
    if cfg0.moe is not None:  # drop-free capacity for exactness
        kw["moe"] = dataclasses.replace(
            cfg0.moe, capacity_factor=float(cfg0.moe.n_experts)
        )
    cfg, prm = _setup(arch, **kw)
    S, B, max_seq = 12, 2, 24
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S + 1)),
                       dtype=jnp.int32)
    full = {}
    if cfg.embeds_input:
        full["embeds"] = jnp.asarray(
            0.05 * rng.standard_normal((B, S + 1, cfg.d_model)), jnp.float32
        )
    else:
        full["tokens"] = toks
    if cfg.family == "vlm":
        full["cross_embeds"] = jnp.asarray(
            0.05 * rng.standard_normal((B, cfg.n_cross_tokens, cfg.d_model)),
            jnp.float32,
        )

    logits_full, _ = transformer.forward(cfg, prm, full)
    want = np.asarray(logits_full[:, S, :])

    pre = dict(full)
    if cfg.embeds_input:
        pre["embeds"] = full["embeds"][:, :S]
    else:
        pre["tokens"] = toks[:, :S]
    _, cache = transformer.prefill(cfg, prm, pre, max_seq)

    dec = {"pos": jnp.int32(S)}
    if cfg.embeds_input:
        dec["embeds"] = full["embeds"][:, S : S + 1]
    else:
        dec["token"] = toks[:, S : S + 1]
    got, _ = transformer.decode_step(cfg, prm, dec, cache)

    err = np.max(np.abs(want - np.asarray(got))) / (np.max(np.abs(want)) + 1e-9)
    assert err < 2e-3, f"{arch}: prefill/decode drift rel_err={err}"


def test_param_counts_match_analytic():
    """PSpec tree total == ModelConfig.param_count() for every arch."""
    for arch in configs.ARCH_IDS:
        full = configs.get_config(arch)
        got = P.count_params(transformer.model_specs(full))
        want = full.param_count()
        rel = abs(got - want) / want
        assert rel < 0.02, f"{arch}: spec={got} analytic={want} rel={rel:.3f}"


def test_full_config_sizes_sane():
    """Full configs land near their nameplate parameter counts."""
    expect = {
        "llama32_vision_11b": (9e9, 13e9),
        "recurrentgemma_9b": (7e9, 11e9),
        "granite_8b": (7e9, 9.5e9),
        "gemma3_1b": (0.7e9, 1.6e9),
        "phi3_medium_14b": (12e9, 16e9),
        "qwen25_14b": (12e9, 16e9),
        "musicgen_medium": (1.2e9, 2.2e9),
        "arctic_480b": (430e9, 520e9),
        "olmoe_1b_7b": (6e9, 8e9),
        "mamba2_780m": (0.6e9, 1.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = configs.get_config(arch).param_count()
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"


def test_long_context_support_flags():
    """long_500k eligibility: ssm/hybrid/local-dominant only (DESIGN.md §4)."""
    runs = {a for a in configs.ARCH_IDS
            if configs.get_config(a).supports_long_context}
    assert runs == {"recurrentgemma_9b", "mamba2_780m", "gemma3_1b"}
