"""Runtime-tunable serving (DESIGN.md §16): ranking, pruning, early exit.

The load-bearing contract is bitwise: budget = 100% with unit weights and
early exit disabled must equal the existing serve path bit for bit — both
backends, packed and unpacked, under residency, and across save -> restore.
Pruning reorders an integer sum (adds commute) and compaction gathers the
same include rows the full contraction reads, so any drift is a kernel bug,
not tolerance noise.
"""
import tempfile

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TMConfig, init_state
from repro.core import accuracy as acc_mod
from repro.core import tm as tm_mod
from repro.kernels import packing as pack_mod
from repro.serve import ServiceConfig, TMService, TunableConfig
from repro.serve import tunable as tun

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # optional dev dependency (requirements-dev.txt)
    HAVE_HYPOTHESIS = False

K, F, C, J, N = 4, 16, 3, 8, 32

_RNG = np.random.default_rng(11)
X = _RNG.random((40, F)) > 0.5
Y = _RNG.integers(0, C, 40).astype(np.int32)


def _cfg(backend="ref"):
    return TMConfig(n_features=F, max_classes=C, max_clauses=J,
                    n_states=N, backend=backend)


def _rand_state(cfg, seed=0, replicas=None):
    """Random-but-legal TA banks: parity needs nontrivial include planes,
    not trained ones."""
    rng = np.random.default_rng(seed)
    shape = (C, J, 2 * F)
    if replicas is not None:
        shape = (replicas,) + shape
    return tm_mod.TMState(ta_state=jnp.asarray(
        rng.integers(1, 2 * N + 1, shape), dtype=jnp.int8))


def _full_perm(rng, replicas=None):
    """A random FULL permutation ranking [C, J] (or [R, C, J])."""
    if replicas is None:
        return np.stack([rng.permutation(J) for _ in range(C)]
                        ).astype(np.int32)
    return np.stack([_full_perm(rng) for _ in range(replicas)])


# ---------------------------------------------------------------------------
# Core: full-budget pruned == plain, subset == manual, both backends
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_full_permutation_pruned_bitwise_equals_plain(backend):
    cfg = _cfg(backend)
    state = _rand_state(cfg, 1)
    rt = tm_mod.init_runtime(cfg)
    xs = jnp.asarray(X)
    sel = jnp.asarray(_full_perm(np.random.default_rng(2)))
    want = np.asarray(tm_mod.predict_batch_(cfg, state, rt, xs))
    got = np.asarray(tm_mod.predict_batch_pruned_(cfg, state, rt, xs, sel))
    np.testing.assert_array_equal(want, got)
    # replicated twin, per-replica permutations
    stR = _rand_state(cfg, 3, replicas=K)
    selR = jnp.asarray(_full_perm(np.random.default_rng(4), replicas=K))
    wantR = np.asarray(tm_mod.predict_batch_replicated_(
        cfg, stR, rt, xs[None]))
    gotR = np.asarray(tm_mod.predict_batch_pruned_replicated_(
        cfg, stR, rt, xs[None], selR))
    np.testing.assert_array_equal(wantR, gotR)


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_packed_pruned_bitwise_equals_unpacked_pruned(backend):
    cfg = _cfg(backend)
    state = _rand_state(cfg, 5)
    rt = tm_mod.init_runtime(cfg)
    sel = jnp.asarray(_full_perm(np.random.default_rng(6))[:, :5])  # m=5
    xs_p = pack_mod.pack_bits(jnp.asarray(X))    # raw feature words (§13)
    votes_u = tm_mod.forward_batch_pruned(cfg, state, rt, jnp.asarray(X),
                                          sel)[1]
    votes_p = tm_mod.forward_batch_pruned(cfg, state, rt, xs_p, sel)[1]
    np.testing.assert_array_equal(np.asarray(votes_u), np.asarray(votes_p))


def test_pruned_votes_match_manual_subset():
    """Budget-m votes == hand-built sum over exactly the selected clauses
    (weighted and unit) — the kernel never reads an unselected clause."""
    cfg = _cfg()
    state = _rand_state(cfg, 7)
    rt = tm_mod.init_runtime(cfg)
    rng = np.random.default_rng(8)
    sel = jnp.asarray(_full_perm(rng)[:, :3])                      # m=3
    weights = jnp.asarray(rng.integers(1, 8, (C, J)), dtype=jnp.int32)
    clauses, votes = tm_mod.forward_batch(cfg, state, rt, jnp.asarray(X))
    swt = np.asarray(tm_mod.vote_weights(cfg, rt, weights))        # [C, J]
    manual = np.zeros((len(X), C), dtype=np.int64)
    cl = np.asarray(clauses, dtype=np.int64)
    for c in range(C):
        for m in range(3):
            j = int(sel[c, m])
            manual[:, c] += cl[:, c, j] * swt[c, j]
    got = tm_mod.forward_batch_pruned(cfg, state, rt, jnp.asarray(X),
                                      sel, weights)[1]
    np.testing.assert_array_equal(manual, np.asarray(got))


def test_analyze_pruned_full_permutation_equals_analyze():
    cfg = _cfg()
    state = _rand_state(cfg, 9)
    rt = tm_mod.init_runtime(cfg)
    sel = jnp.asarray(_full_perm(np.random.default_rng(10)))
    a = float(acc_mod.analyze(cfg, state, rt, jnp.asarray(X),
                              jnp.asarray(Y)))
    b = float(acc_mod.analyze_pruned(cfg, state, rt, jnp.asarray(X),
                                     jnp.asarray(Y), sel))
    assert a == b


# ---------------------------------------------------------------------------
# Ranking: deterministic permutation; weights positive ints
# ---------------------------------------------------------------------------


def test_clause_scores_deterministic_and_rank_is_permutation():
    cfg = _cfg()
    state = _rand_state(cfg, 12)
    rt = tm_mod.init_runtime(cfg)
    s1 = np.asarray(tun.clause_scores(cfg, state, rt, jnp.asarray(X),
                                      jnp.asarray(Y)))
    s2 = np.asarray(tun.clause_scores(cfg, state, rt, jnp.asarray(X),
                                      jnp.asarray(Y)))
    np.testing.assert_array_equal(s1, s2)
    order = tun.rank_from_scores(s1)
    assert order.shape == (C, J)
    np.testing.assert_array_equal(np.sort(order, axis=-1),
                                  np.broadcast_to(np.arange(J), (C, J)))


def test_weights_from_scores_bounds():
    rng = np.random.default_rng(13)
    score = rng.integers(-50, 50, (K, C, J)).astype(np.int32)
    assert tun.weights_from_scores(score, 0) is None
    w = tun.weights_from_scores(score, 4)
    assert w.dtype == np.int32
    assert w.min() >= 1 and w.max() <= 15          # [1, 2^bits - 1]
    # the per-class peak score always gets the max weight
    flat_peak = np.take_along_axis(
        w, score.argmax(axis=-1)[..., None], axis=-1)
    assert (flat_peak == 15).all()


def test_m_for_budget():
    assert tun.m_for_budget(1.0, J) == J
    assert tun.m_for_budget(0.5, J) == J // 2
    assert tun.m_for_budget(1e-9, J) == 1           # floor at one clause
    for bad in (0.0, -0.5, 1.5):
        with pytest.raises(ValueError):
            tun.m_for_budget(bad, J)


# ---------------------------------------------------------------------------
# Early exit: identical predictions, fewer clauses evaluated
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("group", [1, 2, 3, 8])
def test_early_exit_predictions_bitwise_equal_no_exit(group):
    cfg = _cfg()
    rt = tm_mod.init_runtime(cfg)
    stR = _rand_state(cfg, 14, replicas=K)
    rng = np.random.default_rng(15)
    order = _full_perm(rng, replicas=K)
    weights = rng.integers(1, 8, (K, C, J)).astype(np.int32)
    for m in (J, J // 2, 1):
        base, ev0 = tun.predict_pruned_replicated_host(
            cfg, stR, rt, np.asarray(X)[None], order, weights, m,
            group=None)
        got, ev = tun.predict_pruned_replicated_host(
            cfg, stR, rt, np.asarray(X)[None], order, weights, m,
            group=group)
        np.testing.assert_array_equal(base, got)
        assert (ev0 == m).all()
        assert ev.max() <= m and ev.min() >= min(group, m)
    # at m = J, some request should decide before the last group
    # (not guaranteed in general, but overwhelmingly likely here)
    if group <= 2:
        assert (ev < J).any()


def test_early_exit_respects_class_mask():
    """Inactive classes can neither win nor keep the exit bound alive."""
    cfg = _cfg()
    rt = tm_mod.init_runtime(cfg)
    rt = rt._replace(class_mask=jnp.asarray([True, False, True]))
    stR = _rand_state(cfg, 16, replicas=K)
    order = _full_perm(np.random.default_rng(17), replicas=K)
    p0, _ = tun.predict_pruned_replicated_host(
        cfg, stR, rt, np.asarray(X)[None], order, None, J, group=None)
    p1, _ = tun.predict_pruned_replicated_host(
        cfg, stR, rt, np.asarray(X)[None], order, None, J, group=2)
    np.testing.assert_array_equal(p0, p1)
    assert not (p0 == 1).any()                      # masked class never wins


# ---------------------------------------------------------------------------
# Service integration: parity across backends x packed x residency x
# save/restore; adapt; error guidance
# ---------------------------------------------------------------------------


def _service(backend="ref", *, packed=False, resident=None, tunable=None):
    cfg = _cfg(backend)
    sc = ServiceConfig(replicas=K, buffer_capacity=64, chunk=8,
                       s=3.0, T=10, seed=0, packed=packed,
                       resident=resident, tunable=tunable)
    return TMService(cfg, init_state(cfg), sc, eval_x=X, eval_y=Y)


def _train(svc, n=24):
    for i in range(n):
        svc.submit_rows(X[i % len(X)], np.full(K, Y[i % len(Y)]))
        svc.tick()
    svc.flush()
    return svc


@pytest.fixture(scope="module")
def trained_dirs():
    """One trained checkpoint per (backend, packed) combo — the plain
    services whose serve output is the parity oracle."""
    out = {}
    for backend in ("ref", "pallas"):
        for packed in (False, True):
            svc = _train(_service(backend, packed=packed))
            d = tempfile.mkdtemp()
            svc.save(d)
            out[(backend, packed)] = (d, svc.serve(X))
    return out


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("packed", [False, True])
@pytest.mark.parametrize("resident", [None, 2])
def test_service_full_budget_parity(trained_dirs, backend, packed, resident):
    """budget=100%, unit weights, no early exit == the pre-§16 serve path
    bit for bit, through every datapath and the residency plane."""
    d, base = trained_dirs[(backend, packed)]
    svc = _service(backend, packed=packed, resident=resident,
                   tunable=TunableConfig(budget=1.0))
    svc.load(d)
    svc.calibrate()
    if resident is None:
        np.testing.assert_array_equal(svc.serve(X, budget=1.0), base)
    got = svc.serve_replicas(np.arange(K), X, budget=1.0)
    np.testing.assert_array_equal(got, base)


def test_service_parity_across_save_restore(trained_dirs):
    d, base = trained_dirs[("ref", False)]
    svc = _service(tunable=TunableConfig(budget=0.5, weight_bits=4))
    svc.load(d)
    svc.calibrate()
    preds = svc.serve(X)            # active tunable: budgeted by default
    d2 = tempfile.mkdtemp()
    svc.save(d2)
    svc2 = TMService.restore(d2, eval_x=X, eval_y=Y)
    assert svc2.tuner.calibrated
    np.testing.assert_array_equal(svc2.tuner.order, svc.tuner.order)
    np.testing.assert_array_equal(svc2.tuner.weights, svc.tuner.weights)
    np.testing.assert_array_equal(svc2.serve(X), preds)
    # an explicit budget still serves through the restored ranks/weights:
    # pre- and post-restore full-budget serves must agree bit for bit
    np.testing.assert_array_equal(svc2.serve(X, budget=1.0),
                                  svc.serve(X, budget=1.0))


def test_service_ranks_survive_eviction(trained_dirs):
    """Rankings are host-side per-replica state: serving a cohort after
    its members were evicted and reactivated uses the same ranks."""
    d, _ = trained_dirs[("ref", False)]
    tc = TunableConfig(budget=0.5, early_exit=True, group=2)
    svc = _service(resident=2, tunable=tc)
    svc.load(d)
    svc.calibrate()
    first = svc.serve_replicas(np.arange(K), X)
    # touch every replica so each one has been evicted at least once
    for r in range(K):
        svc.serve_replicas([r], X[:2])
    again = svc.serve_replicas(np.arange(K), X)
    np.testing.assert_array_equal(first, again)


def test_service_uncalibrated_and_unconfigured_errors(trained_dirs):
    d, _ = trained_dirs[("ref", False)]
    plain = _service()
    plain.load(d)
    with pytest.raises(ValueError, match="tunable"):
        plain.serve(X, budget=0.5)
    armed = _service(tunable=TunableConfig(budget=0.5))
    armed.load(d)
    with pytest.raises(ValueError, match="calibrate"):
        armed.serve(X)
    with pytest.raises(ValueError, match="budget"):
        plain.serve(X, return_aux=True)


def test_load_of_uncalibrated_checkpoint_resets_tuner(trained_dirs):
    d, _ = trained_dirs[("ref", False)]
    svc = _service(tunable=TunableConfig(budget=1.0))
    svc.load(d)
    svc.calibrate()
    assert svc.tuner.calibrated
    svc.load(d)                      # d was saved without a tuner
    assert not svc.tuner.calibrated


def test_adapt_sheds_and_recovers_budget(trained_dirs):
    d, _ = trained_dirs[("ref", False)]
    tc = TunableConfig(budget=1.0, adapt=True, min_budget=0.25,
                       high_water=4, low_water=1, step=2.0)
    svc = _service(tunable=tc)
    svc.load(d)
    svc.calibrate()
    for i in range(12):
        svc.submit_rows(X[i], np.full(K, Y[i]))
    svc.tick(max_points=1)           # deep queue after a starved drain
    assert svc.tuner.budget == 0.5
    for _ in range(10):
        svc.tick()                   # queue drains; budget climbs home
    assert svc.tuner.budget == 1.0


def test_traffic_result_logs_budget(trained_dirs):
    from repro.serve import SCENARIOS, make_scripts, run_threaded
    d, _ = trained_dirs[("ref", False)]
    tc = TunableConfig(budget=1.0, adapt=True, min_budget=0.25,
                       high_water=16, low_water=1)
    svc = _service(tunable=tc)
    svc.load(d)
    svc.calibrate()
    scen = SCENARIOS["steady"]
    res = run_threaded(svc, make_scripts(scen, X, Y, C, K, seed=3),
                       scenario=scen, pace=0.0, seed=3)
    assert res.tick_budget is not None
    assert len(res.tick_budget) == res.ticks
    assert (res.tick_budget >= tc.min_budget).all()
    assert (res.tick_budget <= 1.0).all()


# ---------------------------------------------------------------------------
# Hypothesis properties (optional dev dependency)
# ---------------------------------------------------------------------------


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           shape=st.tuples(st.integers(1, 4), st.integers(2, 12)))
    def test_property_ranking_is_deterministic_permutation(seed, shape):
        """Every clause ranked exactly once; same scores -> same ranks."""
        c, j = shape
        rng = np.random.default_rng(seed)
        score = rng.integers(-100, 100, (c, j)).astype(np.int32)
        o1 = tun.rank_from_scores(score)
        o2 = tun.rank_from_scores(score.copy())
        np.testing.assert_array_equal(o1, o2)
        np.testing.assert_array_equal(
            np.sort(o1, axis=-1), np.broadcast_to(np.arange(j), (c, j)))
        # ties break toward the lower clause index (stable sort)
        flat = score.reshape(-1, j)
        of = o1.reshape(-1, j)
        for row in range(flat.shape[0]):
            s, o = flat[row], of[row]
            for a, b in zip(o[:-1], o[1:]):
                assert (s[a] > s[b]) or (s[a] == s[b] and a < b)

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1),
           budget=st.floats(0.01, 1.0),
           group=st.one_of(st.none(), st.integers(1, 8)))
    def test_property_budget_never_evaluates_outside_top_m(
            seed, budget, group):
        """At ANY budget the serve path touches only the top-m ranked
        clauses: the aux sel is exactly order[:, :, :m] and per-request
        evaluated counts never exceed m."""
        cfg = _cfg()
        rt = tm_mod.init_runtime(cfg)
        stR = _rand_state(cfg, seed, replicas=K)
        rng = np.random.default_rng(seed)
        order = _full_perm(rng, replicas=K)
        m = tun.m_for_budget(budget, J)
        preds, evaluated = tun.predict_pruned_replicated_host(
            cfg, stR, rt, np.asarray(X)[None], order, None, m, group=group)
        assert evaluated.max() <= m
        # the compacted contraction IS the top-m gather: votes must match
        # a from-scratch evaluation restricted to order[:, :, :m]
        sel = jnp.asarray(order[:, :, :m])
        want = np.asarray(tm_mod.predict_batch_pruned_replicated_(
            cfg, stR, rt, jnp.asarray(X)[None], sel))
        np.testing.assert_array_equal(preds, want)
