"""TMService redesign parity suite: shims == pre-redesign code, bit for bit.

``OnlineSession``, ``OnlineFleet``, ``TMOnlineAdaptManager`` and
``TMFleetAdaptManager`` are now thin shims over ``repro.serve.service.
TMService`` (one FSM, one drain, queue-based ingress). This suite pins
them to their PRE-redesign behavior: the ``Legacy*`` classes below are
faithful transcriptions of the deleted implementations (immediate
per-point device enqueue, per-object RNG key handling, duplicated
scalar / [K] FSMs), and every test drives shim and oracle through the
same traffic and asserts bitwise-identical trajectories — TA banks,
counters, monitoring aux, histories — on both kernel backends and for
K ∈ {1, 3, 8} including per-replica [K] s/T runtime ports.
"""
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TMConfig, init_runtime, init_state
from repro.core import accuracy as acc_mod
from repro.core import feedback as fb_mod
from repro.core import online as online_mod
from repro.core import tm as tm_mod
from repro.core.online import OnlineSession, SessionState
from repro.core.tm import TMState
from repro.data import buffer as buf_mod
from repro.data import iris
from repro.serve.fleet import OnlineFleet
from repro.serve.online_adapt import (
    TMFleetAdaptManager,
    TMOnlineAdaptConfig,
    TMOnlineAdaptManager,
)


def _cfg(backend="ref"):
    return TMConfig(n_features=16, max_classes=3, max_clauses=16,
                    n_states=16, backend=backend)


def _offer_streams(K, n, stride=7):
    xs, ys = iris.load()
    return [
        [(xs[(i + stride * r) % len(xs)], int(ys[(i + stride * r) % len(xs)]))
         for i in range(n)]
        for r in range(K)
    ]


# ---------------------------------------------------------------------------
# Oracles: the pre-redesign implementations, transcribed verbatim (modulo
# imports). These are what the shims must reproduce bit for bit.
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnums=0)
def _legacy_enqueue(cfg, ss, x, y):
    new_buf, ok = buf_mod.push(ss.buf, x, y)
    return ss._replace(buf=new_buf), ok


@partial(jax.jit, static_argnums=0)
def _legacy_enqueue_rows(cfg, ss, xs, ys, mask):
    def push_one(buf_r, x, y, m):
        new_buf, ok = buf_mod.push(buf_r, x, y)
        buf = jax.tree.map(lambda a, b: jnp.where(m, a, b), new_buf, buf_r)
        return buf, ok & m

    bufs, oks = jax.vmap(push_one)(ss.buf, xs, ys, mask)
    return ss._replace(buf=bufs), oks


@jax.jit
def _legacy_advance_keys(keys, active):
    k2 = jax.vmap(jax.random.split)(keys)
    return jnp.where(active[:, None], k2[:, 0], keys), k2[:, 1]


class LegacySession:
    """Pre-redesign OnlineSession: immediate enqueue, own scalar key."""

    def __init__(self, cfg, state, rt, *, buffer_capacity=64, chunk=16,
                 seed=0):
        self.cfg = cfg
        self.rt = rt
        self.chunk = max(1, min(chunk, buffer_capacity))
        self._key = jax.random.PRNGKey(seed)
        self.ss = SessionState(
            tm=state,
            buf=buf_mod.make(buffer_capacity, cfg.n_features),
            step=jnp.int32(0),
        )
        self.dropped = 0

    def offer(self, x, y) -> bool:
        x = jnp.asarray(x, dtype=bool)
        y = jnp.asarray(y, dtype=jnp.int32)
        self.ss, ok = _legacy_enqueue(self.cfg, self.ss, x, y)
        accepted = bool(ok)
        if not accepted:
            self.dropped += 1
        return accepted

    def learn_available(self, max_points, on_chunk=None) -> int:
        trained = 0
        monitor = on_chunk is not None
        while trained < max_points:
            want = min(self.chunk, max_points - trained)
            self._key, k = jax.random.split(self._key)
            self.ss, n, aux = online_mod._consume_many(
                self.cfg, self.chunk, self.ss, self.rt, jnp.int32(want), k,
                monitor=monitor,
            )
            n = int(n)
            trained += n
            if monitor and n:
                on_chunk(aux)
            if n < want:
                break
        return trained

    def infer(self, xs) -> np.ndarray:
        xs = jnp.asarray(xs, dtype=bool)
        return np.asarray(
            tm_mod.predict_batch(self.cfg, self.ss.tm, self.rt, xs)
        )

    @property
    def buffered(self) -> int:
        return int(self.ss.buf.size)


class LegacyFleet:
    """Pre-redesign OnlineFleet: one device dispatch per offered point."""

    def __init__(self, cfg, state, rt, *, n_replicas, buffer_capacity=64,
                 chunk=16, seed=0):
        if state.ta_state.ndim != 4:
            state = TMState(ta_state=jnp.broadcast_to(
                state.ta_state, (n_replicas,) + state.ta_state.shape
            ))
        self.cfg, self.rt = cfg, rt
        self.n_replicas = n_replicas
        self.chunk = max(1, min(chunk, buffer_capacity))
        if isinstance(seed, (int, np.integer)):
            base = jax.random.PRNGKey(int(seed))
            keys = jax.vmap(lambda r: jax.random.fold_in(base, r))(
                jnp.arange(n_replicas)
            )
        else:
            keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seed])
        self._keys = keys
        K = n_replicas
        buf1 = buf_mod.make(buffer_capacity, cfg.n_features)
        bufs = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (K,) + a.shape), buf1
        )
        self.ss = SessionState(
            tm=state, buf=bufs, step=jnp.zeros((K,), jnp.int32)
        )
        self.dropped = np.zeros(K, dtype=np.int64)

    def offer_rows(self, xs, ys, mask=None) -> np.ndarray:
        K = self.n_replicas
        xs = jnp.broadcast_to(
            jnp.asarray(xs, dtype=bool), (K, self.cfg.n_features)
        )
        ys = jnp.broadcast_to(jnp.asarray(ys, dtype=jnp.int32), (K,))
        mask = (
            jnp.ones((K,), dtype=bool) if mask is None
            else jnp.asarray(mask, dtype=bool)
        )
        self.ss, oks = _legacy_enqueue_rows(self.cfg, self.ss, xs, ys, mask)
        accepted = np.asarray(oks)
        self.dropped += np.asarray(mask) & ~accepted
        return accepted

    def offer(self, r, x, y) -> bool:
        mask = np.zeros(self.n_replicas, dtype=bool)
        mask[r] = True
        return bool(self.offer_rows(x, y, mask)[r])

    def drain(self, max_points, on_chunk=None) -> np.ndarray:
        K = self.n_replicas
        budget = np.broadcast_to(
            np.asarray(max_points, dtype=np.int64), (K,)
        ).copy()
        trained = np.zeros(K, dtype=np.int64)
        active = trained < budget
        monitor = on_chunk is not None
        while active.any():
            want = np.where(
                active, np.minimum(self.chunk, budget - trained), 0
            ).astype(np.int32)
            self._keys, chunk_keys = _legacy_advance_keys(
                self._keys, jnp.asarray(active)
            )
            self.ss, n, aux = online_mod._consume_many_replicated(
                self.cfg, self.chunk, self.ss, self.rt,
                jnp.asarray(want), chunk_keys, monitor=monitor,
            )
            n = np.asarray(n, dtype=np.int64)
            trained += n
            if monitor and n.any():
                on_chunk(aux)
            active &= (n == want) & (trained < budget)
        return trained

    def infer(self, xs) -> np.ndarray:
        xs = jnp.asarray(xs, dtype=bool)
        if xs.ndim == 2:
            xs = xs[None]
        return np.asarray(tm_mod.predict_batch_replicated(
            self.cfg, self.ss.tm, self.rt, xs
        ))

    @property
    def buffered(self) -> np.ndarray:
        return np.asarray(self.ss.buf.size)

    @property
    def steps(self) -> np.ndarray:
        return np.asarray(self.ss.step)


class LegacyManager:
    """Pre-redesign TMOnlineAdaptManager: the scalar Fig-3 FSM."""

    def __init__(self, cfg, state, rt, eval_x, eval_y, oc=None, seed=0):
        self.cfg, self.rt = cfg, rt
        self.oc = oc or TMOnlineAdaptConfig()
        self.eval_x = jnp.asarray(eval_x, dtype=bool)
        self.eval_y = jnp.asarray(eval_y, dtype=jnp.int32)
        self.session = LegacySession(
            cfg, state, rt,
            buffer_capacity=self.oc.buffer_capacity,
            chunk=self.oc.chunk, seed=seed,
        )
        self.history: list = []
        self.rollbacks = 0
        self.lost = 0
        self._since_analysis = 0
        self._best: Optional[float] = None
        self._best_state = self.session.ss.tm

    def serve(self, xs) -> np.ndarray:
        return self.session.infer(xs)

    def analyze(self) -> float:
        acc = float(acc_mod.analyze(
            self.cfg, self.session.ss.tm, self.rt, self.eval_x, self.eval_y
        ))
        self.history.append((int(self.session.ss.step), acc))
        return acc

    def offline_train(self, xs, ys, n_epochs=10, seed=1) -> float:
        st = fb_mod.train_epochs(
            self.cfg, self.session.ss.tm, self.rt,
            jnp.asarray(xs, dtype=bool), jnp.asarray(ys, dtype=jnp.int32),
            jax.random.PRNGKey(seed), n_epochs,
        )
        self.session.ss = self.session.ss._replace(tm=st)
        acc = self.analyze()
        self._best, self._best_state = acc, st
        return acc

    def observe(self, x, y) -> Optional[float]:
        chunk = self.session.chunk
        if not self.session.offer(x, y):
            self._since_analysis += self.session.learn_available(chunk)
            if not self.session.offer(x, y):
                self.lost += 1
        self._since_analysis += self.session.learn_available(chunk)
        if self._since_analysis < self.oc.analyze_every:
            return None
        self._since_analysis = 0
        acc = self.analyze()
        if self._best is not None and acc < self._best - self.oc.rollback_threshold:
            self.session.ss = self.session.ss._replace(tm=self._best_state)
            self.rollbacks += 1
        elif self._best is None or acc > self._best:
            self._best, self._best_state = acc, self.session.ss.tm
        return acc


class LegacyFleetManager:
    """Pre-redesign TMFleetAdaptManager: the duplicated [K] Fig-3 FSM."""

    def __init__(self, cfg, state, rt, eval_x, eval_y, *, n_replicas,
                 oc=None, seed=0):
        self.cfg, self.rt = cfg, rt
        self.oc = oc or TMOnlineAdaptConfig()
        self.eval_x = jnp.asarray(eval_x, dtype=bool)
        self.eval_y = jnp.asarray(eval_y, dtype=jnp.int32)
        self.fleet = LegacyFleet(
            cfg, state, rt, n_replicas=n_replicas,
            buffer_capacity=self.oc.buffer_capacity,
            chunk=self.oc.chunk, seed=seed,
        )
        K = self.fleet.n_replicas
        self.history: list = []
        self.rollbacks = np.zeros(K, dtype=np.int64)
        self.lost = np.zeros(K, dtype=np.int64)
        self._since = np.zeros(K, dtype=np.int64)
        self._best = np.full(K, np.nan)
        self._best_state = self.fleet.ss.tm

    def serve(self, xs) -> np.ndarray:
        return self.fleet.infer(xs)

    def analyze(self) -> np.ndarray:
        acc = np.asarray(acc_mod.analyze_replicated(
            self.cfg, self.fleet.ss.tm, self.rt,
            self.eval_x[None], self.eval_y[None],
        ))
        self.history.append((self.fleet.steps, acc))
        return acc

    def offline_train(self, xs, ys, n_epochs=10, seed=1) -> np.ndarray:
        st = fb_mod.train_epochs_replicated(
            self.cfg, self.fleet.ss.tm, self.rt,
            jnp.asarray(xs, dtype=bool)[None],
            jnp.asarray(ys, dtype=jnp.int32)[None],
            jax.random.PRNGKey(seed)[None], n_epochs,
        )
        self.fleet.ss = self.fleet.ss._replace(tm=st)
        acc = self.analyze()
        self._best = acc.copy()
        self._best_state = st
        return acc

    def _select_rows(self, mask, new, old):
        gate = online_mod.replica_gate(jnp.asarray(mask))
        return jax.tree.map(gate, new, old)

    def observe_rows(self, xs, ys, mask=None) -> Optional[np.ndarray]:
        K = self.fleet.n_replicas
        mask = (
            np.ones(K, dtype=bool) if mask is None
            else np.asarray(mask, dtype=bool)
        )
        chunk = self.fleet.chunk
        accepted = self.fleet.offer_rows(xs, ys, mask)
        retry = mask & ~accepted
        if retry.any():
            self._since += self.fleet.drain(chunk)
            accepted = self.fleet.offer_rows(xs, ys, retry)
            self.lost += retry & ~accepted
        self._since += self.fleet.drain(chunk)

        due = self._since >= self.oc.analyze_every
        if not due.any():
            return None
        self._since[due] = 0
        acc = self.analyze()
        have_best = ~np.isnan(self._best)
        collapse = due & have_best & (
            acc < self._best - self.oc.rollback_threshold
        )
        improve = due & (~have_best | (acc > self._best))
        if collapse.any():
            self.fleet.ss = self.fleet.ss._replace(
                tm=self._select_rows(collapse, self._best_state,
                                     self.fleet.ss.tm)
            )
            self.rollbacks += collapse
        if improve.any():
            self._best = np.where(improve, acc, self._best)
            self._best_state = self._select_rows(
                improve, self.fleet.ss.tm, self._best_state
            )
        return acc

    def observe(self, r, x, y) -> Optional[np.ndarray]:
        mask = np.zeros(self.fleet.n_replicas, dtype=bool)
        mask[r] = True
        return self.observe_rows(x, y, mask)


# ---------------------------------------------------------------------------
# Session shim parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_session_shim_bitwise_matches_legacy(backend):
    """OnlineSession (a K = 1 TMService shim) == the pre-redesign session:
    offers incl. backpressure drops, chunked drains with monitoring aux,
    inference, step/buffered counters — identical trajectories."""
    cfg = _cfg(backend)
    rt = init_runtime(cfg, s=3.0, T=15)
    xs, ys = iris.load()

    legacy = LegacySession(cfg, init_state(cfg), rt, buffer_capacity=16,
                           chunk=8, seed=11)
    shim = OnlineSession(cfg, init_state(cfg), rt, buffer_capacity=16,
                         chunk=8, seed=11)

    l_aux, s_aux = [], []
    for round_ in range(3):
        # overfill: the last 4 offers bounce off the full buffer
        for i in range(20):
            j = (round_ * 20 + i) % 150
            a = legacy.offer(xs[j], int(ys[j]))
            b = shim.offer(xs[j], int(ys[j]))
            assert a == b
        assert legacy.buffered == shim.buffered == 16
        assert legacy.dropped == shim.dropped
        budget = [13, 100, 16][round_]  # partial chunk / drain-to-empty
        nl = legacy.learn_available(budget, on_chunk=l_aux.append)
        ns = shim.learn_available(budget, on_chunk=s_aux.append)
        assert nl == ns
        np.testing.assert_array_equal(
            np.asarray(legacy.ss.tm.ta_state), np.asarray(shim.ss.tm.ta_state)
        )
        np.testing.assert_array_equal(legacy.infer(xs[:10]),
                                      shim.infer(xs[:10]))
    assert int(legacy.ss.step) == int(shim.ss.step)
    assert len(l_aux) == len(s_aux)
    for la, sa in zip(l_aux, s_aux):
        for w, g in zip(jax.tree.leaves(la), jax.tree.leaves(sa)):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


def test_session_shim_state_swap_resyncs():
    """Replacing ``ss`` wholesale (the benchmarks' pre-fill pattern) keeps
    the shim's occupancy accounting exact."""
    cfg = _cfg()
    rt = init_runtime(cfg, s=3.0, T=15)
    xs, ys = iris.load()
    shim = OnlineSession(cfg, init_state(cfg), rt, buffer_capacity=8,
                         chunk=4, seed=0)
    filled = buf_mod.RingBuffer(
        data_x=jnp.asarray(xs[:8], dtype=bool),
        data_y=jnp.asarray(ys[:8], dtype=jnp.int32),
        head=jnp.int32(0), size=jnp.int32(8),
    )
    shim.ss = shim.ss._replace(buf=filled)
    assert shim.buffered == 8
    assert not shim.offer(xs[8], int(ys[8]))   # full: backpressure
    assert shim.learn_available(100) == 8
    assert shim.buffered == 0


# ---------------------------------------------------------------------------
# Fleet shim parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
@pytest.mark.parametrize("K", [1, 3, 8])
def test_fleet_shim_bitwise_matches_legacy(K, backend):
    """OnlineFleet (a TMService shim with router ingress) == the
    pre-redesign fleet that dispatched every offer to the device."""
    cfg = _cfg(backend)
    rt = init_runtime(cfg, s=3.0, T=15)
    seeds = [50 + r for r in range(K)]
    streams = _offer_streams(K, 20)

    legacy = LegacyFleet(cfg, init_state(cfg), rt, n_replicas=K,
                         buffer_capacity=32, chunk=8, seed=seeds)
    shim = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=K,
                       buffer_capacity=32, chunk=8, seed=seeds)
    for i in range(20):
        for r in range(K):
            x, y = streams[r][i]
            assert legacy.offer(r, x, y)
            assert shim.offer(r, x, y)
    np.testing.assert_array_equal(legacy.buffered, shim.buffered)

    l_aux, s_aux = [], []
    nl = legacy.drain(20, on_chunk=l_aux.append)
    ns = shim.drain(20, on_chunk=s_aux.append)
    np.testing.assert_array_equal(nl, ns)
    np.testing.assert_array_equal(
        np.asarray(legacy.ss.tm.ta_state), np.asarray(shim.ss.tm.ta_state)
    )
    np.testing.assert_array_equal(legacy.steps, shim.steps)
    assert len(l_aux) == len(s_aux)
    for la, sa in zip(l_aux, s_aux):
        for w, g in zip(jax.tree.leaves(la), jax.tree.leaves(sa)):
            np.testing.assert_array_equal(np.asarray(w), np.asarray(g))
    xs, _ = iris.load()
    np.testing.assert_array_equal(legacy.infer(xs[:12]), shim.infer(xs[:12]))


def test_fleet_shim_per_replica_hyperparameters_match_legacy():
    """[K]-vector s/T runtime ports through the shim == pre-redesign."""
    cfg = _cfg()
    K = 3
    s_vals, T_vals = [1.375, 3.0, 5.0], [5, 15, 10]
    seeds = [21, 22, 23]
    streams = _offer_streams(K, 16)
    rt = init_runtime(cfg)._replace(
        s=jnp.asarray(s_vals, jnp.float32), T=jnp.asarray(T_vals, jnp.int32)
    )
    legacy = LegacyFleet(cfg, init_state(cfg), rt, n_replicas=K,
                         buffer_capacity=32, chunk=8, seed=seeds)
    shim = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=K,
                       buffer_capacity=32, chunk=8, seed=seeds)
    for i in range(16):
        for r in range(K):
            legacy.offer(r, *streams[r][i])
            shim.offer(r, *streams[r][i])
    np.testing.assert_array_equal(legacy.drain(16), shim.drain(16))
    np.testing.assert_array_equal(
        np.asarray(legacy.ss.tm.ta_state), np.asarray(shim.ss.tm.ta_state)
    )


def test_fleet_shim_backpressure_and_masks_match_legacy():
    """Masked offers, uneven budgets and buffer-full drops through the
    router ingress reproduce the immediate-dispatch fleet exactly."""
    cfg = _cfg()
    rt = init_runtime(cfg, s=3.0, T=15)
    K = 3
    streams = _offer_streams(K, 24)
    legacy = LegacyFleet(cfg, init_state(cfg), rt, n_replicas=K,
                         buffer_capacity=6, chunk=4, seed=[1, 2, 3])
    shim = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=K,
                       buffer_capacity=6, chunk=4, seed=[1, 2, 3])
    rng = np.random.default_rng(0)
    for i in range(24):
        mask = rng.random(K) < 0.7
        x, y = streams[0][i]
        np.testing.assert_array_equal(
            legacy.offer_rows(x, y, mask), shim.offer_rows(x, y, mask)
        )
        if i % 5 == 4:
            budgets = rng.integers(0, 7, K)
            np.testing.assert_array_equal(
                legacy.drain(budgets), shim.drain(budgets)
            )
    np.testing.assert_array_equal(legacy.dropped, shim.dropped)
    np.testing.assert_array_equal(legacy.buffered, shim.buffered)
    legacy.drain(10)
    shim.drain(10)
    np.testing.assert_array_equal(
        np.asarray(legacy.ss.tm.ta_state), np.asarray(shim.ss.tm.ta_state)
    )


# ---------------------------------------------------------------------------
# Manager shim parity (the collapsed FSM == both deleted FSMs)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_single_manager_shim_bitwise_matches_legacy(backend):
    """TMOnlineAdaptManager == the deleted scalar FSM across offline
    training, clean + poisoned traffic, backpressure (tiny buffer) and
    §5.3.2 rollbacks — identical histories, counters and TA banks."""
    cfg = _cfg(backend)
    rt = init_runtime(cfg, s=3.0, T=15)
    xs, ys = iris.load()
    oc = TMOnlineAdaptConfig(analyze_every=8, rollback_threshold=0.05,
                             buffer_capacity=8, chunk=8)
    legacy = LegacyManager(cfg, init_state(cfg), rt, xs[100:], ys[100:],
                           oc=oc, seed=5)
    shim = TMOnlineAdaptManager(cfg, init_state(cfg), rt, xs[100:], ys[100:],
                                oc=oc, seed=5)
    bl = legacy.offline_train(xs[:80], ys[:80], n_epochs=6)
    bs = shim.offline_train(xs[:80], ys[:80], n_epochs=6)
    assert bl == bs

    rng = np.random.default_rng(3)
    for i in range(60):
        j = i % 100
        y = int(ys[j]) if i % 3 else int(rng.integers(0, 3))  # drifted labels
        al = legacy.observe(xs[j], y)
        ash = shim.observe(xs[j], y)
        assert (al is None) == (ash is None)
        if al is not None:
            assert al == ash
    assert legacy.rollbacks == shim.rollbacks
    assert legacy.lost == shim.lost
    assert legacy.history == shim.history
    np.testing.assert_array_equal(
        np.asarray(legacy.session.ss.tm.ta_state),
        np.asarray(shim.session.ss.tm.ta_state),
    )
    np.testing.assert_array_equal(legacy.serve(xs[:10]), shim.serve(xs[:10]))


@pytest.mark.parametrize("K", [1, 3, 8])
def test_fleet_manager_shim_bitwise_matches_legacy(K):
    """TMFleetAdaptManager == the deleted [K] FSM: masked traffic,
    per-replica [K] s/T ports, per-replica cadence/rollback/snapshot."""
    cfg = _cfg()
    xs, ys = iris.load()
    rt = init_runtime(cfg)._replace(
        s=jnp.asarray(np.linspace(1.375, 5.0, K), jnp.float32),
        T=jnp.asarray(np.linspace(5, 15, K).astype(int), jnp.int32),
    )
    oc = TMOnlineAdaptConfig(analyze_every=6, rollback_threshold=0.05,
                             buffer_capacity=8, chunk=4)
    seeds = [30 + r for r in range(K)]
    legacy = LegacyFleetManager(cfg, init_state(cfg), rt, xs[100:], ys[100:],
                                n_replicas=K, oc=oc, seed=seeds)
    shim = TMFleetAdaptManager(cfg, init_state(cfg), rt, xs[100:], ys[100:],
                               n_replicas=K, oc=oc, seed=seeds)
    np.testing.assert_array_equal(
        legacy.offline_train(xs[:60], ys[:60], n_epochs=5),
        shim.offline_train(xs[:60], ys[:60], n_epochs=5),
    )

    rng = np.random.default_rng(7)
    for i in range(40):
        j = i % 100
        mask = rng.random(K) < 0.8
        y = int(ys[j]) if i % 3 else int(rng.integers(0, 3))  # drifted labels
        al = legacy.observe_rows(xs[j], y, mask)
        ash = shim.observe_rows(xs[j], y, mask)
        assert (al is None) == (ash is None)
        if al is not None:
            np.testing.assert_array_equal(al, ash)
    np.testing.assert_array_equal(legacy.rollbacks, shim.rollbacks)
    np.testing.assert_array_equal(legacy.lost, shim.lost)
    np.testing.assert_array_equal(legacy._since, shim._since)
    assert len(legacy.history) == len(shim.history)
    for (ls, la), (ss_, sa) in zip(legacy.history, shim.history):
        np.testing.assert_array_equal(ls, ss_)
        np.testing.assert_array_equal(la, sa)
    np.testing.assert_array_equal(
        np.asarray(legacy.fleet.ss.tm.ta_state),
        np.asarray(shim.fleet.ss.tm.ta_state),
    )
    np.testing.assert_array_equal(legacy.serve(xs[:10]), shim.serve(xs[:10]))


def test_manager_shim_without_offline_train_matches_legacy():
    """Cold-start managers (no offline_train): the first due analysis
    snapshots a best from the initial banks instead of crashing — same
    trajectory as the legacy FSM, which seeded _best_state in __init__."""
    cfg = _cfg()
    rt = init_runtime(cfg, s=3.0, T=15)
    xs, ys = iris.load()
    oc = TMOnlineAdaptConfig(analyze_every=4, rollback_threshold=0.1,
                             buffer_capacity=16, chunk=4)
    legacy = LegacyManager(cfg, init_state(cfg), rt, xs[100:], ys[100:],
                           oc=oc, seed=2)
    shim = TMOnlineAdaptManager(cfg, init_state(cfg), rt, xs[100:], ys[100:],
                                oc=oc, seed=2)
    for i in range(12):
        al = legacy.observe(xs[i], int(ys[i]))
        ash = shim.observe(xs[i], int(ys[i]))
        assert (al is None) == (ash is None)
        if al is not None:
            assert al == ash
    assert legacy.history == shim.history
    assert legacy.rollbacks == shim.rollbacks
    np.testing.assert_array_equal(
        np.asarray(legacy.session.ss.tm.ta_state),
        np.asarray(shim.session.ss.tm.ta_state),
    )


# ---------------------------------------------------------------------------
# The native surface: tick() and ingress-specific behavior
# ---------------------------------------------------------------------------


def test_service_tick_drives_cadence_and_rollback():
    """The native submit/tick loop runs the same §5.3.2 policy: a poisoned
    member rolls back to its known-good bank on its next due analysis."""
    from repro.serve import AdaptPolicy, ServiceConfig, TMService

    cfg = _cfg()
    xs, ys = iris.load()
    K = 3
    svc = TMService(
        cfg, init_state(cfg),
        ServiceConfig(replicas=K, buffer_capacity=16, chunk=4,
                      s=3.0, T=15, seed=[5, 6, 7],
                      policy=AdaptPolicy(analyze_every=4,
                                         rollback_threshold=0.1)),
        eval_x=xs[100:], eval_y=ys[100:],
    )
    base = svc.offline_train(xs[:80], ys[:80], n_epochs=10)
    assert base.shape == (K,)

    poisoned = np.asarray(svc.ss.tm.ta_state).copy()
    poisoned[0] = np.asarray(init_state(cfg).ta_state)
    svc.ss = svc.ss._replace(tm=TMState(ta_state=jnp.asarray(poisoned)))

    reports = []
    for i in range(4):
        svc.submit_rows(np.asarray(xs[80 + i]), int(ys[80 + i]))
        reports.append(svc.tick())
    assert all(r.trained.shape == (K,) for r in reports)
    fired = [r for r in reports if r.accuracy is not None]
    assert fired and fired[-1].rolled_back.tolist() == [True, False, False]
    np.testing.assert_array_equal(svc.rollbacks, [1, 0, 0])
    assert float(svc.analyze()[0]) >= float(base[0]) - 0.1


def test_service_ingress_is_batched_not_per_point():
    """The routed ingress path: N offers per replica cost O(N / B_ingress)
    device dispatches, not N — and the buffers still receive every row in
    order (the drained TA banks prove it: bitwise equal to the per-point
    legacy fleet)."""
    cfg = _cfg()
    rt = init_runtime(cfg, s=3.0, T=15)
    K = 4
    streams = _offer_streams(K, 24)
    legacy = LegacyFleet(cfg, init_state(cfg), rt, n_replicas=K,
                         buffer_capacity=32, chunk=8, seed=list(range(K)))
    shim = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=K,
                       buffer_capacity=32, chunk=8, seed=list(range(K)))
    for i in range(24):
        for r in range(K):
            legacy.offer(r, *streams[r][i])
            shim.offer(r, *streams[r][i])
    # 96 offers; ingress_block=32 per replica -> exactly 1 auto-flush so far
    assert shim.service.router.flushes <= 2
    np.testing.assert_array_equal(legacy.drain(24), shim.drain(24))
    np.testing.assert_array_equal(
        np.asarray(legacy.ss.tm.ta_state), np.asarray(shim.ss.tm.ta_state)
    )
