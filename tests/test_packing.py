"""Bit-packed datapath suite (DESIGN.md §13).

Pins the packed-representation contract end to end:

* pack/unpack round-trip at arbitrary widths (hypothesis: any n, including
  non-multiples of 32) with the tail-word bits provably zero,
* the jax and numpy packers produce the SAME words (the router packs
  host-side, the kernels consume device-side — one layout),
* packed clause eval is bitwise identical to the unpacked oracle on BOTH
  backends, batch + replicated, across word-boundary-crossing widths,
* the fault controller commutes with packing (stuck-at applied pre-pack ==
  applied in the packed domain).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import TMConfig, faults, init_runtime, init_state
from repro.core import tm as tm_mod
from repro.kernels import ops, packing, ref

# f values straddling word boundaries: sub-word, word-1, word, word+1,
# multi-word with tail, and the benchmark widths.
WIDTHS = [5, 16, 31, 32, 33, 49, 196, 513, 784]


# ---------------------------------------------------------------------------
# layout: round-trip, tail-bit contract, jax/numpy agreement
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", WIDTHS)
def test_pack_unpack_round_trip(n):
    rng = np.random.default_rng(n)
    bits = rng.random((7, n)) < 0.5
    words = packing.pack_bits(jnp.asarray(bits))
    assert words.shape == (7, packing.n_words(n))
    assert words.dtype == jnp.uint32
    np.testing.assert_array_equal(
        np.asarray(packing.unpack_bits(words, n)), bits
    )


def _roundtrip_and_tail_property(n, seed):
    """The §13 layout property at one (width, seed): round-trip exact,
    tail bits provably zero, numpy/jax packers agree word for word."""
    rng = np.random.default_rng(seed)
    bits = rng.random((3, n)) < 0.5
    words = np.asarray(packing.pack_bits(jnp.asarray(bits)))
    np.testing.assert_array_equal(packing.pack_bits_np(bits), words)
    np.testing.assert_array_equal(
        np.asarray(packing.unpack_bits(jnp.asarray(words), n)), bits
    )
    np.testing.assert_array_equal(packing.unpack_bits_np(words, n), bits)
    # The tail contract the kernels rely on: no bit above position n-1.
    tail = words[..., -1]
    assert (tail & ~np.uint32(packing.tail_mask(n))).max(initial=0) == 0
    # LSB-first word-major: bit i of word w is element 32w + i.
    w, i = (n - 1) // 32, (n - 1) % 32
    np.testing.assert_array_equal(
        (words[:, w] >> np.uint32(i)) & 1, bits[:, n - 1].astype(np.uint32)
    )


@pytest.mark.parametrize("n", WIDTHS + [1, 63, 64, 65])
def test_pack_round_trip_and_tail_zero_sweep(n):
    """Deterministic width sweep of the layout property (always runs)."""
    _roundtrip_and_tail_property(n, seed=n * 7919)


def test_pack_property_arbitrary_widths():
    """Hypothesis form: ANY width in [1, 300], incl. non-multiples of 32."""
    pytest.importorskip(
        "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
    )
    from hypothesis import given, settings, strategies as st

    @given(n=st.integers(min_value=1, max_value=300),
           seed=st.integers(min_value=0, max_value=2**31 - 1))
    @settings(max_examples=60, deadline=None)
    def prop(n, seed):
        _roundtrip_and_tail_property(n, seed)

    prop()


@pytest.mark.parametrize("f", [5, 31, 33, 49])
def test_literal_layout_two_halves(f):
    """pack_literals == [pack(x), pack(~x)], and literals_from_packed
    derives the same words from packed features by pure word ops."""
    rng = np.random.default_rng(f)
    x = jnp.asarray(rng.random((4, f)) < 0.5)
    lit = packing.pack_literals(x)
    assert lit.shape == (4, packing.lit_words(f))
    np.testing.assert_array_equal(
        np.asarray(lit),
        np.concatenate(
            [packing.pack_bits_np(np.asarray(x)),
             packing.pack_bits_np(~np.asarray(x))], axis=-1,
        ),
    )
    np.testing.assert_array_equal(
        np.asarray(packing.literals_from_packed(packing.pack_bits(x), f)),
        np.asarray(lit),
    )


@pytest.mark.parametrize("f", [5, 31, 33, 49])
def test_pack_include_matches_literal_positions(f):
    """pack_include's split puts include bit l at the same (word, bit) as
    literal l in pack_literals — checked via unpack round-trip per half."""
    rng = np.random.default_rng(100 + f)
    inc = rng.random((3, 2, 2 * f)) < 0.3
    words = np.asarray(packing.pack_include(jnp.asarray(inc), f))
    Wf = packing.n_words(f)
    np.testing.assert_array_equal(
        packing.unpack_bits_np(words[..., :Wf], f), inc[..., :f]
    )
    np.testing.assert_array_equal(
        packing.unpack_bits_np(words[..., Wf:], f), inc[..., f:]
    )


# ---------------------------------------------------------------------------
# packed vs unpacked clause eval: bitwise parity on both backends
# ---------------------------------------------------------------------------


def _case(f, seed, C=3, J=6, B=17):
    rng = np.random.default_rng(seed)
    include = jnp.asarray(rng.random((C, J, 2 * f)) < 0.3)
    x = jnp.asarray(rng.random((B, f)) < 0.5)
    lits = jnp.concatenate([x, ~x], axis=-1)
    return include, lits, packing.pack_include(include, f), \
        packing.pack_literals(x)


@pytest.mark.parametrize("f", WIDTHS)
@pytest.mark.parametrize("mod", [ref, ops], ids=["ref", "pallas"])
def test_clause_eval_batch_packed_matches_unpacked(f, mod):
    include, lits, inc_p, lit_p = _case(f, seed=f)
    for training in (True, False):
        want = ref.clause_eval_batch(include, lits, training=training)
        got = mod.clause_eval_batch_packed(inc_p, lit_p, training=training)
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("mod", [ref, ops], ids=["ref", "pallas"])
def test_clause_eval_batch_packed_empty_and_all_include(mod):
    """Edge banks: all-excluded (empty convention) and all-included."""
    f = 33
    inc_empty = jnp.zeros((2, 4, 2 * f), dtype=bool)
    inc_full = jnp.ones((2, 4, 2 * f), dtype=bool)
    x = jnp.asarray(np.random.default_rng(0).random((5, f)) < 0.5)
    lit_p = packing.pack_literals(x)
    for inc in (inc_empty, inc_full):
        inc_p = packing.pack_include(inc, f)
        for training in (True, False):
            want = ref.clause_eval_batch(
                inc, jnp.concatenate([x, ~x], axis=-1), training=training
            )
            got = mod.clause_eval_batch_packed(
                inc_p, lit_p, training=training
            )
            np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@pytest.mark.parametrize("f", [16, 31, 49, 196])
@pytest.mark.parametrize("RD", [(1, 1), (4, 2), (3, 3)])
@pytest.mark.parametrize("mod", [ref, ops], ids=["ref", "pallas"])
def test_clause_eval_batch_replicated_packed_matches_unpacked(f, RD, mod):
    R, D = RD
    rng = np.random.default_rng(hash((f, R, D)) % 2**31)
    include = jnp.asarray(rng.random((R, 3, 6, 2 * f)) < 0.3)
    x = jnp.asarray(rng.random((D, 9, f)) < 0.5)
    lits = jnp.concatenate([x, ~x], axis=-1)
    inc_p = packing.pack_include(include, f)
    lit_p = packing.pack_literals(x)
    for training in (True, False):
        want = ref.clause_eval_batch_replicated(
            include, lits, training=training
        )
        got = mod.clause_eval_batch_replicated_packed(
            inc_p, lit_p, training=training
        )
        np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


def test_packed_replicated_rejects_bad_data_axis():
    f = 16
    inc_p = packing.pack_include(jnp.zeros((4, 1, 2, 2 * f), bool), f)
    lit_p = packing.pack_literals(jnp.zeros((3, 5, f), bool))
    with pytest.raises(ValueError, match="must divide"):
        ref.clause_eval_batch_replicated_packed(inc_p, lit_p, training=False)


@pytest.mark.parametrize("f", [16, 49])
@pytest.mark.parametrize("backend", ["ref", "pallas"])
def test_forward_batch_routes_packed_by_dtype(f, backend):
    """forward_batch/predict on packed uint32 rows == on bool rows."""
    cfg = TMConfig(n_features=f, max_classes=3, max_clauses=8, n_states=50,
                   backend=backend)
    st = init_state(cfg)
    rt = init_runtime(cfg)
    rng = np.random.default_rng(f)
    xs = jnp.asarray(rng.random((11, f)) < 0.5)
    xp = packing.pack_bits(xs)
    for training in (True, False):
        cl_a, v_a = tm_mod.forward_batch(cfg, st, rt, xs, training=training)
        cl_b, v_b = tm_mod.forward_batch(cfg, st, rt, xp, training=training)
        np.testing.assert_array_equal(np.asarray(cl_a), np.asarray(cl_b))
        np.testing.assert_array_equal(np.asarray(v_a), np.asarray(v_b))


# ---------------------------------------------------------------------------
# fault controller commutes with packing (§3.1.2 in the packed domain)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("f", [16, 49])
@pytest.mark.parametrize("stuck_value", [0, 1])
def test_stuck_at_faults_commute_with_packing(f, stuck_value):
    """Fault applied pre-pack == fault applied on packed include words."""
    cfg = TMConfig(n_features=f, max_classes=3, max_clauses=8, n_states=50)
    st = init_state(cfg, key=None)
    rng = np.random.default_rng(f + stuck_value)
    st = st._replace(ta_state=jnp.asarray(
        rng.integers(1, 2 * cfg.n_states + 1,
                     st.ta_state.shape).astype(np.int8)
    ))
    a, o = faults.random_stuck_at(cfg, 0.1, stuck_value, seed=7)
    rt = faults.inject(init_runtime(cfg), a, o)

    # pre-pack: the faulted include plane, then packed (what the packed
    # datapath actually runs via ta_actions_packed)
    pre = tm_mod.ta_actions_packed(cfg, st, rt)

    # packed domain: pack the clean include plane and the fault mappings,
    # then run the AND/OR circuit on words
    clean = tm_mod.ta_actions(cfg, st, faults.clear(cfg, init_runtime(cfg)))
    a_p, o_p = faults.packed_masks(cfg, rt)
    post = faults.apply_packed(packing.pack_include(clean, f), a_p, o_p)

    np.testing.assert_array_equal(np.asarray(pre), np.asarray(post))
    # and both keep the tail-bit contract
    Wf = packing.n_words(f)
    tail = np.uint32(packing.tail_mask(f))
    for words in (np.asarray(pre), np.asarray(post)):
        assert (words[..., Wf - 1] & ~tail).max(initial=0) == 0
        assert (words[..., -1] & ~tail).max(initial=0) == 0


def test_faulted_packed_eval_matches_unpacked(backend="pallas"):
    """Stuck-at faults flow through the packed clause kernels bitwise."""
    f = 49
    cfg = TMConfig(n_features=f, max_classes=2, max_clauses=6, n_states=50,
                   backend=backend)
    rng = np.random.default_rng(3)
    st = init_state(cfg)._replace(ta_state=jnp.asarray(
        rng.integers(1, 2 * cfg.n_states + 1,
                     (2, 6, 2 * f)).astype(np.int8)
    ))
    a, o = faults.even_spread_stuck_at(cfg, 0.2, 1)
    rt = faults.inject(init_runtime(cfg), a, o)
    xs = jnp.asarray(rng.random((13, f)) < 0.5)
    cl_a, v_a = tm_mod.forward_batch(cfg, st, rt, xs)
    cl_b, v_b = tm_mod.forward_batch(cfg, st, rt, packing.pack_bits(xs))
    np.testing.assert_array_equal(np.asarray(cl_a), np.asarray(cl_b))
    np.testing.assert_array_equal(np.asarray(v_a), np.asarray(v_b))
