"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
)
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import TMConfig, init_runtime, init_state, train_step
from repro.core import tm as tm_mod
from repro.kernels import ops, ref

_shapes = st.tuples(
    st.integers(1, 4),    # classes
    st.integers(1, 10).map(lambda j: 2 * j),  # clauses (even)
    st.integers(1, 40),   # literals
)


@settings(max_examples=25, deadline=None)
@given(shape=_shapes, seed=st.integers(0, 2**31 - 1), training=st.booleans())
def test_kernel_clause_eval_equals_oracle(shape, seed, training):
    C, J, L = shape
    rng = np.random.default_rng(seed)
    include = jnp.asarray(rng.random((C, J, L)) < rng.random())
    lits = jnp.asarray(rng.random((L,)) < 0.5)
    want = ref.clause_eval(include, lits, training=training)
    got = ops.clause_eval(include, lits, training=training)
    np.testing.assert_array_equal(np.asarray(want), np.asarray(got))


@settings(max_examples=25, deadline=None)
@given(
    shape=_shapes,
    seed=st.integers(0, 2**31 - 1),
    s=st.floats(1.0, 10.0),
    policy=st.sampled_from(["standard", "hardware"]),
)
def test_kernel_feedback_equals_oracle_and_bounds(shape, seed, s, policy):
    C, J, L = shape
    n = 50
    rng = np.random.default_rng(seed)
    ta = jnp.asarray(rng.integers(1, 2 * n + 1, (C, J, L)), dtype=jnp.int8)
    lits = jnp.asarray(rng.random((L,)) < 0.5)
    c_out = jnp.asarray(rng.random((C, J)) < 0.5)
    t1 = jnp.asarray(rng.random((C, J)) < 0.5)
    t2 = jnp.asarray(rng.random((C, J)) < 0.5) & ~t1
    u = jnp.asarray(rng.random((C, J, L)), dtype=jnp.float32)
    kw = dict(s=jnp.float32(s), n_states=n, s_policy=policy,
              boost_true_positive=bool(seed % 2))
    want = np.asarray(ref.feedback_step(ta, lits, c_out, t1, t2, u, **kw))
    got = np.asarray(ops.feedback_step(ta, lits, c_out, t1, t2, u, **kw))
    np.testing.assert_array_equal(want, got)
    # Invariants: states in [1, 2N]; |delta| <= 1 per TA per step.
    assert want.min() >= 1 and want.max() <= 2 * n
    assert np.abs(want.astype(int) - np.asarray(ta, dtype=int)).max() <= 1


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_train_step_invariants(seed):
    """After any train step: state bounds hold; votes bounded by clause count."""
    cfg = TMConfig(n_features=8, max_classes=3, max_clauses=8, n_states=20)
    rng = np.random.default_rng(seed)
    st0 = init_state(cfg, jax.random.PRNGKey(seed % 997))
    rt = init_runtime(cfg, s=1.0 + 5 * rng.random(), T=int(rng.integers(1, 20)))
    x = jnp.asarray(rng.random(8) < 0.5)
    y = jnp.int32(rng.integers(0, 3))
    st1, aux = train_step(cfg, st0, rt, x, y, jax.random.PRNGKey(seed % 991))
    v = np.asarray(st1.ta_state)
    assert v.min() >= 1 and v.max() <= 2 * cfg.n_states
    assert np.abs(np.asarray(aux.votes)).max() <= cfg.max_clauses // 2
    assert 0.0 <= float(aux.activity) <= 1.0


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), frac=st.floats(0.05, 0.5))
def test_fault_masks_force_clause_eval(seed, frac):
    """Stuck-at-0 on ALL TAs of a clause makes it empty regardless of state."""
    from repro.core import faults as faults_mod

    cfg = TMConfig(n_features=8, max_classes=2, max_clauses=4, n_states=20)
    st0 = init_state(cfg, jax.random.PRNGKey(seed % 1013))
    rt = init_runtime(cfg)
    and_m = np.ones((2, 4, 16), dtype=bool)
    and_m[0, 0, :] = False  # kill every TA of clause (0, 0)
    rt = faults_mod.inject(rt, and_m, np.zeros_like(and_m))
    acts = tm_mod.ta_actions(cfg, st0, rt)
    assert not bool(jnp.any(acts[0, 0]))
    x = jnp.asarray(np.random.default_rng(seed).random(8) < 0.5)
    cl = tm_mod.eval_clauses(cfg, acts, tm_mod.make_literals(x), rt, training=False)
    assert not bool(cl[0, 0])  # empty clause at inference votes 0


@settings(max_examples=10, deadline=None)
@given(
    cap=st.integers(1, 8),
    ops_seq=st.lists(st.tuples(st.booleans(), st.integers(0, 99)), max_size=30),
)
def test_ring_buffer_model(cap, ops_seq):
    """Ring buffer behaves exactly like a bounded FIFO (model-based test)."""
    from collections import deque

    from repro.data import buffer

    buf = buffer.make(cap, 2)
    model: deque = deque()
    for is_push, val in ops_seq:
        if is_push:
            buf, ok = buffer.push(
                buf, jnp.asarray([val % 2, 1], dtype=bool), jnp.int32(val)
            )
            assert bool(ok) == (len(model) < cap)
            if len(model) < cap:
                model.append(val)
        else:
            buf, x, y, valid = buffer.pop(buf)
            assert bool(valid) == (len(model) > 0)
            if model:
                assert int(y) == model.popleft()
        assert int(buf.size) == len(model)


@settings(max_examples=20, deadline=None)
@given(
    cap=st.integers(1, 10),
    vals=st.lists(st.integers(0, 999), min_size=0, max_size=40),
    extra_pops=st.integers(0, 5),
)
def test_ring_buffer_fifo_capacity_and_empty_pop(cap, vals, extra_pops):
    """RingBuffer invariants: FIFO order preserved, size never exceeds
    capacity, pop-on-empty is a no-op flagged by nonempty=False."""
    from repro.data import buffer

    buf = buffer.make(cap, 3)
    accepted = []
    for v in vals:
        buf, ok = buffer.push(
            buf, jnp.asarray([v % 2, (v >> 1) % 2, 1], dtype=bool), jnp.int32(v)
        )
        if bool(ok):
            accepted.append(v)
        assert 0 <= int(buf.size) <= cap  # size never exceeds capacity

    popped = []
    for _ in range(len(accepted) + extra_pops):
        before = jax.tree.map(np.asarray, buf)
        buf, x, y, nonempty = buffer.pop(buf)
        if bool(nonempty):
            popped.append(int(y))
        else:
            # pop-on-empty: flagged, and the buffer state is untouched
            after = jax.tree.map(np.asarray, buf)
            for a, b in zip(jax.tree.leaves(before), jax.tree.leaves(after)):
                np.testing.assert_array_equal(a, b)
    assert popped == accepted  # FIFO order, accepted rows only
    assert int(buf.size) == 0


@settings(max_examples=15, deadline=None)
@given(
    block_len=st.integers(1, 8),
    blocks_split=st.tuples(
        st.integers(1, 3), st.integers(1, 3), st.integers(1, 3)
    ),
    n_orderings=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_orderings_partition_dataset(
    block_len, blocks_split, n_orderings, seed
):
    """Every ordering partitions the dataset exactly; set sizes match
    BlockSpec.sizes()."""
    from repro.data import blocks

    a, b, c = blocks_split
    spec = blocks.BlockSpec(
        block_len=block_len, offline_blocks=a,
        validation_blocks=b, online_blocks=c,
    )
    n = spec.n_blocks * block_len
    rng = np.random.default_rng(seed)
    xs = rng.random((n, 4)) < 0.5
    ys = np.arange(n, dtype=np.int32)  # unique labels -> exact partition check

    orderings = blocks.select_orderings(spec.n_blocks, n_orderings, seed=seed)
    sets = blocks.make_sets(xs, ys, spec, orderings)

    assert sets.offline_y.shape[1:] == (spec.sizes()[0],)
    assert sets.validation_y.shape[1:] == (spec.sizes()[1],)
    assert sets.online_y.shape[1:] == (spec.sizes()[2],)
    for o in range(len(orderings)):
        labels = np.concatenate(
            [sets.offline_y[o], sets.validation_y[o], sets.online_y[o]]
        )
        # exactly the original rows, each exactly once
        np.testing.assert_array_equal(np.sort(labels), ys)
        # and x rows ride along with their labels
        rows = np.concatenate(
            [sets.offline_x[o], sets.validation_x[o], sets.online_x[o]]
        )
        np.testing.assert_array_equal(rows[np.argsort(labels)], xs)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    shape=st.tuples(
        st.integers(1, 3),                        # H (grid cells per stream)
        st.integers(1, 3),                        # D (data streams)
        st.integers(1, 3),                        # classes
        st.integers(1, 6).map(lambda j: 2 * j),   # clauses (even)
        st.integers(1, 40),                       # literals
    ),
    policy=st.sampled_from(["standard", "hardware"]),
)
def test_kernel_feedback_replicated_equals_stacked_oracle(seed, shape, policy):
    """Property form of the replica parity contract: for any R = H*D layout,
    feedback_step_replicated == stacked per-replica feedback_step, bitwise,
    on both backends."""
    H, D, C, J, L = shape
    R = H * D
    n = 50
    rng = np.random.default_rng(seed)
    ta = jnp.asarray(rng.integers(1, 2 * n + 1, (R, C, J, L)), dtype=jnp.int8)
    lits = jnp.asarray(rng.random((D, L)) < 0.5)
    c_out = jnp.asarray(rng.random((R, C, J)) < 0.5)
    t1 = jnp.asarray(rng.random((R, C, J)) < 0.5)
    t2 = jnp.asarray(rng.random((R, C, J)) < 0.5) & ~t1
    u = jnp.asarray(rng.random((D, C, J, L)), dtype=jnp.float32)
    s = jnp.asarray(1.0 + 5.0 * rng.random(R), dtype=jnp.float32)
    kw = dict(n_states=n, s_policy=policy, boost_true_positive=bool(seed % 2))
    want = np.stack([
        np.asarray(ref.feedback_step(
            ta[r], lits[r % D], c_out[r], t1[r], t2[r], u[r % D], s=s[r], **kw
        ))
        for r in range(R)
    ])
    for mod in (ref, ops):
        got = np.asarray(mod.feedback_step_replicated(
            ta, lits, c_out, t1, t2, u, s=s, **kw
        ))
        np.testing.assert_array_equal(want, got)
    # Invariants survive replication: states in [1, 2N], |delta| <= 1 per TA.
    assert want.min() >= 1 and want.max() <= 2 * n
    assert np.abs(want.astype(int) - np.asarray(ta, dtype=int)).max() <= 1
