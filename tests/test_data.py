"""Data subsystems: iris booleanization, block CV, filter, ring buffer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import blocks, buffer, filter as filt, iris, memory


def test_iris_shape_and_balance():
    xs, ys = iris.load()
    assert xs.shape == (150, 16) and xs.dtype == bool
    assert list(np.bincount(ys)) == [50, 50, 50]


def test_thermometer_monotone():
    """Thermometer code: higher bit set => all lower bits set."""
    xs, _ = iris.load()
    b = xs.reshape(150, 4, 4)
    for k in range(3):
        assert np.all(b[:, :, k] >= b[:, :, k + 1])


def test_orderings_are_permutations():
    o = blocks.all_orderings(5)
    assert o.shape == (120, 5)
    assert np.all(np.sort(o, axis=1) == np.arange(5))
    sub = blocks.select_orderings(5, 10, seed=1)
    assert sub.shape == (10, 5)
    assert len({tuple(r) for r in sub}) == 10


def test_sets_partition_dataset():
    """Every ordering's 3 sets must partition the 150 rows exactly."""
    sets, spec = blocks.iris_paper_sets(n_orderings=6)
    xs, ys = iris.load()
    assert spec.sizes() == (30, 60, 60)
    for o in range(6):
        rows = np.concatenate(
            [sets.offline_x[o], sets.validation_x[o], sets.online_x[o]]
        )
        # sort rows of both and compare as multisets
        a = np.sort(rows.view(np.uint8).reshape(150, -1), axis=0)
        b = np.sort(xs.view(np.uint8).reshape(150, -1), axis=0)
        np.testing.assert_array_equal(a, b)


def test_class_filter_mask():
    ys = jnp.asarray([0, 1, 2, 1, 0])
    m = filt.class_filter_mask(ys, jnp.int32(1), jnp.bool_(True))
    np.testing.assert_array_equal(np.asarray(m), [True, False, True, False, True])
    m_off = filt.class_filter_mask(ys, jnp.int32(1), jnp.bool_(False))
    assert bool(jnp.all(m_off))


def test_limit_mask():
    m = filt.limit_mask(30, jnp.int32(20))
    assert int(jnp.sum(m)) == 20 and bool(m[19]) and not bool(m[20])


def test_ring_buffer_fifo():
    buf = buffer.make(4, 3)
    xs = [jnp.asarray([i % 2, 1, 0], dtype=bool) for i in range(5)]
    for i in range(4):
        buf, ok = buffer.push(buf, xs[i], jnp.int32(i))
        assert bool(ok)
    buf, ok = buffer.push(buf, xs[4], jnp.int32(4))
    assert not bool(ok)  # full -> reject (backpressure)
    got = []
    for _ in range(5):
        buf, x, y, valid = buffer.pop(buf)
        if bool(valid):
            got.append(int(y))
    assert got == [0, 1, 2, 3]  # FIFO order, nothing dropped silently


def test_ring_buffer_wraparound():
    buf = buffer.make(2, 1)
    on = jnp.asarray([1], dtype=bool)
    for round_ in range(3):
        buf, ok = buffer.push(buf, on, jnp.int32(10 + round_))
        assert bool(ok)
        buf, x, y, valid = buffer.pop(buf)
        assert bool(valid) and int(y) == 10 + round_
    assert int(buf.size) == 0


def test_rom_source_cycles():
    xs = np.eye(3, dtype=bool)
    ys = np.arange(3, dtype=np.int32)
    src = memory.ROMSource(xs, ys)
    seen = [src.next_row()[1] for _ in range(7)]
    assert seen == [0, 1, 2, 0, 1, 2, 0]
