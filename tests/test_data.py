"""Data subsystems: iris booleanization, block CV, filter, ring buffer,
and the MNIST-scale procedural digit generator."""
import hashlib
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import blocks, buffer, filter as filt, iris, memory, mnist


def test_iris_shape_and_balance():
    xs, ys = iris.load()
    assert xs.shape == (150, 16) and xs.dtype == bool
    assert list(np.bincount(ys)) == [50, 50, 50]


def test_thermometer_monotone():
    """Thermometer code: higher bit set => all lower bits set."""
    xs, _ = iris.load()
    b = xs.reshape(150, 4, 4)
    for k in range(3):
        assert np.all(b[:, :, k] >= b[:, :, k + 1])


def test_orderings_are_permutations():
    o = blocks.all_orderings(5)
    assert o.shape == (120, 5)
    assert np.all(np.sort(o, axis=1) == np.arange(5))
    sub = blocks.select_orderings(5, 10, seed=1)
    assert sub.shape == (10, 5)
    assert len({tuple(r) for r in sub}) == 10


def test_sets_partition_dataset():
    """Every ordering's 3 sets must partition the 150 rows exactly."""
    sets, spec = blocks.iris_paper_sets(n_orderings=6)
    xs, ys = iris.load()
    assert spec.sizes() == (30, 60, 60)
    for o in range(6):
        rows = np.concatenate(
            [sets.offline_x[o], sets.validation_x[o], sets.online_x[o]]
        )
        # sort rows of both and compare as multisets
        a = np.sort(rows.view(np.uint8).reshape(150, -1), axis=0)
        b = np.sort(xs.view(np.uint8).reshape(150, -1), axis=0)
        np.testing.assert_array_equal(a, b)


def test_class_filter_mask():
    ys = jnp.asarray([0, 1, 2, 1, 0])
    m = filt.class_filter_mask(ys, jnp.int32(1), jnp.bool_(True))
    np.testing.assert_array_equal(np.asarray(m), [True, False, True, False, True])
    m_off = filt.class_filter_mask(ys, jnp.int32(1), jnp.bool_(False))
    assert bool(jnp.all(m_off))


def test_limit_mask():
    m = filt.limit_mask(30, jnp.int32(20))
    assert int(jnp.sum(m)) == 20 and bool(m[19]) and not bool(m[20])


def test_ring_buffer_fifo():
    buf = buffer.make(4, 3)
    xs = [jnp.asarray([i % 2, 1, 0], dtype=bool) for i in range(5)]
    for i in range(4):
        buf, ok = buffer.push(buf, xs[i], jnp.int32(i))
        assert bool(ok)
    buf, ok = buffer.push(buf, xs[4], jnp.int32(4))
    assert not bool(ok)  # full -> reject (backpressure)
    got = []
    for _ in range(5):
        buf, x, y, valid = buffer.pop(buf)
        if bool(valid):
            got.append(int(y))
    assert got == [0, 1, 2, 3]  # FIFO order, nothing dropped silently


def test_ring_buffer_wraparound():
    buf = buffer.make(2, 1)
    on = jnp.asarray([1], dtype=bool)
    for round_ in range(3):
        buf, ok = buffer.push(buf, on, jnp.int32(10 + round_))
        assert bool(ok)
        buf, x, y, valid = buffer.pop(buf)
        assert bool(valid) and int(y) == 10 + round_
    assert int(buf.size) == 0


def test_mnist_shapes_and_class_balance():
    """Every class appears exactly n/10 times when 10 | n, at every side."""
    for side in (28, 14, 7):
        xs, ys = mnist.load(n_points=60, side=side)
        assert xs.shape == (60, side * side) and xs.dtype == bool
        assert ys.dtype == np.int32
        assert list(np.bincount(ys, minlength=10)) == [6] * 10
    # uneven n: counts differ by at most one
    ys = mnist.labels(47, seed=3)
    counts = np.bincount(ys, minlength=10)
    assert counts.max() - counts.min() <= 1 and counts.sum() == 47


def test_mnist_deterministic_across_processes():
    """Same seed => bitwise-same splits, even in a fresh interpreter (the
    generator draws from SeedSequence([seed, i]), never global RNG state)."""
    tr_x, tr_y, te_x, te_y = mnist.splits(20, 10, seed=7, side=7)
    digest = hashlib.sha256(
        b"".join(np.ascontiguousarray(a).tobytes()
                 for a in (tr_x, tr_y, te_x, te_y))
    ).hexdigest()
    child = subprocess.run(
        [sys.executable, "-c", (
            "import hashlib, numpy as np\n"
            "from repro.data import mnist\n"
            "parts = mnist.splits(20, 10, seed=7, side=7)\n"
            "print(hashlib.sha256(b''.join("
            "np.ascontiguousarray(a).tobytes() for a in parts)).hexdigest())"
        )],
        capture_output=True, text=True, check=True,
    )
    assert child.stdout.strip() == digest


def test_mnist_splits_are_prefix_stable():
    """Growing the test split never perturbs the train rows (one
    generation, sliced)."""
    a = mnist.splits(20, 5, seed=1, side=7)
    b = mnist.splits(20, 15, seed=1, side=7)
    np.testing.assert_array_equal(a[0], b[0])
    np.testing.assert_array_equal(a[1], b[1])
    np.testing.assert_array_equal(a[2], b[2][:5])


def test_mnist_booleanize_threshold_edge():
    """Booleanization is inclusive: a pixel exactly at the threshold is
    ink; one ulp below is background."""
    thr = mnist.THRESHOLD
    below = np.nextafter(np.float32(thr), np.float32(0.0))
    imgs = np.asarray([[[thr, below], [0.0, 1.0]]], dtype=np.float32)
    bits = mnist.booleanize(imgs)
    np.testing.assert_array_equal(bits, [[True, False, False, True]])


def test_mnist_downscale_blocks():
    """Block-mean pooling halves the raster and averages exact 2x2 blocks."""
    imgs = np.arange(16, dtype=np.float32).reshape(1, 4, 4)
    got = mnist.downscale(imgs, 2)
    np.testing.assert_allclose(
        got, [[[2.5, 4.5], [10.5, 12.5]]]
    )
    with pytest.raises(ValueError):
        mnist.downscale(np.zeros((1, 7, 7), dtype=np.float32), 2)


def test_mnist_glyphs_separable_at_low_res():
    """Different digits produce different booleanized rasters even at 7x7
    (jitter never collapses two classes onto one bitmap)."""
    xs, ys = mnist.load(n_points=40, side=7)
    for a in range(40):
        for b in range(a + 1, 40):
            if ys[a] != ys[b]:
                assert not np.array_equal(xs[a], xs[b])


def test_mnist_downscale_preserves_label_assignment():
    """Hypothesis property: the 28 -> 14 -> 7 downscale chain is a pure
    datapath-width change — the label sequence depends only on (n, seed),
    and block-pooling a 28x28 raster twice matches the 7x7 geometry."""
    pytest.importorskip(
        "hypothesis", reason="optional dev dependency (requirements-dev.txt)"
    )
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(10, 30), seed=st.integers(0, 2**16 - 1))
    def prop(n, seed):
        ys28 = mnist.load(n_points=n, seed=seed, side=28)[1]
        ys14 = mnist.load(n_points=n, seed=seed, side=14)[1]
        ys7 = mnist.load(n_points=n, seed=seed, side=7)[1]
        np.testing.assert_array_equal(ys28, ys14)
        np.testing.assert_array_equal(ys14, ys7)
        imgs28, ys = mnist.raw(n, seed=seed, side=28)
        pooled7 = mnist.downscale(mnist.downscale(imgs28, 2), 2)
        assert pooled7.shape == (n, 7, 7)
        np.testing.assert_array_equal(ys, ys28)
        # pooled ink stays ink-like: every digit keeps some over-threshold
        # mass after two halvings
        assert (pooled7.reshape(n, -1) >= mnist.THRESHOLD).any(axis=1).all()

    prop()


def test_rom_source_cycles():
    xs = np.eye(3, dtype=bool)
    ys = np.arange(3, dtype=np.int32)
    src = memory.ROMSource(xs, ys)
    seen = [src.next_row()[1] for _ in range(7)]
    assert seen == [0, 1, 2, 0, 1, 2, 0]
