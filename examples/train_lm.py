"""End-to-end LM training driver: a ~100M-param model for a few hundred
steps with the full production loop (AdamW + cosine, microbatching, atomic
checkpoints, NaN/straggler watchdog, resume-on-restart).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--small]

--small trains the reduced smoke config (seconds on CPU) — used by CI; the
default ~100M config takes a few s/step on one CPU core.
"""
import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import GLOBAL, ModelConfig, ShapeConfig
from repro.data import synthetic
from repro.models import params as P
from repro.models import transformer
from repro.train import loop as loop_mod
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod

CFG_100M = ModelConfig(
    arch_id="repro-100m",
    family="dense",
    n_layers=12,
    d_model=640,
    n_heads=10,
    n_kv_heads=5,
    d_ff=1708,
    vocab_size=32768,
    layer_pattern=(GLOBAL,),
    act="swiglu",
    compute_dtype="float32",   # CPU example
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = configs.get_smoke_config("granite_8b") if args.small else CFG_100M
    specs = transformer.model_specs(cfg)
    print(f"model: {cfg.arch_id}  params={P.count_params(specs)/1e6:.1f}M")

    tc = ts_mod.TrainConfig(opt=opt_mod.OptConfig(
        lr=1e-3, warmup_steps=20, total_steps=args.steps))
    prm = P.materialize(specs, jax.random.PRNGKey(0), jnp.float32)
    state = ts_mod.init_state(tc, prm)

    shape = ShapeConfig("ex", args.seq, args.batch, "train")
    data = synthetic.token_batches(cfg, shape)
    step_fn = jax.jit(lambda s, b: ts_mod.train_step(cfg, tc, s, b),
                      donate_argnums=(0,))
    lc = loop_mod.LoopConfig(total_steps=args.steps,
                             checkpoint_every=max(args.steps // 4, 10),
                             checkpoint_dir=args.ckpt)
    state = loop_mod.resume_or_init(lc, state)
    state, report = loop_mod.run(lc, state, step_fn, data, log_every=10)
    first = report.losses[0] if report.losses else float("nan")
    last = report.losses[-1] if report.losses else float("nan")
    print(f"\nloss {first:.3f} -> {last:.3f} over {report.steps_run} steps "
          f"(faults={len(report.fault_events)}, "
          f"stragglers={len(report.straggler_steps)})")


if __name__ == "__main__":
    main()
