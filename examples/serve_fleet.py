"""TMService end-to-end: a serving fleet surviving label drift (§5.3.2).

One fleet-native surface drives the paper's whole Fig-3 story at K = 4:

1. offline-train every member on clean iris rows (one replicated scan),
2. serve + adapt online via queue-based batch ingress (``submit_rows``
   stages traffic host-side; ``tick`` drains, analyzes on cadence),
3. poison two members' label streams (drift) — their accuracy collapses,
   the §5.3.2 policy rolls THEM back to their known-good banks while the
   clean members keep learning untouched.

Every member runs under its own (s, T) via the runtime's per-replica
hyperparameter ports.

    PYTHONPATH=src python examples/serve_fleet.py [--replicas 4]
"""
import argparse

import numpy as np

from repro.core import TMConfig, init_state
from repro.data import iris
from repro.serve import AdaptPolicy, ServiceConfig, TMService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=4)
    ap.add_argument("--cycles", type=int, default=160)
    args = ap.parse_args()
    K = args.replicas

    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16, n_states=50)
    xs, ys = iris.load()
    svc = TMService(
        cfg, init_state(cfg),
        ServiceConfig(
            replicas=K, buffer_capacity=32, chunk=8,
            # per-replica hyperparameter ports: each member its own (s, T)
            s=np.linspace(1.375, 1.8, K).tolist(),
            T=[15] * K,
            policy=AdaptPolicy(analyze_every=8, rollback_threshold=0.08),
            seed=list(range(K)),
        ),
        eval_x=xs[100:], eval_y=ys[100:],
    )

    def fmt(v):
        return "[" + " ".join(f"{float(a):.3f}" for a in v) + "]"

    base = svc.offline_train(xs[:80], ys[:80], n_epochs=10)
    print(f"offline phase, per-replica eval accuracy: {fmt(base)}")
    print(f"serving a probe batch: preds[K, B] = {svc.serve(xs[:3]).shape}\n")

    # Online phase: members K//2.. see label drift (adversarial relabels).
    drifted = np.arange(K) >= K // 2
    print("cycle  accuracies (* = rollback fired)   rollbacks")
    for i in range(args.cycles):
        j = 80 + (i % 20)
        y_clean = np.full(K, int(ys[j]), dtype=np.int32)
        y_drift = np.where(drifted, (y_clean + 1) % 3, y_clean)
        svc.submit_rows(np.asarray(xs[j]), y_drift.astype(np.int32))
        report = svc.tick()
        if report.accuracy is not None:
            mark = "*" if report.rolled_back.any() else " "
            print(f"{i:5d}  {fmt(report.accuracy)}{mark}"
                  f"  {svc.rollbacks.tolist()}")

    print(f"\nper-replica rollbacks: {svc.rollbacks.tolist()} "
          f"(drifted members: {np.nonzero(drifted)[0].tolist()})")
    print(f"datapoints lost to backpressure: {svc.lost.tolist()}")
    print(f"ingress device dispatches: {svc.router.flushes} "
          f"for {int(svc.steps.sum())} consumed datapoints")
    final = svc.analyze()
    print(f"final eval accuracy:  {fmt(final)}")
    if svc.rollbacks[drifted].sum() > 0 and (svc.rollbacks[~drifted] == 0).all():
        print("rollbacks hit only drifted members; clean members never "
              "rolled back — the §5.3.2 policy isolated the drift.")


if __name__ == "__main__":
    main()
