"""Use case §5.3: stuck-at faults injected into the running machine.

20% of TAs are forced stuck-at-0 through the fault controller's AND/OR
masks after 5 online cycles (no recompilation — the masks are runtime
state). Online learning re-trains "around" the faulty automata; the frozen
system cannot.

    PYTHONPATH=src python examples/fault_mitigation.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks

import jax.numpy as jnp

from benchmarks import common
from repro.core import faults as faults_mod
from repro.core import manager as mgr


def main():
    inject = 5
    and_m, or_m = faults_mod.even_spread_stuck_at(common.CFG, 0.2, 0)
    masks = (jnp.asarray(and_m), jnp.asarray(or_m))

    online, _, _, _ = common.run_schedule(
        mgr.make_schedule(online_s=1.0, fault_masks=masks,
                          inject_at_cycle=inject),
        n_orderings=12,
    )
    frozen, _, _, _ = common.run_schedule(
        mgr.make_schedule(online_s=1.0, fault_masks=masks,
                          inject_at_cycle=inject, online_enabled=False),
        n_orderings=12,
    )
    print("validation accuracy, 20% stuck-at-0 TAs injected after cycle 5:")
    print("cycle   online-learning   frozen")
    for i in range(len(online)):
        mark = "  <-- faults injected" if i == inject + 1 else ""
        print(f"{i:3d}       {online[i,1]:.3f}          "
              f"{frozen[i,1]:.3f}{mark}")
    print(f"\nfinal gap (online - frozen): "
          f"{online[-1,1] - frozen[-1,1]:+.3f}")


if __name__ == "__main__":
    main()
