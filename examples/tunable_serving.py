"""Runtime-tunable serving (DESIGN.md §16): one fleet, three budgets.

Offline-trains a K-member fleet, calibrates per-replica clause rankings
from the eval set, then serves the SAME traffic burst at compute budgets
100% / 50% / 25% — printing held-out accuracy, serve latency, and (with
early exit on) how many clauses each request actually evaluated. The
100% row is bitwise the plain serve path; the lower rows trade accuracy
for latency without retraining or re-JIT — the knob a latency-pressured
deployment turns.

    PYTHONPATH=src python examples/tunable_serving.py [--replicas 4]
"""
import argparse
import time

import numpy as np

from repro.core import TMConfig, init_state
from repro.data import iris
from repro.serve import ServiceConfig, TMService, TunableConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--replicas", type=int, default=4)
    args = ap.parse_args()
    K = args.replicas

    cfg = TMConfig(n_features=16, max_classes=3, max_clauses=16,
                   n_states=50)
    xs, ys = iris.load()
    svc = TMService(
        cfg, init_state(cfg),
        ServiceConfig(
            replicas=K, buffer_capacity=32, chunk=8,
            s=1.375, T=15, seed=list(range(K)),
            tunable=TunableConfig(budget=1.0, early_exit=True, group=4),
        ),
        eval_x=xs[100:], eval_y=ys[100:],
    )

    base = svc.offline_train(xs[:80], ys[:80], n_epochs=10)
    print(f"offline eval accuracy per replica: "
          f"{[round(float(a), 3) for a in base]}")
    svc.calibrate()
    print(f"calibrated: per-replica clause rankings over "
          f"{cfg.max_clauses} clauses\n")

    burst_x, burst_y = xs[100:], ys[100:]
    print("budget  m   accuracy  serve_ms  clauses evaluated (min/mean)")
    for budget in (1.0, 0.5, 0.25):
        # warm the compiled path for this budget before timing
        svc.serve(burst_x, budget=budget)
        t0 = time.perf_counter()
        preds, aux = svc.serve(burst_x, budget=budget, return_aux=True)
        ms = (time.perf_counter() - t0) * 1e3
        acc = float((preds == burst_y[None]).mean())
        print(f"{budget:6.0%}  {aux.m:2d}  {acc:8.3f}  {ms:8.2f}  "
              f"{aux.evaluated.min():3d} / {aux.evaluated.mean():.1f}")

    # the 100% budget row IS the plain serve path, bit for bit — the
    # early-exit bound is prediction-invariant, so even with exit on the
    # full-budget predictions match the pre-§16 contraction exactly
    np.testing.assert_array_equal(svc.serve(burst_x, budget=1.0),
                                  preds_full(svc, burst_x))
    print("\nbudget=100% verified bitwise against the plain serve path")


def preds_full(svc, xs):
    """The pre-§16 serve path (tuner bypassed) for the parity check."""
    from repro.core import tm as tm_mod

    return np.asarray(tm_mod.predict_batch_replicated(
        svc.cfg, svc._ss.tm, svc.rt, np.asarray(xs)[None]))


if __name__ == "__main__":
    main()
