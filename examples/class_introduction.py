"""Use case §5.2: a class the machine has never seen appears at runtime.

Class 0 is filtered from every set (the over-provisioned class slot stays
gated); after 5 online cycles the filter opens and the class-mask port
enables the slot — no re-JIT, mirroring the FPGA's no-re-synthesis
over-provisioning. With online learning the accuracy dips then recovers;
with it disabled the system stays degraded.

    PYTHONPATH=src python examples/class_introduction.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks


from benchmarks import common
from repro.core import manager as mgr


def main():
    intro = 5
    with_online, _, _, _ = common.run_schedule(
        mgr.make_schedule(online_s=1.0, filtered_class=0,
                          introduce_at_cycle=intro),
        n_orderings=12, offline_limit=None,
    )
    frozen, _, _, _ = common.run_schedule(
        mgr.make_schedule(online_s=1.0, filtered_class=0,
                          introduce_at_cycle=intro, online_enabled=False),
        n_orderings=12, offline_limit=None,
    )
    print("validation-set accuracy (class 0 introduced after cycle 5):")
    print("cycle   online-learning   frozen")
    for i in range(len(with_online)):
        mark = "  <-- class 0 introduced" if i == intro + 1 else ""
        print(f"{i:3d}       {with_online[i,1]:.3f}          "
              f"{frozen[i,1]:.3f}{mark}")
    print(f"\nfinal gap (online - frozen): "
          f"{with_online[-1,1] - frozen[-1,1]:+.3f}")


if __name__ == "__main__":
    main()
