"""Batched serving example: prefill + decode with a persistent KV cache —
the same serve_step the decode_32k dry-run cells lower at 256/512 chips.

    PYTHONPATH=src python examples/serve_lm.py [--arch gemma3-1b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import params as P
from repro.models import transformer
from repro.serve.engine import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()

    cfg = configs.get_smoke_config(args.arch)
    if cfg.embeds_input:
        raise SystemExit("pick a token-input arch for this example")
    prm = P.materialize(transformer.model_specs(cfg), jax.random.PRNGKey(0),
                        jnp.float32)
    ec = EngineConfig(max_seq=16 + args.max_new, batch_slots=args.batch)
    eng = Engine(cfg, prm, ec)

    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, (args.batch, 16)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts, args.max_new)
    dt = time.time() - t0
    print(f"{cfg.arch_id} (reduced): {args.batch} seqs x {args.max_new} "
          f"tokens in {dt:.2f}s ({args.batch*args.max_new/dt:.0f} tok/s)")
    print("first rows:", out[:2, :12])


if __name__ == "__main__":
    main()
