"""Quickstart: the paper's core loop on iris (§5.1 / Fig 4).

Offline-train a Tsetlin Machine on 20 labelled datapoints, then keep
learning online while the accuracy-analysis block tracks all three sets —
the whole experiment (all cross-validation orderings) runs as ONE vmapped
JAX program. Then the same machine goes live behind ``TMService``, the
fleet-native serving surface (a single machine is just K = 1): labelled
traffic through the queue-based batch ingress, ``tick`` interleaving
online training with periodic accuracy analysis.

    PYTHONPATH=src python examples/quickstart.py [--orderings 24]
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks

import argparse


from benchmarks import common
from repro.core import manager as mgr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--orderings", type=int, default=12)
    args = ap.parse_args()

    schedule = mgr.make_schedule(online_s=1.0)
    curve, activity, wall, O = common.run_schedule(
        schedule, n_orderings=args.orderings
    )
    print(f"{O} cross-validation orderings in {wall:.1f}s "
          f"(one vmapped program)\n")
    print("cycle  offline  validation  online")
    for i, (a, b, c) in enumerate(curve):
        tag = "offline-trained" if i == 0 else f"online cycle {i}"
        print(f"{tag:18s} {a:.3f}    {b:.3f}     {c:.3f}")
    gains = curve[-1] - curve[0]
    print(f"\nonline-learning gains: offline {gains[0]:+.3f}  "
          f"validation {gains[1]:+.3f}  online {gains[2]:+.3f}")
    print(f"mean TA-update activity (clock-gating analogue): "
          f"{activity.mean():.4f}")

    # -- the same machine as a live service (TMService, K = 1) --------------
    from repro.core import init_state
    from repro.data import iris
    from repro.serve import AdaptPolicy, ServiceConfig, TMService

    xs, ys = iris.load()
    svc = TMService(
        common.CFG, init_state(common.CFG),
        ServiceConfig(replicas=1, buffer_capacity=32, chunk=8,
                      s=1.0, T=15,
                      policy=AdaptPolicy(analyze_every=16)),
        eval_x=xs[100:], eval_y=ys[100:],
    )
    base = svc.offline_train(xs[:20], ys[:20], n_epochs=10)
    print(f"\nTMService (K=1): offline eval accuracy {float(base[0]):.3f}")
    for i in range(32):                      # labelled traffic -> batch ingress
        svc.submit(0, xs[20 + i], int(ys[20 + i]))
        report = svc.tick()                  # drain + cadence + analysis
        if report.accuracy is not None:
            print(f"  tick {i}: online-adapted eval accuracy "
                  f"{float(report.accuracy[0]):.3f}")
    print(f"  served predictions for a probe batch: "
          f"{svc.serve(xs[:5])[0].tolist()}")


if __name__ == "__main__":
    main()
