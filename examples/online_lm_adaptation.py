"""The paper's Fig-3 FSM applied to an LM (DESIGN.md §4):

offline train -> analyze -> interleave online updates with periodic
analysis; if eval loss collapses (bad online data / faults), roll back to
the last good checkpoint — the TM architecture's accuracy-watchdog +
on-chip-retrain policy (§5.3.2) as an LM serving runtime.

    PYTHONPATH=src python examples/online_lm_adaptation.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs.base import ShapeConfig
from repro.data import synthetic
from repro.models import params as P
from repro.models import transformer
from repro.serve.online_adapt import OnlineAdaptConfig, OnlineAdaptManager
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod


def main():
    cfg = configs.get_smoke_config("gemma3_1b")
    prm = P.materialize(transformer.model_specs(cfg), jax.random.PRNGKey(0),
                        jnp.float32)
    tc = ts_mod.TrainConfig(opt=opt_mod.OptConfig(
        lr=2e-3, warmup_steps=2, total_steps=500))
    state = ts_mod.init_state(tc, prm)
    oc = OnlineAdaptConfig(analyze_every=4, rollback_threshold=0.10,
                           checkpoint_dir="/tmp/repro_online_lm")
    m = OnlineAdaptManager(cfg, tc, state, oc)

    shape = ShapeConfig("ex", 64, 2, "train")
    stream = synthetic.token_batches(cfg, shape, seed=0)
    evalb = synthetic.token_batches(cfg, shape, seed=99).__next__()

    base = m.offline_train([next(stream) for _ in range(8)], evalb)
    print(f"offline phase: eval loss {base:.3f}")

    for step in range(24):
        batch = next(stream)
        if 8 <= step < 12:  # a burst of corrupted online labels
            batch = dict(batch)
            batch["tokens"] = jnp.asarray(
                np.random.default_rng(step).integers(
                    0, cfg.vocab_size, batch["tokens"].shape), jnp.int32)
        loss = m.online_step(batch, evalb)
        if loss is not None:
            print(f"online step {step:2d}: eval={loss:.3f} "
                  f"rollbacks={m.rollbacks}")
    print(f"\nfinal eval {m.history[-1][1]:.3f} (offline {base:.3f}); "
          f"rollbacks={m.rollbacks}")


if __name__ == "__main__":
    main()
