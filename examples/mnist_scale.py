"""MNIST-scale serving demo: the TMService flow at a wide datapath.

The same submit -> tick -> serve loop as examples/serve_fleet.py, but on
the generated booleanized digit workload (10 classes, f = side**2 boolean
inputs; side 28 = the paper-benchmark MNIST width). Rows flow straight
from the generator into the service — no host-side reshaping at any
width; ``--side`` is the only knob that changes the datapath.

    python examples/mnist_scale.py               # 14x14 (f=196), CI-sized
    python examples/mnist_scale.py --side 28     # full MNIST width
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.configs import tm_mnist
from repro.core import init_state
from repro.data import mnist
from repro.serve import AdaptPolicy, ServiceConfig, TMService


def main(side: int = 14, replicas: int = 2, epochs: int = 4,
         cycles: int = 16) -> dict:
    params = tm_mnist.config_for_side(side)
    cfg = params.tm
    print(f"datapath: f={cfg.n_features} ({side}x{side}), "
          f"{cfg.max_classes} classes x {cfg.max_clauses} clauses, "
          f"TA bank {cfg.state_dtype.__name__}")

    tr_x, tr_y, te_x, te_y = mnist.splits(80, 40, side=side)
    svc = TMService(
        cfg, init_state(cfg),
        ServiceConfig(replicas=replicas, buffer_capacity=32, chunk=8,
                      s=params.s_online, T=params.T,
                      seed=list(range(replicas)),
                      policy=AdaptPolicy(analyze_every=16,
                                         rollback_threshold=0.1)),
        eval_x=te_x, eval_y=te_y,
    )
    base = svc.offline_train(tr_x, tr_y, n_epochs=epochs)
    print(f"offline baseline accuracy per member: {np.round(base, 3)}")

    # Online phase: labelled traffic streams in; tick drains, analyzes on
    # cadence and applies the §5.3.2 policy per member.
    for i in range(cycles):
        svc.submit_rows(tr_x[i % len(tr_x)], int(tr_y[i % len(tr_y)]))
        report = svc.tick()
        if report.accuracy is not None:
            print(f"cycle {i:2d}: trained={report.trained.tolist()} "
                  f"acc={np.round(report.accuracy, 3)} "
                  f"rolled_back={report.rolled_back.tolist()}")

    preds = svc.serve(te_x)                       # [K, B] fleet inference
    acc = (preds == np.asarray(te_y)[None]).mean(axis=1)
    print(f"served accuracy per member: {np.round(acc, 3)} "
          f"(rollbacks: {svc.rollbacks.tolist()}, "
          f"dropped: {svc.dropped.tolist()})")
    assert float(acc.min()) > 0.3, "service failed to learn the workload"
    return {"base": base, "served": acc}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--side", type=int, default=14,
                    help="raster width (28 = full MNIST scale)")
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--epochs", type=int, default=4)
    ap.add_argument("--cycles", type=int, default=16)
    a = ap.parse_args()
    main(side=a.side, replicas=a.replicas, epochs=a.epochs, cycles=a.cycles)
