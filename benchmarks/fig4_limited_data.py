"""Paper Figure 4: online learning with labelled data, limited initial set.

Offline: 10 epochs on 20 datapoints (s=1.375). Online: 16 single-pass cycles
over the 60-point online set at s=1.0. Accuracy re-analyzed per cycle on all
three sets, averaged over cross-validation orderings.

Paper claims (iris): starting accuracies ~83% offline / 79.5% validation /
79.5% online; after 16 cycles validation+online rise ~+12%, offline ~+5%.
"""
from __future__ import annotations


from benchmarks import common
from repro.core import manager as mgr


def run(n_orderings: int = 24, seed: int = 0,
        dataset: str = "iris", side: int | None = None):
    params = common.system_params(dataset, side)
    schedule = mgr.make_schedule(online_s=params.s_online)
    curve, activity, wall, O = common.run_schedule(
        schedule, n_orderings=n_orderings, seed=seed,
        dataset=dataset, side=side,
    )
    gains = curve[-1] - curve[0]
    derived = {
        "start_offline": curve[0, 0], "start_val": curve[0, 1],
        "start_online": curve[0, 2],
        "gain_offline": gains[0], "gain_val": gains[1],
        "gain_online": gains[2],
        "mean_activity": float(activity.mean()),
        "orderings": O,
    }
    return curve, derived, wall


def main(n_orderings: int = 24):
    curve, derived, wall = run(n_orderings)
    print(common.curve_csv("fig4", curve))
    us = wall * 1e6 / max(1, len(curve))
    d = (f"start_off={derived['start_offline']:.3f};"
         f"start_val={derived['start_val']:.3f};"
         f"gain_val={derived['gain_val']:+.3f};"
         f"gain_online={derived['gain_online']:+.3f};"
         f"gain_off={derived['gain_offline']:+.3f};"
         f"activity={derived['mean_activity']:.4f}")
    print(f"fig4_limited_data,{us:.0f},{d}")
    return derived


if __name__ == "__main__":
    main()
