"""Paper Figures 8-9: fault mitigation via online learning.

20% of TAs forced stuck-at-0 (evenly spread, §5.3.1) after 5 online cycles.
Fig 8: online learning disabled — accuracy falls and stays down.
Fig 9: online learning enabled — accuracy dips then recovers toward the
fault-free trajectory (paper: final gains on par with Figure 4).
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks import common
from repro.core import faults as faults_mod
from repro.core import manager as mgr


def run(n_orderings: int = 24, inject_at: int = 5, fraction: float = 0.2,
        seed: int = 0, dataset: str = "iris", side: int | None = None):
    params = common.system_params(dataset, side)
    and_m, or_m = faults_mod.even_spread_stuck_at(params.tm, fraction, 0)
    masks = (jnp.asarray(and_m), jnp.asarray(or_m))
    kw = dict(n_orderings=n_orderings, seed=seed, dataset=dataset, side=side)
    out = {}
    out["fig8_faults_no_online"] = common.run_schedule(
        mgr.make_schedule(online_s=params.s_online, fault_masks=masks,
                          inject_at_cycle=inject_at, online_enabled=False),
        **kw,
    )
    out["fig9_faults_online"] = common.run_schedule(
        mgr.make_schedule(online_s=params.s_online, fault_masks=masks,
                          inject_at_cycle=inject_at),
        **kw,
    )
    return out, inject_at


def main(n_orderings: int = 24):
    out, inject = run(n_orderings)
    walls = 0.0
    for name, (curve, _act, wall, _O) in out.items():
        print(common.curve_csv(name, curve))
        walls += wall

    c8 = out["fig8_faults_no_online"][0]
    c9 = out["fig9_faults_online"][0]
    drop8 = c8[inject + 1, 1] - c8[inject, 1]
    dip9 = c9[inject + 1, 1] - c9[inject, 1]
    rec9 = c9[-1, 1] - c9[inject + 1, 1]
    final_gap = c9[-1, 1] - c8[-1, 1]
    us = walls * 1e6 / (2 * len(c9))
    print(f"fig89_faults,{us:.0f},"
          f"frozen_drop={drop8:+.3f};online_dip={dip9:+.3f};"
          f"online_recovery={rec9:+.3f};online_vs_frozen={final_gap:+.3f}")
    return {"drop8": drop8, "dip9": dip9, "rec9": rec9,
            "final_gap": final_gap}


if __name__ == "__main__":
    main()
