"""Paper Figures 5-7: unseen class introduction at runtime.

Fig 5 (baseline): class 0 filtered from all sets for the whole run, online
learning enabled — accuracy improves on the 2-class problem.
Fig 6 (baseline): class 0 introduced after 5 online cycles, online learning
DISABLED — accuracy drops and stays down.
Fig 7: introduction after 5 cycles WITH online learning — accuracy dips then
recovers.

Offline set uses its full 30 rows here (the paper: filtering one of three
classes leaves ~20 of 30 — its §5.1 budget — while val/online drop to ~40).
"""
from __future__ import annotations


from benchmarks import common
from repro.core import manager as mgr


def run(n_orderings: int = 24, introduce_at: int = 5, seed: int = 0,
        dataset: str = "iris", side: int | None = None):
    s_onl = common.system_params(dataset, side).s_online
    kw = dict(n_orderings=n_orderings, offline_limit=None, seed=seed,
              dataset=dataset, side=side)
    out = {}
    out["fig5_filtered_online"] = common.run_schedule(
        mgr.make_schedule(online_s=s_onl, filtered_class=0), **kw
    )
    out["fig6_intro_no_online"] = common.run_schedule(
        mgr.make_schedule(online_s=s_onl, filtered_class=0,
                          introduce_at_cycle=introduce_at,
                          online_enabled=False),
        **kw,
    )
    out["fig7_intro_online"] = common.run_schedule(
        mgr.make_schedule(online_s=s_onl, filtered_class=0,
                          introduce_at_cycle=introduce_at),
        **kw,
    )
    return out, introduce_at


def main(n_orderings: int = 24):
    out, intro = run(n_orderings)
    walls = 0.0
    for name, (curve, _act, wall, _O) in out.items():
        print(common.curve_csv(name, curve))
        walls += wall

    c7 = out["fig7_intro_online"][0]
    c6 = out["fig6_intro_no_online"][0]
    # dip at first analysis after introduction; recovery by the end
    dip7 = c7[intro + 1, 1] - c7[intro, 1]
    rec7 = c7[-1, 1] - c7[intro + 1, 1]
    final_gap = c7[-1, 1] - c6[-1, 1]
    us = walls * 1e6 / (3 * len(c7))
    print(f"fig567_class_intro,{us:.0f},"
          f"dip_val={dip7:+.3f};recovery_val={rec7:+.3f};"
          f"online_vs_frozen_final={final_gap:+.3f}")
    return {"dip": dip7, "recovery": rec7, "final_gap": final_gap}


if __name__ == "__main__":
    main()
