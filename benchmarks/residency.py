"""Thousand-replica residency benchmark: K fleets on bounded device slots.

Drives a ``TMService`` with ``resident`` device slots (DESIGN.md §15)
under sparse personalization traffic — each round a random subset of
replicas receives datapoints and the fleet ticks — at K in {64, 1024,
4096}, the ROADMAP's thousand-replica scale, on whatever device mesh is
present (the CI job forces a 4-host-device topology). The evicted/
reactivated fleet is asserted BITWISE equal to an always-resident
unsharded twin driven with budgets masked by ``buffered > 0`` (the
residency drain's sweep criterion — see tests/test_residency.py), so the
numbers measure a correct fleet, not a drifting one.

Measured per K: adapt throughput (drained points/s through the
submit/tick loop, activation thrash included), offered rows/s, the
explicit activate/evict cohort latency (host snapshot <-> device slot
moves, per replica), and — the §17 tentpole number — the batched
datapath's speedup over PR 8's synchronous per-cohort baseline
(``batched_moves=False``, same traffic, same seeds):
``speedup_vs_percohort``. An extra ``residency_auto`` row drives
``resident="auto"`` through a dense->sparse traffic shift and records
the re-partition trajectory.

Machine-readable results go to ``BENCH_residency.json`` (override with
env ``REPRO_BENCH_RESIDENCY_JSON``). CI gates (benchmarks/gates.py)
every row's ``bitwise_identical``, ``residency_k1024.trained_per_s`` on
the 4-device mesh, and ``residency_k4096.speedup_vs_percohort >= 1.5``.
"""
from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import init_state
from repro.serve import AdaptPolicy, ServiceConfig, TMService

CFG = common.CFG

RESULTS: list[dict] = []

# iris rows as the traffic source (the paper's machine: f = 16)
from repro.data import iris  # noqa: E402

_XS, _YS = (np.asarray(a) for a in iris.load())


def _mesh():
    n = len(jax.devices())
    return jax.make_mesh((n,), ("replicas",)) if n > 1 else None


def _make(K, resident, mesh, seed=0, batched=True):
    return TMService(CFG, init_state(CFG), ServiceConfig(
        replicas=K, buffer_capacity=16, chunk=8, ingress_block=8,
        s=3.0, T=15, seed=seed, resident=resident, mesh=mesh,
        batched_moves=batched,
        policy=AdaptPolicy(analyze_every=10 ** 9),  # drain-only loop
    ))


def _drive(svc, rounds, active, *, twin=None, rng_seed=0):
    """``rounds`` of sparse traffic: ``active`` random replicas get one
    row each, then the fleet ticks. Optionally co-drives an
    always-resident ``twin`` with buffered-masked budgets."""
    rng = np.random.default_rng(rng_seed)
    K = svc.n_replicas
    for r in range(rounds):
        ids = rng.choice(K, size=min(active, K), replace=False)
        mask = np.zeros(K, dtype=bool)
        mask[ids] = True
        i = int(rng.integers(0, len(_XS)))
        svc.submit_rows(_XS[i], int(_YS[i]), mask)
        if twin is not None:
            twin.submit_rows(_XS[i], int(_YS[i]), mask)
            svc.flush()
            buffered = svc.buffered > 0
            svc.tick()
            twin.tick(np.where(buffered, twin.chunk, 0))
        else:
            svc.tick()
    svc.flush()
    if twin is not None:
        twin.flush()


def _assert_twin_bitwise(svc, twin):
    a = jax.tree.leaves((svc.ss, svc.rng_keys, svc.steps))
    b = jax.tree.leaves((twin.ss, twin.rng_keys, twin.steps))
    for la, lb in zip(a, b):
        if not np.array_equal(np.asarray(la), np.asarray(lb)):
            raise AssertionError(
                "residency fleet diverged from always-resident twin"
            )


def _move_latency(svc, cycles=4):
    """Mean per-replica latency of explicit evict -> activate cohort
    moves (host LRU store <-> device slots), on resident-sized cohorts."""
    R = svc.n_resident
    cohort = np.nonzero(svc.resident)[0][:R]
    t_evict = t_act = 0.0
    for _ in range(cycles):
        t0 = time.perf_counter()
        svc.evict(cohort)
        t_evict += time.perf_counter() - t0
        t0 = time.perf_counter()
        svc.activate(cohort)
        jax.block_until_ready(svc._ss.tm.ta_state)
        t_act += time.perf_counter() - t0
    n = cycles * len(cohort)
    return t_evict / n, t_act / n


def residency_bench(K: int, resident: int, rounds: int, active: int,
                    *, mesh=None, twin_check: bool = True) -> dict:
    """One K-point: sparse-traffic adapt loop + move latency + twin."""
    # correctness pass (untimed): sharded residency fleet vs unsharded
    # always-resident twin, bitwise
    bitwise = None
    if twin_check:
        svc = _make(K, resident, mesh)
        twin = _make(K, None, None)
        _drive(svc, rounds, active, twin=twin)
        _assert_twin_bitwise(svc, twin)
        bitwise = True

    # timed pass (twin bookkeeping off the clock); fresh service so the
    # LRU starts cold exactly like the correctness pass
    svc = _make(K, resident, mesh)
    _drive(svc, 2, active)           # warm the compiled paths
    trained0 = int(svc.steps.sum())
    t0 = time.perf_counter()
    _drive(svc, rounds, active, rng_seed=1)
    wall = time.perf_counter() - t0
    trained = int(svc.steps.sum()) - trained0

    # per-cohort baseline (PR 8's synchronous gather/scatter path), same
    # traffic and seeds — the §17 speedup denominator
    base = _make(K, resident, mesh, batched=False)
    _drive(base, 2, active)
    base0 = int(base.steps.sum())
    t0 = time.perf_counter()
    _drive(base, rounds, active, rng_seed=1)
    wall_base = time.perf_counter() - t0
    trained_base = int(base.steps.sum()) - base0
    assert trained_base == trained, "baseline consumed different traffic"

    evict_s, act_s = _move_latency(svc)
    return {
        "n_replicas": K,
        "resident": resident,
        "rounds": rounds,
        "active_per_round": active,
        "devices": len(jax.devices()),
        "sharded": mesh is not None,
        "wall_s": wall,
        "trained_points": trained,
        "trained_per_s": trained / wall,
        "offers_per_s": rounds * active / wall,
        "percohort_wall_s": wall_base,
        "percohort_trained_per_s": trained_base / wall_base,
        "speedup_vs_percohort": wall_base / wall,
        "activations": int(svc._res.activations),
        "evictions": int(svc._res.evictions),
        "evict_latency_s_per_replica": evict_s,
        "activate_latency_s_per_replica": act_s,
        "bitwise_identical": bitwise,
    }


def auto_residency_bench(K: int, rounds: int, *, mesh=None) -> dict:
    """resident='auto' observability row: a dense->sparse traffic shift
    and the re-partition trajectory it provokes, with the twin bitwise
    assertion held across every re-partition."""
    svc = _make(K, "auto", mesh)
    twin = _make(K, None, None)
    r0 = svc.n_resident
    half = rounds // 2
    _drive(svc, half, K, twin=twin)                # dense: grow
    grown = svc.n_resident
    _drive(svc, rounds - half, 1, twin=twin,       # sparse: shrink
           rng_seed=1)
    _assert_twin_bitwise(svc, twin)
    return {
        "n_replicas": K,
        "rounds": rounds,
        "devices": len(jax.devices()),
        "sharded": mesh is not None,
        "resident_initial": r0,
        "resident_after_dense": grown,
        "resident_final": svc.n_resident,
        "repartitions": int(svc.repartitions),
        "ewma_active": float(svc._res.ewma_active),
        "bitwise_identical": True,
    }


def main():
    RESULTS.clear()
    mesh = _mesh()
    # resident divides the device count (grid-major sharding of the slot
    # plane); traffic stays sparse — the personalization regime where a
    # round touches a sliver of the fleet.
    for K, resident, rounds, active in (
        (64, 16, 30, 16),
        (1024, 64, 12, 32),
        (4096, 64, 6, 32),
    ):
        row = residency_bench(K, resident, rounds, active, mesh=mesh)
        name = f"residency_k{K}"
        print(
            f"{name},{row['wall_s'] * 1e6:.1f},"
            f"resident={resident};devices={row['devices']};"
            f"trained_per_s={row['trained_per_s']:.0f};"
            f"speedup_vs_percohort={row['speedup_vs_percohort']:.2f};"
            f"act_us={row['activate_latency_s_per_replica'] * 1e6:.0f};"
            f"evict_us={row['evict_latency_s_per_replica'] * 1e6:.0f};"
            f"bitwise_identical=1"
        )
        RESULTS.append({"name": name, **row})

    row = auto_residency_bench(64, 24, mesh=mesh)
    print(
        f"residency_auto,0.0,"
        f"resident={row['resident_initial']}->"
        f"{row['resident_after_dense']}->{row['resident_final']};"
        f"repartitions={row['repartitions']};bitwise_identical=1"
    )
    RESULTS.append({"name": "residency_auto", **row})

    out_path = os.environ.get("REPRO_BENCH_RESIDENCY_JSON",
                              "BENCH_residency.json")
    payload = {
        "benchmark": "residency",
        "backend": CFG.backend,
        "jax_backend": jax.default_backend(),
        "results": RESULTS,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")
    return payload


if __name__ == "__main__":
    main()
