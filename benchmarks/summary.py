"""Aggregate BENCH_*.json payloads into one markdown summary.

CI's ``bench-summary`` job downloads every benchmark artifact, runs::

    python -m benchmarks.summary BENCH_*.json

and publishes the result twice: appended to ``$GITHUB_STEP_SUMMARY``
(the run's summary page shows every headline number without clicking
into job logs) and written to ``BENCH_summary.md`` (uploaded as the
single roll-up artifact). Locally the same invocation just prints the
markdown.

Each payload renders as one table — rows are the benchmark's result
rows, columns are whichever HEADLINE metrics those rows carry (bitwise/
consistency flags, speedups, throughput rates, latency percentiles,
accuracy). Fields outside the headline list stay in the per-benchmark
JSON artifacts; this file is the at-a-glance view, not the archive.
"""

from __future__ import annotations

import json
import os
import sys

# Column order for every metric worth surfacing on the summary page.
# A column appears in a table only if at least one row carries the key.
HEADLINE = (
    "bitwise_identical",
    "bitwise_at_full_budget",
    "consistent_with_replay",
    "conserved",
    "speedup",
    "speedup_pallas",
    "speedup_vs_full",
    "speedup_vs_percohort",
    "trained_per_s",
    "offers_per_s",
    "points_per_s",
    "serve_p50_s",
    "serve_p99_s",
    "accuracy",
    "accuracy_drop",
    "devices",
    "resident",
    "resident_initial",
    "resident_final",
    "repartitions",
)


def _fmt(v) -> str:
    if isinstance(v, bool):
        return "yes" if v else "**NO**"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 100:
            return f"{v:,.0f}"
        return f"{v:.3g}" if abs(v) >= 1e-3 else f"{v:.2e}"
    if v is None:
        return ""
    return str(v)


def render_payload(payload: dict) -> list[str]:
    """One markdown section (header + table) for one BENCH payload."""
    bench = payload.get("benchmark", "?")
    rows = payload.get("results", [])
    backend = payload.get("backend", "")
    jaxb = payload.get("jax_backend", "")
    lines = [f"### {bench} (`backend={backend}`, `jax={jaxb}`)", ""]
    cols = [k for k in HEADLINE if any(k in r for r in rows)]
    lines.append("| row | " + " | ".join(cols) + " |")
    lines.append("|---" * (len(cols) + 1) + "|")
    for r in rows:
        cells = " | ".join(_fmt(r.get(k)) for k in cols)
        lines.append("| " + str(r.get("name", "?")) + " | " + cells + " |")
    lines.append("")
    return lines


def render(paths: list[str]) -> str:
    lines = ["## Benchmark summary", ""]
    for path in sorted(paths):
        with open(path) as f:
            lines.extend(render_payload(json.load(f)))
    return "\n".join(lines) + "\n"


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: python -m benchmarks.summary BENCH_x.json [...]")
        return 2
    md = render(argv)
    print(md)
    with open("BENCH_summary.md", "w") as f:
        f.write(md)
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if step_summary:
        with open(step_summary, "a") as f:
            f.write(md)
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
