"""Paper §6: performance — parallel TM datapath + hyperparameter search.

The FPGA updates all clauses/TAs in 2 clock cycles, one datapoint per clock.
The TPU/JAX analogue measured here:

* `tm_train_step`  — fused inference+feedback for ONE datapoint (all
  C x J x 2f TA lanes in parallel): wall time per datapoint + TA-updates/s.
* `tm_infer_batch` — batch-first inference throughput (datapoints/s) on the
  dispatched `clause_eval_batch` path (include bank read once per batch).
* `tm_infer_vmap`  — the legacy vmap-of-per-sample inference plane, kept as
  the baseline the batch path is tracked against (bitwise-equal predictions
  asserted every run).
* `tm_online_drain` — chunked `online._consume_many` drain rate vs the
  one-jitted-call-per-datapoint serving loop it replaced.
* `hpsearch_grid`  — the paper's goal (ii): a (s x T x orderings) grid as a
  single vmapped program vs. the same grid run sequentially; the speedup is
  the replication-parallelism the FPGA gets from spatial hardware.
* `activity`       — fraction of TA lanes that actually flip per step (the
  clock-gating/energy analogue; lower s => sparser feedback => lower power,
  §5.1's "bias away from issuing feedback").

Every row is also written machine-readable to ``BENCH_throughput.json``
(override with env ``REPRO_BENCH_JSON``) so speedups are tracked across PRs.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import feedback as fb
from repro.core import hpsearch
from repro.core import online as online_mod
from repro.core import tm as tm_mod
from repro.data import blocks, iris

CFG = common.CFG

RESULTS: list[dict] = []


def _time(fn, *args, n=5, warmup=2):
    """Mean seconds/call over n calls (after warmup). Comparisons between
    two paths should interleave repeated _time calls and take each path's
    min — see the batched-vs-vmap inference block."""
    for _ in range(warmup):
        out = jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n, out


def _emit(name: str, us_per_call: float, derived: str, **extra):
    """Print the CSV row (run.py contract) and record the JSON row."""
    print(f"{name},{us_per_call:.1f},{derived}")
    RESULTS.append({"name": name, "us_per_call": us_per_call, **extra})


def main():
    RESULTS.clear()
    xs, ys = iris.load()
    xs_j, ys_j = jnp.asarray(xs), jnp.asarray(ys)
    rt = tm_mod.init_runtime(CFG, s=1.375, T=15)
    st = tm_mod.init_state(CFG, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    # --- single-datapoint fused train step (the 2-cycle datapath) ---
    step = jax.jit(lambda s, x, y, k: fb.train_step(CFG, s, rt, x, y, k))
    dt, _ = _time(step, st, xs_j[0], ys_j[0], key, n=20)
    ta_lanes = CFG.max_classes * CFG.max_clauses * CFG.n_literals
    _emit("tm_train_step", dt * 1e6,
          f"datapoints_per_s={1/dt:.0f};ta_lanes_per_step={ta_lanes};"
          f"ta_updates_per_s={ta_lanes/dt:.2e}",
          datapoints_per_s=1 / dt, ta_updates_per_s=ta_lanes / dt)

    # --- streamed epoch (150 datapoints serially, hardware row order) ---
    epoch = jax.jit(lambda s, k: fb.train_datapoints(CFG, s, rt, xs_j, ys_j, k))
    dt, (_, aux) = _time(epoch, st, key, n=3)
    _emit("tm_train_epoch150", dt * 1e6, f"datapoints_per_s={150/dt:.0f}",
          datapoints_per_s=150 / dt)

    # --- inference: batch-first dispatched path vs legacy vmap plane ---
    infer_batch = jax.jit(lambda s, x: tm_mod.predict_batch(CFG, s, rt, x))
    infer_vmap = jax.jit(
        lambda s, x: jax.vmap(lambda r: tm_mod.predict(CFG, s, rt, r))(x)
    )
    # Interleave the trials: background load on the host then skews both
    # paths equally instead of whichever happened to run second.
    dt_b, dt_v = float("inf"), float("inf")
    preds_b = preds_v = None
    for _ in range(6):
        t, preds_b = _time(infer_batch, st, xs_j, n=200, warmup=5)
        dt_b = min(dt_b, t)
        t, preds_v = _time(infer_vmap, st, xs_j, n=200, warmup=5)
        dt_v = min(dt_v, t)
    if not np.array_equal(np.asarray(preds_b), np.asarray(preds_v)):
        raise AssertionError("batched and vmap inference predictions diverge")
    speedup = dt_v / dt_b
    _emit("tm_infer_batch150", dt_b * 1e6,
          f"datapoints_per_s={150/dt_b:.0f}", datapoints_per_s=150 / dt_b)
    _emit("tm_infer_vmap150", dt_v * 1e6,
          f"datapoints_per_s={150/dt_v:.0f};batched_speedup={speedup:.2f}x;"
          f"bitwise_identical=1",
          datapoints_per_s=150 / dt_v, batched_speedup=speedup,
          bitwise_identical=True)

    # --- online serving drain: chunked _consume_many vs per-point consume ---
    def drain(chunk):
        sess = online_mod.OnlineSession(
            CFG, st, rt, buffer_capacity=128, chunk=chunk, seed=0
        )
        for i in range(128):
            sess.offer(xs[i % 150], int(ys[i % 150]))
        t0 = time.perf_counter()
        n = sess.learn_available(128)
        jax.block_until_ready(sess.ss.tm.ta_state)
        return (time.perf_counter() - t0) / max(n, 1)

    drain(16), drain(1)  # warm both traces so compile time stays untimed
    per_pt_chunked = drain(16)
    per_pt_single = drain(1)
    _emit("tm_online_drain128", per_pt_chunked * 1e6,
          f"datapoints_per_s={1/per_pt_chunked:.0f};"
          f"chunked_speedup={per_pt_single/per_pt_chunked:.2f}x",
          datapoints_per_s=1 / per_pt_chunked,
          chunked_speedup=per_pt_single / per_pt_chunked)

    # --- activity factor vs s (energy analogue), both s-policies ---
    # The paper: lower s => "bias away from issuing feedback" => lower power.
    # That holds under the `hardware` policy (all stochastic events ~ (s-1)/s)
    # and INVERTS under the software `standard` policy (erase ~ 1/s) — the
    # calibration evidence for DESIGN.md §2's s-semantics discussion.
    import dataclasses as _dc

    for policy in ("standard", "hardware"):
        cfgp = _dc.replace(CFG, s_policy=policy, boost_true_positive=False)
        parts = []
        activities = {}
        for s_val in (1.0, 1.375, 4.0):
            rt_s = tm_mod.init_runtime(cfgp, s=s_val, T=15)
            st2, aux = jax.jit(
                lambda s, k: fb.train_datapoints(cfgp, s, rt_s, xs_j, ys_j, k)
            )(st, key)
            act = float(np.mean(np.asarray(aux.activity)))
            parts.append(f"s={s_val}:{act:.4f}")
            activities[str(s_val)] = act
        _emit(f"tm_activity_vs_s_{policy}", 0.0, ";".join(parts),
              activity_by_s=activities)

    # --- hyperparameter-search acceleration (goal ii) ---
    osets, _ = blocks.iris_paper_sets(n_orderings=12)
    s_grid = [1.375, 2.0, 3.0, 4.0]
    T_grid = [5, 10, 15]
    t0 = time.time()
    res = hpsearch.grid_search(
        CFG, s_grid, T_grid,
        osets.offline_x, osets.offline_y,
        osets.validation_x, osets.validation_y,
        n_epochs=10,
    )
    jax.block_until_ready(res.val_accuracy)
    t_vmapped = time.time() - t0

    # sequential reference: one grid cell at a time (amortised estimate over
    # a subsample to keep CPU wall time sane)
    t0 = time.time()
    _ = hpsearch.grid_search(
        CFG, s_grid[:1], T_grid[:1],
        osets.offline_x[:1], osets.offline_y[:1],
        osets.validation_x[:1], osets.validation_y[:1],
        n_epochs=10,
    )
    t_one = (time.time() - t0)
    n_cells = len(s_grid) * len(T_grid) * 12
    best_s, best_T, best_acc = hpsearch.best(res)
    _emit("hpsearch_grid", t_vmapped * 1e6,
          f"cells={n_cells};vmapped_s={t_vmapped:.2f};"
          f"seq_est_s={t_one*n_cells:.2f};"
          f"speedup={t_one*n_cells/max(t_vmapped,1e-9):.1f}x;"
          f"best_s={best_s};best_T={best_T};best_val={best_acc:.3f}",
          cells=n_cells, vmapped_s=t_vmapped, seq_est_s=t_one * n_cells,
          speedup=t_one * n_cells / max(t_vmapped, 1e-9))

    out_path = os.environ.get("REPRO_BENCH_JSON", "BENCH_throughput.json")
    payload = {
        "benchmark": "throughput",
        "backend": CFG.backend,
        "jax_backend": jax.default_backend(),
        "results": RESULTS,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")


if __name__ == "__main__":
    main()
