"""Paper §6: performance — parallel TM datapath + hyperparameter search.

The FPGA updates all clauses/TAs in 2 clock cycles, one datapoint per clock.
The TPU/JAX analogue measured here:

* `tm_train_step`  — fused inference+feedback for ONE datapoint (all
  C x J x 2f TA lanes in parallel): wall time per datapoint + TA-updates/s.
* `tm_infer_batch` — batched inference throughput (datapoints/s).
* `hpsearch_grid`  — the paper's goal (ii): a (s x T x orderings) grid as a
  single vmapped program vs. the same grid run sequentially; the speedup is
  the replication-parallelism the FPGA gets from spatial hardware.
* `activity`       — fraction of TA lanes that actually flip per step (the
  clock-gating/energy analogue; lower s => sparser feedback => lower power,
  §5.1's "bias away from issuing feedback").
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import feedback as fb
from repro.core import hpsearch
from repro.core import tm as tm_mod
from repro.data import blocks, iris

CFG = common.CFG


def _time(fn, *args, n=5, warmup=2):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(n):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / n, out


def main():
    xs, ys = iris.load()
    xs_j, ys_j = jnp.asarray(xs), jnp.asarray(ys)
    rt = tm_mod.init_runtime(CFG, s=1.375, T=15)
    st = tm_mod.init_state(CFG, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    # --- single-datapoint fused train step (the 2-cycle datapath) ---
    step = jax.jit(lambda s, x, y, k: fb.train_step(CFG, s, rt, x, y, k))
    dt, _ = _time(step, st, xs_j[0], ys_j[0], key, n=20)
    ta_lanes = CFG.max_classes * CFG.max_clauses * CFG.n_literals
    print(f"tm_train_step,{dt*1e6:.1f},"
          f"datapoints_per_s={1/dt:.0f};ta_lanes_per_step={ta_lanes};"
          f"ta_updates_per_s={ta_lanes/dt:.2e}")

    # --- streamed epoch (150 datapoints serially, hardware row order) ---
    epoch = jax.jit(lambda s, k: fb.train_datapoints(CFG, s, rt, xs_j, ys_j, k))
    dt, (_, aux) = _time(epoch, st, key, n=3)
    print(f"tm_train_epoch150,{dt*1e6:.0f},"
          f"datapoints_per_s={150/dt:.0f}")

    # --- batched inference ---
    infer = jax.jit(lambda s, x: tm_mod.predict_batch(CFG, s, rt, x))
    dt, _ = _time(infer, st, xs_j, n=10)
    print(f"tm_infer_batch150,{dt*1e6:.0f},"
          f"datapoints_per_s={150/dt:.0f}")

    # --- activity factor vs s (energy analogue), both s-policies ---
    # The paper: lower s => "bias away from issuing feedback" => lower power.
    # That holds under the `hardware` policy (all stochastic events ~ (s-1)/s)
    # and INVERTS under the software `standard` policy (erase ~ 1/s) — the
    # calibration evidence for DESIGN.md §2's s-semantics discussion.
    import dataclasses as _dc

    for policy in ("standard", "hardware"):
        cfgp = _dc.replace(CFG, s_policy=policy, boost_true_positive=False)
        parts = []
        for s_val in (1.0, 1.375, 4.0):
            rt_s = tm_mod.init_runtime(cfgp, s=s_val, T=15)
            st2, aux = jax.jit(
                lambda s, k: fb.train_datapoints(cfgp, s, rt_s, xs_j, ys_j, k)
            )(st, key)
            parts.append(
                f"s={s_val}:{float(np.mean(np.asarray(aux.activity))):.4f}")
        print(f"tm_activity_vs_s_{policy},0,{';'.join(parts)}")

    # --- hyperparameter-search acceleration (goal ii) ---
    osets, _ = blocks.iris_paper_sets(n_orderings=12)
    s_grid = [1.375, 2.0, 3.0, 4.0]
    T_grid = [5, 10, 15]
    t0 = time.time()
    res = hpsearch.grid_search(
        CFG, s_grid, T_grid,
        osets.offline_x, osets.offline_y,
        osets.validation_x, osets.validation_y,
        n_epochs=10,
    )
    jax.block_until_ready(res.val_accuracy)
    t_vmapped = time.time() - t0

    # sequential reference: one grid cell at a time (amortised estimate over
    # a subsample to keep CPU wall time sane)
    t0 = time.time()
    _ = hpsearch.grid_search(
        CFG, s_grid[:1], T_grid[:1],
        osets.offline_x[:1], osets.offline_y[:1],
        osets.validation_x[:1], osets.validation_y[:1],
        n_epochs=10,
    )
    t_one = (time.time() - t0)
    n_cells = len(s_grid) * len(T_grid) * 12
    best_s, best_T, best_acc = hpsearch.best(res)
    print(f"hpsearch_grid,{t_vmapped*1e6:.0f},"
          f"cells={n_cells};vmapped_s={t_vmapped:.2f};"
          f"seq_est_s={t_one*n_cells:.2f};"
          f"speedup={t_one*n_cells/max(t_vmapped,1e-9):.1f}x;"
          f"best_s={best_s};best_T={best_T};best_val={best_acc:.3f}")


if __name__ == "__main__":
    main()
