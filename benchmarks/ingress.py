"""Fleet ingress benchmark: routed batch ingress vs per-point offers.

Measures the BatchRouter path (``TMService.submit`` staging + packed
``[K, B_ingress]`` block flushes — one jitted dispatch per flush) against
the pre-redesign per-point path (one jitted enqueue dispatch per
datapoint, transcribed below), asserting the ring buffers land bitwise
identical under both. This is the ROADMAP's "Fleet-scale ingress" item:
heavy-traffic serving is dispatch-bound on the producer side, so the win
is roughly the ingress block size.

Machine-readable results go to ``BENCH_ingress.json`` (override with env
``REPRO_BENCH_INGRESS_JSON``). The headline field is
``results[ingress_routed].speedup`` — routed offers/s must stay >= 4x
over the looped per-point path at K = 8 (gated in CI).
"""
from __future__ import annotations

import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import init_runtime, init_state
from repro.data import iris
from repro.serve import ServiceConfig, TMService

CFG = common.CFG

RESULTS: list[dict] = []


@partial(jax.jit, static_argnums=0)
def _offer_point(cfg, ss, xs, ys, mask):
    """The pre-redesign ingress: ONE device dispatch per datapoint."""
    from repro.data import buffer as buf_mod

    def push_one(buf_r, x, y, m):
        new_buf, ok = buf_mod.push(buf_r, x, y)
        buf = jax.tree.map(lambda a, b: jnp.where(m, a, b), new_buf, buf_r)
        return buf, ok & m

    bufs, oks = jax.vmap(push_one)(ss.buf, xs, ys, mask)
    return ss._replace(buf=bufs), oks


def ingress_bench(K: int = 8, n_points: int = 256, block: int = 32,
                  trials: int = 5, *, cfg=None, data=None, rt=None) -> dict:
    """offers/s: routed staging+flush vs per-point dispatch; bitwise check.

    Defaults measure the iris machine; ``cfg``/``data=(xs, ys)``/``rt``
    parameterize the same protocol over other workloads (benchmarks/scale.py
    runs it at MNIST widths) so the per-point baseline lives in ONE place.
    Overriding ``cfg`` requires ``rt`` — the default runtime's s/T are
    iris-calibrated and would silently miscalibrate another machine.
    """
    if cfg is not None and rt is None:
        raise ValueError("pass rt= when overriding cfg= (default s/T are "
                         "iris-calibrated)")
    cfg = CFG if cfg is None else cfg
    xs, ys = iris.load() if data is None else data
    rt = init_runtime(cfg, s=3.0, T=15) if rt is None else rt
    # distinct per-replica streams (row rotations), n_points each
    rows = np.stack([np.roll(np.arange(len(xs)), -7 * r)[
        np.arange(n_points) % len(xs)] for r in range(K)])   # [K, n]
    feed_x = np.asarray(xs)[rows]                            # [K, n, f]
    feed_y = np.asarray(ys)[rows].astype(np.int32)           # [K, n]
    full_mask = jnp.ones((K,), dtype=bool)

    def make_service():
        return TMService(cfg, init_state(cfg), ServiceConfig(
            replicas=K, buffer_capacity=n_points, chunk=16,
            ingress_block=block, seed=list(range(K)),
        ), rt=rt)

    def run_routed(svc):
        for i in range(n_points):
            svc.submit_rows(feed_x[:, i], feed_y[:, i])
        svc.flush()
        jax.block_until_ready(svc.ss.buf.data_x)

    def run_per_point(svc):
        ss = svc.ss
        for i in range(n_points):
            ss, _ = _offer_point(cfg, ss, jnp.asarray(feed_x[:, i]),
                                 jnp.asarray(feed_y[:, i]), full_mask)
        svc.ss = ss
        jax.block_until_ready(svc.ss.buf.data_x)

    # warm both paths (compile) + bitwise equivalence of the landed buffers
    warm_r, warm_p = make_service(), make_service()
    run_routed(warm_r)
    run_per_point(warm_p)
    for name in ("data_x", "data_y", "head", "size"):
        a = np.asarray(getattr(warm_r.ss.buf, name))
        b = np.asarray(getattr(warm_p.ss.buf, name))
        if not np.array_equal(a, b):
            raise AssertionError(
                f"routed ingress diverged from per-point offers ({name})"
            )

    # timed: interleave so background host load skews both paths equally
    t_routed, t_point = float("inf"), float("inf")
    for _ in range(trials):
        svc = make_service()
        t0 = time.perf_counter()
        run_routed(svc)
        t_routed = min(t_routed, time.perf_counter() - t0)

        svc = make_service()
        t0 = time.perf_counter()
        run_per_point(svc)
        t_point = min(t_point, time.perf_counter() - t0)

    offers = K * n_points
    return {
        "n_replicas": K,
        "points_per_replica": n_points,
        "ingress_block": block,
        "wall_s_routed": t_routed,
        "wall_s_per_point": t_point,
        "speedup": t_point / t_routed,
        "offers_per_s_routed": offers / t_routed,
        "offers_per_s_per_point": offers / t_point,
        "device_dispatches_routed": int(np.ceil(n_points / block)),
        "device_dispatches_per_point": n_points,
        "bitwise_identical": True,
    }


def main():
    RESULTS.clear()
    for K in (2, 8):
        row = ingress_bench(K=K)
        name = "ingress_routed" if K == 8 else f"ingress_routed_k{K}"
        print(
            f"{name},{row['wall_s_routed'] * 1e6:.1f},"
            f"K={K};points={row['points_per_replica']};"
            f"offers_per_s={row['offers_per_s_routed']:.0f};"
            f"per_point_s={row['wall_s_per_point']:.4f};"
            f"speedup={row['speedup']:.2f}x;bitwise_identical=1"
        )
        RESULTS.append({"name": name, **row})

    out_path = os.environ.get("REPRO_BENCH_INGRESS_JSON",
                              "BENCH_ingress.json")
    payload = {
        "benchmark": "ingress",
        "backend": CFG.backend,
        "jax_backend": jax.default_backend(),
        "results": RESULTS,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")
    return payload


if __name__ == "__main__":
    main()
