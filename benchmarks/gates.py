"""Declarative CI benchmark gates: one table, one evaluator.

Every speedup floor, bitwise flag and SLO ceiling that used to live as a
copy-pasted inline ``python - <<'EOF'`` block in ``.github/workflows/
ci.yml`` is a ROW in :data:`GATES` — adding a gate is a one-line table
edit, and every job invokes the same evaluator::

    python -m benchmarks.gates BENCH_crossval.json [more.json ...]

The file's ``benchmark`` field selects its gate list. Gate rows are
plain dicts; the supported keys (combine freely on one row):

``row``
    Row name to check, or ``"*"`` for every row in the file. A row spec
    with ONLY ``row`` asserts existence.
``flag`` / ``flags``
    Field name(s) that must be truthy on the selected row(s).
``metric`` + ``floor`` / ``ceiling`` / ``equals``
    Numeric bound(s) on one field of the selected row(s).
``metric`` + ``at_least_row`` [+ ``at_least_metric``]
    Cross-row comparison: the row's metric must be >= another row's
    (same metric unless ``at_least_metric`` names a different one).
``rows_exactly`` / ``rows_at_least``
    Row-count invariants for the whole file.
``reason``
    Free-text shown on failure (the old inline blocks' messages).

Exit status is nonzero on ANY failed gate; every check prints one line
so CI logs keep the old blocks' readability.
"""

from __future__ import annotations

import json
import sys

GATES: dict[str, list[dict]] = {
    "crossval": [
        dict(
            row="crossval_sweep",
            flag="bitwise_identical",
            reason="engine diverged from baseline",
        ),
        dict(
            row="crossval_sweep",
            metric="speedup",
            floor=2.0,
            reason="sweep speedup regressed",
        ),
        dict(
            row="crossval_analyze_fused",
            flag="bitwise_identical",
            reason="fused 3-set analysis diverged",
        ),
    ],
    "fleet": [
        dict(
            row="fleet_drain",
            flag="bitwise_identical",
            reason="fleet diverged from serial sessions",
        ),
        dict(
            row="fleet_drain",
            metric="speedup",
            floor=2.0,
            reason="fleet drain speedup regressed (K=8)",
        ),
    ],
    "ingress": [
        dict(
            row="ingress_routed",
            flag="bitwise_identical",
            reason="routed ingress diverged from per-point offers",
        ),
        dict(
            row="ingress_routed",
            metric="speedup",
            floor=4.0,
            reason="ingress speedup regressed (K=8)",
        ),
    ],
    "scale": [
        dict(
            row="*",
            flag="bitwise_identical",
            reason="ref<->pallas parity or path equivalence broke",
        ),
        dict(
            row="scale_batch_infer_f784",
            metric="speedup",
            at_least_row="scale_batch_infer_f16",
            reason="batch-infer scale path narrowed from f=16 to f=784",
        ),
        dict(
            row="scale_sweep_f784",
            metric="speedup",
            at_least_row="scale_sweep_f16",
            reason="sweep scale path narrowed from f=16 to f=784",
        ),
        dict(
            row="scale_packed_infer_f784",
            metric="speedup_pallas",
            floor=2.0,
            reason="packed datapath regressed vs unpacked at f=784",
        ),
    ],
    "residency": [
        dict(
            row="*",
            flag="bitwise_identical",
            reason="residency fleet diverged from always-resident twin",
        ),
        dict(
            row="residency_k1024",
            metric="devices",
            equals=4,
            reason="mesh forcing failed",
        ),
        dict(
            row="residency_k1024",
            metric="trained_per_s",
            floor=10.0,
            reason="K=1024 adapt throughput collapsed",
        ),
        dict(
            row="residency_k4096",
            reason="K=4096 point missing",
        ),
        dict(
            row="residency_k4096",
            metric="speedup_vs_percohort",
            floor=1.5,
            reason="batched moves lost their win vs per-cohort at K=4096",
        ),
    ],
    "tunable": [
        dict(
            rows_exactly=24,
            reason="budget sweep row count changed",
        ),
        dict(
            row="*",
            flag="bitwise_at_full_budget",
            reason="full-budget pruned serve drifted from plain path",
        ),
        dict(
            row="tunable_mnist-f784_pallas_b0p25",
            metric="speedup_vs_full",
            floor=2.0,
            reason="pallas f=784 budget=25% speedup under floor",
        ),
        dict(
            row="tunable_mnist-f784_pallas_b0p25",
            metric="accuracy_drop",
            ceiling=0.02,
            reason="pallas f=784 budget=25% accuracy drop over ceiling",
        ),
    ],
    "traffic": [
        dict(
            rows_at_least=3,
            reason="scenario schedule went missing",
        ),
        dict(
            row="*",
            flags=("consistent_with_replay", "conserved"),
            reason="threaded run diverged from replay or lost offers",
        ),
        dict(
            row="*",
            metric="serve_p99_s",
            ceiling=1.0,
            reason="p99 serve latency over the 1000ms ceiling",
        ),
        dict(
            row="traffic_steady",
            metric="offers_per_s",
            floor=20.0,
            reason="steady sustained offer rate collapsed",
        ),
    ],
}


class GateFailure(AssertionError):
    pass


def _select(rows: dict[str, dict], spec: dict) -> list[tuple[str, dict]]:
    name = spec["row"]
    if name == "*":
        return sorted(rows.items())
    if name not in rows:
        why = spec.get("reason", "required row")
        raise GateFailure(f"row '{name}' missing: {why}")
    return [(name, rows[name])]


def _check_counts(rows: dict[str, dict], spec: dict) -> list[str]:
    reason = spec.get("reason", "")
    if "rows_exactly" in spec:
        want = spec["rows_exactly"]
        if len(rows) != want:
            raise GateFailure(f"expected {want} rows, got {len(rows)}: {reason}")
        return [f"row count == {want}"]
    want = spec["rows_at_least"]
    if len(rows) < want:
        raise GateFailure(f"expected >= {want} rows, got {len(rows)}: {reason}")
    return [f"row count {len(rows)} >= {want}"]


def _check_metric(rows, name: str, row: dict, spec: dict) -> list[str]:
    reason = spec.get("reason", "")
    metric = spec["metric"]
    if metric not in row:
        raise GateFailure(f"{name}.{metric} missing: {reason}")
    v = row[metric]
    if "floor" in spec and not v >= spec["floor"]:
        bound = spec["floor"]
        raise GateFailure(f"{name}.{metric} = {v:.3g} < {bound:.3g} floor: {reason}")
    if "ceiling" in spec and not v <= spec["ceiling"]:
        bound = spec["ceiling"]
        raise GateFailure(f"{name}.{metric} = {v:.3g} > {bound:.3g} ceiling: {reason}")
    if "equals" in spec and v != spec["equals"]:
        want = spec["equals"]
        raise GateFailure(f"{name}.{metric} = {v!r} != {want!r}: {reason}")
    if "at_least_row" in spec:
        other = spec["at_least_row"]
        om = spec.get("at_least_metric", metric)
        if other not in rows:
            raise GateFailure(f"comparison row '{other}' missing: {reason}")
        ov = rows[other][om]
        if not v >= ov:
            msg = f"{name}.{metric} = {v:.3g} < {other}.{om} = {ov:.3g}"
            raise GateFailure(f"{msg}: {reason}")
        return [f"{name}.{metric} {v:.3g} >= {other}.{om} {ov:.3g}"]
    shown = f"{v:.4g}" if isinstance(v, float) else str(v)
    return [f"{name}.{metric} = {shown}"]


def _check_one(rows: dict[str, dict], spec: dict) -> list[str]:
    """Evaluate one gate row; returns human lines, raises GateFailure."""
    if "rows_exactly" in spec or "rows_at_least" in spec:
        return _check_counts(rows, spec)
    reason = spec.get("reason", "")
    out = []
    flags = tuple(spec.get("flags", ()))
    if "flag" in spec:
        flags = (spec["flag"],) + flags
    for name, row in _select(rows, spec):
        for flag in flags:
            if not row.get(flag):
                raise GateFailure(f"{name}.{flag} is not set: {reason}")
            out.append(f"{name}.{flag} ok")
        if "metric" in spec:
            out.extend(_check_metric(rows, name, row, spec))
        if not flags and "metric" not in spec:
            out.append(f"{name} present")
    return out


def check_file(path: str) -> int:
    """Gate one BENCH_*.json; returns the number of failures (printed)."""
    with open(path) as f:
        payload = json.load(f)
    bench = payload.get("benchmark")
    if bench not in GATES:
        print(f"FAIL {path}: no gates for benchmark {bench!r} — add it to GATES")
        return 1
    rows = {r["name"]: r for r in payload["results"]}
    failures = 0
    for spec in GATES[bench]:
        try:
            for line in _check_one(rows, spec):
                print(f"  ok: {line}")
        except GateFailure as e:
            failures += 1
            print(f"  FAIL: {e}")
    status = "FAIL" if failures else "ok"
    print(f"{status} {path}: {len(GATES[bench])} gates, {failures} failed")
    return failures


def main(argv: list[str]) -> int:
    if not argv:
        print("usage: python -m benchmarks.gates BENCH_x.json [...]")
        return 2
    return 1 if sum(check_file(p) for p in argv) else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
