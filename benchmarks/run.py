"""Benchmark orchestrator. One entry per paper table/figure; prints
``name,us_per_call,derived`` CSV rows (plus per-figure accuracy curves).

Env knobs:
  REPRO_BENCH_ORDERINGS   cross-validation orderings (default 24; paper 120)
"""
from __future__ import annotations

import os
import sys
import time
import traceback


def main() -> None:
    n_ord = int(os.environ.get("REPRO_BENCH_ORDERINGS", "24"))
    print(f"# benchmarks (orderings={n_ord}); csv: name,us_per_call,derived")
    ok = True

    t0 = time.time()
    from benchmarks import crossval as crossval_bench
    from benchmarks import fig4_limited_data, fig567_class_intro, fig89_faults
    from benchmarks import fleet as fleet_bench
    from benchmarks import throughput

    for name, fn in [
        ("fig4", lambda: fig4_limited_data.main(n_ord)),
        ("fig567", lambda: fig567_class_intro.main(n_ord)),
        ("fig89", lambda: fig89_faults.main(n_ord)),
        ("throughput", throughput.main),
        ("crossval", lambda: crossval_bench.main(n_ord)),
        ("fleet", fleet_bench.main),
    ]:
        try:
            fn()
        except Exception:
            ok = False
            print(f"{name},0,ERROR")
            traceback.print_exc()

    print(f"# total wall: {time.time()-t0:.1f}s")
    if not ok:
        sys.exit(1)


if __name__ == "__main__":
    main()
