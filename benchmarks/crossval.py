"""Replica-parallel cross-validation engine benchmark (paper goal ii, §5).

Measures the fused sweep program (repro.eval.crossval.CrossValRun) against
the pre-engine vmap-of-scan paths it replaced, asserting bit-identical
results every run:

* ``crossval_sweep``  — the (s x T x orderings) hyperparameter sweep:
  engine vs the legacy ``hpsearch.grid_search_device`` nested-vmap program.
* ``crossval_system`` — the Fig-3 system flow over all orderings:
  engine vs ``vmap(manager.run_system)`` (the old ``run_orderings`` body).

Every row is written machine-readable to ``BENCH_crossval.json`` (override
with env ``REPRO_BENCH_CROSSVAL_JSON``) so the sweep speedup is tracked
across PRs next to BENCH_throughput.json. The headline field is
``results[crossval_sweep].speedup`` — the replica-parallel engine must stay
>= 2x over the vmap-of-scan baseline on CPU.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import hpsearch
from repro.core import manager as mgr
from repro.core import init_runtime, init_state
from repro.data import blocks
from repro.eval.crossval import CrossValRun

CFG = common.CFG

RESULTS: list[dict] = []

S_GRID = (1.375, 2.0, 3.0)
T_GRID = (5, 10, 15)
N_EPOCHS = 10


def _min_time(fn, *, trials=3, inner=1):
    """Min seconds/call over interleave-friendly trials (first call warms)."""
    out = jax.block_until_ready(fn())
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for _ in range(inner):
            out = jax.block_until_ready(fn())
        best = min(best, (time.perf_counter() - t0) / inner)
    return best, out


def _emit(name: str, us_per_call: float, derived: str, **extra):
    print(f"{name},{us_per_call:.1f},{derived}")
    RESULTS.append({"name": name, "us_per_call": us_per_call, **extra})


def sweep_bench(n_orderings: int, seed: int = 0, *, cfg=None, osets=None,
                s_values=S_GRID, T_values=T_GRID,
                n_epochs=N_EPOCHS) -> dict:
    """Engine vs legacy nested-vmap sweep; bitwise equality asserted.

    Defaults measure the iris machine; ``cfg``/``osets`` parameterize the
    same protocol over other workloads (benchmarks/scale.py runs it at
    MNIST widths) so the legacy-baseline semantics live in ONE place.
    """
    cfg = CFG if cfg is None else cfg
    if osets is None:
        osets, _ = blocks.iris_paper_sets(n_orderings=n_orderings)
    off = (jnp.asarray(osets.offline_x), jnp.asarray(osets.offline_y))
    val = (jnp.asarray(osets.validation_x), jnp.asarray(osets.validation_y))
    keys = jax.random.split(jax.random.PRNGKey(seed), n_orderings)
    s_grid = jnp.asarray(s_values, jnp.float32)
    T_grid = jnp.asarray(T_values, jnp.int32)

    def legacy():
        return hpsearch.grid_search_device(
            cfg, s_grid, T_grid, off, val, keys, n_epochs
        )

    run = CrossValRun(cfg)

    def engine():
        return run.sweep(
            *off, *val, s_values, T_values, n_epochs=n_epochs, seed=seed
        ).val_accuracy

    # Interleave so background host load skews both paths equally.
    t_eng, t_leg = float("inf"), float("inf")
    acc_eng = acc_leg = None
    for _ in range(3):
        t, acc_eng = _min_time(engine, trials=1)
        t_eng = min(t_eng, t)
        t, acc_leg = _min_time(legacy, trials=1)
        t_leg = min(t_leg, t)
    if not np.array_equal(np.asarray(acc_eng), np.asarray(acc_leg)):
        raise AssertionError(
            "replica-parallel sweep diverges from the vmap-of-scan baseline"
        )

    R = len(s_values) * len(T_values) * n_orderings
    return {
        "cells": R,
        "replicas": R,
        "wall_s_engine": t_eng,
        "wall_s_legacy_vmap": t_leg,
        "speedup": t_leg / t_eng,
        "replicas_per_s": R / t_eng,
        "bitwise_identical": True,
    }


def system_bench(n_orderings: int, n_cycles: int = 16, seed: int = 0) -> dict:
    """Engine vs vmap(run_system) over orderings; bitwise equality asserted."""
    sets, O = common.build_sets(n_orderings)
    sys_cfg = mgr.SystemConfig(n_offline_epochs=N_EPOCHS, n_online_cycles=n_cycles)
    schedule = mgr.make_schedule(online_s=1.0)
    rt = init_runtime(CFG, s=1.375, T=15)
    states = jax.vmap(lambda _: init_state(CFG))(jnp.arange(O))
    keys = jax.random.split(jax.random.PRNGKey(seed), O)

    legacy_fn = jax.vmap(
        lambda st, ss, k: mgr.run_system(CFG, sys_cfg, st, rt, ss, schedule, k)
    )
    def legacy():
        return legacy_fn(states, sets, keys)[1]

    run = CrossValRun(CFG)

    def engine():
        return run.system(sys_cfg, states, rt, sets, schedule, keys).accuracies

    t_eng, t_leg = float("inf"), float("inf")
    acc_eng = acc_leg = None
    for _ in range(3):
        t, acc_eng = _min_time(engine, trials=1)
        t_eng = min(t_eng, t)
        t, acc_leg = _min_time(legacy, trials=1)
        t_leg = min(t_leg, t)
    if not np.array_equal(np.asarray(acc_eng), np.asarray(acc_leg)):
        raise AssertionError(
            "replica-parallel system run diverges from vmap(run_system)"
        )

    return {
        "orderings": O,
        "n_cycles": n_cycles,
        "wall_s_engine": t_eng,
        "wall_s_legacy_vmap": t_leg,
        "speedup": t_leg / t_eng,
        "replicas_per_s": O / t_eng,
        "bitwise_identical": True,
    }


def analyze_fused_bench(n_orderings: int, grid: int = 9, seed: int = 0) -> dict:
    """Fused single-contraction 3-set analysis vs three analyze_replicated
    calls (the per-cycle analysis block of the system path); bitwise
    equality asserted."""
    from functools import partial

    from repro.core import accuracy as acc_mod
    from repro.eval.crossval import grid_layout, replicate_state

    sets, O = common.build_sets(n_orderings)
    R = grid * O
    s_rep, T_rep = grid_layout(
        jnp.linspace(1.375, 3.0, grid), (15,), O
    )
    rt = init_runtime(CFG)._replace(s=s_rep, T=T_rep)
    states = replicate_state(CFG, R)
    triple = [
        (sets.offline_x, sets.offline_y, sets.offline_valid),
        (sets.validation_x, sets.validation_y, sets.validation_valid),
        (sets.online_x, sets.online_y, sets.online_valid),
    ]

    @partial(jax.jit, static_argnums=0)
    def fused(cfg, st, r):
        return acc_mod.analyze_sets_replicated(cfg, st, r, triple)

    @partial(jax.jit, static_argnums=0)
    def separate(cfg, st, r):
        return jnp.stack(
            [acc_mod.analyze_replicated(cfg, st, r, x, y, v)
             for x, y, v in triple],
            axis=-1,
        )

    t_fused, out_fused = _min_time(lambda: fused(CFG, states, rt), trials=5)
    t_sep, out_sep = _min_time(lambda: separate(CFG, states, rt), trials=5)
    if not np.array_equal(np.asarray(out_fused), np.asarray(out_sep)):
        raise AssertionError(
            "fused 3-set analysis diverges from three separate calls"
        )
    return {
        "replicas": R,
        "orderings": O,
        "wall_s_fused": t_fused,
        "wall_s_separate": t_sep,
        "speedup": t_sep / t_fused,
        "bitwise_identical": True,
    }


def main(n_orderings: int = 24):
    RESULTS.clear()

    row = sweep_bench(n_orderings)
    _emit(
        "crossval_sweep", row["wall_s_engine"] * 1e6,
        f"cells={row['cells']};replicas_per_s={row['replicas_per_s']:.1f};"
        f"legacy_s={row['wall_s_legacy_vmap']:.3f};"
        f"speedup={row['speedup']:.2f}x;bitwise_identical=1",
        **row,
    )

    row = system_bench(n_orderings)
    _emit(
        "crossval_system", row["wall_s_engine"] * 1e6,
        f"orderings={row['orderings']};"
        f"replicas_per_s={row['replicas_per_s']:.1f};"
        f"legacy_s={row['wall_s_legacy_vmap']:.3f};"
        f"speedup={row['speedup']:.2f}x;bitwise_identical=1",
        **row,
    )

    row = analyze_fused_bench(n_orderings)
    _emit(
        "crossval_analyze_fused", row["wall_s_fused"] * 1e6,
        f"replicas={row['replicas']};"
        f"separate_s={row['wall_s_separate']:.4f};"
        f"speedup={row['speedup']:.2f}x;bitwise_identical=1",
        **row,
    )

    out_path = os.environ.get("REPRO_BENCH_CROSSVAL_JSON", "BENCH_crossval.json")
    payload = {
        "benchmark": "crossval",
        "backend": CFG.backend,
        "jax_backend": jax.default_backend(),
        "grid": {"s": list(S_GRID), "T": list(T_GRID), "n_epochs": N_EPOCHS},
        "results": RESULTS,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")
    return payload


if __name__ == "__main__":
    main(int(os.environ.get("REPRO_BENCH_ORDERINGS", "24")))
