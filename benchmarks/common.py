"""Shared harness for the paper-figure benchmarks.

Builds the paper's iris setup (16 clauses, T=15, s=1.375 offline / 1.0
online, 10 offline epochs, sets 30/60/60, offline limited to 20 rows) and
runs all cross-validation orderings as ONE vmapped program.

Every flow is dataset-parametric: ``dataset="mnist"`` swaps in the
booleanized MNIST-scale digit workload (f = side**2 boolean inputs, 10
classes, same 150-row/5-block CV geometry) with the ``tm_mnist`` preset —
no host-side reshaping anywhere downstream, the datapath width just
changes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tm_iris import CONFIG as TM_SYS
from repro.configs.tm_iris import TMSystemParams
from repro.core import init_runtime, init_state
from repro.core import manager as mgr
from repro.data import blocks

CFG = TM_SYS.tm


def _dataset(dataset: str):
    """ONE dispatch point per dataset: (params_fn(side), sets_fn(n, side))."""
    if dataset == "iris":
        return (lambda side: TM_SYS,
                lambda n, side: blocks.iris_paper_sets(n_orderings=n))
    if dataset == "mnist":
        from repro.configs import tm_mnist

        return (lambda side: tm_mnist.config_for_side(
                    tm_mnist.SIDE if side is None else side),
                lambda n, side: blocks.mnist_paper_sets(
                    n_orderings=n, side=side))
    raise ValueError(f"unknown dataset {dataset!r} (iris | mnist)")


def system_params(dataset: str = "iris", side: int | None = None) -> TMSystemParams:
    """The per-dataset system preset (iris default; mnist at ``side``)."""
    return _dataset(dataset)[0](side)


def build_sets(n_orderings: int, offline_limit: int | None = 20,
               dataset: str = "iris", side: int | None = None):
    """Stacked per-ordering Sets + keys (leading axis = ordering)."""
    osets, _spec = _dataset(dataset)[1](n_orderings, side)
    O, n_off = osets.offline_y.shape
    train_valid = np.ones((O, n_off), dtype=bool)
    if offline_limit is not None:
        train_valid[:, offline_limit:] = False  # §5.1: train on 20 of 30
    sets = mgr.Sets(
        offline_x=jnp.asarray(osets.offline_x),
        offline_y=jnp.asarray(osets.offline_y),
        offline_valid=jnp.ones((O, n_off), dtype=bool),  # analyze all 30
        validation_x=jnp.asarray(osets.validation_x),
        validation_y=jnp.asarray(osets.validation_y),
        validation_valid=jnp.ones(osets.validation_y.shape, dtype=bool),
        online_x=jnp.asarray(osets.online_x),
        online_y=jnp.asarray(osets.online_y),
        online_valid=jnp.ones(osets.online_y.shape, dtype=bool),
        offline_train_valid=jnp.asarray(train_valid),
    )
    return sets, O


def run_schedule(schedule, *, n_orderings=24, n_cycles=16,
                 offline_limit: int | None = 20, seed=0,
                 dataset: str = "iris", side: int | None = None):
    """Mean accuracy curves [1+n_cycles, 3] over orderings + wall time.

    Thin caller of the replica-parallel engine: every ordering's Fig-3 run
    advances in one fused plane per datapoint (repro.eval.crossval).
    """
    from repro.eval.crossval import CrossValRun

    params = system_params(dataset, side)
    cfg = params.tm
    sets, O = build_sets(n_orderings, offline_limit, dataset, side)
    sys_cfg = mgr.SystemConfig(
        n_offline_epochs=params.n_offline_epochs, n_online_cycles=n_cycles
    )
    rt = init_runtime(cfg, s=params.s_offline, T=params.T)
    states = jax.vmap(lambda _: init_state(cfg))(jnp.arange(O))
    keys = jax.random.split(jax.random.PRNGKey(seed), O)

    res = CrossValRun(cfg).system(sys_cfg, states, rt, sets, schedule, keys)
    accs = np.asarray(res.accuracies)    # [O, 1+n_cycles, 3]
    activity = np.asarray(res.activity)  # [O, n_cycles]
    return accs.mean(axis=0), activity.mean(axis=0), res.wall_s, O


def curve_csv(name: str, curve: np.ndarray) -> str:
    """accuracy curve -> csv rows `name,cycle,offline,validation,online`."""
    rows = []
    for i, (a, b, c) in enumerate(curve):
        rows.append(f"{name},{i},{a:.4f},{b:.4f},{c:.4f}")
    return "\n".join(rows)
