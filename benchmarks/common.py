"""Shared harness for the paper-figure benchmarks.

Builds the paper's iris setup (16 clauses, T=15, s=1.375 offline / 1.0
online, 10 offline epochs, sets 30/60/60, offline limited to 20 rows) and
runs all cross-validation orderings as ONE vmapped program.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.tm_iris import CONFIG as TM_SYS
from repro.core import init_runtime, init_state
from repro.core import manager as mgr
from repro.data import blocks

CFG = TM_SYS.tm


def build_sets(n_orderings: int, offline_limit: int | None = 20):
    """Stacked per-ordering Sets + keys (leading axis = ordering)."""
    osets, _spec = blocks.iris_paper_sets(n_orderings=n_orderings)
    O, n_off = osets.offline_y.shape
    train_valid = np.ones((O, n_off), dtype=bool)
    if offline_limit is not None:
        train_valid[:, offline_limit:] = False  # §5.1: train on 20 of 30
    sets = mgr.Sets(
        offline_x=jnp.asarray(osets.offline_x),
        offline_y=jnp.asarray(osets.offline_y),
        offline_valid=jnp.ones((O, n_off), dtype=bool),  # analyze all 30
        validation_x=jnp.asarray(osets.validation_x),
        validation_y=jnp.asarray(osets.validation_y),
        validation_valid=jnp.ones(osets.validation_y.shape, dtype=bool),
        online_x=jnp.asarray(osets.online_x),
        online_y=jnp.asarray(osets.online_y),
        online_valid=jnp.ones(osets.online_y.shape, dtype=bool),
        offline_train_valid=jnp.asarray(train_valid),
    )
    return sets, O


def run_schedule(schedule, *, n_orderings=24, n_cycles=16,
                 offline_limit: int | None = 20, seed=0):
    """Mean accuracy curves [1+n_cycles, 3] over orderings + wall time.

    Thin caller of the replica-parallel engine: every ordering's Fig-3 run
    advances in one fused plane per datapoint (repro.eval.crossval).
    """
    from repro.eval.crossval import CrossValRun

    sets, O = build_sets(n_orderings, offline_limit)
    sys_cfg = mgr.SystemConfig(
        n_offline_epochs=TM_SYS.n_offline_epochs, n_online_cycles=n_cycles
    )
    rt = init_runtime(CFG, s=TM_SYS.s_offline, T=TM_SYS.T)
    states = jax.vmap(lambda _: init_state(CFG))(jnp.arange(O))
    keys = jax.random.split(jax.random.PRNGKey(seed), O)

    res = CrossValRun(CFG).system(sys_cfg, states, rt, sets, schedule, keys)
    accs = np.asarray(res.accuracies)    # [O, 1+n_cycles, 3]
    activity = np.asarray(res.activity)  # [O, n_cycles]
    return accs.mean(axis=0), activity.mean(axis=0), res.wall_s, O


def curve_csv(name: str, curve: np.ndarray) -> str:
    """accuracy curve -> csv rows `name,cycle,offline,validation,online`."""
    rows = []
    for i, (a, b, c) in enumerate(curve):
        rows.append(f"{name},{i},{a:.4f},{b:.4f},{c:.4f}")
    return "\n".join(rows)
