"""Concurrent-producer SLO traffic benchmark (DESIGN.md §14).

Drives the three standard scenario schedules (steady, bursty+drift,
fault-injected — serve/traffic.py) through a live :class:`TMService`
with one producer thread per replica, and reports the numbers a managed
online-learning service is judged by: sustained offers/s and p50/p99
submit/serve latency under real producer/consumer lock contention.

Every threaded run is then replayed through a fresh identical service
from a single thread and the final TA banks / RNG keys / policy state
compared bit for bit (``consistent_with_replay`` — the whole-system
parity oracle). A run that diverges aborts the benchmark: throughput
numbers from a service that computes different answers under threading
are not results.

Machine-readable results go to ``BENCH_traffic.json`` (override with env
``REPRO_BENCH_TRAFFIC_JSON``; ``REPRO_BENCH_TRAFFIC_POINTS`` and
``REPRO_BENCH_TRAFFIC_PRODUCERS`` size the load). CI gates a floor on
the steady scenario's sustained offers/s and a ceiling on every
scenario's p99 serve latency.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks import common
from repro.core import init_state
from repro.data import iris
from repro.serve import ServiceConfig, TMService
from repro.serve.service import AdaptPolicy
from repro.serve.traffic import (
    SCENARIOS,
    Scenario,
    fingerprint,
    fingerprints_equal,
    make_scripts,
    replay_single_caller,
    run_threaded,
    slo_summary,
)

CFG = common.CFG

RESULTS: list[dict] = []


def _sized(sc: Scenario, points: int) -> Scenario:
    """``sc`` rescaled to ``points`` offers per producer (fault point and
    class-introduction/drift fractions keep their relative position)."""
    if points == sc.points:
        return sc
    fault_at = (None if sc.fault_at is None
                else max(1, int(sc.fault_at * points / sc.points)))
    return dataclasses.replace(sc, points=points, fault_at=fault_at)


def _make_service(K: int, seed: int = 0) -> TMService:
    xs, ys = iris.load()
    return TMService(CFG, init_state(CFG), ServiceConfig(
        replicas=K, buffer_capacity=512, chunk=32, ingress_block=32,
        s=3.0, T=15, seed=seed,
        policy=AdaptPolicy(analyze_every=64),
    ), eval_x=np.asarray(xs), eval_y=np.asarray(ys))


def traffic_bench(scenario: Scenario, K: int = 4, *, seed: int = 0,
                  pace: float = 1.0) -> dict:
    """One scenario: threaded run -> SLO summary + bitwise replay check."""
    xs, ys = iris.load()
    scripts = make_scripts(scenario, np.asarray(xs), np.asarray(ys),
                           CFG.max_classes, K, seed=seed)
    live = _make_service(K, seed=seed)
    t0 = time.perf_counter()
    result = run_threaded(live, scripts, scenario=scenario, pace=pace)
    total_s = time.perf_counter() - t0

    twin = _make_service(K, seed=seed)
    replay_single_caller(twin, scripts, result, scenario=scenario)
    consistent = fingerprints_equal(fingerprint(live), fingerprint(twin))
    if not consistent:
        raise AssertionError(
            f"scenario {scenario.name!r}: threaded run diverged from its "
            "single-caller replay — threading changed WHAT was computed"
        )
    if not result.conserved():
        raise AssertionError(
            f"scenario {scenario.name!r}: offers not conserved "
            "(accepted + dropped != offers, or accepted != trained)"
        )
    row = slo_summary(result)
    row["total_s"] = total_s
    row["consistent_with_replay"] = consistent
    return row


def main():
    RESULTS.clear()
    points = int(os.environ.get("REPRO_BENCH_TRAFFIC_POINTS", "256"))
    K = int(os.environ.get("REPRO_BENCH_TRAFFIC_PRODUCERS", "4"))

    # Warm every jitted path (enqueue, drain, serve, analyze) at the
    # benchmark's shapes so no scenario's timing pays compilation.
    warm = _sized(dataclasses.replace(SCENARIOS["fault_injected"],
                                      fault_at=8), 16)
    traffic_bench(warm, K=K, pace=0.0)

    for name, sc in SCENARIOS.items():
        row = traffic_bench(_sized(sc, points), K=K)
        print(
            f"traffic_{name},{row['wall_s'] * 1e6:.1f},"
            f"producers={K};offers={row['offers']};"
            f"offers_per_s={row['offers_per_s']:.0f};"
            f"serve_p50_us={row['serve_p50_s'] * 1e6:.0f};"
            f"serve_p99_us={row['serve_p99_s'] * 1e6:.0f};"
            f"dropped={row['dropped']};rollbacks={row['rollbacks']};"
            f"consistent_with_replay=1"
        )
        RESULTS.append({"name": f"traffic_{name}", **row})

    out_path = os.environ.get("REPRO_BENCH_TRAFFIC_JSON",
                              "BENCH_traffic.json")
    payload = {
        "benchmark": "traffic",
        "backend": CFG.backend,
        "jax_backend": jax.default_backend(),
        "results": RESULTS,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")
    return payload


if __name__ == "__main__":
    main()
