"""Runtime-tunable serving benchmark: accuracy vs compute at fixed budgets.

Measures the DESIGN.md §16 budgeted serve path on a trained machine:
clauses are ranked by calibration vote contribution on the TRAIN split,
then the held-out split is served at budget in {100%, 50%, 25%, 12.5%}
through the compacted pruned kernels (4-bit calibration weights folded
into the vote). Per budget point: held-out accuracy, seconds/batch, and
speedup over the full (non-pruned) serve path — the accuracy-vs-speedup
curve a latency-pressured deployment trades along.

Workloads: the paper's iris machine (f = 16, 16 clauses) and the
MNIST-scale digit workload at f in {196, 784} with the over-provisioned
clause budget (128 clauses, §3.1.1 headroom — the regime where pruning
has redundancy to spend). Rankings are polarity-balanced (best positive
and negative clauses interleave — a plain score sort de-calibrates the
+-vote and costs 4-7 points at budget 25%). Both backends run; trials
interleave full/pruned calls and keep per-path minima so host noise
skews no path.

In-script asserts (the CI ``tunable`` job re-checks from the JSON):
budget=100% with unit weights is BITWISE the plain serve path, and on
pallas at f=784 the 25% budget serves >= 2x faster than full budget with
a held-out accuracy drop of at most 2 points.

Machine-readable results go to ``BENCH_tunable.json`` (override with env
``REPRO_BENCH_TUNABLE_JSON``).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import feedback as fb
from repro.core import accuracy as acc_mod
from repro.core import tm as tm_mod
from repro.serve import tunable as tun

RESULTS: list[dict] = []

BUDGETS = (1.0, 0.5, 0.25, 0.125)
# unit weights: on these workloads the linear calibration weights buy
# nothing over balanced pruning and cost 2-3 points at full budget
# (measured — see DESIGN.md §16); the capability is exercised by the
# test suite, the measured curve serves unweighted.
WEIGHT_BITS = 0


def _time_once(fn, *args):
    t0 = time.perf_counter()
    jax.block_until_ready(fn(*args))
    return time.perf_counter() - t0


def _workload(name: str):
    """name -> (cfg, s, T, epochs, train_xy, test_xy)."""
    if name == "iris":
        from repro.configs.tm_iris import CONFIG as SYS
        from repro.data import iris

        xs, ys = iris.load()
        return (SYS.tm, SYS.s_offline, SYS.T, SYS.n_offline_epochs,
                (xs[:100], ys[:100]), (xs[100:], ys[100:]))
    side = int(name.split("-f", 1)[1]) if "-f" in name else None
    side = {196: 14, 784: 28}[side]
    from repro.configs import tm_mnist
    from repro.data import mnist

    sysp = tm_mnist.config_for_side(side)
    # over-provisioned clause budget (§3.1.1): headroom in reserve is
    # exactly what a runtime budget spends
    cfg = dataclasses.replace(sysp.tm, max_clauses=128)
    tr_x, tr_y, te_x, te_y = mnist.splits(n_train=200, n_test=250,
                                          side=side)
    return (cfg, sysp.s_offline, sysp.T, sysp.n_offline_epochs,
            (tr_x, tr_y), (te_x, te_y))


def _train(cfg, s, T, epochs, xs, ys, seed=0):
    rt = tm_mod.init_runtime(cfg, s=s, T=T)
    st = tm_mod.init_state(cfg, jax.random.PRNGKey(seed))
    xs_j, ys_j = jnp.asarray(xs), jnp.asarray(ys)
    epoch = jax.jit(
        lambda st, k: fb.train_datapoints(cfg, st, rt, xs_j, ys_j, k))
    key = jax.random.PRNGKey(seed + 1)
    for e in range(epochs):
        key, k = jax.random.split(key)
        st, _ = epoch(st, k)
    return jax.block_until_ready(st), rt


def tunable_bench(workload: str, backend: str, trained, *, rounds: int = 4,
                  reps: int = 3) -> list[dict]:
    """One (workload, backend) sweep over BUDGETS. Returns result rows.

    ``trained`` is the (state, rt, splits) from :func:`_train` — training
    is backend-bitwise-identical (the parity suite pins it), so both
    backends serve the SAME banks and the curves are comparable.
    """
    st, rt, (tr_x, tr_y), (te_x, te_y), cfg0 = trained
    cfg = dataclasses.replace(cfg0, backend=backend)
    te_xj, te_yj = jnp.asarray(te_x), jnp.asarray(te_y)
    J = cfg.max_clauses

    # calibrate on the TRAIN split (the held-out set stays held out);
    # polarity-balanced ranking, unit weights (see module docstring)
    score = np.asarray(tun.clause_scores(
        cfg, st, rt, jnp.asarray(tr_x), jnp.asarray(tr_y)))
    order = tun.rank_from_scores(
        score, np.asarray(tm_mod.clause_polarity(cfg)))
    weights = tun.weights_from_scores(score, WEIGHT_BITS)
    w_j = None if weights is None else jnp.asarray(weights)

    full = jax.jit(lambda st, x: tm_mod.predict_batch_(cfg, st, rt, x))
    acc_full = float(acc_mod.analyze(cfg, st, rt, te_xj, te_yj))

    # parity: budget=100% + unit weights == the plain path, bitwise
    sel_full = jnp.asarray(order)
    p_plain = np.asarray(full(st, te_xj))
    p_pruned = np.asarray(tm_mod.predict_batch_pruned(
        cfg, st, rt, te_xj, sel_full, None))
    if not np.array_equal(p_plain, p_pruned):
        raise AssertionError(
            f"{workload}/{backend}: full-budget pruned serve is not "
            "bitwise the plain serve path")

    pruned_fns = {}
    for b in BUDGETS:
        m = tun.m_for_budget(b, J)
        sel = jnp.asarray(order[:, :m])
        pruned_fns[b] = (
            jax.jit(lambda st, x, _sel=sel:
                    tm_mod.predict_batch_pruned_(cfg, st, rt, x, _sel,
                                                 w_j)),
            m,
        )

    # warm every path, then interleave trials: min per path
    _time_once(full, st, te_xj)
    for fn, _ in pruned_fns.values():
        _time_once(fn, st, te_xj)
    t_full = float("inf")
    t_budget = {b: float("inf") for b in BUDGETS}
    for _ in range(rounds):
        dt = min(_time_once(full, st, te_xj) for _ in range(reps))
        t_full = min(t_full, dt)
        for b, (fn, _) in pruned_fns.items():
            dt = min(_time_once(fn, st, te_xj) for _ in range(reps))
            t_budget[b] = min(t_budget[b], dt)

    rows = []
    for b in BUDGETS:
        fn, m = pruned_fns[b]
        acc = float(acc_mod.analyze_pruned(
            cfg, st, rt, te_xj, te_yj, jnp.asarray(order[:, :m]), w_j))
        speedup = t_full / t_budget[b]
        name = (f"tunable_{workload}_{backend}_b"
                f"{str(b).replace('.', 'p')}")
        print(f"{name},{t_budget[b] * 1e6:.1f},"
              f"m={m};acc={acc:.4f};acc_full={acc_full:.4f};"
              f"speedup={speedup:.2f}x;weight_bits={WEIGHT_BITS}")
        rows.append({
            "name": name,
            "workload": workload,
            "backend": backend,
            "budget": b,
            "m": m,
            "n_clauses": J,
            "n_features": cfg.n_features,
            "weight_bits": WEIGHT_BITS,
            "us_per_call": t_budget[b] * 1e6,
            "us_per_call_full": t_full * 1e6,
            "speedup_vs_full": speedup,
            "accuracy": acc,
            "accuracy_full": acc_full,
            "accuracy_drop": acc_full - acc,
            "bitwise_at_full_budget": True,
        })
    return rows


def main():
    RESULTS.clear()
    for workload in ("iris", "mnist-f196", "mnist-f784"):
        cfg, s, T, epochs, (tr_x, tr_y), (te_x, te_y) = _workload(workload)
        # train once on ref — training is backend-bitwise-identical
        st, rt = _train(dataclasses.replace(cfg, backend="ref"),
                        s, T, epochs, tr_x, tr_y)
        trained = (st, rt, (tr_x, tr_y), (te_x, te_y), cfg)
        for backend in ("ref", "pallas"):
            RESULTS.extend(tunable_bench(workload, backend, trained))

    # the serving claim the CI job gates: at MNIST scale on the pallas
    # datapath a quarter of the clause budget buys >= 2x at <= 2 points
    gate = next(r for r in RESULTS
                if r["workload"] == "mnist-f784"
                and r["backend"] == "pallas" and r["budget"] == 0.25)
    if gate["speedup_vs_full"] < 2.0:
        raise AssertionError(
            f"pallas f=784 budget=25% speedup {gate['speedup_vs_full']:.2f}x"
            " < 2x")
    if gate["accuracy_drop"] > 0.02:
        raise AssertionError(
            f"pallas f=784 budget=25% accuracy drop "
            f"{gate['accuracy_drop'] * 100:.1f} points > 2")

    out_path = os.environ.get("REPRO_BENCH_TUNABLE_JSON",
                              "BENCH_tunable.json")
    payload = {
        "benchmark": "tunable",
        "jax_backend": jax.default_backend(),
        "budgets": list(BUDGETS),
        "results": RESULTS,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")
    return payload


if __name__ == "__main__":
    main()
