"""OnlineFleet serving benchmark: fleet drain vs K serial session drains.

Measures the replica-parallel online serving path (repro.serve.fleet)
against draining K independent ``OnlineSession`` machines one at a time —
the exact per-machine serial path the fleet replaced — asserting bitwise-
identical TA banks every run. The drain runs with monitoring compiled out
(the serving configuration), warm, on pre-filled buffers; each trial
re-fills every buffer with the same rows so both paths consume identical
offer streams.

Machine-readable results go to ``BENCH_fleet.json`` (override with env
``REPRO_BENCH_FLEET_JSON``). The headline field is
``results[fleet_drain].speedup`` — the fused fleet drain must stay >= 2x
over the serial K-session drain at K = 8 (gated in CI).
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import init_runtime, init_state
from repro.core.online import OnlineSession
from repro.data import buffer as buf_mod
from repro.data import iris
from repro.serve.fleet import OnlineFleet

CFG = common.CFG

RESULTS: list[dict] = []


def _filled_buffer(xs, ys, cap):
    """A ring buffer holding rows [0, cap) (head=0, size=cap)."""
    return buf_mod.RingBuffer(
        data_x=jnp.asarray(xs[:cap], dtype=bool),
        data_y=jnp.asarray(ys[:cap], dtype=jnp.int32),
        head=jnp.int32(0),
        size=jnp.int32(cap),
    )


def drain_bench(K: int = 8, cap: int = 64, chunk: int = 16,
                trials: int = 5, *, cfg=None, data=None, rt=None) -> dict:
    """Fleet drain vs K serial session drains; bitwise equality asserted.

    Defaults measure the iris machine; ``cfg``/``data=(xs, ys)``/``rt``
    parameterize the same protocol over other workloads (benchmarks/scale.py
    runs it at MNIST widths) so the baseline semantics live in ONE place.
    Overriding ``cfg`` requires ``rt`` — the default runtime's s/T are
    iris-calibrated and would silently miscalibrate another machine.
    """
    if cfg is not None and rt is None:
        raise ValueError("pass rt= when overriding cfg= (default s/T are "
                         "iris-calibrated)")
    cfg = CFG if cfg is None else cfg
    xs, ys = iris.load() if data is None else data
    rt = init_runtime(cfg, s=3.0, T=15) if rt is None else rt
    seeds = list(range(K))
    # per-replica offer streams: distinct row rotations of the dataset
    rows = [np.roll(np.arange(len(xs)), -7 * r)[:cap] for r in range(K)]
    bufs = [_filled_buffer(xs[rows[r]], ys[rows[r]], cap) for r in range(K)]
    stacked = jax.tree.map(lambda *a: jnp.stack(a), *bufs)

    def make_sessions():
        out = []
        for r in range(K):
            s = OnlineSession(cfg, init_state(cfg), rt, buffer_capacity=cap,
                              chunk=chunk, seed=seeds[r])
            s.ss = s.ss._replace(buf=bufs[r])
            out.append(s)
        return out

    def make_fleet():
        f = OnlineFleet(cfg, init_state(cfg), rt, n_replicas=K,
                        buffer_capacity=cap, chunk=chunk, seed=seeds)
        f.ss = f.ss._replace(buf=stacked)
        return f

    # warm both paths (compile), keep outputs for the bitwise check
    warm_sessions = make_sessions()
    for s in warm_sessions:
        assert s.learn_available(cap) == cap
    warm_fleet = make_fleet()
    assert list(warm_fleet.drain(cap)) == [cap] * K
    want = np.stack([np.asarray(s.ss.tm.ta_state) for s in warm_sessions])
    got = np.asarray(warm_fleet.ss.tm.ta_state)
    if not np.array_equal(want, got):
        raise AssertionError(
            "fleet drain diverges from the serial K-session drain"
        )

    # timed: interleave so background host load skews both paths equally
    t_fleet, t_serial = float("inf"), float("inf")
    for _ in range(trials):
        fleet = make_fleet()
        jax.block_until_ready(fleet.ss.buf.data_x)
        t0 = time.perf_counter()
        fleet.drain(cap)
        jax.block_until_ready(fleet.ss.tm.ta_state)
        t_fleet = min(t_fleet, time.perf_counter() - t0)

        sessions = make_sessions()
        jax.block_until_ready(sessions[-1].ss.buf.data_x)
        t0 = time.perf_counter()
        for s in sessions:
            s.learn_available(cap)
        jax.block_until_ready(sessions[-1].ss.tm.ta_state)
        t_serial = min(t_serial, time.perf_counter() - t0)

    return {
        "n_replicas": K,
        "points_per_replica": cap,
        "chunk": chunk,
        "wall_s_fleet": t_fleet,
        "wall_s_serial_sessions": t_serial,
        "speedup": t_serial / t_fleet,
        "points_per_s_fleet": K * cap / t_fleet,
        "bitwise_identical": True,
    }


def main():
    RESULTS.clear()
    for K in (2, 8):
        row = drain_bench(K=K)
        name = "fleet_drain" if K == 8 else f"fleet_drain_k{K}"
        print(
            f"{name},{row['wall_s_fleet'] * 1e6:.1f},"
            f"K={K};points={row['points_per_replica']};"
            f"serial_s={row['wall_s_serial_sessions']:.4f};"
            f"speedup={row['speedup']:.2f}x;bitwise_identical=1"
        )
        RESULTS.append({"name": name, **row})

    out_path = os.environ.get("REPRO_BENCH_FLEET_JSON", "BENCH_fleet.json")
    payload = {
        "benchmark": "fleet",
        "backend": CFG.backend,
        "jax_backend": jax.default_backend(),
        "results": RESULTS,
    }
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {out_path}")
    return payload


if __name__ == "__main__":
    main()
