"""Datapath-width scaling benchmark: the scale path from iris to MNIST.

Every other number in this repo is measured at iris width (f=16 boolean
inputs). The paper's architecture is motivated by edge workloads where the
datapath *width* dominates, so this benchmark re-measures the three hot
paths on the generated booleanized digit workload at

    f in {16, 196, 784}   (4x4 / 14x14 / 28x28 rasters; 784 = MNIST width)

and asserts the ROADMAP's scaling prediction: the batch-first and
replica-parallel paths must *widen* their advantage as f grows —

* ``scale_batch_infer_f*`` — batched GEMM inference vs the legacy
  vmap-of-per-sample plane (bitwise-equal predictions asserted). The
  batch-first headline: the GEMM's one-pass include-bank streaming wins
  more as the literal axis grows.
* ``scale_sweep_f*`` — the replica-parallel sweep engine vs the legacy
  vmap-of-scan ``grid_search_device`` (bitwise-equal accuracies
  asserted; the protocol is ``benchmarks.crossval.sweep_bench``
  parameterized over width). The replicated headline: factored
  uniforms/literals are stored once per data stream, and the per-point
  draw volume the legacy path re-materializes grows with f.
* ``scale_fleet_drain_f*`` / ``scale_ingress_f*`` — fleet drain vs K
  serial sessions, routed ingress vs per-point offers (both
  bitwise-asserted; the protocols are ``benchmarks.fleet.drain_bench``
  and ``benchmarks.ingress.ingress_bench`` parameterized over width) —
  the ROADMAP "wire serving + ingress to a bigger workload" item.
* ``scale_packed_infer_f*`` — the §13 bit-packed datapath vs the boolean
  one: batched inference on uint32 word rows (AND+popcount kernels)
  against the same pass on bool rows, per backend, bitwise-equal
  predictions asserted. Reports the bandwidth story alongside the wall
  clock: bytes per stored row and the ring-buffer / ingress-staging
  footprints at the fleet-bench serving geometry. Gated in-script AND
  in CI: packed must be >= 2x unpacked at f=784 on the pallas backend.
* ``scale_parity_f*`` — one sweep cell (offline epochs + analysis) and
  one batched inference pass run under BOTH kernel backends (ref and
  pallas-interpret), asserted bitwise identical at every width.

The widening asserts (f=784 speedup >= f=16 speedup for the batch-first
and replicated rows) run inside this script AND as a CI gate over the
machine-readable output, ``BENCH_scale.json`` (override with env
``REPRO_BENCH_SCALE_JSON``).
"""
from __future__ import annotations

import dataclasses
import functools
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.crossval import _min_time, sweep_bench as _sweep_bench
from benchmarks.fleet import drain_bench as _drain_bench
from benchmarks.ingress import ingress_bench as _ingress_bench
from repro.configs import tm_mnist
from repro.core import init_runtime, init_state
from repro.core import tm as tm_mod
from repro.data import blocks, mnist

RESULTS: list[dict] = []

SIDES = (4, 14, 28)            # f = 16 / 196 / 784
S_GRID = (2.0, 3.0)
T_GRID = (32,)
N_EPOCHS = 2
N_ORDERINGS = 2


def _emit(name: str, us_per_call: float, derived: str, **extra):
    print(f"{name},{us_per_call:.1f},{derived}")
    RESULTS.append({"name": name, "us_per_call": us_per_call, **extra})


@functools.lru_cache(maxsize=None)
def _width(side: int):
    """(cfg, system params, xs, ys) for one raster width — cached so the
    five bench functions per side share one generated dataset (rendering
    is pure and seed-deterministic; every consumer reads it immutably)."""
    params = tm_mnist.config_for_side(side)
    xs, ys = mnist.load(side=side)
    return params.tm, params, xs, ys


def batch_infer_bench(side: int, trials: int = 5) -> dict:
    """Batched GEMM inference vs the legacy vmap plane; bitwise asserted."""
    cfg, params, xs, ys = _width(side)
    rt = init_runtime(cfg, s=params.s_offline, T=params.T)
    st = init_state(cfg, jax.random.PRNGKey(0))
    xs_j = jnp.asarray(xs)

    infer_batch = jax.jit(lambda s, x: tm_mod.predict_batch(cfg, s, rt, x))
    infer_vmap = jax.jit(
        lambda s, x: jax.vmap(lambda r: tm_mod.predict(cfg, s, rt, r))(x)
    )
    # Interleave trials so background host load skews both paths equally.
    dt_b, dt_v = float("inf"), float("inf")
    preds_b = preds_v = None
    for _ in range(trials):
        t, preds_b = _min_time(lambda: infer_batch(st, xs_j), trials=1)
        dt_b = min(dt_b, t)
        t, preds_v = _min_time(lambda: infer_vmap(st, xs_j), trials=1)
        dt_v = min(dt_v, t)
    if not np.array_equal(np.asarray(preds_b), np.asarray(preds_v)):
        raise AssertionError(
            f"batched and vmap inference diverge at f={cfg.n_features}"
        )
    return {
        "f": cfg.n_features,
        "batch": len(xs),
        "wall_s_batch": dt_b,
        "wall_s_vmap": dt_v,
        "speedup": dt_v / dt_b,
        "datapoints_per_s": len(xs) / dt_b,
        "bitwise_identical": True,
    }


def packed_infer_bench(side: int, trials: int = 5, K: int = 4,
                       cap: int = 32, block: int = 32) -> dict:
    """Packed (AND+popcount, §13) vs boolean batch inference per backend.

    Both paths enter through the same ``predict_batch`` — the uint32 dtype
    routes rows to the packed kernels — so this measures exactly what a
    ``ServiceConfig(packed=True)`` service runs in steady state: inference
    straight off word-packed rows, no unpack. The headline is the pallas
    backend, where packing shrinks the word grid ~32x; the ref backend's
    ratio is reported too (its unpacked path is already a dense int GEMM,
    so popcount is not expected to win there at width). K/cap/block pin
    the fleet-bench serving geometry for the memory-footprint rows.
    """
    from repro.kernels import packing

    _, params, xs, ys = _width(side)
    f = params.tm.n_features
    xs_j = jnp.asarray(xs)
    xp = packing.pack_bits(xs_j)

    row: dict = {"f": f, "batch": len(xs)}
    for backend in ("pallas", "ref"):
        cfg = dataclasses.replace(params.tm, backend=backend)
        rt = init_runtime(cfg, s=params.s_offline, T=params.T)
        st = init_state(cfg, jax.random.PRNGKey(0))
        infer = jax.jit(lambda s, x: tm_mod.predict_batch(cfg, s, rt, x))
        # Interleave trials so background host load skews both paths
        # equally (same protocol as batch_infer_bench).
        dt_u, dt_p = float("inf"), float("inf")
        preds_u = preds_p = None
        for _ in range(trials):
            t, preds_u = _min_time(lambda: infer(st, xs_j), trials=1)
            dt_u = min(dt_u, t)
            t, preds_p = _min_time(lambda: infer(st, xp), trials=1)
            dt_p = min(dt_p, t)
        if not np.array_equal(np.asarray(preds_u), np.asarray(preds_p)):
            raise AssertionError(
                f"packed and unpacked inference diverge at f={f} "
                f"on the {backend} backend"
            )
        row[f"wall_s_unpacked_{backend}"] = dt_u
        row[f"wall_s_packed_{backend}"] = dt_p
        row[f"speedup_{backend}"] = dt_u / dt_p

    bpp_unpacked = f                            # bool row: 1 byte/literal
    bpp_packed = packing.packed_row_bytes(f)    # 4 * ceil(f/32)
    row.update({
        "speedup": row["speedup_pallas"],       # the gated headline
        "datapoints_per_s": len(xs) / row["wall_s_packed_pallas"],
        "bytes_per_point_unpacked": bpp_unpacked,
        "bytes_per_point_packed": bpp_packed,
        "bandwidth_reduction": bpp_unpacked / bpp_packed,
        "buffer_bytes_unpacked": K * cap * bpp_unpacked,
        "buffer_bytes_packed": K * cap * bpp_packed,
        "staging_bytes_unpacked": K * block * bpp_unpacked,
        "staging_bytes_packed": K * block * bpp_packed,
        "bitwise_identical": True,
    })
    return row


def sweep_bench(side: int) -> dict:
    cfg, params, xs, ys = _width(side)
    osets, _ = blocks.paper_sets(xs, ys, N_ORDERINGS)
    row = _sweep_bench(
        N_ORDERINGS, cfg=cfg, osets=osets,
        s_values=S_GRID, T_values=T_GRID, n_epochs=N_EPOCHS,
    )
    return {"f": cfg.n_features, **row}


def fleet_drain_bench(side: int, K: int = 4, cap: int = 32,
                      chunk: int = 8) -> dict:
    cfg, params, xs, ys = _width(side)
    rt = init_runtime(cfg, s=params.s_online, T=params.T)
    row = _drain_bench(K=K, cap=cap, chunk=chunk, trials=3,
                       cfg=cfg, data=(xs, ys), rt=rt)
    return {"f": cfg.n_features, **row}


def ingress_bench(side: int, K: int = 4, n_points: int = 96,
                  block: int = 32) -> dict:
    cfg, params, xs, ys = _width(side)
    rt = init_runtime(cfg, s=params.s_online, T=params.T)
    row = _ingress_bench(K=K, n_points=n_points, block=block, trials=3,
                         cfg=cfg, data=(xs, ys), rt=rt)
    return {"f": cfg.n_features, **row}


def parity_bench(side: int, seed: int = 0) -> dict:
    """One sweep cell + one batched inference pass under both backends,
    asserted bitwise identical at this width."""
    from repro.core import accuracy as acc_mod
    from repro.core import feedback as fb_mod

    _, params, xs, ys = _width(side)
    outs = {}
    for backend in ("ref", "pallas"):
        cfg = dataclasses.replace(params.tm, backend=backend)
        rt = init_runtime(cfg, s=params.s_offline, T=params.T)
        st = fb_mod.train_epochs(
            cfg, init_state(cfg), rt, jnp.asarray(xs[:20]),
            jnp.asarray(ys[:20]), jax.random.PRNGKey(seed), 1,
        )
        acc = acc_mod.analyze(cfg, st, rt, jnp.asarray(xs[20:60]),
                              jnp.asarray(ys[20:60]))
        preds = tm_mod.predict_batch(cfg, st, rt, jnp.asarray(xs[60:120]))
        outs[backend] = (np.asarray(st.ta_state), float(acc),
                         np.asarray(preds))
    ta_ok = np.array_equal(outs["ref"][0], outs["pallas"][0])
    acc_ok = outs["ref"][1] == outs["pallas"][1]
    pred_ok = np.array_equal(outs["ref"][2], outs["pallas"][2])
    if not (ta_ok and acc_ok and pred_ok):
        raise AssertionError(
            f"ref<->pallas parity broken at f={side * side}: "
            f"ta={ta_ok} acc={acc_ok} preds={pred_ok}"
        )
    return {
        "f": side * side,
        "accuracy": outs["ref"][1],
        "bitwise_identical": True,
    }


def main():
    RESULTS.clear()
    by_metric: dict[str, dict[int, dict]] = {}

    for side in SIDES:
        f = side * side
        for metric, fn in (
            ("scale_batch_infer", batch_infer_bench),
            ("scale_packed_infer", packed_infer_bench),
            ("scale_sweep", sweep_bench),
            ("scale_fleet_drain", fleet_drain_bench),
            ("scale_ingress", ingress_bench),
            ("scale_parity", parity_bench),
        ):
            row = fn(side)
            by_metric.setdefault(metric, {})[f] = row
            name = f"{metric}_f{f}"
            us = next(
                (row[k] * 1e6 for k in
                 ("wall_s_batch", "wall_s_packed_pallas", "wall_s_engine",
                  "wall_s_fleet", "wall_s_routed") if k in row), 0.0,
            )
            derived = ";".join(
                f"{k}={row[k]:.3g}" if isinstance(row[k], float)
                else f"{k}={row[k]}"
                for k in row
            )
            _emit(name, us, derived, **row)

    # The ROADMAP scaling prediction, asserted: the batch-first and
    # replicated paths widen their advantage from iris width to MNIST
    # width (the CI gate re-checks this over the JSON artifact).
    for metric in ("scale_batch_infer", "scale_sweep"):
        lo = by_metric[metric][16]["speedup"]
        hi = by_metric[metric][784]["speedup"]
        if hi < lo:
            raise AssertionError(
                f"{metric}: f=784 speedup {hi:.2f}x < f=16 speedup "
                f"{lo:.2f}x — the scale path narrowed its advantage"
            )
        print(f"# {metric}: f16 {lo:.2f}x -> f784 {hi:.2f}x (widened)")

    # The §13 packed-datapath gate (the CI gate re-checks this over the
    # JSON artifact): at full MNIST width the AND+popcount kernels must
    # beat the boolean path by >= 2x on the pallas backend.
    pk = by_metric["scale_packed_infer"][784]
    if pk["speedup_pallas"] < 2.0:
        raise AssertionError(
            f"scale_packed_infer: f=784 pallas packed speedup "
            f"{pk['speedup_pallas']:.2f}x < 2x — the packed datapath "
            f"lost its word-grid advantage"
        )
    print(
        f"# scale_packed_infer: f784 pallas packed "
        f"{pk['speedup_pallas']:.2f}x unpacked (gate >= 2x), "
        f"{pk['bandwidth_reduction']:.1f}x fewer bytes/point"
    )

    out_path = os.environ.get("REPRO_BENCH_SCALE_JSON", "BENCH_scale.json")
    payload = {
        "benchmark": "scale",
        "jax_backend": jax.default_backend(),
        "sides": list(SIDES),
        "grid": {"s": list(S_GRID), "T": list(T_GRID), "n_epochs": N_EPOCHS,
                 "n_orderings": N_ORDERINGS},
        "results": RESULTS,
    }
    with open(out_path, "w") as fh:
        json.dump(payload, fh, indent=2)
    print(f"# wrote {out_path}")
    return payload


if __name__ == "__main__":
    main()
