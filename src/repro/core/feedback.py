"""TM learning: feedback selection + TA updates (paper §2, §4).

The FPGA applies inference *and* feedback for all clauses/TAs of a datapoint in
two clock cycles; here the same plane of work is a single fused vectorized
update, and datapoints stream through ``lax.scan`` preserving the hardware's
serial semantics (feedback at step t sees TA state from t-1).

Runtime hyperparameters ``s`` and ``T`` are traced scalars carried in
:class:`~repro.core.tm.TMRuntime` — changing them (the paper's I/O ports) never
triggers re-compilation.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tm as tm_mod
from repro.core.tm import TMConfig, TMRuntime, TMState
from repro.kernels import dispatch


class StepAux(NamedTuple):
    """Per-step observability (feeds the accuracy/energy analysis blocks)."""

    votes: jax.Array       # [C] int32 class sums (training-mode clause outputs)
    predicted: jax.Array   # scalar int32 argmax class (inference-mode)
    correct: jax.Array     # scalar bool
    activity: jax.Array    # scalar f32 — fraction of TAs that changed state
                           # (the clock-gating/energy analogue, DESIGN.md §2)


def _selection_core(
    cfg: TMConfig,
    T: jax.Array,            # scalar i32
    clause_mask: jax.Array,  # [J] bool
    class_mask: jax.Array,   # [C] bool
    votes: jax.Array,        # [C] int32
    y: jax.Array,            # scalar int32 target class
    key: jax.Array,
):
    """One replica's feedback-type selection (shared by the single-machine and
    replica-parallel paths so both consume identical RNG streams)."""
    k_neg, k_t, k_n = jax.random.split(key, 3)
    Tf = T.astype(jnp.float32)
    C, J = cfg.max_classes, cfg.max_clauses

    # Sample a non-target active class uniformly (the paper's multi-class rule).
    neg_ok = class_mask & (jnp.arange(C) != y)
    logits = jnp.where(neg_ok, 0.0, -jnp.inf)
    ny = jax.random.categorical(k_neg, logits)

    v = jnp.clip(votes, -T, T).astype(jnp.float32)
    p_t = (Tf - v[y]) / (2.0 * Tf)
    p_n = (Tf + v[ny]) / (2.0 * Tf)

    sel_t = (jax.random.uniform(k_t, (J,)) < p_t) & clause_mask
    sel_n = (jax.random.uniform(k_n, (J,)) < p_n) & clause_mask

    pos = tm_mod.clause_polarity(cfg) > 0  # [J]
    onehot_y = jax.nn.one_hot(y, C, dtype=bool)
    onehot_n = jax.nn.one_hot(ny, C, dtype=bool)

    type1 = (
        onehot_y[:, None] & (sel_t & pos)[None, :]
        | onehot_n[:, None] & (sel_n & ~pos)[None, :]
    )
    type2 = (
        onehot_y[:, None] & (sel_t & ~pos)[None, :]
        | onehot_n[:, None] & (sel_n & pos)[None, :]
    )
    # Inactive classes never receive feedback (over-provisioning, §3.1.1).
    type1 = type1 & class_mask[:, None]
    type2 = type2 & class_mask[:, None]
    return type1, type2


def _feedback_selection(
    cfg: TMConfig,
    rt: TMRuntime,
    votes: jax.Array,  # [C] int32
    y: jax.Array,      # scalar int32 target class
    key: jax.Array,
):
    """Choose per-clause feedback types for the target + one sampled non-target.

    Target class y:   P(feedback) = (T - clip(v_y)) / 2T
                      positive-polarity clauses -> Type I, negative -> Type II.
    Sampled class ny: P(feedback) = (T + clip(v_ny)) / 2T
                      positive -> Type II, negative -> Type I.
    """
    return _selection_core(
        cfg, rt.T, rt.clause_mask, rt.class_mask, votes, y, key
    )


def train_update(
    cfg: TMConfig,
    state: TMState,
    rt: TMRuntime,
    x: jax.Array,
    y: jax.Array,
    key: jax.Array,
) -> tuple[TMState, jax.Array, jax.Array]:
    """One supervised datapoint's TA-bank update — no monitoring pass.

    The learning half of the paper's 2-clock-cycle datapath: one fused plane
    of (C x J x 2f) elementwise work plus two small reductions. Returns
    (new_state, training-mode votes [C], activity scalar). Consumers that
    want per-step inference-mode monitoring use :func:`train_step`; batched
    consumers (``online._consume_many``) hoist monitoring out of the serial
    scan and run it once per chunk through the batch-first clause kernel.
    """
    k_sel, k_u = jax.random.split(key)
    lits = tm_mod.make_literals(x)
    include = tm_mod.ta_actions(cfg, state, rt)

    clauses_tr = tm_mod.eval_clauses(cfg, include, lits, rt, training=True)
    votes = tm_mod.class_sums(cfg, clauses_tr)

    type1, type2 = _feedback_selection(cfg, rt, votes, y, k_sel)
    u = jax.random.uniform(
        k_u, (cfg.max_classes, cfg.max_clauses, cfg.n_literals), dtype=jnp.float32
    )

    new_ta = dispatch.resolve(cfg.backend).feedback_step(
        state.ta_state, lits, clauses_tr, type1, type2, u,
        s=rt.s, n_states=cfg.n_states, s_policy=cfg.s_policy,
        boost_true_positive=cfg.boost_true_positive,
    )

    activity = jnp.mean((new_ta != state.ta_state).astype(jnp.float32))
    return TMState(ta_state=new_ta), votes, activity


def train_step(
    cfg: TMConfig,
    state: TMState,
    rt: TMRuntime,
    x: jax.Array,
    y: jax.Array,
    key: jax.Array,
) -> tuple[TMState, StepAux]:
    """One supervised datapoint: inference + feedback for all clauses/TAs."""
    new_state, votes, activity = train_update(cfg, state, rt, x, y, key)

    # Inference-mode prediction for monitoring (empty clauses vote 0).
    lits = tm_mod.make_literals(x)
    include = tm_mod.ta_actions(cfg, state, rt)
    clauses_inf = tm_mod.eval_clauses(cfg, include, lits, rt, training=False)
    votes_inf = tm_mod.class_sums(cfg, clauses_inf)
    votes_inf = jnp.where(rt.class_mask, votes_inf, jnp.iinfo(jnp.int32).min)
    pred = jnp.argmax(votes_inf).astype(jnp.int32)

    aux = StepAux(
        votes=votes,
        predicted=pred,
        correct=(pred == y),
        activity=activity,
    )
    return new_state, aux


def train_datapoints(
    cfg: TMConfig,
    state: TMState,
    rt: TMRuntime,
    xs: jax.Array,       # [n, f] bool
    ys: jax.Array,       # [n] int32
    key: jax.Array,
    valid: jax.Array | None = None,  # [n] bool — masked-out rows are skipped
) -> tuple[TMState, StepAux]:
    """Stream datapoints serially (lax.scan), matching the FPGA's row order.

    ``valid`` lets fixed-shape sets carry variable row counts (class filtering,
    partial sets) without recompilation: invalid rows leave state untouched.
    """
    n = xs.shape[0]
    if valid is None:
        valid = jnp.ones((n,), dtype=bool)

    def body(carry, inp):
        st = carry
        x, y, v, k = inp
        new_st, aux = train_step(cfg, st, rt, x, y, k)
        st = jax.tree.map(lambda a, b: jnp.where(v, a, b), new_st, st)
        aux = aux._replace(
            activity=jnp.where(v, aux.activity, 0.0),
            correct=aux.correct & v,
        )
        return st, aux

    keys = jax.random.split(key, n)
    final, auxes = jax.lax.scan(body, state, (xs, ys, valid, keys))
    return final, auxes


# ---------------------------------------------------------------------------
# Replica-parallel training (cross-validation x hyperparameter sweep axis).
#
# R independent TMs advance one datapoint per step in ONE fused plane. Layout
# rule (mirrors the kernel contract in kernels/dispatch.py): per-replica state
# and control carry a leading R; per-data-stream operands (xs, ys, keys) carry
# a leading D with D | R, replica r consuming stream r % D. A hyperparameter
# sweep lays replicas out grid-major/ordering-minor so the (s, T) grid shares
# each ordering's data and RNG draws instead of tiling them R/D-fold; RNG
# streams are per data replica, so results are bit-identical to running each
# replica through train_update alone.
# ---------------------------------------------------------------------------


def _replica_counts(state: TMState, xs: jax.Array) -> tuple[int, int]:
    R = state.ta_state.shape[0]
    D = xs.shape[0]
    if R % D:
        raise ValueError(f"data replicas {D} must divide replicas {R}")
    return R, D


def train_update_replicated(
    cfg: TMConfig,
    state: TMState,    # leaves [R, ...]
    rt: TMRuntime,     # s/T scalar or [R]; masks shared (unreplicated) shapes
    x: jax.Array,      # [D, f] bool
    y: jax.Array,      # [D] int32
    key: jax.Array,    # [D] keys
) -> tuple[TMState, jax.Array, jax.Array]:
    """One datapoint's TA-bank update for all R replicas at once.

    Replica ``r`` performs exactly the computation of :func:`train_update`
    with data stream ``r % D`` and hyperparameters ``s[r]``/``T[r]`` —
    bit-for-bit, including the RNG draws (streams are keyed per data
    replica, shared across a hyperparameter grid exactly as re-running
    :func:`train_update` per cell would). Returns (new_state,
    votes [R, C], activity [R]); unused outputs are DCE'd under jit.
    """
    R, D = _replica_counts(state, x)
    H = R // D
    k2 = jax.vmap(jax.random.split)(key)        # [D, 2, key]
    k_sel, k_u = k2[:, 0], k2[:, 1]

    lits = tm_mod.make_literals(x)              # [D, L]
    include = tm_mod.ta_actions(cfg, state, rt)  # [R, C, J, L] (masks broadcast)

    backend = dispatch.resolve(cfg.backend)
    clauses_tr = backend.clause_eval_replicated(include, lits, training=True)
    clauses_tr = clauses_tr & rt.clause_mask[None, None, :]
    votes = tm_mod.class_sums(cfg, clauses_tr)  # [R, C]

    T_rep = jnp.broadcast_to(jnp.asarray(rt.T, jnp.int32), (R,))
    sel = partial(_selection_core, cfg)
    type1, type2 = jax.vmap(sel, in_axes=(0, None, None, 0, 0, 0))(
        T_rep, rt.clause_mask, rt.class_mask,
        votes, jnp.tile(y, H), jnp.tile(k_sel, (H, 1)),
    )

    u = jax.vmap(
        lambda k: jax.random.uniform(
            k, (cfg.max_classes, cfg.max_clauses, cfg.n_literals),
            dtype=jnp.float32,
        )
    )(k_u)                                      # [D, C, J, L] — factored draws

    new_ta = backend.feedback_step_replicated(
        state.ta_state, lits, clauses_tr, type1, type2, u,
        s=jnp.broadcast_to(jnp.asarray(rt.s, jnp.float32), (R,)),
        n_states=cfg.n_states, s_policy=cfg.s_policy,
        boost_true_positive=cfg.boost_true_positive,
    )

    activity = jnp.mean(
        (new_ta != state.ta_state).astype(jnp.float32), axis=(1, 2, 3)
    )
    return TMState(ta_state=new_ta), votes, activity


def train_datapoints_replicated(
    cfg: TMConfig,
    state: TMState,    # leaves [R, ...]
    rt: TMRuntime,
    xs: jax.Array,     # [D, n, f] bool
    ys: jax.Array,     # [D, n] int32
    key: jax.Array,    # [D] keys
    valid: jax.Array | None = None,  # [D, n] bool
) -> tuple[TMState, jax.Array]:
    """Stream the data sets serially while updating all R replicas per step.

    The replica-parallel form of :func:`train_datapoints`: ONE ``lax.scan``
    over datapoint index (the FPGA's row order, preserving feedback-sees-
    state-from-t-1 semantics) whose body advances every replica in a single
    fused plane. Returns (final_state, activity [n, R]).
    """
    R, D = _replica_counts(state, xs)
    H = R // D
    n = xs.shape[1]
    if valid is None:
        valid = jnp.ones((D, n), dtype=bool)

    keys = jax.vmap(lambda k: jax.random.split(k, n))(key)  # [D, n, key]
    keys = jnp.swapaxes(keys, 0, 1)                         # [n, D, key]

    def body(carry, inp):
        st = carry
        x, y, v, k = inp               # [D, f], [D], [D], [D] keys
        new_st, _, act = train_update_replicated(cfg, st, rt, x, y, k)
        vR = jnp.tile(v, H)            # replica r gated by stream r % D
        st = jax.tree.map(
            lambda a, b: jnp.where(
                vR.reshape((R,) + (1,) * (a.ndim - 1)), a, b
            ),
            new_st, st,
        )
        return st, jnp.where(vR, act, 0.0)

    final, activity = jax.lax.scan(
        body, state,
        (jnp.swapaxes(xs, 0, 1), jnp.swapaxes(ys, 0, 1),
         jnp.swapaxes(valid, 0, 1), keys),
    )
    return final, activity


@partial(jax.jit, static_argnums=0)
def train_epochs_replicated(
    cfg: TMConfig,
    state: TMState,    # leaves [R, ...]
    rt: TMRuntime,
    xs: jax.Array,     # [D, n, f]
    ys: jax.Array,     # [D, n]
    key: jax.Array,    # [D] keys
    n_epochs: int | jax.Array,
    valid: jax.Array | None = None,
) -> TMState:
    """Replica-parallel :func:`train_epochs`: the whole sweep's offline
    training is one compiled program scanning the dataset once per epoch."""
    n_epochs = jnp.asarray(n_epochs, dtype=jnp.int32)

    def body(i, st):
        k = jax.vmap(lambda kk: jax.random.fold_in(kk, i))(key)
        new_st, _ = train_datapoints_replicated(cfg, st, rt, xs, ys, k, valid)
        return new_st

    return jax.lax.fori_loop(0, n_epochs, body, state)


@partial(jax.jit, static_argnums=0)
def train_epochs(
    cfg: TMConfig,
    state: TMState,
    rt: TMRuntime,
    xs: jax.Array,
    ys: jax.Array,
    key: jax.Array,
    n_epochs: int | jax.Array,
    valid: jax.Array | None = None,
) -> TMState:
    """Repeat the dataset for a (traced) number of epochs.

    ``n_epochs`` is a runtime value: the scan runs to a static max derived from
    the array only when traced as python int; otherwise use fori_loop.
    """
    n_epochs = jnp.asarray(n_epochs, dtype=jnp.int32)

    def body(i, st):
        k = jax.random.fold_in(key, i)
        new_st, _ = train_datapoints(cfg, st, rt, xs, ys, k, valid)
        return new_st

    return jax.lax.fori_loop(0, n_epochs, body, state)
