"""Accuracy-analysis block + history RAM (paper §3.3).

``analyze`` is the paper's error-counting pass over a data set (masked rows
excluded, so class-filtered / partially-used sets keep fixed shapes);
``History`` is the preallocated on-device record of per-cycle accuracies that
the FPGA keeps in RAM during simulation and offloads to the microcontroller
on hardware.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tm as tm_mod
from repro.core.tm import TMConfig, TMRuntime, TMState


def analyze(
    cfg: TMConfig,
    state: TMState,
    rt: TMRuntime,
    xs: jax.Array,      # [n, f] bool
    ys: jax.Array,      # [n] int32
    valid: jax.Array | None = None,
) -> jax.Array:
    """Accuracy over the valid rows of a set. Scalar f32 in [0, 1].

    One batch-first pass: the whole set's clause plane is a single dispatched
    ``clause_eval_batch`` (include bank read once), not a vmap of per-sample
    predictions — this runs thrice per online cycle in the manager, so it is
    the hottest inference path in the system.

    ``xs`` may be PACKED rows [n, ceil(f/32)] uint32 (DESIGN.md §13) — the
    core's dtype routing sends them to the AND+popcount kernels with
    bit-identical predictions, so packed services analyze packed.
    """
    preds = tm_mod.predict_batch_(cfg, state, rt, xs)
    ok = (preds == ys).astype(jnp.float32)
    if valid is None:
        return jnp.mean(ok)
    v = valid.astype(jnp.float32)
    return jnp.sum(ok * v) / jnp.maximum(jnp.sum(v), 1.0)


def analyze_replicated(
    cfg: TMConfig,
    state: TMState,     # leaves [R, ...]
    rt: TMRuntime,      # masks shared; s/T scalar or [R]
    xs: jax.Array,      # [D, m, f] bool — replica r analyzes set r % D
    ys: jax.Array,      # [D, m] int32
    valid: jax.Array | None = None,  # [D, m] bool
) -> jax.Array:
    """Per-replica accuracy over R independent machines. [R] f32.

    The replica-parallel form of :func:`analyze`: the whole cross-validation
    sweep's analysis pass is ONE dispatched ``clause_eval_batch_replicated``
    contraction. Replica ``r`` reproduces ``analyze`` on set ``r % D``
    bit-for-bit (violation counts are integer-exact in f32; the per-replica
    mean reduces over the same m values in the same order). Packed ``xs``
    ([D, m, W] uint32, §13) route to the packed replicated kernel.
    """
    preds = tm_mod.predict_batch_replicated_(cfg, state, rt, xs)  # [R, m]
    return _reduce_replicated(preds, ys, valid)


def _reduce_replicated(
    preds: jax.Array,   # [R, m] int32
    ys: jax.Array,      # [D, m] int32 (D | R)
    valid: jax.Array | None,  # [D, m] bool
) -> jax.Array:
    """The accuracy reduction of :func:`analyze_replicated`. [R] f32."""
    H = preds.shape[0] // ys.shape[0]
    ok = (preds == jnp.tile(ys, (H, 1))).astype(jnp.float32)
    if valid is None:
        return jnp.mean(ok, axis=-1)
    v = jnp.tile(valid, (H, 1)).astype(jnp.float32)
    return jnp.sum(ok * v, axis=-1) / jnp.maximum(jnp.sum(v, axis=-1), 1.0)


def analyze_sets_replicated(
    cfg: TMConfig,
    state: TMState,     # leaves [R, ...]
    rt: TMRuntime,      # masks shared; s/T scalar or [R]
    sets: "list[tuple[jax.Array, jax.Array, jax.Array | None]]",
    # each set: (xs [D, m_i, f], ys [D, m_i], valid [D, m_i] | None) — all
    # sets must share the data-stream count D (D | R)
) -> jax.Array:
    """Per-replica accuracy over MANY sets in ONE contraction. [R, n_sets].

    The Fig-3 manager analyzes three sets (offline / validation / online)
    per cycle; calling :func:`analyze_replicated` thrice launches three
    clause contractions that each re-stream the include bank. Here the sets
    are concatenated along the batch axis so the whole analysis block is a
    single ``clause_eval_batch_replicated`` launch — the include bank is
    read once per *cycle*, not once per set.

    Bitwise-identical to stacking the three separate calls: each batch
    row's violation counts are independent integer dot products (exact in
    the kernels' f32/int32 accumulation), and each set's mean reduces over
    the same m_i values in the same order as :func:`analyze_replicated`.
    """
    xs = jnp.concatenate([s[0] for s in sets], axis=1)  # [D, sum(m_i), f]
    preds = tm_mod.predict_batch_replicated_(cfg, state, rt, xs)
    out, off = [], 0
    for x, y, valid in sets:
        m = x.shape[1]
        out.append(_reduce_replicated(preds[:, off:off + m], y, valid))
        off += m
    return jnp.stack(out, axis=-1)                     # [R, n_sets]


def analyze_pruned(
    cfg: TMConfig,
    state: TMState,
    rt: TMRuntime,
    xs: jax.Array,       # [n, f] bool | packed uint32
    ys: jax.Array,       # [n] int32
    sel: jax.Array,      # [C, M] int32 — clause ids to evaluate, per class
    weights: jax.Array | None = None,   # [C, J] int magnitudes
    valid: jax.Array | None = None,
) -> jax.Array:
    """Accuracy of the BUDGETED serve path over a set. Scalar f32.

    The §16 calibration/benchmark measurement: same reduction as
    :func:`analyze`, predictions from ``predict_batch_pruned_`` (only the
    elected clauses contracted, weights folded into the vote). With a
    full-permutation ``sel`` and unit weights this IS :func:`analyze`,
    bit for bit.
    """
    preds = tm_mod.predict_batch_pruned_(cfg, state, rt, xs, sel, weights)
    ok = (preds == ys).astype(jnp.float32)
    if valid is None:
        return jnp.mean(ok)
    v = valid.astype(jnp.float32)
    return jnp.sum(ok * v) / jnp.maximum(jnp.sum(v), 1.0)


def analyze_pruned_replicated(
    cfg: TMConfig,
    state: TMState,      # leaves [R, ...]
    rt: TMRuntime,
    xs: jax.Array,       # [D, m, f] — replica r analyzes set r % D
    ys: jax.Array,       # [D, m] int32
    sel: jax.Array,      # [R, C, M] int32 — per-replica rankings
    weights: jax.Array | None = None,   # [R, C, J] int magnitudes
    valid: jax.Array | None = None,
) -> jax.Array:
    """Per-replica budgeted-serve accuracy. [R] f32 — the fleet's
    accuracy-vs-budget curve in one contraction per budget point."""
    preds = tm_mod.predict_batch_pruned_replicated_(
        cfg, state, rt, xs, sel, weights
    )
    return _reduce_replicated(preds, ys, valid)


class History(NamedTuple):
    """Fixed-capacity accuracy history (the paper's history RAM)."""

    values: jax.Array  # [capacity, n_sets] f32
    idx: jax.Array     # scalar int32 — next write slot


def make_history(capacity: int, n_sets: int) -> History:
    return History(
        values=jnp.full((capacity, n_sets), jnp.nan, dtype=jnp.float32),
        idx=jnp.int32(0),
    )


def record(hist: History, row: jax.Array) -> History:
    """Append one accuracy row (no-op when full, like a saturating RAM)."""
    cap = hist.values.shape[0]
    full = hist.idx >= cap
    slot = jnp.minimum(hist.idx, cap - 1)
    new_vals = jax.lax.dynamic_update_slice(
        hist.values, row[None].astype(jnp.float32), (slot, 0)
    )
    return History(
        values=jnp.where(full, hist.values, new_vals),
        idx=jnp.where(full, hist.idx, hist.idx + 1),
    )
