"""Accuracy-analysis block + history RAM (paper §3.3).

``analyze`` is the paper's error-counting pass over a data set (masked rows
excluded, so class-filtered / partially-used sets keep fixed shapes);
``History`` is the preallocated on-device record of per-cycle accuracies that
the FPGA keeps in RAM during simulation and offloads to the microcontroller
on hardware.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tm as tm_mod
from repro.core.tm import TMConfig, TMRuntime, TMState


def analyze(
    cfg: TMConfig,
    state: TMState,
    rt: TMRuntime,
    xs: jax.Array,      # [n, f] bool
    ys: jax.Array,      # [n] int32
    valid: jax.Array | None = None,
) -> jax.Array:
    """Accuracy over the valid rows of a set. Scalar f32 in [0, 1].

    One batch-first pass: the whole set's clause plane is a single dispatched
    ``clause_eval_batch`` (include bank read once), not a vmap of per-sample
    predictions — this runs thrice per online cycle in the manager, so it is
    the hottest inference path in the system.
    """
    preds = tm_mod.predict_batch_(cfg, state, rt, xs)
    ok = (preds == ys).astype(jnp.float32)
    if valid is None:
        return jnp.mean(ok)
    v = valid.astype(jnp.float32)
    return jnp.sum(ok * v) / jnp.maximum(jnp.sum(v), 1.0)


class History(NamedTuple):
    """Fixed-capacity accuracy history (the paper's history RAM)."""

    values: jax.Array  # [capacity, n_sets] f32
    idx: jax.Array     # scalar int32 — next write slot


def make_history(capacity: int, n_sets: int) -> History:
    return History(
        values=jnp.full((capacity, n_sets), jnp.nan, dtype=jnp.float32),
        idx=jnp.int32(0),
    )


def record(hist: History, row: jax.Array) -> History:
    """Append one accuracy row (no-op when full, like a saturating RAM)."""
    cap = hist.values.shape[0]
    full = hist.idx >= cap
    slot = jnp.minimum(hist.idx, cap - 1)
    new_vals = jax.lax.dynamic_update_slice(
        hist.values, row[None].astype(jnp.float32), (slot, 0)
    )
    return History(
        values=jnp.where(full, hist.values, new_vals),
        idx=jnp.where(full, hist.idx, hist.idx + 1),
    )
