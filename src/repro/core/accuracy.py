"""Accuracy-analysis block + history RAM (paper §3.3).

``analyze`` is the paper's error-counting pass over a data set (masked rows
excluded, so class-filtered / partially-used sets keep fixed shapes);
``History`` is the preallocated on-device record of per-cycle accuracies that
the FPGA keeps in RAM during simulation and offloads to the microcontroller
on hardware.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import tm as tm_mod
from repro.core.tm import TMConfig, TMRuntime, TMState
from repro.kernels import dispatch


def analyze(
    cfg: TMConfig,
    state: TMState,
    rt: TMRuntime,
    xs: jax.Array,      # [n, f] bool
    ys: jax.Array,      # [n] int32
    valid: jax.Array | None = None,
) -> jax.Array:
    """Accuracy over the valid rows of a set. Scalar f32 in [0, 1].

    One batch-first pass: the whole set's clause plane is a single dispatched
    ``clause_eval_batch`` (include bank read once), not a vmap of per-sample
    predictions — this runs thrice per online cycle in the manager, so it is
    the hottest inference path in the system.
    """
    preds = tm_mod.predict_batch_(cfg, state, rt, xs)
    ok = (preds == ys).astype(jnp.float32)
    if valid is None:
        return jnp.mean(ok)
    v = valid.astype(jnp.float32)
    return jnp.sum(ok * v) / jnp.maximum(jnp.sum(v), 1.0)


def analyze_replicated(
    cfg: TMConfig,
    state: TMState,     # leaves [R, ...]
    rt: TMRuntime,      # masks shared; s/T scalar or [R]
    xs: jax.Array,      # [D, m, f] bool — replica r analyzes set r % D
    ys: jax.Array,      # [D, m] int32
    valid: jax.Array | None = None,  # [D, m] bool
) -> jax.Array:
    """Per-replica accuracy over R independent machines. [R] f32.

    The replica-parallel form of :func:`analyze`: the whole cross-validation
    sweep's analysis pass is ONE dispatched ``clause_eval_batch_replicated``
    contraction. Replica ``r`` reproduces ``analyze`` on set ``r % D``
    bit-for-bit (violation counts are integer-exact in f32; the per-replica
    mean reduces over the same m values in the same order).
    """
    R = state.ta_state.shape[0]
    D = xs.shape[0]
    H = R // D
    lits = tm_mod.make_literals(xs)                    # [D, m, 2f]
    include = tm_mod.ta_actions(cfg, state, rt)        # [R, C, J, L]
    clauses = dispatch.resolve(cfg.backend).clause_eval_batch_replicated(
        include, lits, training=False
    )                                                  # [R, m, C, J]
    clauses = clauses & rt.clause_mask
    votes = tm_mod.class_sums(cfg, clauses)            # [R, m, C]
    votes = jnp.where(rt.class_mask, votes, jnp.iinfo(jnp.int32).min)
    preds = jnp.argmax(votes, axis=-1)                 # [R, m]
    ok = (preds == jnp.tile(ys, (H, 1))).astype(jnp.float32)
    if valid is None:
        return jnp.mean(ok, axis=-1)
    v = jnp.tile(valid, (H, 1)).astype(jnp.float32)
    return jnp.sum(ok * v, axis=-1) / jnp.maximum(jnp.sum(v, axis=-1), 1.0)


class History(NamedTuple):
    """Fixed-capacity accuracy history (the paper's history RAM)."""

    values: jax.Array  # [capacity, n_sets] f32
    idx: jax.Array     # scalar int32 — next write slot


def make_history(capacity: int, n_sets: int) -> History:
    return History(
        values=jnp.full((capacity, n_sets), jnp.nan, dtype=jnp.float32),
        idx=jnp.int32(0),
    )


def record(hist: History, row: jax.Array) -> History:
    """Append one accuracy row (no-op when full, like a saturating RAM)."""
    cap = hist.values.shape[0]
    full = hist.idx >= cap
    slot = jnp.minimum(hist.idx, cap - 1)
    new_vals = jax.lax.dynamic_update_slice(
        hist.values, row[None].astype(jnp.float32), (slot, 0)
    )
    return History(
        values=jnp.where(full, hist.values, new_vals),
        idx=jnp.where(full, hist.idx, hist.idx + 1),
    )
