"""System operation FSM (paper §4, Fig. 3).

Execution flow: offline training -> accuracy analysis (offline/validation/
online sets) -> [online training pass -> accuracy analysis] x n_cycles.

Runtime *schedules* express the paper's use-case events — class introduction
(§5.2), fault injection (§5.3), s/T changes — as pure functions of the cycle
index over the fixed-shape runtime, so one traced program covers the whole
experiment and `vmap` runs all cross-validation orderings simultaneously.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import accuracy as acc_mod
from repro.core import feedback as fb_mod
from repro.core.tm import TMConfig, TMRuntime, TMState


class Sets(NamedTuple):
    """The three data sets (§3.6.1) with validity masks (fixed shapes).

    ``offline_train_valid`` restricts TRAINING rows (§5.1 uses 20 of 30);
    ``offline_valid`` governs accuracy ANALYSIS of the offline set (the paper
    analyzes the full set, so the 10 untrained rows count toward accuracy).

    Shapes below are the single-machine form (:func:`run_system`). Under the
    replica-parallel engine (:func:`run_orderings` /
    ``repro.eval.crossval``), every leaf carries a LEADING ordering axis
    ``[O, ...]`` — see the Schedule contract note below.
    """

    offline_x: jax.Array     # [n_off, f] bool
    offline_y: jax.Array     # [n_off] i32
    offline_valid: jax.Array # [n_off] bool — analysis mask
    validation_x: jax.Array
    validation_y: jax.Array
    validation_valid: jax.Array
    online_x: jax.Array
    online_y: jax.Array
    online_valid: jax.Array
    offline_train_valid: jax.Array = None  # [n_off] bool — training mask


class CycleCtl(NamedTuple):
    """Per-cycle control word produced by a schedule (the runtime 'ports')."""

    rt: TMRuntime
    sets: Sets
    online_enabled: jax.Array  # scalar bool


# A schedule maps (cycle_index, base_runtime, base_sets) -> CycleCtl.
# cycle_index == -1 denotes the offline-training phase.
#
# CONTRACT: a schedule must be broadcast-safe over a leading replica axis.
# Under run_system it sees the documented per-ordering Sets shapes; under
# the replica-parallel engine (run_orderings / repro.eval.crossval) the
# SAME schedule is applied once to Sets whose leaves carry a leading [O]
# ordering axis (and a shared runtime). Write mask logic against the LAST
# axes (e.g. ``ys != c``, ``arange(n) < k`` broadcast against ``[..., n]``)
# and never key off ``shape[0]`` — everything make_schedule produces obeys
# this.
Schedule = Callable[[jax.Array, TMRuntime, Sets], CycleCtl]


def default_schedule(cycle: jax.Array, rt: TMRuntime, sets: Sets) -> CycleCtl:
    return CycleCtl(rt=rt, sets=sets, online_enabled=jnp.bool_(True))


def make_schedule(
    *,
    online_enabled: bool = True,
    filtered_class: int | None = None,
    introduce_at_cycle: int | None = None,
    fault_masks: tuple[jax.Array, jax.Array] | None = None,
    inject_at_cycle: int | None = None,
    online_s: float | None = None,
) -> Schedule:
    """Compose the paper's use-case events into one schedule.

    * ``filtered_class`` — class removed from all sets (and the class mask)
      until ``introduce_at_cycle`` (None = filtered forever). §5.2.
    * ``fault_masks`` — (and_mask, or_mask) written at ``inject_at_cycle``. §5.3.
    * ``online_s`` — the runtime s-port value used during online cycles. §5.1.
    """

    def schedule(cycle: jax.Array, rt: TMRuntime, sets: Sets) -> CycleCtl:
        cycle = jnp.asarray(cycle, dtype=jnp.int32)

        if filtered_class is not None:
            if introduce_at_cycle is None:
                filtering = jnp.bool_(True)
            else:
                filtering = cycle < introduce_at_cycle

            def filt(ys, valid):
                return valid & jnp.where(filtering, ys != filtered_class, True)

            sets = sets._replace(
                offline_valid=filt(sets.offline_y, sets.offline_valid),
                validation_valid=filt(sets.validation_y, sets.validation_valid),
                online_valid=filt(sets.online_y, sets.online_valid),
            )
            # The over-provisioned class is enabled only once introduced.
            n_cls = rt.class_mask.shape[0]
            cls_mask = rt.class_mask & jnp.where(
                filtering, jnp.arange(n_cls) != filtered_class, True
            )
            rt = rt._replace(class_mask=cls_mask)

        if fault_masks is not None and inject_at_cycle is not None:
            and_m, or_m = fault_masks
            injected = cycle >= inject_at_cycle
            rt = rt._replace(
                ta_and_mask=jnp.where(injected, and_m, rt.ta_and_mask),
                ta_or_mask=jnp.where(injected, or_m, rt.ta_or_mask),
            )

        if online_s is not None:
            rt = rt._replace(
                s=jnp.where(cycle >= 0, jnp.float32(online_s), rt.s)
            )

        return CycleCtl(rt=rt, sets=sets, online_enabled=jnp.bool_(online_enabled))

    return schedule


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    """High-level manager parameters (paper §5: 10 offline epochs, 16 cycles)."""

    n_offline_epochs: int = 10
    n_online_cycles: int = 16


def _analyze_all(cfg, state, ctl: CycleCtl) -> jax.Array:
    s = ctl.sets
    return jnp.stack([
        acc_mod.analyze(cfg, state, ctl.rt, s.offline_x, s.offline_y, s.offline_valid),
        acc_mod.analyze(cfg, state, ctl.rt, s.validation_x, s.validation_y,
                        s.validation_valid),
        acc_mod.analyze(cfg, state, ctl.rt, s.online_x, s.online_y, s.online_valid),
    ])


@partial(jax.jit, static_argnums=(0, 1, 5))
def run_system(
    cfg: TMConfig,
    sys_cfg: SystemConfig,
    state: TMState,
    rt: TMRuntime,
    sets: Sets,
    schedule: Schedule,
    key: jax.Array,
) -> tuple[TMState, jax.Array, jax.Array]:
    """Run the full Fig-3 flow.

    Returns (final_state,
             accuracies [1 + n_cycles, 3] (offline/validation/online sets),
             activity   [n_cycles] mean TA-update activity per online cycle).
    """
    k_off, k_onl = jax.random.split(key)

    # --- offline training phase (cycle index -1) ---
    ctl0 = schedule(jnp.int32(-1), rt, sets)
    train_valid = ctl0.sets.offline_train_valid
    if train_valid is None:
        train_valid = ctl0.sets.offline_valid
    else:
        train_valid = train_valid & ctl0.sets.offline_valid
    state = fb_mod.train_epochs(
        cfg, state, ctl0.rt,
        ctl0.sets.offline_x, ctl0.sets.offline_y,
        k_off, sys_cfg.n_offline_epochs,
        valid=train_valid,
    )
    acc0 = _analyze_all(cfg, state, ctl0)

    # --- online cycles ---
    def body(carry, cycle):
        st = carry
        ctl = schedule(cycle, rt, sets)
        k = jax.random.fold_in(k_onl, cycle)
        new_st, aux = fb_mod.train_datapoints(
            cfg, st, ctl.rt, ctl.sets.online_x, ctl.sets.online_y, k,
            valid=ctl.sets.online_valid,
        )
        st = jax.tree.map(
            lambda a, b: jnp.where(ctl.online_enabled, a, b), new_st, st
        )
        accs = _analyze_all(cfg, st, ctl)
        activity = jnp.where(
            ctl.online_enabled, jnp.mean(aux.activity), 0.0
        )
        return st, (accs, activity)

    cycles = jnp.arange(sys_cfg.n_online_cycles, dtype=jnp.int32)
    state, (accs, activity) = jax.lax.scan(body, state, cycles)
    return state, jnp.concatenate([acc0[None], accs], axis=0), activity


def run_orderings(
    cfg: TMConfig,
    sys_cfg: SystemConfig,
    states: TMState,       # leading axis = ordering
    rt: TMRuntime,
    sets: Sets,            # leading axis = ordering on every leaf
    schedule: Schedule,
    keys: jax.Array,       # [O] keys
    mesh=None,
):
    """All cross-validation orderings in parallel — ONE replicated program.

    This is the paper's 120-orderings re-run executed through the
    replica-parallel engine (repro.eval.crossval): each datapoint step
    advances every ordering's TA bank in one fused plane, the TPU-native
    form of the paper's block-ROM cross-validation subsystem. Thin caller of
    :meth:`CrossValRun.system`; bit-identical to vmapping
    :func:`run_system` over orderings (tests/test_manager.py).
    """
    from repro.eval.crossval import CrossValRun

    res = CrossValRun(cfg, mesh=mesh).system(
        sys_cfg, states, rt, sets, schedule, keys
    )
    return res.state, res.accuracies, res.activity
