"""Accelerated hyperparameter search + cross-validation (paper goal ii, §5).

The paper: "the fast execution time allows entire datasets to be analyzed in a
matter of seconds, allowing the optimum hyper-parameters ... to be discovered
within a short period of time." On TPU the acceleration axis is *replication*:
every (ordering x s x T) replica is an independent TM. :func:`grid_search` is
now a thin caller of the replica-parallel engine
(:class:`repro.eval.crossval.CrossValRun`), which fuses the whole sweep into
ONE compiled program over a leading replica axis (shardable over the device
mesh for pod-scale search).

:func:`_one_cell` is the per-cell reference semantics, and
:func:`grid_search_device` keeps the pre-engine vmap-of-scan path alive as
the baseline the engine is benchmarked (and bit-compared) against — see
benchmarks/crossval.py / BENCH_crossval.json.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import numpy as np

from repro.core import accuracy as acc_mod
from repro.core import feedback as fb_mod
from repro.core import tm as tm_mod
from repro.core.tm import TMConfig


class GridResult(NamedTuple):
    s_grid: np.ndarray        # [S]
    T_grid: np.ndarray        # [T]
    val_accuracy: jax.Array   # [S, T, O] per-ordering validation accuracy
    mean_accuracy: jax.Array  # [S, T]


def _one_cell(
    cfg: TMConfig,
    s: jax.Array,
    T: jax.Array,
    off_x, off_y, val_x, val_y,
    key: jax.Array,
    n_epochs: int,
) -> jax.Array:
    """Train one TM with (s, T) on one ordering's offline set; return val acc."""
    rt = tm_mod.init_runtime(cfg)._replace(s=s, T=T)
    state = tm_mod.init_state(cfg)
    state = fb_mod.train_epochs(cfg, state, rt, off_x, off_y, key, n_epochs)
    return acc_mod.analyze(cfg, state, rt, val_x, val_y)


@partial(jax.jit, static_argnums=(0, 6))
def grid_search_device(
    cfg: TMConfig,
    s_grid: jax.Array,   # [S] f32
    T_grid: jax.Array,   # [G] i32
    off_sets,            # (off_x [O,n,f], off_y [O,n])
    val_sets,            # (val_x [O,m,f], val_y [O,m])
    keys: jax.Array,     # [O] keys
    n_epochs: int,
) -> jax.Array:
    """Validation accuracy for every (s, T, ordering). [S, G, O] f32.

    LEGACY vmap-of-scan path (pre replica-parallel engine), kept as the
    benchmark baseline and as an independent oracle for the engine's
    bit-exactness tests. New callers should use ``grid_search`` (engine).
    """
    off_x, off_y = off_sets
    val_x, val_y = val_sets

    per_ordering = jax.vmap(
        lambda s, T: jax.vmap(
            lambda ox, oy, vx, vy, k: _one_cell(
                cfg, s, T, ox, oy, vx, vy, k, n_epochs
            )
        )(off_x, off_y, val_x, val_y, keys)
    , in_axes=(None, 0))
    return jax.vmap(per_ordering, in_axes=(0, None))(s_grid, T_grid)


def grid_search(
    cfg: TMConfig,
    s_values,
    T_values,
    off_x, off_y, val_x, val_y,
    *,
    n_epochs: int = 10,
    seed: int = 0,
    mesh=None,
) -> GridResult:
    """The full (s x T x orderings) sweep as one replica-parallel program.

    Thin caller of :class:`repro.eval.crossval.CrossValRun`; results are
    bit-identical to the legacy :func:`grid_search_device` path (and to
    looping :func:`_one_cell`).
    """
    from repro.eval.crossval import CrossValRun

    res = CrossValRun(cfg, mesh=mesh).sweep(
        off_x, off_y, val_x, val_y, s_values, T_values,
        n_epochs=n_epochs, seed=seed,
    )
    return GridResult(
        s_grid=res.s_grid,
        T_grid=res.T_grid,
        val_accuracy=res.val_accuracy,
        mean_accuracy=res.mean_accuracy,
    )


def best(result: GridResult) -> tuple[float, int, float]:
    """(s*, T*, mean validation accuracy) of the best grid cell."""
    m = np.asarray(result.mean_accuracy)
    i, j = np.unravel_index(np.argmax(m), m.shape)
    return float(result.s_grid[i]), int(result.T_grid[j]), float(m[i, j])
