"""Accelerated hyperparameter search + cross-validation (paper goal ii, §5).

The paper: "the fast execution time allows entire datasets to be analyzed in a
matter of seconds, allowing the optimum hyper-parameters ... to be discovered
within a short period of time." On TPU the acceleration axis is *replication*:
every (ordering x s x T) replica is an independent TM, so the whole grid is
one `vmap`-ed program, and the replica axis shards over the device mesh
(`data` axis) with pjit for pod-scale search.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import accuracy as acc_mod
from repro.core import feedback as fb_mod
from repro.core import tm as tm_mod
from repro.core.tm import TMConfig, TMRuntime, TMState


class GridResult(NamedTuple):
    s_grid: np.ndarray        # [S]
    T_grid: np.ndarray        # [T]
    val_accuracy: jax.Array   # [S, T, O] per-ordering validation accuracy
    mean_accuracy: jax.Array  # [S, T]


def _one_cell(
    cfg: TMConfig,
    s: jax.Array,
    T: jax.Array,
    off_x, off_y, val_x, val_y,
    key: jax.Array,
    n_epochs: int,
) -> jax.Array:
    """Train one TM with (s, T) on one ordering's offline set; return val acc."""
    rt = tm_mod.init_runtime(cfg)._replace(s=s, T=T)
    state = tm_mod.init_state(cfg)
    state = fb_mod.train_epochs(cfg, state, rt, off_x, off_y, key, n_epochs)
    return acc_mod.analyze(cfg, state, rt, val_x, val_y)


@partial(jax.jit, static_argnums=(0, 6))
def grid_search_device(
    cfg: TMConfig,
    s_grid: jax.Array,   # [S] f32
    T_grid: jax.Array,   # [G] i32
    off_sets,            # (off_x [O,n,f], off_y [O,n])
    val_sets,            # (val_x [O,m,f], val_y [O,m])
    keys: jax.Array,     # [O] keys
    n_epochs: int,
) -> jax.Array:
    """Validation accuracy for every (s, T, ordering). [S, G, O] f32."""
    off_x, off_y = off_sets
    val_x, val_y = val_sets

    per_ordering = jax.vmap(
        lambda s, T: jax.vmap(
            lambda ox, oy, vx, vy, k: _one_cell(
                cfg, s, T, ox, oy, vx, vy, k, n_epochs
            )
        )(off_x, off_y, val_x, val_y, keys)
    , in_axes=(None, 0))
    return jax.vmap(per_ordering, in_axes=(0, None))(s_grid, T_grid)


def grid_search(
    cfg: TMConfig,
    s_values,
    T_values,
    off_x, off_y, val_x, val_y,
    *,
    n_epochs: int = 10,
    seed: int = 0,
) -> GridResult:
    """Host wrapper: the full (s x T x orderings) sweep as one program."""
    s_grid = jnp.asarray(s_values, dtype=jnp.float32)
    T_grid = jnp.asarray(T_values, dtype=jnp.int32)
    n_orderings = off_x.shape[0]
    keys = jax.random.split(jax.random.PRNGKey(seed), n_orderings)
    acc = grid_search_device(
        cfg, s_grid, T_grid,
        (jnp.asarray(off_x, bool), jnp.asarray(off_y, jnp.int32)),
        (jnp.asarray(val_x, bool), jnp.asarray(val_y, jnp.int32)),
        keys, n_epochs,
    )
    return GridResult(
        s_grid=np.asarray(s_grid),
        T_grid=np.asarray(T_grid),
        val_accuracy=acc,
        mean_accuracy=jnp.mean(acc, axis=-1),
    )


def best(result: GridResult) -> tuple[float, int, float]:
    """(s*, T*, mean validation accuracy) of the best grid cell."""
    m = np.asarray(result.mean_accuracy)
    i, j = np.unravel_index(np.argmax(m), m.shape)
    return float(result.s_grid[i]), int(result.T_grid[j]), float(m[i, j])
