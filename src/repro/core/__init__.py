"""The paper's primary contribution: TM online-learning system in JAX.

Public surface:
  TMConfig / TMState / TMRuntime      — design-time / learnt / runtime state
  init_state / init_runtime           — constructors
  forward / forward_batch / predict / predict_batch — inference datapath
                                        (batch-first; kernels/dispatch.py)
  train_step / train_update / train_datapoints / train_epochs — learning
  faults, accuracy, manager, online, hpsearch   — management subsystems
"""
from repro.core.tm import (  # noqa: F401
    TMConfig,
    TMRuntime,
    TMState,
    forward,
    forward_batch,
    init_runtime,
    init_state,
    predict,
    predict_batch,
)
from repro.core.feedback import (  # noqa: F401
    StepAux,
    train_datapoints,
    train_epochs,
    train_step,
    train_update,
)
