"""Tsetlin Machine core — the paper's central datapath, in JAX.

The TM here mirrors the FPGA architecture of the paper:

* a bank of Tsetlin automata (TA) per (class, clause, literal) whose 2N-state
  counters decide include/exclude of each literal,
* clause evaluation as an include-masked AND over literals (+ complements),
* a majority vote (positive/negative polarity clauses) per class,
* **over-provisioning**: the arrays are allocated at `max_classes`/`max_clauses`
  (the paper's pre-synthesis parameters) while *runtime masks* select the active
  subset — the JAX analogue of avoiding FPGA re-synthesis is avoiding re-JIT:
  shapes never change when classes/clauses are enabled at runtime,
* **fault injection**: per-TA AND/OR masks force TA action outputs to stuck-at
  values exactly as the paper's fault controller does (§3.1.2).

Everything is a pure function over explicit state so the whole machine can be
`vmap`-ed over cross-validation orderings / hyperparameter grids and `pjit`-ed
over a device mesh (the paper's goal (ii): accelerated CV + HP search).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch, packing


# ---------------------------------------------------------------------------
# Configuration (the paper's design-time parameters, §3.1)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TMConfig:
    """Design-time parameters — fixed at trace time (≈ FPGA synthesis time).

    `max_classes` / `max_clauses` over-provision resources (§3.1.1); the active
    subset is selected at *runtime* via masks carried in :class:`TMRuntime`.
    """

    n_features: int                  # booleanized input width (iris: 16)
    max_classes: int                 # provisioned classes (≥ active classes)
    max_clauses: int                 # provisioned clauses per class (even)
    n_states: int = 99               # N states per action (TA has 2N states)
    s_policy: str = "standard"       # "standard" | "hardware"  (see DESIGN.md §2)
    boost_true_positive: bool = True # deterministic strengthen on (clause=1,lit=1)
    backend: str = "ref"             # kernel backend name (see kernels/dispatch.py)

    def __post_init__(self):
        if self.max_clauses % 2:
            raise ValueError("max_clauses must be even (half +, half - polarity)")
        if self.n_states < 1:
            raise ValueError("n_states must be >= 1")
        if self.s_policy not in ("standard", "hardware"):
            raise ValueError(f"unknown s_policy {self.s_policy!r}")
        if self.backend not in dispatch.available():
            raise ValueError(
                f"unknown backend {self.backend!r}; "
                f"available: {dispatch.available()}"
            )

    @property
    def n_literals(self) -> int:
        return 2 * self.n_features

    @property
    def state_dtype(self):
        # 2N must fit the dtype; int8 keeps the TA bank tiny (paper: few bits/TA).
        return jnp.int8 if 2 * self.n_states <= 127 else jnp.int16


# ---------------------------------------------------------------------------
# Runtime-controllable knobs (the paper's I/O-port parameters, §3.1)
# ---------------------------------------------------------------------------


class TMRuntime(NamedTuple):
    """Runtime ports: adjustable WITHOUT re-JIT (paper: without re-synthesis).

    * ``s``/``T`` — the runtime hyperparameter ports,
    * ``clause_mask`` — the clause-number port (over-provisioned clauses gated),
    * ``class_mask`` — over-provisioned classes gated until introduced,
    * ``ta_and_mask``/``ta_or_mask`` — the fault-controller mappings (§3.1.2):
      action' = (action AND and_mask) OR or_mask. Fault-free: and=1, or=0.
    """

    s: jax.Array            # scalar f32 — sensitivity
    T: jax.Array            # scalar i32 — vote threshold/target
    clause_mask: jax.Array  # [max_clauses] bool
    class_mask: jax.Array   # [max_classes] bool
    ta_and_mask: jax.Array  # [max_classes, max_clauses, 2f] bool
    ta_or_mask: jax.Array   # [max_classes, max_clauses, 2f] bool


class TMState(NamedTuple):
    """Learnt state: the TA bank. States 1..N => exclude, N+1..2N => include."""

    ta_state: jax.Array  # [max_classes, max_clauses, 2f] int8/int16


def init_state(cfg: TMConfig, key: Optional[jax.Array] = None) -> TMState:
    """TA bank initialised at the decision boundary (states N or N+1).

    The FPGA initialises automata randomly on either side of the boundary;
    with a key we do the same, without a key we use the deterministic N
    (all-exclude) start which the hardware also supports.
    """
    shape = (cfg.max_classes, cfg.max_clauses, cfg.n_literals)
    n = cfg.n_states
    if key is None:
        ta = jnp.full(shape, n, dtype=cfg.state_dtype)
    else:
        coin = jax.random.bernoulli(key, 0.5, shape)
        ta = jnp.where(coin, n + 1, n).astype(cfg.state_dtype)
    return TMState(ta_state=ta)


def init_runtime(
    cfg: TMConfig,
    *,
    s: float = 3.9,
    T: int = 15,
    n_active_classes: Optional[int] = None,
    n_active_clauses: Optional[int] = None,
) -> TMRuntime:
    """Fault-free runtime with the first ``n_active_*`` resources enabled."""
    n_cls = cfg.max_classes if n_active_classes is None else n_active_classes
    n_clz = cfg.max_clauses if n_active_clauses is None else n_active_clauses
    shape = (cfg.max_classes, cfg.max_clauses, cfg.n_literals)
    return TMRuntime(
        s=jnp.float32(s),
        T=jnp.int32(T),
        clause_mask=jnp.arange(cfg.max_clauses) < n_clz,
        class_mask=jnp.arange(cfg.max_classes) < n_cls,
        ta_and_mask=jnp.ones(shape, dtype=bool),
        ta_or_mask=jnp.zeros(shape, dtype=bool),
    )


# ---------------------------------------------------------------------------
# Datapath: literals -> faulted actions -> clauses -> votes (paper Fig. 1)
# ---------------------------------------------------------------------------


def make_literals(x: jax.Array) -> jax.Array:
    """Boolean features -> literal vector [x, ~x] (length 2f)."""
    x = x.astype(bool)
    return jnp.concatenate([x, ~x], axis=-1)


def ta_actions(cfg: TMConfig, state: TMState, rt: TMRuntime) -> jax.Array:
    """Include bits from TA states, with the fault controller applied.

    action = state > N;  action' = (action & and_mask) | or_mask  (§3.1.2).
    """
    include = state.ta_state > cfg.n_states
    return (include & rt.ta_and_mask) | rt.ta_or_mask


def make_literals_packed(xs_packed: jax.Array, n_features: int) -> jax.Array:
    """Packed features [..., ceil(f/32)] u32 -> packed literals (§13 layout).

    The packed twin of :func:`make_literals`: the complement half is a word
    operation, so buffered packed rows become literal words without unpacking.
    """
    return packing.literals_from_packed(xs_packed, n_features)


def ta_actions_packed(cfg: TMConfig, state: TMState, rt: TMRuntime) -> jax.Array:
    """Post-fault include masks, packed to uint32 words (§13 layout).

    This is the include-mask derivation boundary of the packed datapath: the
    int8 TA bank stays unpacked (feedback needs per-literal state), and the
    include plane packs ONCE per batched clause-eval call — O(C·J·L) pack
    work amortized over O(B·C·J·W) evaluation work.
    """
    return packing.pack_include(ta_actions(cfg, state, rt), cfg.n_features)


def clause_polarity(cfg: TMConfig) -> jax.Array:
    """+1 for even-indexed clauses, -1 for odd (half vote for, half against)."""
    return jnp.where(jnp.arange(cfg.max_clauses) % 2 == 0, 1, -1).astype(jnp.int32)


def eval_clauses(
    cfg: TMConfig,
    include: jax.Array,   # [C, J, 2f] bool  (post-fault actions)
    literals: jax.Array,  # [2f] bool
    rt: TMRuntime,
    *,
    training: bool,
) -> jax.Array:
    """Clause outputs [C, J] bool.

    A clause fires iff every included literal is 1. Empty clauses output 1
    during training (so Type I feedback can grow them) and 0 during inference
    (standard TM convention; the paper inherits it from [5]).
    """
    out = dispatch.resolve(cfg.backend).clause_eval(
        include, literals, training=training
    )
    return out & rt.clause_mask[None, :]


def eval_clauses_batch(
    cfg: TMConfig,
    include: jax.Array,   # [C, J, 2f] bool  (post-fault actions)
    literals: jax.Array,  # [B, 2f] bool
    rt: TMRuntime,
    *,
    training: bool,
) -> jax.Array:
    """Batch-first clause outputs [B, C, J] bool.

    The include bank is streamed once per batch (not once per datapoint);
    semantics are row-wise identical to :func:`eval_clauses`.
    """
    out = dispatch.resolve(cfg.backend).clause_eval_batch(
        include, literals, training=training
    )
    return out & rt.clause_mask[None, None, :]


def eval_clauses_batch_packed(
    cfg: TMConfig,
    include_packed: jax.Array,   # [C, J, W] uint32 (packed post-fault actions)
    literals_packed: jax.Array,  # [B, W] uint32
    rt: TMRuntime,
    *,
    training: bool,
) -> jax.Array:
    """Batch-first clause outputs [B, C, J] bool from the packed datapath.

    Bit-identical to :func:`eval_clauses_batch` on the corresponding
    unpacked operands (the kernel contract's packed parity guarantee).
    """
    out = dispatch.resolve(cfg.backend).clause_eval_batch_packed(
        include_packed, literals_packed, training=training
    )
    return out & rt.clause_mask[None, None, :]


def class_sums(cfg: TMConfig, clause_out: jax.Array) -> jax.Array:
    """Per-class vote: sum of +/- polarity clause outputs over the last axis.

    clause_out [..., C, J] -> votes [..., C] int32 (works for single
    datapoints and batch-first [B, C, J] planes alike).
    """
    pol = clause_polarity(cfg)
    return jnp.sum(clause_out.astype(jnp.int32) * pol, axis=-1)


def forward(
    cfg: TMConfig,
    state: TMState,
    rt: TMRuntime,
    x: jax.Array,
    *,
    training: bool = False,
):
    """One datapoint through the datapath. Returns (clause_out [C,J], votes [C])."""
    lits = make_literals(x)
    include = ta_actions(cfg, state, rt)
    clauses = eval_clauses(cfg, include, lits, rt, training=training)
    return clauses, class_sums(cfg, clauses)


def forward_batch(
    cfg: TMConfig,
    state: TMState,
    rt: TMRuntime,
    xs: jax.Array,  # [B, f] bool
    *,
    training: bool = False,
):
    """A batch through the datapath. Returns (clause_out [B,C,J], votes [B,C]).

    ``xs`` is either bool features [B, f] or PACKED features
    [B, ceil(f/32)] uint32 (§13) — the dtype is static under tracing, so
    the branch specializes per representation and packed callers
    (buffer-fed monitoring, packed serving/analysis) route to the
    AND+popcount kernels with no call-site changes. Outputs are
    bit-identical across the two routes.
    """
    if xs.dtype == jnp.uint32:
        lits = make_literals_packed(xs, cfg.n_features)
        include = ta_actions_packed(cfg, state, rt)
        clauses = eval_clauses_batch_packed(
            cfg, include, lits, rt, training=training
        )
    else:
        lits = make_literals(xs)
        include = ta_actions(cfg, state, rt)
        clauses = eval_clauses_batch(cfg, include, lits, rt, training=training)
    return clauses, class_sums(cfg, clauses)


def predict(cfg: TMConfig, state: TMState, rt: TMRuntime, x: jax.Array) -> jax.Array:
    """argmax class over active classes (inactive classes vote -inf)."""
    _, votes = forward(cfg, state, rt, x, training=False)
    votes = jnp.where(rt.class_mask, votes, jnp.iinfo(jnp.int32).min)
    return jnp.argmax(votes)


def predict_batch_(
    cfg: TMConfig, state: TMState, rt: TMRuntime, xs: jax.Array
) -> jax.Array:
    """Unjitted batch-first prediction [B] (composable inside other jits)."""
    _, votes = forward_batch(cfg, state, rt, xs, training=False)
    votes = jnp.where(rt.class_mask[None, :], votes, jnp.iinfo(jnp.int32).min)
    return jnp.argmax(votes, axis=-1)


@partial(jax.jit, static_argnums=0)
def predict_batch(
    cfg: TMConfig, state: TMState, rt: TMRuntime, xs: jax.Array
) -> jax.Array:
    """Batch-first inference over a batch of datapoints (the serving path).

    The clause plane for all B datapoints is one dispatched
    ``clause_eval_batch`` call — the include bank is read once per batch —
    rather than a vmap of per-sample :func:`predict` planes.
    """
    return predict_batch_(cfg, state, rt, xs)


def forward_batch_replicated(
    cfg: TMConfig,
    state: TMState,     # leaves [R, ...]
    rt: TMRuntime,      # masks shared; s/T scalar or [R]
    xs: jax.Array,      # [D, B, f] bool — replica r reads batch r % D
    *,
    training: bool = False,
):
    """Replica-first batch datapath: (clause_out [R,B,C,J], votes [R,B,C]).

    R independent machines evaluate their batch in ONE dispatched
    ``clause_eval_batch_replicated`` contraction; replica ``r`` reproduces
    :func:`forward_batch` on batch ``r % D`` bit-for-bit (the kernel
    contract's stacking guarantee). ``xs`` may be PACKED features
    [D, B, ceil(f/32)] uint32 (§13) — dtype routing, bit-identical.
    """
    if xs.dtype == jnp.uint32:
        lits = make_literals_packed(xs, cfg.n_features)  # [D, B, W]
        include = ta_actions_packed(cfg, state, rt)      # [R, C, J, W]
        clauses = dispatch.resolve(
            cfg.backend
        ).clause_eval_batch_replicated_packed(include, lits, training=training)
    else:
        lits = make_literals(xs)                        # [D, B, 2f]
        include = ta_actions(cfg, state, rt)            # [R, C, J, L]
        clauses = dispatch.resolve(cfg.backend).clause_eval_batch_replicated(
            include, lits, training=training
        )                                               # [R, B, C, J]
    clauses = clauses & rt.clause_mask
    return clauses, class_sums(cfg, clauses)            # [R, B, C]


def predict_batch_replicated_(
    cfg: TMConfig,
    state: TMState,     # leaves [R, ...]
    rt: TMRuntime,      # masks shared; s/T scalar or [R]
    xs: jax.Array,      # [D, B, f] bool — replica r predicts batch r % D
) -> jax.Array:
    """Unjitted replica-first prediction [R, B] (composable inside jits).

    The fleet serving path: :func:`forward_batch_replicated` + the active-
    class argmax (inactive classes vote -inf), per replica.
    """
    _, votes = forward_batch_replicated(cfg, state, rt, xs, training=False)
    votes = jnp.where(rt.class_mask, votes, jnp.iinfo(jnp.int32).min)
    return jnp.argmax(votes, axis=-1)                   # [R, B]


@partial(jax.jit, static_argnums=0)
def predict_batch_replicated(
    cfg: TMConfig, state: TMState, rt: TMRuntime, xs: jax.Array
) -> jax.Array:
    """Jitted :func:`predict_batch_replicated_` — the fleet ``infer`` entry."""
    return predict_batch_replicated_(cfg, state, rt, xs)


# ---------------------------------------------------------------------------
# Budgeted (pruned / weighted) inference — DESIGN.md §16.
# ---------------------------------------------------------------------------


def vote_weights(
    cfg: TMConfig, rt: TMRuntime, weights: Optional[jax.Array] = None
) -> jax.Array:
    """Signed per-clause vote weights: polarity x clause_mask x |weight|.

    ``weights`` is an optional [.., C, J] int plane of positive magnitudes
    (None = unit weights). Returns [C, J] (or [.., C, J]) int32 such that
    ``votes = sum_j clause_out[.., c, j] * vote_weights(...)[.., c, j]``
    reproduces :func:`class_sums` on mask-gated outputs exactly when
    weights are unit — the bitwise bridge between the budgeted vote and
    the plain serving path.
    """
    base = clause_polarity(cfg) * rt.clause_mask.astype(jnp.int32)   # [J]
    if weights is None:
        return jnp.broadcast_to(base, (cfg.max_classes, cfg.max_clauses))
    return weights.astype(jnp.int32) * base


def forward_batch_pruned(
    cfg: TMConfig,
    state: TMState,
    rt: TMRuntime,
    xs: jax.Array,       # [B, f] bool | [B, ceil(f/32)] uint32
    sel: jax.Array,      # [C, M] int32 — clause ids to evaluate, per class
    weights: Optional[jax.Array] = None,  # [C, J] int magnitudes (None = unit)
):
    """Budgeted batch datapath: (clause_out [B,C,M], votes [B,C] i32).

    Only the ``sel``-elected clauses are contracted (compacted include
    banks — the kernel contract's pruned entries), and the class vote
    folds the signed :func:`vote_weights` of the elected clauses. With
    ``sel`` a full permutation and unit weights the int32 vote sums are
    term-for-term a reordering of :func:`forward_batch`'s — bitwise
    identical votes, hence bitwise identical predictions.
    """
    kb = dispatch.resolve(cfg.backend)
    if xs.dtype == jnp.uint32:
        lits = make_literals_packed(xs, cfg.n_features)
        include = ta_actions_packed(cfg, state, rt)
        clauses = kb.clause_eval_batch_pruned_packed(
            include, sel, lits, training=False
        )
    else:
        lits = make_literals(xs)
        include = ta_actions(cfg, state, rt)
        clauses = kb.clause_eval_batch_pruned(
            include, sel, lits, training=False
        )                                                  # [B, C, M]
    swt = vote_weights(cfg, rt, weights)                   # [C, J]
    wsel = jnp.take_along_axis(swt, sel, axis=-1)          # [C, M]
    votes = jnp.sum(clauses.astype(jnp.int32) * wsel[None], axis=-1)
    return clauses, votes


def predict_batch_pruned_(
    cfg: TMConfig, state: TMState, rt: TMRuntime, xs: jax.Array,
    sel: jax.Array, weights: Optional[jax.Array] = None,
) -> jax.Array:
    """Unjitted budgeted prediction [B] (composable inside other jits)."""
    _, votes = forward_batch_pruned(cfg, state, rt, xs, sel, weights)
    votes = jnp.where(rt.class_mask[None, :], votes, jnp.iinfo(jnp.int32).min)
    return jnp.argmax(votes, axis=-1)


@partial(jax.jit, static_argnums=0)
def predict_batch_pruned(
    cfg: TMConfig, state: TMState, rt: TMRuntime, xs: jax.Array,
    sel: jax.Array, weights: Optional[jax.Array] = None,
) -> jax.Array:
    """Jitted :func:`predict_batch_pruned_` — the budgeted serving entry."""
    return predict_batch_pruned_(cfg, state, rt, xs, sel, weights)


def forward_batch_pruned_replicated(
    cfg: TMConfig,
    state: TMState,      # leaves [R, ...]
    rt: TMRuntime,
    xs: jax.Array,       # [D, B, ...] — replica r reads batch r % D
    sel: jax.Array,      # [R, C, M] int32 — per-replica clause rankings
    weights: Optional[jax.Array] = None,  # [R, C, J] int magnitudes
):
    """Replica-first budgeted datapath: (clauses [R,B,C,M], votes [R,B,C]).

    Every replica serves from its OWN ranked clause subset (and weight
    plane) in one contraction over the compacted banks.
    """
    kb = dispatch.resolve(cfg.backend)
    if xs.dtype == jnp.uint32:
        lits = make_literals_packed(xs, cfg.n_features)
        include = ta_actions_packed(cfg, state, rt)
        clauses = kb.clause_eval_batch_pruned_replicated_packed(
            include, sel, lits, training=False
        )
    else:
        lits = make_literals(xs)
        include = ta_actions(cfg, state, rt)
        clauses = kb.clause_eval_batch_pruned_replicated(
            include, sel, lits, training=False
        )                                                  # [R, B, C, M]
    swt = vote_weights(cfg, rt, weights)
    if swt.ndim == 2:
        swt = jnp.broadcast_to(swt, sel.shape[:1] + swt.shape)
    wsel = jnp.take_along_axis(swt, sel, axis=-1)          # [R, C, M]
    votes = jnp.sum(clauses.astype(jnp.int32) * wsel[:, None], axis=-1)
    return clauses, votes                                  # votes [R, B, C]


def predict_batch_pruned_replicated_(
    cfg: TMConfig, state: TMState, rt: TMRuntime, xs: jax.Array,
    sel: jax.Array, weights: Optional[jax.Array] = None,
) -> jax.Array:
    """Unjitted replica-first budgeted prediction [R, B]."""
    _, votes = forward_batch_pruned_replicated(
        cfg, state, rt, xs, sel, weights
    )
    votes = jnp.where(rt.class_mask, votes, jnp.iinfo(jnp.int32).min)
    return jnp.argmax(votes, axis=-1)


@partial(jax.jit, static_argnums=0)
def predict_batch_pruned_replicated(
    cfg: TMConfig, state: TMState, rt: TMRuntime, xs: jax.Array,
    sel: jax.Array, weights: Optional[jax.Array] = None,
) -> jax.Array:
    """Jitted :func:`predict_batch_pruned_replicated_` — the fleet's
    budgeted serve entry (TMService.serve with a compute budget)."""
    return predict_batch_pruned_replicated_(cfg, state, rt, xs, sel, weights)
