"""Online data manager + interleaved learning session (paper §3.5, §4).

The FPGA's online path: datapoints arrive from an application-dependent source,
pass through the cyclic buffer (so accuracy-analysis stalls never drop data),
and are consumed one per request by the TM manager which interleaves training
with inference. ``OnlineSession`` reproduces that control path on the host with
jitted device steps; all device-side state is fixed-shape.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import feedback as fb_mod
from repro.core import tm as tm_mod
from repro.core.tm import TMConfig, TMRuntime, TMState
from repro.data import buffer as buf_mod
from repro.data.memory import DataSource


class SessionState(NamedTuple):
    tm: TMState
    buf: buf_mod.RingBuffer
    step: jax.Array  # int32 — online datapoints consumed


@partial(jax.jit, static_argnums=0)
def _enqueue(cfg: TMConfig, ss: SessionState, x, y):
    new_buf, ok = buf_mod.push(ss.buf, x, y)
    return ss._replace(buf=new_buf), ok


@partial(jax.jit, static_argnums=0)
def _consume(cfg: TMConfig, ss: SessionState, rt: TMRuntime, key):
    """Pop one buffered datapoint and apply one online training step."""
    new_buf, x, y, valid = buf_mod.pop(ss.buf)
    new_tm, aux = fb_mod.train_step(cfg, ss.tm, rt, x, y, key)
    tm = jax.tree.map(lambda a, b: jnp.where(valid, a, b), new_tm, ss.tm)
    out = SessionState(
        tm=tm, buf=new_buf, step=ss.step + valid.astype(jnp.int32)
    )
    return out, valid, aux


class OnlineSession:
    """Host-side driver for interleaved inference + online learning.

    * ``offer(x, y)``     — producer side: push into the cyclic buffer.
    * ``learn_available``  — consumer side: drain up to ``max_points`` buffered
      datapoints through online training (the per-cycle budget of Fig. 3).
    * ``infer(xs)``        — batched inference at any time.
    """

    def __init__(
        self,
        cfg: TMConfig,
        state: TMState,
        rt: TMRuntime,
        *,
        buffer_capacity: int = 64,
        seed: int = 0,
    ):
        self.cfg = cfg
        self.rt = rt
        self._key = jax.random.PRNGKey(seed)
        self.ss = SessionState(
            tm=state,
            buf=buf_mod.make(buffer_capacity, cfg.n_features),
            step=jnp.int32(0),
        )
        self.dropped = 0  # producer-side backpressure events

    def offer(self, x, y) -> bool:
        x = jnp.asarray(x, dtype=bool)
        y = jnp.asarray(y, dtype=jnp.int32)
        self.ss, ok = _enqueue(self.cfg, self.ss, x, y)
        accepted = bool(ok)
        if not accepted:
            self.dropped += 1
        return accepted

    def fill_from(self, source: DataSource, n: int) -> int:
        """Pull ``n`` rows from a data source into the buffer."""
        accepted = 0
        for _ in range(n):
            x, y = source.next_row()
            accepted += self.offer(x, int(y))
        return accepted

    def learn_available(self, max_points: int) -> int:
        """Consume up to ``max_points`` buffered datapoints; returns #trained."""
        trained = 0
        for _ in range(max_points):
            self._key, k = jax.random.split(self._key)
            self.ss, valid, _ = _consume(self.cfg, self.ss, self.rt, k)
            if not bool(valid):
                break
            trained += 1
        return trained

    def infer(self, xs) -> np.ndarray:
        xs = jnp.asarray(xs, dtype=bool)
        return np.asarray(tm_mod.predict_batch(self.cfg, self.ss.tm, self.rt, xs))

    @property
    def buffered(self) -> int:
        return int(self.ss.buf.size)
