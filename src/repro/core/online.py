"""Online data manager + interleaved learning session (paper §3.5, §4).

The FPGA's online path: datapoints arrive from an application-dependent source,
pass through the cyclic buffer (so accuracy-analysis stalls never drop data),
and are consumed one per request by the TM manager which interleaves training
with inference. ``OnlineSession`` reproduces that control path on the host with
jitted device steps; all device-side state is fixed-shape.
"""
from __future__ import annotations

from functools import partial
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import feedback as fb_mod
from repro.core import tm as tm_mod
from repro.core.tm import TMConfig, TMRuntime, TMState
from repro.data import buffer as buf_mod
from repro.data.memory import DataSource
from repro.kernels import packing


class SessionState(NamedTuple):
    """Device-side state of one machine — or, replica-first, of a fleet.

    The single-machine form carries the documented shapes; under
    :func:`_consume_many_replicated` (and :class:`repro.serve.fleet.
    OnlineFleet`) every leaf carries a LEADING replica axis ``[K, ...]``:
    K distinct TA banks, K ring buffers, K step counters.
    """

    tm: TMState
    buf: buf_mod.RingBuffer
    step: jax.Array  # int32 — online datapoints consumed ([K] replicated)


class ChunkAux(NamedTuple):
    """Per-chunk observability from the drain.

    Single-machine shapes below (chunk size K); the replica-first drain
    returns the same fields with a LEADING replica axis ``[R, K]``.
    """

    predicted: jax.Array  # [K] int32 — batched inference under the post-chunk state
    correct: jax.Array    # [K] bool  — predicted == label, invalid rows False
    valid: jax.Array      # [K] bool  — rows actually consumed
    activity: jax.Array   # [K] f32   — per-step TA-update activity


def replica_gate(valid: jax.Array):
    """Per-leaf where(valid, new, old) with valid [R] broadcast over leaves.

    The replica-masked state update shared by the fleet drain and the
    fleet manager's per-replica rollback/snapshot logic."""
    def apply(a, b):
        v = valid.reshape(valid.shape + (1,) * (a.ndim - valid.ndim))
        return jnp.where(v, a, b)
    return apply


@jax.jit
def _take_rows(tree, idx):
    return jax.tree.map(lambda a: a[idx], tree)


def gather_replicas_issue(tree, idx):
    """ISSUE half of :func:`gather_replicas`: slice the named rows of
    every replica-leading leaf as DEVICE values and return immediately.

    JAX dispatch is asynchronous and arrays are immutable, so the
    returned slices stay bit-correct even after the caller functionally
    replaces the plane (activations, drain chunks) — the residency
    layer's deferred-spill path issues the gather here and materializes
    it with :func:`gather_replicas_await` only when the snapshot is
    actually read, off the inter-cohort critical path (DESIGN.md §17).
    The whole tree is sliced in ONE jitted dispatch — per-leaf eager
    gathers on a sharded plane pay ~ms of dispatch each, which at
    K=4096 dominated the cohort-move path.
    """
    return _take_rows(tree, jnp.asarray(np.asarray(idx)))


def gather_replicas_await(tree):
    """AWAIT half: materialize an issued gather to HOST numpy (blocks
    until the device slices are ready)."""
    return jax.tree.map(np.asarray, tree)


def gather_replicas(tree, idx):
    """Rows ``idx`` of every replica-leading leaf, as HOST numpy.

    The residency layer's synchronous evict path: pull the named
    device-plane slots into one stacked host tree (``[len(idx), ...]``
    per leaf) with a blocking eager gather per leaf. This is the
    ``batched_moves=False`` oracle/baseline datapath and deliberately
    stays per-leaf eager — the jitted one-dispatch slice is the batched
    path's (:func:`gather_replicas_issue`) half of the §17 win.
    """
    idx = np.asarray(idx)
    return gather_replicas_await(jax.tree.map(lambda a: a[idx], tree))


def scatter_replicas(tree, idx, values):
    """Write stacked ``values`` (leading ``len(idx)``) into rows ``idx``
    of every replica-leading leaf. The residency layer's activate path;
    dtypes are pinned to the destination leaf (int8 TA banks, uint32
    packed words and bool rows survive the host round-trip bit for bit).
    """
    idx = jnp.asarray(idx)
    return jax.tree.map(
        lambda a, v: a.at[idx].set(jnp.asarray(v, a.dtype)), tree, values
    )


@jax.jit
def activate_replicas(plane, act_plane, mask):
    """Per-slot mask-select activation: slot ``r`` takes ``act_plane``
    where ``mask[r]``, else keeps ``plane`` — ONE fused elementwise
    dispatch for a whole activation cohort (the batched-residency twin
    of :func:`scatter_replicas`, DESIGN.md §17).

    ``act_plane`` is a SLOT-INDEXED host tree (``[R, ...]`` per leaf,
    zeros in inactive rows), so there is no index scatter at all — no
    duplicate-index ordering hazard, and the select fuses with whatever
    jitted work follows in the same dispatch. Dtypes are pinned to the
    destination leaf, like the scatter path.
    """
    gate = replica_gate(mask)
    return jax.tree.map(
        lambda new, old: gate(new.astype(old.dtype), old), act_plane, plane
    )


@partial(jax.jit, static_argnums=(0, 1), static_argnames=("monitor",))
def _consume_many_replicated(
    cfg: TMConfig,
    k: int,                 # static chunk size (one trace per chunk size)
    ss: SessionState,       # leaves [R, ...]
    rt: TMRuntime,          # masks shared; s/T scalar or [R]
    limit: jax.Array,       # [R] i32 — per-replica row budget for this chunk
    keys: jax.Array,        # [R] chunk keys (one RNG stream per replica)
    *,
    monitor: bool = True,   # static: False skips the monitoring pass (aux=None)
) -> tuple[SessionState, jax.Array, Optional[ChunkAux]]:
    """Drain up to ``min(k, limit[r], buffered[r])`` rows from EVERY replica
    in ONE jitted call — the fleet form of the Fig-3 online drain.

    The TA updates keep the FPGA's serial row-order semantics per replica
    (``lax.scan``: feedback at step t sees state from t-1) while each step
    advances all R machines in a single fused
    ``feedback_step_replicated`` plane (D = R: every machine owns its data
    stream). The per-datapoint inference-mode monitoring is hoisted out of
    the scan and done once per chunk as ONE replica-first batched clause
    contraction under the post-chunk states.

    Replica ``r`` is bit-identical to running :func:`_consume_many` alone
    with ``(ss[r], limit[r], keys[r])`` — the replicated kernels' stacking
    guarantee plus per-replica RNG streams (split per chunk key exactly as
    the single-machine path splits its one key).

    PACKED buffers (uint32 rows, DESIGN.md §13) are transparent here: each
    popped row unpacks once for the elementwise TA feedback (pack/unpack is
    exact, so the trained states are bit-identical to the unpacked path)
    while the hoisted monitoring pass consumes the packed rows directly —
    ``predict_batch_replicated_`` routes them to the AND+popcount kernels.
    """
    R = ss.step.shape[0]
    limit = jnp.asarray(limit, dtype=jnp.int32)
    packed = ss.buf.data_x.dtype == jnp.uint32          # static at trace time

    step_keys = jax.vmap(lambda kk: jax.random.split(kk, k))(keys)
    step_keys = jnp.swapaxes(step_keys, 0, 1)           # [k, R, key]

    def body(carry, inp):
        buf, tm, n = carry
        i, kk = inp                                     # scalar i32, [R] keys
        new_buf, x, y, nonempty = jax.vmap(buf_mod.pop)(buf)
        valid = (i < limit) & nonempty                  # [R]
        xb = packing.unpack_bits(x, cfg.n_features) if packed else x
        new_tm, _, activity = fb_mod.train_update_replicated(
            cfg, tm, rt, xb, y, kk
        )
        tm = jax.tree.map(replica_gate(valid), new_tm, tm)
        buf = jax.tree.map(replica_gate(valid), new_buf, buf)
        n = n + valid.astype(jnp.int32)
        return (buf, tm, n), (x, y, valid, jnp.where(valid, activity, 0.0))

    idx = jnp.arange(k, dtype=jnp.int32)
    (buf, tm, n), (xs, ys, valids, activity) = jax.lax.scan(
        body, (ss.buf, ss.tm, jnp.zeros((R,), jnp.int32)), (idx, step_keys)
    )

    # Hoisted monitoring: ONE replica-first batched inference contraction
    # over every replica's chunk. Compiled out entirely when unwanted (a
    # jitted return value can't be DCE'd).
    aux = None
    if monitor:
        preds = tm_mod.predict_batch_replicated_(
            cfg, tm, rt, jnp.swapaxes(xs, 0, 1)         # [R, k, f|Wf]
        )
        aux = ChunkAux(
            predicted=preds.astype(jnp.int32),          # [R, k]
            correct=(preds == jnp.swapaxes(ys, 0, 1)) & jnp.swapaxes(valids, 0, 1),
            valid=jnp.swapaxes(valids, 0, 1),
            activity=jnp.swapaxes(activity, 0, 1),
        )
    return SessionState(tm=tm, buf=buf, step=ss.step + n), n, aux


@partial(jax.jit, static_argnums=(0, 1), static_argnames=("monitor",))
def _consume_many(
    cfg: TMConfig,
    k: int,                 # static chunk size (one trace per chunk size)
    ss: SessionState,
    rt: TMRuntime,
    limit: jax.Array,       # traced i32 — consume at most this many rows
    key: jax.Array,
    *,
    monitor: bool = True,   # static: False skips the monitoring pass (aux=None)
) -> tuple[SessionState, jax.Array, Optional[ChunkAux]]:
    """Drain up to ``min(k, limit, buffered)`` datapoints in ONE jitted call.

    The TA updates keep the FPGA's serial row-order semantics (``lax.scan``:
    feedback at step t sees state from t-1), but the per-datapoint
    inference-mode monitoring that :func:`~repro.core.feedback.train_step`
    would run inside the loop is hoisted out and done once per chunk as a
    batch-first clause eval under the post-chunk state — the include bank is
    read K times for learning (inherent to serial semantics) and once, not K
    times, for monitoring.

    This is semantically the R = 1 slice of :func:`_consume_many_replicated`
    (the fleet drain), but keeps a specialized single-machine body: the
    replicated plane's per-step vmapped pop / key-split / gather machinery
    is pure overhead at R = 1 (~1.3x on the compiled chunk: 20.0 vs 25.7
    us/point, best-of-30 A/B on the iris machine). The two
    implementations are pinned bitwise against each other by the K = 1
    fleet parity suite (tests/test_fleet.py), which is a stronger check
    than sharing the body would be.

    PACKED buffers (uint32 rows, §13): popped rows unpack once for the
    elementwise feedback; the hoisted monitoring pass stays packed (dtype
    routing in ``predict_batch_``). Bit-identical to the unpacked drain.
    """
    limit = jnp.asarray(limit, dtype=jnp.int32)
    packed = ss.buf.data_x.dtype == jnp.uint32          # static at trace time

    def body(carry, inp):
        buf, tm, n = carry
        i, kk = inp
        new_buf, x, y, nonempty = buf_mod.pop(buf)
        valid = (i < limit) & nonempty
        xb = packing.unpack_bits(x, cfg.n_features) if packed else x
        new_tm, _, activity = fb_mod.train_update(cfg, tm, rt, xb, y, kk)
        tm = jax.tree.map(lambda a, b: jnp.where(valid, a, b), new_tm, tm)
        buf = jax.tree.map(lambda a, b: jnp.where(valid, a, b), new_buf, buf)
        n = n + valid.astype(jnp.int32)
        return (buf, tm, n), (x, y, valid, jnp.where(valid, activity, 0.0))

    idx = jnp.arange(k, dtype=jnp.int32)
    keys = jax.random.split(key, k)
    (buf, tm, n), (xs, ys, valids, activity) = jax.lax.scan(
        body, (ss.buf, ss.tm, jnp.int32(0)), (idx, keys)
    )

    # Hoisted monitoring: one batched inference pass over the chunk. Skipped
    # entirely (not just discarded — a jitted return value can't be DCE'd)
    # when the caller doesn't want it.
    aux = None
    if monitor:
        preds = tm_mod.predict_batch_(cfg, tm, rt, xs)
        aux = ChunkAux(
            predicted=preds.astype(jnp.int32),
            correct=(preds == ys) & valids,
            valid=valids,
            activity=activity,
        )
    return SessionState(tm=tm, buf=buf, step=ss.step + n), n, aux


class OnlineSession:
    """Host-side driver for interleaved inference + online learning.

    * ``offer(x, y)``     — producer side: push into the cyclic buffer.
    * ``learn_available``  — consumer side: drain up to ``max_points`` buffered
      datapoints through online training (the per-cycle budget of Fig. 3).
    * ``infer(xs)``        — batched inference at any time.

    Since the TMService redesign this is a compatibility shim: the K = 1
    slice of :class:`repro.serve.service.TMService` (which keeps the
    specialized single-machine drain body on this slice), exposing the
    historical scalar-shaped ``ss``/``step``/aux views. Pinned bitwise to
    the pre-redesign implementation by tests/test_service.py.
    """

    def __init__(
        self,
        cfg: TMConfig,
        state: TMState,
        rt: TMRuntime,
        *,
        buffer_capacity: int = 64,
        chunk: int = 16,
        seed: int = 0,
    ):
        from repro.serve.service import ServiceConfig, TMService

        # seed as a 1-sequence: the service then consumes PRNGKey(seed)
        # exactly like the pre-redesign session (no fold_in).
        self._svc = TMService(cfg, state, ServiceConfig(
            replicas=1, buffer_capacity=buffer_capacity, chunk=chunk,
            seed=[int(seed)],
        ), rt=rt)

    @classmethod
    def _from_service(cls, svc) -> "OnlineSession":
        if svc.n_replicas != 1:
            raise ValueError("OnlineSession shims a K = 1 service only")
        sess = cls.__new__(cls)
        sess._svc = svc
        return sess

    @property
    def service(self):
        """The fleet-native surface this shim fronts (K = 1)."""
        return self._svc

    @property
    def cfg(self) -> TMConfig:
        return self._svc.cfg

    @property
    def rt(self) -> TMRuntime:
        return self._svc.rt

    @property
    def chunk(self) -> int:
        return self._svc.chunk

    @property
    def ss(self) -> SessionState:
        """The historical single-machine view: every leaf squeezed of its
        leading K = 1 replica axis."""
        return jax.tree.map(lambda a: a[0], self._svc.ss)

    @ss.setter
    def ss(self, value: SessionState):
        self._svc.ss = jax.tree.map(lambda a: jnp.asarray(a)[None], value)

    @property
    def dropped(self) -> int:
        return int(self._svc.dropped[0])  # backpressure events

    def offer(self, x, y) -> bool:
        return self._svc.submit(0, x, y)

    def fill_from(self, source: DataSource, n: int) -> int:
        """Pull ``n`` rows from a data source into the buffer."""
        accepted = 0
        for _ in range(n):
            x, y = source.next_row()
            accepted += self.offer(x, int(y))
        return accepted

    def learn_available(
        self,
        max_points: int,
        on_chunk: Optional[Callable[[ChunkAux], None]] = None,
    ) -> int:
        """Consume up to ``max_points`` buffered datapoints; returns #trained.

        Drains in chunks of ``self.chunk`` per jitted call (one device
        dispatch per chunk instead of one per datapoint); the final partial
        chunk is handled by the traced ``limit`` port, so chunk size never
        retraces.

        ``on_chunk`` (optional) receives each chunk's :class:`ChunkAux` —
        the serving-side accuracy/activity observability of the paper's
        Fig. 3 analysis block, in the historical single-machine shapes
        ([chunk], no replica axis). Without a callback the monitoring pass
        is compiled out entirely (``monitor=False``), so observability
        costs nothing unless requested.
        """
        cb = None if on_chunk is None else (
            lambda aux: on_chunk(jax.tree.map(lambda a: a[0], aux))
        )
        return int(self._svc.drain(max_points, on_chunk=cb)[0])

    def infer(self, xs) -> np.ndarray:
        return self._svc.serve(xs)[0]

    @property
    def buffered(self) -> int:
        return int(self._svc.buffered[0])
