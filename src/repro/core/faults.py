"""Fault controller (paper §3.1.2, §5.3).

Stuck-at faults are injected by forcing TA action outputs through AND/OR
masks: ``action' = (action & and_mask) | or_mask``. Fault-free operation is
and=1 / or=0. The masks live in :class:`~repro.core.tm.TMRuntime`, are
addressable per-TA, and can be rewritten at runtime without recompilation —
exactly the paper's microcontroller-programmable fault mappings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.tm import TMConfig, TMRuntime


def fault_free_masks(cfg: TMConfig) -> tuple[jax.Array, jax.Array]:
    shape = (cfg.max_classes, cfg.max_clauses, cfg.n_literals)
    return jnp.ones(shape, dtype=bool), jnp.zeros(shape, dtype=bool)


def even_spread_stuck_at(
    cfg: TMConfig,
    fraction: float,
    stuck_value: int,
    *,
    offset: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Evenly-spread stuck-at faults over the flattened TA bank.

    Mirrors the paper's Python script: "an equal spread of fault mappings
    across the TAs" (§5.3.1) — every k-th TA is faulted, k = 1/fraction.

    Returns (and_mask, or_mask) as numpy bool arrays.
    """
    shape = (cfg.max_classes, cfg.max_clauses, cfg.n_literals)
    total = int(np.prod(shape))
    n_faults = int(round(total * fraction))
    and_mask = np.ones(total, dtype=bool)
    or_mask = np.zeros(total, dtype=bool)
    if n_faults > 0:
        idx = (np.floor(np.arange(n_faults) * (total / n_faults)).astype(np.int64)
               + offset) % total
        if stuck_value == 0:
            and_mask[idx] = False   # ANDed signal 0 => output always 0
        else:
            or_mask[idx] = True     # ORed signal 1 => output always 1
    return and_mask.reshape(shape), or_mask.reshape(shape)


def random_stuck_at(
    cfg: TMConfig,
    fraction: float,
    stuck_value: int,
    seed: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Uniform-random stuck-at faults (without replacement)."""
    shape = (cfg.max_classes, cfg.max_clauses, cfg.n_literals)
    total = int(np.prod(shape))
    n_faults = int(round(total * fraction))
    rng = np.random.default_rng(seed)
    idx = rng.choice(total, size=n_faults, replace=False)
    and_mask = np.ones(total, dtype=bool)
    or_mask = np.zeros(total, dtype=bool)
    if stuck_value == 0:
        and_mask[idx] = False
    else:
        or_mask[idx] = True
    return and_mask.reshape(shape), or_mask.reshape(shape)


def packed_masks(cfg: TMConfig, rt: TMRuntime) -> tuple[jax.Array, jax.Array]:
    """The runtime's fault mappings, packed to the §13 literal-word layout.

    The fault controller is a bitwise circuit, so it commutes with packing:

        pack((include & and) | or) == (pack(include) & pack(and)) | pack(or)

    (both sides have zero tail bits — packing zero-fills, AND keeps zeros,
    and the OR mask's packed tail is zero). A packed datapath can therefore
    apply stuck-at faults directly on include words; the regression test in
    tests/test_packing.py pins this homomorphism against the pre-pack
    application used by ``tm.ta_actions_packed``.
    """
    from repro.kernels import packing

    return (
        packing.pack_include(rt.ta_and_mask, cfg.n_features),
        packing.pack_include(rt.ta_or_mask, cfg.n_features),
    )


def apply_packed(
    include_packed: jax.Array, and_packed: jax.Array, or_packed: jax.Array
) -> jax.Array:
    """Packed-domain fault controller: action' words from action words."""
    return (include_packed & and_packed) | or_packed


def stuck_at_runtime(
    cfg: TMConfig,
    rt: TMRuntime,
    fraction: float,
    stuck_value: int,
    *,
    seed: int | None = None,
    offset: int = 0,
) -> TMRuntime:
    """One-call §5.3 injection: build a stuck-at mask set and write it in.

    ``seed=None`` gives the paper's deterministic even spread
    (:func:`even_spread_stuck_at`, reproducible with no RNG — the traffic
    harness relies on this for its bitwise single-caller replays);
    an integer seed draws :func:`random_stuck_at` faults instead.
    """
    if seed is None:
        masks = even_spread_stuck_at(cfg, fraction, stuck_value,
                                     offset=offset)
    else:
        masks = random_stuck_at(cfg, fraction, stuck_value, seed)
    return inject(rt, *masks)


def inject(rt: TMRuntime, and_mask, or_mask) -> TMRuntime:
    """Write new fault mappings into the runtime (microcontroller write)."""
    return rt._replace(
        ta_and_mask=jnp.asarray(and_mask, dtype=bool),
        ta_or_mask=jnp.asarray(or_mask, dtype=bool),
    )


def clear(cfg: TMConfig, rt: TMRuntime) -> TMRuntime:
    a, o = fault_free_masks(cfg)
    return rt._replace(ta_and_mask=a, ta_or_mask=o)
