"""Serving launcher: `python -m repro.launch.serve --arch <id>`.

Runs the batched prefill+decode engine on a reduced config (CPU) or the full
config (--full, cluster). Demonstrates the same serve_step the decode dry-run
cells lower.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import params as P
from repro.models import transformer
from repro.serve.engine import Engine, EngineConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.get_config(args.arch) if args.full
           else configs.get_smoke_config(args.arch))
    if cfg.embeds_input:
        raise SystemExit(f"{args.arch} takes stub embeddings; use the "
                         "examples/serve_lm.py driver for embed inputs")
    specs = transformer.model_specs(cfg)
    prm = P.materialize(specs, jax.random.PRNGKey(args.seed), jnp.float32)

    ec = EngineConfig(
        max_seq=args.prompt_len + args.max_new,
        batch_slots=args.batch,
        temperature=args.temperature,
    )
    eng = Engine(cfg, prm, ec, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           (args.batch, args.prompt_len)).astype(np.int32)
    t0 = time.time()
    out = eng.generate(prompts, args.max_new)
    dt = time.time() - t0
    print(f"arch={cfg.arch_id} generated {out.shape} in {dt:.2f}s "
          f"({args.batch*args.max_new/dt:.1f} tok/s)")
    print(out[:, :16])


if __name__ == "__main__":
    main()
