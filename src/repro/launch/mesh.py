"""Production mesh construction.

Functions, not module-level constants — importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before the first jax init).

Mesh axes:
  pod   — across pods (2 in the multi-pod dry-run); DP/FSDP outer axis
  data  — within-pod data parallel / FSDP axis (16)
  model — TP / EP / SP axis (16; maps to the v5e 2D torus's second dim)
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False, n_pods: int = 2):
    shape = (n_pods, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return jax.make_mesh((n // model, model), ("data", "model"))
