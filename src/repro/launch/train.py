"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

Single-host CPU runs use reduced (smoke) configs by default; pass --full to
train the full config (requires a real cluster). The loop is the
fault-tolerant driver in repro.train.loop (checkpoint/restart, straggler
watch, nan-watchdog).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import ShapeConfig
from repro.data import synthetic
from repro.models import params as P
from repro.models import transformer
from repro.train import loop as loop_mod
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (configs.get_config(args.arch) if args.full
           else configs.get_smoke_config(args.arch))
    tc = ts_mod.TrainConfig(
        opt=opt_mod.OptConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(args.steps // 20, 5)),
        microbatches=args.microbatches,
        grad_compress=args.grad_compress,
    )
    specs = transformer.model_specs(cfg)
    prm = P.materialize(specs, jax.random.PRNGKey(args.seed), jnp.float32)
    state = ts_mod.init_state(tc, prm)
    n_params = P.count_params(specs)
    print(f"arch={cfg.arch_id} params={n_params/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch}x{args.seq}")

    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    data = synthetic.token_batches(cfg, shape, seed=args.seed)

    step_fn = jax.jit(lambda s, b: ts_mod.train_step(cfg, tc, s, b),
                      donate_argnums=(0,))
    lc = loop_mod.LoopConfig(
        total_steps=args.steps, checkpoint_every=args.ckpt_every,
        checkpoint_dir=args.ckpt_dir,
    )
    state = loop_mod.resume_or_init(lc, state)
    state, report = loop_mod.run(lc, state, step_fn, data)
    print(f"done: steps_run={report.steps_run} "
          f"final_loss={report.losses[-1] if report.losses else None} "
          f"faults={len(report.fault_events)} "
          f"stragglers={len(report.straggler_steps)} "
          f"restores={report.restores}")


if __name__ == "__main__":
    main()
