import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This proves the distribution config is coherent without hardware: parameters,
optimizer state, inputs and caches are ShapeDtypeStructs (zero allocation);
`jit(...).lower().compile()` runs the full GSPMD partitioning pipeline for the
production meshes:

    single-pod: (data=16, model=16)            = 256 chips
    multi-pod:  (pod=2, data=16, model=16)     = 512 chips

Artifacts per cell (memory analysis, cost analysis, collective stats, HLO
text) are dumped under artifacts/dryrun/ for the roofline model
(repro.roofline.model) and EXPERIMENTS.md.

Usage:
  python -m repro.launch.dryrun --arch gemma3-1b --shape decode_32k --mesh multi
  python -m repro.launch.dryrun --all [--mesh both] [--subprocess]
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.configs.base import SHAPES, ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.launch import mesh as mesh_mod
from repro.models import params as P
from repro.models import stubs, transformer
from repro.roofline import hlo_parse
from repro.train import optimizer as opt_mod
from repro.train import train_step as ts_mod

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")


def _policy_for(cfg: ModelConfig, shape: ShapeConfig) -> shd.ShardingPolicy:
    if shape.kind == "train":
        return shd.ShardingPolicy()
    # Serving is weight-stationary: NO FSDP (a per-step weight all-gather
    # would dominate decode), experts sharded over `data` (EP all-to-all),
    # expert_ff/vocab/heads TP over `model`. SP shards very long sequences.
    rules = dict(shd.DEFAULT_RULES)
    rules["experts"] = "data"
    seq_axis = "model" if (shape.kind == "decode"
                           and shape.global_batch < 16) else None
    return shd.ShardingPolicy(rules=rules, fsdp=False, seq_axis=seq_axis)


def _moe_groups(cfg: ModelConfig, mesh) -> int:
    if cfg.moe is None:
        return 1
    g = 1
    for ax in ("pod", "data"):
        if ax in mesh.shape:
            g *= mesh.shape[ax]
    return g


def build_cell(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Returns (jitted_fn, example_args_abstract) for one cell."""
    policy = _policy_for(cfg, shape)
    specs = transformer.model_specs(cfg)
    # Serving cells deploy bf16 weights (standard practice, and half the
    # weight-gather traffic); training keeps fp32 masters + bf16 compute.
    p_dtype = (jnp.dtype(cfg.param_dtype) if shape.kind == "train"
               else jnp.dtype(cfg.compute_dtype))
    params_abs = P.abstract(specs, p_dtype)
    p_shard = shd.param_shardings(specs, mesh, policy)
    batch_abs = stubs.input_specs(cfg, shape)

    if shape.kind == "train":
        tc = ts_mod.TrainConfig(
            opt=opt_mod.OptConfig(moment_dtype=cfg.adam_dtype),
            microbatches=cfg.train_microbatches,
            moe_num_groups=_moe_groups(cfg, mesh),
        )
        mu_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(cfg.adam_dtype)),
            params_abs)
        state_abs = ts_mod.TrainState(
            params=params_abs,
            opt=opt_mod.OptState(
                step=jax.ShapeDtypeStruct((), jnp.int32),
                mu=mu_abs, nu=mu_abs),
            compress=None,
        )
        state_shard = ts_mod.TrainState(
            params=p_shard,
            opt=opt_mod.OptState(
                step=shd.NamedSharding(mesh, shd.PS()),
                mu=p_shard, nu=p_shard),
            compress=None,
        )
        b_shard = shd.batch_shardings(batch_abs, mesh, policy)

        def step(state, batch):
            new_state, metrics = ts_mod.train_step(cfg, tc, state, batch)
            # Pin the output placement: the updated params/moments stay FSDP-
            # sharded (otherwise GSPMD may replicate them through the update,
            # turning the gradient reduce-scatter into a full all-reduce).
            new_state = jax.lax.with_sharding_constraint(new_state, state_shard)
            return new_state, metrics

        fn = jax.jit(step, in_shardings=(state_shard, b_shard),
                     donate_argnums=(0,))
        return fn, (state_abs, batch_abs)

    if shape.kind == "prefill":
        b_shard = shd.batch_shardings(batch_abs, mesh, policy)
        pc_shard = shd.cache_shardings(
            transformer.cache_struct(cfg, shape.global_batch, shape.seq_len),
            mesh, policy)

        def prefill_fn(params, batch):
            logits, cache = transformer.prefill(cfg, params, batch,
                                                shape.seq_len)
            cache = jax.lax.with_sharding_constraint(cache, pc_shard)
            return logits, cache

        fn = jax.jit(prefill_fn, in_shardings=(p_shard, b_shard))
        return fn, (params_abs, batch_abs)

    # decode
    cache_abs = batch_abs.pop("cache")
    c_shard = shd.cache_shardings(cache_abs, mesh, policy)
    b_shard = shd.batch_shardings(batch_abs, mesh, policy)

    def serve_step(params, batch, cache):
        logits, new_cache = transformer.decode_step(cfg, params, batch, cache)
        new_cache = jax.lax.with_sharding_constraint(new_cache, c_shard)
        return logits, new_cache

    fn = jax.jit(serve_step, in_shardings=(p_shard, b_shard, c_shard),
                 donate_argnums=(2,))
    return fn, (params_abs, batch_abs, cache_abs)


def build_tm_cell(mesh):
    """The paper's technique on the production mesh: the (s x T x orderings)
    cross-validation/HP-search grid as ONE program, replicas sharded over
    every mesh axis (goal (ii) at pod scale). 8 x 4 x 128 = 4096 TM replicas
    train 10 epochs on 30-row offline sets and report validation accuracy."""
    from jax.sharding import NamedSharding, PartitionSpec as PS

    from repro.configs.tm_iris import CONFIG as TM_SYS
    from repro.core import hpsearch

    cfg = TM_SYS.tm
    O, n_off, n_val, f = 128, 30, 60, cfg.n_features
    s_grid = jax.ShapeDtypeStruct((16,), jnp.float32)
    T_grid = jax.ShapeDtypeStruct((4,), jnp.int32)
    repl = NamedSharding(mesh, PS())
    # s-grid over `data`, orderings over `model`: 16 x 4 x 128 = 8192 TM
    # replicas, 32/device at 256 chips (pod axis replicates when present).
    osh = NamedSharding(mesh, PS("model"))
    gsh = NamedSharding(mesh, PS("data"))

    off = (jax.ShapeDtypeStruct((O, n_off, f), jnp.bool_),
           jax.ShapeDtypeStruct((O, n_off), jnp.int32))
    val = (jax.ShapeDtypeStruct((O, n_val, f), jnp.bool_),
           jax.ShapeDtypeStruct((O, n_val), jnp.int32))
    keys = jax.ShapeDtypeStruct((O, 2), jnp.uint32)

    def grid_fn(s_grid, T_grid, off, val, keys):
        return hpsearch.grid_search_device(cfg, s_grid, T_grid, off, val,
                                           keys, 10)

    fn = jax.jit(
        grid_fn,
        in_shardings=(gsh, repl,
                      (osh, osh), (osh, osh), osh),
        out_shardings=NamedSharding(mesh, PS("data", None, "model")),
    )
    return fn, (s_grid, T_grid, off, val, keys)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             save_hlo: bool = True) -> dict:
    if arch in ("tm-iris", "tm_iris"):
        return run_tm_cell(mesh_kind, out_dir, save_hlo)
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "skip", "reason": None,
    }
    if shape_name == "long_500k" and not cfg.supports_long_context:
        result["reason"] = "pure full-attention arch (DESIGN.md skip table)"
        return result

    if mesh_kind.startswith("multi"):
        n_pods = int(mesh_kind[5:]) if len(mesh_kind) > 5 else 2
        mesh = mesh_mod.make_production_mesh(multi_pod=True, n_pods=n_pods)
    else:
        mesh = mesh_mod.make_production_mesh(multi_pod=False)
    n_dev = mesh.devices.size
    t0 = time.time()
    from repro.distributed import autoshard
    expert_axis = "model" if shape.kind == "train" else "data"
    with mesh, autoshard.use(mesh, moe_expert_axis=expert_axis):
        fn, args = build_cell(cfg, shape, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_d = {
        k: int(getattr(mem, k))
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes")
        if hasattr(mem, k)
    }
    cost = compiled.cost_analysis() or {}
    cost_d = {k: float(v) for k, v in cost.items()
              if isinstance(v, (int, float))}
    hlo = compiled.as_text()
    coll = hlo_parse.parse_collectives(hlo, n_dev)

    result.update({
        "status": "ok",
        "n_devices": n_dev,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": mem_d,
        "cost": cost_d,
        "collectives": {
            "bytes_by_op": coll.bytes_by_op,
            "count_by_op": coll.count_by_op,
            "wire_bytes_by_op": coll.wire_bytes_by_op,
            "total_wire_bytes": coll.total_wire_bytes,
        },
        "param_count": cfg.param_count(),
        "active_param_count": cfg.active_param_count(),
    })
    os.makedirs(out_dir, exist_ok=True)
    stem = f"{arch.replace('.', '_')}__{shape_name}__{mesh_kind}"
    if save_hlo:
        with open(os.path.join(out_dir, stem + ".hlo.txt"), "w") as f:
            f.write(hlo)
    with open(os.path.join(out_dir, stem + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def run_tm_cell(mesh_kind: str, out_dir: str, save_hlo: bool = True) -> dict:
    """Lower + compile the TM hp-search grid on the production mesh."""
    if mesh_kind.startswith("multi"):
        mesh = mesh_mod.make_production_mesh(multi_pod=True)
    else:
        mesh = mesh_mod.make_production_mesh(multi_pod=False)
    t0 = time.time()
    with mesh:
        fn, args = build_tm_cell(mesh)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = hlo_parse.parse_collectives(hlo, mesh.devices.size)
    result = {
        "arch": "tm-iris", "shape": "hpsearch_grid", "mesh": mesh_kind,
        "status": "ok", "n_devices": int(mesh.devices.size),
        "compile_s": round(time.time() - t0, 2),
        "lower_s": 0.0,
        "replicas": 16 * 4 * 128,
        "memory": {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes")
            if hasattr(mem, k)
        },
        "cost": {k: float(v)
                 for k, v in (compiled.cost_analysis() or {}).items()
                 if isinstance(v, (int, float))},
        "collectives": {
            "count_by_op": coll.count_by_op,
            "total_wire_bytes": coll.total_wire_bytes,
        },
        "param_count": 0, "active_param_count": 0,
    }
    os.makedirs(out_dir, exist_ok=True)
    stem = f"tm-iris__hpsearch_grid__{mesh_kind}"
    if save_hlo:
        with open(os.path.join(out_dir, stem + ".hlo.txt"), "w") as f:
            f.write(hlo)
    with open(os.path.join(out_dir, stem + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    return result


def all_cells(mesh_kinds):
    for arch in configs.ARCH_IDS:
        cfg = configs.get_config(arch)
        for shape_name in SHAPES:
            if shape_name == "long_500k" and not cfg.supports_long_context:
                continue
            for mk in mesh_kinds:
                yield configs.get_config(arch).arch_id, shape_name, mk


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "multi4", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in a fresh process (bounded memory)")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--no-hlo", action="store_true")
    args = ap.parse_args()

    mesh_kinds = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    out = os.path.abspath(args.out)

    if args.all:
        cells = list(all_cells(mesh_kinds))
        failures = 0
        for i, (arch, shape_name, mk) in enumerate(cells):
            stem = f"{arch.replace('.', '_')}__{shape_name}__{mk}"
            if os.path.exists(os.path.join(out, stem + ".json")):
                print(f"[{i+1}/{len(cells)}] {stem}: cached")
                continue
            if args.subprocess:
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape_name, "--mesh", mk,
                       "--out", out] + (["--no-hlo"] if args.no_hlo else [])
                t0 = time.time()
                r = subprocess.run(cmd, capture_output=True, text=True)
                ok = r.returncode == 0
                print(f"[{i+1}/{len(cells)}] {stem}: "
                      f"{'ok' if ok else 'FAIL'} ({time.time()-t0:.0f}s)")
                if not ok:
                    failures += 1
                    print(r.stdout[-2000:])
                    print(r.stderr[-2000:])
            else:
                try:
                    res = run_cell(arch, shape_name, mk, out,
                                   save_hlo=not args.no_hlo)
                    print(f"[{i+1}/{len(cells)}] {stem}: {res['status']}")
                except Exception:
                    failures += 1
                    traceback.print_exc()
        sys.exit(1 if failures else 0)

    assert args.arch and args.shape
    for mk in mesh_kinds:
        res = run_cell(args.arch, args.shape, mk, out,
                       save_hlo=not args.no_hlo)
        print(json.dumps({k: v for k, v in res.items()
                          if k not in ("collectives",)}, indent=1))
        if res["status"] == "ok":
            print("collective wire bytes:",
                  res["collectives"]["total_wire_bytes"])


if __name__ == "__main__":
    main()
