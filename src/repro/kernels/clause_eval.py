"""Pallas TPU kernel: clause evaluation as an MXU matvec.

TPU adaptation of the paper's 2-cycle clause datapath (DESIGN.md §2/§8):
the FPGA computes each clause as a wide AND over included literals with
dedicated LUT trees. On TPU we recast the AND-reduction as an **int8 matmul
on the MXU**:

    violations[c,j] = sum_k include[c,j,k] * (1 - literal[k])
    n_included[c,j] = sum_k include[c,j,k]

    clause fires      <=> violations == 0
    clause is "empty" <=> n_included == 0  (training: fires; inference: not)

Both sums come from ONE [CJ, L] x [L, 2] int8 matmul (rhs columns = ~literals
and ones), so the whole clause plane rides the systolic array instead of the
VPU, and the include bank streams HBM->VMEM exactly once per datapoint.

The block grid tiles the flattened (class x clause) axis AND, at MNIST-scale
widths, the literal axis: up to ``BLK_L`` literal lanes per block (iris
L=32 pads to one 128-lane block; booleanized-MNIST L=1568 runs 4 blocks of
512), with partial sums accumulated into the output block over the
*innermost* grid dimension — the standard Pallas reduction pattern, so
revisits of an output block are consecutive and VMEM residency per block
stays bounded no matter how wide the datapath grows. Accumulation is int32
(``preferred_element_type``): counts are <= L, so there is no headroom
concern at any realistic width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref

# int8-native TPU tile: 32 sublanes x 128 lanes.
BLK_CJ = 32
LANES = 128
# Literal-axis block: 4 int8 tiles. Widths <= BLK_L keep the pre-tiling
# single-block layout (one l-step); wider datapaths stream literal blocks.
BLK_L = 512


def _pad_l(L: int) -> tuple[int, int]:
    """(padded literal width, literal block) for a datapath of width L."""
    blk = min(BLK_L, -(-L // LANES) * LANES)
    return -(-L // blk) * blk, blk


def _kernel(l_axis: int, inc_ref, rhs_ref, out_ref):
    # inc: [BLK_CJ, blk_l] int8, rhs: [blk_l, LANES] int8 -> accumulate
    # [BLK_CJ, LANES] i32 partial sums over the innermost (literal) axis.
    @pl.when(pl.program_id(l_axis) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        inc_ref[...], rhs_ref[...], preferred_element_type=jnp.int32
    )


def _kernel_replicated(l_axis: int, inc_ref, rhs_ref, out_ref):
    # Leading length-1 replica block: inc [1, BLK_CJ, blk_l], rhs
    # [1, blk_l, LANES] -> out [1, BLK_CJ, LANES] i32 (shared by both
    # replicated launches), accumulated over the innermost literal axis.
    @pl.when(pl.program_id(l_axis) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += jnp.dot(
        inc_ref[0], rhs_ref[0], preferred_element_type=jnp.int32
    )[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def clause_counts(
    include: jax.Array,   # [CJ, L] int8/bool — flattened (class, clause) rows
    literals: jax.Array,  # [L] bool
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(violations [CJ] i32, n_included [CJ] i32) via one MXU matmul."""
    cj, L = include.shape
    cjp = -(-cj // BLK_CJ) * BLK_CJ
    Lp, blk_l = _pad_l(L)

    inc = jnp.zeros((cjp, Lp), dtype=jnp.int8).at[:cj, :L].set(
        include.astype(jnp.int8)
    )
    # rhs col 0: ~literal (violation counter); col 1: ones (include counter).
    rhs = jnp.zeros((Lp, LANES), dtype=jnp.int8)
    rhs = rhs.at[:L, 0].set(1 - literals.astype(jnp.int8))
    rhs = rhs.at[:L, 1].set(1)

    out = pl.pallas_call(
        functools.partial(_kernel, 1),
        grid=(cjp // BLK_CJ, Lp // blk_l),
        in_specs=[
            pl.BlockSpec((BLK_CJ, blk_l), lambda i, l: (i, l)),
            pl.BlockSpec((blk_l, LANES), lambda i, l: (l, 0)),
        ],
        out_specs=pl.BlockSpec((BLK_CJ, LANES), lambda i, l: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cjp, LANES), jnp.int32),
        interpret=interpret,
    )(inc, rhs)
    return out[:cj, 0], out[:cj, 1]


def clause_eval(
    include: jax.Array,   # [C, J, L] bool (post-fault TA actions)
    literals: jax.Array,  # [L] bool
    *,
    training: bool,
    interpret: bool = True,
) -> jax.Array:
    """Kernel-backed clause outputs [C, J] bool (same contract as ref)."""
    C, J, L = include.shape
    viol, n_inc = clause_counts(
        include.reshape(C * J, L), literals, interpret=interpret
    )
    fired = viol == 0
    empty = n_inc == 0
    out = jnp.where(empty, jnp.bool_(training), fired)
    return out.reshape(C, J)


@functools.partial(jax.jit, static_argnames=("interpret",))
def clause_counts_batch(
    include: jax.Array,   # [CJ, L] int8/bool — flattened (class, clause) rows
    literals: jax.Array,  # [B, L] bool — one row per datapoint
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(violations [CJ, B] i32, n_included [CJ] i32) via ONE MXU matmul.

    The batch-first form of :func:`clause_counts`: rhs columns 0..B-1 carry
    ``~literal_b`` (per-datapoint violation counters) and column B carries
    ones (the include counter — datapoint-independent, so a single column
    refines the [L, 2B] design down to [L, B+1]). The include bank streams
    HBM->VMEM once per *batch*; the grid tiles the flattened
    (class x clause) axis, the datapoint-column axis and the literal axis
    (innermost, accumulated).
    """
    cj, L = include.shape
    B = literals.shape[0]
    cjp = -(-cj // BLK_CJ) * BLK_CJ
    Lp, blk_l = _pad_l(L)
    cols = B + 1
    colsp = -(-cols // LANES) * LANES

    inc = jnp.zeros((cjp, Lp), dtype=jnp.int8).at[:cj, :L].set(
        include.astype(jnp.int8)
    )
    rhs = jnp.zeros((Lp, colsp), dtype=jnp.int8)
    rhs = rhs.at[:L, :B].set((1 - literals.astype(jnp.int8)).T)
    rhs = rhs.at[:L, B].set(1)

    out = pl.pallas_call(
        functools.partial(_kernel, 2),
        grid=(cjp // BLK_CJ, colsp // LANES, Lp // blk_l),
        in_specs=[
            pl.BlockSpec((BLK_CJ, blk_l), lambda i, j, l: (i, l)),
            pl.BlockSpec((blk_l, LANES), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((BLK_CJ, LANES), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((cjp, colsp), jnp.int32),
        interpret=interpret,
    )(inc, rhs)
    return out[:cj, :B], out[:cj, B]


def clause_eval_batch(
    include: jax.Array,   # [C, J, L] bool (post-fault TA actions)
    literals: jax.Array,  # [B, L] bool
    *,
    training: bool,
    interpret: bool = True,
) -> jax.Array:
    """Kernel-backed batch-first clause outputs [B, C, J] bool."""
    C, J, L = include.shape
    B = literals.shape[0]
    viol, n_inc = clause_counts_batch(
        include.reshape(C * J, L), literals, interpret=interpret
    )
    fired = (viol == 0).T.reshape(B, C, J)
    empty = (n_inc == 0).reshape(C, J)
    return jnp.where(empty[None], jnp.bool_(training), fired)


@functools.partial(jax.jit, static_argnames=("interpret",))
def clause_counts_replicated(
    include: jax.Array,   # [R, CJ, L] int8/bool — per-replica include banks
    literals: jax.Array,  # [D, L] bool — replica r reads row r % D
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(violations [R, CJ] i32, n_included [R, CJ] i32) in ONE kernel launch.

    Replica-first form of :func:`clause_counts`: a grid over
    (replica, clause-block, literal-block), each replica contracting its own
    include bank against its data stream's literal row. The rhs BlockSpec
    maps replica ``r`` to literal row ``r % D``, so a hyperparameter grid
    sharing one ordering's data stream stores the rhs once per ordering.
    """
    R, cj, L = include.shape
    D = literals.shape[0]
    if R % D:
        raise ValueError(f"data replicas {D} must divide replicas {R}")
    cjp = -(-cj // BLK_CJ) * BLK_CJ
    Lp, blk_l = _pad_l(L)

    inc = jnp.zeros((R, cjp, Lp), dtype=jnp.int8).at[:, :cj, :L].set(
        include.astype(jnp.int8)
    )
    rhs = jnp.zeros((D, Lp, LANES), dtype=jnp.int8)
    rhs = rhs.at[:, :L, 0].set(1 - literals.astype(jnp.int8))
    rhs = rhs.at[:, :L, 1].set(1)

    out = pl.pallas_call(
        functools.partial(_kernel_replicated, 2),
        grid=(R, cjp // BLK_CJ, Lp // blk_l),
        in_specs=[
            pl.BlockSpec((1, BLK_CJ, blk_l), lambda r, i, l: (r, i, l)),
            pl.BlockSpec((1, blk_l, LANES), lambda r, i, l: (r % D, l, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLK_CJ, LANES), lambda r, i, l: (r, i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, cjp, LANES), jnp.int32),
        interpret=interpret,
    )(inc, rhs)
    return out[:, :cj, 0], out[:, :cj, 1]


def clause_eval_replicated(
    include: jax.Array,   # [R, C, J, L] bool (post-fault TA actions)
    literals: jax.Array,  # [D, L] bool — replica r reads row r % D
    *,
    training: bool,
    interpret: bool = True,
) -> jax.Array:
    """Kernel-backed replica-first clause outputs [R, C, J] bool."""
    R, C, J, L = include.shape
    viol, n_inc = clause_counts_replicated(
        include.reshape(R, C * J, L), literals, interpret=interpret
    )
    fired = viol == 0
    empty = n_inc == 0
    out = jnp.where(empty, jnp.bool_(training), fired)
    return out.reshape(R, C, J)


@functools.partial(jax.jit, static_argnames=("interpret",))
def clause_counts_batch_replicated(
    include: jax.Array,   # [R, CJ, L] int8/bool — per-replica include banks
    literals: jax.Array,  # [D, B, L] bool — replica r reads batch r % D
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(violations [R, CJ, B] i32, n_included [R, CJ] i32) in ONE launch.

    The replica-first form of :func:`clause_counts_batch`: a 4-D grid over
    (replica, clause-block, column-block, literal-block), each replica
    contracting its own include bank against its data stream's [L, B+1]
    rhs. The rhs BlockSpec maps replica ``r`` to stream ``r % D`` — the
    factored layout rule — so a hyperparameter grid sharing one ordering's
    batch stores the rhs once per ordering instead of gathering an R/D-fold
    tiled copy (the take+vmap formulation this replaced). This is the
    kernel under both the fused multi-set analysis pass
    (``accuracy.analyze_sets_replicated``) and the fleet serving ``infer``
    path (``tm.predict_batch_replicated``).
    """
    R, cj, L = include.shape
    D, B, _ = literals.shape
    if R % D:
        raise ValueError(f"data replicas {D} must divide replicas {R}")
    cjp = -(-cj // BLK_CJ) * BLK_CJ
    Lp, blk_l = _pad_l(L)
    cols = B + 1
    colsp = -(-cols // LANES) * LANES

    inc = jnp.zeros((R, cjp, Lp), dtype=jnp.int8).at[:, :cj, :L].set(
        include.astype(jnp.int8)
    )
    rhs = jnp.zeros((D, Lp, colsp), dtype=jnp.int8)
    rhs = rhs.at[:, :L, :B].set(
        jnp.swapaxes(1 - literals.astype(jnp.int8), 1, 2)
    )
    rhs = rhs.at[:, :L, B].set(1)

    out = pl.pallas_call(
        functools.partial(_kernel_replicated, 3),
        grid=(R, cjp // BLK_CJ, colsp // LANES, Lp // blk_l),
        in_specs=[
            pl.BlockSpec((1, BLK_CJ, blk_l), lambda r, i, j, l: (r, i, l)),
            pl.BlockSpec((1, blk_l, LANES), lambda r, i, j, l: (r % D, l, j)),
        ],
        out_specs=pl.BlockSpec(
            (1, BLK_CJ, LANES), lambda r, i, j, l: (r, i, j)
        ),
        out_shape=jax.ShapeDtypeStruct((R, cjp, colsp), jnp.int32),
        interpret=interpret,
    )(inc, rhs)
    return out[:, :cj, :B], out[:, :cj, B]


def clause_eval_batch_replicated(
    include: jax.Array,   # [R, C, J, L] bool (post-fault TA actions)
    literals: jax.Array,  # [D, B, L] bool — replica r reads batch r % D
    *,
    training: bool,
    interpret: bool = True,
) -> jax.Array:
    """Kernel-backed replica-first batch clause outputs [R, B, C, J] bool.

    One launch of :func:`clause_counts_batch_replicated` — the whole
    analysis / serving-inference plane of R machines rides a single kernel
    grid with the ``r % D`` rhs index map doing the data-stream factoring
    (previously a per-replica gather + vmap of :func:`clause_eval_batch`).
    Bit-identical to stacking ``clause_eval_batch(include[r],
    literals[r % D])`` per replica.
    """
    R, C, J, L = include.shape
    B = literals.shape[1]
    viol, n_inc = clause_counts_batch_replicated(
        include.reshape(R, C * J, L), literals, interpret=interpret
    )
    fired = jnp.swapaxes(viol == 0, 1, 2).reshape(R, B, C, J)
    empty = (n_inc == 0).reshape(R, 1, C, J)
    return jnp.where(empty, jnp.bool_(training), fired)


# ---------------------------------------------------------------------------
# Bit-packed datapath: AND + popcount over uint32 words (DESIGN.md §13)
# ---------------------------------------------------------------------------
#
# The packed kernels are the closest TPU analogue of the FPGA's literal
# wires: the include bank and the literal rows are uint32 words (32 literals
# per lane element), and
#
#     violations[cj, b] = sum_w popcount(include[cj, w] & ~literal[b, w])
#
# This is VPU work, not MXU work — popcount has no matmul form — but the
# operand traffic shrinks 8x vs the int8 GEMM formulation and the word axis
# is 32x shorter than the literal axis, so the whole reduction usually fits
# ONE word block where the unpacked kernel streams several BLK_L blocks.
# The grid reuses the unpacked kernels' innermost-axis accumulation pattern
# on the word axis for datapaths wider than BLK_W*32 = 4096 literals.
#
# Tail safety: the packing contract (packing.py) zeroes include tail bits,
# so `include & ~literals` is zero at every pad position — the word padding
# added here (both word-axis padding to BLK_W and batch padding to BLK_B)
# only ever ANDs against zero include words and is sliced off the output.

BLK_B = 128   # datapoint columns per block (lane dim of the output tile)
BLK_W = 128   # uint32 words per block — 4096 literals per accumulation step


def _pad_w(W: int) -> tuple[int, int]:
    """(padded word width, word block) for a packed datapath of W words."""
    blk = min(BLK_W, -(-W // 8) * 8)  # 8 = uint32 sublane granule
    return -(-W // blk) * blk, blk


def _packed_kernel(w_axis: int, inc_ref, lit_ref, out_ref):
    # inc: [BLK_CJ, blk_w] u32, lit: [BLK_B, blk_w] u32 -> accumulate
    # [BLK_CJ, BLK_B] i32 violation partial sums over the word axis.
    @pl.when(pl.program_id(w_axis) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    viol = inc_ref[...][:, None, :] & ~lit_ref[...][None, :, :]
    out_ref[...] += jnp.sum(
        jax.lax.population_count(viol).astype(jnp.int32), axis=-1
    )


def _packed_kernel_replicated(w_axis: int, inc_ref, lit_ref, out_ref):
    # Leading length-1 replica block, as in _kernel_replicated.
    @pl.when(pl.program_id(w_axis) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    viol = inc_ref[0][:, None, :] & ~lit_ref[0][None, :, :]
    out_ref[...] += jnp.sum(
        jax.lax.population_count(viol).astype(jnp.int32), axis=-1
    )[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def clause_counts_batch_packed(
    include_packed: jax.Array,   # [CJ, W] uint32 — packed include rows
    literals_packed: jax.Array,  # [B, W] uint32 — packed literal rows
    *,
    interpret: bool = True,
) -> jax.Array:
    """Violations [CJ, B] i32 via the word-tiled AND+popcount grid.

    ``n_included`` has no per-datapoint dependence, so unlike the unpacked
    kernels there is no ones-column trick to fold it into the same launch —
    callers derive emptiness from the include words directly (cheap:
    [CJ, W] is 32x smaller than the bool include bank).
    """
    cj, W = include_packed.shape
    B = literals_packed.shape[0]
    cjp = -(-cj // BLK_CJ) * BLK_CJ
    Wp, blk_w = _pad_w(W)
    Bp = -(-B // BLK_B) * BLK_B

    inc = jnp.zeros((cjp, Wp), dtype=jnp.uint32).at[:cj, :W].set(
        include_packed
    )
    lit = jnp.zeros((Bp, Wp), dtype=jnp.uint32).at[:B, :W].set(
        literals_packed
    )

    out = pl.pallas_call(
        functools.partial(_packed_kernel, 2),
        grid=(cjp // BLK_CJ, Bp // BLK_B, Wp // blk_w),
        in_specs=[
            pl.BlockSpec((BLK_CJ, blk_w), lambda i, j, w: (i, w)),
            pl.BlockSpec((BLK_B, blk_w), lambda i, j, w: (j, w)),
        ],
        out_specs=pl.BlockSpec((BLK_CJ, BLK_B), lambda i, j, w: (i, j)),
        out_shape=jax.ShapeDtypeStruct((cjp, Bp), jnp.int32),
        interpret=interpret,
    )(inc, lit)
    return out[:cj, :B]


def clause_eval_batch_packed(
    include_packed: jax.Array,   # [C, J, W] uint32 (packed post-fault actions)
    literals_packed: jax.Array,  # [B, W] uint32
    *,
    training: bool,
    interpret: bool = True,
) -> jax.Array:
    """Kernel-backed packed batch clause outputs [B, C, J] bool.

    Same contract as ``ref.clause_eval_batch_packed`` — and, through the
    packing contract, bit-identical to the unpacked oracle.
    """
    C, J, W = include_packed.shape
    B = literals_packed.shape[0]
    viol = clause_counts_batch_packed(
        include_packed.reshape(C * J, W), literals_packed, interpret=interpret
    )
    fired = (viol == 0).T.reshape(B, C, J)
    empty = ~jnp.any(include_packed != 0, axis=-1)
    return jnp.where(empty[None], jnp.bool_(training), fired)


@functools.partial(jax.jit, static_argnames=("interpret",))
def clause_counts_batch_replicated_packed(
    include_packed: jax.Array,   # [R, CJ, W] uint32
    literals_packed: jax.Array,  # [D, B, W] uint32 — replica r reads r % D
    *,
    interpret: bool = True,
) -> jax.Array:
    """Violations [R, CJ, B] i32 in ONE launch: the packed replica plane.

    Grid (replica, clause-block, column-block, word-block) with the same
    ``r % D`` rhs index map as :func:`clause_counts_batch_replicated` — the
    factored data-stream rule carries over to packed words unchanged.
    """
    R, cj, W = include_packed.shape
    D, B, _ = literals_packed.shape
    if R % D:
        raise ValueError(f"data replicas {D} must divide replicas {R}")
    cjp = -(-cj // BLK_CJ) * BLK_CJ
    Wp, blk_w = _pad_w(W)
    Bp = -(-B // BLK_B) * BLK_B

    inc = jnp.zeros((R, cjp, Wp), dtype=jnp.uint32).at[:, :cj, :W].set(
        include_packed
    )
    lit = jnp.zeros((D, Bp, Wp), dtype=jnp.uint32).at[:, :B, :W].set(
        literals_packed
    )

    out = pl.pallas_call(
        functools.partial(_packed_kernel_replicated, 3),
        grid=(R, cjp // BLK_CJ, Bp // BLK_B, Wp // blk_w),
        in_specs=[
            pl.BlockSpec((1, BLK_CJ, blk_w), lambda r, i, j, w: (r, i, w)),
            pl.BlockSpec((1, BLK_B, blk_w), lambda r, i, j, w: (r % D, j, w)),
        ],
        out_specs=pl.BlockSpec((1, BLK_CJ, BLK_B), lambda r, i, j, w: (r, i, j)),
        out_shape=jax.ShapeDtypeStruct((R, cjp, Bp), jnp.int32),
        interpret=interpret,
    )(inc, lit)
    return out[:, :cj, :B]


def clause_eval_batch_replicated_packed(
    include_packed: jax.Array,   # [R, C, J, W] uint32
    literals_packed: jax.Array,  # [D, B, W] uint32 — replica r reads r % D
    *,
    training: bool,
    interpret: bool = True,
) -> jax.Array:
    """Kernel-backed packed replica-first batch outputs [R, B, C, J] bool."""
    R, C, J, W = include_packed.shape
    B = literals_packed.shape[1]
    viol = clause_counts_batch_replicated_packed(
        include_packed.reshape(R, C * J, W), literals_packed,
        interpret=interpret,
    )
    fired = jnp.swapaxes(viol == 0, 1, 2).reshape(R, B, C, J)
    empty = ~jnp.any(include_packed != 0, axis=-1).reshape(R, 1, C, J)
    return jnp.where(empty, jnp.bool_(training), fired)


# ---------------------------------------------------------------------------
# Budgeted (pruned) eval: compacted include banks (DESIGN.md §16).
#
# The XLA-side ``ref.gather_include`` compacts the bank to the top-M ranked
# clauses per class BEFORE the pallas launch, so the kernel grid itself
# shrinks with the budget — C·M/BLK_CJ clause blocks instead of C·J/BLK_CJ —
# rather than masking pruned clauses inside a full-size contraction.
# ---------------------------------------------------------------------------


def clause_eval_batch_pruned(
    include: jax.Array, sel: jax.Array, literals: jax.Array,
    *, training: bool, interpret: bool = True,
) -> jax.Array:
    """[C, J, L] x sel [C, M] x [B, L] -> [B, C, M] (see ref twin)."""
    return clause_eval_batch(
        _ref.gather_include(include, sel), literals,
        training=training, interpret=interpret,
    )


def clause_eval_batch_pruned_replicated(
    include: jax.Array, sel: jax.Array, literals: jax.Array,
    *, training: bool, interpret: bool = True,
) -> jax.Array:
    """[R, C, J, L] x sel [R, C, M] x [D, B, L] -> [R, B, C, M]."""
    return clause_eval_batch_replicated(
        _ref.gather_include(include, sel), literals,
        training=training, interpret=interpret,
    )


def clause_eval_batch_pruned_packed(
    include_packed: jax.Array, sel: jax.Array, literals_packed: jax.Array,
    *, training: bool, interpret: bool = True,
) -> jax.Array:
    """[C, J, W] u32 x sel [C, M] x [B, W] u32 -> [B, C, M]."""
    return clause_eval_batch_packed(
        _ref.gather_include(include_packed, sel), literals_packed,
        training=training, interpret=interpret,
    )


def clause_eval_batch_pruned_replicated_packed(
    include_packed: jax.Array, sel: jax.Array, literals_packed: jax.Array,
    *, training: bool, interpret: bool = True,
) -> jax.Array:
    """[R, C, J, W] u32 x sel [R, C, M] x [D, B, W] u32 -> [R, B, C, M]."""
    return clause_eval_batch_replicated_packed(
        _ref.gather_include(include_packed, sel), literals_packed,
        training=training, interpret=interpret,
    )
