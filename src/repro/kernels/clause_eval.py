"""Pallas TPU kernel: clause evaluation as an MXU matvec.

TPU adaptation of the paper's 2-cycle clause datapath (DESIGN.md §2/§8):
the FPGA computes each clause as a wide AND over included literals with
dedicated LUT trees. On TPU we recast the AND-reduction as an **int8 matmul
on the MXU**:

    violations[c,j] = sum_k include[c,j,k] * (1 - literal[k])
    n_included[c,j] = sum_k include[c,j,k]

    clause fires      <=> violations == 0
    clause is "empty" <=> n_included == 0  (training: fires; inference: not)

Both sums come from ONE [CJ, L] x [L, 2] int8 matmul (rhs columns = ~literals
and ones), so the whole clause plane rides the systolic array instead of the
VPU, and the include bank streams HBM->VMEM exactly once per datapoint.

The block grid tiles the flattened (class x clause) axis; the literal axis is
kept whole per block (L is small: 2 x booleanized features — iris 32, MNIST
1568 — far under VMEM limits at int8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# int8-native TPU tile: 32 sublanes x 128 lanes.
BLK_CJ = 32
LANES = 128


def _kernel(inc_ref, rhs_ref, out_ref):
    # inc: [BLK_CJ, Lp] int8, rhs: [Lp, LANES] int8 -> out: [BLK_CJ, LANES] i32
    out_ref[...] = jnp.dot(
        inc_ref[...], rhs_ref[...], preferred_element_type=jnp.int32
    )


def _kernel_replicated(inc_ref, rhs_ref, out_ref):
    # Leading length-1 replica block: inc [1, BLK_CJ, Lp], rhs [1, Lp, LANES]
    # -> out [1, BLK_CJ, LANES] i32 (shared by both replicated launches).
    out_ref[...] = jnp.dot(
        inc_ref[0], rhs_ref[0], preferred_element_type=jnp.int32
    )[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def clause_counts(
    include: jax.Array,   # [CJ, L] int8/bool — flattened (class, clause) rows
    literals: jax.Array,  # [L] bool
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(violations [CJ] i32, n_included [CJ] i32) via one MXU matmul."""
    cj, L = include.shape
    cjp = -(-cj // BLK_CJ) * BLK_CJ
    Lp = -(-L // LANES) * LANES

    inc = jnp.zeros((cjp, Lp), dtype=jnp.int8).at[:cj, :L].set(
        include.astype(jnp.int8)
    )
    # rhs col 0: ~literal (violation counter); col 1: ones (include counter).
    rhs = jnp.zeros((Lp, LANES), dtype=jnp.int8)
    rhs = rhs.at[:L, 0].set(1 - literals.astype(jnp.int8))
    rhs = rhs.at[:L, 1].set(1)

    out = pl.pallas_call(
        _kernel,
        grid=(cjp // BLK_CJ,),
        in_specs=[
            pl.BlockSpec((BLK_CJ, Lp), lambda i: (i, 0)),
            pl.BlockSpec((Lp, LANES), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLK_CJ, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((cjp, LANES), jnp.int32),
        interpret=interpret,
    )(inc, rhs)
    return out[:cj, 0], out[:cj, 1]


def clause_eval(
    include: jax.Array,   # [C, J, L] bool (post-fault TA actions)
    literals: jax.Array,  # [L] bool
    *,
    training: bool,
    interpret: bool = True,
) -> jax.Array:
    """Kernel-backed clause outputs [C, J] bool (same contract as ref)."""
    C, J, L = include.shape
    viol, n_inc = clause_counts(
        include.reshape(C * J, L), literals, interpret=interpret
    )
    fired = viol == 0
    empty = n_inc == 0
    out = jnp.where(empty, jnp.bool_(training), fired)
    return out.reshape(C, J)


@functools.partial(jax.jit, static_argnames=("interpret",))
def clause_counts_batch(
    include: jax.Array,   # [CJ, L] int8/bool — flattened (class, clause) rows
    literals: jax.Array,  # [B, L] bool — one row per datapoint
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(violations [CJ, B] i32, n_included [CJ] i32) via ONE MXU matmul.

    The batch-first form of :func:`clause_counts`: rhs columns 0..B-1 carry
    ``~literal_b`` (per-datapoint violation counters) and column B carries
    ones (the include counter — datapoint-independent, so a single column
    refines the [L, 2B] design down to [L, B+1]). The include bank streams
    HBM->VMEM once per *batch*; the grid tiles both the flattened
    (class x clause) axis and the datapoint-column axis.
    """
    cj, L = include.shape
    B = literals.shape[0]
    cjp = -(-cj // BLK_CJ) * BLK_CJ
    Lp = -(-L // LANES) * LANES
    cols = B + 1
    colsp = -(-cols // LANES) * LANES

    inc = jnp.zeros((cjp, Lp), dtype=jnp.int8).at[:cj, :L].set(
        include.astype(jnp.int8)
    )
    rhs = jnp.zeros((Lp, colsp), dtype=jnp.int8)
    rhs = rhs.at[:L, :B].set((1 - literals.astype(jnp.int8)).T)
    rhs = rhs.at[:L, B].set(1)

    out = pl.pallas_call(
        _kernel,
        grid=(cjp // BLK_CJ, colsp // LANES),
        in_specs=[
            pl.BlockSpec((BLK_CJ, Lp), lambda i, j: (i, 0)),
            pl.BlockSpec((Lp, LANES), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((BLK_CJ, LANES), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((cjp, colsp), jnp.int32),
        interpret=interpret,
    )(inc, rhs)
    return out[:cj, :B], out[:cj, B]


def clause_eval_batch(
    include: jax.Array,   # [C, J, L] bool (post-fault TA actions)
    literals: jax.Array,  # [B, L] bool
    *,
    training: bool,
    interpret: bool = True,
) -> jax.Array:
    """Kernel-backed batch-first clause outputs [B, C, J] bool."""
    C, J, L = include.shape
    B = literals.shape[0]
    viol, n_inc = clause_counts_batch(
        include.reshape(C * J, L), literals, interpret=interpret
    )
    fired = (viol == 0).T.reshape(B, C, J)
    empty = (n_inc == 0).reshape(C, J)
    return jnp.where(empty[None], jnp.bool_(training), fired)


@functools.partial(jax.jit, static_argnames=("interpret",))
def clause_counts_replicated(
    include: jax.Array,   # [R, CJ, L] int8/bool — per-replica include banks
    literals: jax.Array,  # [D, L] bool — replica r reads row r % D
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(violations [R, CJ] i32, n_included [R, CJ] i32) in ONE kernel launch.

    Replica-first form of :func:`clause_counts`: a 2-D grid over
    (replica, clause-block), each replica contracting its own include bank
    against its data stream's literal row. The rhs BlockSpec maps replica
    ``r`` to literal row ``r % D``, so a hyperparameter grid sharing one
    ordering's data stream stores the rhs once per ordering.
    """
    R, cj, L = include.shape
    D = literals.shape[0]
    if R % D:
        raise ValueError(f"data replicas {D} must divide replicas {R}")
    cjp = -(-cj // BLK_CJ) * BLK_CJ
    Lp = -(-L // LANES) * LANES

    inc = jnp.zeros((R, cjp, Lp), dtype=jnp.int8).at[:, :cj, :L].set(
        include.astype(jnp.int8)
    )
    rhs = jnp.zeros((D, Lp, LANES), dtype=jnp.int8)
    rhs = rhs.at[:, :L, 0].set(1 - literals.astype(jnp.int8))
    rhs = rhs.at[:, :L, 1].set(1)

    out = pl.pallas_call(
        _kernel_replicated,
        grid=(R, cjp // BLK_CJ),
        in_specs=[
            pl.BlockSpec((1, BLK_CJ, Lp), lambda r, i: (r, i, 0)),
            pl.BlockSpec((1, Lp, LANES), lambda r, i: (r % D, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLK_CJ, LANES), lambda r, i: (r, i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, cjp, LANES), jnp.int32),
        interpret=interpret,
    )(inc, rhs)
    return out[:, :cj, 0], out[:, :cj, 1]


def clause_eval_replicated(
    include: jax.Array,   # [R, C, J, L] bool (post-fault TA actions)
    literals: jax.Array,  # [D, L] bool — replica r reads row r % D
    *,
    training: bool,
    interpret: bool = True,
) -> jax.Array:
    """Kernel-backed replica-first clause outputs [R, C, J] bool."""
    R, C, J, L = include.shape
    viol, n_inc = clause_counts_replicated(
        include.reshape(R, C * J, L), literals, interpret=interpret
    )
    fired = viol == 0
    empty = n_inc == 0
    out = jnp.where(empty, jnp.bool_(training), fired)
    return out.reshape(R, C, J)


@functools.partial(jax.jit, static_argnames=("interpret",))
def clause_counts_batch_replicated(
    include: jax.Array,   # [R, CJ, L] int8/bool — per-replica include banks
    literals: jax.Array,  # [D, B, L] bool — replica r reads batch r % D
    *,
    interpret: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """(violations [R, CJ, B] i32, n_included [R, CJ] i32) in ONE launch.

    The replica-first form of :func:`clause_counts_batch`: a 3-D grid over
    (replica, clause-block, column-block), each replica contracting its own
    include bank against its data stream's [L, B+1] rhs. The rhs BlockSpec
    maps replica ``r`` to stream ``r % D`` — the factored layout rule — so
    a hyperparameter grid sharing one ordering's batch stores the rhs once
    per ordering instead of gathering an R/D-fold tiled copy (the
    take+vmap formulation this replaced). This is the kernel under both the
    fused multi-set analysis pass (``accuracy.analyze_sets_replicated``)
    and the fleet serving ``infer`` path (``tm.predict_batch_replicated``).
    """
    R, cj, L = include.shape
    D, B, _ = literals.shape
    if R % D:
        raise ValueError(f"data replicas {D} must divide replicas {R}")
    cjp = -(-cj // BLK_CJ) * BLK_CJ
    Lp = -(-L // LANES) * LANES
    cols = B + 1
    colsp = -(-cols // LANES) * LANES

    inc = jnp.zeros((R, cjp, Lp), dtype=jnp.int8).at[:, :cj, :L].set(
        include.astype(jnp.int8)
    )
    rhs = jnp.zeros((D, Lp, colsp), dtype=jnp.int8)
    rhs = rhs.at[:, :L, :B].set(
        jnp.swapaxes(1 - literals.astype(jnp.int8), 1, 2)
    )
    rhs = rhs.at[:, :L, B].set(1)

    out = pl.pallas_call(
        _kernel_replicated,
        grid=(R, cjp // BLK_CJ, colsp // LANES),
        in_specs=[
            pl.BlockSpec((1, BLK_CJ, Lp), lambda r, i, j: (r, i, 0)),
            pl.BlockSpec((1, Lp, LANES), lambda r, i, j: (r % D, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, BLK_CJ, LANES), lambda r, i, j: (r, i, j)),
        out_shape=jax.ShapeDtypeStruct((R, cjp, colsp), jnp.int32),
        interpret=interpret,
    )(inc, rhs)
    return out[:, :cj, :B], out[:, :cj, B]


def clause_eval_batch_replicated(
    include: jax.Array,   # [R, C, J, L] bool (post-fault TA actions)
    literals: jax.Array,  # [D, B, L] bool — replica r reads batch r % D
    *,
    training: bool,
    interpret: bool = True,
) -> jax.Array:
    """Kernel-backed replica-first batch clause outputs [R, B, C, J] bool.

    One launch of :func:`clause_counts_batch_replicated` — the whole
    analysis / serving-inference plane of R machines rides a single 3-D
    kernel grid with the ``r % D`` rhs index map doing the data-stream
    factoring (previously a per-replica gather + vmap of
    :func:`clause_eval_batch`). Bit-identical to stacking
    ``clause_eval_batch(include[r], literals[r % D])`` per replica.
    """
    R, C, J, L = include.shape
    B = literals.shape[1]
    viol, n_inc = clause_counts_batch_replicated(
        include.reshape(R, C * J, L), literals, interpret=interpret
    )
    fired = jnp.swapaxes(viol == 0, 1, 2).reshape(R, B, C, J)
    empty = (n_inc == 0).reshape(R, 1, C, J)
    return jnp.where(empty, jnp.bool_(training), fired)
