"""Pallas TPU kernel: fused Type I/II TA-bank update.

The FPGA applies feedback to every TA in one clock. Here the whole
(class x clause x literal) plane is one elementwise VPU pass, fused so the
TA states are read+written exactly once per datapoint and no [CJ, L]
intermediates (deltas, masks) ever round-trip to HBM.

Layout: rows = flattened (class, clause); lanes = literals. Per-clause
control (clause output, Type I/II selection) is packed into the first three
columns of a [CJ, LANES] int8 control block so every operand block is
TPU-tile aligned; probabilities ride a [1, LANES] f32 vector (col 0 =
p_strengthen, col 1 = p_erase) and broadcast inside the kernel.

At MNIST-scale widths the literal axis is tiled too (``BLK_L`` lanes per
block, the same scheme as ``clause_eval.py``): the update is elementwise
along literals, so literal blocks are independent grid steps — no
accumulation — and the f32 uniforms block (the widest operand) stays
bounded in VMEM regardless of datapath width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.clause_eval import _pad_l

BLK_CJ = 32
LANES = 128


def _kernel(n_states: int, ta_ref, lit_ref, ctl_ref, u_ref, p_ref, out_ref):
    ta = ta_ref[...].astype(jnp.int32)        # [BLK, Lp]
    lit = lit_ref[...] != 0                   # [1, Lp] bool
    ctl = ctl_ref[...]                        # [BLK, LANES] int8
    u = u_ref[...]                            # [BLK, Lp] f32
    p = p_ref[...]                            # [1, LANES] f32

    c_out = ctl[:, 0:1] != 0                  # [BLK, 1]
    t1 = ctl[:, 1:2] != 0
    t2 = ctl[:, 2:3] != 0

    p_strengthen = p[0:1, 0:1]                # [1, 1] broadcasts over the plane
    p_erase = p[0:1, 1:2]

    include = ta > n_states
    strengthen = c_out & lit                  # clause fired & literal true
    d1 = jnp.where(
        strengthen,
        (u < p_strengthen).astype(jnp.int32),
        -((u < p_erase).astype(jnp.int32)),
    )
    d2 = (c_out & (~lit) & (~include)).astype(jnp.int32)
    delta = jnp.where(t1, d1, 0) + jnp.where(t2, d2, 0)
    out_ref[...] = jnp.clip(ta + delta, 1, 2 * n_states).astype(out_ref.dtype)


def _kernel_replicated(n_states: int, ta_ref, lit_ref, ctl_ref, u_ref, p_ref,
                       out_ref):
    # Refs carry a leading replica-block dim of 1: [1, BLK, Lp] / [1, 1, Lp].
    ta = ta_ref[...].astype(jnp.int32)        # [1, BLK, Lp]
    lit = lit_ref[...] != 0                   # [1, 1, Lp] bool
    ctl = ctl_ref[...]                        # [1, BLK, LANES] int8
    u = u_ref[...]                            # [1, BLK, Lp] f32
    p = p_ref[...]                            # [1, 1, LANES] f32 (per-replica)

    c_out = ctl[:, :, 0:1] != 0               # [1, BLK, 1]
    t1 = ctl[:, :, 1:2] != 0
    t2 = ctl[:, :, 2:3] != 0

    p_strengthen = p[0:1, 0:1, 0:1]           # broadcasts over the plane
    p_erase = p[0:1, 0:1, 1:2]

    include = ta > n_states
    strengthen = c_out & lit
    d1 = jnp.where(
        strengthen,
        (u < p_strengthen).astype(jnp.int32),
        -((u < p_erase).astype(jnp.int32)),
    )
    d2 = (c_out & (~lit) & (~include)).astype(jnp.int32)
    delta = jnp.where(t1, d1, 0) + jnp.where(t2, d2, 0)
    out_ref[...] = jnp.clip(ta + delta, 1, 2 * n_states).astype(out_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_states", "interpret")
)
def feedback_plane(
    ta_state: jax.Array,    # [CJ, L] int8/int16
    literals: jax.Array,    # [L] bool
    clause_out: jax.Array,  # [CJ] bool
    type1_sel: jax.Array,   # [CJ] bool
    type2_sel: jax.Array,   # [CJ] bool
    u: jax.Array,           # [CJ, L] f32 uniforms
    p_strengthen: jax.Array,  # scalar f32
    p_erase: jax.Array,       # scalar f32
    *,
    n_states: int,
    interpret: bool = True,
) -> jax.Array:
    """Fused TA update over the flattened plane. Returns new ta_state [CJ, L]."""
    cj, L = ta_state.shape
    cjp = -(-cj // BLK_CJ) * BLK_CJ
    Lp, blk_l = _pad_l(L)
    dt = ta_state.dtype

    ta = jnp.ones((cjp, Lp), dtype=dt).at[:cj, :L].set(ta_state)
    lit = jnp.zeros((1, Lp), dtype=jnp.int8).at[0, :L].set(
        literals.astype(jnp.int8)
    )
    ctl = jnp.zeros((cjp, LANES), dtype=jnp.int8)
    ctl = ctl.at[:cj, 0].set(clause_out.astype(jnp.int8))
    ctl = ctl.at[:cj, 1].set(type1_sel.astype(jnp.int8))
    ctl = ctl.at[:cj, 2].set(type2_sel.astype(jnp.int8))
    # Pad u with 1.0 so padded lanes never draw an action.
    up = jnp.ones((cjp, Lp), dtype=jnp.float32).at[:cj, :L].set(
        u.astype(jnp.float32)
    )
    p = jnp.zeros((1, LANES), dtype=jnp.float32)
    p = p.at[0, 0].set(p_strengthen).at[0, 1].set(p_erase)

    out = pl.pallas_call(
        functools.partial(_kernel, n_states),
        grid=(cjp // BLK_CJ, Lp // blk_l),
        in_specs=[
            pl.BlockSpec((BLK_CJ, blk_l), lambda i, l: (i, l)),
            pl.BlockSpec((1, blk_l), lambda i, l: (0, l)),
            pl.BlockSpec((BLK_CJ, LANES), lambda i, l: (i, 0)),
            pl.BlockSpec((BLK_CJ, blk_l), lambda i, l: (i, l)),
            pl.BlockSpec((1, LANES), lambda i, l: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLK_CJ, blk_l), lambda i, l: (i, l)),
        out_shape=jax.ShapeDtypeStruct((cjp, Lp), dt),
        interpret=interpret,
    )(ta, lit, ctl, up, p)
    return out[:cj, :L]


@functools.partial(
    jax.jit, static_argnames=("n_states", "interpret")
)
def feedback_plane_replicated(
    ta_state: jax.Array,    # [R, CJ, L] int8/int16
    literals: jax.Array,    # [D, L] bool — replica r reads row r % D
    clause_out: jax.Array,  # [R, CJ] bool
    type1_sel: jax.Array,   # [R, CJ] bool
    type2_sel: jax.Array,   # [R, CJ] bool
    u: jax.Array,           # [D, CJ, L] f32 — replica r reads row r % D
    p_strengthen: jax.Array,  # [R] f32
    p_erase: jax.Array,       # [R] f32
    *,
    n_states: int,
    interpret: bool = True,
) -> jax.Array:
    """R independent TA banks updated in ONE kernel launch.

    2-D grid over (replica, clause-block): the FPGA's per-datapoint feedback
    plane replicated spatially, the TPU form of the paper's cross-validation
    re-runs. A vmap over :func:`feedback_plane` would pad and launch R
    separate planes; here the replica axis is a grid dimension, so the i-th
    clause block of every replica reuses the same tile program, and the
    literal/uniform operands are *factored* — the BlockSpec index map sends
    replica ``r`` to data row ``r % D``, so draws shared across a
    hyperparameter grid are stored once, not R/D times.

    Returns new ta_state [R, CJ, L].
    """
    R, cj, L = ta_state.shape
    D = literals.shape[0]
    if R % D:
        raise ValueError(f"data replicas {D} must divide replicas {R}")
    cjp = -(-cj // BLK_CJ) * BLK_CJ
    Lp, blk_l = _pad_l(L)
    dt = ta_state.dtype

    ta = jnp.ones((R, cjp, Lp), dtype=dt).at[:, :cj, :L].set(ta_state)
    lit = jnp.zeros((D, 1, Lp), dtype=jnp.int8).at[:, 0, :L].set(
        literals.astype(jnp.int8)
    )
    ctl = jnp.zeros((R, cjp, LANES), dtype=jnp.int8)
    ctl = ctl.at[:, :cj, 0].set(clause_out.astype(jnp.int8))
    ctl = ctl.at[:, :cj, 1].set(type1_sel.astype(jnp.int8))
    ctl = ctl.at[:, :cj, 2].set(type2_sel.astype(jnp.int8))
    # Pad u with 1.0 so padded lanes never draw an action.
    up = jnp.ones((D, cjp, Lp), dtype=jnp.float32).at[:, :cj, :L].set(
        u.astype(jnp.float32)
    )
    p = jnp.zeros((R, 1, LANES), dtype=jnp.float32)
    p = p.at[:, 0, 0].set(p_strengthen.astype(jnp.float32))
    p = p.at[:, 0, 1].set(p_erase.astype(jnp.float32))

    out = pl.pallas_call(
        functools.partial(_kernel_replicated, n_states),
        grid=(R, cjp // BLK_CJ, Lp // blk_l),
        in_specs=[
            pl.BlockSpec((1, BLK_CJ, blk_l), lambda r, i, l: (r, i, l)),
            pl.BlockSpec((1, 1, blk_l), lambda r, i, l: (r % D, 0, l)),
            pl.BlockSpec((1, BLK_CJ, LANES), lambda r, i, l: (r, i, 0)),
            pl.BlockSpec((1, BLK_CJ, blk_l), lambda r, i, l: (r % D, i, l)),
            pl.BlockSpec((1, 1, LANES), lambda r, i, l: (r, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLK_CJ, blk_l), lambda r, i, l: (r, i, l)),
        out_shape=jax.ShapeDtypeStruct((R, cjp, Lp), dt),
        interpret=interpret,
    )(ta, lit, ctl, up, p)
    return out[:, :cj, :L]
