"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: the Pallas kernels in ``clause_eval.py``
and ``feedback.py`` are asserted allclose against these across shape/dtype
sweeps (see tests/test_kernels_*.py). They are also the default CPU backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def clause_eval(include: jax.Array, literals: jax.Array, *, training: bool) -> jax.Array:
    """Clause outputs: AND over included literals.

    Args:
      include: [C, J, L] bool — post-fault TA actions (L = 2*features).
      literals: [L] bool — input literal vector [x, ~x].
      training: empty clauses output 1 while training, 0 at inference.

    Returns: [C, J] bool clause outputs.
    """
    # A clause fails iff some included literal is 0.
    match = jnp.logical_or(~include, literals[None, None, :])
    fired = jnp.all(match, axis=-1)
    empty = ~jnp.any(include, axis=-1)
    return jnp.where(empty, jnp.bool_(training), fired)


def clause_eval_batch(
    include: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """Batch-first clause eval: literals [B, L] -> [B, C, J].

    One [B, L] x [L, CJ] matmul instead of a vmap of per-sample AND-reductions:
    the include bank is the stationary GEMM operand, read once per *batch*,
    and the reduction rides the platform's GEMM (MXU on TPU, vectorized GEMM
    on CPU). Batch rows on the left so the [B, C, J] result needs no
    transpose.

        violations[b, cj] = sum_l (1 - literal[b, l]) * include[cj, l]
        clause fires     <=> violations == 0
        clause is empty  <=> n_included == 0

    f32 accumulation is exact here (counts are integers <= L << 2^24), so the
    result is bit-identical to stacking :func:`clause_eval` over rows.
    """
    C, J, L = include.shape
    B = literals.shape[0]
    inc = include.reshape(C * J, L).astype(jnp.float32)
    neg = 1.0 - literals.astype(jnp.float32)              # [B, L] — row b = ~lit_b
    violations = neg @ inc.T                              # [B, CJ]
    fired = (violations == 0).reshape(B, C, J)
    empty = ~jnp.any(include, axis=-1)                    # [C, J]
    return jnp.where(empty[None], jnp.bool_(training), fired)


def clause_eval_loop(
    include: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """Per-sample-loop batched eval: the oracle the batch paths are tested
    against (literally a vmap of :func:`clause_eval` over rows)."""
    return jax.vmap(lambda l: clause_eval(include, l, training=training))(literals)


def clause_eval_replicated(
    include: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """Replica-first clause eval: include [R, C, J, L] x literals [D, L] ->
    [R, C, J].

    Replica ``r`` evaluates against literal row ``r % D`` (``D`` must divide
    ``R``). The cross-validation engine lays replicas out grid-major /
    ordering-minor — replicas that share a data stream (one ordering trained
    under many (s, T) cells) are adjacent modulo ``D``, so the literal bank is
    stored once per *ordering* and broadcast across the hyperparameter grid
    instead of being tiled ``R/D``-fold. MUST equal stacking
    :func:`clause_eval` with ``(include[r], literals[r % D])`` bit-for-bit.
    """
    R, C, J, L = include.shape
    D = literals.shape[0]
    if R % D:
        raise ValueError(f"data replicas {D} must divide replicas {R}")
    inc = include.reshape(R // D, D, C, J, L)
    lit = literals[None, :, None, None, :]
    fired = jnp.all(jnp.logical_or(~inc, lit), axis=-1)
    empty = ~jnp.any(inc, axis=-1)
    return jnp.where(empty, jnp.bool_(training), fired).reshape(R, C, J)


def clause_eval_batch_replicated(
    include: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """Replica-first batch eval: include [R, C, J, L] x literals [D, B, L] ->
    [R, B, C, J].

    One batched GEMM over all replicas (replica ``r`` reads literal batch
    ``r % D``): the whole cross-validation sweep's accuracy analysis — all
    three per-cycle sets concatenated (``accuracy.analyze_sets_replicated``)
    — and the serving fleet's batched ``infer`` path are each a single
    contraction on this entry. Violation counts are integers << 2^24, so f32
    accumulation is exact and the result is bit-identical to stacking
    :func:`clause_eval_batch` per replica.
    """
    R, C, J, L = include.shape
    D, B, _ = literals.shape
    if R % D:
        raise ValueError(f"data replicas {D} must divide replicas {R}")
    inc = include.reshape(R // D, D, C * J, L).astype(jnp.float32)
    neg = 1.0 - literals.astype(jnp.float32)                  # [D, B, L]
    viol = jnp.einsum("hdkl,dbl->hdbk", inc, neg)
    fired = (viol == 0).reshape(R // D, D, B, C, J)
    empty = ~jnp.any(include, axis=-1).reshape(R // D, D, 1, C, J)
    out = jnp.where(empty, jnp.bool_(training), fired)
    return out.reshape(R, B, C, J)


def clause_eval_batch_packed(
    include_packed: jax.Array, literals_packed: jax.Array, *, training: bool
) -> jax.Array:
    """Bit-packed batch clause eval: the FPGA's AND-tree, word-at-a-time.

    Args:
      include_packed: [C, J, W] uint32 — include masks packed per
        ``packing.pack_include`` (W = 2*ceil(f/32) words; tail bits ZERO).
      literals_packed: [B, W] uint32 — literal rows packed per
        ``packing.pack_literals`` (same two-half layout).
      training: empty-clause convention, as in :func:`clause_eval_batch`.

    Returns: [B, C, J] bool, bit-identical to the unpacked oracle on the
    corresponding bool operands.

        violations[b, c, j] = sum_w popcount(include[c,j,w] & ~literal[b,w])
        clause fires      <=> violations == 0
        clause is empty   <=> sum_w popcount(include[c,j,w]) == 0

    Tail safety: include tail bits are zero by the packing contract, so
    ``include & ~literals`` is zero at every pad position even though the
    complement sets the literal tail to ones — each per-word popcount equals
    the unpacked per-word violation count exactly, and the sums match
    bit-for-bit.
    """
    viol_words = include_packed[None] & ~literals_packed[:, None, None, :]
    viol = jnp.sum(
        jax.lax.population_count(viol_words).astype(jnp.int32), axis=-1
    )                                                     # [B, C, J]
    fired = viol == 0
    empty = ~jnp.any(include_packed != 0, axis=-1)        # [C, J]
    return jnp.where(empty[None], jnp.bool_(training), fired)


def clause_eval_batch_replicated_packed(
    include_packed: jax.Array, literals_packed: jax.Array, *, training: bool
) -> jax.Array:
    """Replica-first bit-packed batch eval: include [R, C, J, W] uint32 x
    literals [D, B, W] uint32 -> [R, B, C, J] bool.

    Replica ``r`` reads literal batch ``r % D`` — the same factored layout
    rule as :func:`clause_eval_batch_replicated`, on packed words. MUST be
    bit-identical to stacking :func:`clause_eval_batch_packed` per replica,
    and (via the packing contract) to the unpacked replicated oracle.
    """
    R, C, J, W = include_packed.shape
    D, B, _ = literals_packed.shape
    if R % D:
        raise ValueError(f"data replicas {D} must divide replicas {R}")
    inc = include_packed.reshape(R // D, D, 1, C, J, W)
    lit = literals_packed[None, :, :, None, None, :]      # [1, D, B, 1, 1, W]
    viol = jnp.sum(
        jax.lax.population_count(inc & ~lit).astype(jnp.int32), axis=-1
    )                                                     # [H, D, B, C, J]
    fired = viol == 0
    empty = ~jnp.any(include_packed != 0, axis=-1)        # [R, C, J]
    empty = empty.reshape(R // D, D, 1, C, J)
    out = jnp.where(empty, jnp.bool_(training), fired)
    return out.reshape(R, B, C, J)


def gather_include(include: jax.Array, sel: jax.Array) -> jax.Array:
    """Compact an include bank to the selected clauses: [..., C, J, L|W] x
    sel [..., C, M] i32 -> [..., C, M, L|W].

    The budgeted-serve primitive (DESIGN.md §16): instead of masking
    pruned clauses out (which still pays the full [C·J, L] contraction),
    the include bank is *gathered* down to the top-M ranked clauses per
    class, so the clause contraction — GEMM rows on ref, grid blocks on
    pallas — shrinks with the budget. Works on unpacked [.., L] bool and
    packed [.., W] uint32 banks alike (the gather never touches the last
    axis, so the §13 packing contract — tail bits zero — is preserved).
    """
    return jnp.take_along_axis(include, sel[..., None], axis=-2)


def clause_eval_batch_pruned(
    include: jax.Array, sel: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """Budgeted batch eval: only the selected clauses are contracted.

    Args:
      include: [C, J, L] bool — the FULL post-fault include bank.
      sel: [C, M] int32 — clause indices (within J) to evaluate, per class.
      literals: [B, L] bool.

    Returns [B, C, M] bool: column m is clause ``sel[c, m]``'s output.
    MUST equal ``clause_eval_batch(include, literals)[:, c, sel[c, m]]``
    bit-for-bit — the compaction is a pure gather, so every selected
    clause (including empty ones) keeps its full-bank semantics.
    """
    return clause_eval_batch(
        gather_include(include, sel), literals, training=training
    )


def clause_eval_batch_pruned_replicated(
    include: jax.Array, sel: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """Replica-first budgeted eval: include [R, C, J, L] x sel [R, C, M] x
    literals [D, B, L] -> [R, B, C, M] (replica ``r`` reads batch
    ``r % D`` and its OWN clause ranking ``sel[r]``)."""
    return clause_eval_batch_replicated(
        gather_include(include, sel), literals, training=training
    )


def clause_eval_batch_pruned_packed(
    include_packed: jax.Array, sel: jax.Array, literals_packed: jax.Array,
    *, training: bool,
) -> jax.Array:
    """Bit-packed budgeted eval: include [C, J, W] u32 x sel [C, M] x
    literals [B, W] u32 -> [B, C, M]. The AND+popcount contraction runs
    over M gathered words-rows per class instead of J."""
    return clause_eval_batch_packed(
        gather_include(include_packed, sel), literals_packed,
        training=training,
    )


def clause_eval_batch_pruned_replicated_packed(
    include_packed: jax.Array, sel: jax.Array, literals_packed: jax.Array,
    *, training: bool,
) -> jax.Array:
    """Replica-first bit-packed budgeted eval: [R, C, J, W] u32 x
    [R, C, M] x [D, B, W] u32 -> [R, B, C, M]."""
    return clause_eval_batch_replicated_packed(
        gather_include(include_packed, sel), literals_packed,
        training=training,
    )


def feedback_step(
    ta_state: jax.Array,    # [C, J, L] int8/int16 (pre-update)
    literals: jax.Array,    # [L] bool
    clause_out: jax.Array,  # [C, J] bool (training-mode, post-fault outputs)
    type1_sel: jax.Array,   # [C, J] bool — clauses given Type I feedback
    type2_sel: jax.Array,   # [C, J] bool — clauses given Type II feedback
    u: jax.Array,           # [C, J, L] f32 uniforms in [0,1) — one draw per TA
    *,
    s: jax.Array,           # scalar f32
    n_states: int,
    s_policy: str,
    boost_true_positive: bool,
) -> jax.Array:
    """One datapoint's TA-bank update (Type I + Type II). Returns new ta_state.

    Type I (recognize/erase — combats false negatives):
      clause=1 & lit=1:  strengthen include  w.p. p_strengthen
      otherwise:         push toward exclude w.p. p_erase
    Type II (reject — combats false positives):
      clause=1 & lit=0 & excluded: +1 toward include, deterministic.

    s-policies (DESIGN.md §2):
      standard: p_strengthen=(s-1)/s (or 1 if boost), p_erase=1/s
      hardware: p_strengthen=(s-1)/s (or 1 if boost), p_erase=(s-1)/s
                (all stochastic events rarer as s->1: the paper's low-power bias)
    """
    p_strengthen = jnp.where(boost_true_positive, 1.0, (s - 1.0) / s)
    p_erase = (1.0 / s) if s_policy == "standard" else (s - 1.0) / s

    lit = literals[None, None, :]
    c_out = clause_out[:, :, None]
    include = ta_state > n_states

    # Type I deltas.
    strengthen = c_out & lit
    d1 = jnp.where(
        strengthen,
        (u < p_strengthen).astype(jnp.int32),
        -((u < p_erase).astype(jnp.int32)),
    )

    # Type II deltas: insert a blocking literal.
    d2 = (c_out & ~lit & ~include).astype(jnp.int32)

    delta = (
        type1_sel[:, :, None].astype(jnp.int32) * d1
        + type2_sel[:, :, None].astype(jnp.int32) * d2
    )
    new_state = jnp.clip(ta_state.astype(jnp.int32) + delta, 1, 2 * n_states)
    return new_state.astype(ta_state.dtype)


def feedback_step_replicated(
    ta_state: jax.Array,    # [R, C, J, L] int8/int16 (pre-update)
    literals: jax.Array,    # [D, L] bool — replica r reads row r % D
    clause_out: jax.Array,  # [R, C, J] bool
    type1_sel: jax.Array,   # [R, C, J] bool
    type2_sel: jax.Array,   # [R, C, J] bool
    u: jax.Array,           # [D, C, J, L] f32 — replica r reads row r % D
    *,
    s: jax.Array,           # [R] f32 (scalars broadcast)
    n_states: int,
    s_policy: str,
    boost_true_positive: bool,
) -> jax.Array:
    """R independent TA banks updated as ONE fused elementwise plane.

    This is the training half of the replica-parallel engine: every
    (ordering x s x T) replica of a cross-validation sweep advances one
    datapoint in a single [R, C·J, L] update instead of R separate
    :func:`feedback_step` planes. Two things make it faster than a vmap of
    the per-replica oracle without changing a single bit of the result:

    * the uniforms (and literals) are *factored*: replicas sharing a data
      stream (same ordering, different (s, T)) consume the same draws, so
      ``u`` is stored once per data replica and broadcast across the grid
      rather than tiled to [R, C, J, L];
    * the delta arithmetic runs at the TA bank's native int8 width. Exact:
      states are <= 2N <= 126 in int8, Type II applies only to excluded TAs
      (state <= N), so ``state + delta`` never exceeds 127.

    MUST be bit-identical to stacking ``feedback_step(ta[r], literals[r % D],
    ..., u[r % D], s=s[r])`` over replicas — asserted in tests/test_kernels.py.
    """
    R, C, J, L = ta_state.shape
    D = literals.shape[0]
    if R % D:
        raise ValueError(f"data replicas {D} must divide replicas {R}")
    H = R // D

    s = jnp.broadcast_to(jnp.asarray(s, jnp.float32), (R,)).reshape(H, D, 1, 1, 1)
    p_strengthen = jnp.where(boost_true_positive, 1.0, (s - 1.0) / s)
    p_erase = (1.0 / s) if s_policy == "standard" else (s - 1.0) / s

    ta = ta_state.reshape(H, D, C, J, L)
    lit = literals[None, :, None, None, :]
    uB = u[None]
    c_out = clause_out.reshape(H, D, C, J)[..., None]
    t1 = type1_sel.reshape(H, D, C, J)[..., None]
    t2 = type2_sel.reshape(H, D, C, J)[..., None]
    include = ta > n_states

    # int8 states: all arithmetic stays int8 (exact — see docstring); wider
    # states fall back to the oracle's int32 maths.
    acc_dtype = jnp.int8 if ta_state.dtype == jnp.int8 else jnp.int32

    strengthen = c_out & lit
    d1 = jnp.where(
        strengthen,
        (uB < p_strengthen).astype(acc_dtype),
        -((uB < p_erase).astype(acc_dtype)),
    )
    d2 = (c_out & ~lit & ~include).astype(acc_dtype)
    delta = jnp.where(t1, d1, 0) + jnp.where(t2, d2, 0)
    new_state = jnp.clip(ta.astype(acc_dtype) + delta, 1, 2 * n_states)
    return new_state.reshape(R, C, J, L).astype(ta_state.dtype)
