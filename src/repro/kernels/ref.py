"""Pure-jnp oracles for the Pallas kernels.

These are the semantic ground truth: the Pallas kernels in ``clause_eval.py``
and ``feedback.py`` are asserted allclose against these across shape/dtype
sweeps (see tests/test_kernels_*.py). They are also the default CPU backend.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def clause_eval(include: jax.Array, literals: jax.Array, *, training: bool) -> jax.Array:
    """Clause outputs: AND over included literals.

    Args:
      include: [C, J, L] bool — post-fault TA actions (L = 2*features).
      literals: [L] bool — input literal vector [x, ~x].
      training: empty clauses output 1 while training, 0 at inference.

    Returns: [C, J] bool clause outputs.
    """
    # A clause fails iff some included literal is 0.
    match = jnp.logical_or(~include, literals[None, None, :])
    fired = jnp.all(match, axis=-1)
    empty = ~jnp.any(include, axis=-1)
    return jnp.where(empty, jnp.bool_(training), fired)


def clause_eval_batch(
    include: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """Batch-first clause eval: literals [B, L] -> [B, C, J].

    One [B, L] x [L, CJ] matmul instead of a vmap of per-sample AND-reductions:
    the include bank is the stationary GEMM operand, read once per *batch*,
    and the reduction rides the platform's GEMM (MXU on TPU, vectorized GEMM
    on CPU). Batch rows on the left so the [B, C, J] result needs no
    transpose.

        violations[b, cj] = sum_l (1 - literal[b, l]) * include[cj, l]
        clause fires     <=> violations == 0
        clause is empty  <=> n_included == 0

    f32 accumulation is exact here (counts are integers <= L << 2^24), so the
    result is bit-identical to stacking :func:`clause_eval` over rows.
    """
    C, J, L = include.shape
    B = literals.shape[0]
    inc = include.reshape(C * J, L).astype(jnp.float32)
    neg = 1.0 - literals.astype(jnp.float32)              # [B, L] — row b = ~lit_b
    violations = neg @ inc.T                              # [B, CJ]
    fired = (violations == 0).reshape(B, C, J)
    empty = ~jnp.any(include, axis=-1)                    # [C, J]
    return jnp.where(empty[None], jnp.bool_(training), fired)


def clause_eval_loop(
    include: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """Per-sample-loop batched eval: the oracle the batch paths are tested
    against (literally a vmap of :func:`clause_eval` over rows)."""
    return jax.vmap(lambda l: clause_eval(include, l, training=training))(literals)


def feedback_step(
    ta_state: jax.Array,    # [C, J, L] int8/int16 (pre-update)
    literals: jax.Array,    # [L] bool
    clause_out: jax.Array,  # [C, J] bool (training-mode, post-fault outputs)
    type1_sel: jax.Array,   # [C, J] bool — clauses given Type I feedback
    type2_sel: jax.Array,   # [C, J] bool — clauses given Type II feedback
    u: jax.Array,           # [C, J, L] f32 uniforms in [0,1) — one draw per TA
    *,
    s: jax.Array,           # scalar f32
    n_states: int,
    s_policy: str,
    boost_true_positive: bool,
) -> jax.Array:
    """One datapoint's TA-bank update (Type I + Type II). Returns new ta_state.

    Type I (recognize/erase — combats false negatives):
      clause=1 & lit=1:  strengthen include  w.p. p_strengthen
      otherwise:         push toward exclude w.p. p_erase
    Type II (reject — combats false positives):
      clause=1 & lit=0 & excluded: +1 toward include, deterministic.

    s-policies (DESIGN.md §2):
      standard: p_strengthen=(s-1)/s (or 1 if boost), p_erase=1/s
      hardware: p_strengthen=(s-1)/s (or 1 if boost), p_erase=(s-1)/s
                (all stochastic events rarer as s->1: the paper's low-power bias)
    """
    p_strengthen = jnp.where(boost_true_positive, 1.0, (s - 1.0) / s)
    p_erase = (1.0 / s) if s_policy == "standard" else (s - 1.0) / s

    lit = literals[None, None, :]
    c_out = clause_out[:, :, None]
    include = ta_state > n_states

    # Type I deltas.
    strengthen = c_out & lit
    d1 = jnp.where(
        strengthen,
        (u < p_strengthen).astype(jnp.int32),
        -((u < p_erase).astype(jnp.int32)),
    )

    # Type II deltas: insert a blocking literal.
    d2 = (c_out & ~lit & ~include).astype(jnp.int32)

    delta = (
        type1_sel[:, :, None].astype(jnp.int32) * d1
        + type2_sel[:, :, None].astype(jnp.int32) * d2
    )
    new_state = jnp.clip(ta_state.astype(jnp.int32) + delta, 1, 2 * n_states)
    return new_state.astype(ta_state.dtype)
