"""Backend dispatch: ONE seam between the TM core and its kernel backends.

The paper's FPGA fixes its datapath at synthesis; here the datapath
implementation is chosen at trace time through a registry keyed by
``TMConfig.backend``:

* ``"ref"``    — pure-jnp oracles (:mod:`repro.kernels.ref`). CPU default and
  the semantic ground truth every other backend is asserted bit-exact against.
* ``"pallas"`` — TPU Pallas kernels (:mod:`repro.kernels.ops`): MXU clause
  matmul + fused VPU feedback plane (interpreted off-TPU).
* ``"auto"``   — resolves to ``pallas`` when JAX is running on a TPU,
  ``ref`` otherwise.

Every backend implements the same typed contract, :class:`KernelBackend`,
and the contract is **batch-first**: ``clause_eval_batch`` takes ``[B, L]``
literals and returns ``[B, C, J]`` clause outputs with the include bank
streamed once per *batch* (not once per datapoint — see DESIGN.md §8).
Future backends (sharded, multi-device, GPU) plug in via :func:`register`
without touching the core.

This module is the ONLY place allowed to know which concrete kernel module
backs which name; ``if cfg.backend == ...`` branches anywhere else are a bug.
"""
from __future__ import annotations

import os
from typing import Callable, NamedTuple

import jax


class KernelBackend(NamedTuple):
    """The typed kernel contract every backend must implement.

    All entries are pure, trace-compatible functions. Two orthogonal axes
    run through the contract: *batch-first* entries stream many datapoints
    through ONE machine, *replica-first* entries stream one datapoint each
    through MANY independent machines — the cross-validation /
    hyperparameter sweep axis (paper §3.6.1/§5, DESIGN.md §9) and, since
    the serving layer became the contract's third consumer, the online
    fleet axis (K concurrent Fig-3 sessions, DESIGN.md §10). Replica-first operands
    follow one layout rule: per-replica state/control carries a leading
    ``R``; per-data-stream operands (literals, uniforms) carry a leading
    ``D`` with ``D | R``, and replica ``r`` reads data row ``r % D`` — so a
    hyperparameter grid over shared data stores each draw once.

    * ``clause_eval(include [C,J,L] bool, literals [L] bool, *, training)
      -> [C,J] bool`` — one datapoint's clause plane.
    * ``clause_eval_batch(include [C,J,L] bool, literals [B,L] bool, *,
      training) -> [B,C,J] bool`` — the batch-first entry point; MUST equal
      stacking ``clause_eval`` over rows bit-for-bit.
    * ``clause_eval_replicated(include [R,C,J,L], literals [D,L], *,
      training) -> [R,C,J]`` — replica-first clause plane; MUST equal
      stacking ``clause_eval(include[r], literals[r % D])`` bit-for-bit.
    * ``clause_eval_batch_replicated(include [R,C,J,L], literals [D,B,L], *,
      training) -> [R,B,C,J]`` — replica-first analysis/serving pass (the
      sweep's fused multi-set analysis AND the fleet ``infer`` path run on
      this entry; pallas: one 3-D (replica, clause-block, column-block)
      grid with ``r % D`` rhs index maps); MUST equal stacking
      ``clause_eval_batch`` per replica bit-for-bit.
    * ``clause_eval_batch_packed(include_packed [C,J,W] uint32,
      literals_packed [B,W] uint32, *, training) -> [B,C,J]`` — the
      bit-packed datapath (DESIGN.md §13): W = 2*ceil(f/32) words per the
      two-half layout in :mod:`repro.kernels.packing`, clause eval as
      AND + popcount (``fires <=> sum_w popcount(inc & ~lit) == 0``). MUST
      equal ``clause_eval_batch`` on the corresponding unpacked operands
      bit-for-bit — the unpacked entry is the packed path's parity oracle.
    * ``clause_eval_batch_replicated_packed(include_packed [R,C,J,W],
      literals_packed [D,B,W], *, training) -> [R,B,C,J]`` — replica-first
      packed analysis/serving pass, same ``r % D`` data-stream rule; MUST
      equal ``clause_eval_batch_replicated`` on unpacked operands.
    * ``clause_eval_batch_pruned(include [C,J,L], sel [C,M] i32,
      literals [B,L], *, training) -> [B,C,M]`` — budgeted serve
      (DESIGN.md §16): the include bank compacts to the selected clauses
      (a gather along J) BEFORE the contraction, so compute shrinks with
      the budget M rather than masking. Column m MUST equal
      ``clause_eval_batch(...)[:, c, sel[c, m]]`` bit-for-bit.
    * ``clause_eval_batch_pruned_replicated(include [R,C,J,L],
      sel [R,C,M], literals [D,B,L], *, training) -> [R,B,C,M]`` —
      replica-first budgeted serve; replica ``r`` reads batch ``r % D``
      and its OWN per-replica ranking ``sel[r]``.
    * ``clause_eval_batch_pruned_packed(include_packed [C,J,W] u32,
      sel [C,M], literals_packed [B,W] u32, *, training) -> [B,C,M]`` and
      ``clause_eval_batch_pruned_replicated_packed([R,C,J,W], [R,C,M],
      [D,B,W], *, training) -> [R,B,C,M]`` — the packed twins (the gather
      never touches the word axis, so the §13 tail-bits-zero contract is
      preserved and packed pruned MUST equal unpacked pruned bit-for-bit).
    * ``feedback_step(ta_state [C,J,L], literals [L], clause_out [C,J],
      type1_sel [C,J], type2_sel [C,J], u [C,J,L], *, s, n_states, s_policy,
      boost_true_positive) -> new ta_state`` — one datapoint's TA update.
    * ``feedback_step_replicated(ta_state [R,C,J,L], literals [D,L],
      clause_out [R,C,J], type1_sel [R,C,J], type2_sel [R,C,J], u [D,C,J,L],
      *, s [R], n_states, s_policy, boost_true_positive) -> [R,C,J,L]`` —
      R independent TA-bank updates in one fused plane (ref: one [R, C·J, L]
      elementwise pass; pallas: a 2-D (replica, clause-block) grid); MUST
      equal stacking ``feedback_step`` per replica bit-for-bit.
    """

    name: str
    clause_eval: Callable[..., jax.Array]
    clause_eval_batch: Callable[..., jax.Array]
    clause_eval_replicated: Callable[..., jax.Array]
    clause_eval_batch_replicated: Callable[..., jax.Array]
    clause_eval_batch_packed: Callable[..., jax.Array]
    clause_eval_batch_replicated_packed: Callable[..., jax.Array]
    clause_eval_batch_pruned: Callable[..., jax.Array]
    clause_eval_batch_pruned_replicated: Callable[..., jax.Array]
    clause_eval_batch_pruned_packed: Callable[..., jax.Array]
    clause_eval_batch_pruned_replicated_packed: Callable[..., jax.Array]
    feedback_step: Callable[..., jax.Array]
    feedback_step_replicated: Callable[..., jax.Array]


# Factories, not instances: "pallas" must not import Pallas machinery unless
# it is actually selected (keeps ref-only environments import-light).
_FACTORIES: dict[str, Callable[[], KernelBackend]] = {}
_CACHE: dict[str, KernelBackend] = {}


def register(name: str, factory: Callable[[], KernelBackend]) -> None:
    """Register (or replace) a backend under ``name``."""
    _FACTORIES[name] = factory
    _CACHE.pop(name, None)


def available() -> tuple[str, ...]:
    """Registered backend names (plus the ``auto`` alias)."""
    return tuple(sorted(_FACTORIES)) + ("auto",)


def _auto_name() -> str:
    # TM_BACKEND overrides auto-resolution (CI runs the kernel/parity suite
    # a second time with TM_BACKEND=pallas in interpret mode).
    env = os.environ.get("TM_BACKEND")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def resolve(name: str) -> KernelBackend:
    """Backend name (or ``"auto"``) -> the :class:`KernelBackend` instance."""
    if name == "auto":
        name = _auto_name()
    if name not in _FACTORIES:
        raise ValueError(
            f"unknown kernel backend {name!r}; available: {available()}"
        )
    if name not in _CACHE:
        _CACHE[name] = _FACTORIES[name]()
    return _CACHE[name]


def _make_ref() -> KernelBackend:
    from repro.kernels import ref

    return KernelBackend(
        name="ref",
        clause_eval=ref.clause_eval,
        clause_eval_batch=ref.clause_eval_batch,
        clause_eval_replicated=ref.clause_eval_replicated,
        clause_eval_batch_replicated=ref.clause_eval_batch_replicated,
        clause_eval_batch_packed=ref.clause_eval_batch_packed,
        clause_eval_batch_replicated_packed=(
            ref.clause_eval_batch_replicated_packed
        ),
        clause_eval_batch_pruned=ref.clause_eval_batch_pruned,
        clause_eval_batch_pruned_replicated=(
            ref.clause_eval_batch_pruned_replicated
        ),
        clause_eval_batch_pruned_packed=ref.clause_eval_batch_pruned_packed,
        clause_eval_batch_pruned_replicated_packed=(
            ref.clause_eval_batch_pruned_replicated_packed
        ),
        feedback_step=ref.feedback_step,
        feedback_step_replicated=ref.feedback_step_replicated,
    )


def _make_pallas() -> KernelBackend:
    from repro.kernels import ops

    return KernelBackend(
        name="pallas",
        clause_eval=ops.clause_eval,
        clause_eval_batch=ops.clause_eval_batch,
        clause_eval_replicated=ops.clause_eval_replicated,
        clause_eval_batch_replicated=ops.clause_eval_batch_replicated,
        clause_eval_batch_packed=ops.clause_eval_batch_packed,
        clause_eval_batch_replicated_packed=(
            ops.clause_eval_batch_replicated_packed
        ),
        clause_eval_batch_pruned=ops.clause_eval_batch_pruned,
        clause_eval_batch_pruned_replicated=(
            ops.clause_eval_batch_pruned_replicated
        ),
        clause_eval_batch_pruned_packed=ops.clause_eval_batch_pruned_packed,
        clause_eval_batch_pruned_replicated_packed=(
            ops.clause_eval_batch_pruned_replicated_packed
        ),
        feedback_step=ops.feedback_step,
        feedback_step_replicated=ops.feedback_step_replicated,
    )


register("ref", _make_ref)
register("pallas", _make_pallas)
