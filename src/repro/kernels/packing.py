"""Bit-packed literal layout: uint32 lanes for the boolean datapath.

The source FPGA is fast because TM state and booleanized data are *bits*:
clause evaluation is wide AND/NOT logic over literal wires, not arithmetic.
The unpacked datapath in this repo moves one int8/bool per literal — at
MNIST width (f = 784, L = 1568) every clause pass streams ~1.5 KB per
sample where 49 uint32 words carry the same information. This module
defines the packed representation and the pack/unpack boundaries; the
packed clause kernels (``ref.clause_eval_batch_packed`` and the
word-tiled Pallas kernel in ``clause_eval.py``) evaluate clauses as
``AND`` + ``popcount`` over these words.

Layout rule (DESIGN.md §13):

* **Word-major, LSB-first**: bit ``i`` of word ``w`` holds element
  ``32*w + i`` of the bit vector. A vector of ``n`` bits packs into
  ``ceil(n/32)`` uint32 words; the unused high bits of the last word
  ("tail bits") are ALWAYS zero — every packer here guarantees it, and
  the packed clause kernels rely on it (``include & ~literals`` is
  tail-safe iff the include tail is zero; the literal tail is then
  don't-care).
* **Literals pack as two feature halves**: the literal vector
  ``[x, ~x]`` (length 2f) packs as ``[pack(x), pack(~x)]`` — 2·ceil(f/32)
  words, each half independently tail-padded. This keeps the packed
  complement a pure word operation (``~words & word_mask``), so ring
  buffers and routers store *packed features* (ceil(f/32) words) and the
  drain/infer boundary derives packed literals without ever unpacking.
  Include masks over the literal axis pack with the SAME split
  (:func:`pack_include`), so bit positions line up by construction.
* **Pack/unpack boundaries**: features pack at ingress (host-side,
  :func:`pack_bits_np`, before staging) and stay packed through the ring
  buffer and every inference/analysis pass; the ONLY unpack is the
  per-datapoint feedback step inside the online drain (TA updates are
  per-literal elementwise work and need the bits). Include masks pack
  from the int8 TA banks at each drain/infer call boundary
  (``tm.ta_actions_packed``) — O(C·J·L) once per batched call vs
  O(B·C·J·L) for the evaluation it feeds.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32


def n_words(n_bits: int) -> int:
    """uint32 words needed for a vector of ``n_bits`` bits."""
    return -(-n_bits // WORD_BITS)


def tail_bits(n_bits: int) -> int:
    """Valid bits in the last word (32 when ``n_bits`` is word-aligned)."""
    r = n_bits % WORD_BITS
    return WORD_BITS if r == 0 else r


def tail_mask(n_bits: int) -> int:
    """Python-int mask of the valid bits in the last word."""
    return (1 << tail_bits(n_bits)) - 1


def word_mask(n_bits: int) -> jax.Array:
    """[n_words] uint32 — all-ones per word, tail bits masked off."""
    w = n_words(n_bits)
    m = np.full((w,), 0xFFFFFFFF, dtype=np.uint32)
    m[-1] = tail_mask(n_bits)
    return jnp.asarray(m)


# ---------------------------------------------------------------------------
# Generic bit packing (jax + numpy twins, asserted equal in tests)
# ---------------------------------------------------------------------------

_SHIFTS = np.arange(WORD_BITS, dtype=np.uint32)


def pack_bits(bits: jax.Array) -> jax.Array:
    """[..., n] bool -> [..., ceil(n/32)] uint32, LSB-first, tail bits zero."""
    bits = jnp.asarray(bits).astype(bool)
    n = bits.shape[-1]
    w = n_words(n)
    pad = w * WORD_BITS - n
    if pad:
        bits = jnp.concatenate(
            [bits, jnp.zeros(bits.shape[:-1] + (pad,), dtype=bool)], axis=-1
        )
    b = bits.reshape(bits.shape[:-1] + (w, WORD_BITS)).astype(jnp.uint32)
    # Sum of distinct powers of two — exact in uint32 by construction.
    return jnp.sum(b << jnp.asarray(_SHIFTS), axis=-1, dtype=jnp.uint32)


def unpack_bits(words: jax.Array, n_bits: int) -> jax.Array:
    """[..., ceil(n/32)] uint32 -> [..., n_bits] bool (pack_bits inverse)."""
    words = jnp.asarray(words, dtype=jnp.uint32)
    b = (words[..., :, None] >> jnp.asarray(_SHIFTS)) & jnp.uint32(1)
    b = b.reshape(words.shape[:-1] + (words.shape[-1] * WORD_BITS,))
    return b[..., :n_bits].astype(bool)


def pack_bits_np(bits: np.ndarray) -> np.ndarray:
    """Host-side :func:`pack_bits` twin (the router's staging boundary)."""
    bits = np.asarray(bits, dtype=bool)
    n = bits.shape[-1]
    w = n_words(n)
    pad = w * WORD_BITS - n
    if pad:
        bits = np.concatenate(
            [bits, np.zeros(bits.shape[:-1] + (pad,), dtype=bool)], axis=-1
        )
    b = bits.reshape(bits.shape[:-1] + (w, WORD_BITS)).astype(np.uint32)
    return (b << _SHIFTS).sum(axis=-1, dtype=np.uint32)


def unpack_bits_np(words: np.ndarray, n_bits: int) -> np.ndarray:
    """Host-side :func:`unpack_bits` twin."""
    words = np.asarray(words, dtype=np.uint32)
    b = (words[..., :, None] >> _SHIFTS) & np.uint32(1)
    b = b.reshape(words.shape[:-1] + (words.shape[-1] * WORD_BITS,))
    return b[..., :n_bits].astype(bool)


# ---------------------------------------------------------------------------
# The literal-axis layout: two feature halves
# ---------------------------------------------------------------------------


def lit_words(n_features: int) -> int:
    """Packed width of the literal vector [x, ~x]: 2 * ceil(f/32) words."""
    return 2 * n_words(n_features)


def pack_literals(x: jax.Array) -> jax.Array:
    """bool features [..., f] -> packed literals [..., 2*ceil(f/32)] uint32.

    Equals ``[pack_bits(x), pack_bits(~x)]`` — the two-half layout, NOT a
    contiguous pack of the [2f] vector (those differ when f % 32 != 0).
    """
    x = jnp.asarray(x).astype(bool)
    return jnp.concatenate([pack_bits(x), pack_bits(~x)], axis=-1)


def literals_from_packed(x_packed: jax.Array, n_features: int) -> jax.Array:
    """Packed features [..., ceil(f/32)] -> packed literals [..., 2*ceil(f/32)].

    The complement half is a pure word operation (``~x & word_mask``) —
    the reason literals pack as two halves: buffered packed features turn
    into packed literals without touching individual bits. Bit-identical
    to ``pack_literals(unpack_bits(x_packed, f))``.
    """
    x_packed = jnp.asarray(x_packed, dtype=jnp.uint32)
    neg = ~x_packed & word_mask(n_features)
    return jnp.concatenate([x_packed, neg], axis=-1)


def pack_include(include: jax.Array, n_features: int) -> jax.Array:
    """Include masks [..., 2f] bool -> [..., 2*ceil(f/32)] uint32.

    Same two-half split as :func:`pack_literals` so a packed include word
    and a packed literal word index the same literal positions.
    """
    include = jnp.asarray(include).astype(bool)
    pos = include[..., :n_features]
    neg = include[..., n_features:]
    return jnp.concatenate([pack_bits(pos), pack_bits(neg)], axis=-1)


def packed_row_bytes(n_features: int) -> int:
    """Bytes per packed feature row (the ingress/buffer bandwidth unit)."""
    return 4 * n_words(n_features)
