"""Pallas TPU kernels for the paper's compute hot-spots (DESIGN.md §8).

dispatch.py    — THE backend seam: ref|pallas|auto registry behind one typed,
                 batch-first kernel contract (KernelBackend)
clause_eval.py — clause evaluation as an int8 MXU matmul (the paper's
                 2-cycle inference datapath, recast for the systolic array);
                 batched form evaluates all B datapoints per include-bank
                 read; packed form evaluates uint32 literal words as
                 AND + popcount (the FPGA's bit-level datapath, §13)
packing.py     — the bit-packed literal layout: uint32 words, two-half
                 [pack(x), pack(~x)] literal split, tail-bit contract
feedback.py    — fused Type I/II TA-bank update (one VPU pass per datapoint)
ops.py         — jit'd public wrappers (interpret=True on CPU; TPU target)
ref.py         — pure-jnp oracles; kernels are asserted bit-exact vs these
"""
