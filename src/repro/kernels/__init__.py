"""Pallas TPU kernels for the paper's compute hot-spots (DESIGN.md §8).

clause_eval.py — clause evaluation as an int8 MXU matmul (the paper's
                 2-cycle inference datapath, recast for the systolic array)
feedback.py    — fused Type I/II TA-bank update (one VPU pass per datapoint)
ops.py         — jit'd public wrappers (interpret=True on CPU; TPU target)
ref.py         — pure-jnp oracles; kernels are asserted bit-exact vs these
"""
