"""Public jit'd wrappers over the Pallas kernels.

Same contracts as :mod:`repro.kernels.ref` (the pure-jnp oracles) so the TM
core can switch backends with ``TMConfig.backend``. ``interpret`` defaults to
True — this container is CPU-only; on a real TPU pass ``interpret=False``
(the kernels are written against TPU tile constraints: int8 32x128 blocks,
128-lane last dims, MXU-shaped matmuls).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import clause_eval as _ce
from repro.kernels import feedback as _fb

INTERPRET = True  # flipped by launch scripts when running on real TPUs


def clause_eval(
    include: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """[C, J, L] bool x [L] bool -> [C, J] bool (see ref.clause_eval)."""
    return _ce.clause_eval(
        include, literals, training=training, interpret=INTERPRET
    )


def clause_eval_batch(
    include: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """[C, J, L] bool x [B, L] bool -> [B, C, J] bool (see ref.clause_eval_batch)."""
    return _ce.clause_eval_batch(
        include, literals, training=training, interpret=INTERPRET
    )


def clause_eval_replicated(
    include: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """[R, C, J, L] x [D, L] -> [R, C, J] (see ref.clause_eval_replicated)."""
    return _ce.clause_eval_replicated(
        include, literals, training=training, interpret=INTERPRET
    )


def clause_eval_batch_replicated(
    include: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """[R, C, J, L] x [D, B, L] -> [R, B, C, J] (see ref.clause_eval_batch_replicated)."""
    return _ce.clause_eval_batch_replicated(
        include, literals, training=training, interpret=INTERPRET
    )


def clause_eval_batch_packed(
    include_packed: jax.Array, literals_packed: jax.Array, *, training: bool
) -> jax.Array:
    """[C, J, W] u32 x [B, W] u32 -> [B, C, J] bool (see
    ref.clause_eval_batch_packed)."""
    return _ce.clause_eval_batch_packed(
        include_packed, literals_packed, training=training, interpret=INTERPRET
    )


def clause_eval_batch_replicated_packed(
    include_packed: jax.Array, literals_packed: jax.Array, *, training: bool
) -> jax.Array:
    """[R, C, J, W] u32 x [D, B, W] u32 -> [R, B, C, J] bool (see
    ref.clause_eval_batch_replicated_packed)."""
    return _ce.clause_eval_batch_replicated_packed(
        include_packed, literals_packed, training=training, interpret=INTERPRET
    )


def clause_eval_batch_pruned(
    include: jax.Array, sel: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """[C, J, L] x sel [C, M] x [B, L] -> [B, C, M] (see
    ref.clause_eval_batch_pruned). The include bank compacts to the
    selected clauses before launch, so the kernel grid shrinks with M."""
    return _ce.clause_eval_batch_pruned(
        include, sel, literals, training=training, interpret=INTERPRET
    )


def clause_eval_batch_pruned_replicated(
    include: jax.Array, sel: jax.Array, literals: jax.Array, *, training: bool
) -> jax.Array:
    """[R, C, J, L] x sel [R, C, M] x [D, B, L] -> [R, B, C, M] (see
    ref.clause_eval_batch_pruned_replicated)."""
    return _ce.clause_eval_batch_pruned_replicated(
        include, sel, literals, training=training, interpret=INTERPRET
    )


def clause_eval_batch_pruned_packed(
    include_packed: jax.Array, sel: jax.Array, literals_packed: jax.Array,
    *, training: bool,
) -> jax.Array:
    """[C, J, W] u32 x sel [C, M] x [B, W] u32 -> [B, C, M] (see
    ref.clause_eval_batch_pruned_packed)."""
    return _ce.clause_eval_batch_pruned_packed(
        include_packed, sel, literals_packed,
        training=training, interpret=INTERPRET,
    )


def clause_eval_batch_pruned_replicated_packed(
    include_packed: jax.Array, sel: jax.Array, literals_packed: jax.Array,
    *, training: bool,
) -> jax.Array:
    """[R, C, J, W] u32 x sel [R, C, M] x [D, B, W] u32 -> [R, B, C, M]
    (see ref.clause_eval_batch_pruned_replicated_packed)."""
    return _ce.clause_eval_batch_pruned_replicated_packed(
        include_packed, sel, literals_packed,
        training=training, interpret=INTERPRET,
    )


def feedback_step(
    ta_state: jax.Array,
    literals: jax.Array,
    clause_out: jax.Array,
    type1_sel: jax.Array,
    type2_sel: jax.Array,
    u: jax.Array,
    *,
    s: jax.Array,
    n_states: int,
    s_policy: str,
    boost_true_positive: bool,
) -> jax.Array:
    """Same contract as ref.feedback_step, backed by the fused Pallas kernel."""
    C, J, L = ta_state.shape
    s = jnp.asarray(s, dtype=jnp.float32)
    p_strengthen = jnp.where(boost_true_positive, 1.0, (s - 1.0) / s)
    p_erase = (1.0 / s) if s_policy == "standard" else (s - 1.0) / s
    out = _fb.feedback_plane(
        ta_state.reshape(C * J, L),
        literals,
        clause_out.reshape(C * J),
        type1_sel.reshape(C * J),
        type2_sel.reshape(C * J),
        u.reshape(C * J, L),
        p_strengthen,
        jnp.asarray(p_erase, dtype=jnp.float32),
        n_states=n_states,
        interpret=INTERPRET,
    )
    return out.reshape(C, J, L)


def feedback_step_replicated(
    ta_state: jax.Array,    # [R, C, J, L]
    literals: jax.Array,    # [D, L] — replica r reads row r % D
    clause_out: jax.Array,  # [R, C, J]
    type1_sel: jax.Array,   # [R, C, J]
    type2_sel: jax.Array,   # [R, C, J]
    u: jax.Array,           # [D, C, J, L] — replica r reads row r % D
    *,
    s: jax.Array,           # [R] f32 (scalars broadcast)
    n_states: int,
    s_policy: str,
    boost_true_positive: bool,
) -> jax.Array:
    """Same contract as ref.feedback_step_replicated: R TA banks, ONE launch
    of the 2-D-grid (replica, clause-block) fused Pallas plane."""
    R, C, J, L = ta_state.shape
    D = literals.shape[0]
    s = jnp.broadcast_to(jnp.asarray(s, dtype=jnp.float32), (R,))
    p_strengthen = jnp.where(boost_true_positive, 1.0, (s - 1.0) / s)
    p_erase = (1.0 / s) if s_policy == "standard" else (s - 1.0) / s
    out = _fb.feedback_plane_replicated(
        ta_state.reshape(R, C * J, L),
        literals,
        clause_out.reshape(R, C * J),
        type1_sel.reshape(R, C * J),
        type2_sel.reshape(R, C * J),
        u.reshape(D, C * J, L),
        p_strengthen,
        jnp.asarray(p_erase, dtype=jnp.float32),
        n_states=n_states,
        interpret=INTERPRET,
    )
    return out.reshape(R, C, J, L)
