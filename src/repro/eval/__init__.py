"""Evaluation engines: cross-validation x hyperparameter sweeps (paper §3.6.1, §5)."""
from repro.eval.crossval import CrossValRun, SweepResult, SystemResult  # noqa: F401
