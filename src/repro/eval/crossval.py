"""Replica-parallel cross-validation engine (paper §3.6.1, §5 — goal ii).

The paper's "inbuilt cross-validation infrastructure" re-runs every
experiment over block orderings and sweeps (s, T) "within a short period of
time". Here the whole sweep — orderings x s-grid x T-grid — is compiled as
ONE program over a leading *replica* axis:

* replica layout is grid-major / ordering-minor: ``r = (si*G + ti)*O + o``,
  so the ``D = O`` data streams (block orderings) are the fastest-varying
  factor and every per-data operand (datapoints, labels, RNG streams) is
  stored once per ordering and broadcast across the (s, T) grid — the
  kernel contract's ``r % D`` rule (kernels/dispatch.py);
* training runs through :func:`repro.core.feedback.train_epochs_replicated`
  — one ``lax.scan`` over datapoints whose body advances all R TA banks in
  a single fused ``feedback_step_replicated`` plane;
* analysis is one ``clause_eval_batch_replicated`` contraction for all
  replicas;
* the replica axis is shardable over a device mesh via
  :func:`repro.distributed.sharding.replica_shardings` (pass ``mesh=``).

Results are **bit-identical** to looping ``hpsearch._one_cell`` over cells
(asserted in tests/test_crossval.py and benchmarks/crossval.py) while the
sweep wall-clock is >= 2x faster than the pre-replica vmap-of-scan path on
CPU (tracked in BENCH_crossval.json).

The same replica machinery also runs the paper's Fig-3 system flow
(offline -> online cycles -> per-cycle analysis) for all orderings at once:
:meth:`CrossValRun.system` is what ``manager.run_orderings`` and the
figure benchmarks are thin callers of.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import accuracy as acc_mod
from repro.core import feedback as fb_mod
from repro.core import manager as mgr
from repro.core import tm as tm_mod
from repro.core.tm import TMConfig, TMRuntime, TMState
from repro.distributed import sharding as shard_mod


class SweepResult(NamedTuple):
    """One (s x T x orderings) sweep's output (mirrors hpsearch.GridResult)."""

    s_grid: np.ndarray        # [S]
    T_grid: np.ndarray        # [G]
    val_accuracy: jax.Array   # [S, G, O] per-ordering validation accuracy
    mean_accuracy: jax.Array  # [S, G]
    replicas: int             # R = S * G * O
    wall_s: float             # device wall-clock of the compiled sweep
    replicas_per_s: float


class SystemResult(NamedTuple):
    """All-orderings Fig-3 system run (mirrors manager.run_system outputs)."""

    state: TMState            # leaves [O, ...]
    accuracies: jax.Array     # [O, 1 + n_cycles, 3] (offline/validation/online)
    activity: jax.Array       # [O, n_cycles]
    replicas: int
    wall_s: float


def replicate_state(cfg: TMConfig, n_replicas: int) -> TMState:
    """R copies of the deterministic boundary init (init_state without key)."""
    base = tm_mod.init_state(cfg).ta_state
    return TMState(
        ta_state=jnp.broadcast_to(base, (n_replicas,) + base.shape)
    )


def grid_layout(
    s_values, T_values, n_orderings: int
) -> tuple[jax.Array, jax.Array]:
    """Per-replica (s, T) for the grid-major / ordering-minor layout.

    ``r = (si*G + ti)*O + o`` — the ordering axis is fastest-varying so data
    streams are shared across the grid via the kernels' ``r % D`` rule.
    """
    s_grid = jnp.asarray(s_values, dtype=jnp.float32)
    T_grid = jnp.asarray(T_values, dtype=jnp.int32)
    G, O = T_grid.shape[0], n_orderings
    s_rep = jnp.repeat(s_grid, G * O)
    T_rep = jnp.tile(jnp.repeat(T_grid, O), s_grid.shape[0])
    return s_rep, T_rep


@partial(jax.jit, static_argnums=(0, 5))
def _sweep_device(
    cfg: TMConfig,
    s_rep: jax.Array,    # [R] f32
    T_rep: jax.Array,    # [R] i32
    off_sets,            # (off_x [O,n,f], off_y [O,n], off_valid [O,n]|None)
    val_sets,            # (val_x [O,m,f], val_y [O,m])
    n_epochs: int,
    keys: jax.Array,     # [O] keys
) -> jax.Array:
    """The entire sweep as one compiled program. [R] validation accuracy."""
    off_x, off_y, off_valid = off_sets
    val_x, val_y = val_sets
    R = s_rep.shape[0]

    rt = tm_mod.init_runtime(cfg)._replace(s=s_rep, T=T_rep)
    state = replicate_state(cfg, R)
    state = fb_mod.train_epochs_replicated(
        cfg, state, rt, off_x, off_y, keys, n_epochs, valid=off_valid
    )
    return acc_mod.analyze_replicated(cfg, state, rt, val_x, val_y)


def _analyze_all_replicated(cfg, state, ctl: mgr.CycleCtl) -> jax.Array:
    # ONE clause contraction for the whole three-set analysis block (the
    # ROADMAP system-path item): the include bank streams once per cycle.
    s = ctl.sets
    return acc_mod.analyze_sets_replicated(cfg, state, ctl.rt, [
        (s.offline_x, s.offline_y, s.offline_valid),
        (s.validation_x, s.validation_y, s.validation_valid),
        (s.online_x, s.online_y, s.online_valid),
    ])                                                 # [O, 3]


@partial(jax.jit, static_argnums=(0, 1, 5))
def _system_device(
    cfg: TMConfig,
    sys_cfg: mgr.SystemConfig,
    states: TMState,     # leaves [O, ...]
    rt: TMRuntime,       # shared (unreplicated) leaves
    sets: mgr.Sets,      # leaves [O, ...]
    schedule: mgr.Schedule,
    keys: jax.Array,     # [O] keys
):
    """Replica-parallel Fig-3 flow: every ordering advances per step in one
    fused plane instead of one vmapped program per ordering."""
    ks = jax.vmap(jax.random.split)(keys)              # [O, 2, key]
    k_off, k_onl = ks[:, 0], ks[:, 1]

    # --- offline training phase (cycle index -1) ---
    ctl0 = schedule(jnp.int32(-1), rt, sets)
    train_valid = ctl0.sets.offline_train_valid
    if train_valid is None:
        train_valid = ctl0.sets.offline_valid
    else:
        train_valid = train_valid & ctl0.sets.offline_valid
    state = fb_mod.train_epochs_replicated(
        cfg, states, ctl0.rt,
        ctl0.sets.offline_x, ctl0.sets.offline_y,
        k_off, sys_cfg.n_offline_epochs,
        valid=train_valid,
    )
    acc0 = _analyze_all_replicated(cfg, state, ctl0)

    # --- online cycles ---
    def body(carry, cycle):
        st = carry
        ctl = schedule(cycle, rt, sets)
        k = jax.vmap(lambda kk: jax.random.fold_in(kk, cycle))(k_onl)
        new_st, act = fb_mod.train_datapoints_replicated(
            cfg, st, ctl.rt, ctl.sets.online_x, ctl.sets.online_y, k,
            valid=ctl.sets.online_valid,
        )
        st = jax.tree.map(
            lambda a, b: jnp.where(ctl.online_enabled, a, b), new_st, st
        )
        accs = _analyze_all_replicated(cfg, st, ctl)
        activity = jnp.where(ctl.online_enabled, jnp.mean(act, axis=0), 0.0)
        return st, (accs, activity)

    cycles = jnp.arange(sys_cfg.n_online_cycles, dtype=jnp.int32)
    state, (accs, activity) = jax.lax.scan(body, state, cycles)
    accuracies = jnp.concatenate(
        [acc0[:, None], jnp.moveaxis(accs, 0, 1)], axis=1
    )                                                  # [O, 1+cycles, 3]
    return state, accuracies, jnp.moveaxis(activity, 0, 1)


@dataclasses.dataclass(frozen=True)
class CrossValRun:
    """The cross-validation engine: ONE compiled program per sweep.

    ``mesh`` (optional) shards the replica axis of every input over the
    mesh's ``data`` axes via :func:`distributed.sharding.replica_shardings`
    before launch — pod-scale design-space exploration with zero changes to
    the compiled program.
    """

    cfg: TMConfig
    mesh: Optional[Mesh] = None

    def _put(self, tree, n_replicas: Optional[int] = None):
        if self.mesh is None:
            return tree
        # Shard only the full-R (grid-major) axis; per-data-stream leaves
        # (leading D < R) replicate so every replica's r % D gather stays
        # device-local (no cross-device collectives inside the sweep).
        sh = shard_mod.replica_shardings(
            tree, self.mesh, n_replicas=n_replicas
        )
        return jax.tree.map(jax.device_put, tree, sh)

    def sweep(
        self,
        off_x, off_y,      # [O, n, f] / [O, n] — per-ordering offline sets
        val_x, val_y,      # [O, m, f] / [O, m] — per-ordering validation sets
        s_values, T_values,
        *,
        n_epochs: int = 10,
        seed: int = 0,
        offline_valid=None,  # [O, n] bool — masked-out rows skipped
    ) -> SweepResult:
        """The full (s x T x orderings) sweep as one program.

        Bit-identical to looping ``hpsearch._one_cell`` over every cell with
        the same per-ordering keys (``split(PRNGKey(seed), O)``).
        """
        O = off_x.shape[0]
        s_rep, T_rep = grid_layout(s_values, T_values, O)
        S, G = len(np.atleast_1d(s_values)), len(np.atleast_1d(T_values))
        R = S * G * O
        keys = jax.random.split(jax.random.PRNGKey(seed), O)

        off = (
            jnp.asarray(off_x, bool), jnp.asarray(off_y, jnp.int32),
            None if offline_valid is None else jnp.asarray(offline_valid, bool),
        )
        val = (jnp.asarray(val_x, bool), jnp.asarray(val_y, jnp.int32))
        s_rep, T_rep, off, val, keys = self._put(
            (s_rep, T_rep, off, val, keys), n_replicas=R
        )

        t0 = time.perf_counter()
        acc = _sweep_device(self.cfg, s_rep, T_rep, off, val, n_epochs, keys)
        acc = jax.block_until_ready(acc)
        wall = time.perf_counter() - t0

        val_accuracy = acc.reshape(S, G, O)
        return SweepResult(
            s_grid=np.asarray(s_values, dtype=np.float32),
            T_grid=np.asarray(T_values, dtype=np.int32),
            val_accuracy=val_accuracy,
            mean_accuracy=jnp.mean(val_accuracy, axis=-1),
            replicas=R,
            wall_s=wall,
            replicas_per_s=R / max(wall, 1e-9),
        )

    def system(
        self,
        sys_cfg: mgr.SystemConfig,
        states: TMState,     # leaves [O, ...]
        rt: TMRuntime,       # shared leaves (s/T scalars, masks)
        sets: mgr.Sets,      # leaves [O, ...]
        schedule: mgr.Schedule,
        keys: jax.Array,     # [O] keys
    ) -> SystemResult:
        """All cross-validation orderings through the Fig-3 system flow.

        The replica-parallel successor of vmapping ``manager.run_system``:
        same accuracies/activity bit-for-bit, one fused plane per datapoint.
        """
        O = keys.shape[0]
        states, sets, keys = self._put((states, sets, keys), n_replicas=O)
        t0 = time.perf_counter()
        state, accs, activity = _system_device(
            self.cfg, sys_cfg, states, rt, sets, schedule, keys
        )
        accs = jax.block_until_ready(accs)
        return SystemResult(
            state=state,
            accuracies=accs,
            activity=activity,
            replicas=O,
            wall_s=time.perf_counter() - t0,
        )
