"""Checkpointing: atomic, keep-k, resumable, and **reshardable**.

Layout:  <dir>/step_<n>/ arrays.npz + manifest.json   (+ <dir>/LATEST)

* Atomicity: write into `step_<n>.tmp`, fsync, rename — a crash mid-save
  never corrupts the restore point (the paper's accuracy-watchdog "retrain
  from a known-good state" maps to exactly this).
* Elasticity: arrays are saved as full logical tensors (gathered); on load
  they are re-placed under the *current* mesh's shardings, so a job can
  restart on a different device count / mesh shape (reshard-on-load).
* keep-k garbage collection bounds disk use on long runs.
* Dtype fidelity: every leaf restores with exactly the dtype it was saved
  with (pinned against the manifest, not numpy's defaults) — int8 TA
  banks, uint32 packed words and bool rows survive the trip bit for bit.
  Typed JAX PRNG key arrays (``jax.random.key``) cannot pass through
  ``np.asarray`` at all; they are routed through ``jax.random.key_data``
  on save and re-wrapped with ``jax.random.wrap_key_data`` (impl recorded
  in the manifest) on restore.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict[str, Any]:
    flat = {}

    def walk(node, path):
        if node is None:
            return  # e.g. disabled optional state (compression off)
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{path}/{k}" if path else k)
        elif isinstance(node, (tuple, list)):
            for i, v in enumerate(node):
                walk(v, f"{path}/{i}")
        else:
            flat[path] = node

    walk(tree, "")
    return flat


def _unflatten_like(template, flat: dict[str, Any]):
    def walk(node, path):
        if node is None:
            return None
        if isinstance(node, dict):
            return {k: walk(v, f"{path}/{k}" if path else k)
                    for k, v in node.items()}
        if isinstance(node, tuple):
            vals = [walk(v, f"{path}/{i}") for i, v in enumerate(node)]
            return type(node)(*vals) if hasattr(node, "_fields") else tuple(vals)
        if isinstance(node, list):
            return [walk(v, f"{path}/{i}") for i, v in enumerate(node)]
        return flat[path]

    return walk(template, "")


def _is_typed_key(v) -> bool:
    """True for new-style typed PRNG key arrays (custom key<...> dtype)."""
    dtype = getattr(v, "dtype", None)
    return dtype is not None and jax.dtypes.issubdtype(dtype, jax.dtypes.prng_key)


def save(directory: str, step: int, tree, *, keep: int = 3,
         extra: Optional[dict] = None) -> str:
    """Atomic checkpoint write. Returns the final path."""
    os.makedirs(directory, exist_ok=True)
    flat = _flatten_with_paths(tree)
    # Typed PRNG key arrays have a custom dtype np.asarray rejects: store
    # the underlying uint32 key data and remember the impl for re-wrap.
    key_impls = {}
    arrays = {}
    for k, v in flat.items():
        if _is_typed_key(v):
            key_impls[k] = str(jax.random.key_impl(v))
            v = jax.random.key_data(v)
        arrays[k] = np.asarray(v)

    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": sorted(arrays.keys()),
        "dtypes": {k: str(v.dtype) for k, v in arrays.items()},
        "shapes": {k: list(v.shape) for k, v in arrays.items()},
        "key_impls": key_impls,
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)

    with open(os.path.join(directory, "LATEST"), "w") as f:
        f.write(os.path.basename(final))

    _gc(directory, keep)
    return final


def _gc(directory: str, keep: int):
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    marker = os.path.join(directory, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        name = f.read().strip()
    if not os.path.isdir(os.path.join(directory, name)):
        return None
    return int(name.split("_")[1])


def read_manifest(directory: str, *, step: Optional[int] = None) -> dict:
    """The manifest alone (no array IO) — for callers that rebuild a
    restore template from ``extra`` before loading the arrays."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        return json.load(f)


def restore(directory: str, template, *, step: Optional[int] = None,
            shardings=None, device: bool = True):
    """Load a checkpoint into the template's structure.

    ``shardings`` (optional tree of NamedSharding) re-places every array under
    the current mesh — restarts may use a different mesh than the writer
    (elastic scaling / reshard-on-load). ``device=False`` returns the
    manifest-pinned HOST numpy arrays untouched — callers that keep parts
    of the tree host-side (e.g. the service's int64/float64 policy
    counters, which a default-x32 ``jnp.asarray`` would silently demote)
    place leaves themselves.
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    key_impls = manifest.get("key_impls", {})
    flat = {}
    for k in manifest["keys"]:
        # Pin the saved dtype explicitly: a leaf must restore as exactly
        # what it was (int8 TA banks, uint32 words, bool rows), never as
        # whatever numpy or a later asarray would promote it to.
        v = np.asarray(data[k], dtype=np.dtype(manifest["dtypes"][k]))
        if k in key_impls:
            v = jax.random.wrap_key_data(
                jax.numpy.asarray(v), impl=key_impls[k]
            )
        flat[k] = v

    tree = _unflatten_like(template, flat)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings
        )
    elif device:
        tree = jax.tree.map(
            lambda x: x if _is_typed_key(x) else jax.numpy.asarray(x), tree
        )
    return tree, manifest
