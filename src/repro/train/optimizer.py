"""Hand-rolled optimizers (no optax in this environment): AdamW + SGD,
cosine/linear-warmup schedules, global-norm clipping.

Optimizer state mirrors the parameter tree, so it inherits parameter
shardings (ZeRO: FSDP-sharded params => FSDP-sharded moments for free).
Moment dtype is configurable per arch (`ModelConfig.adam_dtype`) — arctic's
480B params keep bf16 moments to fit v5e HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # adamw | sgd
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"     # cosine | linear | constant
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"


class OptState(NamedTuple):
    step: jax.Array
    mu: Any        # first moment (or momentum for sgd)
    nu: Any        # second moment (adamw only; zeros tree for sgd)


def init(cfg: OptConfig, params) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return OptState(step=jnp.int32(0), mu=zeros,
                    nu=jax.tree.map(jnp.zeros_like, zeros))


def schedule_lr(cfg: OptConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (s + 1.0) / max(cfg.warmup_steps, 1))
    if cfg.schedule == "cosine":
        t = jnp.clip((s - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        t = jnp.clip((s - cfg.warmup_steps)
                     / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        decay = 1.0 - t
    else:
        decay = 1.0
    return cfg.lr * warm * decay


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def apply(cfg: OptConfig, state: OptState, params, grads):
    """One update. Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    lr = schedule_lr(cfg, state.step)
    t = (state.step + 1).astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    if cfg.name == "sgd":
        new_mu = jax.tree.map(
            lambda m, g: (cfg.b1 * m.astype(jnp.float32)
                          + g.astype(jnp.float32)).astype(mdt),
            state.mu, grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32)
                          - lr * m.astype(jnp.float32)).astype(p.dtype),
            params, new_mu)
        return new_params, OptState(state.step + 1, new_mu, state.nu), {
            "lr": lr, "grad_norm": gnorm}

    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        step_ = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (step_ + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m32.astype(mdt), v32.astype(mdt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_params, OptState(state.step + 1, new_mu, new_nu), {
        "lr": lr, "grad_norm": gnorm}
