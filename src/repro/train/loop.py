"""Fault-tolerant training driver.

The paper's runtime-management posture (accuracy watchdog -> retrain from a
known-good state; §5.3.2) generalized to the LM trainer:

* periodic **atomic checkpoints** + resume-from-latest on (re)start,
* a **health watchdog**: non-finite loss or a per-step deadline breach is a
  fault event — the step is logged, and after `max_faults` consecutive events
  the driver restores the last checkpoint (the TM's "retrain on-chip from the
  offline set" maps to "restore + continue"),
* **straggler watch**: steps slower than `straggler_factor` x the running
  median are recorded (on a real pod this feeds the scheduler; here the
  control path is identical, the signal is wall-time),
* optional elastic restart: `resume(mesh)` re-places the checkpoint under a
  *different* mesh via reshard-on-load.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

from repro.train import checkpoint as ckpt_mod
from repro.train.train_step import TrainState


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 25
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    step_deadline_s: float = 120.0
    straggler_factor: float = 2.0
    max_faults: int = 3


@dataclasses.dataclass
class LoopReport:
    steps_run: int = 0
    losses: list = dataclasses.field(default_factory=list)
    fault_events: list = dataclasses.field(default_factory=list)
    straggler_steps: list = dataclasses.field(default_factory=list)
    restores: int = 0


def run(
    lc: LoopConfig,
    state: TrainState,
    step_fn: Callable[[TrainState, dict], tuple[TrainState, dict]],
    data_iter,
    *,
    shardings=None,
    log_every: int = 10,
    log: Callable[[str], None] = print,
) -> tuple[TrainState, LoopReport]:
    report = LoopReport()
    durations: list[float] = []
    consecutive_faults = 0

    start_step = int(jax.device_get(state.opt.step))
    last_good = start_step

    for step in range(start_step, lc.total_steps):
        batch = next(data_iter)
        t0 = time.monotonic()
        new_state, metrics = step_fn(state, batch)
        loss = float(jax.device_get(metrics["loss"]))
        dt = time.monotonic() - t0

        healthy = np.isfinite(loss) and dt <= lc.step_deadline_s
        if durations and dt > lc.straggler_factor * float(np.median(durations)):
            report.straggler_steps.append((step, dt))
        durations.append(dt)

        if not healthy:
            reason = "nan_loss" if not np.isfinite(loss) else "deadline"
            report.fault_events.append((step, reason, dt))
            consecutive_faults += 1
            log(f"[fault] step {step}: {reason} ({dt:.1f}s) "
                f"({consecutive_faults}/{lc.max_faults})")
            if consecutive_faults >= lc.max_faults:
                log(f"[fault] restoring last good checkpoint @ {last_good}")
                tree, _ = ckpt_mod.restore(
                    lc.checkpoint_dir, state, shardings=shardings
                )
                state = tree
                report.restores += 1
                consecutive_faults = 0
            continue  # skip the bad update

        consecutive_faults = 0
        state = new_state
        report.steps_run += 1
        report.losses.append(loss)

        if step % log_every == 0:
            log(f"step {step}: loss={loss:.4f} ({dt:.2f}s)")
        if (step + 1) % lc.checkpoint_every == 0:
            ckpt_mod.save(lc.checkpoint_dir, step + 1, state, keep=lc.keep)
            last_good = step + 1

    return state, report


def resume_or_init(
    lc: LoopConfig, init_state: TrainState, *, shardings=None
) -> TrainState:
    """Restore the latest checkpoint if present (restart path), else init."""
    step = ckpt_mod.latest_step(lc.checkpoint_dir)
    if step is None:
        return init_state
    tree, _ = ckpt_mod.restore(lc.checkpoint_dir, init_state,
                               shardings=shardings)
    return tree
