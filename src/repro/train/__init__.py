"""Training substrate: optimizer, train step, checkpointing, driver loop."""
