"""The jitted train step: microbatched grad accumulation + optimizer update.

Gradient accumulation runs as `lax.scan` over microbatches (activation
memory / interconnect overlap knob); gradient compression (int8 + error
feedback) optionally gates the cross-device reduction; the optimizer update
reuses parameter shardings for all its state (ZeRO).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import collectives
from repro.models import transformer
from repro.train import optimizer as opt_mod


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: opt_mod.OptConfig = dataclasses.field(default_factory=opt_mod.OptConfig)
    microbatches: int = 1
    grad_compress: bool = False
    moe_num_groups: int = 1


class TrainState(NamedTuple):
    params: Any
    opt: opt_mod.OptState
    compress: Optional[collectives.CompressionState]


def init_state(tc: TrainConfig, params) -> TrainState:
    return TrainState(
        params=params,
        opt=opt_mod.init(tc.opt, params),
        compress=collectives.init_state(params) if tc.grad_compress else None,
    )


def _split_microbatches(batch: dict, m: int) -> dict:
    def split(x):
        if x.ndim == 0:
            return jnp.broadcast_to(x, (m,))
        assert x.shape[0] % m == 0, (x.shape, m)
        return x.reshape((m, x.shape[0] // m) + x.shape[1:])

    return jax.tree.map(split, batch)


def cast_for_compute(cfg: ModelConfig, params):
    """fp32 master params -> compute dtype ONCE at the step boundary, so
    FSDP all-gathers and gradient reduce-scatters move bf16, not fp32
    (2x interconnect + weight-buffer traffic otherwise)."""
    cd = jnp.dtype(cfg.compute_dtype)
    return jax.tree.map(
        lambda p: p.astype(cd) if p.dtype == jnp.float32 else p, params
    )


def grad_fn(cfg: ModelConfig, tc: TrainConfig, params, batch):
    """Loss + grads with microbatch accumulation (scan keeps HLO small).

    Gradients are taken w.r.t. the bf16 compute copy (grad exchange in bf16);
    the optimizer re-accumulates into fp32 master params.
    """
    params_c = cast_for_compute(cfg, params)

    if tc.microbatches == 1:
        (loss, parts), grads = jax.value_and_grad(
            lambda p: transformer.loss_fn(
                cfg, p, batch, num_groups=tc.moe_num_groups
            ),
            has_aux=True,
        )(params_c)
        return loss, parts, grads

    mb = _split_microbatches(batch, tc.microbatches)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(carry, mbatch):
        acc, loss_acc = carry
        (loss, parts), grads = jax.value_and_grad(
            lambda p: transformer.loss_fn(
                cfg, p, mbatch, num_groups=tc.moe_num_groups
            ),
            has_aux=True,
        )(params_c)
        acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), acc, grads
        )
        return (acc, loss_acc + loss), parts

    (grads, loss_sum), parts = jax.lax.scan(body, (zeros, 0.0), mb)
    inv = 1.0 / tc.microbatches
    grads = jax.tree.map(lambda g: g * inv, grads)
    parts = jax.tree.map(lambda x: jnp.mean(x), parts)
    return loss_sum * inv, parts, grads


def train_step(
    cfg: ModelConfig, tc: TrainConfig, state: TrainState, batch: dict
) -> tuple[TrainState, dict]:
    loss, parts, grads = grad_fn(cfg, tc, state.params, batch)

    comp = state.compress
    metrics = {"loss": loss, **parts}
    if comp is not None:
        grads, comp, cm = collectives.compress_grads(grads, comp)
        metrics.update(cm)

    params, opt_state, om = opt_mod.apply(tc.opt, state.opt, state.params, grads)
    metrics.update(om)
    return TrainState(params=params, opt=opt_state, compress=comp), metrics
