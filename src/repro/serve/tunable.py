"""Runtime-tunable serving: clause ranking, budgeted inference, early exit.

"Runtime Tunable Tsetlin Machines for Edge Inference on eFPGAs" (PAPERS.md)
shows a trained TM's serve cost can be traded against accuracy *at runtime
without retraining*: rank clauses by their vote contribution, serve from
the top-m ranked subset, and stop the class vote once its outcome is
provably decided. This module is that knob for the fleet (DESIGN.md §16),
in four deterministic pieces:

* **Ranking** — :func:`clause_scores` / :func:`clause_scores_replicated`
  score every clause's net helpful vote contribution over a calibration
  set (one batch contraction on the trained TA banks, per replica on the
  [K] plane); :func:`rank_from_scores` turns scores into a per-class
  permutation of the clause axis (descending score, ties by clause index
  — deterministic for a fixed TA bank and calibration set).
* **Budgeted serve** — a budget b elects the top ``m = ceil(b·J)`` ranked
  clauses per class; the kernel contract's ``clause_eval_batch_pruned*``
  entries contract only the compacted include bank, so compute shrinks
  with the budget. :func:`weights_from_scores` optionally derives small
  integer vote weights from the same calibration scores.
* **Early-exit voting** — :func:`predict_pruned_replicated_host` chunks
  the ranked list into groups and stops once every request's class-sum
  margin provably exceeds the remaining groups' maximum swing (bounded by
  the remaining elected clauses' signed weight sums). Exit never changes
  a prediction: the bound is conservative, so early-exit ON is bitwise
  identical to early-exit OFF at the same budget — it only changes how
  many clause groups were evaluated (returned per request).
* **TuneController** — the per-service policy object `TMService` carries
  when built with ``ServiceConfig(tunable=...)``: holds the calibrated
  ranks/weights (host-side per-replica state — survives residency
  eviction and rides ``save``/``restore``), the current budget, and the
  queue-depth adaptation rule ``tick`` applies under load.

The correctness contract (pinned by tests/test_tunable.py): budget=100%,
unit weights, early-exit disabled is **bitwise identical** to the plain
serving path — the full ranking is a permutation, int32 vote sums commute,
and the argmax sees identical votes.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tm as tm_mod
from repro.core.tm import TMConfig, TMRuntime, TMState


# ---------------------------------------------------------------------------
# Clause ranking (calibration).
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnums=0)
def clause_scores(
    cfg: TMConfig, state: TMState, rt: TMRuntime,
    xs: jax.Array, ys: jax.Array,
) -> jax.Array:
    """Net helpful vote contribution of every clause. [C, J] int32.

    For calibration row b with label y, clause (c, j)'s vote contribution
    to class c's sum is ``fired · polarity``; it is *helpful* when it
    pushes the correct decision — positive contribution when y == c,
    negative when y != c:

        score[c, j] = sum_b fired[b, c, j] · pol[j] · (+1 if y_b == c else -1)

    One batch-first clause contraction over the whole calibration set
    (clause outputs masked by the runtime's clause mask, inference
    semantics — empty clauses score 0). Integer counts: deterministic.
    """
    clauses, _ = tm_mod.forward_batch(cfg, state, rt, xs, training=False)
    pol = tm_mod.clause_polarity(cfg)                          # [J]
    agree = jnp.where(
        ys[:, None] == jnp.arange(cfg.max_classes)[None, :], 1, -1
    ).astype(jnp.int32)                                        # [B, C]
    return jnp.sum(
        clauses.astype(jnp.int32) * pol * agree[..., None], axis=0
    )


@partial(jax.jit, static_argnums=0)
def clause_scores_replicated(
    cfg: TMConfig, state: TMState, rt: TMRuntime,
    xs: jax.Array, ys: jax.Array,
) -> jax.Array:
    """Per-replica clause scores on the [R] plane. [R, C, J] int32.

    The fleet calibration pass: xs [D, B, ...] / ys [D, B] with the usual
    ``r % D`` data-stream rule — ONE replicated clause contraction scores
    every replica's bank (replica r reproduces :func:`clause_scores` on
    stream r % D exactly; the sums are integer).
    """
    clauses, _ = tm_mod.forward_batch_replicated(
        cfg, state, rt, xs, training=False
    )                                                          # [R, B, C, J]
    R = clauses.shape[0]
    D = ys.shape[0]
    pol = tm_mod.clause_polarity(cfg)
    agree = jnp.where(
        ys[..., None] == jnp.arange(cfg.max_classes)[None, None, :], 1, -1
    ).astype(jnp.int32)                                        # [D, B, C]
    agree = jnp.tile(agree, (R // D, 1, 1))                    # [R, B, C]
    return jnp.sum(
        clauses.astype(jnp.int32) * pol * agree[..., None], axis=1
    )


def rank_from_scores(score, polarity=None) -> np.ndarray:
    """Scores [.., C, J] -> ranking [.., C, J] int32: clause ids, best first.

    Descending score, ties broken by ascending clause index (stable sort
    on the negated integer scores) — every clause appears exactly once
    per class, and the order is a pure function of the scores.

    With ``polarity`` ([J], +-1) the ranking is POLARITY-BALANCED: the
    best positive and best negative clauses interleave, so any top-m
    prefix keeps (near-)equal numbers of for- and against-voters. A
    plain score sort prunes the two polarities unevenly and de-calibrates
    the +-vote sums across classes — measured on the f = 784 workload it
    costs 4-7 accuracy points at budget 25% that balancing gets back
    (DESIGN.md §16). Calibrated serving always ranks balanced.
    """
    s = np.asarray(score)
    if polarity is None:
        return np.argsort(-s, axis=-1, kind="stable").astype(np.int32)
    pol = np.asarray(polarity).reshape(-1)
    pos = np.nonzero(pol > 0)[0]
    neg = np.nonzero(pol <= 0)[0]
    po = pos[np.argsort(-s[..., pos], axis=-1, kind="stable")]
    ne = neg[np.argsort(-s[..., neg], axis=-1, kind="stable")]
    out = np.empty(s.shape, dtype=np.int32)
    k = min(len(pos), len(neg))
    out[..., 0:2 * k:2] = po[..., :k]
    out[..., 1:2 * k:2] = ne[..., :k]
    if len(pos) > k:
        out[..., 2 * k:] = po[..., k:]
    elif len(neg) > k:
        out[..., 2 * k:] = ne[..., k:]
    return out


def weights_from_scores(score, weight_bits: int) -> Optional[np.ndarray]:
    """Integer vote weights in [1, 2^bits - 1] from calibration scores.

    Linear in the clamped-positive score, per class, all-integer
    arithmetic (deterministic): the top-scoring clause of each class gets
    the full ``2^bits - 1``, non-positive scores get 1 — a pruned *and*
    weighted vote emphasises the clauses that carried the calibration
    set. ``weight_bits <= 0`` returns None (unit weights).
    """
    if weight_bits <= 0:
        return None
    s = np.maximum(np.asarray(score, dtype=np.int64), 0)
    wmax = (1 << weight_bits) - 1
    peak = np.maximum(s.max(axis=-1, keepdims=True), 1)
    return (1 + (s * (wmax - 1)) // peak).astype(np.int32)


def m_for_budget(budget: float, n_clauses: int) -> int:
    """Compute budget (fraction of clauses) -> elected clauses per class."""
    if not 0.0 < budget <= 1.0:
        raise ValueError(f"budget must be in (0, 1], got {budget}")
    return max(1, min(n_clauses, math.ceil(budget * n_clauses)))


# ---------------------------------------------------------------------------
# Budgeted + early-exit prediction (host driver over the pruned kernels).
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnums=0)
def _votes_pruned_replicated(cfg, state, rt, xs, sel, weights):
    """One clause group's partial class sums [R, B, C] int32."""
    _, votes = tm_mod.forward_batch_pruned_replicated(
        cfg, state, rt, xs, sel, weights
    )
    return votes


_NEG = np.int64(-1) << 40   # "inactive class" vote floor (host-side int64)


def predict_pruned_replicated_host(
    cfg: TMConfig,
    state: TMState,          # leaves [R, ...]
    rt: TMRuntime,
    xs: jax.Array,           # [D, B, ...] — replica r reads batch r % D
    order: np.ndarray,       # [R, C, J] int32 — per-replica rankings
    weights: Optional[np.ndarray],  # [R, C, J] int32 magnitudes (None = unit)
    m: int,                  # elected ranked clauses per class
    *,
    group: Optional[int] = None,    # early-exit group size (None = off)
) -> tuple[np.ndarray, np.ndarray]:
    """Budgeted fleet prediction with optional early-exit voting.

    Returns ``(preds [R, B] int32, evaluated [R, B] int32)`` where
    ``evaluated`` counts the ranked clause slots (per class) each request
    actually needed — ``m`` exactly when early exit is off.

    Early exit evaluates the elected clauses in ranked groups of
    ``group``. After each group, with current masked class sums ``v`` and
    leader ``t``, the remaining groups can raise class c's sum by at most
    ``up[c]`` (sum of remaining elected clauses' positive signed weights)
    and lower it by at most ``down[c]``; a request is decided once

        v[t] - down[t] > max_{c != t} (v[c] + up[c])

    — then the final argmax is provably ``t`` no matter how the remaining
    clauses fire (strict inequality, so tie-breaking cannot differ
    either). Decided requests stop counting; the group loop stops
    launching contractions once EVERY request in the batch is decided, so
    single-request probes (the traffic harness) stop computing too.
    """
    R, C, J = order.shape
    sel_full = jnp.asarray(order[:, :, :m])
    w_dev = None if weights is None else jnp.asarray(weights)
    if group is None or group >= m:
        preds = np.asarray(tm_mod.predict_batch_pruned_replicated(
            cfg, state, rt, xs, sel_full, w_dev
        ))
        return preds, np.full(preds.shape, m, dtype=np.int32)

    # Signed weights of the elected clauses, in ranked order: [R, C, m].
    pol = np.where(np.arange(J) % 2 == 0, 1, -1).astype(np.int64)
    cmask = np.asarray(rt.clause_mask).astype(np.int64)
    mag = (np.ones((R, C, J), dtype=np.int64) if weights is None
           else np.asarray(weights, dtype=np.int64))
    signed = np.take_along_axis(mag * pol * cmask, order, axis=-1)[:, :, :m]
    up_tail = np.maximum(signed, 0)[:, :, ::-1].cumsum(axis=-1)[:, :, ::-1]
    dn_tail = np.maximum(-signed, 0)[:, :, ::-1].cumsum(axis=-1)[:, :, ::-1]

    class_mask = np.asarray(rt.class_mask)
    B = xs.shape[1]
    votes = np.zeros((R, B, C), dtype=np.int64)
    decided = np.zeros((R, B), dtype=bool)
    preds = np.zeros((R, B), dtype=np.int32)
    evaluated = np.zeros((R, B), dtype=np.int32)
    ridx = np.arange(R)[:, None]

    edges = list(range(0, m, group)) + [m]
    for gi in range(len(edges) - 1):
        lo, hi = edges[gi], edges[gi + 1]
        sel_g = jnp.asarray(np.ascontiguousarray(order[:, :, lo:hi]))
        votes += np.asarray(
            _votes_pruned_replicated(cfg, state, rt, xs, sel_g, w_dev),
            dtype=np.int64,
        )
        evaluated[~decided] += hi - lo
        masked = np.where(class_mask[None, None, :], votes, _NEG)
        top = masked.argmax(axis=-1)                       # [R, B]
        if hi == m:
            preds[~decided] = top[~decided]
            decided[:] = True
            break
        # Remaining-swing bound after this group ([R, C] per replica).
        rem_up = up_tail[:, :, hi] if hi < m else np.zeros((R, C), np.int64)
        rem_dn = dn_tail[:, :, hi] if hi < m else np.zeros((R, C), np.int64)
        floor = (np.take_along_axis(masked, top[..., None], -1)[..., 0]
                 - rem_dn[ridx, top])                      # [R, B]
        rival = masked + rem_up[:, None, :]
        np.put_along_axis(rival, top[..., None], _NEG, axis=-1)
        newly = (floor > rival.max(axis=-1)) & ~decided
        preds[newly] = top[newly]
        decided |= newly
        if decided.all():
            break
    return preds, evaluated


# ---------------------------------------------------------------------------
# The service-facing controller.
# ---------------------------------------------------------------------------


class ServeAux(NamedTuple):
    """What a budgeted serve actually computed (per call)."""

    budget: float        # effective compute budget (fraction of clauses)
    m: int               # elected ranked clauses per class
    sel: np.ndarray      # [K, C, m] int32 — the clause ids eligible to run
    evaluated: np.ndarray  # [K, B] int32 — ranked slots evaluated per request


@dataclasses.dataclass(frozen=True)
class TunableConfig:
    """The ``ServiceConfig(tunable=...)`` knob set (DESIGN.md §16).

    ``budget`` is the default (and maximum) serve budget as a fraction of
    the provisioned clauses; ``weight_bits`` > 0 folds calibrated integer
    vote weights in; ``early_exit``/``group`` chunk the ranked vote and
    stop once the margin is provably decided. With ``adapt`` on,
    ``TMService.tick`` moves the live budget between ``min_budget`` and
    ``budget`` by factors of ``step``: halve when any replica's observed
    queue depth reaches ``high_water`` (shed serve compute so the
    consumer loop catches up), recover when the deepest queue falls to
    ``low_water``.
    """

    budget: float = 1.0
    weight_bits: int = 0
    early_exit: bool = False
    group: int = 16
    adapt: bool = False
    min_budget: float = 0.125
    high_water: int = 32
    low_water: int = 4
    step: float = 2.0

    def __post_init__(self):
        if not 0.0 < self.budget <= 1.0:
            raise ValueError("budget must be in (0, 1]")
        if not 0.0 < self.min_budget <= self.budget:
            raise ValueError("min_budget must be in (0, budget]")
        if self.early_exit and self.group < 1:
            raise ValueError("early-exit group must be >= 1")
        if self.step <= 1.0:
            raise ValueError("step must be > 1")


class TuneController:
    """Calibrated ranks/weights + the live budget, for one service.

    Host-side per-replica state ([K, C, J] numpy arrays): orthogonal to
    residency (eviction moves device planes; the ranking of an evicted
    replica stays put) and serialized into the service checkpoint, so a
    restored fleet serves at the same budget from the same ranking
    without recalibrating.
    """

    def __init__(self, tc: TunableConfig, n_replicas: int, n_clauses: int):
        self.tc = tc
        self.n_replicas = n_replicas
        self.n_clauses = n_clauses
        self.budget = float(tc.budget)
        self.order: Optional[np.ndarray] = None    # [K, C, J] int32
        self.weights: Optional[np.ndarray] = None  # [K, C, J] int32
        self.score: Optional[np.ndarray] = None    # [K, C, J] int32

    @property
    def calibrated(self) -> bool:
        return self.order is not None

    @property
    def active(self) -> bool:
        """Does default serving need the budgeted path at all?"""
        return (self.budget < 1.0 or self.tc.weight_bits > 0
                or self.tc.early_exit)

    def set_ranking(
        self, order: np.ndarray, weights: Optional[np.ndarray],
        score: Optional[np.ndarray] = None,
    ) -> None:
        order = np.asarray(order, dtype=np.int32)
        K, J = self.n_replicas, self.n_clauses
        if order.ndim != 3 or order.shape[0] != K or order.shape[2] != J:
            raise ValueError(
                f"ranking must be [replicas={K}, C, clauses={J}], "
                f"got {order.shape}"
            )
        if not np.array_equal(
            np.sort(order, axis=-1),
            np.broadcast_to(np.arange(J, dtype=np.int32), order.shape),
        ):
            raise ValueError("ranking rows must be permutations of the "
                             "clause axis")
        self.order = order
        self.weights = (None if weights is None
                        else np.asarray(weights, dtype=np.int32))
        self.score = None if score is None else np.asarray(score)

    def m_for(self, budget: Optional[float] = None) -> int:
        b = self.budget if budget is None else float(budget)
        return m_for_budget(b, self.n_clauses)

    def update(self, queue_depth) -> float:
        """One ``tick``'s budget adaptation from observed queue depth.

        ``queue_depth`` is the [K] outstanding-rows vector (staged +
        buffered); the deepest lane governs — one overwhelmed replica is
        an SLO breach even if the mean is healthy. Returns the (possibly
        unchanged) live budget.
        """
        tc = self.tc
        if not tc.adapt:
            return self.budget
        depth = int(np.max(queue_depth)) if np.size(queue_depth) else 0
        if depth >= tc.high_water:
            self.budget = max(tc.min_budget, self.budget / tc.step)
        elif depth <= tc.low_water:
            self.budget = min(tc.budget, self.budget * tc.step)
        return self.budget
