"""Host-spilled replica residency: K logical machines on R device slots.

The thousand-replica fleet plane (ROADMAP: per-user personalization).
Device memory holds only ``R = resident`` machines' state — TA banks,
ring buffers, step counters, RNG keys — while the remaining ``K - R``
replicas live as host-side snapshots in an LRU store. Int8 TA banks make
a snapshot tiny (~KB per machine; packed word rings shrink the buffer
leaves another ~8x), so a K=4096 fleet fits comfortably where the device
plane alone could not.

This module is pure bookkeeping: :class:`ResidencyMap` tracks the
replica <-> slot assignment, the LRU clock, and the spilled-snapshot
store. All device traffic (gather on evict, scatter on activate) goes
through :func:`repro.core.online.gather_replicas` /
:func:`~repro.core.online.scatter_replicas` and is driven by
:class:`~repro.serve.service.TMService`, which owns the locking: every
mutation here happens under the service's device lock (DESIGN.md §15;
the §14 two-lock order device -> router is unchanged — residency never
takes the router lock).

Correctness contract (pinned by tests/test_residency.py): a snapshot is
the replica's COMPLETE per-machine consumer state, so an
evict -> activate cycle is invisible to that replica's trajectory — it
lands bit-for-bit where an always-resident twin lands. The per-replica
bitwise guarantee of ``_consume_many_replicated`` (replica r's stream
never mixes with its neighbours') is what makes the slot a replica sits
in irrelevant.
"""
from __future__ import annotations

from typing import Any

import numpy as np

# EWMA smoothing of the observed active-set size: 0.5 tracks a shifted
# working set within ~3 rounds while one idle round moves the estimate
# only halfway (the hysteresis band absorbs that).
EWMA_ALPHA = 0.5
# Grow/shrink target = ceil(ewma * headroom): room for the active set to
# jitter above its average without immediately re-cohorting.
AUTO_HEADROOM = 1.5


class ResidencyMap:
    """Replica <-> device-slot assignment + LRU + spilled snapshot store.

    ``slot_of[k]`` is replica k's device slot, or -1 when evicted (its
    state then lives in ``store[k]``). ``replica_of[r]`` inverts the
    assignment (-1 = free slot). Eviction order is least-recently-*used*:
    ``touch`` stamps a monotone clock on every slot that serves, flushes
    or drains, and :meth:`lru_victims` returns the stalest slots first.

    Snapshots are immutable once stored (activate pops, evict writes a
    fresh host tree), so initial snapshots may share one broadcast bank
    without copy-on-write hazards.
    """

    def __init__(self, n_replicas: int, n_slots: int):
        # <= (not <): the resident="auto" service may grow the plane to
        # the full fleet while keeping the residency layer's semantics
        # (uniform serve/evict surface across re-partitions).
        if not (1 <= n_slots <= n_replicas):
            raise ValueError(
                f"residency needs 1 <= resident <= replicas, got "
                f"resident={n_slots} replicas={n_replicas}"
            )
        self.n_replicas = int(n_replicas)
        self.n_slots = int(n_slots)
        self.slot_of = np.full(n_replicas, -1, dtype=np.int64)
        self.replica_of = np.full(n_slots, -1, dtype=np.int64)
        self.last_use = np.zeros(n_slots, dtype=np.int64)
        self._clock = 0
        self.store: dict[int, Any] = {}     # rid -> host snapshot tree
        self.activations = 0                # lifetime counters (bench +
        self.evictions = 0                  # observability)
        # EWMA of the per-round active-set size (replicas with buffered
        # rows AND budget per drain round) — the autotune signal.
        self.ewma_active = float("nan")

    @property
    def resident_mask(self) -> np.ndarray:
        """[K] bool — which replicas hold a device slot right now."""
        return self.slot_of >= 0

    def touch(self, slots) -> None:
        """Stamp the LRU clock on the given slots (most recently used)."""
        self._clock += 1
        self.last_use[np.asarray(slots)] = self._clock

    def lru_victims(self, n: int, pinned=()) -> np.ndarray:
        """The ``n`` least-recently-used occupied slots, never a pinned
        one (pinned = slots the caller is about to use in this cohort)."""
        pinned = set(int(s) for s in pinned)
        cand = [s for s in range(self.n_slots)
                if self.replica_of[s] >= 0 and s not in pinned]
        # stable sort on the clock: ties (e.g. never-touched) break by
        # slot id, deterministically
        cand.sort(key=lambda s: (self.last_use[s], s))
        if n > len(cand):
            raise RuntimeError(
                f"need {n} eviction victims but only {len(cand)} "
                f"unpinned occupied slots exist"
            )
        return np.asarray(cand[:n], dtype=np.int64)

    def free_slots(self) -> np.ndarray:
        return np.nonzero(self.replica_of < 0)[0].astype(np.int64)

    def assign(self, rids, slots) -> None:
        rids = np.asarray(rids, dtype=np.int64)
        slots = np.asarray(slots, dtype=np.int64)
        self.slot_of[rids] = slots
        self.replica_of[slots] = rids
        self.activations += len(rids)
        self.touch(slots)

    def release(self, slots) -> np.ndarray:
        """Unassign the given slots; returns the replica ids they held."""
        slots = np.asarray(slots, dtype=np.int64)
        rids = self.replica_of[slots].copy()
        self.slot_of[rids] = -1
        self.replica_of[slots] = -1
        self.evictions += len(slots)
        return rids

    # -- slot-count autotuning (ServiceConfig(resident="auto")) -------------

    def note_active(self, n: int) -> None:
        """Feed one drain round's active-set size into the EWMA. The
        first observation seeds the average (no warm-up bias)."""
        n = float(n)
        if np.isnan(self.ewma_active):
            self.ewma_active = n
        else:
            self.ewma_active = (EWMA_ALPHA * n
                                + (1.0 - EWMA_ALPHA) * self.ewma_active)

    def autotune_target(self, *, headroom: float = AUTO_HEADROOM,
                        granule: int = 1) -> int:
        """The slot count the plane SHOULD have, given the EWMA — or the
        current count when inside the hysteresis band.

        Grow when the estimated active set no longer fits the plane
        (``ceil(ewma) > n_slots``: rounds are being cohorted), to
        ``ceil(ewma * headroom)``. Shrink when even with headroom the
        demand uses less than half the plane (``ewma * headroom <
        n_slots / 2``), to the same target. The half-plane gap between
        the grow and shrink conditions is the hysteresis band — a fleet
        oscillating around a working-set size never thrashes
        re-partitions. Targets clamp to [1, n_replicas] and round up to
        ``granule`` (the mesh device count, so sharding stays even),
        capped at the fleet size.
        """
        if np.isnan(self.ewma_active):
            return self.n_slots
        want = self.ewma_active * headroom
        grow = int(np.ceil(self.ewma_active)) > self.n_slots
        shrink = want < self.n_slots / 2
        if not (grow or shrink):
            return self.n_slots
        target = max(1, int(np.ceil(want)))
        granule = max(1, int(granule))
        target = -(-target // granule) * granule
        return min(self.n_replicas, target)
