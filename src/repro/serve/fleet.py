"""OnlineFleet: replica-parallel online serving (paper §3.5 + §4 at fleet scale).

MATADOR (arXiv 2403.10538) and the runtime-tunable eFPGA TM (arXiv
2502.07823) both run many concurrent TM instances on one accelerator; the
ROADMAP names "replica-parallel online serving" as the path from
``OnlineSession`` (one machine drained at a time) to serving heavy traffic.
:class:`OnlineFleet` is that path: K concurrent online sessions — K distinct
TA banks, K cyclic buffers, K RNG streams, K Fig-3 step counters — whose
buffered datapoints drain through ``feedback_step_replicated`` in ONE jitted
call per chunk, the same R-leading layout ``CrossValRun`` uses for
cross-validation orderings, now carrying live interleaved train/infer
traffic. The serving layer is the third consumer of the replicated kernel
contract, after the CV engine and hpsearch.

Layout rule (kernels/dispatch.py): every fleet member owns its data stream,
so D = R = K — state, buffers, budgets and keys all lead with K. Per-replica
hyperparameters ride the runtime's ``s``/``T`` ports as ``[K]`` vectors
(the replicated kernels broadcast scalars, so a homogeneous fleet costs
nothing).

Bit-exactness contract: replica ``r`` of a fleet reproduces a standalone
:class:`~repro.core.online.OnlineSession` given the same RNG key and offer
stream, bit for bit — drained TA banks, monitoring aux and inference alike
(asserted for K ∈ {1, 3, 8} on both backends in tests/test_fleet.py).
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import online as online_mod
from repro.core import tm as tm_mod
from repro.core.online import ChunkAux, SessionState
from repro.core.tm import TMConfig, TMRuntime, TMState
from repro.data import buffer as buf_mod
from repro.distributed import sharding as shard_mod


@jax.jit
def _advance_keys(keys, active):
    """Split every ACTIVE replica's RNG key; retired replicas keep theirs.

    Returns (new persistent keys [K], chunk keys [K]). One jitted dispatch
    per chunk — a replica's key splits exactly once per chunk it
    participates in, matching a standalone session's per-chunk split (the
    chunk keys handed to retired replicas are unused: their row budget for
    the chunk is 0, so no state is touched).
    """
    k2 = jax.vmap(jax.random.split)(keys)               # [K, 2, key]
    return jnp.where(active[:, None], k2[:, 0], keys), k2[:, 1]


@partial(jax.jit, static_argnums=0)
def _enqueue_rows(cfg: TMConfig, ss: SessionState, xs, ys, mask):
    """Push one datapoint into each masked replica's ring buffer.

    xs [K, f] bool, ys [K] i32, mask [K] bool — ONE jitted dispatch offers a
    row to every selected fleet member (the fleet ingress path).
    Returns (new state, accepted [K] bool).
    """
    def push_one(buf_r, x, y, m):
        new_buf, ok = buf_mod.push(buf_r, x, y)
        buf = jax.tree.map(lambda a, b: jnp.where(m, a, b), new_buf, buf_r)
        return buf, ok & m

    bufs, oks = jax.vmap(push_one)(ss.buf, xs, ys, mask)
    return ss._replace(buf=bufs), oks


class OnlineFleet:
    """K concurrent online-learning sessions drained as ONE replicated plane.

    * ``offer(r, x, y)`` / ``offer_rows(xs, ys)`` — producer side: push into
      replica r's cyclic buffer (rows into every replica's buffer at once).
    * ``drain(max_points)`` — consumer side: all replicas consume up to
      their per-replica budget through online training, chunk by chunk, one
      jitted ``_consume_many_replicated`` call per chunk (the per-cycle
      budget of Fig. 3, K machines per dispatch instead of one).
    * ``infer(xs)`` — fleet inference: one replica-first batched clause
      contraction serves every member's batch.

    ``state`` may be a single machine's :class:`TMState` (broadcast to K
    identical banks) or an already-replicated ``[K, ...]`` state. ``seed``
    may be an int (per-replica streams derived by ``fold_in``) or a
    sequence of K ints — replica r then consumes exactly the RNG stream of
    ``OnlineSession(seed=seed[r])``, which is what the parity suite pins.
    ``mesh`` shards the replica axis over the mesh's data axes via
    :func:`repro.distributed.sharding.replica_shardings`.
    """

    def __init__(
        self,
        cfg: TMConfig,
        state: TMState,
        rt: TMRuntime,
        *,
        n_replicas: Optional[int] = None,
        buffer_capacity: int = 64,
        chunk: int = 16,
        seed: Union[int, Sequence[int]] = 0,
        mesh: Optional[Mesh] = None,
    ):
        replicated = state.ta_state.ndim == 4
        if n_replicas is None:
            if not replicated:
                raise ValueError(
                    "n_replicas is required when state is unreplicated"
                )
            n_replicas = state.ta_state.shape[0]
        if replicated and state.ta_state.shape[0] != n_replicas:
            raise ValueError(
                f"state carries {state.ta_state.shape[0]} replicas, "
                f"expected {n_replicas}"
            )
        if not replicated:
            state = TMState(ta_state=jnp.broadcast_to(
                state.ta_state, (n_replicas,) + state.ta_state.shape
            ))

        self.cfg = cfg
        self.rt = rt
        self.n_replicas = n_replicas
        self.chunk = max(1, min(chunk, buffer_capacity))
        self.mesh = mesh

        if isinstance(seed, (int, np.integer)):
            base = jax.random.PRNGKey(int(seed))
            keys = jax.vmap(lambda r: jax.random.fold_in(base, r))(
                jnp.arange(n_replicas)
            )
        else:
            if len(seed) != n_replicas:
                raise ValueError(
                    f"need {n_replicas} seeds, got {len(seed)}"
                )
            keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seed])
        self._keys = keys                                  # [K, key]

        K = n_replicas
        buf1 = buf_mod.make(buffer_capacity, cfg.n_features)
        bufs = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (K,) + a.shape), buf1
        )
        self.ss = SessionState(
            tm=state, buf=bufs, step=jnp.zeros((K,), jnp.int32)
        )
        if mesh is not None:
            sh = shard_mod.replica_shardings(
                (self.ss, self._keys), mesh, n_replicas=K
            )
            self.ss, self._keys = jax.tree.map(
                jax.device_put, (self.ss, self._keys), sh
            )
        self.dropped = np.zeros(K, dtype=np.int64)  # backpressure events

    # -- producer side ------------------------------------------------------

    def offer_rows(self, xs, ys, mask=None) -> np.ndarray:
        """One datapoint into every (masked) replica's buffer; [K] accepted."""
        K = self.n_replicas
        xs = jnp.broadcast_to(
            jnp.asarray(xs, dtype=bool), (K, self.cfg.n_features)
        )
        ys = jnp.broadcast_to(jnp.asarray(ys, dtype=jnp.int32), (K,))
        mask = (
            jnp.ones((K,), dtype=bool) if mask is None
            else jnp.asarray(mask, dtype=bool)
        )
        self.ss, oks = _enqueue_rows(self.cfg, self.ss, xs, ys, mask)
        accepted = np.asarray(oks)
        self.dropped += np.asarray(mask) & ~accepted
        return accepted

    def offer(self, r: int, x, y) -> bool:
        """Push one datapoint into replica ``r``'s buffer (the per-member
        ingress; routing one dispatch per point — batch with offer_rows)."""
        mask = np.zeros(self.n_replicas, dtype=bool)
        mask[r] = True
        return bool(self.offer_rows(x, y, mask)[r])

    # -- consumer side ------------------------------------------------------

    def drain(
        self,
        max_points,
        on_chunk: Optional[Callable[[ChunkAux], None]] = None,
    ) -> np.ndarray:
        """Consume up to ``max_points`` buffered rows PER REPLICA; [K] trained.

        Chunked like :meth:`OnlineSession.learn_available` — one jitted
        replicated call per chunk — but every dispatch advances the whole
        fleet. Per-replica RNG/termination semantics exactly mirror K
        independent sessions: a replica's key splits once per chunk it
        participates in, and a replica retires once its budget is met or
        its buffer drains early, without burning further key splits.

        ``on_chunk`` receives each chunk's :class:`ChunkAux` with leading
        replica axis ``[K, chunk]``; without it the monitoring contraction
        is compiled out entirely.
        """
        K = self.n_replicas
        budget = np.broadcast_to(
            np.asarray(max_points, dtype=np.int64), (K,)
        ).copy()
        trained = np.zeros(K, dtype=np.int64)
        active = trained < budget
        monitor = on_chunk is not None
        while active.any():
            want = np.where(
                active, np.minimum(self.chunk, budget - trained), 0
            ).astype(np.int32)
            self._keys, chunk_keys = _advance_keys(
                self._keys, jnp.asarray(active)
            )
            self.ss, n, aux = online_mod._consume_many_replicated(
                self.cfg, self.chunk, self.ss, self.rt,
                jnp.asarray(want), chunk_keys, monitor=monitor,
            )
            n = np.asarray(n, dtype=np.int64)
            trained += n
            if monitor and n.any():
                on_chunk(aux)
            active &= (n == want) & (trained < budget)
        return trained

    # -- inference ----------------------------------------------------------

    def infer(self, xs) -> np.ndarray:
        """Fleet inference [K, B]: every member's batch in ONE contraction.

        ``xs`` is [B, f] (the same batch served by all members) or
        [K, B, f] (one batch per member).
        """
        xs = jnp.asarray(xs, dtype=bool)
        if xs.ndim == 2:
            xs = xs[None]  # D = 1: one shared stream, factored (stored once)
        return np.asarray(tm_mod.predict_batch_replicated(
            self.cfg, self.ss.tm, self.rt, xs
        ))

    @property
    def buffered(self) -> np.ndarray:
        return np.asarray(self.ss.buf.size)

    @property
    def steps(self) -> np.ndarray:
        return np.asarray(self.ss.step)
