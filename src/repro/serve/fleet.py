"""OnlineFleet: compatibility shim over :class:`~repro.serve.service.TMService`.

The replica-parallel online serving surface (paper §3.5 + §4 at fleet
scale) now lives in ONE place — ``serve/service.py`` — and ``OnlineFleet``
is its pre-redesign face: ``offer``/``offer_rows`` map to the router-staged
``submit``/``submit_rows`` ingress (so the old one-dispatch-per-point
``offer`` cost is gone: acceptance is decided against the host-side
occupancy mirror and the device sees packed ``[K, B_ingress]`` blocks),
``drain`` and ``infer`` map to ``TMService.drain``/``serve``. Observable
behavior is pinned bitwise to the pre-redesign fleet by
tests/test_service.py (oracles transcribed from the old implementation)
and tests/test_fleet.py.

Layout rule, bit-exactness contract and per-replica ``[K]`` s/T ports are
documented on :class:`TMService` (DESIGN.md §10-§11).
"""
from __future__ import annotations

from typing import Callable, Optional, Sequence, Union

import numpy as np
from jax.sharding import Mesh

from repro.core.online import ChunkAux, SessionState
from repro.core.tm import TMConfig, TMRuntime, TMState
from repro.serve.service import ServiceConfig, TMService


class OnlineFleet:
    """K concurrent online-learning sessions drained as ONE replicated plane.

    * ``offer(r, x, y)`` / ``offer_rows(xs, ys)`` — producer side: stage
      into replica r's stream (rows into every replica's stream at once);
      the batch router lands staged rows in packed blocks, one jitted
      dispatch per flush.
    * ``drain(max_points)`` — consumer side: all replicas consume up to
      their per-replica budget through online training, chunk by chunk,
      one jitted call per chunk (the per-cycle budget of Fig. 3, K
      machines per dispatch instead of one).
    * ``infer(xs)`` — fleet inference: one replica-first batched clause
      contraction serves every member's batch.

    ``state`` may be a single machine's :class:`TMState` (broadcast to K
    identical banks) or an already-replicated ``[K, ...]`` state. ``seed``
    may be an int (per-replica streams derived by ``fold_in``) or a
    sequence of K ints — replica r then consumes exactly the RNG stream of
    ``OnlineSession(seed=seed[r])``, which is what the parity suite pins.
    ``mesh`` shards the replica axis over the mesh's data axes via
    :func:`repro.distributed.sharding.replica_shardings`.
    """

    def __init__(
        self,
        cfg: TMConfig,
        state: TMState,
        rt: TMRuntime,
        *,
        n_replicas: Optional[int] = None,
        buffer_capacity: int = 64,
        chunk: int = 16,
        seed: Union[int, Sequence[int]] = 0,
        mesh: Optional[Mesh] = None,
    ):
        if n_replicas is None:
            if state.ta_state.ndim != 4:
                raise ValueError(
                    "n_replicas is required when state is unreplicated"
                )
            n_replicas = state.ta_state.shape[0]
        self._svc = TMService(cfg, state, ServiceConfig(
            replicas=n_replicas, buffer_capacity=buffer_capacity,
            chunk=chunk, seed=seed, mesh=mesh,
        ), rt=rt)

    @classmethod
    def _from_service(cls, svc: TMService) -> "OnlineFleet":
        fleet = cls.__new__(cls)
        fleet._svc = svc
        return fleet

    # -- service passthrough -------------------------------------------------

    @property
    def service(self) -> TMService:
        """The fleet-native surface this shim fronts."""
        return self._svc

    @property
    def cfg(self) -> TMConfig:
        return self._svc.cfg

    @property
    def rt(self) -> TMRuntime:
        return self._svc.rt

    @property
    def n_replicas(self) -> int:
        return self._svc.n_replicas

    @property
    def chunk(self) -> int:
        return self._svc.chunk

    @property
    def mesh(self) -> Optional[Mesh]:
        return self._svc.mesh

    @property
    def ss(self) -> SessionState:
        return self._svc.ss

    @ss.setter
    def ss(self, value: SessionState):
        self._svc.ss = value

    # -- producer side ------------------------------------------------------

    def offer_rows(self, xs, ys, mask=None) -> np.ndarray:
        """One datapoint into every (masked) replica's stream; [K] accepted."""
        return self._svc.submit_rows(xs, ys, mask)

    def offer(self, r: int, x, y) -> bool:
        """Push one datapoint into replica ``r``'s stream (the per-member
        ingress; staged host-side by the batch router, so a loop of offers
        costs one device dispatch per flushed block, not one per point)."""
        return self._svc.submit(r, x, y)

    # -- consumer side ------------------------------------------------------

    def drain(
        self,
        max_points,
        on_chunk: Optional[Callable[[ChunkAux], None]] = None,
    ) -> np.ndarray:
        """Consume up to ``max_points`` buffered rows PER REPLICA; [K]
        trained. See :meth:`TMService.drain`."""
        return self._svc.drain(max_points, on_chunk)

    # -- durable state ------------------------------------------------------

    def save(self, directory: str, *, step: Optional[int] = None,
             keep: int = 3) -> str:
        """Checkpoint the whole fleet (see :meth:`TMService.save`)."""
        return self._svc.save(directory, step=step, keep=keep)

    @classmethod
    def restore(cls, directory: str, *, step: Optional[int] = None,
                mesh: Optional[Mesh] = None) -> "OnlineFleet":
        """Rebuild a fleet from a :meth:`save` checkpoint — construction
        knobs from the manifest, arrays from the npz; continuation is
        bitwise identical to never stopping (tests/test_residency.py)."""
        return cls._from_service(
            TMService.restore(directory, step=step, mesh=mesh)
        )

    # -- inference ----------------------------------------------------------

    def infer(self, xs) -> np.ndarray:
        """Fleet inference [K, B]: every member's batch in ONE contraction.

        ``xs`` is [B, f] (the same batch served by all members) or
        [K, B, f] (one batch per member).
        """
        return self._svc.serve(xs)

    @property
    def buffered(self) -> np.ndarray:
        return self._svc.buffered

    @property
    def dropped(self) -> np.ndarray:
        return self._svc.dropped

    @property
    def steps(self) -> np.ndarray:
        return self._svc.steps
