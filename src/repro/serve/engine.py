"""Batched serving engine: prefill + decode with a persistent KV/state cache.

A deliberately small, production-shaped loop: fixed batch slots, prompt
prefill, greedy/temperature decode steps, per-slot stop handling. The jitted
step functions are the same ones the dry-run lowers for the decode_32k /
long_500k cells, so serving-path performance work transfers 1:1.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer


@dataclasses.dataclass
class EngineConfig:
    max_seq: int = 512
    batch_slots: int = 4
    temperature: float = 0.0     # 0 => greedy
    eos_id: int = -1             # -1 => never stops early


class Engine:
    def __init__(self, cfg: ModelConfig, params, ec: EngineConfig,
                 seed: int = 0):
        self.cfg, self.params, self.ec = cfg, params, ec
        self._key = jax.random.PRNGKey(seed)

        @partial(jax.jit, static_argnums=())
        def _prefill(params, batch):
            return transformer.prefill(cfg, params, batch, ec.max_seq)

        @jax.jit
        def _decode(params, batch, cache):
            return transformer.decode_step(cfg, params, batch, cache)

        self._prefill_fn = _prefill
        self._decode_fn = _decode

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.ec.temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self._key, k = jax.random.split(self._key)
        return jax.random.categorical(
            k, logits / self.ec.temperature, axis=-1
        ).astype(jnp.int32)

    def generate(
        self,
        prompts: np.ndarray,   # [B, S0] int32 (right-aligned, same length)
        max_new: int,
    ) -> np.ndarray:
        """Greedy/temperature generation for a batch of equal-length prompts."""
        B, S0 = prompts.shape
        assert B == self.ec.batch_slots
        assert S0 + max_new <= self.ec.max_seq
        batch = {"tokens": jnp.asarray(prompts, dtype=jnp.int32)}
        logits, cache = self._prefill_fn(self.params, batch)
        out = []
        tok = self._sample(logits)
        out.append(np.asarray(tok))
        for i in range(1, max_new):
            step_batch = {"token": tok[:, None], "pos": jnp.int32(S0 + i - 1)}
            logits, cache = self._decode_fn(self.params, step_batch, cache)
            tok = self._sample(logits)
            out.append(np.asarray(tok))
        seq = np.stack(out, axis=1)   # [B, max_new]
        if self.ec.eos_id >= 0:
            # trim after first EOS per row (host-side post-processing)
            for b in range(B):
                hits = np.where(seq[b] == self.ec.eos_id)[0]
                if len(hits):
                    seq[b, hits[0] + 1:] = self.ec.eos_id
        return seq
