"""Serving: batched prefill+decode engine, online-adaptation manager."""
