"""Serving: batched prefill+decode engine, online-adaptation managers, and
the replica-parallel online fleet (DESIGN.md §10)."""
from repro.serve.fleet import OnlineFleet  # noqa: F401
