"""Serving: the fleet-native ``TMService`` surface (queue-based batch
ingress, replica-parallel drain, §5.3.2 adapt policy), its compatibility
shims (``OnlineSession`` lives in ``repro.core.online``; ``OnlineFleet``
and the adapt managers here), and the batched LM prefill+decode engine
(DESIGN.md §10-§11)."""
from repro.serve.fleet import OnlineFleet  # noqa: F401
from repro.serve.online_adapt import (  # noqa: F401
    OnlineAdaptConfig,
    OnlineAdaptManager,
    TMFleetAdaptManager,
    TMOnlineAdaptConfig,
    TMOnlineAdaptManager,
)
from repro.serve.router import BatchRouter  # noqa: F401
from repro.serve.service import (  # noqa: F401
    AdaptPolicy,
    ServiceConfig,
    TickReport,
    TMService,
)
from repro.serve.tunable import (  # noqa: F401
    ServeAux,
    TunableConfig,
    TuneController,
)
from repro.serve.traffic import (  # noqa: F401
    SCENARIOS,
    ProducerScript,
    Scenario,
    TrafficResult,
    make_script,
    make_scripts,
    replay_single_caller,
    run_threaded,
)

__all__ = [
    "AdaptPolicy",
    "BatchRouter",
    "Engine",
    "EngineConfig",
    "OnlineAdaptConfig",
    "OnlineAdaptManager",
    "OnlineFleet",
    "ProducerScript",
    "SCENARIOS",
    "Scenario",
    "ServeAux",
    "ServiceConfig",
    "TickReport",
    "TunableConfig",
    "TuneController",
    "TMFleetAdaptManager",
    "TMOnlineAdaptConfig",
    "TMOnlineAdaptManager",
    "TMService",
    "TrafficResult",
    "make_script",
    "make_scripts",
    "replay_single_caller",
    "run_threaded",
]


def __getattr__(name):
    # The LM serving engine pulls the whole transformer/models stack;
    # loaded lazily so the TM-only serving surface stays light to import.
    if name in ("Engine", "EngineConfig"):
        from repro.serve import engine

        return getattr(engine, name)
    raise AttributeError(f"module 'repro.serve' has no attribute {name!r}")
