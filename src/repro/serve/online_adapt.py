"""The paper's Fig-3 online-learning FSM generalized to LM serving.

offline train -> accuracy analysis -> [serve + interleaved online updates ->
periodic re-analysis] — with the paper's §5.3.2 mitigation policy: if
analysis accuracy (here: eval loss) degrades past a threshold, roll back to
the last good checkpoint and optionally re-train. This is the TM
architecture's learning-management subsystem applied to any arch in
`repro.configs` (DESIGN.md §4: what transfers to every architecture).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer
from repro.train import checkpoint as ckpt_mod
from repro.train import train_step as ts_mod


@dataclasses.dataclass
class OnlineAdaptConfig:
    analyze_every: int = 8          # online updates between accuracy analyses
    rollback_threshold: float = 0.25  # relative eval-loss degradation
    checkpoint_dir: str = "/tmp/repro_online_adapt"


class OnlineAdaptManager:
    """Host FSM; device work stays in two jitted functions (update / eval)."""

    def __init__(self, cfg: ModelConfig, tc: ts_mod.TrainConfig,
                 state: ts_mod.TrainState, oc: OnlineAdaptConfig):
        self.cfg, self.tc, self.oc = cfg, tc, oc
        self.state = state
        self._update = jax.jit(
            lambda s, b: ts_mod.train_step(cfg, tc, s, b))
        self._eval = jax.jit(
            lambda p, b: transformer.loss_fn(cfg, p, b)[0])
        self.history: list = []       # (step, eval_loss)
        self.rollbacks = 0
        self._steps = 0
        self._best: Optional[float] = None

    def analyze(self, eval_batch: dict) -> float:
        loss = float(jax.device_get(
            self._eval(self.state.params, eval_batch)))
        self.history.append((self._steps, loss))
        return loss

    def offline_train(self, batches, eval_batch: dict) -> float:
        for b in batches:
            self.state, _ = self._update(self.state, b)
            self._steps += 1
        loss = self.analyze(eval_batch)
        self._best = loss
        ckpt_mod.save(self.oc.checkpoint_dir, self._steps, self.state)
        return loss

    def online_step(self, batch: dict, eval_batch: dict) -> Optional[float]:
        """One labelled online update; periodic analysis + rollback policy."""
        self.state, _ = self._update(self.state, batch)
        self._steps += 1
        if self._steps % self.oc.analyze_every:
            return None
        loss = self.analyze(eval_batch)
        if self._best is not None and loss > self._best * (
                1.0 + self.oc.rollback_threshold):
            # §5.3.2: accuracy collapsed — restore the known-good state.
            self.state, _ = ckpt_mod.restore(
                self.oc.checkpoint_dir, self.state)
            self.rollbacks += 1
        elif self._best is None or loss < self._best:
            self._best = loss
            ckpt_mod.save(self.oc.checkpoint_dir, self._steps, self.state)
        return loss
