"""The paper's Fig-3 online-learning FSM at the serving layer.

Two managers share the same control shape (offline train -> accuracy
analysis -> [serve + interleaved online updates -> periodic re-analysis]
with the §5.3.2 mitigation policy: on degradation past a threshold, roll
back to the last known-good state):

* :class:`TMOnlineAdaptManager` — the paper's own machine. Serving inference
  and analysis both route through the **batch-first dispatched kernel path**
  (``tm.predict_batch`` / ``accuracy.analyze``; DESIGN.md §8) and online
  updates drain through the chunked ``online._consume_many`` scan — the
  served numbers are produced by exactly the code the benchmarks measure.
* :class:`OnlineAdaptManager` — the same FSM generalized to LM serving for
  any arch in `repro.configs` (DESIGN.md §4: what transfers).
* :class:`TMFleetAdaptManager` — the FSM lifted to a whole serving fleet
  (:class:`repro.serve.fleet.OnlineFleet`): K machines share every device
  dispatch while cadence counters, best-state snapshots and §5.3.2
  rollbacks run per replica (DESIGN.md §10).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import accuracy as acc_mod
from repro.core import online as online_mod
from repro.core.tm import TMConfig, TMRuntime, TMState
from repro.models import transformer
from repro.train import checkpoint as ckpt_mod
from repro.train import train_step as ts_mod


@dataclasses.dataclass
class TMOnlineAdaptConfig:
    analyze_every: int = 32           # online datapoints between analyses
    rollback_threshold: float = 0.1   # absolute accuracy drop triggering rollback
    buffer_capacity: int = 64
    chunk: int = 16                   # datapoints drained per jitted call


class TMOnlineAdaptManager:
    """Fig-3 FSM serving the TM itself, on the batch-first kernel path.

    * ``serve(xs)``  — batched inference (``tm.predict_batch``).
    * ``observe(x, y)`` — labelled traffic into the cyclic buffer; every
      ``analyze_every`` consumed points the eval set is re-analyzed (one
      batch-first pass) and the §5.3.2 policy rolls the TA bank back to the
      last known-good snapshot if accuracy collapsed.
    """

    def __init__(self, cfg: TMConfig, state: TMState, rt: TMRuntime,
                 eval_x, eval_y, oc: Optional[TMOnlineAdaptConfig] = None,
                 seed: int = 0):
        self.cfg, self.rt = cfg, rt
        self.oc = oc or TMOnlineAdaptConfig()
        self.eval_x = jnp.asarray(eval_x, dtype=bool)
        self.eval_y = jnp.asarray(eval_y, dtype=jnp.int32)
        self.session = online_mod.OnlineSession(
            cfg, state, rt,
            buffer_capacity=self.oc.buffer_capacity,
            chunk=self.oc.chunk, seed=seed,
        )
        self.history: list = []       # (consumed_steps, eval_accuracy)
        self.rollbacks = 0
        self.lost = 0                 # datapoints dropped even after retry
        self._since_analysis = 0
        self._best: Optional[float] = None
        self._best_state: TMState = self.session.ss.tm

    def serve(self, xs) -> np.ndarray:
        """Batched predictions for live traffic (the shipped number)."""
        return self.session.infer(xs)

    def analyze(self) -> float:
        acc = float(acc_mod.analyze(
            self.cfg, self.session.ss.tm, self.rt, self.eval_x, self.eval_y
        ))
        self.history.append((int(self.session.ss.step), acc))
        return acc

    def offline_train(self, xs, ys, n_epochs: int = 10, seed: int = 1) -> float:
        from repro.core import feedback as fb_mod

        st = fb_mod.train_epochs(
            self.cfg, self.session.ss.tm, self.rt,
            jnp.asarray(xs, dtype=bool), jnp.asarray(ys, dtype=jnp.int32),
            jax.random.PRNGKey(seed), n_epochs,
        )
        self.session.ss = self.session.ss._replace(tm=st)
        acc = self.analyze()
        self._best, self._best_state = acc, st
        return acc

    def observe(self, x, y) -> Optional[float]:
        """One labelled online datapoint; returns eval accuracy on analysis
        steps, None otherwise."""
        chunk = self.session.chunk  # session clamps to [1, buffer_capacity]
        if not self.session.offer(x, y):
            # Backpressure: drain a chunk, then retry once. Drained points
            # still count toward the analysis cadence. Note session.dropped
            # counts rejection *events* (including a first attempt whose
            # retry succeeds); ``self.lost`` counts actual losses.
            self._since_analysis += self.session.learn_available(chunk)
            if not self.session.offer(x, y):
                self.lost += 1
        self._since_analysis += self.session.learn_available(chunk)
        if self._since_analysis < self.oc.analyze_every:
            return None
        self._since_analysis = 0
        acc = self.analyze()
        if self._best is not None and acc < self._best - self.oc.rollback_threshold:
            # §5.3.2: accuracy collapsed — restore the known-good TA bank.
            self.session.ss = self.session.ss._replace(tm=self._best_state)
            self.rollbacks += 1
        elif self._best is None or acc > self._best:
            self._best, self._best_state = acc, self.session.ss.tm
        return acc


class TMFleetAdaptManager:
    """Fig-3 FSM for a whole serving fleet, with per-replica threshold state.

    The fleet generalisation of :class:`TMOnlineAdaptManager`: K machines
    (one :class:`~repro.serve.fleet.OnlineFleet`) share every device
    dispatch — offers, drains, analyses — while the §5.3.2 mitigation
    policy runs per replica: each member carries its own analysis-cadence
    counter, its own best-known accuracy/TA-bank snapshot, and rolls back
    independently when ITS accuracy collapses. Per-replica runtime
    thresholds are first-class: pass ``rt`` with ``s``/``T`` as ``[K]``
    vectors and every member serves and learns under its own (s, T) — the
    replicated kernels' per-replica hyperparameter ports (DESIGN.md §9).

    The analysis pass is ONE ``analyze_replicated`` contraction over the
    shared eval set (stored once: D = 1 data stream factored across the
    fleet) regardless of how many members hit their cadence that step.
    """

    def __init__(self, cfg: TMConfig, state: TMState, rt: TMRuntime,
                 eval_x, eval_y, *, n_replicas: int,
                 oc: Optional[TMOnlineAdaptConfig] = None,
                 seed: Union[int, Sequence[int]] = 0, mesh=None):
        from repro.serve.fleet import OnlineFleet

        self.cfg, self.rt = cfg, rt
        self.oc = oc or TMOnlineAdaptConfig()
        self.eval_x = jnp.asarray(eval_x, dtype=bool)
        self.eval_y = jnp.asarray(eval_y, dtype=jnp.int32)
        self.fleet = OnlineFleet(
            cfg, state, rt, n_replicas=n_replicas,
            buffer_capacity=self.oc.buffer_capacity,
            chunk=self.oc.chunk, seed=seed, mesh=mesh,
        )
        K = self.fleet.n_replicas
        self.history: list = []            # (steps [K], accuracies [K])
        self.rollbacks = np.zeros(K, dtype=np.int64)
        self.lost = np.zeros(K, dtype=np.int64)
        self._since = np.zeros(K, dtype=np.int64)
        self._best = np.full(K, np.nan)    # nan = no known-good snapshot yet
        self._best_state: TMState = self.fleet.ss.tm

    def serve(self, xs) -> np.ndarray:
        """Fleet predictions [K, B] for live traffic (the shipped numbers)."""
        return self.fleet.infer(xs)

    def analyze(self) -> np.ndarray:
        """Eval accuracy of every member in ONE contraction. [K] f32."""
        acc = np.asarray(acc_mod.analyze_replicated(
            self.cfg, self.fleet.ss.tm, self.rt,
            self.eval_x[None], self.eval_y[None],   # D = 1: stored once
        ))
        self.history.append((self.fleet.steps, acc))
        return acc

    def offline_train(self, xs, ys, n_epochs: int = 10,
                      seed: int = 1) -> np.ndarray:
        """Offline phase for the whole fleet (one replicated epochs scan)."""
        from repro.core import feedback as fb_mod

        st = fb_mod.train_epochs_replicated(
            self.cfg, self.fleet.ss.tm, self.rt,
            jnp.asarray(xs, dtype=bool)[None],
            jnp.asarray(ys, dtype=jnp.int32)[None],
            jax.random.PRNGKey(seed)[None], n_epochs,
        )
        self.fleet.ss = self.fleet.ss._replace(tm=st)
        acc = self.analyze()
        self._best = acc.copy()
        self._best_state = st
        return acc

    def _select_rows(self, mask: np.ndarray, new: TMState,
                     old: TMState) -> TMState:
        gate = online_mod.replica_gate(jnp.asarray(mask))
        return jax.tree.map(gate, new, old)

    def observe_rows(self, xs, ys, mask=None) -> Optional[np.ndarray]:
        """One labelled datapoint per (masked) replica; returns [K] eval
        accuracies when at least one member hits its analysis cadence,
        None otherwise.

        The drain-retry backpressure policy of the single-machine manager,
        fleet-wide: every drain is one replicated dispatch for all members,
        and drained points advance each member's OWN cadence counter.
        """
        K = self.fleet.n_replicas
        mask = (
            np.ones(K, dtype=bool) if mask is None
            else np.asarray(mask, dtype=bool)
        )
        chunk = self.fleet.chunk  # fleet clamps to [1, buffer_capacity],
        # exactly like the single-machine manager's session.chunk budget
        accepted = self.fleet.offer_rows(xs, ys, mask)
        retry = mask & ~accepted
        if retry.any():
            # Backpressure: drain a chunk fleet-wide, then retry once.
            self._since += self.fleet.drain(chunk)
            accepted = self.fleet.offer_rows(xs, ys, retry)
            self.lost += retry & ~accepted
        self._since += self.fleet.drain(chunk)

        due = self._since >= self.oc.analyze_every
        if not due.any():
            return None
        self._since[due] = 0
        acc = self.analyze()
        have_best = ~np.isnan(self._best)
        collapse = due & have_best & (
            acc < self._best - self.oc.rollback_threshold
        )
        improve = due & (~have_best | (acc > self._best))
        if collapse.any():
            # §5.3.2 per replica: restore collapsed members' known-good
            # TA banks; healthy members keep serving untouched.
            self.fleet.ss = self.fleet.ss._replace(
                tm=self._select_rows(collapse, self._best_state,
                                     self.fleet.ss.tm)
            )
            self.rollbacks += collapse
        if improve.any():
            self._best = np.where(improve, acc, self._best)
            self._best_state = self._select_rows(
                improve, self.fleet.ss.tm, self._best_state
            )
        return acc

    def observe(self, r: int, x, y) -> Optional[np.ndarray]:
        """One labelled datapoint into replica ``r`` only."""
        mask = np.zeros(self.fleet.n_replicas, dtype=bool)
        mask[r] = True
        return self.observe_rows(x, y, mask)


@dataclasses.dataclass
class OnlineAdaptConfig:
    analyze_every: int = 8          # online updates between accuracy analyses
    rollback_threshold: float = 0.25  # relative eval-loss degradation
    checkpoint_dir: str = "/tmp/repro_online_adapt"


class OnlineAdaptManager:
    """Host FSM; device work stays in two jitted functions (update / eval)."""

    def __init__(self, cfg: ModelConfig, tc: ts_mod.TrainConfig,
                 state: ts_mod.TrainState, oc: OnlineAdaptConfig):
        self.cfg, self.tc, self.oc = cfg, tc, oc
        self.state = state
        self._update = jax.jit(
            lambda s, b: ts_mod.train_step(cfg, tc, s, b))
        self._eval = jax.jit(
            lambda p, b: transformer.loss_fn(cfg, p, b)[0])
        self.history: list = []       # (step, eval_loss)
        self.rollbacks = 0
        self._steps = 0
        self._best: Optional[float] = None

    def analyze(self, eval_batch: dict) -> float:
        loss = float(jax.device_get(
            self._eval(self.state.params, eval_batch)))
        self.history.append((self._steps, loss))
        return loss

    def offline_train(self, batches, eval_batch: dict) -> float:
        for b in batches:
            self.state, _ = self._update(self.state, b)
            self._steps += 1
        loss = self.analyze(eval_batch)
        self._best = loss
        ckpt_mod.save(self.oc.checkpoint_dir, self._steps, self.state)
        return loss

    def online_step(self, batch: dict, eval_batch: dict) -> Optional[float]:
        """One labelled online update; periodic analysis + rollback policy."""
        self.state, _ = self._update(self.state, batch)
        self._steps += 1
        if self._steps % self.oc.analyze_every:
            return None
        loss = self.analyze(eval_batch)
        if self._best is not None and loss > self._best * (
                1.0 + self.oc.rollback_threshold):
            # §5.3.2: accuracy collapsed — restore the known-good state.
            self.state, _ = ckpt_mod.restore(
                self.oc.checkpoint_dir, self.state)
            self.rollbacks += 1
        elif self._best is None or loss < self._best:
            self._best = loss
            ckpt_mod.save(self.oc.checkpoint_dir, self._steps, self.state)
        return loss
