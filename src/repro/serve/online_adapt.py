"""The paper's Fig-3 online-learning FSM at the serving layer.

The FSM itself — offer -> buffer -> interleaved train/infer with periodic
accuracy analysis and the §5.3.2 mitigation policy (roll back to the last
known-good state on degradation) — lives in ONE place now:
:class:`repro.serve.service.AdaptPolicy` driven by
:class:`repro.serve.service.TMService`, on ``[K]`` arrays. This module
keeps the pre-redesign faces as thin shims (no FSM or drain logic of
their own; pinned bitwise to the old implementations by
tests/test_service.py):

* :class:`TMOnlineAdaptManager` — the paper's own machine: the K = 1
  slice, scalar history/counters.
* :class:`TMFleetAdaptManager` — the same FSM for a whole serving fleet,
  per-replica ``[K]`` counters/snapshots/rollbacks and per-replica
  ``s``/``T`` runtime ports (DESIGN.md §10-§11).
* :class:`OnlineAdaptManager` — the FSM generalized to LM serving for any
  arch in `repro.configs` (DESIGN.md §4: what transfers); independent of
  the TM service surface.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Union

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.online import OnlineSession
from repro.core.tm import TMConfig, TMRuntime, TMState
from repro.serve.fleet import OnlineFleet
from repro.serve.service import AdaptPolicy, ServiceConfig, TMService


@dataclasses.dataclass
class TMOnlineAdaptConfig:
    analyze_every: int = 32           # online datapoints between analyses
    rollback_threshold: float = 0.1   # absolute accuracy drop triggering rollback
    buffer_capacity: int = 64
    chunk: int = 16                   # datapoints drained per jitted call

    def policy(self) -> AdaptPolicy:
        return AdaptPolicy(analyze_every=self.analyze_every,
                           rollback_threshold=self.rollback_threshold)


class TMOnlineAdaptManager:
    """Fig-3 FSM serving the TM itself — the K = 1 face of ``TMService``.

    * ``serve(xs)``  — batched inference (``tm.predict_batch``).
    * ``observe(x, y)`` — labelled traffic into the cyclic buffer; every
      ``analyze_every`` consumed points the eval set is re-analyzed (one
      batch-first pass) and the §5.3.2 policy rolls the TA bank back to the
      last known-good snapshot if accuracy collapsed.
    """

    def __init__(self, cfg: TMConfig, state: TMState, rt: TMRuntime,
                 eval_x, eval_y, oc: Optional[TMOnlineAdaptConfig] = None,
                 seed: int = 0):
        self.oc = oc or TMOnlineAdaptConfig()
        self._svc = TMService(cfg, state, ServiceConfig(
            replicas=1, buffer_capacity=self.oc.buffer_capacity,
            chunk=self.oc.chunk, policy=self.oc.policy(), seed=[int(seed)],
        ), rt=rt, eval_x=eval_x, eval_y=eval_y)
        self.session = OnlineSession._from_service(self._svc)

    @property
    def service(self) -> TMService:
        return self._svc

    @property
    def cfg(self) -> TMConfig:
        return self._svc.cfg

    @property
    def rt(self) -> TMRuntime:
        return self._svc.rt

    @property
    def eval_x(self):
        return self._svc.eval_x

    @property
    def eval_y(self):
        return self._svc.eval_y

    @property
    def history(self) -> list:
        """(consumed_steps, eval_accuracy) pairs, scalar as ever."""
        return [(int(s[0]), float(a[0])) for s, a in self._svc.history]

    @property
    def rollbacks(self) -> int:
        return int(self._svc.rollbacks[0])

    @property
    def lost(self) -> int:
        """Datapoints dropped even after the backpressure retry."""
        return int(self._svc.lost[0])

    def serve(self, xs) -> np.ndarray:
        """Batched predictions for live traffic (the shipped number)."""
        return self._svc.serve(xs)[0]

    def analyze(self) -> float:
        return float(self._svc.analyze()[0])

    def offline_train(self, xs, ys, n_epochs: int = 10, seed: int = 1) -> float:
        return float(self._svc.offline_train(xs, ys, n_epochs, seed)[0])

    def observe(self, x, y) -> Optional[float]:
        """One labelled online datapoint; returns eval accuracy on analysis
        steps, None otherwise."""
        acc = self._svc.observe_rows(x, y)
        return None if acc is None else float(acc[0])


class TMFleetAdaptManager:
    """Fig-3 FSM for a whole serving fleet, with per-replica threshold state.

    The K > 1 face of ``TMService``: K machines share every device
    dispatch — offers, drains, analyses — while the §5.3.2 mitigation
    policy runs per replica: each member carries its own analysis-cadence
    counter, its own best-known accuracy/TA-bank snapshot, and rolls back
    independently when ITS accuracy collapses. Per-replica runtime
    thresholds are first-class: pass ``rt`` with ``s``/``T`` as ``[K]``
    vectors and every member serves and learns under its own (s, T) — the
    replicated kernels' per-replica hyperparameter ports (DESIGN.md §9).

    The analysis pass is ONE ``analyze_replicated`` contraction over the
    shared eval set (stored once: D = 1 data stream factored across the
    fleet) regardless of how many members hit their cadence that step.
    """

    def __init__(self, cfg: TMConfig, state: TMState, rt: TMRuntime,
                 eval_x, eval_y, *, n_replicas: int,
                 oc: Optional[TMOnlineAdaptConfig] = None,
                 seed: Union[int, Sequence[int]] = 0, mesh=None):
        self.oc = oc or TMOnlineAdaptConfig()
        self._svc = TMService(cfg, state, ServiceConfig(
            replicas=n_replicas, buffer_capacity=self.oc.buffer_capacity,
            chunk=self.oc.chunk, policy=self.oc.policy(), seed=seed,
            mesh=mesh,
        ), rt=rt, eval_x=eval_x, eval_y=eval_y)
        self.fleet = OnlineFleet._from_service(self._svc)

    @property
    def service(self) -> TMService:
        return self._svc

    @property
    def cfg(self) -> TMConfig:
        return self._svc.cfg

    @property
    def rt(self) -> TMRuntime:
        return self._svc.rt

    @property
    def eval_x(self):
        return self._svc.eval_x

    @property
    def eval_y(self):
        return self._svc.eval_y

    @property
    def history(self) -> list:
        """(steps [K], accuracies [K]) pairs."""
        return self._svc.history

    @property
    def rollbacks(self) -> np.ndarray:
        return self._svc.rollbacks

    @property
    def lost(self) -> np.ndarray:
        return self._svc.lost

    @property
    def _since(self) -> np.ndarray:
        return self._svc.since_analysis

    def serve(self, xs) -> np.ndarray:
        """Fleet predictions [K, B] for live traffic (the shipped numbers)."""
        return self._svc.serve(xs)

    def analyze(self) -> np.ndarray:
        """Eval accuracy of every member in ONE contraction. [K] f32."""
        return self._svc.analyze()

    def offline_train(self, xs, ys, n_epochs: int = 10,
                      seed: int = 1) -> np.ndarray:
        """Offline phase for the whole fleet (one replicated epochs scan)."""
        return self._svc.offline_train(xs, ys, n_epochs, seed)

    def observe_rows(self, xs, ys, mask=None) -> Optional[np.ndarray]:
        """One labelled datapoint per (masked) replica; returns [K] eval
        accuracies when at least one member hits its analysis cadence,
        None otherwise.

        The drain-retry backpressure policy of the single-machine manager,
        fleet-wide: every drain is one replicated dispatch for all members,
        and drained points advance each member's OWN cadence counter.
        """
        return self._svc.observe_rows(xs, ys, mask)

    def observe(self, r: int, x, y) -> Optional[np.ndarray]:
        """One labelled datapoint into replica ``r`` only. Note the FSM
        drains right after offering (the legacy per-point cadence), so
        this path still costs device dispatches per point — bulk traffic
        should go through ``service.submit``/``submit_rows`` + ``tick``,
        where the router's batching actually pays off."""
        mask = np.zeros(self._svc.n_replicas, dtype=bool)
        mask[r] = True
        return self.observe_rows(x, y, mask)


@dataclasses.dataclass
class OnlineAdaptConfig:
    analyze_every: int = 8          # online updates between accuracy analyses
    rollback_threshold: float = 0.25  # relative eval-loss degradation
    checkpoint_dir: str = "/tmp/repro_online_adapt"


class OnlineAdaptManager:
    """Host FSM; device work stays in two jitted functions (update / eval)."""

    def __init__(self, cfg: ModelConfig, tc, state, oc: OnlineAdaptConfig):
        from repro.models import transformer
        from repro.train import train_step as ts_mod

        self.cfg, self.tc, self.oc = cfg, tc, oc
        self.state = state
        self._update = jax.jit(
            lambda s, b: ts_mod.train_step(cfg, tc, s, b))
        self._eval = jax.jit(
            lambda p, b: transformer.loss_fn(cfg, p, b)[0])
        self.history: list = []       # (step, eval_loss)
        self.rollbacks = 0
        self._steps = 0
        self._best: Optional[float] = None

    def analyze(self, eval_batch: dict) -> float:
        loss = float(jax.device_get(
            self._eval(self.state.params, eval_batch)))
        self.history.append((self._steps, loss))
        return loss

    def offline_train(self, batches, eval_batch: dict) -> float:
        from repro.train import checkpoint as ckpt_mod

        for b in batches:
            self.state, _ = self._update(self.state, b)
            self._steps += 1
        loss = self.analyze(eval_batch)
        self._best = loss
        ckpt_mod.save(self.oc.checkpoint_dir, self._steps, self.state)
        return loss

    def online_step(self, batch: dict, eval_batch: dict) -> Optional[float]:
        """One labelled online update; periodic analysis + rollback policy."""
        from repro.train import checkpoint as ckpt_mod

        self.state, _ = self._update(self.state, batch)
        self._steps += 1
        if self._steps % self.oc.analyze_every:
            return None
        loss = self.analyze(eval_batch)
        if self._best is not None and loss > self._best * (
                1.0 + self.oc.rollback_threshold):
            # §5.3.2: accuracy collapsed — restore the known-good state.
            self.state, _ = ckpt_mod.restore(
                self.oc.checkpoint_dir, self.state)
            self.rollbacks += 1
        elif self._best is None or loss < self._best:
            self._best = loss
            ckpt_mod.save(self.oc.checkpoint_dir, self._steps, self.state)
        return loss
