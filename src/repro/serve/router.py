"""Fleet ingress: a host-side batch router feeding the ring buffers.

Heavy-traffic serving cannot afford one jitted dispatch per datapoint
(the ROADMAP's "Fleet-scale ingress" item): a million offers/s through a
per-point ``offer`` is a million device round-trips. :class:`BatchRouter`
is the missing layer — labelled traffic accumulates in a shared numpy
staging block (``[K, B_ingress]`` rows + per-replica fill counts, no
device interaction at all) and flushes through :func:`_enqueue_rows` as
ONE jitted dispatch pushing up to ``B_ingress`` rows into every replica's
ring buffer at once. ``benchmarks/ingress.py`` gates the win (>= 4x
offers/s over the looped per-point path at K = 8; far more in practice —
the dispatch count drops by a factor of ``B_ingress``).

Acceptance is decided host-side: the router carries an exact mirror of
every replica's free buffer space (device size is only mutated by the
owning :class:`~repro.serve.service.TMService`, which keeps the mirror in
sync on drains and state swaps), so a ``submit`` can report backpressure
synchronously — same observable semantics as the old immediate-dispatch
``offer`` — while the device enqueue happens later, batched.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import buffer as buf_mod


@partial(jax.jit, static_argnums=1)
def _enqueue_rows(ss, block: int, xs, ys, counts):
    """Push up to ``counts[r]`` staged rows into EVERY replica's ring buffer.

    xs [K, B, f] bool (or [K, B, ceil(f/32)] uint32 when the fleet's
    buffers are packed — the push is dtype-agnostic), ys [K, B] i32,
    counts [K] i32 — ONE jitted dispatch
    lands a whole ingress block (rows keep their per-replica submission
    order; rows at index >= counts[r] are padding and never touch state).
    Returns (new session state, accepted-row count [K] i32).
    """
    def per_replica(buf, xr, yr, c):
        def step(carry, inp):
            b, acc = carry
            x, y, i = inp
            new_b, ok = buf_mod.push(b, x, y)
            take = i < c
            b = jax.tree.map(lambda a, o: jnp.where(take, a, o), new_b, b)
            return (b, acc + (ok & take).astype(jnp.int32)), None

        idx = jnp.arange(block, dtype=jnp.int32)
        (buf, acc), _ = jax.lax.scan(step, (buf, jnp.int32(0)), (xr, yr, idx))
        return buf, acc

    bufs, accepted = jax.vmap(per_replica)(ss.buf, xs, ys, counts)
    return ss._replace(buf=bufs), accepted


class BatchRouter:
    """Host-side staging queue between producers and the fleet's buffers.

    * ``stage_rows(xs, ys, mask, dev_size)`` — producer side: copy one row
      per masked replica into the shared numpy block, deciding acceptance
      against the free-space mirror (rejected rows are per-replica
      ``dropped`` backpressure events, exactly like the old per-point
      ``offer``; a single-replica offer is a one-hot mask).
    * ``take_block()`` — consumer side: hand the staged ``[K, B]`` block
      (plus fill counts) to the service for one ``_enqueue_rows`` dispatch
      and reset the staging counts.

    The service flushes whenever any replica's staging lane fills, and
    before every drain/inference-independent consumer step — so a lane
    never overflows and no staged row is ever reordered within its
    replica's stream.
    """

    def __init__(self, n_replicas: int, n_features: int, capacity: int,
                 block: int = 32, *, packed: bool = False):
        K = n_replicas
        self.n_replicas = K
        self.n_features = n_features
        self.capacity = capacity
        self.block = max(1, min(block, capacity))
        self.packed = packed
        if packed:
            # Packed staging (DESIGN.md §13): rows pack host-side at the
            # staging boundary, so the staging block, the flush transfer
            # AND the device ring rows all carry ceil(f/32) uint32 words
            # instead of f bools (~8x less ingress bandwidth; the flush
            # enqueue is dtype-agnostic).
            from repro.kernels.packing import n_words

            self._stage_x = np.zeros((K, self.block, n_words(n_features)),
                                     dtype=np.uint32)
        else:
            self._stage_x = np.zeros((K, self.block, n_features), dtype=bool)
        self._stage_y = np.zeros((K, self.block), dtype=np.int32)
        self._count = np.zeros(K, dtype=np.int32)
        self.dropped = np.zeros(K, dtype=np.int64)   # backpressure events
        self.flushes = 0                             # device dispatches

    # -- producer side ------------------------------------------------------

    @property
    def staged(self) -> np.ndarray:
        """Rows staged but not yet flushed, per replica. [K] i32 (a copy)."""
        return self._count.copy()

    def lane_full(self) -> bool:
        """True when some replica's staging lane is full (flush before the
        next stage call, or it would have to reject for lack of lane space
        rather than true buffer backpressure)."""
        return bool((self._count >= self.block).any())

    def stage_rows(self, xs, ys, mask, dev_size) -> np.ndarray:
        """Stage one row per masked replica. Returns accepted [K] bool.

        ``dev_size`` is the service's device-buffer-occupancy mirror;
        acceptance is ``dev_size + staged < capacity``, which is exactly
        what an immediate device push would have reported.
        """
        K, f = self.n_replicas, self.n_features
        xs = np.asarray(xs, dtype=bool)
        if xs.shape != (K, f):
            xs = np.broadcast_to(xs, (K, f))
        ys = np.asarray(ys, dtype=np.int32)
        if ys.shape != (K,):
            ys = np.broadcast_to(ys, (K,))
        accepted = mask & (dev_size + self._count < self.capacity)
        if (accepted & (self._count >= self.block)).any():
            # Protocol error, not backpressure: the caller must flush a
            # full lane before staging into it (TMService does this
            # automatically around every stage call).
            raise RuntimeError(
                "BatchRouter staging lane full — take_block()/flush before "
                "staging more rows into this replica"
            )
        idx = np.nonzero(accepted)[0]
        if idx.size:
            c = self._count[idx]
            if self.packed:
                from repro.kernels.packing import pack_bits_np

                # Rows pack here, at the staging boundary: everything
                # downstream (staging block, flush, ring rows) is words.
                self._stage_x[idx, c] = pack_bits_np(xs[idx])
            else:
                self._stage_x[idx, c] = xs[idx]
            self._stage_y[idx, c] = ys[idx]
            self._count[idx] += 1
        self.dropped += mask & ~accepted
        return accepted

    # -- consumer side ------------------------------------------------------

    def take_block(self) -> Optional[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """The staged (xs [K, B, f], ys [K, B], counts [K]) block, or None
        when nothing is staged. Staging counts reset; the arrays are only
        valid until the next stage call (the jitted enqueue copies them to
        device immediately)."""
        if not self._count.any():
            return None
        counts = self._count.copy()
        self._count[:] = 0
        self.flushes += 1
        return self._stage_x, self._stage_y, counts
