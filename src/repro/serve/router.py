"""Fleet ingress: a host-side batch router feeding the ring buffers.

Heavy-traffic serving cannot afford one jitted dispatch per datapoint
(the ROADMAP's "Fleet-scale ingress" item): a million offers/s through a
per-point ``offer`` is a million device round-trips. :class:`BatchRouter`
is the missing layer — labelled traffic accumulates in a shared numpy
staging block (``[K, B_ingress]`` rows + per-replica fill counts, no
device interaction at all) and flushes through :func:`_enqueue_rows` as
ONE jitted dispatch pushing up to ``B_ingress`` rows into every replica's
ring buffer at once. ``benchmarks/ingress.py`` gates the win (>= 4x
offers/s over the looped per-point path at K = 8; far more in practice —
the dispatch count drops by a factor of ``B_ingress``).

Acceptance is decided host-side: the router carries an exact mirror of
every replica's outstanding datapoints (device occupancy + rows in
flight to the device; only mutated by the owning
:class:`~repro.serve.service.TMService`, which keeps the mirror in sync
on drains, flushes and state swaps), so a ``submit`` can report
backpressure synchronously — same observable semantics as the old
immediate-dispatch ``offer`` — while the device enqueue happens later,
batched.

Concurrency (DESIGN.md §14): staging is DOUBLE-BUFFERED so producers and
the flushing consumer never share an array. Two pre-allocated blocks
alternate: producers fill the *active* block under :attr:`lock`, and
``take_block`` *swaps* the blocks — the filled block becomes consumer
property (stable until the consumer's transfer completes and the next
swap hands it back), the spare becomes the new active block. Any number
of producer threads may call ``stage_rows`` concurrently; ``take_block``
assumes ONE consumer at a time (``TMService.flush`` serializes consumers
behind the service's device lock).
"""
from __future__ import annotations

import threading
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import buffer as buf_mod


@partial(jax.jit, static_argnums=1)
def _enqueue_rows(ss, block: int, xs, ys, counts):
    """Push up to ``counts[r]`` staged rows into EVERY replica's ring buffer.

    xs [K, B, f] bool (or [K, B, ceil(f/32)] uint32 when the fleet's
    buffers are packed — the push is dtype-agnostic), ys [K, B] i32,
    counts [K] i32 — ONE jitted dispatch
    lands a whole ingress block (rows keep their per-replica submission
    order; rows at index >= counts[r] are padding and never touch state).
    Returns (new session state, accepted-row count [K] i32).
    """
    def per_replica(buf, xr, yr, c):
        def step(carry, inp):
            b, acc = carry
            x, y, i = inp
            new_b, ok = buf_mod.push(b, x, y)
            take = i < c
            b = jax.tree.map(lambda a, o: jnp.where(take, a, o), new_b, b)
            return (b, acc + (ok & take).astype(jnp.int32)), None

        idx = jnp.arange(block, dtype=jnp.int32)
        (buf, acc), _ = jax.lax.scan(step, (buf, jnp.int32(0)), (xr, yr, idx))
        return buf, acc

    bufs, accepted = jax.vmap(per_replica)(ss.buf, xs, ys, counts)
    return ss._replace(buf=bufs), accepted


class _StageBlock:
    """One staging block: [K, B] rows + per-replica fill counts."""

    __slots__ = ("x", "y", "count")

    def __init__(self, n_replicas: int, block: int, row_shape: tuple,
                 dtype) -> None:
        self.x = np.zeros((n_replicas, block) + row_shape, dtype=dtype)
        self.y = np.zeros((n_replicas, block), dtype=np.int32)
        self.count = np.zeros(n_replicas, dtype=np.int32)


class BatchRouter:
    """Host-side staging queue between producers and the fleet's buffers.

    * ``stage_rows(xs, ys, mask, dev_size)`` — producer side: copy one row
      per masked replica into the active staging block, deciding acceptance
      against the outstanding-rows mirror (rejected rows are per-replica
      ``dropped`` backpressure events, exactly like the old per-point
      ``offer``; a single-replica offer is a one-hot mask). Replicas whose
      staging lane is full are returned as *blocked* — neither accepted nor
      dropped; the caller flushes and retries them.
    * ``take_block()`` — consumer side: swap the double-buffered blocks and
      hand the filled ``[K, B]`` block (plus fill counts) to the service
      for one ``_enqueue_rows`` dispatch. The returned arrays stay stable
      while producers fill the other block; they are recycled at the
      next-but-one ``take_block``, by which time the (single) consumer has
      finished its transfer.

    The service flushes whenever any replica's staging lane fills, and
    before every drain/inference-independent consumer step — so a lane
    never overflows and no staged row is ever reordered within its
    replica's stream. :attr:`lock` (re-entrant) guards ALL producer-side
    state: both blocks, the drop counter, and — by convention, see
    DESIGN.md §14 — the owning service's occupancy mirror.
    """

    def __init__(self, n_replicas: int, n_features: int, capacity: int,
                 block: int = 32, *, packed: bool = False):
        K = n_replicas
        self.n_replicas = K
        self.n_features = n_features
        self.capacity = capacity
        self.block = max(1, min(block, capacity))
        self.packed = packed
        if packed:
            # Packed staging (DESIGN.md §13): rows pack host-side at the
            # staging boundary, so the staging block, the flush transfer
            # AND the device ring rows all carry ceil(f/32) uint32 words
            # instead of f bools (~8x less ingress bandwidth; the flush
            # enqueue is dtype-agnostic).
            from repro.kernels.packing import n_words

            row_shape, dtype = (n_words(n_features),), np.uint32
        else:
            row_shape, dtype = (n_features,), np.dtype(bool)
        self._blocks = (_StageBlock(K, self.block, row_shape, dtype),
                        _StageBlock(K, self.block, row_shape, dtype))
        self._active = 0
        self.lock = threading.RLock()
        self.dropped = np.zeros(K, dtype=np.int64)   # backpressure events
        self.flushes = 0                             # device dispatches

    # -- producer side ------------------------------------------------------

    @property
    def staged(self) -> np.ndarray:
        """Rows staged but not yet flushed, per replica. [K] i32 (a copy)."""
        with self.lock:
            return self._blocks[self._active].count.copy()

    def lane_full(self) -> bool:
        """True when some replica's staging lane is full (flush before the
        next stage call, or it would block that replica's row for lack of
        lane space rather than true buffer backpressure)."""
        with self.lock:
            return bool(
                (self._blocks[self._active].count >= self.block).any()
            )

    def _route_rows(self, xs) -> tuple[np.ndarray, bool]:
        """Dtype-route producer rows: bool rows pass (and later pack when
        the router is packed); already-packed uint32 word rows pass through
        on a packed router and are a hard error on an unpacked one (a
        silent ``astype(bool)`` would mangle them). Returns
        (rows broadcast to [K, width], already_packed?)."""
        K = self.n_replicas
        xs = np.asarray(xs)
        if xs.dtype == np.uint32:
            if not self.packed:
                raise TypeError(
                    "uint32 rows look bit-packed (DESIGN.md §13) but this "
                    "router stages unpacked bool rows — build the service "
                    "with ServiceConfig(packed=True) or submit bool rows"
                )
            from repro.kernels.packing import n_words

            W = n_words(self.n_features)
            if xs.shape != (K, W):
                xs = np.broadcast_to(xs, (K, W))
            return xs, True
        xs = xs.astype(bool)
        if xs.shape != (K, self.n_features):
            xs = np.broadcast_to(xs, (K, self.n_features))
        return xs, False

    def stage_rows(self, xs, ys, mask,
                   dev_size) -> tuple[np.ndarray, np.ndarray]:
        """Stage one row per masked replica. Returns (accepted, blocked),
        both [K] bool.

        ``dev_size`` is the service's outstanding-rows mirror (device
        occupancy + in-flight flush rows); acceptance is
        ``dev_size + staged < capacity``, which is exactly what an
        immediate device push would have reported. A replica that has
        buffer space but a FULL staging lane comes back ``blocked`` —
        not a backpressure drop; the caller must flush and retry (under
        concurrent producers a lane can fill between anyone's check and
        stage, so this is an expected slow path, not a protocol error).
        """
        xs, already_packed = self._route_rows(xs)
        ys = np.asarray(ys, dtype=np.int32)
        if ys.shape != (self.n_replicas,):
            ys = np.broadcast_to(ys, (self.n_replicas,))
        with self.lock:
            blk = self._blocks[self._active]
            ok = mask & (dev_size + blk.count < self.capacity)
            room = blk.count < self.block
            accepted = ok & room
            blocked = ok & ~room
            idx = np.nonzero(accepted)[0]
            if idx.size:
                c = blk.count[idx]
                if self.packed and not already_packed:
                    from repro.kernels.packing import pack_bits_np

                    # Rows pack here, at the staging boundary: everything
                    # downstream (staging block, flush, ring rows) is words.
                    blk.x[idx, c] = pack_bits_np(xs[idx])
                else:
                    blk.x[idx, c] = xs[idx]
                blk.y[idx, c] = ys[idx]
                blk.count[idx] += 1
            self.dropped += mask & ~ok
        return accepted, blocked

    # -- consumer side ------------------------------------------------------

    def take_lanes(
        self, rids
    ) -> Optional[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Take ONLY the named replicas' staged rows out of the active
        block (stable copies; their lane counts zero so producers restage
        from the front). Returns (xs [n, B, f], ys [n, B], counts [n]) or
        None when none of the named lanes holds rows.

        The scoped-flush path for :meth:`TMService.evict`: landing a few
        replicas' rows before a spill must not force a whole-fleet flush.
        Other lanes' staged rows stay exactly where they are. Like
        ``take_block`` this assumes ONE consumer (the service's device
        lock); the inactive block never holds rows outside an in-flight
        flush, so the active block is the only staged storage to scan.
        """
        with self.lock:
            blk = self._blocks[self._active]
            rids = np.asarray(rids, dtype=np.int64).reshape(-1)
            counts = blk.count[rids].copy()
            if not counts.any():
                return None
            xs = blk.x[rids].copy()
            ys = blk.y[rids].copy()
            blk.count[rids] = 0
            return xs, ys, counts

    def take_block(self) -> Optional[tuple[np.ndarray, np.ndarray, np.ndarray]]:
        """Swap the staging blocks; returns the filled (xs [K, B, f],
        ys [K, B], counts [K]) block, or None when nothing is staged.

        Producers immediately continue into the fresh block; the returned
        arrays are NOT written again until the next-but-one ``take_block``
        (single consumer: by then its transfer is done). ``counts`` is a
        copy — the caller owns it.
        """
        with self.lock:
            blk = self._blocks[self._active]
            if not blk.count.any():
                return None
            counts = blk.count.copy()
            blk.count[:] = 0
            self._active ^= 1
            self.flushes += 1
            return blk.x, blk.y, counts
