"""TMService: the one fleet-native serving surface (a single machine is K=1).

The paper's deliverable is a managed serving *system* — Fig. 3's
offer -> cyclic buffer -> interleaved train/infer loop with the §5.3.2
mitigation policy — and MATADOR (arXiv 2403.10538) plus the
runtime-tunable eFPGA TM (arXiv 2502.07823) both show the multi-instance
form winning on ONE clean control interface with per-instance
hyperparameters. :class:`TMService` is that interface here:

* ``submit`` / ``submit_rows`` — labelled traffic, staged host-side by a
  :class:`~repro.serve.router.BatchRouter` and flushed as packed
  ``[K, B_ingress]`` row-batches (one jitted dispatch per flush, not one
  per datapoint).
* ``serve`` — fleet inference, one replica-first clause contraction.
* ``tick`` — the Fig-3 consumer cycle: flush ingress, drain each
  replica's budget through online training, advance the analysis cadence
  and apply the §5.3.2 policy (:class:`AdaptPolicy`, per replica).

Everything that used to be two parallel APIs — ``OnlineSession`` /
``TMOnlineAdaptManager`` (scalar) vs ``OnlineFleet`` /
``TMFleetAdaptManager`` (``[K]``) — is now a thin shim over this class;
the K = 1 slice reproduces the scalar semantics bit for bit (pinned by
tests/test_service.py against oracles transcribed from the pre-redesign
implementations). K = 1 with scalar runtime ports additionally keeps the
specialized single-machine drain body (`online._consume_many`; the
replicated plane costs ~1.3x at R = 1, DESIGN.md §10), which the same
parity suite pins bitwise against the replicated path.
"""
from __future__ import annotations

import dataclasses
import threading
from functools import partial
from typing import Callable, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import accuracy as acc_mod
from repro.core import feedback as fb_mod
from repro.core import online as online_mod
from repro.core import tm as tm_mod
from repro.core.online import ChunkAux, SessionState
from repro.core.tm import TMConfig, TMRuntime, TMState, init_runtime
from repro.data import buffer as buf_mod
from repro.distributed import sharding as shard_mod
from repro.kernels import packing
from repro.serve import residency as res_mod
from repro.serve import router as router_mod
from repro.serve import tunable as tun_mod
from repro.train import checkpoint as ckpt_mod


@jax.jit
def _advance_keys(keys, active):
    """Split every ACTIVE replica's RNG key; retired replicas keep theirs.

    Returns (new persistent keys [K], chunk keys [K]). One jitted dispatch
    per chunk — a replica's key splits exactly once per chunk it
    participates in, matching a standalone session's per-chunk split (the
    chunk keys handed to retired replicas are unused: their row budget for
    the chunk is 0, so no state is touched).
    """
    k2 = jax.vmap(jax.random.split)(keys)               # [K, 2, key]
    return jnp.where(active[:, None], k2[:, 0], keys), k2[:, 1]


@partial(jax.jit, static_argnums=(2,))
def _activate_enqueue_rows(ss, keys, block: int, act_mask, act_ss,
                           act_keys, xs, ys, counts):
    """A residency cohort's activation select FUSED with its superblock
    enqueue — ONE device round-trip where PR 8's per-cohort path paid a
    blocking gather, an index scatter and an enqueue (DESIGN.md §17).

    ``act_ss``/``act_keys`` are the slot-indexed activation payload from
    ``TMService._prepare_slots`` (host zeros outside ``act_mask``); the
    mask-select lands the snapshots, then the staged rows push into the
    freshly activated ring buffers inside the same jitted program.
    """
    ss, keys = online_mod.activate_replicas(
        (ss, keys), (act_ss, act_keys), act_mask
    )
    ss, accepted = router_mod._enqueue_rows(ss, block, xs, ys, counts)
    return ss, keys, accepted


def _select_replicas(mask, new: TMState, old: TMState) -> TMState:
    """Per-replica tree select: replica r takes ``new`` where mask[r]."""
    gate = online_mod.replica_gate(jnp.asarray(mask))
    return jax.tree.map(gate, new, old)


# ---------------------------------------------------------------------------
# The Fig-3 FSM (§5.3.2 mitigation policy), once, on [K] arrays.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _PolicyState:
    """Host-side FSM state of :class:`AdaptPolicy`, all per replica."""

    since: np.ndarray          # [K] i64 — points consumed since last analysis
    best: np.ndarray           # [K] f64 — best known accuracy (nan = none yet)
    rollbacks: np.ndarray      # [K] i64 — §5.3.2 rollbacks fired
    lost: np.ndarray           # [K] i64 — datapoints lost even after retry
    best_state: Optional[TMState] = None   # replicated [K, ...] snapshot


@dataclasses.dataclass
class AdaptPolicy:
    """The §5.3.2 mitigation policy: periodic analysis + rollback, per replica.

    ONE implementation on ``[K]`` arrays — K = 1 yields exactly the old
    scalar ``TMOnlineAdaptManager`` semantics, K > 1 the old
    ``TMFleetAdaptManager`` semantics (both shims now delegate here; the
    ~200 duplicated FSM lines are gone). A member that consumed
    ``analyze_every`` points since its last analysis is *due*: its eval
    accuracy is re-measured, and it rolls back to its own known-good TA
    bank on a drop past ``rollback_threshold`` — or snapshots a new best.
    Members that are not due are never touched.
    """

    analyze_every: int = 32           # online datapoints between analyses
    rollback_threshold: float = 0.1   # absolute accuracy drop -> rollback

    def init(self, n_replicas: int) -> _PolicyState:
        K = n_replicas
        return _PolicyState(
            since=np.zeros(K, dtype=np.int64),
            best=np.full(K, np.nan),
            rollbacks=np.zeros(K, dtype=np.int64),
            lost=np.zeros(K, dtype=np.int64),
        )

    def due(self, ps: _PolicyState) -> np.ndarray:
        return ps.since >= self.analyze_every

    def apply(self, ps: _PolicyState, due: np.ndarray, acc: np.ndarray,
              tm: TMState) -> tuple[TMState, np.ndarray]:
        """One policy transition for the due members. Returns
        (new TA banks, rolled-back mask [K])."""
        ps.since[due] = 0
        have_best = ~np.isnan(ps.best)
        collapse = due & have_best & (acc < ps.best - self.rollback_threshold)
        improve = due & (~have_best | (acc > ps.best))
        if collapse.any():
            # §5.3.2 per replica: restore collapsed members' known-good
            # TA banks; healthy members keep serving untouched.
            tm = _select_replicas(collapse, ps.best_state, tm)
            ps.rollbacks += collapse
        if improve.any():
            ps.best = np.where(improve, acc, ps.best)
            # The very first improve is an UNCONDITIONAL snapshot:
            # ``init()`` leaves best_state None (there is no known-good
            # bank before the first analysis or offline_train), and
            # _select_replicas on a None pytree is a structure-mismatch
            # crash. Taking ``tm`` wholesale is safe for the replicas not
            # improving here: their ``best`` stays nan, so their slice of
            # the snapshot is unreachable (collapse requires have_best)
            # until their own first improve overwrites it.
            ps.best_state = (tm if ps.best_state is None
                             else _select_replicas(improve, tm, ps.best_state))
        return tm, collapse

    def snapshot(self, ps: _PolicyState, acc: np.ndarray, tm: TMState):
        """Unconditional known-good snapshot (the offline-train baseline)."""
        ps.best = np.asarray(acc, dtype=np.float64).copy()
        ps.best_state = tm


class TickReport(NamedTuple):
    """What one :meth:`TMService.tick` did, per replica."""

    trained: np.ndarray                 # [K] i64 — points consumed
    accuracy: Optional[np.ndarray]      # [K] f32 — eval accs, None if not due
    rolled_back: np.ndarray             # [K] bool — §5.3.2 rollbacks fired


@dataclasses.dataclass
class ServiceConfig:
    """Construction-time knobs of a :class:`TMService`.

    ``s``/``T`` ride the runtime's per-replica hyperparameter ports:
    scalars give a homogeneous fleet, length-K sequences give every member
    its own (s, T) without re-JIT. ``ingress_block`` is B_ingress — the
    router's staged rows per replica per flushed dispatch.

    ``packed`` switches the whole boolean datapath to the bit-packed
    uint32 representation (DESIGN.md §13): rows pack host-side at the
    router's staging boundary, the ring buffers store ceil(f/32) words
    per datapoint (~8x less ingress/buffer traffic), and every
    inference/analysis/monitoring pass runs the AND+popcount clause
    kernels. Served predictions, drained TA states and analysis
    accuracies are bit-identical to the unpacked path (which stays the
    parity oracle — pinned by tests/test_scale.py).

    ``history_limit`` bounds the analysis ``history`` list to its most
    recent N entries — a long-running service analyzing on cadence would
    otherwise grow it without bound (a memory leak at traffic scale).
    None keeps the legacy unbounded behavior.

    ``resident`` caps how many replicas hold DEVICE state at once
    (DESIGN.md §15): the device plane shrinks to ``[resident, ...]``
    slots and the other ``K - resident`` machines live as host-side LRU
    snapshots (:mod:`repro.serve.residency`), activated transparently
    when traffic, inference or analysis touches them. This is the
    thousand-replica knob — K=4096 personalization fleets on a 4-device
    mesh with bounded device memory. None (default) keeps every replica
    resident. The string ``"auto"`` (DESIGN.md §17) self-sizes the
    plane: the residency map keeps an EWMA of the per-round active-set
    size and ``tick`` re-partitions (via the checkpoint-migration
    machinery) when the estimate crosses the grow/shrink hysteresis
    bands — trajectories are unchanged across re-partitions
    (partitioning is not logical state). Requires scalar ``s``/``T``
    (a slot's runtime ports must not change meaning with the replica
    occupying it).

    ``batched_moves`` (default True) selects the batched residency
    datapath (DESIGN.md §17): activation snapshots ride the flush/drain
    dispatch as a fused mask-select and eviction gathers are issued
    asynchronously, settled off the critical path. False keeps PR 8's
    synchronous per-cohort gather/scatter sequence — bitwise identical
    (pinned by tests/test_residency.py) and the baseline
    ``benchmarks/residency.py`` measures the batched path against.

    ``tunable`` (a :class:`~repro.serve.tunable.TunableConfig`) arms the
    runtime-tunable serving path (DESIGN.md §16): after
    :meth:`TMService.calibrate` ranks every replica's clauses, ``serve``
    takes a per-call compute ``budget`` (fraction of clauses actually
    contracted), optional calibrated integer vote weights, and early-exit
    voting; with ``adapt`` on, ``tick`` moves the live budget from
    observed queue depth (load shedding under SLO pressure). Budget 1.0
    with unit weights and early exit off is bitwise identical to plain
    serving.
    """

    replicas: int = 1
    buffer_capacity: int = 64
    chunk: int = 16                   # datapoints drained per jitted call
    ingress_block: int = 32           # staged rows per replica per flush
    packed: bool = False              # bit-packed datapath (DESIGN.md §13)
    history_limit: Optional[int] = None   # analysis entries kept (None = all)
    # device slots: None = all K resident, int = fixed, "auto" = self-sizing
    resident: Union[int, None, str] = None
    batched_moves: bool = True        # batched residency datapath (§17)
    s: Union[float, Sequence[float], None] = None
    T: Union[int, Sequence[int], None] = None
    policy: AdaptPolicy = dataclasses.field(default_factory=AdaptPolicy)
    seed: Union[int, Sequence[int]] = 0
    mesh: Optional[Mesh] = None
    tunable: Optional[tun_mod.TunableConfig] = None

    def runtime(self, cfg: TMConfig) -> TMRuntime:
        """A fault-free runtime with this config's s/T ports."""
        rt = init_runtime(cfg)
        for name, port, dtype in (("s", self.s, jnp.float32),
                                  ("T", self.T, jnp.int32)):
            if port is None:
                continue
            if np.ndim(port) == 0:
                rt = rt._replace(**{name: dtype(port)})
            else:
                if len(port) != self.replicas:
                    raise ValueError(
                        f"per-replica {name} carries {len(port)} entries, "
                        f"expected {self.replicas}"
                    )
                rt = rt._replace(**{name: jnp.asarray(port, dtype)})
        return rt


class TMService:
    """K concurrent Fig-3 machines behind one control surface (K >= 1).

    Device layout is the replicated kernel contract (DESIGN.md §9/§10):
    every member owns its data stream, so state, buffers, budgets and RNG
    keys all lead with K, per-replica hyperparameters ride the runtime's
    ``s``/``T`` ports, and each drain chunk advances the whole fleet in
    ONE ``_consume_many_replicated`` call. Ingress is the
    :class:`~repro.serve.router.BatchRouter` staging queue — ``submit`` is
    a host-side numpy write; the device sees packed ``[K, B_ingress]``
    blocks.

    ``state`` may be a single machine's :class:`TMState` (broadcast to K
    identical banks) or an already-replicated ``[K, ...]`` state. ``rt``
    overrides the runtime built from ``sc.s``/``sc.T`` (shims pass their
    caller's runtime through). ``eval_x``/``eval_y`` are the accuracy-
    analysis set; without them ``tick`` still drains but never analyzes.

    Threading (DESIGN.md §14): ``submit``/``submit_rows`` are safe from
    any number of producer threads — they touch only the router's
    double-buffered staging state and the outstanding-rows mirror, both
    guarded by ``router.lock``. Everything consumer-side (device state,
    RNG keys, policy FSM, history, the runtime ``rt``) is serialized by
    one re-entrant device lock taken by ``flush``/``drain``/``tick``/
    ``serve``/``analyze``/``offline_train``; a producer only ever reaches
    the device lock through ``flush`` when its staging lane fills
    (lane-full backpressure blocks that producer until the consumer's
    current step completes). Lock order is always device -> router.
    """

    def __init__(
        self,
        cfg: TMConfig,
        state: TMState,
        sc: Optional[ServiceConfig] = None,
        *,
        rt: Optional[TMRuntime] = None,
        eval_x=None,
        eval_y=None,
    ):
        sc = sc or ServiceConfig()
        if sc.history_limit is not None and sc.history_limit < 1:
            raise ValueError("history_limit must be >= 1 (or None)")
        replicated = state.ta_state.ndim == 4
        K = sc.replicas
        if replicated and state.ta_state.shape[0] != K:
            raise ValueError(
                f"state carries {state.ta_state.shape[0]} replicas, "
                f"expected {K}"
            )
        auto = sc.resident == "auto"
        if isinstance(sc.resident, str) and not auto:
            raise ValueError(
                f"resident must be an int, None or 'auto', "
                f"got {sc.resident!r}"
            )
        if not auto and sc.resident is not None and sc.resident < 1:
            raise ValueError("resident must be >= 1 (or None, or 'auto')")
        # Auto-residency (§17): re-partition targets round up to the mesh
        # device count so the plane always shards evenly.
        granule = 1 if sc.mesh is None else int(sc.mesh.devices.size)
        if auto:
            # Start at a quarter of the fleet (granule-rounded): small
            # enough that a sparse workload shrinks within one band, big
            # enough that dense traffic grows without thrashing first.
            P = max(1, -(-K // 4))
            P = min(K, -(-P // granule) * granule)
            residency = True
        else:
            residency = sc.resident is not None and sc.resident < K
            # P: the device-plane length — R slots under residency, else K.
            P = int(sc.resident) if residency else K

        self.cfg = cfg
        self.sc = sc
        self.rt = rt if rt is not None else sc.runtime(cfg)
        self.n_replicas = K
        self.n_resident = P
        self.chunk = max(1, min(sc.chunk, sc.buffer_capacity))
        self.mesh = sc.mesh
        self.policy = sc.policy
        if residency and (jnp.ndim(self.rt.s) != 0
                          or jnp.ndim(self.rt.T) != 0):
            raise ValueError(
                "residency (resident < replicas) requires scalar s/T "
                "runtime ports — a slot's hyperparameters must not "
                "change with the replica occupying it"
            )
        # Packed services hold the eval set as words too: every analysis
        # pass then rides the packed kernels (dtype routing in the core).
        self.eval_x = None if eval_x is None else self._ingest(eval_x)
        self.eval_y = None if eval_y is None else jnp.asarray(eval_y,
                                                              jnp.int32)
        # K = 1 with scalar runtime ports keeps the specialized
        # single-machine drain/inference bodies (DESIGN.md §10: the
        # replicated plane costs ~1.3x at R = 1); pinned bitwise against
        # the replicated path by the parity suites.
        self._k1 = (K == 1 and self.mesh is None
                    and jnp.ndim(self.rt.s) == 0 and jnp.ndim(self.rt.T) == 0)

        seed = sc.seed
        if isinstance(seed, (int, np.integer)):
            base = jax.random.PRNGKey(int(seed))
            keys = jax.vmap(lambda r: jax.random.fold_in(base, r))(
                jnp.arange(K)
            )
        else:
            if len(seed) != K:
                raise ValueError(f"need {K} seeds, got {len(seed)}")
            keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seed])

        buf1 = buf_mod.make(sc.buffer_capacity, cfg.n_features,
                            packed=sc.packed)
        plane_tm = (TMState(ta_state=state.ta_state[:P]) if replicated
                    else TMState(ta_state=jnp.broadcast_to(
                        state.ta_state, (P,) + state.ta_state.shape)))
        bufs = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (P,) + a.shape), buf1
        )
        self._ss = SessionState(
            tm=plane_tm, buf=bufs, step=jnp.zeros((P,), jnp.int32)
        )
        self._keys = keys if not residency else keys[:P]   # [P, key]
        if self.mesh is not None:
            sh = shard_mod.replica_shardings(
                (self._ss, self._keys), self.mesh, n_replicas=P
            )
            self._ss, self._keys = jax.tree.map(
                jax.device_put, (self._ss, self._keys), sh
            )
        # Residency (DESIGN.md §15): replicas 0..P-1 start in the device
        # slots; the rest spill as host snapshots sharing the broadcast
        # initial bank / empty buffer (snapshots are immutable in the
        # store, so sharing is safe).
        self._res: Optional[res_mod.ResidencyMap] = None
        self._best_host: Optional[np.ndarray] = None  # [K, C, J, L] banks
        self._auto = auto
        self._granule = granule
        # Batched residency moves (§17): fused activate+enqueue dispatch,
        # deferred spill settlement. False = PR 8's synchronous per-cohort
        # path, kept as the bitwise oracle + bench baseline.
        self._batched = residency and sc.batched_moves
        self.repartitions = 0              # auto re-partition count
        # Deferred spills: (device value tree, rids) pairs issued but not
        # yet copied to host. Settled lazily (before any full-plane read,
        # store access, or re-activation of a pending rid) — the device
        # slices stay valid across plane replacement (JAX immutability).
        self._pending_spills: list = []
        self._pending_rids: set = set()
        if residency:
            self._res = res_mod.ResidencyMap(K, P)
            self._res.assign(np.arange(P), np.arange(P))
            keys_host = np.asarray(keys)
            buf_host = jax.tree.map(np.asarray, buf1)
            banks_host = np.asarray(state.ta_state)
            for rid in range(P, K):
                bank = banks_host[rid] if replicated else banks_host
                self._res.store[rid] = (
                    SessionState(tm=TMState(ta_state=bank), buf=buf_host,
                                 step=np.int32(0)),
                    keys_host[rid],
                )
        self.router = router_mod.BatchRouter(
            K, cfg.n_features, sc.buffer_capacity, sc.ingress_block,
            packed=sc.packed,
        )
        # Outstanding-rows mirror: device buffer occupancy + rows in
        # flight to the device (credited at block swap, rejects undone
        # after the enqueue). Guarded by router.lock — the producer-side
        # acceptance decision reads it together with the staging counts.
        self._dev_size = np.zeros(K, dtype=np.int64)
        # Consumer-side serialization (DESIGN.md §14). Re-entrant: drain
        # flushes inside its own critical section.
        self._device_lock = threading.RLock()
        self._full_mask = np.ones(K, dtype=bool)
        # best_state starts None: there is no known-good bank before the
        # first analysis/offline_train — the policy's first improve
        # snapshots unconditionally (the old init-state pre-seed hid an
        # AdaptPolicy.apply crash on standalone-initialized policies).
        self._ps = sc.policy.init(K)
        self.history: list = []            # (steps [K], accuracies [K])
        # Runtime-tunable serving (DESIGN.md §16): the controller holds
        # per-replica clause rankings host-side — [K, ...] like _best_host,
        # so residency eviction never touches them and save/restore
        # carries them with the fleet.
        self.tuner: Optional[tun_mod.TuneController] = (
            None if sc.tunable is None
            else tun_mod.TuneController(sc.tunable, K, cfg.max_clauses)
        )

    def _ingest(self, xs) -> jax.Array:
        """Bool rows -> the service's wire representation: bool features
        unpacked, uint32 words when ``sc.packed`` (already-packed uint32
        input passes through)."""
        xs = jnp.asarray(xs)
        if not self.sc.packed:
            return xs.astype(bool)
        if xs.dtype == jnp.uint32:
            return xs
        return packing.pack_bits(xs.astype(bool))

    # -- device state (mirror-preserving) -----------------------------------

    @property
    def ss(self) -> SessionState:
        """Device state, with staged ingress flushed first — so externally
        read (and read-modify-written) state always contains every accepted
        datapoint, exactly like the pre-staging immediate-enqueue API.
        Under residency this is the ASSEMBLED full-K logical fleet (device
        slots gathered + spilled snapshots) — a read-only view; use
        save/restore or evict/activate to move state."""
        with self._device_lock:
            self.flush()
            if self._res is None:
                return self._ss
            ss_K, _ = self._assemble_plane()
            return jax.tree.map(jnp.asarray, ss_K)

    @ss.setter
    def ss(self, value: SessionState):
        """Replacing device state wholesale re-syncs the occupancy mirror
        (benchmarks pre-fill buffers this way). Traffic staged but never
        read back via the getter still lands on the next flush."""
        with self._device_lock:
            if self._res is not None:
                raise ValueError(
                    "a residency service's device plane cannot be "
                    "swapped wholesale; use restore() for bulk state"
                )
            self._ss = value
            with self.router.lock:
                self._dev_size = np.asarray(
                    value.buf.size, dtype=np.int64
                ).reshape(self.n_replicas).copy()

    def _assemble_plane(self) -> tuple[SessionState, np.ndarray]:
        """The full-K logical (SessionState, keys) as HOST numpy — device
        rows gathered into replica order, spilled snapshots filled in."""
        self._settle_spills()
        host = jax.tree.map(np.asarray, (self._ss, self._keys))
        if self._res is None:
            return host
        K = self.n_replicas
        flat_p, treedef = jax.tree_util.tree_flatten(host)
        outs = [np.zeros((K,) + l.shape[1:], l.dtype) for l in flat_p]
        m = self._res.replica_of >= 0
        rids = self._res.replica_of[m]
        for o, l in zip(outs, flat_p):
            o[rids] = l[m]
        for rid, snap in self._res.store.items():
            flat_s, _ = jax.tree_util.tree_flatten(snap)
            for o, l in zip(outs, flat_s):
                o[rid] = l
        return jax.tree_util.tree_unflatten(treedef, outs)

    # -- ingress (producer side) --------------------------------------------

    def submit_rows(self, xs, ys, mask=None) -> np.ndarray:
        """One labelled datapoint into every (masked) replica's stream;
        returns accepted [K] bool (False = backpressure, counted in
        ``dropped``). Host-side staging only — the device enqueue happens
        on the next flush (a full staging lane flushes automatically).

        Safe under concurrent producers: replicas whose lane filled while
        this call raced another producer come back *blocked* from the
        router, and the call flushes and retries them — blocked rows are
        never silently dropped nor double-staged.
        """
        pending = (self._full_mask if mask is None
                   else np.asarray(mask, dtype=bool))
        accepted = np.zeros(self.n_replicas, dtype=bool)
        while True:
            ok, blocked = self.router.stage_rows(
                xs, ys, pending, self._dev_size
            )
            accepted |= ok
            if self.router.lane_full():
                self.flush()
            if not blocked.any():
                return accepted
            pending = blocked

    def submit(self, r: int, x, y) -> bool:
        """One labelled datapoint into replica ``r``'s stream."""
        mask = np.zeros(self.n_replicas, dtype=bool)
        mask[r] = True
        return bool(self.submit_rows(x, y, mask)[r])

    def flush(self) -> np.ndarray:
        """Push every staged row to the device buffers — ONE jitted
        ``_enqueue_rows`` dispatch per staged block. Returns [K] rows
        landed. Rows a buffer rejects despite the mirror (only possible
        when device state was swapped mid-flight) count as dropped.

        The block swap and the mirror credit happen atomically under
        ``router.lock`` (taken rows are *in flight*: no longer staged,
        not yet device-visible — crediting them at swap time keeps every
        outstanding row counted exactly once by concurrent acceptance
        decisions); the device transfer itself runs outside that lock,
        overlapping producers filling the other staging block.
        """
        K = self.n_replicas
        landed = np.zeros(K, dtype=np.int64)
        with self._device_lock:
            while True:
                with self.router.lock:
                    block = self.router.take_block()
                    if block is not None:
                        self._dev_size += block[2]
                if block is None:
                    return landed
                landed += (self._flush_block(*block) if self._res is None
                           else self._flush_block_residency(*block))

    def _flush_block(self, xs, ys, counts) -> np.ndarray:
        """One taken [K, B] staging block -> one enqueue dispatch."""
        self._ss, accepted = router_mod._enqueue_rows(
            self._ss, self.router.block, xs, ys, counts
        )
        acc = np.asarray(accepted, dtype=np.int64)
        with self.router.lock:
            self._dev_size -= counts - acc
            self.router.dropped += counts - acc
        return acc

    def _flush_block_residency(self, xs, ys, counts) -> np.ndarray:
        """One taken [K, B] block under residency: the full hot-lane set
        is built host-side ONCE per round, then lands cohort by cohort
        through :meth:`_enqueue_lanes` — the batched path (§17) fuses
        each cohort's activation select with its superblock enqueue into
        one dispatch; ``batched_moves=False`` keeps PR 8's synchronous
        per-cohort gather/scatter/enqueue sequence as the oracle."""
        lanes = np.nonzero(np.asarray(counts) > 0)[0]
        return self._enqueue_lanes(lanes, xs[lanes], ys[lanes],
                                   counts[lanes])

    def _enqueue_lanes(self, lanes, xs_l, ys_l, cnt_l) -> np.ndarray:
        """Land the given lanes' staged rows (lane-indexed [n, B, ...])
        into their replicas' device rings, cohorting by the slot count.
        Returns [K] rows landed (mirror + drop accounting per cohort)."""
        K, R = self.n_replicas, self.n_resident
        landed = np.zeros(K, dtype=np.int64)
        for i in range(0, len(lanes), R):
            sl = slice(i, i + R)
            cohort = lanes[sl]
            enqueue = (self._enqueue_cohort_batched if self._batched
                       else self._enqueue_cohort_sync)
            acc = enqueue(cohort, xs_l[sl], ys_l[sl], cnt_l[sl])
            rej = np.asarray(cnt_l[sl], dtype=np.int64) - acc
            with self.router.lock:
                self._dev_size[cohort] -= rej
                self.router.dropped[cohort] += rej
            landed[cohort] += acc
        return landed

    def _enqueue_cohort_sync(self, cohort, xs_c, ys_c,
                             cnt_c) -> np.ndarray:
        """PR 8's per-cohort path: synchronous activation (blocking
        gather + index scatter), then a separate enqueue dispatch. The
        bitwise oracle the batched path is pinned against
        (tests/test_residency.py) and the baseline it is benched
        against (benchmarks/residency.py)."""
        R = self.n_resident
        slots = self._ensure_resident(cohort)
        xs_p = np.zeros((R,) + xs_c.shape[1:], dtype=xs_c.dtype)
        ys_p = np.zeros((R,) + ys_c.shape[1:], dtype=ys_c.dtype)
        cnt_p = np.zeros((R,), dtype=cnt_c.dtype)
        xs_p[slots] = xs_c
        ys_p[slots] = ys_c
        cnt_p[slots] = cnt_c
        self._ss, accepted = router_mod._enqueue_rows(
            self._ss, self.router.block, xs_p, ys_p, cnt_p
        )
        return np.asarray(accepted, dtype=np.int64)[slots]

    def _enqueue_cohort_batched(self, cohort, xs_c, ys_c,
                                cnt_c) -> np.ndarray:
        """§17 batched cohort: prepare the slots (victim gathers ISSUED,
        not awaited; activation snapshots stacked into slot-indexed host
        planes), scatter the lane rows to the [R, B] superblock, then
        ONE fused activate+enqueue dispatch. Pending spill copies settle
        only after the dispatch is in flight, so the device->host
        drain of cohort i's victims overlaps cohort i+1's device work."""
        R = self.n_resident
        slots, act = self._prepare_slots(cohort)
        xs_p = np.zeros((R,) + xs_c.shape[1:], dtype=xs_c.dtype)
        ys_p = np.zeros((R,) + ys_c.shape[1:], dtype=ys_c.dtype)
        cnt_p = np.zeros((R,), dtype=cnt_c.dtype)
        xs_p[slots] = xs_c
        ys_p[slots] = ys_c
        cnt_p[slots] = cnt_c
        if act is None:
            self._ss, accepted = router_mod._enqueue_rows(
                self._ss, self.router.block, xs_p, ys_p, cnt_p
            )
        else:
            act_mask, (act_ss, act_keys) = act
            self._ss, self._keys, accepted = _activate_enqueue_rows(
                self._ss, self._keys, self.router.block,
                act_mask, act_ss, act_keys, xs_p, ys_p, cnt_p,
            )
            self._reshard_plane()
        self._settle_spills()
        return np.asarray(accepted, dtype=np.int64)[slots]

    def _reshard_plane(self) -> None:
        """Re-pin the device plane's sharding after a dispatch whose
        host-side activation operands carried no placement (mesh only;
        a no-op move when the compiler already kept the layout)."""
        if self.mesh is None:
            return
        plane = (self._ss, self._keys)
        sh = shard_mod.replica_shardings(
            plane, self.mesh, n_replicas=self.n_resident
        )
        self._ss, self._keys = jax.tree.map(jax.device_put, plane, sh)

    # -- residency (DESIGN.md §15) ------------------------------------------

    @property
    def resident(self) -> np.ndarray:
        """[K] bool — replicas holding device state right now (all True
        on a service without a residency layer)."""
        if self._res is None:
            return np.ones(self.n_replicas, dtype=bool)
        return self._res.resident_mask.copy()

    def _ensure_resident(self, rids) -> np.ndarray:
        """Device slots for the named replicas, activating evicted ones
        (spilling LRU residents to make room). Callers hold the device
        lock; a cohort is at most ``n_resident`` distinct replicas."""
        if not self._batched:
            return self._ensure_resident_sync(rids)
        slots, act = self._prepare_slots(rids)
        if act is not None:
            act_mask, act_plane = act
            self._ss, self._keys = online_mod.activate_replicas(
                (self._ss, self._keys), act_plane, act_mask
            )
            self._reshard_plane()
        return slots

    def _ensure_resident_sync(self, rids) -> np.ndarray:
        """PR 8's synchronous residency body (``batched_moves=False``):
        blocking gather on spill, index scatter on activate."""
        res = self._res
        rids = np.asarray(rids, dtype=np.int64).reshape(-1)
        if len(rids) > self.n_resident:
            raise ValueError(
                f"cohort of {len(rids)} replicas exceeds the "
                f"{self.n_resident} device slots"
            )
        if len(np.unique(rids)) != len(rids):
            raise ValueError("duplicate replicas in a residency cohort")
        need = rids[res.slot_of[rids] < 0]
        if len(need):
            free = res.free_slots()
            take = list(free[:len(need)])
            short = len(need) - len(take)
            if short > 0:
                pinned = res.slot_of[rids]
                victims = res.lru_victims(short, pinned[pinned >= 0])
                self._spill(victims)
                take += list(victims)
            self._activate(need, np.asarray(take[:len(need)],
                                            dtype=np.int64))
        slots = res.slot_of[rids]
        res.touch(slots)
        return slots

    def _prepare_slots(self, rids):
        """Slots for the named cohort, with the activation BUILT but not
        landed: victims' device gathers are issued (not awaited) and the
        evicted members' snapshots stack into slot-indexed [R, ...] host
        planes plus an activation mask — ready to ride a fused dispatch
        (§17). Returns (slots [n], None | (act_mask [R],
        (act_ss_plane, act_keys_plane)))."""
        res = self._res
        R = self.n_resident
        rids = np.asarray(rids, dtype=np.int64).reshape(-1)
        if len(rids) > R:
            raise ValueError(
                f"cohort of {len(rids)} replicas exceeds the "
                f"{R} device slots"
            )
        if len(np.unique(rids)) != len(rids):
            raise ValueError("duplicate replicas in a residency cohort")
        need = rids[res.slot_of[rids] < 0]
        if len(need) == 0:
            slots = res.slot_of[rids]
            res.touch(slots)
            return slots, None
        free = res.free_slots()
        take = list(free[:len(need)])
        short = len(need) - len(take)
        if short > 0:
            pinned = res.slot_of[rids]
            victims = res.lru_victims(short, pinned[pinned >= 0])
            self._spill_issue(victims)
            take += list(victims)
        take = np.asarray(take[:len(need)], dtype=np.int64)
        # Re-activating a replica whose spill is still in flight needs
        # the snapshot NOW — its bits exist only in the deferred device
        # slices until a settle writes the store.
        if self._pending_rids.intersection(int(r) for r in need):
            self._settle_spills()
        snaps = [res.store.pop(int(r)) for r in need]
        vals = jax.tree.map(lambda *xs: np.stack(xs), *snaps)

        def to_plane(leaf):
            leaf = np.asarray(leaf)
            out = np.zeros((R,) + leaf.shape[1:], dtype=leaf.dtype)
            out[take] = leaf
            return out

        act_plane = jax.tree.map(to_plane, vals)
        act_mask = np.zeros(R, dtype=bool)
        act_mask[take] = True
        res.assign(need, take)
        slots = res.slot_of[rids]
        res.touch(slots)
        return slots, (act_mask, act_plane)

    def _spill_issue(self, slots) -> None:
        """ISSUE the device->host gather for the replicas in the given
        slots without awaiting it: the sliced device values (immutable,
        so bit-correct across later plane replacements) park on the
        pending list and materialize at the next settle point — off the
        inter-cohort critical path (§17)."""
        slots = np.asarray(slots, dtype=np.int64)
        if len(slots) == 0:
            return
        vals = online_mod.gather_replicas_issue(
            (self._ss, self._keys), slots
        )
        rids = self._res.release(slots)
        self._pending_spills.append((vals, rids))
        self._pending_rids.update(int(r) for r in rids)

    def _settle_spills(self) -> None:
        """Materialize every pending spill into the host store. Cheap
        no-op when nothing is pending; every full-plane read
        (_assemble_plane, steps, bank access) settles first."""
        if not self._pending_spills:
            return
        pending, self._pending_spills = self._pending_spills, []
        self._pending_rids.clear()
        for vals, rids in pending:
            host = online_mod.gather_replicas_await(vals)
            for j, rid in enumerate(rids):
                self._res.store[int(rid)] = jax.tree.map(
                    lambda a, _j=j: a[_j], host
                )

    def _spill(self, slots) -> None:
        """Evict the replicas in the given slots: one device->host gather,
        complete per-machine snapshots into the LRU store."""
        slots = np.asarray(slots, dtype=np.int64)
        if len(slots) == 0:
            return
        vals = online_mod.gather_replicas((self._ss, self._keys), slots)
        rids = self._res.release(slots)
        for j, rid in enumerate(rids):
            self._res.store[int(rid)] = jax.tree.map(lambda a: a[j], vals)

    def _activate(self, rids, slots) -> None:
        """Load the named (evicted) replicas' snapshots into free slots:
        one host->device scatter per cohort."""
        snaps = [self._res.store.pop(int(r)) for r in rids]
        vals = jax.tree.map(lambda *xs: np.stack(xs), *snaps)
        plane = online_mod.scatter_replicas(
            (self._ss, self._keys), slots, vals
        )
        if self.mesh is not None:
            sh = shard_mod.replica_shardings(
                plane, self.mesh, n_replicas=self.n_resident
            )
            plane = jax.tree.map(jax.device_put, plane, sh)
        self._ss, self._keys = plane
        self._res.assign(np.asarray(rids, dtype=np.int64), slots)

    def evict(self, replicas) -> None:
        """Spill the named replicas to the host store. Their staged
        ingress lands first — scoped to THEIR lanes only via
        :meth:`BatchRouter.take_lanes` (a K=4096 fleet must not pay a
        whole-fleet flush to spill a handful of members; other lanes'
        staged rows stay staged). Any later submit/serve/analysis
        touching the evicted members re-activates transparently."""
        with self._device_lock:
            if self._res is None:
                raise ValueError(
                    "service has no residency layer (resident is None)"
                )
            rids = np.unique(
                np.asarray(replicas, dtype=np.int64).reshape(-1)
            )
            with self.router.lock:
                taken = self.router.take_lanes(rids)
                if taken is not None:
                    # taken rows are in flight: credit the mirror at the
                    # take, debit rejects after the enqueue — same
                    # accounting as the block-swap flush
                    self._dev_size[rids] += taken[2]
            if taken is not None:
                xs_l, ys_l, cnt_l = taken
                hot = np.nonzero(cnt_l > 0)[0]
                self._enqueue_lanes(rids[hot], xs_l[hot], ys_l[hot],
                                    cnt_l[hot])
            slots = self._res.slot_of[rids]
            slots = np.unique(slots[slots >= 0])
            if self._batched:
                # an explicit evict wants the snapshots durable NOW (the
                # caller may read svc.ss or save() without another op)
                self._spill_issue(slots)
                self._settle_spills()
            else:
                self._spill(slots)

    def activate(self, replicas) -> np.ndarray:
        """Make the named replicas device-resident (at most ``resident``
        of them); returns their slots."""
        with self._device_lock:
            if self._res is None:
                raise ValueError(
                    "service has no residency layer (resident is None)"
                )
            return self._ensure_resident(replicas)

    @property
    def buffered(self) -> np.ndarray:
        """Datapoints awaiting consumption per replica (device + in-flight
        + staged; read coherently under the router lock)."""
        with self.router.lock:
            return self._dev_size + self.router.staged

    @property
    def dropped(self) -> np.ndarray:
        """Backpressure events per replica. [K] i64 (a copy)."""
        with self.router.lock:
            return self.router.dropped.copy()

    # -- consumer side ------------------------------------------------------

    def drain(
        self,
        max_points,
        on_chunk: Optional[Callable[[ChunkAux], None]] = None,
    ) -> np.ndarray:
        """Consume up to ``max_points`` buffered rows PER REPLICA; [K]
        trained. Flushes staged ingress first, then drains chunk by chunk
        — one jitted call per chunk for the whole fleet (the per-cycle
        budget of Fig. 3, K machines per dispatch). Per-replica
        RNG/termination semantics exactly mirror K independent sessions.

        ``on_chunk`` receives each chunk's :class:`ChunkAux` with leading
        replica axis ``[K, chunk]``; without it the monitoring contraction
        is compiled out entirely.
        """
        K = self.n_replicas
        budget = np.broadcast_to(
            np.asarray(max_points, dtype=np.int64), (K,)
        ).copy()
        # the drain bodies keep the occupancy mirror in sync per chunk (not
        # here, after the fact) so an on_chunk callback raising mid-drain
        # can't desync accounting from the device
        with self._device_lock:
            self.flush()
            if self._res is None:
                return (self._drain_k1(budget, on_chunk) if self._k1
                        else self._drain_replicated(budget, on_chunk))
            # Residency: sweep EVERY replica holding buffered rows (and
            # budget) in cohorts of <= resident slots — no lane starves
            # behind the working set, and sparse traffic only ever
            # activates its own users. A replica with budget but no
            # buffered rows is skipped entirely, so its RNG key does not
            # split; the always-resident twin property therefore masks
            # budgets by ``buffered > 0`` (tests/test_residency.py).
            trained = np.zeros(K, dtype=np.int64)
            with self.router.lock:
                has_rows = self._dev_size > 0
            todo = np.nonzero(has_rows & (budget > 0))[0]
            # the active-set size is the autotune signal (§17): how many
            # replicas actually need a slot this round
            self._res.note_active(len(todo))
            R = self.n_resident
            for i in range(0, len(todo), R):
                cohort = todo[i:i + R]
                slots = self._ensure_resident(cohort)
                budget_p = np.zeros(R, dtype=np.int64)
                budget_p[slots] = budget[cohort]
                trained_p = self._drain_replicated(budget_p, on_chunk)
                trained[cohort] = trained_p[slots]
            self._settle_spills()
            return trained

    def _drain_replicated(self, budget, on_chunk) -> np.ndarray:
        K = len(budget)   # the device-plane length (= slots, not fleet K)
        trained = np.zeros(K, dtype=np.int64)
        active = trained < budget
        monitor = on_chunk is not None
        while active.any():
            want = np.where(
                active, np.minimum(self.chunk, budget - trained), 0
            ).astype(np.int32)
            self._keys, chunk_keys = _advance_keys(
                self._keys, jnp.asarray(active)
            )
            self._ss, n, aux = online_mod._consume_many_replicated(
                self.cfg, self.chunk, self._ss, self.rt,
                jnp.asarray(want), chunk_keys, monitor=monitor,
            )
            n = np.asarray(n, dtype=np.int64)
            trained += n
            with self.router.lock:
                self._debit_mirror(n)
            if monitor and n.any():
                on_chunk(aux)
            active &= (n == want) & (trained < budget)
        return trained

    def _debit_mirror(self, n_plane) -> None:
        """Map a device-plane consumed-rows vector onto the [K] mirror
        (identity without residency). Callers hold the router lock."""
        if self._res is None:
            self._dev_size -= n_plane
        else:
            m = self._res.replica_of >= 0
            np.subtract.at(self._dev_size, self._res.replica_of[m],
                           n_plane[m])

    def _drain_k1(self, budget, on_chunk) -> np.ndarray:
        """The specialized single-machine drain body on the K = 1 slice."""
        ss1 = jax.tree.map(lambda a: a[0], self._ss)
        trained, budget1 = 0, int(budget[0])
        monitor = on_chunk is not None
        while trained < budget1:
            want = min(self.chunk, budget1 - trained)
            self._keys, chunk_keys = _advance_keys(
                self._keys, jnp.ones((1,), bool)
            )
            ss1, n, aux = online_mod._consume_many(
                self.cfg, self.chunk, ss1, self.rt,
                jnp.int32(want), chunk_keys[0], monitor=monitor,
            )
            n = int(n)
            trained += n
            # commit state + mirror before the callback (see drain())
            self._ss = jax.tree.map(lambda a: a[None], ss1)
            with self.router.lock:
                self._dev_size[0] -= n
            if monitor and n:
                on_chunk(jax.tree.map(lambda a: a[None], aux))
            if n < want:  # buffer drained before the budget ran out
                break
        return np.asarray([trained], dtype=np.int64)

    # -- inference ----------------------------------------------------------

    def serve(self, xs, *, budget=None, return_aux: bool = False):
        """Fleet inference [K, B]: every member's batch in ONE contraction.

        ``xs`` is [B, f] (the same batch served by all members) or
        [K, B, f] (one batch per member). Packed services pack the batch
        here and serve it through the AND+popcount kernels, bit-identically.

        ``budget`` (fraction of clauses, (0, 1]) routes the request
        through the runtime-tunable path (DESIGN.md §16): only the top-m
        ranked clauses per class are contracted, with the configured
        weights/early-exit applied. Requires ``ServiceConfig(tunable=...)``
        and a prior :meth:`calibrate`. Without an explicit budget, a
        tunable service serves at the controller's live budget (plain
        path when that is 1.0 with unit weights and no early exit).
        ``return_aux`` additionally returns the
        :class:`~repro.serve.tunable.ServeAux` (elected clause ids +
        per-request evaluated counts) — tunable path only.

        A residency service cannot serve the whole fleet in one
        contraction (only ``resident`` machines are on device) — use
        :meth:`serve_replicas` to name the members a request targets.
        """
        xs = self._ingest(xs)
        with self._device_lock:
            if self._res is not None:
                raise ValueError(
                    "TMService.serve needs the whole fleet device-resident, "
                    f"but ServiceConfig(resident={self.sc.resident}) < "
                    f"replicas={self.n_replicas} spills part of it: use "
                    "serve_replicas(replicas, xs) to serve named members "
                    "(activated on demand), or raise the 'resident' knob to "
                    "cover the fleet"
                )
            tunable = budget is not None or (
                self.tuner is not None and self.tuner.active
            )
            if not tunable:
                if return_aux:
                    raise ValueError(
                        "return_aux reports the budgeted path's compute — "
                        "pass a budget (or configure an active tunable)"
                    )
                if xs.ndim == 2 and self._k1:
                    tm1 = jax.tree.map(lambda a: a[0], self._ss.tm)
                    return np.asarray(
                        tm_mod.predict_batch(self.cfg, tm1, self.rt, xs)
                    )[None]
                if xs.ndim == 2:
                    # D = 1: one shared stream, factored (stored once)
                    xs = xs[None]
                return np.asarray(tm_mod.predict_batch_replicated(
                    self.cfg, self._ss.tm, self.rt, xs
                ))
            tuner = self._require_tuner()
            preds, aux = self._serve_tunable(
                self._ss.tm, xs, tuner.order, tuner.weights, budget
            )
            return (preds, aux) if return_aux else preds

    def _require_tuner(self) -> tun_mod.TuneController:
        if self.tuner is None:
            raise ValueError(
                "budgeted serving needs ServiceConfig(tunable=TunableConfig"
                "(...)) — this service was built without it"
            )
        if not self.tuner.calibrated:
            raise ValueError(
                "budgeted serving needs clause ranks: call calibrate() "
                "(after training) before serving with a budget"
            )
        return self.tuner

    def _serve_tunable(
        self, tm_plane, xs, order, weights, budget
    ) -> tuple[np.ndarray, tun_mod.ServeAux]:
        """The budgeted serve body on an already-gathered device plane.
        ``order``/``weights`` rows must align with the plane's rows."""
        tc = self.sc.tunable
        b = self.tuner.budget if budget is None else float(budget)
        m = tun_mod.m_for_budget(b, self.cfg.max_clauses)
        if xs.ndim == 2:
            xs = xs[None]     # D = 1: one shared stream
        preds, evaluated = tun_mod.predict_pruned_replicated_host(
            self.cfg, tm_plane, self.rt, xs, order, weights, m,
            group=tc.group if tc.early_exit else None,
        )
        aux = tun_mod.ServeAux(
            budget=b, m=m, sel=order[:, :, :m].copy(), evaluated=evaluated
        )
        return preds, aux

    def serve_replicas(self, replicas, xs, *, budget=None,
                       return_aux: bool = False):
        """Inference for the NAMED replicas only: [n, B] predictions.

        ``xs`` is [B, f] (one batch shared by the named members) or
        [n, B, f] (one per member). Under residency, evicted members are
        activated in cohorts of at most ``resident`` (LRU-spilling as
        needed), so a K=4096 fleet serves any subset on bounded device
        memory; predictions are bit-identical to an always-resident
        fleet's (prediction never touches the s/T ports, so the gathered
        sub-plane contraction is exact).

        ``budget``/``return_aux`` as in :meth:`serve` — each named member
        serves from its OWN calibrated ranking (rankings are host-side
        per-replica state, so they survive eviction; the cohort gather
        reads them by replica id, not by slot).
        """
        xs = self._ingest(xs)
        rids = np.asarray(replicas, dtype=np.int64).reshape(-1)
        shared = xs.ndim == 2
        cap = self.n_resident
        tunable = budget is not None or (
            self.tuner is not None and self.tuner.active
        )
        if return_aux and not tunable:
            raise ValueError(
                "return_aux reports the budgeted path's compute — pass a "
                "budget (or configure an active tunable)"
            )
        tuner = self._require_tuner() if tunable else None
        outs, auxes = [], []
        with self._device_lock:
            for i in range(0, len(rids), cap):
                cohort = rids[i:i + cap]
                slots = (cohort if self._res is None
                         else self._ensure_resident(cohort))
                tm_c = jax.tree.map(lambda a: a[jnp.asarray(slots)],
                                    self._ss.tm)
                xs_c = xs[None] if shared else xs[i:i + cap]
                if not tunable:
                    outs.append(np.asarray(tm_mod.predict_batch_replicated(
                        self.cfg, tm_c, self.rt, xs_c
                    )))
                    continue
                w_c = (None if tuner.weights is None
                       else tuner.weights[cohort])
                preds, aux = self._serve_tunable(
                    tm_c, xs_c, tuner.order[cohort], w_c, budget
                )
                outs.append(preds)
                auxes.append(aux)
        preds = np.concatenate(outs, axis=0)
        if not return_aux:
            return preds
        aux = tun_mod.ServeAux(
            budget=auxes[0].budget, m=auxes[0].m,
            sel=np.concatenate([a.sel for a in auxes], axis=0),
            evaluated=np.concatenate([a.evaluated for a in auxes], axis=0),
        )
        return preds, aux

    def calibrate(self, xs=None, ys=None) -> np.ndarray:
        """Rank every replica's clauses from a calibration set (default:
        the eval set); derives integer vote weights when the tunable
        config asks for them. Returns the [K, C, J] score plane.

        Under residency the fleet calibrates in cohorts of at most
        ``resident`` slots (evicted members activate transparently, like
        the analysis sweep) — ranks land host-side per replica either
        way. Recalibrate whenever the banks have drifted enough that the
        ranking should follow (e.g. after offline_train or a long online
        phase); serving between calibrations just uses the older ranks.
        """
        if self.tuner is None:
            raise ValueError(
                "calibrate needs ServiceConfig(tunable=TunableConfig(...))"
            )
        xs = self.eval_x if xs is None else self._ingest(xs)
        ys = self.eval_y if ys is None else jnp.asarray(ys, jnp.int32)
        if xs is None or ys is None:
            raise ValueError(
                "calibrate needs a labelled set: pass (xs, ys) or build "
                "the service with eval_x/eval_y"
            )
        K = self.n_replicas
        C, J = self.cfg.max_classes, self.cfg.max_clauses
        scores = np.zeros((K, C, J), dtype=np.int32)
        with self._device_lock:
            if self._res is None:
                if self._k1:
                    tm1 = jax.tree.map(lambda a: a[0], self._ss.tm)
                    scores[0] = np.asarray(tun_mod.clause_scores(
                        self.cfg, tm1, self.rt, xs, ys
                    ))
                else:
                    scores[:] = np.asarray(tun_mod.clause_scores_replicated(
                        self.cfg, self._ss.tm, self.rt, xs[None], ys[None]
                    ))
            else:
                for i in range(0, K, self.n_resident):
                    cohort = np.arange(i, min(i + self.n_resident, K))
                    slots = self._ensure_resident(cohort)
                    tm_c = jax.tree.map(lambda a: a[jnp.asarray(slots)],
                                        self._ss.tm)
                    scores[cohort] = np.asarray(
                        tun_mod.clause_scores_replicated(
                            self.cfg, tm_c, self.rt, xs[None], ys[None]
                        ))
            self.tuner.set_ranking(
                tun_mod.rank_from_scores(
                    scores, np.asarray(tm_mod.clause_polarity(self.cfg))
                ),
                tun_mod.weights_from_scores(
                    scores, self.sc.tunable.weight_bits
                ),
                score=scores,
            )
        return scores

    # -- analysis + the Fig-3 policy loop -----------------------------------

    def analyze(self) -> np.ndarray:
        """Eval accuracy of every member in ONE contraction. [K] f32.

        Under residency only the device-resident members measure; evicted
        members read nan (``activate`` them first for a full sweep — the
        policy loop does exactly that for its due members)."""
        if self.eval_x is None:
            raise ValueError("TMService built without an eval set")
        with self._device_lock:
            acc = self._measure()
            self.history.append((self.steps, acc))
            if self.sc.history_limit is not None:
                del self.history[:-self.sc.history_limit]
            return acc

    def _measure(self) -> np.ndarray:
        """One eval contraction over the device plane; [K] f32 (nan for
        evicted replicas). No history side effects."""
        if self._k1:
            tm1 = jax.tree.map(lambda a: a[0], self._ss.tm)
            # same [K] f32 contract as the K > 1 path
            return np.asarray([float(acc_mod.analyze(
                self.cfg, tm1, self.rt, self.eval_x, self.eval_y
            ))], dtype=np.float32)
        acc_p = np.asarray(acc_mod.analyze_replicated(
            self.cfg, self._ss.tm, self.rt,
            self.eval_x[None], self.eval_y[None],  # D = 1: shared
        ))
        if self._res is None:
            return acc_p
        acc = np.full(self.n_replicas, np.nan, dtype=np.float32)
        m = self._res.replica_of >= 0
        acc[self._res.replica_of[m]] = acc_p[m]
        return acc

    def offline_train(self, xs, ys, n_epochs: int = 10,
                      seed: int = 1) -> np.ndarray:
        """Offline phase for the whole fleet (one replicated epochs scan);
        the result becomes every member's known-good baseline."""
        xs = jnp.asarray(xs, dtype=bool)
        ys = jnp.asarray(ys, dtype=jnp.int32)
        with self._device_lock:
            if self._res is not None:
                raise ValueError(
                    "offline_train needs the full fleet device-resident; "
                    "train a full-resident service (or a single machine) "
                    "first, then construct the residency service from its "
                    "state"
                )
            return self._offline_train_locked(xs, ys, n_epochs, seed)

    def _offline_train_locked(self, xs, ys, n_epochs, seed) -> np.ndarray:
        if self._k1:
            tm1 = jax.tree.map(lambda a: a[0], self._ss.tm)
            st = fb_mod.train_epochs(
                self.cfg, tm1, self.rt, xs, ys,
                jax.random.PRNGKey(seed), n_epochs,
            )
            st = jax.tree.map(lambda a: a[None], st)
        else:
            st = fb_mod.train_epochs_replicated(
                self.cfg, self._ss.tm, self.rt, xs[None], ys[None],
                jax.random.PRNGKey(seed)[None], n_epochs,
            )
        self._ss = self._ss._replace(tm=st)
        acc = self.analyze()
        self.policy.snapshot(self._ps, acc, st)
        return acc

    def _maybe_analyze(self) -> Optional[tuple[np.ndarray, np.ndarray]]:
        """Run analysis + the §5.3.2 policy if any member is due.
        Returns (accuracies [K], rolled-back mask [K]) or None."""
        if self.eval_x is None:
            return None
        due = self.policy.due(self._ps)
        if not due.any():
            return None
        if self._res is not None:
            return self._analyze_residency(due)
        acc = self.analyze()
        tm, rolled = self.policy.apply(self._ps, due, acc, self._ss.tm)
        self._ss = self._ss._replace(tm=tm)
        return acc, rolled

    def _analyze_residency(self, due) -> tuple[np.ndarray, np.ndarray]:
        """The §5.3.2 transition under residency: measure the due members
        (activating evicted ones cohort by cohort), append ONE history
        entry, then run the policy FSM with the known-good banks living
        host-side (one [K, ...] numpy array — ``_best_host`` — instead of
        a device-resident snapshot tree)."""
        acc = self._measure()
        missing = due & np.isnan(acc)
        while missing.any():
            ids = np.nonzero(missing)[0][: self.n_resident]
            self._ensure_resident(ids)
            fresh = self._measure()
            acc = np.where(np.isnan(acc), fresh, acc).astype(np.float32)
            missing = due & np.isnan(acc)
        self.history.append((self.steps, acc))
        if self.sc.history_limit is not None:
            del self.history[:-self.sc.history_limit]
        rolled = self._policy_apply_residency(due, acc)
        return acc, rolled

    def _policy_apply_residency(self, due, acc) -> np.ndarray:
        """AdaptPolicy.apply's FSM on host-side known-good banks. The
        transition rules are identical (same since/best/collapse/improve
        algebra on the [K] arrays); only the snapshot storage differs —
        scatters into ``_best_host`` on improve, per-replica bank writes
        (device slot or spilled snapshot) on collapse."""
        ps, pol = self._ps, self.policy
        ps.since[due] = 0
        measured = due & ~np.isnan(acc)
        have_best = ~np.isnan(ps.best)
        collapse = measured & have_best & (
            acc < ps.best - pol.rollback_threshold)
        improve = measured & (~have_best | (acc > ps.best))
        if collapse.any():
            for rid in np.nonzero(collapse)[0]:
                self._write_bank(int(rid), self._best_host[rid])
            ps.rollbacks += collapse
        if improve.any():
            if self._best_host is None:
                ta = self._ss.tm.ta_state
                self._best_host = np.zeros(
                    (self.n_replicas,) + tuple(ta.shape[1:]),
                    dtype=np.dtype(ta.dtype),
                )
            for rid in np.nonzero(improve)[0]:
                self._best_host[rid] = self._read_bank(int(rid))
            ps.best = np.where(improve, acc, ps.best)
        return collapse

    def _read_bank(self, rid: int) -> np.ndarray:
        self._settle_spills()
        slot = int(self._res.slot_of[rid])
        if slot >= 0:
            return np.asarray(self._ss.tm.ta_state[slot])
        return np.asarray(self._res.store[rid][0].tm.ta_state)

    def _write_bank(self, rid: int, bank) -> None:
        self._settle_spills()
        slot = int(self._res.slot_of[rid])
        if slot >= 0:
            ta = self._ss.tm.ta_state
            self._ss = self._ss._replace(tm=TMState(
                ta_state=ta.at[slot].set(jnp.asarray(bank, ta.dtype))
            ))
        else:
            ss_s, key_s = self._res.store[rid]
            self._res.store[rid] = (
                ss_s._replace(tm=TMState(ta_state=np.array(bank))),
                key_s,
            )

    def tick(
        self,
        max_points=None,
        on_chunk: Optional[Callable[[ChunkAux], None]] = None,
    ) -> TickReport:
        """One Fig-3 consumer cycle: flush ingress, drain up to
        ``max_points`` (default: one chunk) per replica, advance the
        analysis cadence, and apply the mitigation policy to due members.
        """
        budget = self.chunk if max_points is None else max_points
        with self._device_lock:
            trained = self.drain(budget, on_chunk)
            self._ps.since += trained
            if self._auto:
                target = self._res.autotune_target(granule=self._granule)
                if target != self.n_resident:
                    self._repartition(target)
            out = self._maybe_analyze()
            if self.tuner is not None and self.sc.tunable.adapt:
                # SLO pressure valve (§16): post-drain queue depth is the
                # observed backlog — deep queues shed serve compute, light
                # queues restore it (never above the configured budget).
                self.tuner.update(self.buffered)
        if out is None:
            return TickReport(trained, None,
                              np.zeros(self.n_replicas, dtype=bool))
        return TickReport(trained, out[0], out[1])

    def observe_rows(self, xs, ys, mask=None) -> Optional[np.ndarray]:
        """The legacy managers' per-point FSM step: one labelled datapoint
        per (masked) replica, drain-retry backpressure, one chunk-budget
        drain, then cadence/analysis/rollback. Returns [K] eval accuracies
        when at least one member hit its cadence, None otherwise.

        Drained points advance each member's OWN cadence counter — a
        backpressure drain's points still count toward the analysis
        cadence, exactly like the pre-redesign managers.
        """
        K = self.n_replicas
        mask = (np.ones(K, dtype=bool) if mask is None
                else np.asarray(mask, dtype=bool))
        with self._device_lock:
            accepted = self.submit_rows(xs, ys, mask)
            retry = mask & ~accepted
            if retry.any():
                # Backpressure: drain a chunk fleet-wide, then retry once.
                self._ps.since += self.drain(self.chunk)
                accepted = self.submit_rows(xs, ys, retry)
                self._ps.lost += retry & ~accepted
            self._ps.since += self.drain(self.chunk)
            out = self._maybe_analyze()
        return None if out is None else out[0]

    # -- durable state (save / restore; DESIGN.md §15) ----------------------

    def save(self, directory: str, *, step: Optional[int] = None,
             keep: int = 3) -> str:
        """Write the FULL consumer-side state as one atomic checkpoint
        (train/checkpoint.py layout): TA banks, ring buffers, step
        counters, RNG keys, the §5.3.2 policy FSM including the
        known-good banks, the analysis history and the router's loss
        counters. Staged ingress flushes first, so every accepted
        datapoint is either in a saved ring buffer or already consumed —
        save -> restore -> continue is bitwise identical to never
        stopping. Residency services save the ASSEMBLED full-K logical
        fleet: the checkpoint is residency-agnostic and restores under
        any ``resident`` budget (migration across device budgets).
        Returns the checkpoint path."""
        with self._device_lock:
            self.flush()
            ss_K, keys_K = self._assemble_plane()
            ps = self._ps
            if self._res is not None:
                best = (None if self._best_host is None
                        else TMState(ta_state=self._best_host))
            else:
                best = ps.best_state
            if self.history:
                hsteps = np.stack([np.asarray(h[0]) for h in self.history])
                haccs = np.stack([np.asarray(h[1]) for h in self.history])
            else:
                hsteps = np.zeros((0, self.n_replicas), dtype=np.int32)
                haccs = np.zeros((0, self.n_replicas), dtype=np.float32)
            with self.router.lock:
                router_state = {
                    "dropped": self.router.dropped.copy(),
                    "flushes": np.int64(self.router.flushes),
                }
            tree = {
                "ss": ss_K,
                "keys": keys_K,
                "rt": jax.tree.map(np.asarray, self.rt),
                "policy": {
                    "since": ps.since, "best": ps.best,
                    "rollbacks": ps.rollbacks, "lost": ps.lost,
                    "best_state": best,
                },
                "router": router_state,
                "history": {"steps": hsteps, "acc": haccs},
            }
            has_tun = self.tuner is not None and self.tuner.calibrated
            if has_tun:
                tree["tunable"] = {
                    "order": self.tuner.order,
                    "score": self.tuner.score,
                    "weights": self.tuner.weights,  # None when unit
                }
            extra = {
                "service": self._service_manifest(),
                "has_best_state": best is not None,
                "has_tunable": has_tun,
                "tunable_weighted": has_tun and self.tuner.weights is not None,
                "tunable_scored": has_tun and self.tuner.score is not None,
                "tunable_budget": (float(self.tuner.budget)
                                   if self.tuner is not None else None),
            }
            if step is None:
                step = int(self.steps.max(initial=0))
            return ckpt_mod.save(directory, int(step), tree, keep=keep,
                                 extra=extra)

    def _service_manifest(self) -> dict:
        """JSON-able construction knobs — enough for :meth:`restore` to
        rebuild the service without the caller knowing them."""
        sc = self.sc

        def plain(v):
            if v is None or isinstance(v, (bool, int, float, str)):
                return v
            return np.asarray(v).tolist()

        return {
            "cfg": dataclasses.asdict(self.cfg),
            "replicas": sc.replicas,
            "buffer_capacity": sc.buffer_capacity,
            "chunk": sc.chunk,
            "ingress_block": sc.ingress_block,
            "packed": sc.packed,
            "history_limit": sc.history_limit,
            "s": plain(sc.s),
            "T": plain(sc.T),
            "seed": plain(sc.seed),
            "resident": sc.resident,
            "policy": {
                "analyze_every": self.policy.analyze_every,
                "rollback_threshold": self.policy.rollback_threshold,
            },
            "tunable": (None if sc.tunable is None
                        else dataclasses.asdict(sc.tunable)),
        }

    def load(self, directory: str, *, step: Optional[int] = None) -> None:
        """Restore a :meth:`save` checkpoint INTO this service. The
        service must structurally match the writer (same TMConfig,
        replicas, capacity, packing — :meth:`restore` guarantees that);
        the ``resident`` budget may differ. Anything staged or held now
        is discarded: the checkpoint defines the complete state."""
        with self._device_lock:
            # settle pending spills BEFORE the install clears the store —
            # a stale deferred snapshot must never land in the fresh one
            self._settle_spills()
            while self.router.take_block() is not None:
                pass  # drop staged rows (pre-restore traffic)
            man = ckpt_mod.read_manifest(directory, step=step)
            meta = man["extra"]["service"]
            if meta["replicas"] != self.n_replicas:
                raise ValueError(
                    f"checkpoint carries {meta['replicas']} replicas, "
                    f"this service has {self.n_replicas}"
                )
            if bool(meta["packed"]) != bool(self.sc.packed):
                raise ValueError(
                    "checkpoint and service disagree on the packed "
                    "datapath — ring-buffer rows are not interchangeable"
                )
            has_best = bool(man["extra"].get("has_best_state"))
            has_tun = bool(man["extra"].get("has_tunable"))
            template = {
                "ss": self._ss,
                "keys": 0,
                "rt": self.rt,
                "policy": {
                    "since": 0, "best": 0, "rollbacks": 0, "lost": 0,
                    "best_state": (TMState(ta_state=0) if has_best
                                   else None),
                },
                "router": {"dropped": 0, "flushes": 0},
                "history": {"steps": 0, "acc": 0},
            }
            if has_tun:
                template["tunable"] = {
                    "order": 0,
                    "score": (0 if man["extra"].get("tunable_scored")
                              else None),
                    "weights": (0 if man["extra"].get("tunable_weighted")
                                else None),
                }
            tree, man = ckpt_mod.restore(directory, template, step=step,
                                         device=False)
            self.rt = jax.tree.map(jnp.asarray, tree["rt"])
            pol = tree["policy"]
            self._ps = _PolicyState(
                since=np.asarray(pol["since"], dtype=np.int64),
                best=np.asarray(pol["best"], dtype=np.float64),
                rollbacks=np.asarray(pol["rollbacks"], dtype=np.int64),
                lost=np.asarray(pol["lost"], dtype=np.int64),
            )
            self._best_host = None
            if has_best:
                bank_K = np.asarray(pol["best_state"].ta_state)
                if self._res is not None:
                    self._best_host = bank_K
                else:
                    bs = TMState(ta_state=jnp.asarray(bank_K))
                    if self.mesh is not None:
                        sh = shard_mod.replica_shardings(
                            bs, self.mesh, n_replicas=self.n_replicas
                        )
                        bs = jax.tree.map(jax.device_put, bs, sh)
                    self._ps.best_state = bs
            hsteps, haccs = tree["history"]["steps"], tree["history"]["acc"]
            self.history = [
                (np.asarray(hsteps[i]), np.asarray(haccs[i]))
                for i in range(len(hsteps))
            ]
            if self.tuner is not None:
                # Ranks are per-replica durable state (§16): a calibrated
                # checkpoint restores them; an uncalibrated one resets the
                # controller (the checkpoint defines the complete state).
                if has_tun:
                    tun = tree["tunable"]
                    self.tuner.set_ranking(
                        np.asarray(tun["order"], dtype=np.int32),
                        (None if tun["weights"] is None
                         else np.asarray(tun["weights"], dtype=np.int32)),
                        score=(None if tun["score"] is None
                               else np.asarray(tun["score"],
                                               dtype=np.int32)),
                    )
                else:
                    self.tuner.order = None
                    self.tuner.weights = None
                    self.tuner.score = None
                saved_b = man["extra"].get("tunable_budget")
                if saved_b is not None:
                    self.tuner.budget = float(saved_b)
            ss_K, keys_K = tree["ss"], tree["keys"]
            with self.router.lock:
                self.router.dropped[:] = np.asarray(
                    tree["router"]["dropped"])
                self.router.flushes = int(tree["router"]["flushes"])
                self._dev_size = np.asarray(
                    ss_K.buf.size, dtype=np.int64
                ).reshape(self.n_replicas).copy()
            self._install_plane(ss_K, keys_K)

    def _install_plane(self, ss_K: SessionState, keys_K) -> None:
        """Install a full-K logical (SessionState, keys) host tree. Under
        residency the fleet re-partitions deterministically — replicas
        0..resident-1 take the slots, the rest spill — which is invisible
        to trajectories (activation is transparent)."""
        if self._res is None:
            plane = (jax.tree.map(jnp.asarray, ss_K), jnp.asarray(keys_K))
            if self.mesh is not None:
                sh = shard_mod.replica_shardings(
                    plane, self.mesh, n_replicas=self.n_replicas
                )
                plane = jax.tree.map(jax.device_put, plane, sh)
            self._ss, self._keys = plane
            return
        K, R = self.n_replicas, self.n_resident
        res = self._res
        res.store.clear()
        res.slot_of[:] = -1
        res.replica_of[:] = -1
        res.last_use[:] = 0
        host = jax.tree.map(np.asarray, (ss_K, keys_K))
        dev = jax.tree.map(lambda a: jnp.asarray(a[:R]), host)
        if self.mesh is not None:
            sh = shard_mod.replica_shardings(dev, self.mesh, n_replicas=R)
            dev = jax.tree.map(jax.device_put, dev, sh)
        self._ss, self._keys = dev
        res.assign(np.arange(R), np.arange(R))
        for rid in range(R, K):
            res.store[rid] = jax.tree.map(lambda a, _r=rid: a[_r], host)

    def _repartition(self, new_r: int) -> None:
        """Resize the device plane to ``new_r`` slots (§17
        auto-residency). The full-K logical fleet assembles host-side, a
        fresh residency map takes over at the new width, and
        :meth:`_install_plane` re-lands it — the same machinery that
        migrates checkpoints across device budgets, which is the proof
        that partitioning is not logical state: trajectories are
        bitwise unchanged across re-partitions."""
        ss_K, keys_K = self._assemble_plane()   # settles pending spills
        old = self._res
        self.n_resident = int(new_r)
        res = res_mod.ResidencyMap(self.n_replicas, self.n_resident)
        # lifetime counters and the autotune EWMA survive the resize;
        # the LRU clock and assignment restart deterministically
        res.activations = old.activations
        res.evictions = old.evictions
        res.ewma_active = old.ewma_active
        self._res = res
        self.repartitions += 1
        self._install_plane(ss_K, keys_K)

    @classmethod
    def restore(
        cls,
        directory: str,
        *,
        step: Optional[int] = None,
        mesh: Optional[Mesh] = None,
        eval_x=None,
        eval_y=None,
        resident: Union[int, None, str] = "saved",
    ) -> "TMService":
        """Rebuild a service from a :meth:`save` checkpoint: construction
        knobs come from the manifest, arrays from the npz. ``mesh`` and
        the eval set are runtime resources (not serialized) and are
        passed fresh; ``resident`` defaults to the saved budget and may
        be overridden (including to None) to migrate a fleet across
        device budgets — the checkpoint itself is residency-agnostic."""
        man = ckpt_mod.read_manifest(directory, step=step)
        meta = man["extra"]["service"]
        cfg = TMConfig(**meta["cfg"])
        sc = ServiceConfig(
            replicas=meta["replicas"],
            buffer_capacity=meta["buffer_capacity"],
            chunk=meta["chunk"],
            ingress_block=meta["ingress_block"],
            packed=meta["packed"],
            history_limit=meta["history_limit"],
            s=meta["s"],
            T=meta["T"],
            policy=AdaptPolicy(**meta["policy"]),
            seed=meta["seed"],
            mesh=mesh,
            resident=(meta["resident"] if resident == "saved"
                      else resident),
            tunable=(None if meta.get("tunable") is None
                     else tun_mod.TunableConfig(**meta["tunable"])),
        )
        svc = cls(cfg, tm_mod.init_state(cfg), sc,
                  eval_x=eval_x, eval_y=eval_y)
        svc.load(directory, step=step)
        return svc

    # -- observability ------------------------------------------------------

    @property
    def steps(self) -> np.ndarray:
        if self._res is None:
            return np.asarray(self._ss.step)
        self._settle_spills()
        out = np.zeros(self.n_replicas, dtype=np.int32)
        step_p = np.asarray(self._ss.step)
        m = self._res.replica_of >= 0
        out[self._res.replica_of[m]] = step_p[m]
        for rid, snap in self._res.store.items():
            out[rid] = snap[0].step
        return out

    @property
    def rng_keys(self) -> np.ndarray:
        """Per-replica RNG keys, full-K host view (raw uint32 key data)."""
        if self._res is None:
            return np.asarray(self._keys)
        _, keys_K = self._assemble_plane()
        return keys_K

    @property
    def rollbacks(self) -> np.ndarray:
        return self._ps.rollbacks

    @property
    def lost(self) -> np.ndarray:
        return self._ps.lost

    @property
    def since_analysis(self) -> np.ndarray:
        return self._ps.since
