"""TMService: the one fleet-native serving surface (a single machine is K=1).

The paper's deliverable is a managed serving *system* — Fig. 3's
offer -> cyclic buffer -> interleaved train/infer loop with the §5.3.2
mitigation policy — and MATADOR (arXiv 2403.10538) plus the
runtime-tunable eFPGA TM (arXiv 2502.07823) both show the multi-instance
form winning on ONE clean control interface with per-instance
hyperparameters. :class:`TMService` is that interface here:

* ``submit`` / ``submit_rows`` — labelled traffic, staged host-side by a
  :class:`~repro.serve.router.BatchRouter` and flushed as packed
  ``[K, B_ingress]`` row-batches (one jitted dispatch per flush, not one
  per datapoint).
* ``serve`` — fleet inference, one replica-first clause contraction.
* ``tick`` — the Fig-3 consumer cycle: flush ingress, drain each
  replica's budget through online training, advance the analysis cadence
  and apply the §5.3.2 policy (:class:`AdaptPolicy`, per replica).

Everything that used to be two parallel APIs — ``OnlineSession`` /
``TMOnlineAdaptManager`` (scalar) vs ``OnlineFleet`` /
``TMFleetAdaptManager`` (``[K]``) — is now a thin shim over this class;
the K = 1 slice reproduces the scalar semantics bit for bit (pinned by
tests/test_service.py against oracles transcribed from the pre-redesign
implementations). K = 1 with scalar runtime ports additionally keeps the
specialized single-machine drain body (`online._consume_many`; the
replicated plane costs ~1.3x at R = 1, DESIGN.md §10), which the same
parity suite pins bitwise against the replicated path.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import accuracy as acc_mod
from repro.core import feedback as fb_mod
from repro.core import online as online_mod
from repro.core import tm as tm_mod
from repro.core.online import ChunkAux, SessionState
from repro.core.tm import TMConfig, TMRuntime, TMState, init_runtime
from repro.data import buffer as buf_mod
from repro.distributed import sharding as shard_mod
from repro.kernels import packing
from repro.serve import router as router_mod


@jax.jit
def _advance_keys(keys, active):
    """Split every ACTIVE replica's RNG key; retired replicas keep theirs.

    Returns (new persistent keys [K], chunk keys [K]). One jitted dispatch
    per chunk — a replica's key splits exactly once per chunk it
    participates in, matching a standalone session's per-chunk split (the
    chunk keys handed to retired replicas are unused: their row budget for
    the chunk is 0, so no state is touched).
    """
    k2 = jax.vmap(jax.random.split)(keys)               # [K, 2, key]
    return jnp.where(active[:, None], k2[:, 0], keys), k2[:, 1]


def _select_replicas(mask, new: TMState, old: TMState) -> TMState:
    """Per-replica tree select: replica r takes ``new`` where mask[r]."""
    gate = online_mod.replica_gate(jnp.asarray(mask))
    return jax.tree.map(gate, new, old)


# ---------------------------------------------------------------------------
# The Fig-3 FSM (§5.3.2 mitigation policy), once, on [K] arrays.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _PolicyState:
    """Host-side FSM state of :class:`AdaptPolicy`, all per replica."""

    since: np.ndarray          # [K] i64 — points consumed since last analysis
    best: np.ndarray           # [K] f64 — best known accuracy (nan = none yet)
    rollbacks: np.ndarray      # [K] i64 — §5.3.2 rollbacks fired
    lost: np.ndarray           # [K] i64 — datapoints lost even after retry
    best_state: Optional[TMState] = None   # replicated [K, ...] snapshot


@dataclasses.dataclass
class AdaptPolicy:
    """The §5.3.2 mitigation policy: periodic analysis + rollback, per replica.

    ONE implementation on ``[K]`` arrays — K = 1 yields exactly the old
    scalar ``TMOnlineAdaptManager`` semantics, K > 1 the old
    ``TMFleetAdaptManager`` semantics (both shims now delegate here; the
    ~200 duplicated FSM lines are gone). A member that consumed
    ``analyze_every`` points since its last analysis is *due*: its eval
    accuracy is re-measured, and it rolls back to its own known-good TA
    bank on a drop past ``rollback_threshold`` — or snapshots a new best.
    Members that are not due are never touched.
    """

    analyze_every: int = 32           # online datapoints between analyses
    rollback_threshold: float = 0.1   # absolute accuracy drop -> rollback

    def init(self, n_replicas: int) -> _PolicyState:
        K = n_replicas
        return _PolicyState(
            since=np.zeros(K, dtype=np.int64),
            best=np.full(K, np.nan),
            rollbacks=np.zeros(K, dtype=np.int64),
            lost=np.zeros(K, dtype=np.int64),
        )

    def due(self, ps: _PolicyState) -> np.ndarray:
        return ps.since >= self.analyze_every

    def apply(self, ps: _PolicyState, due: np.ndarray, acc: np.ndarray,
              tm: TMState) -> tuple[TMState, np.ndarray]:
        """One policy transition for the due members. Returns
        (new TA banks, rolled-back mask [K])."""
        ps.since[due] = 0
        have_best = ~np.isnan(ps.best)
        collapse = due & have_best & (acc < ps.best - self.rollback_threshold)
        improve = due & (~have_best | (acc > ps.best))
        if collapse.any():
            # §5.3.2 per replica: restore collapsed members' known-good
            # TA banks; healthy members keep serving untouched.
            tm = _select_replicas(collapse, ps.best_state, tm)
            ps.rollbacks += collapse
        if improve.any():
            ps.best = np.where(improve, acc, ps.best)
            ps.best_state = _select_replicas(improve, tm, ps.best_state)
        return tm, collapse

    def snapshot(self, ps: _PolicyState, acc: np.ndarray, tm: TMState):
        """Unconditional known-good snapshot (the offline-train baseline)."""
        ps.best = np.asarray(acc, dtype=np.float64).copy()
        ps.best_state = tm


class TickReport(NamedTuple):
    """What one :meth:`TMService.tick` did, per replica."""

    trained: np.ndarray                 # [K] i64 — points consumed
    accuracy: Optional[np.ndarray]      # [K] f32 — eval accs, None if not due
    rolled_back: np.ndarray             # [K] bool — §5.3.2 rollbacks fired


@dataclasses.dataclass
class ServiceConfig:
    """Construction-time knobs of a :class:`TMService`.

    ``s``/``T`` ride the runtime's per-replica hyperparameter ports:
    scalars give a homogeneous fleet, length-K sequences give every member
    its own (s, T) without re-JIT. ``ingress_block`` is B_ingress — the
    router's staged rows per replica per flushed dispatch.

    ``packed`` switches the whole boolean datapath to the bit-packed
    uint32 representation (DESIGN.md §13): rows pack host-side at the
    router's staging boundary, the ring buffers store ceil(f/32) words
    per datapoint (~8x less ingress/buffer traffic), and every
    inference/analysis/monitoring pass runs the AND+popcount clause
    kernels. Served predictions, drained TA states and analysis
    accuracies are bit-identical to the unpacked path (which stays the
    parity oracle — pinned by tests/test_scale.py).

    ``history_limit`` bounds the analysis ``history`` list to its most
    recent N entries — a long-running service analyzing on cadence would
    otherwise grow it without bound (a memory leak at traffic scale).
    None keeps the legacy unbounded behavior.
    """

    replicas: int = 1
    buffer_capacity: int = 64
    chunk: int = 16                   # datapoints drained per jitted call
    ingress_block: int = 32           # staged rows per replica per flush
    packed: bool = False              # bit-packed datapath (DESIGN.md §13)
    history_limit: Optional[int] = None   # analysis entries kept (None = all)
    s: Union[float, Sequence[float], None] = None
    T: Union[int, Sequence[int], None] = None
    policy: AdaptPolicy = dataclasses.field(default_factory=AdaptPolicy)
    seed: Union[int, Sequence[int]] = 0
    mesh: Optional[Mesh] = None

    def runtime(self, cfg: TMConfig) -> TMRuntime:
        """A fault-free runtime with this config's s/T ports."""
        rt = init_runtime(cfg)
        for name, port, dtype in (("s", self.s, jnp.float32),
                                  ("T", self.T, jnp.int32)):
            if port is None:
                continue
            if np.ndim(port) == 0:
                rt = rt._replace(**{name: dtype(port)})
            else:
                if len(port) != self.replicas:
                    raise ValueError(
                        f"per-replica {name} carries {len(port)} entries, "
                        f"expected {self.replicas}"
                    )
                rt = rt._replace(**{name: jnp.asarray(port, dtype)})
        return rt


class TMService:
    """K concurrent Fig-3 machines behind one control surface (K >= 1).

    Device layout is the replicated kernel contract (DESIGN.md §9/§10):
    every member owns its data stream, so state, buffers, budgets and RNG
    keys all lead with K, per-replica hyperparameters ride the runtime's
    ``s``/``T`` ports, and each drain chunk advances the whole fleet in
    ONE ``_consume_many_replicated`` call. Ingress is the
    :class:`~repro.serve.router.BatchRouter` staging queue — ``submit`` is
    a host-side numpy write; the device sees packed ``[K, B_ingress]``
    blocks.

    ``state`` may be a single machine's :class:`TMState` (broadcast to K
    identical banks) or an already-replicated ``[K, ...]`` state. ``rt``
    overrides the runtime built from ``sc.s``/``sc.T`` (shims pass their
    caller's runtime through). ``eval_x``/``eval_y`` are the accuracy-
    analysis set; without them ``tick`` still drains but never analyzes.

    Threading (DESIGN.md §14): ``submit``/``submit_rows`` are safe from
    any number of producer threads — they touch only the router's
    double-buffered staging state and the outstanding-rows mirror, both
    guarded by ``router.lock``. Everything consumer-side (device state,
    RNG keys, policy FSM, history, the runtime ``rt``) is serialized by
    one re-entrant device lock taken by ``flush``/``drain``/``tick``/
    ``serve``/``analyze``/``offline_train``; a producer only ever reaches
    the device lock through ``flush`` when its staging lane fills
    (lane-full backpressure blocks that producer until the consumer's
    current step completes). Lock order is always device -> router.
    """

    def __init__(
        self,
        cfg: TMConfig,
        state: TMState,
        sc: Optional[ServiceConfig] = None,
        *,
        rt: Optional[TMRuntime] = None,
        eval_x=None,
        eval_y=None,
    ):
        sc = sc or ServiceConfig()
        if sc.history_limit is not None and sc.history_limit < 1:
            raise ValueError("history_limit must be >= 1 (or None)")
        replicated = state.ta_state.ndim == 4
        K = sc.replicas
        if replicated and state.ta_state.shape[0] != K:
            raise ValueError(
                f"state carries {state.ta_state.shape[0]} replicas, "
                f"expected {K}"
            )
        if not replicated:
            state = TMState(ta_state=jnp.broadcast_to(
                state.ta_state, (K,) + state.ta_state.shape
            ))

        self.cfg = cfg
        self.sc = sc
        self.rt = rt if rt is not None else sc.runtime(cfg)
        self.n_replicas = K
        self.chunk = max(1, min(sc.chunk, sc.buffer_capacity))
        self.mesh = sc.mesh
        self.policy = sc.policy
        # Packed services hold the eval set as words too: every analysis
        # pass then rides the packed kernels (dtype routing in the core).
        self.eval_x = None if eval_x is None else self._ingest(eval_x)
        self.eval_y = None if eval_y is None else jnp.asarray(eval_y,
                                                              jnp.int32)
        # K = 1 with scalar runtime ports keeps the specialized
        # single-machine drain/inference bodies (DESIGN.md §10: the
        # replicated plane costs ~1.3x at R = 1); pinned bitwise against
        # the replicated path by the parity suites.
        self._k1 = (K == 1 and self.mesh is None
                    and jnp.ndim(self.rt.s) == 0 and jnp.ndim(self.rt.T) == 0)

        seed = sc.seed
        if isinstance(seed, (int, np.integer)):
            base = jax.random.PRNGKey(int(seed))
            keys = jax.vmap(lambda r: jax.random.fold_in(base, r))(
                jnp.arange(K)
            )
        else:
            if len(seed) != K:
                raise ValueError(f"need {K} seeds, got {len(seed)}")
            keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seed])
        self._keys = keys                                  # [K, key]

        buf1 = buf_mod.make(sc.buffer_capacity, cfg.n_features,
                            packed=sc.packed)
        bufs = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (K,) + a.shape), buf1
        )
        self._ss = SessionState(
            tm=state, buf=bufs, step=jnp.zeros((K,), jnp.int32)
        )
        if self.mesh is not None:
            sh = shard_mod.replica_shardings(
                (self._ss, self._keys), self.mesh, n_replicas=K
            )
            self._ss, self._keys = jax.tree.map(
                jax.device_put, (self._ss, self._keys), sh
            )
        self.router = router_mod.BatchRouter(
            K, cfg.n_features, sc.buffer_capacity, sc.ingress_block,
            packed=sc.packed,
        )
        # Outstanding-rows mirror: device buffer occupancy + rows in
        # flight to the device (credited at block swap, rejects undone
        # after the enqueue). Guarded by router.lock — the producer-side
        # acceptance decision reads it together with the staging counts.
        self._dev_size = np.zeros(K, dtype=np.int64)
        # Consumer-side serialization (DESIGN.md §14). Re-entrant: drain
        # flushes inside its own critical section.
        self._device_lock = threading.RLock()
        self._full_mask = np.ones(K, dtype=bool)
        self._ps = sc.policy.init(K)
        # Like the pre-redesign managers: the initial TA banks are the
        # known-good snapshot until an analysis/offline_train replaces it
        # (best stays nan, so the first due analysis can only improve).
        self._ps.best_state = self._ss.tm
        self.history: list = []            # (steps [K], accuracies [K])

    def _ingest(self, xs) -> jax.Array:
        """Bool rows -> the service's wire representation: bool features
        unpacked, uint32 words when ``sc.packed`` (already-packed uint32
        input passes through)."""
        xs = jnp.asarray(xs)
        if not self.sc.packed:
            return xs.astype(bool)
        if xs.dtype == jnp.uint32:
            return xs
        return packing.pack_bits(xs.astype(bool))

    # -- device state (mirror-preserving) -----------------------------------

    @property
    def ss(self) -> SessionState:
        """Device state, with staged ingress flushed first — so externally
        read (and read-modify-written) state always contains every accepted
        datapoint, exactly like the pre-staging immediate-enqueue API."""
        with self._device_lock:
            self.flush()
            return self._ss

    @ss.setter
    def ss(self, value: SessionState):
        """Replacing device state wholesale re-syncs the occupancy mirror
        (benchmarks pre-fill buffers this way). Traffic staged but never
        read back via the getter still lands on the next flush."""
        with self._device_lock:
            self._ss = value
            with self.router.lock:
                self._dev_size = np.asarray(
                    value.buf.size, dtype=np.int64
                ).reshape(self.n_replicas).copy()

    # -- ingress (producer side) --------------------------------------------

    def submit_rows(self, xs, ys, mask=None) -> np.ndarray:
        """One labelled datapoint into every (masked) replica's stream;
        returns accepted [K] bool (False = backpressure, counted in
        ``dropped``). Host-side staging only — the device enqueue happens
        on the next flush (a full staging lane flushes automatically).

        Safe under concurrent producers: replicas whose lane filled while
        this call raced another producer come back *blocked* from the
        router, and the call flushes and retries them — blocked rows are
        never silently dropped nor double-staged.
        """
        pending = (self._full_mask if mask is None
                   else np.asarray(mask, dtype=bool))
        accepted = np.zeros(self.n_replicas, dtype=bool)
        while True:
            ok, blocked = self.router.stage_rows(
                xs, ys, pending, self._dev_size
            )
            accepted |= ok
            if self.router.lane_full():
                self.flush()
            if not blocked.any():
                return accepted
            pending = blocked

    def submit(self, r: int, x, y) -> bool:
        """One labelled datapoint into replica ``r``'s stream."""
        mask = np.zeros(self.n_replicas, dtype=bool)
        mask[r] = True
        return bool(self.submit_rows(x, y, mask)[r])

    def flush(self) -> np.ndarray:
        """Push every staged row to the device buffers — ONE jitted
        ``_enqueue_rows`` dispatch per staged block. Returns [K] rows
        landed. Rows a buffer rejects despite the mirror (only possible
        when device state was swapped mid-flight) count as dropped.

        The block swap and the mirror credit happen atomically under
        ``router.lock`` (taken rows are *in flight*: no longer staged,
        not yet device-visible — crediting them at swap time keeps every
        outstanding row counted exactly once by concurrent acceptance
        decisions); the device transfer itself runs outside that lock,
        overlapping producers filling the other staging block.
        """
        K = self.n_replicas
        landed = np.zeros(K, dtype=np.int64)
        with self._device_lock:
            while True:
                with self.router.lock:
                    block = self.router.take_block()
                    if block is not None:
                        self._dev_size += block[2]
                if block is None:
                    return landed
                xs, ys, counts = block
                self._ss, accepted = router_mod._enqueue_rows(
                    self._ss, self.router.block, xs, ys, counts
                )
                acc = np.asarray(accepted, dtype=np.int64)
                with self.router.lock:
                    self._dev_size -= counts - acc
                    self.router.dropped += counts - acc
                landed += acc

    @property
    def buffered(self) -> np.ndarray:
        """Datapoints awaiting consumption per replica (device + in-flight
        + staged; read coherently under the router lock)."""
        with self.router.lock:
            return self._dev_size + self.router.staged

    @property
    def dropped(self) -> np.ndarray:
        """Backpressure events per replica. [K] i64 (a copy)."""
        with self.router.lock:
            return self.router.dropped.copy()

    # -- consumer side ------------------------------------------------------

    def drain(
        self,
        max_points,
        on_chunk: Optional[Callable[[ChunkAux], None]] = None,
    ) -> np.ndarray:
        """Consume up to ``max_points`` buffered rows PER REPLICA; [K]
        trained. Flushes staged ingress first, then drains chunk by chunk
        — one jitted call per chunk for the whole fleet (the per-cycle
        budget of Fig. 3, K machines per dispatch). Per-replica
        RNG/termination semantics exactly mirror K independent sessions.

        ``on_chunk`` receives each chunk's :class:`ChunkAux` with leading
        replica axis ``[K, chunk]``; without it the monitoring contraction
        is compiled out entirely.
        """
        K = self.n_replicas
        budget = np.broadcast_to(
            np.asarray(max_points, dtype=np.int64), (K,)
        ).copy()
        # the drain bodies keep the occupancy mirror in sync per chunk (not
        # here, after the fact) so an on_chunk callback raising mid-drain
        # can't desync accounting from the device
        with self._device_lock:
            self.flush()
            return (self._drain_k1(budget, on_chunk) if self._k1
                    else self._drain_replicated(budget, on_chunk))

    def _drain_replicated(self, budget, on_chunk) -> np.ndarray:
        K = self.n_replicas
        trained = np.zeros(K, dtype=np.int64)
        active = trained < budget
        monitor = on_chunk is not None
        while active.any():
            want = np.where(
                active, np.minimum(self.chunk, budget - trained), 0
            ).astype(np.int32)
            self._keys, chunk_keys = _advance_keys(
                self._keys, jnp.asarray(active)
            )
            self._ss, n, aux = online_mod._consume_many_replicated(
                self.cfg, self.chunk, self._ss, self.rt,
                jnp.asarray(want), chunk_keys, monitor=monitor,
            )
            n = np.asarray(n, dtype=np.int64)
            trained += n
            with self.router.lock:
                self._dev_size -= n
            if monitor and n.any():
                on_chunk(aux)
            active &= (n == want) & (trained < budget)
        return trained

    def _drain_k1(self, budget, on_chunk) -> np.ndarray:
        """The specialized single-machine drain body on the K = 1 slice."""
        ss1 = jax.tree.map(lambda a: a[0], self._ss)
        trained, budget1 = 0, int(budget[0])
        monitor = on_chunk is not None
        while trained < budget1:
            want = min(self.chunk, budget1 - trained)
            self._keys, chunk_keys = _advance_keys(
                self._keys, jnp.ones((1,), bool)
            )
            ss1, n, aux = online_mod._consume_many(
                self.cfg, self.chunk, ss1, self.rt,
                jnp.int32(want), chunk_keys[0], monitor=monitor,
            )
            n = int(n)
            trained += n
            # commit state + mirror before the callback (see drain())
            self._ss = jax.tree.map(lambda a: a[None], ss1)
            with self.router.lock:
                self._dev_size[0] -= n
            if monitor and n:
                on_chunk(jax.tree.map(lambda a: a[None], aux))
            if n < want:  # buffer drained before the budget ran out
                break
        return np.asarray([trained], dtype=np.int64)

    # -- inference ----------------------------------------------------------

    def serve(self, xs) -> np.ndarray:
        """Fleet inference [K, B]: every member's batch in ONE contraction.

        ``xs`` is [B, f] (the same batch served by all members) or
        [K, B, f] (one batch per member). Packed services pack the batch
        here and serve it through the AND+popcount kernels, bit-identically.
        """
        xs = self._ingest(xs)
        with self._device_lock:
            if xs.ndim == 2 and self._k1:
                tm1 = jax.tree.map(lambda a: a[0], self._ss.tm)
                return np.asarray(
                    tm_mod.predict_batch(self.cfg, tm1, self.rt, xs)
                )[None]
            if xs.ndim == 2:
                # D = 1: one shared stream, factored (stored once)
                xs = xs[None]
            return np.asarray(tm_mod.predict_batch_replicated(
                self.cfg, self._ss.tm, self.rt, xs
            ))

    # -- analysis + the Fig-3 policy loop -----------------------------------

    def analyze(self) -> np.ndarray:
        """Eval accuracy of every member in ONE contraction. [K] f32."""
        if self.eval_x is None:
            raise ValueError("TMService built without an eval set")
        with self._device_lock:
            if self._k1:
                tm1 = jax.tree.map(lambda a: a[0], self._ss.tm)
                # same [K] f32 contract as the K > 1 path
                acc = np.asarray([float(acc_mod.analyze(
                    self.cfg, tm1, self.rt, self.eval_x, self.eval_y
                ))], dtype=np.float32)
            else:
                acc = np.asarray(acc_mod.analyze_replicated(
                    self.cfg, self._ss.tm, self.rt,
                    self.eval_x[None], self.eval_y[None],  # D = 1: shared
                ))
            self.history.append((self.steps, acc))
            if self.sc.history_limit is not None:
                del self.history[:-self.sc.history_limit]
            return acc

    def offline_train(self, xs, ys, n_epochs: int = 10,
                      seed: int = 1) -> np.ndarray:
        """Offline phase for the whole fleet (one replicated epochs scan);
        the result becomes every member's known-good baseline."""
        xs = jnp.asarray(xs, dtype=bool)
        ys = jnp.asarray(ys, dtype=jnp.int32)
        with self._device_lock:
            return self._offline_train_locked(xs, ys, n_epochs, seed)

    def _offline_train_locked(self, xs, ys, n_epochs, seed) -> np.ndarray:
        if self._k1:
            tm1 = jax.tree.map(lambda a: a[0], self._ss.tm)
            st = fb_mod.train_epochs(
                self.cfg, tm1, self.rt, xs, ys,
                jax.random.PRNGKey(seed), n_epochs,
            )
            st = jax.tree.map(lambda a: a[None], st)
        else:
            st = fb_mod.train_epochs_replicated(
                self.cfg, self._ss.tm, self.rt, xs[None], ys[None],
                jax.random.PRNGKey(seed)[None], n_epochs,
            )
        self._ss = self._ss._replace(tm=st)
        acc = self.analyze()
        self.policy.snapshot(self._ps, acc, st)
        return acc

    def _maybe_analyze(self) -> Optional[tuple[np.ndarray, np.ndarray]]:
        """Run analysis + the §5.3.2 policy if any member is due.
        Returns (accuracies [K], rolled-back mask [K]) or None."""
        if self.eval_x is None:
            return None
        due = self.policy.due(self._ps)
        if not due.any():
            return None
        acc = self.analyze()
        tm, rolled = self.policy.apply(self._ps, due, acc, self._ss.tm)
        self._ss = self._ss._replace(tm=tm)
        return acc, rolled

    def tick(
        self,
        max_points=None,
        on_chunk: Optional[Callable[[ChunkAux], None]] = None,
    ) -> TickReport:
        """One Fig-3 consumer cycle: flush ingress, drain up to
        ``max_points`` (default: one chunk) per replica, advance the
        analysis cadence, and apply the mitigation policy to due members.
        """
        budget = self.chunk if max_points is None else max_points
        with self._device_lock:
            trained = self.drain(budget, on_chunk)
            self._ps.since += trained
            out = self._maybe_analyze()
        if out is None:
            return TickReport(trained, None,
                              np.zeros(self.n_replicas, dtype=bool))
        return TickReport(trained, out[0], out[1])

    def observe_rows(self, xs, ys, mask=None) -> Optional[np.ndarray]:
        """The legacy managers' per-point FSM step: one labelled datapoint
        per (masked) replica, drain-retry backpressure, one chunk-budget
        drain, then cadence/analysis/rollback. Returns [K] eval accuracies
        when at least one member hit its cadence, None otherwise.

        Drained points advance each member's OWN cadence counter — a
        backpressure drain's points still count toward the analysis
        cadence, exactly like the pre-redesign managers.
        """
        K = self.n_replicas
        mask = (np.ones(K, dtype=bool) if mask is None
                else np.asarray(mask, dtype=bool))
        with self._device_lock:
            accepted = self.submit_rows(xs, ys, mask)
            retry = mask & ~accepted
            if retry.any():
                # Backpressure: drain a chunk fleet-wide, then retry once.
                self._ps.since += self.drain(self.chunk)
                accepted = self.submit_rows(xs, ys, retry)
                self._ps.lost += retry & ~accepted
            self._ps.since += self.drain(self.chunk)
            out = self._maybe_analyze()
        return None if out is None else out[0]

    # -- observability ------------------------------------------------------

    @property
    def steps(self) -> np.ndarray:
        return np.asarray(self._ss.step)

    @property
    def rollbacks(self) -> np.ndarray:
        return self._ps.rollbacks

    @property
    def lost(self) -> np.ndarray:
        return self._ps.lost

    @property
    def since_analysis(self) -> np.ndarray:
        return self._ps.since
