"""Traffic-realistic serving harness: concurrent producers, SLO metrics.

The paper's deliverable is a managed online-learning *system* (Fig. 3:
offer -> cyclic buffer -> interleaved train/infer loop), and the ROADMAP
asks for it to be measured like one: not component microbenchmarks but
sustained offers/s and serve-latency percentiles under concurrent
producers replaying the paper's use cases as *load*. This module is that
harness, in three deterministic pieces (DESIGN.md §14):

* :class:`Scenario` + :func:`make_scripts` — a seeded traffic generator
  that compiles a scenario schedule (bursty arrivals, label delay, §5.2
  class introduction, label drift, §5.3 stuck-at faults) into per-producer
  :class:`ProducerScript` event streams. Scripts are pure functions of
  ``(scenario, dataset, producer, seed)`` — every run offers the same
  rows in the same per-producer order.
* :func:`run_threaded` — N producer threads (one per replica, so each
  replica's FIFO stream has a single well-defined order) submit labelled
  traffic and issue serve probes against a live :class:`TMService` while
  the consumer loop ticks; records per-offer submit/serve latencies, the
  per-tick consumption log, and which offers were accepted.
* :func:`replay_single_caller` — replays a recorded run through a FRESH
  service from ONE thread: same accepted rows per replica in the same
  order, same per-tick consumption, same fault-injection tick. The
  replayed TA banks / RNG keys / step counters must match the threaded
  run bit for bit (:func:`fingerprint`) — the whole-system equivalent of
  the kernel parity oracles, and the test that threading changed *when*
  work happened but never *what* was computed.

``benchmarks/traffic.py`` drives three standard schedules through this
module and gates sustained offers/s + p99 serve latency in CI
(BENCH_traffic.json).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional

import numpy as np

from repro.core import faults as faults_mod


# ---------------------------------------------------------------------------
# Scenario schedules — the paper's use cases expressed as load.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One deterministic traffic schedule (the §14 schedule format).

    Each producer offers ``points`` labelled datapoints drawn (seeded)
    from a dataset; the knobs below reshape that stream:

    * ``burst``/``burst_gap_s`` — arrivals come in back-to-back bursts of
      ``burst`` offers separated by idle gaps (0 = steady arrivals).
    * ``label_delay`` — use case "delayed ground truth": a point's serve
      probe fires when the point *arrives*, but its labelled submission
      trails ``label_delay`` offer slots behind (the stream's tail labels
      arrive after the last probe).
    * ``introduce_class``/``introduce_at`` — §5.2 class introduction: the
      named class is absent from the first ``introduce_at`` fraction of
      every producer's stream, then appears.
    * ``drift_at``/``drift_shift`` — label drift: from that fraction of
      the stream on, labels are relabelled ``(y + shift) % n_classes``
      (the adversarial relabeling of examples/serve_fleet.py, §5.3.2's
      trigger).
    * ``fault_at``/``fault_fraction``/``fault_stuck`` — §5.3 stuck-at
      faults: once the consumer has drained ``fault_at`` datapoints
      (fleet-wide), it injects an even-spread stuck-at mask set into the
      runtime (``core.faults.stuck_at_runtime`` — deterministic, so the
      replay can reproduce it exactly at the recorded tick).
    * ``probe_every`` — issue a serve probe every n-th offer (0 = never);
      probes ride the producer threads, so serve latency is measured
      under real lock contention with the consumer's tick loop.
    """

    name: str
    points: int = 256
    burst: int = 0
    burst_gap_s: float = 0.0
    label_delay: int = 0
    introduce_class: Optional[int] = None
    introduce_at: float = 0.5
    drift_at: Optional[float] = None
    drift_shift: int = 1
    fault_at: Optional[int] = None
    fault_fraction: float = 0.1
    fault_stuck: int = 1
    probe_every: int = 1


#: The three standard schedules gated in CI (BENCH_traffic.json): a clean
#: steady-state baseline, the paper's "world changed" composite (bursty
#: arrivals + late labels + a class appearing mid-stream + label drift),
#: and hardware degradation mid-run (§5.3 stuck-at-1 faults).
SCENARIOS = {
    "steady": Scenario(name="steady"),
    "bursty_drift": Scenario(
        name="bursty_drift", burst=32, burst_gap_s=0.002, label_delay=8,
        introduce_class=2, introduce_at=0.25, drift_at=0.75,
    ),
    "fault_injected": Scenario(
        name="fault_injected", fault_at=192, fault_fraction=0.1,
        fault_stuck=1,
    ),
}


@dataclasses.dataclass
class ProducerScript:
    """One producer's compiled event stream (offer order = array order)."""

    x: np.ndarray         # [n, f] bool — feature rows
    y: np.ndarray         # [n] i32 — labels as submitted (drift applied)
    gap_s: np.ndarray     # [n] f32 — arrival gap before each offer slot
    label_delay: int      # submissions trail probes by this many slots
    probe_every: int      # serve probe cadence (0 = never)

    def __len__(self) -> int:
        return len(self.y)


def make_script(sc: Scenario, xs, ys, n_classes: int, producer: int,
                seed: int = 0) -> ProducerScript:
    """Compile ``sc`` into one producer's deterministic event stream.

    Rows are drawn with replacement from ``(xs, ys)`` by an RNG keyed
    ``SeedSequence([seed, producer])`` — the stream is a pure function of
    its arguments (process-independent, like data/mnist.py).
    """
    xs = np.asarray(xs, dtype=bool)
    ys = np.asarray(ys, dtype=np.int32)
    rng = np.random.default_rng(np.random.SeedSequence([seed, producer]))
    n = sc.points
    intro_end = (int(n * sc.introduce_at)
                 if sc.introduce_class is not None else 0)
    pick = np.empty(n, dtype=np.int64)

    def _fill(lo: int, hi: int, exclude: Optional[int]) -> None:
        # Rejection-sample the slot range [lo, hi): draws of the withheld
        # class are discarded wholesale so surviving picks keep their
        # draw order (filtering then compacting per-slot would let
        # late-drawn withheld rows slide into early slots).
        have = lo
        while have < hi:
            draw = rng.integers(0, len(xs), size=hi - lo)
            if exclude is not None:
                draw = draw[ys[draw] != exclude]
            take = min(len(draw), hi - have)
            pick[have:have + take] = draw[:take]
            have += take

    _fill(0, intro_end, sc.introduce_class)
    _fill(intro_end, n, None)
    y = ys[pick].copy()
    if sc.drift_at is not None:
        drifted = np.arange(n) >= int(n * sc.drift_at)
        y[drifted] = (y[drifted] + sc.drift_shift) % n_classes
    gaps = np.zeros(n, dtype=np.float32)
    if sc.burst > 0 and sc.burst_gap_s > 0:
        slots = np.arange(n)
        gaps[(slots > 0) & (slots % sc.burst == 0)] = sc.burst_gap_s
    return ProducerScript(
        x=xs[pick], y=y, gap_s=gaps,
        label_delay=sc.label_delay, probe_every=sc.probe_every,
    )


def make_scripts(sc: Scenario, xs, ys, n_classes: int, n_producers: int,
                 seed: int = 0) -> list[ProducerScript]:
    return [make_script(sc, xs, ys, n_classes, p, seed)
            for p in range(n_producers)]


# ---------------------------------------------------------------------------
# The threaded run.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class TrafficResult:
    """Everything a threaded run observed — and everything a bitwise
    single-caller replay needs (accepted offers per producer in order,
    the per-tick consumption log, the fault-injection tick)."""

    scenario: str
    n_producers: int
    offers: int                      # labelled submissions attempted
    probes: int                      # serve probes issued
    accepted: np.ndarray             # [K] i64 — offers accepted per replica
    dropped: np.ndarray              # [K] i64 — backpressure drops
    trained: np.ndarray              # [K] i64 — datapoints consumed
    wall_s: float                    # barrier-to-drained wall time
    tick_trained: np.ndarray         # [T, K] i64 — per-tick consumption log
    fault_tick: Optional[int]        # tick index of §5.3 injection (or None)
    analyses: int                    # cadence analyses that fired
    rollbacks: np.ndarray            # [K] i64 — §5.3.2 rollbacks fired
    submit_lat_s: np.ndarray         # [offers] f64 — per-submit wall times
    serve_lat_s: np.ndarray          # [probes] f64 — per-probe wall times
    accepted_mask: list              # per producer: [n] bool, offer order
    tick_budget: Optional[np.ndarray] = None  # [T] f64 — tuner budget after
    # each tick (None unless the service carries a §16 TuneController)

    @property
    def ticks(self) -> int:
        return len(self.tick_trained)

    @property
    def offers_per_s(self) -> float:
        return self.offers / self.wall_s if self.wall_s > 0 else float("inf")

    def conserved(self) -> bool:
        """offers == accepted + dropped and accepted == trained, per
        replica (the run drains its buffers before returning)."""
        per_replica_offers = np.asarray(
            [int(m.size) for m in self.accepted_mask], dtype=np.int64
        )
        return (
            bool(np.array_equal(self.accepted + self.dropped,
                                per_replica_offers))
            and bool(np.array_equal(self.accepted, self.trained))
        )


def _percentile(samples: np.ndarray, q: float) -> float:
    return float(np.percentile(samples, q)) if samples.size else 0.0


def run_threaded(
    svc,
    scripts: list[ProducerScript],
    *,
    scenario: Scenario,
    pace: float = 1.0,
    seed: int = 0,
) -> TrafficResult:
    """Drive ``svc`` with one producer thread per replica plus the consumer
    tick loop on the calling thread; returns the full observation record.

    ``len(scripts)`` must equal ``svc.n_replicas`` — producer ``p`` owns
    replica ``p``'s stream, which is what makes per-replica FIFO order
    (and therefore the bitwise replay) well defined. ``pace`` scales the
    scripts' arrival gaps (0 = closed-loop, as fast as the host allows).
    """
    K = svc.n_replicas
    if len(scripts) != K:
        raise ValueError(
            f"{len(scripts)} producer scripts for {K} replicas — the "
            "harness runs one producer per replica (per-replica FIFO "
            "order, and the replay contract, depend on it)"
        )
    barrier = threading.Barrier(K + 1)
    submit_lat = [[] for _ in range(K)]
    serve_lat = [[] for _ in range(K)]
    accepted_mask = [np.zeros(len(s), dtype=bool) for s in scripts]
    errors: list[BaseException] = []

    def producer(p: int) -> None:
        s = scripts[p]
        n = len(s)
        try:
            barrier.wait()
            for slot in range(n + s.label_delay):
                if slot < n:
                    if pace and s.gap_s[slot]:
                        time.sleep(float(s.gap_s[slot]) * pace)
                    if s.probe_every and slot % s.probe_every == 0:
                        t0 = time.perf_counter()
                        svc.serve(s.x[slot][None])
                        serve_lat[p].append(time.perf_counter() - t0)
                j = slot - s.label_delay
                if j >= 0:
                    t0 = time.perf_counter()
                    ok = svc.submit(p, s.x[j], int(s.y[j]))
                    submit_lat[p].append(time.perf_counter() - t0)
                    accepted_mask[p][j] = ok
        except BaseException as e:  # surfaced to the caller after join
            errors.append(e)

    threads = [threading.Thread(target=producer, args=(p,), daemon=True)
               for p in range(K)]
    for t in threads:
        t.start()

    tick_trained: list[np.ndarray] = []
    tick_budget: list[float] = []
    fault_tick: Optional[int] = None
    analyses = 0
    consumed = 0
    barrier.wait()
    t_begin = time.perf_counter()
    while True:
        alive = any(t.is_alive() for t in threads)
        if (scenario.fault_at is not None and fault_tick is None
                and consumed >= scenario.fault_at):
            # §5.3 injection — consumer-owned runtime swap, recorded by
            # tick index so the replay lands it at the same point.
            svc.rt = faults_mod.stuck_at_runtime(
                svc.cfg, svc.rt, scenario.fault_fraction, scenario.fault_stuck
            )
            fault_tick = len(tick_trained)
        rep = svc.tick()
        tick_trained.append(np.asarray(rep.trained, dtype=np.int64))
        if getattr(svc, "tuner", None) is not None:
            tick_budget.append(float(svc.tuner.budget))
        consumed += int(tick_trained[-1].sum())
        if rep.accuracy is not None:
            analyses += 1
        if not alive and not svc.buffered.any():
            break
    wall = time.perf_counter() - t_begin
    for t in threads:
        t.join()
    if errors:
        raise errors[0]

    return TrafficResult(
        scenario=scenario.name,
        n_producers=K,
        offers=sum(len(s) for s in scripts),
        probes=sum(len(ls) for ls in serve_lat),
        accepted=np.asarray([int(m.sum()) for m in accepted_mask],
                            dtype=np.int64),
        dropped=svc.dropped,
        trained=svc.steps.astype(np.int64),
        wall_s=wall,
        tick_trained=(np.stack(tick_trained) if tick_trained
                      else np.zeros((0, K), dtype=np.int64)),
        fault_tick=fault_tick,
        analyses=analyses,
        rollbacks=svc.rollbacks.copy(),
        submit_lat_s=np.asarray(sorted(v for ls in submit_lat for v in ls)),
        serve_lat_s=np.asarray(sorted(v for ls in serve_lat for v in ls)),
        tick_budget=(np.asarray(tick_budget, dtype=np.float64)
                     if tick_budget else None),
        accepted_mask=accepted_mask,
    )


def slo_summary(result: TrafficResult) -> dict:
    """The SLO numbers BENCH_traffic.json reports for one scenario run."""
    return {
        "scenario": result.scenario,
        "n_producers": result.n_producers,
        "offers": result.offers,
        "probes": result.probes,
        "accepted": int(result.accepted.sum()),
        "dropped": int(result.dropped.sum()),
        "trained": int(result.trained.sum()),
        "ticks": result.ticks,
        "analyses": result.analyses,
        "rollbacks": int(result.rollbacks.sum()),
        "fault_tick": result.fault_tick,
        "wall_s": result.wall_s,
        "offers_per_s": result.offers_per_s,
        "submit_p50_s": _percentile(result.submit_lat_s, 50),
        "submit_p99_s": _percentile(result.submit_lat_s, 99),
        "serve_p50_s": _percentile(result.serve_lat_s, 50),
        "serve_p99_s": _percentile(result.serve_lat_s, 99),
        "conserved": result.conserved(),
    }


# ---------------------------------------------------------------------------
# The single-caller replay (bitwise consistency oracle).
# ---------------------------------------------------------------------------


def replay_single_caller(svc, scripts: list[ProducerScript],
                         result: TrafficResult,
                         *, scenario: Scenario) -> None:
    """Replay a recorded threaded run through ``svc`` from ONE thread.

    ``svc`` must be a FRESH service constructed exactly like the threaded
    run's (same config/state/seed/eval set). Per tick of the record: the
    rows that tick consumed are submitted (each replica's accepted rows,
    in producer order — the per-replica FIFO), the §5.3 fault lands at
    its recorded tick, and ``tick`` runs with the recorded per-replica
    consumption as its budget (``max(n, 1)`` so idle replicas still
    advance their per-tick RNG split, exactly as a chunk-budget tick
    does). After the loop ``fingerprint(svc)`` must equal the threaded
    run's — same TA banks, RNG keys, steps, policy state, bit for bit.
    """
    K = svc.n_replicas
    rows = [(s.x[m], s.y[m]) for s, m in zip(scripts, result.accepted_mask)]
    cursor = np.zeros(K, dtype=np.int64)
    for t, trained_t in enumerate(result.tick_trained):
        if result.fault_tick is not None and t == result.fault_tick:
            svc.rt = faults_mod.stuck_at_runtime(
                svc.cfg, svc.rt, scenario.fault_fraction, scenario.fault_stuck
            )
        for r in range(K):
            lo, hi = int(cursor[r]), int(cursor[r]) + int(trained_t[r])
            for j in range(lo, hi):
                if not svc.submit(r, rows[r][0][j], int(rows[r][1][j])):
                    raise AssertionError(
                        f"replay row rejected (replica {r}, row {j}) — "
                        "the recorded run accepted it"
                    )
            cursor[r] = hi
        svc.tick(np.maximum(trained_t, 1))


def fingerprint(svc) -> dict:
    """The consumer-side trajectory state compared bitwise between a
    threaded run and its replay."""
    ss = svc.ss
    return {
        "ta_state": np.asarray(ss.tm.ta_state),
        "steps": svc.steps.copy(),
        "keys": np.asarray(svc._keys),
        "since_analysis": svc.since_analysis.copy(),
        "rollbacks": svc.rollbacks.copy(),
        "best": svc._ps.best.copy(),
    }


def fingerprints_equal(a: dict, b: dict) -> bool:
    return all(
        np.array_equal(a[k], b[k], equal_nan=True)
        if a[k].dtype.kind == "f" else np.array_equal(a[k], b[k])
        for k in a
    )
