"""Mixture-of-Experts FFN: top-k routing, capacity-bounded grouped dispatch.

Tokens are split into ``num_groups`` groups (aligned with the mesh's data
shards so dispatch stays device-local); each group scatters its tokens into
per-expert capacity buffers (`at[].add` — static shapes, dry-run safe, and
O(T*k*D) memory instead of the O(T*E*C) one-hot dispatch tensor of the
classic GShard einsum formulation). The buffer tensor is sharded
[groups->data, experts->model], so GSPMD emits the expert-parallel all-to-all
at the group<->expert resharding boundary.

arctic-480b's ``dense_residual`` adds the architecture's parallel dense FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.autoshard import hint, setting
from repro.models import layers
from repro.models.params import PSpec

_DP = ("pod", "data")  # combined data-parallel axes for the group dim


def _expert_axis():
    # training: experts over `model` (EP in the TP axis); serving: experts
    # over `data` (weight-stationary, expert_ff stays on `model`).
    return setting("moe_expert_axis", "model")


def moe_specs(cfg: ModelConfig) -> dict:
    assert cfg.moe is not None
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.n_experts
    sp = {
        "router": PSpec((d, e), ("embed", "experts")),
        "w_gate": PSpec((e, d, f), ("experts", "embed", "expert_ff")),
        "w_up": PSpec((e, d, f), ("experts", "embed", "expert_ff")),
        "w_down": PSpec((e, f, d), ("experts", "expert_ff", "embed")),
    }
    if m.dense_residual:
        sp["dense"] = layers.mlp_specs(cfg)
    return sp


def moe_ffn(
    cfg: ModelConfig, p: dict, x: jax.Array, *, num_groups: int = 1
) -> tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (out [B, S, D], router aux loss scalar f32)."""
    m = cfg.moe
    cd = jnp.dtype(cfg.compute_dtype)
    B, S, D = x.shape
    T = B * S
    G = num_groups
    assert T % G == 0, (T, G)
    Tg = T // G
    xt = hint(x.reshape(G, Tg, D).astype(cd), _DP, None, None)

    logits = jnp.einsum(
        "gtd,de->gte", xt, p["router"].astype(cd)
    ).astype(jnp.float32)
    logits = hint(logits, _DP, None, None)
    probs = jax.nn.softmax(logits, axis=-1)                     # [G,Tg,E]
    gate_vals, expert_idx = jax.lax.top_k(probs, m.top_k)       # [G,Tg,k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Switch-style load-balance loss: E * sum_e mean(probs_e) * mean(top1==e).
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], m.n_experts), axis=(0, 1)
    )
    aux = m.n_experts * jnp.sum(me * ce) * m.router_aux_weight

    C = max(1, int(round(Tg * m.top_k * m.capacity_factor / m.n_experts)))

    # Position of each (token, k) slot inside its expert's buffer, per group.
    sel = jax.nn.one_hot(expert_idx, m.n_experts, dtype=jnp.int32)  # [G,Tg,k,E]
    flat = sel.reshape(G, Tg * m.top_k, m.n_experts)
    pos = jnp.cumsum(flat, axis=1) - flat                           # [G,Tk,E]
    pos = jnp.sum(pos * flat, axis=-1).reshape(G, Tg, m.top_k)      # [G,Tg,k]
    keep = pos < C
    w = jnp.where(keep, gate_vals, 0.0).astype(cd)                  # [G,Tg,k]
    # Dropped slots scatter into a discard row (index C, sliced off below).
    pos_c = jnp.where(keep, pos, C)

    def dispatch_one(xg, eidx, posg, keepg):
        # xg: [Tg,D], eidx/posg/keepg: [Tg,k] -> buffers [E, C+1, D]
        buf = jnp.zeros((m.n_experts, C + 1, D), dtype=cd)
        xk = xg[:, None, :] * keepg[..., None]   # raw tokens (kept slots only)
        return buf.at[eidx, posg].add(xk)

    buffers = jax.vmap(dispatch_one)(xt, expert_idx, pos_c, keep.astype(cd))
    # Dispatch happened group-local (buffers sharded over G=DP); the expert
    # einsums want the expert axis sharded — this hint boundary IS the
    # all-to-all GSPMD emits.
    ea = _expert_axis()
    g_axis = None if ea == "data" else _DP
    buffers = hint(buffers[:, :, :C, :], g_axis, ea, None, None)

    # Expert FFN over [G, E, C, D] buffers (weights shared across groups).
    g_ = jnp.einsum("gecd,edf->gecf", buffers, p["w_gate"].astype(cd))
    act = jax.nn.silu(g_) if cfg.act == "swiglu" else jax.nn.gelu(g_)
    if "w_up" in p:
        u = jnp.einsum("gecd,edf->gecf", buffers, p["w_up"].astype(cd))
        act = act * u
    ex_out = jnp.einsum("gecf,efd->gecd", act, p["w_down"].astype(cd))
    ex_out = hint(ex_out, g_axis, ea, None, None)

    def combine_one(bufg, eidx, posg, wg):
        # bufg: [E,C,D] -> out [Tg, D]: gate-weighted sum of expert outputs.
        got = bufg[eidx, jnp.minimum(posg, C - 1)]   # [Tg,k,D]
        return jnp.sum(got * wg[..., None], axis=1)

    out = jax.vmap(combine_one)(ex_out, expert_idx, pos_c, w)

    if m.dense_residual:
        out = out + layers.mlp(cfg, p["dense"], xt)
    return out.reshape(B, S, D).astype(x.dtype), aux
