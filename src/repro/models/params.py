"""Parameter specification trees.

Every model is declared once as a tree of :class:`PSpec` (shape + logical axis
names + initializer). From that single declaration we derive:

* real parameters (`materialize`) for smoke tests / small-scale training,
* `jax.ShapeDtypeStruct`s (`abstract`) for the 512-device dry-run — no
  allocation ever happens for the full-size configs,
* `NamedSharding`s (`distributed.sharding.build_shardings`) by mapping logical
  axes through the parallelism rules.

This keeps shapes, initializers and sharding in lock-step — the usual failure
mode of hand-written sharding tables drifting from the model code is
structurally impossible.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class PSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]   # logical axis per dim, e.g. ("vocab","embed")
    init: str = "normal"              # normal | zeros | ones | scaled | conv
    scale: float = 1.0                # stddev multiplier / fan-in override

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def tree_map_specs(fn: Callable[[PSpec], Any], tree):
    """Map over a nested dict-of-PSpec tree."""
    if isinstance(tree, PSpec):
        return fn(tree)
    if isinstance(tree, dict):
        return {k: tree_map_specs(fn, v) for k, v in tree.items()}
    raise TypeError(f"unexpected node {type(tree)}")


def stack_specs(tree, n: int, axis_name: Optional[str] = None):
    """Add a leading stacked-layers dim of size n to every spec (for lax.scan)."""
    return tree_map_specs(
        lambda s: PSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale),
        tree,
    )


def abstract(tree, dtype) -> Any:
    """ShapeDtypeStructs — the dry-run's zero-allocation parameter stand-ins."""
    return tree_map_specs(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), tree
    )


def _init_one(spec: PSpec, key: jax.Array, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "normal":
        # fan-in scaled normal over the first axis (or only axis).
        fan_in = spec.shape[0] if len(spec.shape) > 1 else max(spec.shape[0], 1)
        std = spec.scale / math.sqrt(fan_in)
        return std * jax.random.normal(key, spec.shape, dtype)
    if spec.init == "scaled":
        return spec.scale * jax.random.normal(key, spec.shape, dtype)
    raise ValueError(f"unknown init {spec.init}")


def materialize(tree, key: jax.Array, dtype) -> Any:
    """Real parameters (deterministic per-path keys: stable across refactors)."""

    def walk(node, path):
        if isinstance(node, PSpec):
            k = jax.random.fold_in(key, hash(path) % (2**31))
            return _init_one(node, k, dtype)
        return {k: walk(v, path + (k,)) for k, v in node.items()}

    return walk(tree, ())


def count_params(tree) -> int:
    total = 0

    def add(s: PSpec):
        nonlocal total
        total += int(np.prod(s.shape))

    tree_map_specs(add, tree)
    return total
