"""Transformer building blocks: norms, RoPE, GQA attention, gated MLPs.

Pure functions over explicit parameter dicts (built from PSpec trees in
:mod:`repro.models.params`). Attention comes in three temporal modes:

* full-sequence (training / prefill) with causal or sliding-window masks,
* single-token decode against a KV cache (`dynamic_update_slice` writes),
* cross-attention over stub modality embeddings (vlm), flamingo-style gated.

Numerics: parameters fp32 (or per-config), matmuls in `cfg.compute_dtype`
(bf16 on TPU), softmax/logsumexp always fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PSpec

# ---------------------------------------------------------------------------
# Param spec builders
# ---------------------------------------------------------------------------


def norm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {"scale": PSpec((d,), ("embed",), "ones"),
                "bias": PSpec((d,), ("embed",), "zeros")}
    return {"scale": PSpec((d,), ("embed",), "ones")}


def attention_specs(cfg: ModelConfig, *, gated: bool = False) -> dict:
    d, dh = cfg.d_model, cfg.head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    sp = {
        "wq": PSpec((d, hq, dh), ("embed", "heads", "head_dim")),
        "wk": PSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wv": PSpec((d, hkv, dh), ("embed", "kv_heads", "head_dim")),
        "wo": PSpec((hq, dh, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qkv_bias:
        sp["bq"] = PSpec((hq, dh), ("heads", "head_dim"), "zeros")
        sp["bk"] = PSpec((hkv, dh), ("kv_heads", "head_dim"), "zeros")
        sp["bv"] = PSpec((hkv, dh), ("kv_heads", "head_dim"), "zeros")
    if gated:
        sp["gate"] = PSpec((), (), "zeros")  # tanh-gated residual, init 0
    return sp


def mlp_specs(cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d = cfg.d_model
    f = cfg.d_ff if d_ff is None else d_ff
    if cfg.act in ("swiglu", "geglu"):
        return {
            "w_gate": PSpec((d, f), ("embed", "ff")),
            "w_up": PSpec((d, f), ("embed", "ff")),
            "w_down": PSpec((f, d), ("ff", "embed")),
        }
    return {
        "w_up": PSpec((d, f), ("embed", "ff")),
        "w_down": PSpec((f, d), ("ff", "embed")),
    }


# ---------------------------------------------------------------------------
# Forward ops
# ---------------------------------------------------------------------------


def norm(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + 1e-6)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + 1e-6) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: [..., S, H, D]; positions: [..., S] or [S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -jnp.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(ang)[..., :, None, :]  # broadcast over heads
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    ).astype(x.dtype)
    return out


def _project_qkv(cfg, p, x, xkv=None):
    """q: [B,S,Hq,D]; k,v: [B,T,Hkv,D] (xkv defaults to x)."""
    cd = jnp.dtype(cfg.compute_dtype)
    xkv = x if xkv is None else xkv
    q = jnp.einsum("bsd,dhk->bshk", x.astype(cd), p["wq"].astype(cd))
    k = jnp.einsum("btd,dhk->bthk", xkv.astype(cd), p["wk"].astype(cd))
    v = jnp.einsum("btd,dhk->bthk", xkv.astype(cd), p["wv"].astype(cd))
    if "bq" in p:
        q = q + p["bq"].astype(cd)
        k = k + p["bk"].astype(cd)
        v = v + p["bv"].astype(cd)
    return q, k, v


def _gqa_scores_out(cfg, q, k, v, mask):
    """Grouped-query attention core. mask: [B or 1, 1, S, T] additive f32."""
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    g = hq // hkv
    B, S = q.shape[0], q.shape[1]
    qg = q.reshape(B, S, hkv, g, q.shape[-1])
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    scores = scores + mask[:, :, None, :, :]  # [B,kv,g,S,T]
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(B, S, hq, q.shape[-1])


def _chunk_mask(S: int, j, chunk: int, window: Optional[int]):
    """Validity of (query i, key j*chunk+t) pairs. [S, chunk] bool."""
    qi = jnp.arange(S)
    kpos = j * chunk + jnp.arange(chunk)
    ok = kpos[None, :] <= qi[:, None]
    if window is not None:
        ok &= (qi[:, None] - kpos[None, :]) < window
    return ok


def _flash_fwd_impl(q, k, v, window: Optional[int], chunk: int):
    """Streaming-softmax forward. q:[B,S,Hq,D], k/v:[B,S,Hkv,D].
    Returns (out [B,S,Hq,D], lse [B,Hkv,g,S] f32)."""
    cd = q.dtype
    B, S, hq, D = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    n_chunks = S // chunk
    qg = q.reshape(B, S, hkv, g, D)
    scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)

    kc = k.reshape(B, n_chunks, chunk, hkv, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, hkv, D).transpose(1, 0, 2, 3, 4)

    m0 = jnp.full((B, hkv, g, S), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, hkv, g, S), jnp.float32)
    a0 = jnp.zeros((B, hkv, g, S, D), jnp.float32)

    def body(carry, inp):
        m, l, acc = carry
        j, kj, vj = inp
        s = jnp.einsum("bskgd,btkd->bkgst", qg, kj).astype(jnp.float32)
        s = s * scale
        ok = _chunk_mask(S, j, chunk, window)
        s = jnp.where(ok[None, None, None], s, -jnp.inf)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        live = ~jnp.isinf(m_new)   # fully-masked prefix guard (window warmup)
        p = jnp.where(live[..., None], jnp.exp(s - m_new[..., None]), 0.0)
        r = jnp.where(live & ~jnp.isinf(m), jnp.exp(m - m_new), 0.0)
        l = l * r + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgst,btkd->bkgsd", p.astype(cd), vj)
        acc = acc * r[..., None] + pv.astype(jnp.float32)
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc)
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, S, hq, D)
    return out.astype(cd), lse


import functools as _functools


@_functools.lru_cache(maxsize=None)
def _flash_fn(window: Optional[int], chunk: int):
    """custom_vjp flash attention: the backward recomputes per-chunk
    probabilities from the saved logsumexp stats (never stores the stacked
    [n_chunks, ..., S, chunk] score tensors the naive scan-grad would)."""

    @jax.custom_vjp
    def fa(q, k, v):
        return _flash_fwd_impl(q, k, v, window, chunk)[0]

    def fwd(q, k, v):
        out, lse = _flash_fwd_impl(q, k, v, window, chunk)
        return out, (q, k, v, out, lse)

    def bwd(res, do):
        q, k, v, out, lse = res
        cd = q.dtype
        B, S, hq, D = q.shape
        hkv = k.shape[2]
        g = hq // hkv
        n_chunks = S // chunk
        scale = 1.0 / jnp.sqrt(D).astype(jnp.float32)
        qg = q.reshape(B, S, hkv, g, D)
        dog = do.reshape(B, S, hkv, g, D)
        og = out.reshape(B, S, hkv, g, D)
        # D_row = sum_d do * o   [B,hkv,g,S]
        Drow = jnp.einsum("bskgd,bskgd->bkgs",
                          dog.astype(jnp.float32), og.astype(jnp.float32))

        kc = k.reshape(B, n_chunks, chunk, hkv, D).transpose(1, 0, 2, 3, 4)
        vc = v.reshape(B, n_chunks, chunk, hkv, D).transpose(1, 0, 2, 3, 4)
        dq0 = jnp.zeros((B, S, hkv, g, D), jnp.float32)

        def body(dq, inp):
            j, kj, vj = inp
            s = jnp.einsum("bskgd,btkd->bkgst", qg, kj).astype(jnp.float32)
            s = s * scale
            ok = _chunk_mask(S, j, chunk, window)
            p = jnp.where(ok[None, None, None],
                          jnp.exp(s - lse[..., None]), 0.0)
            dv_j = jnp.einsum("bkgst,bskgd->btkd", p.astype(cd), dog)
            dp = jnp.einsum("bskgd,btkd->bkgst", dog, vj).astype(jnp.float32)
            ds = p * (dp - Drow[..., None]) * scale
            dq = dq + jnp.einsum("bkgst,btkd->bskgd",
                                 ds.astype(cd), kj).astype(jnp.float32)
            dk_j = jnp.einsum("bkgst,bskgd->btkd", ds.astype(cd), qg)
            return dq, (dk_j, dv_j)

        dq, (dks, dvs) = jax.lax.scan(
            body, dq0, (jnp.arange(n_chunks), kc, vc)
        )
        dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, S, hkv, D)
        dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, S, hkv, D)
        return dq.astype(cd).reshape(B, S, hq, D), dk.astype(cd), dv.astype(cd)

    fa.defvjp(fwd, bwd)
    return fa


def gqa_attention(cfg, q, k, v, *, window: Optional[int]):
    """Full-sequence GQA dispatch: dense mask below the chunk threshold,
    flash (streaming-softmax, custom-vjp) above it."""
    S = q.shape[1]
    chunk = getattr(cfg, "attn_chunk", 512)
    if S > chunk and S % chunk == 0:
        return _flash_fn(window, chunk)(q, k, v)
    mask = causal_mask(S, S, window=window)
    return _gqa_scores_out(cfg, q, k, v, mask)


def causal_mask(S: int, T: int, offset: int = 0, window: Optional[int] = None):
    """Additive mask [1,1,S,T]: query i attends keys j with
    j <= i+offset and (window is None or i+offset - j < window)."""
    qi = jnp.arange(S)[:, None] + offset
    kj = jnp.arange(T)[None, :]
    ok = kj <= qi
    if window is not None:
        ok &= (qi - kj) < window
    return jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)[None, None]


def self_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,                 # [B, S, D]
    *,
    window: Optional[int] = None,
    pos_offset: int = 0,
) -> jax.Array:
    """Full-sequence causal (optionally sliding-window) self-attention."""
    cd = jnp.dtype(cfg.compute_dtype)
    S = x.shape[1]
    q, k, v = _project_qkv(cfg, p, x)
    pos = jnp.arange(S) + pos_offset
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    out = gqa_attention(cfg, q, k, v, window=window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))


def decode_self_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,        # [B, 1, D] — the new token
    cache_k: jax.Array,  # [B, S_max, Hkv, Dh]
    cache_v: jax.Array,
    pos: jax.Array,      # scalar i32 — index of the new token
    *,
    window: Optional[int] = None,
):
    """One decode step: write K/V at ``pos``, attend to the valid prefix."""
    cd = jnp.dtype(cfg.compute_dtype)
    q, k, v = _project_qkv(cfg, p, x)
    q = rope(q, pos[None], cfg.rope_theta)
    k = rope(k, pos[None], cfg.rope_theta)
    ck = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0)
    )
    cv = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0)
    )
    T = ck.shape[1]
    kj = jnp.arange(T)
    ok = kj <= pos
    if window is not None:
        ok &= (pos - kj) < window
    mask = jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)[None, None, None, :]
    out = _gqa_scores_out(cfg, q, ck.astype(cd), cv.astype(cd), mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd)), ck, cv


def decode_local_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,        # [B, 1, D]
    cache_k: jax.Array,  # [B, W, Hkv, Dh] rotating window cache
    cache_v: jax.Array,
    pos: jax.Array,      # scalar i32 — ABSOLUTE position of the new token
):
    """Sliding-window decode against a rotating cache (slot = pos % W).

    Keys were RoPE'd at their absolute positions when written; a slot s holds
    the key for absolute position  pos - ((pos - s) mod W),  which is negative
    (=> masked) until the window has warmed up.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    W = cache_k.shape[1]
    q, k, v = _project_qkv(cfg, p, x)
    q = rope(q, pos[None], cfg.rope_theta)
    k = rope(k, pos[None], cfg.rope_theta)
    slot = jnp.mod(pos, W)
    ck = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, slot, 0, 0)
    )
    cv = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, slot, 0, 0)
    )
    slots = jnp.arange(W)
    abs_pos = pos - jnp.mod(pos - slots, W)
    mask = jnp.where(abs_pos >= 0, 0.0, -jnp.inf).astype(jnp.float32)
    mask = mask[None, None, None, :]
    out = _gqa_scores_out(cfg, q, ck.astype(cd), cv.astype(cd), mask)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd)), ck, cv


def decode_attention_stacked(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,          # [B, 1, D]
    buf_k: jax.Array,      # [L?, B, S|W, Hkv, Dh] stacked (idx given) or unstacked
    buf_v: jax.Array,
    idx,                   # scan layer index into the stacked dim, or None
    pos: jax.Array,        # absolute position of the new token
    *,
    local: bool,
):
    """One decode step writing the new K/V **directly into the (stacked)
    cache buffer** — the write region is a single token, so XLA aliases the
    multi-GB buffer in place across the layer scan instead of copying it.

    Global attention masks keys beyond `pos`; local attention uses a rotating
    window buffer (slot = pos % W) with absolute-position masking.
    """
    cd = jnp.dtype(cfg.compute_dtype)
    q, k, v = _project_qkv(cfg, p, x)
    q = rope(q, pos[None], cfg.rope_theta)
    k = rope(k, pos[None], cfg.rope_theta)

    W = buf_k.shape[2] if idx is not None else buf_k.shape[1]
    write_pos = jnp.mod(pos, W) if local else pos
    kw = k.astype(buf_k.dtype)
    vw = v.astype(buf_v.dtype)
    if idx is not None:
        buf_k = jax.lax.dynamic_update_slice(
            buf_k, kw[None], (idx, 0, write_pos, 0, 0))
        buf_v = jax.lax.dynamic_update_slice(
            buf_v, vw[None], (idx, 0, write_pos, 0, 0))
        ck = jax.lax.dynamic_index_in_dim(buf_k, idx, 0, keepdims=False)
        cv = jax.lax.dynamic_index_in_dim(buf_v, idx, 0, keepdims=False)
    else:
        buf_k = jax.lax.dynamic_update_slice(buf_k, kw, (0, write_pos, 0, 0))
        buf_v = jax.lax.dynamic_update_slice(buf_v, vw, (0, write_pos, 0, 0))
        ck, cv = buf_k, buf_v

    slots = jnp.arange(W)
    if local:
        abs_pos = pos - jnp.mod(pos - slots, W)
        ok = abs_pos >= 0
    else:
        ok = slots <= pos
    mask = jnp.where(ok, 0.0, -jnp.inf).astype(jnp.float32)[None, None, None]
    out = _gqa_scores_out(cfg, q, ck.astype(cd), cv.astype(cd), mask)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return out, buf_k, buf_v


def cross_attention(
    cfg: ModelConfig,
    p: dict,
    x: jax.Array,            # [B, S, D] queries (text stream)
    cross_kv: jax.Array,     # [B, N, D] stub modality embeddings
) -> jax.Array:
    """Gated cross-attention (flamingo-style: tanh(gate) starts at 0)."""
    cd = jnp.dtype(cfg.compute_dtype)
    q, k, v = _project_qkv(cfg, p, x, xkv=cross_kv)
    B, S = x.shape[0], x.shape[1]
    N = cross_kv.shape[1]
    mask = jnp.zeros((1, 1, S, N), dtype=jnp.float32)
    out = _gqa_scores_out(cfg, q, k, v, mask)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(cd))
    return out * jnp.tanh(p["gate"].astype(cd))


def mlp(cfg: ModelConfig, p: dict, x: jax.Array) -> jax.Array:
    cd = jnp.dtype(cfg.compute_dtype)
    xc = x.astype(cd)
    if cfg.act in ("swiglu", "geglu"):
        g = xc @ p["w_gate"].astype(cd)
        u = xc @ p["w_up"].astype(cd)
        g = jax.nn.silu(g) if cfg.act == "swiglu" else jax.nn.gelu(g)
        return (g * u) @ p["w_down"].astype(cd)
    h = jax.nn.gelu(xc @ p["w_up"].astype(cd))
    return h @ p["w_down"].astype(cd)
