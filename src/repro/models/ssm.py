"""Mamba-2 SSD (state-space duality) block  [arXiv:2405.21060].

Training/prefill uses the chunked SSD algorithm: the sequence is split into
chunks; within a chunk the output is the masked quadratic (attention-like)
form, across chunks a compact [heads, head_dim, d_state] state is carried by
an associative scan — O(S * chunk) work, O(S/chunk) sequential depth, and MXU
shaped matmuls throughout. Decode carries the same state one token at a time,
so long_500k decode is O(1) per token in sequence length (the sub-quadratic
arch the brief requires for that shape).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.autoshard import hint
from repro.models.params import PSpec

_DP = ("pod", "data")


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model
    nh = di // s.head_dim
    return di, nh, s.d_state, s.d_conv


def ssd_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, nh, ds, dc = _dims(cfg)
    conv_dim = di + 2 * ds  # conv runs over x, B, C streams
    return {
        "in_proj": PSpec((d, 2 * di + 2 * ds + nh), ("embed", "inner")),
        "conv_w": PSpec((dc, conv_dim), ("conv", "inner"), "scaled", 0.1),
        "conv_b": PSpec((conv_dim,), ("inner",), "zeros"),
        "a_log": PSpec((nh,), ("ssm_heads",), "zeros"),
        "dt_bias": PSpec((nh,), ("ssm_heads",), "zeros"),
        "d_skip": PSpec((nh,), ("ssm_heads",), "ones"),
        "norm": PSpec((di,), ("inner",), "ones"),
        "out_proj": PSpec((di, d), ("inner", "embed")),
    }


class SSDState(NamedTuple):
    """Decode-time recurrent state for one SSD layer."""

    h: jax.Array          # [B, nh, hd, ds] ssm state
    conv: jax.Array       # [B, d_conv-1, conv_dim] causal-conv tail


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSDState:
    di, nh, ds, dc = _dims(cfg)
    hd = cfg.ssm.head_dim
    return SSDState(
        h=jnp.zeros((batch, nh, hd, ds), dtype),
        conv=jnp.zeros((batch, dc - 1, di + 2 * ds), dtype),
    )


def _split_proj(cfg, zxbcdt):
    di, nh, ds, _ = _dims(cfg)
    z, x, B, C, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + ds, 2 * di + 2 * ds], axis=-1
    )
    return z, x, B, C, dt


def _causal_conv(cfg, p, xbc, conv_tail=None):
    """Depthwise causal conv over the sequence. xbc: [B, S, conv_dim]."""
    dc = cfg.ssm.d_conv
    if conv_tail is None:
        pad = jnp.zeros((xbc.shape[0], dc - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = conv_tail.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    # windowed dot with the [dc, conv_dim] depthwise filter
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * p["conv_w"][i].astype(xbc.dtype)
        for i in range(dc)
    )
    out = out + p["conv_b"].astype(xbc.dtype)
    new_tail = xp[:, xp.shape[1] - (dc - 1) :, :]
    return jax.nn.silu(out), new_tail


def ssd_forward(
    cfg: ModelConfig, p: dict, xin: jax.Array
) -> jax.Array:
    """Full-sequence SSD (training / prefill). xin: [B, S, D] -> [B, S, D]."""
    cd = jnp.dtype(cfg.compute_dtype)
    di, nh, ds, _ = _dims(cfg)
    hd = cfg.ssm.head_dim
    Q = cfg.ssm.chunk
    B_, S, _ = xin.shape
    assert S % Q == 0 or S < Q, (S, Q)
    Qe = min(Q, S)
    nchunk = max(1, S // Qe)

    zxbcdt = xin.astype(cd) @ p["in_proj"].astype(cd)
    z, x, Bmat, Cmat, dt = _split_proj(cfg, zxbcdt)
    xbc, _ = _causal_conv(cfg, p, jnp.concatenate([x, Bmat, Cmat], axis=-1))
    x, Bmat, Cmat = jnp.split(xbc, [di, di + ds], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))          # [nh], negative
    dA = dt * A[None, None, :]                            # [B,S,nh] log-decay

    xh = x.reshape(B_, S, nh, hd)
    # chunk views — chunks are sequence-parallel over `model` (intra-chunk
    # work is independent; the inter-chunk state scan is log-depth in n).
    xc = hint(xh.reshape(B_, nchunk, Qe, nh, hd), _DP, "model", None, None, None)
    Bc = hint(Bmat.reshape(B_, nchunk, Qe, ds), _DP, "model", None, None)
    Cc = hint(Cmat.reshape(B_, nchunk, Qe, ds), _DP, "model", None, None)
    dtc = hint(dt.reshape(B_, nchunk, Qe, nh), _DP, "model", None, None)
    dAc = hint(dA.reshape(B_, nchunk, Qe, nh), _DP, "model", None, None)

    seg = jnp.cumsum(dAc, axis=2)                         # [B,n,Q,nh]
    # --- intra-chunk (quadratic within the chunk) ---
    # decay from position j to i (i>=j): exp(seg_i - seg_j). The [Q,Q] plane
    # is streamed in head-blocks so the transient stays VMEM-sized on TPU.
    # §Perf C1: all operands are pre-transposed ONCE to head-leading layout
    # [B,n,h,Q,...] so the per-block slices are contiguous and the block
    # einsums need no internal transposes (the naive trailing-head layout
    # cost ~3 TB/device of transpose traffic at train_4k).
    causal = jnp.tril(jnp.ones((Qe, Qe), bool))
    cb = jnp.einsum("bnis,bnjs->bnij", Cc.astype(jnp.float32),
                    Bc.astype(jnp.float32))               # [B,n,Q,Q]
    hb = min(8, nh)
    assert nh % hb == 0, (nh, hb)
    seg_h = seg.transpose(0, 1, 3, 2)                     # [B,n,nh,Q]
    dtc_h = dtc.transpose(0, 1, 3, 2)                     # [B,n,nh,Q]
    xc_h = xc.transpose(0, 1, 3, 2, 4).astype(cd)         # [B,n,nh,Q,hd]
    y_blocks = []
    for h0 in range(0, nh, hb):
        seg_b = seg_h[:, :, h0 : h0 + hb]                 # [B,n,hb,Q]
        rel = seg_b[..., :, None] - seg_b[..., None, :]   # [B,n,hb,Q,Q]
        gamma = jnp.where(
            causal[None, None, None], jnp.exp(rel), 0.0
        )
        # §Perf C5: the decay-attention plane rides bf16 into the MXU with
        # f32 accumulation (flash-attention numerics) — the f32 operand
        # stream was ~1.5 TB/device of the train_4k memory term.
        att = (cb[:, :, None] * gamma).astype(cd)         # [B,n,hb,Q,Q]
        y_blocks.append(jnp.einsum(
            "bnhij,bnhj,bnhjd->bnhid", att,
            dtc_h[:, :, h0 : h0 + hb].astype(cd),
            xc_h[:, :, h0 : h0 + hb],
            preferred_element_type=jnp.float32,
        ))
    y_intra = jnp.concatenate(y_blocks, axis=2)           # [B,n,nh,Q,hd]
    y_intra = y_intra.transpose(0, 1, 3, 2, 4)            # [B,n,Q,nh,hd]

    # --- inter-chunk state passing ---
    # §Perf C3: the prefix states are a TRIANGULAR MATMUL over chunks, not a
    # scan:  st_n = sum_{m<=n} exp(L_n - L_m) * s_m  with L the cumulative
    # log-decay. n is small (S/Q), so the n^2 weight matrix is tiny and the
    # whole inter-chunk pass rides the MXU — this replaced an
    # associative_scan whose pad/concat/permute lowering moved ~1.5 TB/device
    # at train_4k (the SSD duality applied at the chunk level).
    decay_to_end = jnp.exp(seg[:, :, -1:, :] - seg)       # [B,n,Q,nh]
    chunk_state = jnp.einsum(
        "bnjs,bnjh,bnjh,bnjhd->bnhds",
        Bc.astype(jnp.float32), dtc, decay_to_end, xc.astype(jnp.float32),
    )                                                     # [B,n,nh,hd,ds]
    L = jnp.cumsum(seg[:, :, -1, :], axis=1)              # [B,n,nh] log-decay
    Wd = jnp.exp(L[:, :, None, :] - L[:, None, :, :])     # decay m->n
    tri = jnp.tril(jnp.ones((nchunk, nchunk), bool))
    Wd = jnp.where(tri[None, :, :, None], Wd, 0.0)        # [B,n,m,nh]
    # §Perf C4: pin the output chunk axis sharded — the contraction over the
    # sharded m axis then reduce-scatters its partials instead of
    # all-reducing + re-assembling the full [B,n,nh,hd,ds] tensor.
    st_scan = hint(
        jnp.einsum("bnmh,bmhds->bnhds", Wd, chunk_state),
        _DP, "model", None, None, None,
    )
    # state entering chunk n = scan result of chunks < n
    h_in = jnp.concatenate(
        [jnp.zeros_like(st_scan[:, :1]), st_scan[:, :-1]], axis=1
    )                                                     # [B,n,nh,hd,ds]
    decay_in = jnp.exp(seg)                               # decay 0..i within chunk
    y_inter = jnp.einsum(
        "bnis,bnih,bnhds->bnihd", Cc.astype(jnp.float32), decay_in, h_in
    )

    y = (y_intra + y_inter).reshape(B_, S, nh, hd)
    y = y + xh.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B_, S, di).astype(cd)

    # gated RMSNorm (mamba2: norm(y * silu(z)))
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm"].astype(jnp.float32)).astype(cd)
    return y @ p["out_proj"].astype(cd)


def ssd_decode_step(
    cfg: ModelConfig, p: dict, xin: jax.Array, state: SSDState
) -> tuple[jax.Array, SSDState]:
    """One-token decode. xin: [B, 1, D] -> ([B, 1, D], new state)."""
    cd = jnp.dtype(cfg.compute_dtype)
    di, nh, ds, dc = _dims(cfg)
    hd = cfg.ssm.head_dim
    B_ = xin.shape[0]

    zxbcdt = xin.astype(cd) @ p["in_proj"].astype(cd)
    z, x, Bmat, Cmat, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([x, Bmat, Cmat], axis=-1)       # [B,1,conv_dim]
    xbc_act, new_tail = _causal_conv(cfg, p, xbc, conv_tail=state.conv)
    x, Bmat, Cmat = jnp.split(xbc_act, [di, di + ds], axis=-1)

    dt = jax.nn.softplus(
        dt[:, 0].astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )                                                     # [B,nh]
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt * A[None, :])                         # [B,nh]

    xh = x[:, 0].reshape(B_, nh, hd).astype(jnp.float32)
    Bv = Bmat[:, 0].astype(jnp.float32)                   # [B,ds]
    Cv = Cmat[:, 0].astype(jnp.float32)
    h = state.h * da[:, :, None, None] + jnp.einsum(
        "bhd,bh,bs->bhds", xh, dt, Bv
    )
    y = jnp.einsum("bhds,bs->bhd", h, Cv)
    y = y + xh * p["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B_, 1, di).astype(cd)

    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    y = (yf * jax.lax.rsqrt(jnp.mean(yf * yf, -1, keepdims=True) + 1e-6)
         * p["norm"].astype(jnp.float32)).astype(cd)
    out = y @ p["out_proj"].astype(cd)
    return out, SSDState(h=h, conv=new_tail.astype(state.conv.dtype))
