"""Modality-frontend stubs + input specs per (arch x shape).

Per the brief, [vlm]/[audio] archs specify the transformer BACKBONE only; the
frontend (vision encoder / EnCodec) is a stub that supplies precomputed
patch/frame embeddings. `input_specs` returns ShapeDtypeStructs (weak-type
correct, shardable, zero allocation) for the dry-run; `synthetic_batch`
returns concrete arrays of the same structure for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import transformer


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Abstract inputs for one dry-run cell."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    cd = jnp.dtype(cfg.compute_dtype)

    if shape.kind == "train":
        batch: dict = {}
        if cfg.embeds_input:
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cd)
            batch["labels"] = jax.ShapeDtypeStruct((B, S), i32)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.family == "vlm":
            batch["cross_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_cross_tokens, cfg.d_model), cd
            )
        return batch

    if shape.kind == "prefill":
        batch = {}
        if cfg.embeds_input:
            batch["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), cd)
        else:
            batch["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        if cfg.family == "vlm":
            batch["cross_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_cross_tokens, cfg.d_model), cd
            )
        return batch

    if shape.kind == "decode":
        batch = {"pos": jax.ShapeDtypeStruct((), i32)}
        if cfg.embeds_input:
            batch["embeds"] = jax.ShapeDtypeStruct((B, 1, cfg.d_model), cd)
        else:
            batch["token"] = jax.ShapeDtypeStruct((B, 1), i32)
        batch["cache"] = transformer.cache_struct(cfg, B, S)
        return batch

    raise ValueError(shape.kind)


def synthetic_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Concrete random inputs matching input_specs (smoke tests / examples)."""
    specs = input_specs(cfg, shape)
    rng = np.random.default_rng(seed)

    def fill(s):
        if s.dtype == jnp.int32:
            if s.shape == ():
                return jnp.int32(min(7, shape.seq_len - 1))
            hi = cfg.vocab_size if cfg.vocab_size > 0 else 2
            return jnp.asarray(rng.integers(0, hi, s.shape), dtype=jnp.int32)
        return jnp.asarray(
            0.02 * rng.standard_normal(s.shape), dtype=s.dtype
        )

    return jax.tree.map(
        fill, specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)
    )
