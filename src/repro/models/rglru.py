"""RG-LRU recurrent block (Griffin / RecurrentGemma)  [arXiv:2402.19427].

The recurrence  h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)  is a
diagonal linear recurrence, so full sequences run as a `jax.lax.associative_
scan` (log-depth on TPU) and decode carries a [B, lru_width] state. Gates are
block-diagonal linear maps (RecurrentGemma's `block_width` heads).

Block layout (Griffin "recurrent block"): the residual branch splits into a
GeLU gate branch and a conv1d(4) -> RG-LRU branch, merged multiplicatively
and projected back to d_model.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import PSpec

_C_SCALE = 8.0  # Griffin's fixed recurrence sharpness c


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.expand * cfg.d_model         # lru_width (recurrentgemma: == d_model)
    nb = cfg.n_heads                    # gate block count
    return di, nb, di // nb, s.d_conv


def rglru_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, nb, bw, dc = _dims(cfg)
    return {
        "w_gate_branch": PSpec((d, di), ("embed", "inner")),
        "w_rec_branch": PSpec((d, di), ("embed", "inner")),
        "conv_w": PSpec((dc, di), ("conv", "inner"), "scaled", 0.1),
        "conv_b": PSpec((di,), ("inner",), "zeros"),
        # block-diagonal input/recurrence gates
        "w_a": PSpec((nb, bw, bw), ("ssm_heads", None, None)),
        "b_a": PSpec((di,), ("inner",), "zeros"),
        "w_x": PSpec((nb, bw, bw), ("ssm_heads", None, None)),
        "b_x": PSpec((di,), ("inner",), "zeros"),
        # softplus-parameterised Lambda, init so a^c ~ U[0.9, 0.999]-ish
        "lambda_p": PSpec((di,), ("inner",), "ones"),
        "w_out": PSpec((di, d), ("inner", "embed")),
    }


class RGLRUState(NamedTuple):
    h: jax.Array     # [B, di] recurrent state
    conv: jax.Array  # [B, d_conv-1, di] conv tail


def init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> RGLRUState:
    di, _, _, dc = _dims(cfg)
    return RGLRUState(
        h=jnp.zeros((batch, di), dtype),
        conv=jnp.zeros((batch, dc - 1, di), dtype),
    )


def _block_linear(w, b, x):
    """Block-diagonal linear: x [ ..., di] with w [nb, bw, bw]."""
    nb, bw, _ = w.shape
    xs = x.reshape(x.shape[:-1] + (nb, bw))
    out = jnp.einsum("...nb,nbc->...nc", xs, w.astype(x.dtype))
    return out.reshape(x.shape) + b.astype(x.dtype)


def _gates(cfg, p, xr):
    """Recurrence gate a_t (log-space) and gated input. xr: [..., di] f32."""
    r = jax.nn.sigmoid(_block_linear(p["w_a"], p["b_a"], xr))
    i = jax.nn.sigmoid(_block_linear(p["w_x"], p["b_x"], xr))
    # a = sigmoid(lambda)^(c*r)  -> log a = -c * r * softplus(lambda_p)
    log_a = -_C_SCALE * r * jax.nn.softplus(p["lambda_p"].astype(xr.dtype))
    a = jnp.exp(log_a)
    gated_x = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * xr)
    return a, gated_x


def _causal_conv(cfg, p, x, tail=None):
    dc = cfg.ssm.d_conv
    if tail is None:
        pad = jnp.zeros((x.shape[0], dc - 1, x.shape[2]), x.dtype)
    else:
        pad = tail.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * p["conv_w"][i].astype(x.dtype)
        for i in range(dc)
    ) + p["conv_b"].astype(x.dtype)
    return out, xp[:, xp.shape[1] - (dc - 1) :, :]


def rglru_forward(cfg: ModelConfig, p: dict, xin: jax.Array) -> jax.Array:
    """Full-sequence recurrent block. xin: [B, S, D] -> [B, S, D]."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = xin.astype(cd)
    gate = jax.nn.gelu(x @ p["w_gate_branch"].astype(cd))
    rec = x @ p["w_rec_branch"].astype(cd)
    rec, _ = _causal_conv(cfg, p, rec)

    a, gx = _gates(cfg, p, rec.astype(jnp.float32))
    # h_t = a_t h_{t-1} + gx_t  — associative over the sequence axis.
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    out = (h.astype(cd) * gate) @ p["w_out"].astype(cd)
    return out


def rglru_decode_step(
    cfg: ModelConfig, p: dict, xin: jax.Array, state: RGLRUState
) -> tuple[jax.Array, RGLRUState]:
    """One-token decode. xin: [B, 1, D]."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = xin.astype(cd)
    gate = jax.nn.gelu(x @ p["w_gate_branch"].astype(cd))
    rec = x @ p["w_rec_branch"].astype(cd)
    rec, new_tail = _causal_conv(cfg, p, rec, tail=state.conv)

    a, gx = _gates(cfg, p, rec[:, 0].astype(jnp.float32))
    h = a * state.h + gx                                   # [B, di]
    out = (h[:, None, :].astype(cd) * gate) @ p["w_out"].astype(cd)
    return out, RGLRUState(h=h, conv=new_tail.astype(state.conv.dtype))
