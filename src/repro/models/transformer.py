"""Pattern-based decoder stacks for all assigned architectures.

A model is `n_layers` of per-kind blocks (GLOBAL/LOCAL/CROSS attention,
RGLRU, SSD) described by `cfg.layer_pattern`. Identical super-blocks (one
repetition of the pattern) are **stacked and scanned** (`jax.lax.scan`), so
HLO size — and therefore 512-device dry-run compile time and real multi-pod
compile time — is O(pattern) instead of O(depth). Pattern remainders are
unrolled.

Three temporal modes:
  forward     — full sequence (training, and the prefill_32k dry-run shape)
  prefill     — forward + KV/state cache construction (serving)
  decode_step — one token against the cache (decode_32k / long_500k shapes)

Sliding-window layers keep **window-sized rotating caches** (slot = pos %
window), so gemma3-1b's long_500k cell stores 512-token caches for local
layers instead of 524288-token ones.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.configs.base import (
    CROSS, GLOBAL, LOCAL, RGLRU, SSD, ModelConfig,
)
from repro.distributed.autoshard import hint
from repro.models import layers, moe, rglru, ssm
from repro.models.params import PSpec, stack_specs

# Residual-stream sharding: batch over DP axes, sequence over `model`
# (Megatron-style sequence parallelism — elementwise/norm work stays SP,
# GSPMD inserts the gather/scatter around attention/MoE). No-op without an
# active mesh; dims that don't divide fall back to replication.
_DP = ("pod", "data")


def _shard_stream(x: jax.Array) -> jax.Array:
    if x.ndim == 3:
        return hint(x, _DP, "model", None)
    return x

# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------


def block_specs(cfg: ModelConfig, kind: str) -> dict:
    if kind in (GLOBAL, LOCAL):
        ffn = moe.moe_specs(cfg) if cfg.moe is not None else layers.mlp_specs(cfg)
        return {
            "ln1": layers.norm_specs(cfg),
            "attn": layers.attention_specs(cfg),
            "ln2": layers.norm_specs(cfg),
            "ffn": ffn,
        }
    if kind == CROSS:
        # Gated cross-attention layer (llama-3.2-vision style insertion).
        return {
            "ln1": layers.norm_specs(cfg),
            "xattn": layers.attention_specs(cfg, gated=True),
            "ln2": layers.norm_specs(cfg),
            "ffn": layers.mlp_specs(cfg),
            "ffn_gate": PSpec((), (), "zeros"),
        }
    if kind == RGLRU:
        return {
            "ln1": layers.norm_specs(cfg),
            "rec": rglru.rglru_specs(cfg),
            "ln2": layers.norm_specs(cfg),
            "ffn": layers.mlp_specs(cfg),
        }
    if kind == SSD:
        return {"ln1": layers.norm_specs(cfg), "mamba": ssm.ssd_specs(cfg)}
    raise ValueError(kind)


def _pattern_split(cfg: ModelConfig) -> tuple[int, int]:
    P = len(cfg.layer_pattern)
    return cfg.n_layers // P, cfg.n_layers % P


def model_specs(cfg: ModelConfig) -> dict:
    d, v = cfg.d_model, cfg.vocab_size
    n_super, n_rem = _pattern_split(cfg)
    sp: dict = {}
    if not cfg.embeds_input:
        sp["embed"] = PSpec((v, d), ("vocab", "embed"), "scaled", 0.02)
    if n_super > 0:
        sp["blocks"] = {
            f"pos{i}": stack_specs(block_specs(cfg, k), n_super, "layers")
            for i, k in enumerate(cfg.layer_pattern)
        }
    if n_rem:
        sp["rem"] = {
            f"rem{i}": block_specs(cfg, cfg.layer_pattern[i])
            for i in range(n_rem)
        }
    sp["final_norm"] = layers.norm_specs(cfg)
    if not cfg.tie_embeddings:
        sp["head"] = PSpec((d, v), ("embed", "vocab"), "scaled", 0.02)
    return sp


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill logits)
# ---------------------------------------------------------------------------


def _apply_block(cfg, kind, p, x, cross_embeds, num_groups):
    """One layer. Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    if kind in (GLOBAL, LOCAL):
        w = cfg.sliding_window if kind == LOCAL else None
        x = x + layers.self_attention(cfg, p["attn"],
                                      layers.norm(cfg, p["ln1"], x), window=w)
        h = layers.norm(cfg, p["ln2"], x)
        if cfg.moe is not None:
            f, aux = moe.moe_ffn(cfg, p["ffn"], h, num_groups=num_groups)
        else:
            f = layers.mlp(cfg, p["ffn"], h)
        x = x + f
    elif kind == CROSS:
        if cross_embeds is None:
            raise ValueError("CROSS layer requires cross_embeds")
        x = x + layers.cross_attention(
            cfg, p["xattn"], layers.norm(cfg, p["ln1"], x), cross_embeds
        )
        h = layers.norm(cfg, p["ln2"], x)
        x = x + layers.mlp(cfg, p["ffn"], h) * jnp.tanh(
            p["ffn_gate"].astype(x.dtype)
        )
    elif kind == RGLRU:
        x = x + rglru.rglru_forward(cfg, p["rec"], layers.norm(cfg, p["ln1"], x))
        x = x + layers.mlp(cfg, p["ffn"], layers.norm(cfg, p["ln2"], x))
    elif kind == SSD:
        x = x + ssm.ssd_forward(cfg, p["mamba"], layers.norm(cfg, p["ln1"], x))
    else:
        raise ValueError(kind)
    return x, aux


def _maybe_remat(cfg, fn):
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return fn


def embed_inputs(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    cd = jnp.dtype(cfg.compute_dtype)
    if cfg.embeds_input:
        return batch["embeds"].astype(cd)
    return params["embed"].astype(cd)[batch["tokens"]]


def unembed(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = layers.norm(cfg, params["final_norm"], x)
    w = (params["embed"].T if cfg.tie_embeddings else params["head"])
    return (x @ w.astype(x.dtype)).astype(jnp.float32)


def forward(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    *,
    num_groups: int = 1,
) -> tuple[jax.Array, jax.Array]:
    """Full-sequence forward. batch: {tokens|embeds, cross_embeds?}.

    Returns (logits [B,S,V] f32, aux_loss scalar).
    """
    x = embed_inputs(cfg, params, batch)
    cross = batch.get("cross_embeds")
    if cross is not None:
        cross = cross.astype(x.dtype)
    n_super, n_rem = _pattern_split(cfg)
    aux_total = jnp.float32(0.0)

    if n_super > 0:
        def super_block(h, blk):
            aux = jnp.float32(0.0)
            h = _shard_stream(h)
            for i, kind in enumerate(cfg.layer_pattern):
                h, a = _apply_block(cfg, kind, blk[f"pos{i}"], h, cross,
                                    num_groups)
                aux = aux + a
            return _shard_stream(h), aux

        body = _maybe_remat(cfg, super_block)
        x, auxes = jax.lax.scan(lambda h, blk: body(h, blk), x, params["blocks"])
        aux_total = aux_total + jnp.sum(auxes)

    for i in range(n_rem):
        x, a = _apply_block(
            cfg, cfg.layer_pattern[i], params["rem"][f"rem{i}"], x, cross,
            num_groups,
        )
        aux_total = aux_total + a
    x = _shard_stream(x)

    return unembed(cfg, params, x), aux_total


def loss_fn(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    *,
    num_groups: int = 1,
) -> tuple[jax.Array, dict]:
    """Next-token (or provided-labels) cross-entropy + router aux."""
    logits, aux = forward(cfg, params, batch, num_groups=num_groups)
    if "labels" in batch:
        labels = batch["labels"]
        valid = jnp.ones(labels.shape, dtype=jnp.float32)
    else:
        labels = batch["tokens"][:, 1:]
        logits = logits[:, :-1]
        valid = jnp.ones(labels.shape, dtype=jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.sum((lse - gold) * valid) / jnp.maximum(jnp.sum(valid), 1.0)
    return ce + aux, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Cache + decode
# ---------------------------------------------------------------------------


def _layer_cache_struct(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    """Abstract cache shapes for one layer (concrete zeros built by caller)."""
    hkv, dh = cfg.n_kv_heads, cfg.head_dim
    cd = jnp.dtype(cfg.compute_dtype)
    if kind == GLOBAL:
        return {
            "k": jax.ShapeDtypeStruct((batch, max_seq, hkv, dh), cd),
            "v": jax.ShapeDtypeStruct((batch, max_seq, hkv, dh), cd),
        }
    if kind == LOCAL:
        w = min(cfg.sliding_window, max_seq)
        return {
            "k": jax.ShapeDtypeStruct((batch, w, hkv, dh), cd),
            "v": jax.ShapeDtypeStruct((batch, w, hkv, dh), cd),
        }
    if kind == CROSS:
        n = max(cfg.n_cross_tokens, 1)
        return {
            "ck": jax.ShapeDtypeStruct((batch, n, hkv, dh), cd),
            "cv": jax.ShapeDtypeStruct((batch, n, hkv, dh), cd),
        }
    if kind == RGLRU:
        di = cfg.ssm.expand * cfg.d_model
        dc = cfg.ssm.d_conv
        return {
            "h": jax.ShapeDtypeStruct((batch, di), jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, dc - 1, di), cd),
        }
    if kind == SSD:
        di = cfg.ssm.expand * cfg.d_model
        nh = di // cfg.ssm.head_dim
        ds, dc = cfg.ssm.d_state, cfg.ssm.d_conv
        return {
            "h": jax.ShapeDtypeStruct((batch, nh, cfg.ssm.head_dim, ds),
                                      jnp.float32),
            "conv": jax.ShapeDtypeStruct((batch, dc - 1, di + 2 * ds), cd),
        }
    raise ValueError(kind)


def cache_struct(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    """Abstract cache tree (ShapeDtypeStructs) matching params structure."""
    n_super, n_rem = _pattern_split(cfg)
    out: dict = {}
    if n_super > 0:
        out["blocks"] = {}
        for i, kind in enumerate(cfg.layer_pattern):
            leaf = _layer_cache_struct(cfg, kind, batch, max_seq)
            out["blocks"][f"pos{i}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((n_super,) + s.shape, s.dtype),
                leaf,
            )
    if n_rem:
        out["rem"] = {
            f"rem{i}": _layer_cache_struct(cfg, cfg.layer_pattern[i], batch,
                                           max_seq)
            for i in range(n_rem)
        }
    return out


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        cache_struct(cfg, batch, max_seq),
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def _decode_block(cfg, kind, p, x, cache, pos, cross_embeds, idx=None,
                  num_groups=1):
    """One layer, one token. ``cache`` leaves may carry a stacked leading
    layer dim (idx selects the layer — updates go straight into the stacked
    buffer so the scan carry aliases in place). Returns (x, new_cache)."""

    def read(leaf):
        if idx is None:
            return leaf
        return jax.lax.dynamic_index_in_dim(leaf, idx, 0, keepdims=False)

    def write(buf, new):
        if idx is None:
            return new
        return jax.lax.dynamic_update_index_in_dim(
            buf, new.astype(buf.dtype), idx, 0)

    if kind in (GLOBAL, LOCAL):
        h = layers.norm(cfg, p["ln1"], x)
        a, ck, cv = layers.decode_attention_stacked(
            cfg, p["attn"], h, cache["k"], cache["v"], idx, pos,
            local=(kind == LOCAL),
        )
        x = x + a
        h2 = layers.norm(cfg, p["ln2"], x)
        if cfg.moe is not None:
            f, _ = moe.moe_ffn(cfg, p["ffn"], h2, num_groups=num_groups)
        else:
            f = layers.mlp(cfg, p["ffn"], h2)
        return x + f, {"k": ck, "v": cv}
    if kind == CROSS:
        ck, cv = read(cache["ck"]), read(cache["cv"])
        h = layers.norm(cfg, p["ln1"], x)
        cd = jnp.dtype(cfg.compute_dtype)
        # cross K/V were projected at prefill; attend directly (read-only).
        q = jnp.einsum("bsd,dhk->bshk", h.astype(cd), p["xattn"]["wq"].astype(cd))
        if "bq" in p["xattn"]:
            q = q + p["xattn"]["bq"].astype(cd)
        mask = jnp.zeros((1, 1, 1, ck.shape[1]), jnp.float32)
        out = layers._gqa_scores_out(cfg, q, ck.astype(cd), cv.astype(cd),
                                     mask)
        out = jnp.einsum("bshk,hkd->bsd", out, p["xattn"]["wo"].astype(cd))
        x = x + out * jnp.tanh(p["xattn"]["gate"].astype(cd))
        h2 = layers.norm(cfg, p["ln2"], x)
        x = x + layers.mlp(cfg, p["ffn"], h2) * jnp.tanh(
            p["ffn_gate"].astype(cd)
        )
        return x, cache
    if kind == RGLRU:
        st = rglru.RGLRUState(h=read(cache["h"]), conv=read(cache["conv"]))
        out, st = rglru.rglru_decode_step(
            cfg, p["rec"], layers.norm(cfg, p["ln1"], x), st
        )
        x = x + out
        x = x + layers.mlp(cfg, p["ffn"], layers.norm(cfg, p["ln2"], x))
        return x, {"h": write(cache["h"], st.h),
                   "conv": write(cache["conv"], st.conv)}
    if kind == SSD:
        st = ssm.SSDState(h=read(cache["h"]), conv=read(cache["conv"]))
        out, st = ssm.ssd_decode_step(
            cfg, p["mamba"], layers.norm(cfg, p["ln1"], x), st
        )
        return x + out, {"h": write(cache["h"], st.h),
                         "conv": write(cache["conv"], st.conv)}
    raise ValueError(kind)


def decode_step(
    cfg: ModelConfig,
    params: dict,
    batch: dict,     # {token: [B,1] i32 | embeds: [B,1,D], pos: scalar i32}
    cache: dict,
) -> tuple[jax.Array, dict]:
    """One decode step for the whole stack. Returns (logits [B,V], cache)."""
    pos = batch["pos"]
    if cfg.embeds_input:
        x = batch["embeds"].astype(jnp.dtype(cfg.compute_dtype))
    else:
        x = params["embed"].astype(jnp.dtype(cfg.compute_dtype))[batch["token"]]
    cross = None  # cross K/V live in the cache during decode
    n_super, n_rem = _pattern_split(cfg)
    new_cache: dict = {}

    if n_super > 0:
        # The stacked cache rides the scan CARRY (sliced/updated in place per
        # layer) rather than xs/ys — XLA aliases carry buffers across while
        # iterations, so the multi-GB cache is never copied per step.
        def body(carry, xs):
            h, cch = carry
            blk, idx = xs
            for i, kind in enumerate(cfg.layer_pattern):
                cch = dict(cch)
                h, cch[f"pos{i}"] = _decode_block(
                    cfg, kind, blk[f"pos{i}"], h, cch[f"pos{i}"], pos,
                    cross, idx=idx,
                )
            return (h, cch), None

        idxs = jnp.arange(n_super, dtype=jnp.int32)
        (x, nc), _ = jax.lax.scan(
            body, (x, cache["blocks"]), (params["blocks"], idxs)
        )
        new_cache["blocks"] = nc

    if n_rem:
        new_cache["rem"] = {}
        for i in range(n_rem):
            kind = cfg.layer_pattern[i]
            x, c = _decode_block(cfg, kind, params["rem"][f"rem{i}"], x,
                                 cache["rem"][f"rem{i}"], pos, cross)
            new_cache["rem"][f"rem{i}"] = c

    logits = unembed(cfg, params, x)[:, 0, :]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill: forward + cache construction
# ---------------------------------------------------------------------------


def _prefill_block(cfg, kind, p, x, pos0, cross_embeds, batch_size, max_seq):
    """Layer forward that also emits its decode cache."""
    cd = jnp.dtype(cfg.compute_dtype)
    if kind in (GLOBAL, LOCAL):
        w = cfg.sliding_window if kind == LOCAL else None
        h = layers.norm(cfg, p["ln1"], x)
        S = h.shape[1]
        q, k, v = layers._project_qkv(cfg, p["attn"], h)
        pos = jnp.arange(S)
        q = layers.rope(q, pos, cfg.rope_theta)
        k = layers.rope(k, pos, cfg.rope_theta)
        a = layers.gqa_attention(cfg, q, k, v, window=w)
        x = x + jnp.einsum("bshk,hkd->bsd", a, p["attn"]["wo"].astype(cd))
        h2 = layers.norm(cfg, p["ln2"], x)
        if cfg.moe is not None:
            f, _ = moe.moe_ffn(cfg, p["ffn"], h2, num_groups=1)
        else:
            f = layers.mlp(cfg, p["ffn"], h2)
        x = x + f
        if kind == GLOBAL:
            kv_hint = ((_DP, "model", None, None) if max_seq >= 4096
                       else (_DP, None, None, "model"))
            ck = jnp.zeros((batch_size, max_seq) + k.shape[2:], cd)
            ck = jax.lax.dynamic_update_slice(ck, k.astype(cd), (0, 0, 0, 0))
            cv = jnp.zeros((batch_size, max_seq) + v.shape[2:], cd)
            cv = jax.lax.dynamic_update_slice(cv, v.astype(cd), (0, 0, 0, 0))
            ck, cv = hint(ck, *kv_hint), hint(cv, *kv_hint)
        else:
            W = min(cfg.sliding_window, max_seq)
            # last W entries, placed at their rotating slots (abs % W)
            kw, vw = k[:, -W:], v[:, -W:]
            slots = jnp.mod(jnp.arange(S)[-W:] if S >= W
                            else jnp.arange(S), W)
            ck = jnp.zeros((batch_size, W) + k.shape[2:], cd)
            cv = jnp.zeros((batch_size, W) + v.shape[2:], cd)
            ck = ck.at[:, slots].set(kw.astype(cd))
            cv = cv.at[:, slots].set(vw.astype(cd))
        return x, {"k": ck, "v": cv}
    if kind == CROSS:
        h = layers.norm(cfg, p["ln1"], x)
        x = x + layers.cross_attention(cfg, p["xattn"], h, cross_embeds)
        h2 = layers.norm(cfg, p["ln2"], x)
        x = x + layers.mlp(cfg, p["ffn"], h2) * jnp.tanh(
            p["ffn_gate"].astype(cd)
        )
        _, ck, cv = layers._project_qkv(cfg, p["xattn"], x, xkv=cross_embeds)
        return x, {"ck": ck.astype(cd), "cv": cv.astype(cd)}
    if kind == RGLRU:
        # run full-seq then recompute final state via a short decode replay of
        # the last d_conv tokens for the conv tail + a full scan for h.
        h_in = layers.norm(cfg, p["ln1"], x)
        out = rglru.rglru_forward(cfg, p["rec"], h_in)
        x = x + out
        x = x + layers.mlp(cfg, p["ffn"], layers.norm(cfg, p["ln2"], x))
        st = _rglru_final_state(cfg, p["rec"], h_in)
        return x, {"h": st.h, "conv": st.conv}
    if kind == SSD:
        h_in = layers.norm(cfg, p["ln1"], x)
        x = x + ssm.ssd_forward(cfg, p["mamba"], h_in)
        st = _ssd_final_state(cfg, p["mamba"], h_in)
        return x, {"h": st.h, "conv": st.conv}
    raise ValueError(kind)


def _rglru_final_state(cfg, p, xin):
    """Final (h, conv tail) after consuming xin — for prefill->decode handoff."""
    cd = jnp.dtype(cfg.compute_dtype)
    x = xin.astype(cd)
    rec = x @ p["w_rec_branch"].astype(cd)
    rec_c, tail = rglru._causal_conv(cfg, p, rec)
    a, gx = rglru._gates(cfg, p, rec_c.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, h = jax.lax.associative_scan(combine, (a, gx), axis=1)
    return rglru.RGLRUState(h=h[:, -1], conv=tail)


def _ssd_final_state(cfg, p, xin):
    """Final SSD state after consuming xin (chunked state recurrence)."""
    cd = jnp.dtype(cfg.compute_dtype)
    di = cfg.ssm.expand * cfg.d_model
    nh = di // cfg.ssm.head_dim
    ds = cfg.ssm.d_state
    B_, S, _ = xin.shape
    zxbcdt = xin.astype(cd) @ p["in_proj"].astype(cd)
    _, x, Bmat, Cmat, dt = ssm._split_proj(cfg, zxbcdt)
    xbc, tail = ssm._causal_conv(
        cfg, p, jnp.concatenate([x, Bmat, Cmat], axis=-1)
    )
    x, Bmat, _ = jnp.split(xbc, [di, di + ds], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["a_log"].astype(jnp.float32))
    dA = dt * A[None, None, :]
    seg = jnp.cumsum(dA, axis=1)
    decay_to_end = jnp.exp(seg[:, -1:, :] - seg)
    xh = x.reshape(B_, S, nh, cfg.ssm.head_dim).astype(jnp.float32)
    h = jnp.einsum("bts,bth,bth,bthd->bhds",
                   Bmat.astype(jnp.float32), dt, decay_to_end, xh)
    return ssm.SSDState(h=h, conv=tail.astype(jnp.float32))


def prefill(
    cfg: ModelConfig,
    params: dict,
    batch: dict,
    max_seq: int,
) -> tuple[jax.Array, dict]:
    """Consume the prompt; return (last-position logits [B,V], decode cache)."""
    x = embed_inputs(cfg, params, batch)
    B = x.shape[0]
    cross = batch.get("cross_embeds")
    if cross is not None:
        cross = cross.astype(x.dtype)
    n_super, n_rem = _pattern_split(cfg)
    cache: dict = {}

    if n_super > 0:
        def body(h, blk):
            cs = {}
            h = _shard_stream(h)
            for i, kind in enumerate(cfg.layer_pattern):
                h, c = _prefill_block(cfg, kind, blk[f"pos{i}"], h, 0, cross,
                                      B, max_seq)
                cs[f"pos{i}"] = c
            return _shard_stream(h), cs

        x, cs = jax.lax.scan(body, x, params["blocks"])
        cache["blocks"] = cs

    if n_rem:
        cache["rem"] = {}
        for i in range(n_rem):
            kind = cfg.layer_pattern[i]
            x, c = _prefill_block(cfg, kind, params["rem"][f"rem{i}"], x, 0,
                                  cross, B, max_seq)
            cache["rem"][f"rem{i}"] = c

    logits = unembed(cfg, params, x)[:, -1, :]
    return logits, cache
