"""The iris dataset (Fisher 1936 / UCI) + TM booleanization.

The paper's experiments use iris with *16 booleanised inputs, 3 classes, 150
unique datapoints*. We embed the canonical dataset (sepal length/width, petal
length/width in cm; classes setosa=0, versicolor=1, virginica=2) and
booleanise each of the 4 features with a 4-level thermometer code against
per-feature quantile thresholds => 4 x 4 = 16 boolean inputs, matching the
paper's input width.
"""
from __future__ import annotations

import numpy as np

# 150 rows x (sepal_len, sepal_wid, petal_len, petal_wid), class-major
# (50 setosa, 50 versicolor, 50 virginica) — canonical UCI ordering.
_IRIS = np.array([
    [5.1, 3.5, 1.4, 0.2], [4.9, 3.0, 1.4, 0.2], [4.7, 3.2, 1.3, 0.2],
    [4.6, 3.1, 1.5, 0.2], [5.0, 3.6, 1.4, 0.2], [5.4, 3.9, 1.7, 0.4],
    [4.6, 3.4, 1.4, 0.3], [5.0, 3.4, 1.5, 0.2], [4.4, 2.9, 1.4, 0.2],
    [4.9, 3.1, 1.5, 0.1], [5.4, 3.7, 1.5, 0.2], [4.8, 3.4, 1.6, 0.2],
    [4.8, 3.0, 1.4, 0.1], [4.3, 3.0, 1.1, 0.1], [5.8, 4.0, 1.2, 0.2],
    [5.7, 4.4, 1.5, 0.4], [5.4, 3.9, 1.3, 0.4], [5.1, 3.5, 1.4, 0.3],
    [5.7, 3.8, 1.7, 0.3], [5.1, 3.8, 1.5, 0.3], [5.4, 3.4, 1.7, 0.2],
    [5.1, 3.7, 1.5, 0.4], [4.6, 3.6, 1.0, 0.2], [5.1, 3.3, 1.7, 0.5],
    [4.8, 3.4, 1.9, 0.2], [5.0, 3.0, 1.6, 0.2], [5.0, 3.4, 1.6, 0.4],
    [5.2, 3.5, 1.5, 0.2], [5.2, 3.4, 1.4, 0.2], [4.7, 3.2, 1.6, 0.2],
    [4.8, 3.1, 1.6, 0.2], [5.4, 3.4, 1.5, 0.4], [5.2, 4.1, 1.5, 0.1],
    [5.5, 4.2, 1.4, 0.2], [4.9, 3.1, 1.5, 0.2], [5.0, 3.2, 1.2, 0.2],
    [5.5, 3.5, 1.3, 0.2], [4.9, 3.6, 1.4, 0.1], [4.4, 3.0, 1.3, 0.2],
    [5.1, 3.4, 1.5, 0.2], [5.0, 3.5, 1.3, 0.3], [4.5, 2.3, 1.3, 0.3],
    [4.4, 3.2, 1.3, 0.2], [5.0, 3.5, 1.6, 0.6], [5.1, 3.8, 1.9, 0.4],
    [4.8, 3.0, 1.4, 0.3], [5.1, 3.8, 1.6, 0.2], [4.6, 3.2, 1.4, 0.2],
    [5.3, 3.7, 1.5, 0.2], [5.0, 3.3, 1.4, 0.2],
    [7.0, 3.2, 4.7, 1.4], [6.4, 3.2, 4.5, 1.5], [6.9, 3.1, 4.9, 1.5],
    [5.5, 2.3, 4.0, 1.3], [6.5, 2.8, 4.6, 1.5], [5.7, 2.8, 4.5, 1.3],
    [6.3, 3.3, 4.7, 1.6], [4.9, 2.4, 3.3, 1.0], [6.6, 2.9, 4.6, 1.3],
    [5.2, 2.7, 3.9, 1.4], [5.0, 2.0, 3.5, 1.0], [5.9, 3.0, 4.2, 1.5],
    [6.0, 2.2, 4.0, 1.0], [6.1, 2.9, 4.7, 1.4], [5.6, 2.9, 3.6, 1.3],
    [6.7, 3.1, 4.4, 1.4], [5.6, 3.0, 4.5, 1.5], [5.8, 2.7, 4.1, 1.0],
    [6.2, 2.2, 4.5, 1.5], [5.6, 2.5, 3.9, 1.1], [5.9, 3.2, 4.8, 1.8],
    [6.1, 2.8, 4.0, 1.3], [6.3, 2.5, 4.9, 1.5], [6.1, 2.8, 4.7, 1.2],
    [6.4, 2.9, 4.3, 1.3], [6.6, 3.0, 4.4, 1.4], [6.8, 2.8, 4.8, 1.4],
    [6.7, 3.0, 5.0, 1.7], [6.0, 2.9, 4.5, 1.5], [5.7, 2.6, 3.5, 1.0],
    [5.5, 2.4, 3.8, 1.1], [5.5, 2.4, 3.7, 1.0], [5.8, 2.7, 3.9, 1.2],
    [6.0, 2.7, 5.1, 1.6], [5.4, 3.0, 4.5, 1.5], [6.0, 3.4, 4.5, 1.6],
    [6.7, 3.1, 4.7, 1.5], [6.3, 2.3, 4.4, 1.3], [5.6, 3.0, 4.1, 1.3],
    [5.5, 2.5, 4.0, 1.3], [5.5, 2.6, 4.4, 1.2], [6.1, 3.0, 4.6, 1.4],
    [5.8, 2.6, 4.0, 1.2], [5.0, 2.3, 3.3, 1.0], [5.6, 2.7, 4.2, 1.3],
    [5.7, 3.0, 4.2, 1.2], [5.7, 2.9, 4.2, 1.3], [6.2, 2.9, 4.3, 1.3],
    [5.1, 2.5, 3.0, 1.1], [5.7, 2.8, 4.1, 1.3],
    [6.3, 3.3, 6.0, 2.5], [5.8, 2.7, 5.1, 1.9], [7.1, 3.0, 5.9, 2.1],
    [6.3, 2.9, 5.6, 1.8], [6.5, 3.0, 5.8, 2.2], [7.6, 3.0, 6.6, 2.1],
    [4.9, 2.5, 4.5, 1.7], [7.3, 2.9, 6.3, 1.8], [6.7, 2.5, 5.8, 1.8],
    [7.2, 3.6, 6.1, 2.5], [6.5, 3.2, 5.1, 2.0], [6.4, 2.7, 5.3, 1.9],
    [6.8, 3.0, 5.5, 2.1], [5.7, 2.5, 5.0, 2.0], [5.8, 2.8, 5.1, 2.4],
    [6.4, 3.2, 5.3, 2.3], [6.5, 3.0, 5.5, 1.8], [7.7, 3.8, 6.7, 2.2],
    [7.7, 2.6, 6.9, 2.3], [6.0, 2.2, 5.0, 1.5], [6.9, 3.2, 5.7, 2.3],
    [5.6, 2.8, 4.9, 2.0], [7.7, 2.8, 6.7, 2.0], [6.3, 2.7, 4.9, 1.8],
    [6.7, 3.3, 5.7, 2.1], [7.2, 3.2, 6.0, 1.8], [6.2, 2.8, 4.8, 1.8],
    [6.1, 3.0, 4.9, 1.8], [6.4, 2.8, 5.6, 2.1], [7.2, 3.0, 5.8, 1.6],
    [7.4, 2.8, 6.1, 1.9], [7.9, 3.8, 6.4, 2.0], [6.4, 2.8, 5.6, 2.2],
    [6.3, 2.8, 5.1, 1.5], [6.1, 2.6, 5.6, 1.4], [7.7, 3.0, 6.1, 2.3],
    [6.3, 3.4, 5.6, 2.4], [6.4, 3.1, 5.5, 1.8], [6.0, 3.0, 4.8, 1.8],
    [6.9, 3.1, 5.4, 2.1], [6.7, 3.1, 5.6, 2.4], [6.9, 3.1, 5.1, 2.3],
    [5.8, 2.7, 5.1, 1.9], [6.8, 3.2, 5.9, 2.3], [6.7, 3.3, 5.7, 2.5],
    [6.7, 3.0, 5.2, 2.3], [6.3, 2.5, 5.0, 1.9], [6.5, 3.0, 5.2, 2.0],
    [6.2, 3.4, 5.4, 2.3], [5.9, 3.0, 5.1, 1.8],
])
_LABELS = np.repeat(np.arange(3), 50)

N_FEATURES_RAW = 4
N_THERMOMETER_BITS = 4
N_BOOL_FEATURES = N_FEATURES_RAW * N_THERMOMETER_BITS  # 16, as in the paper
N_CLASSES = 3
N_POINTS = 150


def raw() -> tuple[np.ndarray, np.ndarray]:
    """(features [150,4] f32, labels [150] i32)."""
    return _IRIS.astype(np.float32).copy(), _LABELS.astype(np.int32).copy()


def thermometer_thresholds(x: np.ndarray, n_bits: int = N_THERMOMETER_BITS) -> np.ndarray:
    """Per-feature quantile thresholds [f, n_bits] (20/40/60/80th pct for 4 bits)."""
    qs = np.linspace(0, 100, n_bits + 2)[1:-1]
    return np.percentile(x, qs, axis=0).T  # [f, n_bits]


def booleanize(x: np.ndarray, thresholds: np.ndarray) -> np.ndarray:
    """Thermometer-encode: bit b of feature f is (x_f >= thresholds[f, b])."""
    return (x[:, :, None] >= thresholds[None, :, :]).reshape(x.shape[0], -1)


def load(seed: int = 2023) -> tuple[np.ndarray, np.ndarray]:
    """Booleanized iris, deterministically shuffled.

    The paper's block cross-validation needs class-mixed blocks (the raw UCI
    file is class-major); a fixed-seed shuffle gives every 30-row block a
    representative class mix, mirroring the paper's stratification intent.

    Returns (xs [150,16] bool, ys [150] int32).
    """
    x, y = raw()
    thr = thermometer_thresholds(x)
    xb = booleanize(x, thr)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(N_POINTS)
    return xb[perm].astype(bool), y[perm].astype(np.int32)
