"""Data-source abstraction (paper §3.4.2 offline memory manager, §3.5 online
input subsystem).

The TM-management FSM requests rows through a narrow interface; the concrete
source (block ROM, microcontroller stream, sensor IP...) is swappable without
touching the management logic. We keep that layering: ``DataSource`` is the
interface, ``ROMSource`` mirrors the paper's on-chip ROM with a cyclic
cross-correlation read pattern, ``StreamSource`` wraps a host iterator (the
microcontroller/UART path).
"""
from __future__ import annotations

from typing import Iterator, Protocol

import numpy as np


class DataSource(Protocol):
    n_features: int

    def next_row(self) -> tuple[np.ndarray, int]:
        """Return (x [f] bool, y int). Sources are infinite (cyclic)."""
        ...


class ROMSource:
    """Cyclic reader over an in-memory array — the paper's on-chip ROM."""

    def __init__(self, xs: np.ndarray, ys: np.ndarray):
        assert len(xs) == len(ys) and len(xs) > 0
        self.xs = np.asarray(xs, dtype=bool)
        self.ys = np.asarray(ys, dtype=np.int32)
        self.n_features = self.xs.shape[1]
        self._i = 0

    def next_row(self) -> tuple[np.ndarray, int]:
        x, y = self.xs[self._i], int(self.ys[self._i])
        self._i = (self._i + 1) % len(self.xs)
        return x, y


class StreamSource:
    """Wraps a host iterator of (x, y) pairs (microcontroller/UART analogue)."""

    def __init__(self, it: Iterator[tuple[np.ndarray, int]], n_features: int):
        self._it = it
        self.n_features = n_features

    def next_row(self) -> tuple[np.ndarray, int]:
        return next(self._it)
