"""MNIST-scale booleanized digit workload (procedural, dependency-free).

The paper's FPGA architecture targets edge workloads where the datapath
*width* dominates; booleanized MNIST (28x28 -> 784 boolean inputs, 10
classes) is the standard TM hardware benchmark at that width (MATADOR,
the runtime-tunable eFPGA TMs). Real MNIST cannot ship in-repo and may
not be downloaded in CI, so this module *generates* an MNIST-shaped
workload deterministically:

* each digit 0-9 is a glyph — a set of strokes (line segments) in the
  unit square, seven-segment geometry plus digit-specific diagonals so
  classes stay separable even at 7x7;
* each sample rasterizes its glyph onto an ``side x side`` grayscale
  grid under a per-sample random affine jitter (translate/scale/rotate),
  stroke-thickness jitter and additive pixel noise — every draw comes
  from ``SeedSequence([seed, index])``, so sample ``i`` is bitwise
  reproducible across processes and machines;
* per-pixel threshold booleanization (``pixel >= THRESHOLD``) yields
  ``f = side*side`` boolean inputs — f=784 at the paper-benchmark width,
  and the ``side`` knob scales the SAME workload down (14x14 -> f=196,
  7x7 -> f=49, 4x4 -> f=16 = iris width) for tests and benchmarks.

Labels depend only on ``(n, seed)`` — never on ``side`` — so a downscaled
run is the same classification problem at a narrower datapath
(tests/test_data.py holds a hypothesis property to this).

The public API mirrors ``data/iris.py``: ``load`` returns
``(xs [n, f] bool, ys [n] i32)``; ``splits`` adds the seeded train/test
split the online-serving flows feed from.
"""
from __future__ import annotations

import numpy as np

SIDE = 28                       # the paper-benchmark raster width
N_CLASSES = 10
N_BOOL_FEATURES = SIDE * SIDE   # 784 boolean inputs at full width
THRESHOLD = 0.5                 # booleanization threshold (inclusive: >=)
N_POINTS = 150                  # default load() size — mirrors iris's 150
                                # rows so every block-CV flow (5 blocks of
                                # 30, sets 30/60/60) transfers unchanged

# Seven-segment stroke geometry in the unit square (x right, y down):
#   A top, B top-right, C bottom-right, D bottom, E bottom-left,
#   F top-left, G middle — plus digit-specific diagonals/flags so the
#   ten classes differ in stroke topology, not just segment subsets.
_X0, _X1 = 0.28, 0.72
_Y0, _Y1, _Y2 = 0.16, 0.50, 0.84
_SEG = {
    "A": ((_X0, _Y0), (_X1, _Y0)),
    "B": ((_X1, _Y0), (_X1, _Y1)),
    "C": ((_X1, _Y1), (_X1, _Y2)),
    "D": ((_X0, _Y2), (_X1, _Y2)),
    "E": ((_X0, _Y1), (_X0, _Y2)),
    "F": ((_X0, _Y0), (_X0, _Y1)),
    "G": ((_X0, _Y1), (_X1, _Y1)),
    # extras
    "slash": ((_X1, _Y0), (0.40, _Y2)),        # 7's descender
    "flag": ((0.38, 0.28), (0.50, _Y0)),       # 1's serif flag
    "zdiag": ((_X1, _Y0 + 0.04), (_X0, _Y2 - 0.04)),  # 2's diagonal
}
_GLYPHS: tuple[tuple[str, ...], ...] = (
    ("A", "B", "C", "D", "E", "F"),            # 0
    ("flag", "B", "C"),                        # 1
    ("A", "zdiag", "D"),                       # 2
    ("A", "B", "G", "C", "D"),                 # 3
    ("F", "G", "B", "C"),                      # 4
    ("A", "F", "G", "C", "D"),                 # 5
    ("A", "F", "E", "D", "C", "G"),            # 6
    ("A", "slash"),                            # 7
    ("A", "B", "C", "D", "E", "F", "G"),       # 8
    ("G", "F", "A", "B", "C", "D"),            # 9
)


def glyph_segments(digit: int) -> np.ndarray:
    """The digit's strokes as endpoint pairs. [n_seg, 2, 2] f32."""
    return np.asarray([_SEG[s] for s in _GLYPHS[digit]], dtype=np.float32)


def labels(n: int = N_POINTS, seed: int = 2023) -> np.ndarray:
    """Balanced shuffled labels [n] i32 — a function of (seed, index) ONLY.

    Block-shuffled: rows ``10k .. 10k+9`` are an independently seeded
    permutation of the ten classes, so every class appears ``n // 10`` or
    ``n // 10 + 1`` times (exactly balanced when ``10 | n``) AND the
    sequence is *prefix-stable* — label ``i`` never depends on ``n`` (or
    on ``side``), so growing a run extends it without perturbing earlier
    rows and every raster width sees the same labelled problem.
    """
    reps = -(-n // N_CLASSES)
    out = np.concatenate([
        np.random.default_rng(
            np.random.SeedSequence([seed, 0xBA15, k])
        ).permutation(N_CLASSES)
        for k in range(reps)
    ])
    return out[:n].astype(np.int32)


def _render(digit: int, side: int, rng: np.random.Generator) -> np.ndarray:
    """One jittered grayscale glyph raster [side, side] f32 in [0, 1]."""
    segs = glyph_segments(digit)                     # [S, 2, 2]

    # Per-sample affine jitter about the glyph center.
    scale = rng.uniform(0.85, 1.08)
    theta = rng.uniform(-0.12, 0.12)
    shift = rng.uniform(-0.05, 0.05, size=2)
    thick = rng.uniform(0.055, 0.095)
    rot = np.array([[np.cos(theta), -np.sin(theta)],
                    [np.sin(theta), np.cos(theta)]], dtype=np.float32)
    pts = (segs.reshape(-1, 2) - 0.5) * scale @ rot.T + 0.5 + shift
    segs = pts.reshape(-1, 2, 2)

    # Pixel centers in unit coordinates.
    c = (np.arange(side, dtype=np.float32) + 0.5) / side
    px = np.stack(np.meshgrid(c, c, indexing="xy"), axis=-1)  # [side, side, 2]

    # Distance from every pixel to every stroke (point-to-segment).
    a, b = segs[:, 0], segs[:, 1]                    # [S, 2]
    ab = b - a                                       # [S, 2]
    denom = np.maximum((ab * ab).sum(-1), 1e-12)     # [S]
    ap = px[None] - a[:, None, None]                 # [S, side, side, 2]
    t = np.clip((ap * ab[:, None, None]).sum(-1) / denom[:, None, None], 0, 1)
    proj = a[:, None, None] + t[..., None] * ab[:, None, None]
    d = np.sqrt(((px[None] - proj) ** 2).sum(-1)).min(axis=0)  # [side, side]

    # Antialiased ink + mild noise; soft edge spans ~ one full-width pixel
    # so downscaled rasters keep smooth strokes.
    soft = max(0.04, 1.0 / SIDE)
    img = np.clip((thick + soft - d) / soft, 0.0, 1.0)
    img = img + rng.uniform(0.0, 0.22, size=img.shape)
    return np.clip(img, 0.0, 1.0).astype(np.float32)


def raw(
    n: int = N_POINTS, seed: int = 2023, side: int = SIDE
) -> tuple[np.ndarray, np.ndarray]:
    """(images [n, side, side] f32 in [0,1], labels [n] i32).

    Sample ``i`` draws from ``SeedSequence([seed, 1 + i])`` — bitwise
    process-independent and O(1)-seekable (a slice of a bigger run equals
    generating those indices alone).
    """
    ys = labels(n, seed)
    imgs = np.empty((n, side, side), dtype=np.float32)
    for i in range(n):
        rng = np.random.default_rng(np.random.SeedSequence([seed, 1 + i]))
        imgs[i] = _render(int(ys[i]), side, rng)
    return imgs, ys


def booleanize(imgs: np.ndarray, threshold: float = THRESHOLD) -> np.ndarray:
    """Per-pixel threshold booleanization -> [n, side*side] bool.

    Inclusive (``>=``): a pixel exactly at the threshold is ink — the
    same convention as iris's thermometer code.
    """
    n = imgs.shape[0]
    return (imgs >= threshold).reshape(n, -1)


def downscale(imgs: np.ndarray, factor: int = 2) -> np.ndarray:
    """Block-mean pooling [n, S, S] -> [n, S//factor, S//factor].

    The scale knob for tests/benchmarks: 28 -> 14 -> 7 halvings keep the
    glyph recognizable while shrinking the datapath width 4x per step.
    ``S`` must be divisible by ``factor``.
    """
    n, s, _ = imgs.shape
    if s % factor:
        raise ValueError(f"side {s} not divisible by downscale factor {factor}")
    k = s // factor
    return imgs.reshape(n, k, factor, k, factor).mean(axis=(2, 4))


def load(
    seed: int = 2023, n_points: int = N_POINTS, side: int = SIDE
) -> tuple[np.ndarray, np.ndarray]:
    """Booleanized digit workload: (xs [n, side*side] bool, ys [n] i32).

    Same API shape as :func:`repro.data.iris.load`; ``side`` is the
    downscale knob (28 = the paper-benchmark f=784; 14/7 for tests).
    """
    imgs, ys = raw(n_points, seed, side)
    return booleanize(imgs), ys


def splits(
    n_train: int = 100,
    n_test: int = 50,
    seed: int = 2023,
    side: int = SIDE,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Seeded disjoint train/test splits of one generated run.

    (train_x, train_y, test_x, test_y) — the first ``n_train`` rows
    train, the next ``n_test`` test, from a single ``n_train + n_test``
    generation (so growing ``n_test`` never perturbs the train rows).
    """
    xs, ys = load(seed=seed, n_points=n_train + n_test, side=side)
    return xs[:n_train], ys[:n_train], xs[n_train:], ys[n_train:]
