"""Deterministic synthetic token pipeline for LM training/serving.

A Zipf-ish unigram stream with short-range repetition structure so losses
drop measurably within a few hundred steps (pure-uniform tokens give a flat
loss at ln(V)). Seeded and stateless per step index — resuming from a
checkpoint replays the exact same batch sequence (fault-tolerance tests rely
on this).
"""
from __future__ import annotations

from typing import Iterator

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


def _batch(cfg: ModelConfig, shape: ShapeConfig, step: int, seed: int) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    B, S = shape.global_batch, shape.seq_len
    V = max(cfg.vocab_size, 2)
    # Zipf unigram over a clipped vocab + copy structure (periodic repeats).
    base = rng.zipf(1.3, size=(B, S)).astype(np.int64)
    toks = np.clip(base, 1, V - 1)
    period = max(4, S // 8)
    idx = np.arange(S)
    copy_mask = (idx % period) >= (period // 2)
    src = np.maximum(idx - period // 2, 0)
    toks[:, copy_mask] = toks[:, src[copy_mask]]
    out: dict = {}
    if cfg.embeds_input:
        emb = rng.standard_normal((B, S, cfg.d_model)).astype(np.float32)
        out["embeds"] = 0.02 * emb
        out["labels"] = toks.astype(np.int32)
    else:
        out["tokens"] = toks.astype(np.int32)
    if cfg.family == "vlm":
        ce = rng.standard_normal(
            (B, cfg.n_cross_tokens, cfg.d_model)).astype(np.float32)
        out["cross_embeds"] = 0.02 * ce
    return out


def token_batches(cfg: ModelConfig, shape: ShapeConfig,
                  seed: int = 0, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield _batch(cfg, shape, step, seed)
        step += 1
