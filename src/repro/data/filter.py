"""Class filter IP (paper §3.4.1).

Removes a chosen class from a data stream under an external enable signal —
used by the unseen-class-introduction use case (§5.2). Shapes stay fixed:
filtering yields a *validity mask* instead of resizing arrays, so toggling the
enable at runtime never recompiles (the paper's no-re-synthesis property).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def class_filter_mask(
    ys: jax.Array,              # [n] int32 labels
    filtered_class: jax.Array,  # scalar int32
    enabled: jax.Array,         # scalar bool — the external enable signal
    base_valid: jax.Array | None = None,
) -> jax.Array:
    """Validity mask: rows of ``filtered_class`` dropped while ``enabled``."""
    ys = jnp.asarray(ys)
    keep = jnp.where(enabled, ys != filtered_class, True)
    if base_valid is not None:
        keep = keep & base_valid
    return keep


def limit_mask(n: int, limit: jax.Array) -> jax.Array:
    """Validity mask enabling only the first ``limit`` rows (e.g. the paper's
    §5.1 use of 20 of the 30 offline rows)."""
    return jnp.arange(n) < limit
