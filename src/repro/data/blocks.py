"""Block-based cross-validation (paper §3.6.1).

The dataset is split into equally-sized *blocks* (iris: 5 blocks of 30, the
highest common factor of the 30/60/60 set sizes). Blocks are permuted into
*orderings*; for each ordering the first blocks form the offline-training set,
the next the validation set, and the last the online-training set. Experiments
re-run across orderings and average — this module materialises all ordering
datasets as stacked arrays so the whole sweep can be `vmap`-ed (the TPU
analogue of the paper's block-ROM + ordering-manipulation subsystem).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import NamedTuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """Set sizes in *blocks*: iris paper = 1 offline / 2 validation / 2 online."""

    block_len: int = 30
    offline_blocks: int = 1
    validation_blocks: int = 2
    online_blocks: int = 2

    @property
    def n_blocks(self) -> int:
        return self.offline_blocks + self.validation_blocks + self.online_blocks

    def sizes(self) -> tuple[int, int, int]:
        return (
            self.offline_blocks * self.block_len,
            self.validation_blocks * self.block_len,
            self.online_blocks * self.block_len,
        )


class OrderedSets(NamedTuple):
    """Stacked per-ordering sets; leading axis = ordering (vmap axis)."""

    offline_x: np.ndarray    # [O, n_off, f] bool
    offline_y: np.ndarray    # [O, n_off] i32
    validation_x: np.ndarray
    validation_y: np.ndarray
    online_x: np.ndarray
    online_y: np.ndarray


def all_orderings(n_blocks: int) -> np.ndarray:
    """All block permutations in lexicographic order. [n_blocks!, n_blocks]."""
    return np.array(list(itertools.permutations(range(n_blocks))), dtype=np.int64)


def select_orderings(n_blocks: int, n_orderings: int, seed: int = 0) -> np.ndarray:
    """First ``n_orderings`` of a seeded shuffle of all permutations.

    The paper uses all 120 iris orderings; smaller counts subsample evenly for
    cheap CPU runs while staying deterministic.
    """
    full = all_orderings(n_blocks)
    total = len(full)
    if n_orderings >= total:
        return full
    rng = np.random.default_rng(seed)
    idx = rng.permutation(total)[:n_orderings]
    return full[np.sort(idx)]


def make_sets(
    xs: np.ndarray,
    ys: np.ndarray,
    spec: BlockSpec,
    orderings: np.ndarray,
) -> OrderedSets:
    """Assemble (offline/validation/online) sets for every ordering."""
    n, f = xs.shape
    if n != spec.n_blocks * spec.block_len:
        raise ValueError(
            f"dataset length {n} != n_blocks*block_len "
            f"{spec.n_blocks}*{spec.block_len}"
        )
    blocks_x = xs.reshape(spec.n_blocks, spec.block_len, f)
    blocks_y = ys.reshape(spec.n_blocks, spec.block_len)

    def gather(block_ids: np.ndarray):  # [O, k] -> ([O, k*L, f], [O, k*L])
        bx = blocks_x[block_ids]  # [O, k, L, f]
        by = blocks_y[block_ids]
        O, k, L = by.shape
        return bx.reshape(O, k * L, f), by.reshape(O, k * L)

    a = spec.offline_blocks
    b = a + spec.validation_blocks
    off_x, off_y = gather(orderings[:, :a])
    val_x, val_y = gather(orderings[:, a:b])
    onl_x, onl_y = gather(orderings[:, b:])
    return OrderedSets(off_x, off_y, val_x, val_y, onl_x, onl_y)


def paper_sets(
    xs: np.ndarray,
    ys: np.ndarray,
    n_orderings: int,
    seed: int = 2023,
    spec: BlockSpec | None = None,
) -> tuple[OrderedSets, BlockSpec]:
    """The paper's block-CV recipe over an arbitrary booleanized dataset.

    Default spec is the 1/2/2 split at ``block_len = n_rows // 5`` — the
    iris geometry (30/60/60 at 150 rows) generalized so any dataset with
    ``5 | n_rows`` rides the same cross-validation flows regardless of
    feature width.
    """
    if spec is None:
        n = xs.shape[0]
        if n % 5:
            raise ValueError(f"default 5-block spec needs 5 | n_rows, got {n}")
        spec = BlockSpec(block_len=n // 5, offline_blocks=1,
                         validation_blocks=2, online_blocks=2)
    orderings = select_orderings(spec.n_blocks, n_orderings, seed=seed)
    return make_sets(xs, ys, spec, orderings), spec


def iris_paper_sets(
    n_orderings: int = 120, seed: int = 2023
) -> tuple[OrderedSets, BlockSpec]:
    """The paper's exact iris split: 5 blocks of 30 -> sets of 30/60/60."""
    from repro.data import iris

    xs, ys = iris.load(seed=seed)
    return paper_sets(xs, ys, n_orderings, seed=seed)


def mnist_paper_sets(
    n_orderings: int = 120, seed: int = 2023, side: int | None = None
) -> tuple[OrderedSets, BlockSpec]:
    """The same 5-block CV recipe on the MNIST-scale digit workload.

    150 generated rows (10 balanced classes) -> sets of 30/60/60 at
    ``f = side**2`` boolean inputs — the wide-datapath twin of
    :func:`iris_paper_sets`, so every sweep/system/serving flow accepts
    it with zero host-side reshaping. ``side`` defaults to the full
    28x28 raster; pass 14 or 7 for CPU-cheap runs.
    """
    from repro.data import mnist

    xs, ys = mnist.load(seed=seed, side=mnist.SIDE if side is None else side)
    return paper_sets(xs, ys, n_orderings, seed=seed)
