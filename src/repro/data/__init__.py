"""Data-management subsystems (paper §3.4-3.6)."""
