"""Cyclic online-input buffer (paper §3.5.2).

The FPGA buffers online datapoints in RAM so none are dropped while the
accuracy-analysis process stalls the consumer. Here the buffer is a fixed-shape
ring in device memory (capacity x features + head/size scalars) updated with
``dynamic_update_slice`` — bounded memory, pure-functional, scan/vmap friendly.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class RingBuffer(NamedTuple):
    data_x: jax.Array  # [capacity, f] bool — or [capacity, ceil(f/32)] uint32
                       # when the buffer stores PACKED rows (DESIGN.md §13)
    data_y: jax.Array  # [capacity] int32
    head: jax.Array    # scalar int32 — next slot to pop
    size: jax.Array    # scalar int32 — valid entries

    @property
    def capacity(self) -> int:
        return self.data_x.shape[0]


def make(capacity: int, n_features: int, *, packed: bool = False) -> RingBuffer:
    """Empty ring. ``packed=True`` stores uint32 word rows (ceil(f/32) per
    datapoint — ~1/8 the bool footprint); producers must then push rows
    already packed per :mod:`repro.kernels.packing`."""
    if packed:
        from repro.kernels import packing

        data_x = jnp.zeros((capacity, packing.n_words(n_features)),
                           dtype=jnp.uint32)
    else:
        data_x = jnp.zeros((capacity, n_features), dtype=bool)
    return RingBuffer(
        data_x=data_x,
        data_y=jnp.zeros((capacity,), dtype=jnp.int32),
        head=jnp.int32(0),
        size=jnp.int32(0),
    )


def push(buf: RingBuffer, x: jax.Array, y: jax.Array) -> tuple[RingBuffer, jax.Array]:
    """Append one datapoint. Returns (buffer, accepted?).

    A full buffer rejects the push (the FPGA would stall its producer; we
    surface the condition so the caller can apply backpressure).
    """
    cap = buf.capacity
    full = buf.size >= cap
    tail = jnp.mod(buf.head + buf.size, cap)
    new_x = jax.lax.dynamic_update_slice(
        buf.data_x, x[None].astype(buf.data_x.dtype), (tail, 0)
    )
    new_y = jax.lax.dynamic_update_slice(
        buf.data_y, y[None].astype(jnp.int32), (tail,)
    )
    out = RingBuffer(
        data_x=jnp.where(full, buf.data_x, new_x),
        data_y=jnp.where(full, buf.data_y, new_y),
        head=buf.head,
        size=jnp.where(full, buf.size, buf.size + 1),
    )
    return out, ~full


def pop(buf: RingBuffer) -> tuple[RingBuffer, jax.Array, jax.Array, jax.Array]:
    """Remove the oldest datapoint. Returns (buffer, x, y, valid?).

    Popping an empty buffer returns valid=False and leaves state untouched.
    """
    empty = buf.size <= 0
    x = jax.lax.dynamic_slice(buf.data_x, (buf.head, 0), (1, buf.data_x.shape[1]))[0]
    y = jax.lax.dynamic_slice(buf.data_y, (buf.head,), (1,))[0]
    out = RingBuffer(
        data_x=buf.data_x,
        data_y=buf.data_y,
        head=jnp.where(empty, buf.head, jnp.mod(buf.head + 1, buf.capacity)),
        size=jnp.where(empty, buf.size, buf.size - 1),
    )
    return out, x, y, ~empty
