"""olmoe-1b-7b  [moe]  [arXiv:2409.02060; hf]

16L d_model=2048 16H (GQA kv=16 => MHA) d_ff=1024 vocab=50304, MoE 64
experts top-8 (d_ff per expert = 1024, no shared/dense residual).
"""
import dataclasses

from repro.configs.base import GLOBAL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    layer_pattern=(GLOBAL,),
    act="swiglu",
    moe=MoEConfig(n_experts=64, top_k=8, d_ff_expert=1024),
    remat="dots",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
        vocab_size=256,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=64),
        remat="none", compute_dtype="float32",
    )
