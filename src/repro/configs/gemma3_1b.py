"""gemma3-1b  [dense]  [hf:google/gemma-3-1b-pt; unverified]

26L d_model=1152 4H (GQA kv=1) d_ff=6912 vocab=262144 — 5:1 local:global
layer pattern, sliding window 512, head_dim 256 (decoupled from d_model),
tied embeddings. Local-attention dominant => runs long_500k (global layers
at decode are O(1) per token against the cache; see DESIGN.md §4).
"""
import dataclasses

from repro.configs.base import GLOBAL, LOCAL, ModelConfig

CONFIG = ModelConfig(
    arch_id="gemma3-1b",
    family="dense",
    n_layers=26,
    d_model=1152,
    n_heads=4,
    n_kv_heads=1,
    d_head=256,
    d_ff=6912,
    vocab_size=262_144,
    layer_pattern=(LOCAL, LOCAL, LOCAL, LOCAL, LOCAL, GLOBAL),
    sliding_window=512,
    act="geglu",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    long_context_ok=True,
    remat="dots",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=8, d_model=64, n_heads=4, n_kv_heads=1, d_head=16,
        d_ff=128, vocab_size=512, sliding_window=8, remat="none",
        compute_dtype="float32",
    )
