"""qwen2.5-14b  [dense]  [hf:Qwen/Qwen2.5-0.5B; hf]

48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064 — GQA with QKV bias.
"""
import dataclasses

from repro.configs.base import GLOBAL, ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2.5-14b",
    family="dense",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=152_064,
    layer_pattern=(GLOBAL,),
    qkv_bias=True,
    act="swiglu",
    rope_theta=1_000_000.0,
    remat="dots",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, remat="none", compute_dtype="float32",
    )
