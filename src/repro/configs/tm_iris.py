"""The paper's own machine: Tsetlin Machine on iris (§5).

16 booleanised inputs, 3 classes, 16 clauses, T=15, s=1.375 offline / 1.0
online, 10 offline epochs, 16 online cycles, 120 block orderings. Classes and
clauses can be over-provisioned above the active counts (§3.1.1).
"""
import dataclasses

from repro.core.tm import TMConfig


@dataclasses.dataclass(frozen=True)
class TMSystemParams:
    tm: TMConfig
    s_offline: float = 1.375
    s_online: float = 1.0
    T: int = 15
    n_offline_epochs: int = 10
    n_online_cycles: int = 16
    n_orderings: int = 120
    offline_limit: int = 20     # §5.1 uses 20 of the 30 offline rows


CONFIG = TMSystemParams(
    tm=TMConfig(
        n_features=16,
        max_classes=3,
        max_clauses=16,
        n_states=16,   # 5-bit TAs — calibrated against Fig 4 (EXPERIMENTS.md)
        s_policy="standard",
        boost_true_positive=True,
    ),
)

# Over-provisioned variant: a 4th class slot + 2x clauses held in reserve
# (enabled at runtime without re-JIT — the paper's re-synthesis avoidance).
OVERPROVISIONED = dataclasses.replace(
    CONFIG,
    tm=dataclasses.replace(CONFIG.tm, max_classes=4, max_clauses=32),
)


def smoke_config() -> TMSystemParams:
    return dataclasses.replace(
        CONFIG, n_offline_epochs=2, n_online_cycles=2, n_orderings=2
    )
