"""musicgen-medium  [audio]  [arXiv:2306.05284; hf]

48L d_model=1536 24H (kv=24 => MHA) d_ff=6144 vocab=2048 — decoder-only over
EnCodec tokens. The EnCodec frontend is a STUB per the brief: inputs arrive
as precomputed frame embeddings (`embeds_input=True`), labels are codebook
token ids over the 2048-entry vocab. LayerNorm + GELU per the audiocraft
implementation; positions via RoPE (sinusoidal in the original — recorded as
an adaptation in DESIGN.md).
"""
import dataclasses

from repro.configs.base import GLOBAL, ModelConfig

CONFIG = ModelConfig(
    arch_id="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    layer_pattern=(GLOBAL,),
    norm="layernorm",
    act="gelu",
    embeds_input=True,
    remat="dots",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=64, remat="none", compute_dtype="float32",
    )
