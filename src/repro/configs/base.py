"""Model configuration schema for the assigned architectures.

One frozen dataclass covers dense / MoE / SSM / hybrid / vlm / audio families;
`layer_pattern` describes the repeating per-layer kinds so heterogeneous
stacks (gemma3 5:1 local:global, recurrentgemma 2:1 RG-LRU:attn, llama-vision
cross-attn insertions) lower as a `lax.scan` over the repeating super-block.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

# Layer kinds.
GLOBAL = "global"        # full causal self-attention
LOCAL = "local"          # sliding-window causal self-attention
CROSS = "cross"          # self-attention + gated cross-attention (vlm)
RGLRU = "rglru"          # Griffin RG-LRU recurrent block
SSD = "ssd"              # Mamba-2 state-space dual block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25
    dense_residual: bool = False   # arctic: dense FFN in parallel with MoE
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk: int = 256               # SSD chunk length


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: Optional[int] = None    # default d_model // n_heads
    layer_pattern: Sequence[str] = (GLOBAL,)  # tiled to n_layers (+ remainder)
    sliding_window: int = 4096
    qkv_bias: bool = False
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    act: str = "swiglu"             # swiglu | geglu | gelu
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # vlm: number of (stub) image tokens attended by cross-attn layers.
    n_cross_tokens: int = 0
    # audio/vlm stub: inputs arrive as precomputed frame/patch embeddings.
    embeds_input: bool = False
    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # streaming-softmax key-chunk size for full-sequence attention (flash
    # attention at the HLO level; S <= attn_chunk uses the dense-mask path)
    attn_chunk: int = 512
    # long_500k eligibility (brief: skip pure full-attention archs). Set
    # explicitly per config; see DESIGN.md §4 for the skip table.
    long_context_ok: bool = False
    # memory
    remat: str = "none"             # none | full | dots
    # optimizer-state dtype (arctic needs bf16 moments to fit v5e HBM)
    adam_dtype: str = "float32"
    # gradient-accumulation microbatches for the train_4k cell
    train_microbatches: int = 1

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Per-layer kind list of length n_layers (pattern tiled + truncated)."""
        p = tuple(self.layer_pattern)
        reps = -(-self.n_layers // len(p))
        return (p * reps)[: self.n_layers]

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic stacks: SSM / hybrid / local-dominant patterns."""
        return self.long_context_ok

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, v = self.d_model, self.vocab_size
        dh = self.head_dim
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d  # head
        for kind in self.layer_kinds:
            if kind in (GLOBAL, LOCAL, CROSS):
                # CROSS layers carry one (gated cross-) attention sub-block,
                # same parameter count as self-attention.
                qkv = d * dh * (self.n_heads + 2 * self.n_kv_heads)
                o = self.n_heads * dh * d
                total += qkv + o
                total += self._ffn_params()
                total += 2 * d  # norms
            elif kind == RGLRU:
                assert self.ssm is not None
                di = self.ssm.expand * d
                total += 2 * d * di + di * d        # gate/in proj + out proj
                total += di * self.ssm.d_conv        # conv
                total += 3 * di                       # lambda + gates biases
                total += self._ffn_params() + 2 * d
            elif kind == SSD:
                assert self.ssm is not None
                di = self.ssm.expand * d
                nh = di // self.ssm.head_dim
                total += d * (2 * di + 2 * self.ssm.d_state + nh)  # in_proj
                total += di * d                       # out_proj
                total += (di + 2 * self.ssm.d_state) * self.ssm.d_conv
                total += 2 * nh + di                  # A, dt bias, norm
                total += d                            # norm
        total += d  # final norm
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts)."""
        if self.moe is None:
            return self.param_count()
        total = self.param_count()
        e = self.moe
        per_expert = self._expert_params()
        inactive = (e.n_experts - e.top_k) * per_expert * self._n_moe_layers()
        return total - inactive

    def _n_moe_layers(self) -> int:
        return sum(1 for k in self.layer_kinds if k in (GLOBAL, LOCAL, CROSS)) \
            if self.moe else 0

    def _expert_params(self) -> int:
        assert self.moe is not None
        mult = 3 if self.act in ("swiglu", "geglu") else 2
        return mult * self.d_model * self.moe.d_ff_expert

    def _ffn_params(self) -> int:
        if self.moe is not None:
            p = self.moe.n_experts * self._expert_params()
            p += self.d_model * self.moe.n_experts  # router
            if self.moe.dense_residual:
                mult = 3 if self.act in ("swiglu", "geglu") else 2
                p += mult * self.d_model * self.d_ff
            return p
        mult = 3 if self.act in ("swiglu", "geglu") else 2
        return mult * self.d_model * self.d_ff


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One dry-run cell's input shape (from the assignment brief)."""

    name: str          # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
