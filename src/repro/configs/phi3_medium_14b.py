"""phi3-medium-14b  [dense]  [arXiv:2404.14219; unverified]

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352 — RoPE SwiGLU GQA.
"""
import dataclasses

from repro.configs.base import GLOBAL, ModelConfig

CONFIG = ModelConfig(
    arch_id="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_ff=17920,
    vocab_size=100_352,
    layer_pattern=(GLOBAL,),
    act="swiglu",
    remat="dots",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=80, n_heads=4, n_kv_heads=2, d_ff=160,
        vocab_size=256, remat="none", compute_dtype="float32",
    )
