"""llama-3.2-vision-11b  [vlm]  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

40L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256 — cross-attn image
layers. Pattern: 4 self-attention layers then 1 gated cross-attention layer
(the HF checkpoint inserts 8 cross-attn layers across the 40-layer stack).
The vision frontend is a STUB per the brief: `input_specs()` supplies
precomputed patch embeddings (projected to d_model) as `cross_embeds`.
"""
import dataclasses

from repro.configs.base import CROSS, GLOBAL, ModelConfig

CONFIG = ModelConfig(
    arch_id="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    layer_pattern=(GLOBAL, GLOBAL, GLOBAL, GLOBAL, CROSS),
    rope_theta=500_000.0,
    act="swiglu",
    n_cross_tokens=1601,   # 1 tile x (40x40 patches + cls), projected
    remat="dots",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        n_cross_tokens=9,
        remat="none",
        compute_dtype="float32",
    )
