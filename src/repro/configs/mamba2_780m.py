"""mamba2-780m  [ssm]  [arXiv:2405.21060; unverified]

48L d_model=1536 (attention-free) vocab=50280, ssm_state=128 — SSD
(state-space duality) blocks: expand=2 (d_inner 3072), head_dim 64
(48 SSD heads), conv4. No MLP (the Mamba block is the whole layer).
Attention-free => runs long_500k (O(1)/token decode state).
"""
import dataclasses

from repro.configs.base import SSD, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=24,          # unused by SSD; kept for schema completeness
    n_kv_heads=24,
    d_ff=0,
    vocab_size=50280,
    layer_pattern=(SSD,),
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64, chunk=128),
    tie_embeddings=True,
    long_context_ok=True,
    remat="dots",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, vocab_size=256,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16, chunk=16),
        remat="none", compute_dtype="float32",
    )
