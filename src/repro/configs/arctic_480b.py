"""arctic-480b  [moe]  [hf:Snowflake/snowflake-arctic-base; hf]

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128 experts top-2
PLUS a dense residual MLP in parallel (Arctic's dense-MoE hybrid). Adam
moments in bf16 so the FSDP-sharded optimizer state fits v5e HBM (see
DESIGN.md §6).
"""
import dataclasses

from repro.configs.base import GLOBAL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    arch_id="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    layer_pattern=(GLOBAL,),
    act="swiglu",
    moe=MoEConfig(n_experts=128, top_k=2, d_ff_expert=4864,
                  dense_residual=True),
    adam_dtype="bfloat16",
    train_microbatches=1,
    remat="full",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab_size=256,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=96,
                      dense_residual=True),
        adam_dtype="float32", remat="none", compute_dtype="float32",
    )
