"""MNIST-scale machine: Tsetlin Machine on the booleanized digit workload.

784 boolean inputs (28x28 per-pixel threshold), 10 classes. The paper's
clause-budget guidance (§3.1/§5: provision clauses per class roughly with
problem difficulty, over-provision rather than re-synthesize) scaled from
the iris calibration: iris uses 16 clauses for a 3-class/16-input problem;
the digit workload carries 10 classes at 49x the input width, so the
preset provisions 64 clauses per class — the same order MATADOR-class TM
hardware flows use for booleanized-MNIST — with 6-bit-plus TAs
(``n_states=63``: the widest that keeps the TA bank int8, the paper's
few-bits-per-TA bias at 1568 literals x 640 clause rows = a ~1 MB bank).

``T`` scales with the clause budget (T ~= clauses/2, as the iris preset's
15 ~= 16); ``s`` is calibrated on the generated workload (s=2.0/T=32
reaches ~0.97 train / ~0.82 held-out accuracy in 10 offline epochs at
14x14 on 100 rows; higher s under-includes at this width — the sweep in
tests/test_scale.py keeps the calibration honest).

``config_for_side`` is the downscale knob's twin: the same machine at
14x14 (f=196) or 7x7 (f=49) for tests and benchmarks that must stay
CPU-cheap while exercising the identical code paths.
"""
import dataclasses

from repro.configs.tm_iris import TMSystemParams
from repro.core.tm import TMConfig
from repro.data import mnist as mnist_data

SIDE = mnist_data.SIDE  # 28


def config_for_side(side: int = SIDE) -> TMSystemParams:
    """The MNIST-scale system preset at raster width ``side``.

    ``n_features = side**2``; everything else (clause budget, s/T, cycle
    counts) is width-independent so a 14x14 run exercises exactly the
    full-width program shapes modulo the literal axis.
    """
    return TMSystemParams(
        tm=TMConfig(
            n_features=side * side,
            max_classes=mnist_data.N_CLASSES,
            max_clauses=64,
            n_states=63,   # widest int8 TA bank (2N = 126 <= 127)
            s_policy="standard",
            boost_true_positive=True,
        ),
        s_offline=2.0,
        s_online=1.5,
        T=32,
        n_offline_epochs=10,
        n_online_cycles=16,
        n_orderings=120,
        offline_limit=20,
    )


CONFIG = config_for_side(SIDE)

# Over-provisioned variant (§3.1.1): clause headroom held in reserve,
# enabled at runtime without re-JIT (the paper's re-synthesis avoidance).
OVERPROVISIONED = dataclasses.replace(
    CONFIG,
    tm=dataclasses.replace(CONFIG.tm, max_clauses=128),
)


def smoke_config(side: int = 14) -> TMSystemParams:
    """CI-sized variant: downscaled raster, short offline/online schedule."""
    return dataclasses.replace(
        config_for_side(side),
        n_offline_epochs=2, n_online_cycles=2, n_orderings=2,
    )
