"""recurrentgemma-9b  [hybrid]  [arXiv:2402.19427; unverified]

38L d_model=4096 16H (GQA kv=1 => MQA) d_ff=12288 vocab=256000 — Griffin
pattern: (RG-LRU, RG-LRU, local attention) repeating; lru_width = d_model
(expand=1), local window 2048, GeGLU MLP. 38 = 12x3 + 2 remainder recurrents.
Sub-quadratic => runs the long_500k shape.
"""
import dataclasses

from repro.configs.base import LOCAL, RGLRU, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    arch_id="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab_size=256_000,
    layer_pattern=(RGLRU, RGLRU, LOCAL),
    sliding_window=2048,
    act="geglu",
    ssm=SSMConfig(d_conv=4, expand=1),
    tie_embeddings=True,
    long_context_ok=True,
    remat="dots",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG,
        n_layers=5,
        d_model=64,
        n_heads=4,
        n_kv_heads=1,
        d_ff=128,
        vocab_size=256,
        sliding_window=8,
        remat="none",
        compute_dtype="float32",
    )
