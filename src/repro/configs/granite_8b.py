"""granite-8b  [dense]  [arXiv:2405.04324; hf]

36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152 — llama-architecture
code model (RoPE + SwiGLU + RMSNorm).
"""
import dataclasses

from repro.configs.base import GLOBAL, ModelConfig

CONFIG = ModelConfig(
    arch_id="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=49152,
    layer_pattern=(GLOBAL,),
    act="swiglu",
    rope_theta=10_000.0,
    remat="dots",
)


def smoke_config() -> ModelConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=256, remat="none", compute_dtype="float32",
    )
