"""Architecture registry: one module per assigned architecture.

`get_config(arch_id)` returns the full-size config (dry-run only — never
materialised); `get_smoke_config(arch_id)` returns the reduced same-family
config used by the CPU smoke tests.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig, ShapeConfig, SHAPES  # noqa: F401

ARCH_IDS = [
    "llama32_vision_11b",
    "recurrentgemma_9b",
    "granite_8b",
    "gemma3_1b",
    "phi3_medium_14b",
    "qwen25_14b",
    "musicgen_medium",
    "arctic_480b",
    "olmoe_1b_7b",
    "mamba2_780m",
]

# brief ids (with dots/dashes) -> module names
ALIASES = {
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "granite-8b": "granite_8b",
    "gemma3-1b": "gemma3_1b",
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen2.5-14b": "qwen25_14b",
    "musicgen-medium": "musicgen_medium",
    "arctic-480b": "arctic_480b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "mamba2-780m": "mamba2_780m",
}


def _module(arch_id: str):
    name = ALIASES.get(arch_id, arch_id)
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    return _module(arch_id).smoke_config()
