"""Distributed-optimization tricks: gradient compression with error feedback.

int8 quantized gradient exchange (per-tensor max-abs scaling) with an error-
feedback residual so the compression bias does not accumulate [Seide et al.
2014; Karimireddy et al. 2019]. Under pjit the quantize->(all-reduce happens
at the sharding boundary)->dequantize pattern cuts gradient all-reduce bytes
4x vs fp32 / 2x vs bf16; the residual tree lives with the optimizer state.

Compression is OFF by default and enabled per-run (`TrainConfig.grad_compress`)
— the paper's energy-accuracy trade-off knob, applied to communication.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    residual: Any  # error-feedback tree, same structure as grads


def init_state(params) -> CompressionState:
    return CompressionState(
        residual=jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    )


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(
    grads, state: CompressionState
) -> tuple[Any, CompressionState, dict]:
    """Quantize (grad + residual) to int8; return dequantized grads + new
    residuals. The int8 tensors are what crosses the network under SPMD."""

    def one(g, r):
        g32 = g.astype(jnp.float32) + r
        q, scale = _quantize_int8(g32)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), g32 - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(state.residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    new_g = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_r = jax.tree.unflatten(treedef, [o[1] for o in outs])
    err = sum(jnp.sum(jnp.abs(r)) for r in jax.tree.leaves(new_r))
    return new_g, CompressionState(residual=new_r), {"compress_err_l1": err}
