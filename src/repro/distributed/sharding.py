"""Logical-axis sharding rules -> NamedShardings (DP / FSDP / TP / EP / SP).

Every parameter carries logical axis names from its PSpec (models/params.py);
a rule table maps logical axes to mesh axes. On top of plain TP we apply
ZeRO-3/FSDP: each parameter's largest *unsharded* dimension is additionally
sharded over the (pod, data) axes, which also shards optimizer state (the
optimizer tree reuses parameter shardings).

Rules silently fall back to replication when a dimension is not divisible by
the mesh-axis size (e.g. kv_heads=1 with model=16) — exactly what a
production sharding pass must do rather than crash.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

from repro.models.params import PSpec, tree_map_specs

# Logical-axis -> mesh-axis table (TP/EP on "model").
DEFAULT_RULES: dict[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "expert_ff": "model",
    "experts": "model",
    "embed": None,
    "inner": "model",       # ssm/rglru inner width
    "ssm_heads": "model",
    "conv": None,
    "state": None,
    "layers": None,
    None: None,
}


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    rules: dict = dataclasses.field(default_factory=lambda: dict(DEFAULT_RULES))
    fsdp: bool = True                      # ZeRO-3 over (pod, data)
    fsdp_axes: tuple[str, ...] = ("pod", "data")
    data_axes: tuple[str, ...] = ("pod", "data")  # batch sharding
    seq_axis: Optional[str] = None         # SP: shard sequence/cache over this


def _axis_size(mesh: Mesh, name: Optional[str]) -> int:
    if name is None:
        return 1
    return mesh.shape[name] if name in mesh.shape else 1


def _mesh_axes_present(mesh: Mesh, axes: tuple[str, ...]) -> tuple[str, ...]:
    return tuple(a for a in axes if a in mesh.shape)


def spec_partition(
    spec: PSpec, mesh: Mesh, policy: ShardingPolicy
) -> PS:
    """PartitionSpec for one parameter."""
    parts: list = []
    used: set[str] = set()
    for dim, ax in zip(spec.shape, spec.axes):
        mesh_ax = policy.rules.get(ax)
        if (
            mesh_ax is not None
            and mesh_ax in mesh.shape
            and mesh_ax not in used
            and dim % mesh.shape[mesh_ax] == 0
        ):
            parts.append(mesh_ax)
            used.add(mesh_ax)
        else:
            parts.append(None)

    if policy.fsdp:
        fsdp = _mesh_axes_present(mesh, policy.fsdp_axes)
        fsdp = tuple(a for a in fsdp if a not in used)
        if fsdp:
            group = int(np.prod([mesh.shape[a] for a in fsdp]))
            # shard the largest still-unsharded dim that divides the group
            order = sorted(
                range(len(spec.shape)),
                key=lambda i: -(spec.shape[i] // max(
                    _axis_size(mesh, parts[i]) if isinstance(parts[i], str)
                    else 1, 1)),
            )
            for i in order:
                if parts[i] is None and spec.shape[i] % group == 0:
                    parts[i] = fsdp if len(fsdp) > 1 else fsdp[0]
                    break

    return PS(*parts)


def param_shardings(specs_tree, mesh: Mesh, policy: ShardingPolicy):
    """NamedSharding tree matching a PSpec tree."""
    return tree_map_specs(
        lambda s: NamedSharding(mesh, spec_partition(s, mesh, policy)),
        specs_tree,
    )


def batch_shardings(batch_struct, mesh: Mesh, policy: ShardingPolicy):
    """Shard inputs: leading batch dim over data axes; optional SP on seq.

    Works on a tree of ShapeDtypeStructs (dry-run) or arrays.
    """
    data = _mesh_axes_present(mesh, policy.data_axes)
    data_spec = data if len(data) > 1 else (data[0] if data else None)

    def one(x):
        shape = x.shape
        if len(shape) == 0:
            return NamedSharding(mesh, PS())
        group = int(np.prod([mesh.shape[a] for a in data])) if data else 1
        parts: list = [None] * len(shape)
        if group > 1 and shape[0] % group == 0:
            parts[0] = data_spec
        if (
            policy.seq_axis is not None
            and len(shape) >= 2
            and policy.seq_axis in mesh.shape
            and shape[1] % mesh.shape[policy.seq_axis] == 0
        ):
            parts[1] = policy.seq_axis
        return NamedSharding(mesh, PS(*parts))

    return jax.tree.map(
        one, batch_struct,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, jax.Array)),
    )


def replica_shardings(
    tree,
    mesh: Mesh,
    *,
    axes: tuple[str, ...] = ("data",),
    n_replicas: Optional[int] = None,
):
    """Shard each leaf's LEADING replica axis over the given mesh axes.

    The cross-validation / hyperparameter-sweep engine (repro.eval.crossval)
    and the online serving fleet (repro.serve.fleet) run R independent TMs
    as one program; every replica is data-parallel by construction, so the
    only sharding decision is the replica axis itself. Leaves whose leading
    dim does not divide the mesh group fall back to replication (the same
    never-crash rule as :func:`spec_partition`).

    ``n_replicas`` pins the layout rule for mixed trees: sweep inputs mix
    full-R leaves (TA banks, per-replica s/T) with per-data-stream leaves
    of leading ``D | R`` (ordering datapoints, RNG keys). ONLY leaves whose
    leading dim equals ``n_replicas`` shard — the grid-major replica axis
    goes device-local in contiguous slabs while every data stream is
    replicated onto all devices, so the kernels' ``r % D`` gather never
    crosses a device boundary. The old guess-by-divisibility form
    (``n_replicas=None``) sharded any divisible leading dim, scattering the
    D streams away from the replicas that read them; it warned as
    deprecated through PR 8 and is now a hard ``TypeError``.
    """
    if n_replicas is None:
        raise TypeError(
            "replica_shardings() requires n_replicas: the old "
            "n_replicas=None form sharded ANY divisible leading dim, "
            "scattering D | R data-stream leaves away from the replicas "
            "that read them (cross-device r % D gathers). Pass the fleet's "
            "replica count so only the full-R grid-major axis shards."
        )
    present = _mesh_axes_present(mesh, axes)
    group = int(np.prod([mesh.shape[a] for a in present])) if present else 1
    spec_axes = present if len(present) > 1 else (present[0] if present else None)

    def one(x):
        shape = getattr(x, "shape", ())
        if (
            present
            and len(shape) >= 1
            and shape[0] % group == 0
            and shape[0] == n_replicas
        ):
            return NamedSharding(mesh, PS(spec_axes))
        return NamedSharding(mesh, PS())

    return jax.tree.map(
        one, tree,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, jax.Array)),
    )


def cache_shardings(cache_struct, mesh: Mesh, policy: ShardingPolicy):
    """KV/state cache shardings, key-aware.

    * self-attention k/v ([L?, B, S|W, Hkv, Dh]): batch over data, HEAD_DIM
      over model. Sharding Dh keeps the one-token decode write shard-local
      (an S-sharded cache turns the DUS into a full-buffer select under
      SPMD); attention contracts Dh into small partial-sum all-reduces.
    * cross-attention ck/cv: read-only and small — batch over data only.
    * SSM/RGLRU h/conv states: inner width (>=1024) over model (matches the
      TP sharding of the recurrent weights), batch over data.
    """
    model = "model" if "model" in mesh.shape else None
    data = _mesh_axes_present(mesh, policy.data_axes)
    data_spec = data if len(data) > 1 else (data[0] if data else None)
    group = int(np.prod([mesh.shape[a] for a in data])) if data else 1

    def data_dims(parts, shape):
        for i in range(min(2, len(shape))):
            if parts[i] is None and group > 1 and shape[i] % group == 0:
                parts[i] = data_spec
                break
        return parts

    def one(path, x):
        key = str(path[-1].key) if path and hasattr(path[-1], "key") else ""
        shape = x.shape
        parts: list = [None] * len(shape)
        msize = mesh.shape[model] if model else 1
        if key in ("k", "v") and len(shape) >= 4:
            # Long caches shard S over model: decode READS then touch only
            # 1/model of the cache per device (context parallelism) — worth
            # far more than the one-token select-DUS write tax it causes
            # (§Perf A1 measured unsharding at ~10x MORE traffic). Short
            # window caches shard head_dim (writes stay shard-local).
            seq_dim = len(shape) - 3
            if model and shape[seq_dim] >= 4096 and shape[seq_dim] % msize == 0:
                parts[seq_dim] = model
            elif model and shape[-1] % msize == 0 and shape[-1] >= msize:
                parts[-1] = model
        elif key in ("ck", "cv"):
            pass  # replicate over model; batch over data below
        else:  # h / conv and other states: inner width over model
            for i in sorted(range(1, len(shape)), key=lambda i: -shape[i]):
                if model and shape[i] >= 1024 and shape[i] % msize == 0:
                    parts[i] = model
                    break
        parts = data_dims(parts, shape)
        return NamedSharding(mesh, PS(*parts))

    import jax.tree_util as jtu

    return jtu.tree_map_with_path(
        one, cache_struct,
        is_leaf=lambda x: isinstance(x, (jax.ShapeDtypeStruct, jax.Array)),
    )
