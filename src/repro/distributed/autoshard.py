"""Ambient sharding hints for model-internal tensors.

GSPMD propagates shardings from inputs, but data-dependent ops (MoE scatter
dispatch, top-k) and long einsum chains can drop them, silently replicating
multi-TB intermediates. Model code calls ``hint(x, axis_names...)`` at the few
load-bearing points; the launcher activates a mesh with ``use(mesh)``. With no
active mesh (CPU smoke tests) hints are no-ops, so model code stays
mesh-agnostic.

Axis-name entries may be None, a mesh axis name, or a tuple of axis names
(e.g. ("pod", "data") for a combined DP dimension). Names missing from the
active mesh or not dividing the dimension are dropped — the production
fallback is replication on that dim, never a crash.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as PS

_state = threading.local()


def current_mesh() -> Optional[Mesh]:
    return getattr(_state, "mesh", None)


def setting(name: str, default=None):
    """Launcher-provided knob (e.g. moe_expert_axis: 'model' for training EP,
    'data' for weight-stationary serving EP)."""
    return getattr(_state, "settings", {}).get(name, default)


@contextlib.contextmanager
def use(mesh: Optional[Mesh], **settings):
    prev = current_mesh()
    prev_s = getattr(_state, "settings", {})
    _state.mesh = mesh
    _state.settings = settings
    try:
        yield
    finally:
        _state.mesh = prev
        _state.settings = prev_s


def _filter_entry(mesh: Mesh, dim: int, entry):
    if entry is None:
        return None
    names = entry if isinstance(entry, tuple) else (entry,)
    names = tuple(n for n in names if n in mesh.shape)
    if not names:
        return None
    size = int(np.prod([mesh.shape[n] for n in names]))
    if size <= 1 or dim % size != 0:
        return None
    return names if len(names) > 1 else names[0]


def hint(x: jax.Array, *axes) -> jax.Array:
    """Constrain x's sharding (no-op without an active mesh)."""
    mesh = current_mesh()
    if mesh is None:
        return x
    assert len(axes) == x.ndim, (axes, x.shape)
    used: set = set()
    parts = []
    for dim, entry in zip(x.shape, axes):
        e = _filter_entry(mesh, dim, entry)
        if e is not None:
            flat = e if isinstance(e, tuple) else (e,)
            if any(n in used for n in flat):
                e = None
            else:
                used.update(flat)
        parts.append(e)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, PS(*parts))
    )
