"""Distribution: sharding rules, mesh construction, collectives."""
