"""Trip-count-aware cost extraction from optimized (post-SPMD) HLO text.

XLA's `compiled.cost_analysis()` counts while-loop bodies ONCE — for
scan-over-layers programs that undercounts FLOPs/bytes/collectives by the
layer count. This module parses the HLO module structure:

  * computations + their instructions (with result/operand shapes),
  * while-loop trip counts (from the `compare(ind_var, constant)` in each
    condition computation — scans lower to exactly that form),
  * a multiplier map (product of enclosing loop trip counts),

and produces corrected per-device totals:

  * `dot_flops`   — 2 x prod(result dims) x prod(contracting dims) per dot,
  * `traffic_bytes` — Σ (operand + result bytes) per top-level instruction
    (tensor-granularity HBM traffic; on-chip fusion reuse already folded in
    because fusions count as single instructions),
  * collective wire bytes by op (ring-algorithm factors x replica-group size).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Optional

_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(
    r"^(?:ENTRY )?%?([\w.-]+)\s*\(.*\)\s*->\s*.+\{\s*$"
)
_INSTR = re.compile(
    r"^\s+(?:ROOT\s+)?%([\w.-]+)\s*=\s*((?:\([^)]*\)|[^=]+?))\s*"
    r"([\w-]+)\((.*)$"
)
_PARAM_DECL = re.compile(r"%?([\w.-]+):\s*((?:\([^)]*\)|[\w\[\]{},]+))")

_NO_TRAFFIC = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "reshape",  # layout-preserving reshape is free on TPU
}
_COLL = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute"}


def _dims(shape_text: str) -> list[tuple[str, list[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt in _BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _shape_bytes(shape_text: str) -> int:
    total = 0
    for dt, dims in _dims(shape_text):
        n = 1
        for d in dims:
            n *= d
        total += n * _BYTES[dt]
    return total


@dataclasses.dataclass
class Instr:
    name: str
    type_text: str
    op: str
    rest: str  # operand list + attributes


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    shapes: dict  # value name -> type text (params + results)


def parse_module(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line)
            if m:
                cur = Computation(m.group(1), [], {})
                hdr_params = line.split("(", 1)[1].rsplit(")", 1)[0]
                for pname, ptype in _PARAM_DECL.findall(hdr_params):
                    cur.shapes[pname] = ptype
            continue
        if line == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if m:
            name, type_text, op, rest = m.groups()
            cur.instrs.append(Instr(name, type_text.strip(), op, rest))
            cur.shapes[name] = type_text.strip()
    if cur is not None:
        comps[cur.name] = cur
    return comps


def _trip_count(ins: Instr, comps: dict) -> int:
    """XLA annotates `backend_config={"known_trip_count":{"n":"N"}}`; fall
    back to parsing the condition's compare-with-constant."""
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.rest)
    if m:
        return max(1, int(m.group(1)))
    cond = re.search(r"condition=%?([\w.-]+)", ins.rest)
    if cond and cond.group(1) in comps:
        cc = comps[cond.group(1)]
        consts = {}
        for i2 in cc.instrs:
            if i2.op == "constant":
                mm = re.match(r"(-?\d+)", i2.rest.rstrip(") ,"))
                if mm and "[]" in i2.type_text:
                    consts[i2.name] = int(mm.group(1))
        for i2 in cc.instrs:
            if i2.op == "compare" and ("direction=LT" in i2.rest
                                       or "direction=GT" in i2.rest):
                for o in re.findall(r"%([\w.-]+)",
                                    i2.rest.split("direction")[0]):
                    if o in consts:
                        return max(1, consts[o])
    return 1


def _multipliers(comps: dict) -> tuple[dict, set]:
    """Returns (computation -> product of enclosing trip counts,
    set of 'material' computations: entry + while bodies/conds + branches —
    anything NOT reached purely through fusion `calls=`/`to_apply=`)."""
    entry = None
    for name in comps:
        if name.startswith("main") or ".main" in name:
            entry = name
    if entry is None:
        entry = list(comps)[-1]

    mult = defaultdict(float)
    material: set = set()

    def visit(name: str, m: float, is_material: bool):
        if name not in comps:
            return
        again = mult[name] < m or (is_material and name not in material)
        if not again:
            return
        mult[name] = max(mult[name], m)
        if is_material:
            material.add(name)
        comp = comps[name]
        for ins in comp.instrs:
            if ins.op == "while":
                trip = _trip_count(ins, comps)
                body = re.search(r"body=%?([\w.-]+)", ins.rest)
                cond = re.search(r"condition=%?([\w.-]+)", ins.rest)
                if body:
                    visit(body.group(1), m * trip, is_material)
                if cond:
                    visit(cond.group(1), m * (trip + 1), is_material)
            elif ins.op == "conditional":
                for br in re.findall(
                        r"(?:true_computation|false_computation|"
                        r"branch_computations)=\{?%?([\w.,% -]+)", ins.rest):
                    for b in re.findall(r"[\w.-]+", br):
                        visit(b, m, is_material)
            else:
                for attr in ("calls", "to_apply"):
                    mm = re.search(rf"{attr}=%?([\w.-]+)", ins.rest)
                    if mm:
                        visit(mm.group(1), m, False)

    visit(entry, 1.0, True)
    return mult, material


def _dot_flops(comp: Computation, ins: Instr) -> float:
    """2 x prod(result) x prod(contracting dims of lhs)."""
    out_elems = 1
    for _, dims in _dims(ins.type_text):
        for d in dims:
            out_elems *= d
    operand_part = ins.rest.split(")")[0]
    ops = re.findall(r"%([\w.-]+)", operand_part)
    lhs_dims = _dims(comp.shapes.get(ops[0], "")) if ops else []
    lc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    k = 1
    if lc and lhs_dims:
        dims = lhs_dims[0][1]
        for idx in (int(i) for i in lc.group(1).split(",") if i):
            if idx < len(dims):
                k *= dims[idx]
    return 2.0 * out_elems * k


def _group_size(rest: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rest)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", rest)
    if m:
        first = m.group(1).strip()
        return len(first.split(",")) if first else default
    return default


def _wire_factor(op: str, g: int, rb: int) -> float:
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g * rb
    if op == "all-gather":
        return (g - 1) / g * rb
    if op == "reduce-scatter":
        return float((g - 1) * rb)
    if op == "all-to-all":
        return (g - 1) / g * rb
    if op == "collective-permute":
        return float(rb)
    return float(rb)


@dataclasses.dataclass
class HloCost:
    dot_flops: float
    traffic_bytes: float
    wire_bytes_by_op: dict
    count_by_op: dict
    n_while: int
    multiplier_max: float

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes_by_op.values())


def analyze(text: str, n_devices: int) -> HloCost:
    comps = parse_module(text)
    mult, material = _multipliers(comps)

    flops = 0.0
    traffic = 0.0
    wire = defaultdict(float)
    counts = defaultdict(float)
    n_while = 0

    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue  # unreachable (e.g. dead fusions)
        fusion_like = cname not in material
        for ins in comp.instrs:
            base = ins.op
            if base.endswith("-start"):
                base = base[: -len("-start")]
            if base.endswith("-done"):
                continue
            if base == "while":
                n_while += 1
            if base == "dot" and not fusion_like:
                flops += m * _dot_flops(comp, ins)
            if base in _COLL and not fusion_like:
                rb = _shape_bytes(ins.type_text)
                g = _group_size(ins.rest, n_devices)
                wire[base] += m * _wire_factor(base, g, rb)
                counts[base] += m
            if fusion_like or base in _NO_TRAFFIC or base in ("while",
                                                              "conditional"):
                continue
            # tensor-granularity traffic: result + operands
            rb = _shape_bytes(ins.type_text)
            ob = 0
            for oname in re.findall(r"%([\w.-]+)", ins.rest)[:8]:
                if oname in comp.shapes:
                    ob += _shape_bytes(comp.shapes[oname])
            traffic += m * (rb + ob)

    return HloCost(
        dot_flops=flops,
        traffic_bytes=traffic,
        wire_bytes_by_op=dict(wire),
        count_by_op=dict(counts),
        n_while=n_while,
        multiplier_max=max(mult.values()) if mult else 1.0,
    )
