"""Per-cell hotspot breakdown for the §Perf hypothesis loop.

Usage: PYTHONPATH=src python -m repro.roofline.breakdown <hlo_file> <n_dev>
Prints top traffic instructions (with loop multipliers), top collectives,
and dot-flops — the dry-run 'profile' this CPU-only environment offers.
"""
from __future__ import annotations

import re
import sys
from collections import defaultdict

from repro.roofline import hlo_cost as H


def breakdown(path: str, n_dev: int, top: int = 14):
    text = open(path).read()
    comps = H.parse_module(text)
    mult, material = H._multipliers(comps)
    traffic = defaultdict(float)
    coll = defaultdict(float)
    for cname, comp in comps.items():
        m = mult.get(cname, 0.0)
        if m <= 0 or cname not in material:
            continue
        for ins in comp.instrs:
            base = ins.op[:-6] if ins.op.endswith("-start") else ins.op
            if ins.op.endswith("-done") or base in H._NO_TRAFFIC or base in (
                    "while", "conditional"):
                continue
            rb = H._shape_bytes(ins.type_text)
            ob = sum(H._shape_bytes(comp.shapes[o])
                     for o in re.findall(r"%([\w.-]+)", ins.rest)[:8]
                     if o in comp.shapes)
            t = m * (rb + ob)
            meta = re.search(r'op_name="([^"]+)"', ins.rest)
            tag = (meta.group(1).split("/")[-1] if meta else base)[:40]
            traffic[(base, ins.type_text[:44], tag)] += t
            if base in H._COLL:
                g = H._group_size(ins.rest, n_dev)
                coll[(base, ins.type_text[:44], tag)] += m * H._wire_factor(
                    base, g, rb)

    print("== traffic hotspots (bytes x loop multipliers) ==")
    for k, v in sorted(traffic.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{v/1e9:9.2f} GB  {k[0]:18s} {k[1]:46s} {k[2]}")
    print("== collective hotspots (wire bytes) ==")
    for k, v in sorted(coll.items(), key=lambda kv: -kv[1])[:top]:
        print(f"{v/1e9:9.2f} GB  {k[0]:18s} {k[1]:46s} {k[2]}")
    c = H.analyze(text, n_dev)
    print(f"== totals: dot_flops={c.dot_flops:.3e} traffic={c.traffic_bytes/1e9:.1f}GB "
          f"wire={c.total_wire_bytes/1e9:.2f}GB ==")


if __name__ == "__main__":
    breakdown(sys.argv[1], int(sys.argv[2]),
              int(sys.argv[3]) if len(sys.argv) > 3 else 14)
