"""Extract collective traffic from optimized (post-SPMD) HLO text.

`compiled.cost_analysis()` has FLOPs and memory bytes but NOT collective
bytes; we parse `compiled.as_text()` and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
tracking replica-group sizes so the roofline model can apply per-algorithm
wire-byte factors (ring AG/RS move (g-1)/g x bytes, AR moves 2(g-1)/g, A2A
moves (g-1)/g of the shard, permute moves the shard once).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# e.g.:  %all-reduce.5 = f32[4,128]{1,0} all-reduce(%x), replica_groups=...
_OP_RE = re.compile(
    r"=\s*(?:\([^=]*\)|[\w\[\]{},. ]+?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\("
)


def _shape_bytes(text: str) -> int:
    """Sum sizes of all `dtype[a,b,...]` shapes in a type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    """Replica-group size from `replica_groups={{0,1,..},{..}}` or
    `replica_groups=[8,64]<=[512]` (iota) forms."""
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))  # [num_groups, group_size]
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        first = m.group(1).strip()
        return len(first.split(",")) if first else default
    return default


@dataclasses.dataclass
class CollectiveStats:
    # op -> total result bytes (logical, per device program)
    bytes_by_op: dict
    count_by_op: dict
    # op -> sum over instances of bytes * wire-factor(group)
    wire_bytes_by_op: dict

    @property
    def total_wire_bytes(self) -> float:
        return sum(self.wire_bytes_by_op.values())


def _wire_factor(op: str, g: int, result_bytes: int) -> float:
    """Bytes a device actually sends on the wire per ring algorithms."""
    if g <= 1:
        return 0.0
    if op == "all-reduce":
        return 2.0 * (g - 1) / g * result_bytes
    if op == "all-gather":
        # result is the gathered tensor; each device contributes 1/g of it
        return (g - 1) / g * result_bytes
    if op == "reduce-scatter":
        # result is the scattered shard (input/g); ring sends (g-1)/g x input
        return float((g - 1) * result_bytes)
    if op == "all-to-all":
        return (g - 1) / g * result_bytes
    if op == "collective-permute":
        return float(result_bytes)
    return float(result_bytes)


def parse_collectives(hlo_text: str, n_devices: int) -> CollectiveStats:
    bytes_by_op: dict = defaultdict(int)
    count_by_op: dict = defaultdict(int)
    wire_by_op: dict = defaultdict(float)

    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # count start ops only for async pairs
        op = m.group(1)
        lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1].split(op)[0]
        rb = _shape_bytes(lhs)
        g = _group_size(line, n_devices)
        bytes_by_op[op] += rb
        count_by_op[op] += 1
        wire_by_op[op] += _wire_factor(op, g, rb)

    return CollectiveStats(
        bytes_by_op=dict(bytes_by_op),
        count_by_op=dict(count_by_op),
        wire_bytes_by_op=dict(wire_by_op),
    )
