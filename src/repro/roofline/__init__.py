"""Roofline analysis: HLO collective extraction + three-term model."""
