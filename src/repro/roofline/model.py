"""Three-term roofline model over the dry-run artifacts.

Terms (per device, TPU v5e constants):
  compute    = FLOPs / 197e12            (bf16 peak)
  memory     = bytes / 819e9             (HBM bandwidth)
  collective = wire bytes / 50e9         (ICI per-link, per the brief)

FLOPs / bytes / wire bytes come from the trip-count-corrected HLO cost model
(`hlo_cost.analyze`) over the saved optimized HLO — `cost_analysis()` alone
undercounts scanned layers. MODEL_FLOPS is the analytic useful compute
(6·N·D train / 2·N_active·tokens serve); its ratio to HLO dot FLOPs exposes
remat/replication waste.

Caveat recorded per cell: the CPU backend legalizes bf16 dots via f32
upcasts, inflating `traffic`/memory vs a real TPU lowering; numbers are
upper bounds for serve cells.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Optional

from repro import configs
from repro.configs.base import SHAPES
from repro.roofline import hlo_cost

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link (brief: collective term denominator)


@dataclasses.dataclass
class CellRoofline:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    fits_16g: bool
    mem_gib: float
    # per-device
    hlo_flops: float
    traffic_bytes: float
    wire_bytes: float
    model_flops_device: float
    # seconds
    t_compute: float
    t_memory: float
    t_collective: float

    model_bytes_device: float = 0.0  # minimal bytes/step (params + caches)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """useful FLOPs / HLO dot FLOPs, clamped to [0, 1] (SSM decode cells
        lower to elementwise ops — no dots — so the raw ratio is unbounded)."""
        return self.model_flops_device / max(self.hlo_flops,
                                             self.model_flops_device, 1.0)

    @property
    def is_decode(self) -> bool:
        return self.shape in ("decode_32k", "long_500k")

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term bound spent on irreducible work.

        Train/prefill: useful-compute time / dominant bound (MFU-like).
        Decode: useful-bytes time / dominant bound — decode is inherently
        memory-bound (one full pass over weights+cache per token); the
        meaningful roofline is bytes, not FLOPs.
        """
        if self.is_decode:
            t_useful = self.model_bytes_device / HBM_BW
        else:
            t_useful = self.model_flops_device / PEAK_FLOPS
        t_bound = max(self.t_compute, self.t_memory, self.t_collective)
        return t_useful / max(t_bound, 1e-30)


def model_bytes(arch: str, shape_name: str, n_devices: int) -> float:
    """Minimal per-device HBM bytes per serve step: bf16 active params read
    once + the KV/state cache read once (+ the one-token write, negligible)."""
    import jax
    import numpy as np

    from repro.models import transformer

    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind != "decode":
        return 0.0
    params_b = 2.0 * cfg.active_param_count()
    cache = transformer.cache_struct(cfg, shape.global_batch, shape.seq_len)
    cache_b = sum(
        float(np.prod(l.shape)) * l.dtype.itemsize
        for l in jax.tree.leaves(cache)
    )
    return (params_b + cache_b) / n_devices


def model_flops(arch: str, shape_name: str, n_devices: int) -> float:
    """Analytic useful FLOPs per device per step."""
    cfg = configs.get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per sequence
        total = 2.0 * n_active * shape.global_batch
    return total / n_devices


def improvement_hint(c: CellRoofline) -> str:
    if c.dominant == "collective":
        return ("cut cross-device bytes: bf16 collectives, fuse/batch "
                "gathers, or reshard to keep the hot loop local")
    if c.dominant == "memory":
        if c.useful_ratio < 0.5:
            return ("HLO moves >2x useful bytes: fuse the offending op chain "
                    "(kernel) or remove replicated/select-DUS traffic")
        return "raise arithmetic intensity: larger microbatch/chunk, fusion"
    if c.useful_ratio < 0.5:
        return "compute is replicated or rematerialised: check shardings/remat"
    return "near compute bound: only kernel-level MXU utilisation remains"


def analyze_cell(json_path: str) -> Optional[CellRoofline]:
    with open(json_path) as f:
        r = json.load(f)
    if r.get("status") != "ok":
        return None
    hlo_path = json_path.replace(".json", ".hlo.txt")
    if os.path.exists(hlo_path):
        with open(hlo_path) as f:
            cost = hlo_cost.analyze(f.read(), r["n_devices"])
        flops = cost.dot_flops
        traffic = cost.traffic_bytes
        wire = cost.total_wire_bytes
    else:  # fall back to (undercounted) XLA numbers
        flops = r["cost"].get("flops", 0.0)
        traffic = r["cost"].get("bytes accessed", 0.0)
        wire = r["collectives"]["total_wire_bytes"]

    mem = r["memory"]
    mem_bytes = mem.get("argument_size_in_bytes", 0) + mem.get(
        "temp_size_in_bytes", 0)
    mf = model_flops(r["arch"], r["shape"], r["n_devices"])
    mb = model_bytes(r["arch"], r["shape"], r["n_devices"])
    return CellRoofline(
        model_bytes_device=mb,
        arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
        n_devices=r["n_devices"],
        fits_16g=mem_bytes < 16 * 2**30,
        mem_gib=mem_bytes / 2**30,
        hlo_flops=flops,
        traffic_bytes=traffic,
        wire_bytes=wire,
        model_flops_device=mf,
        t_compute=flops / PEAK_FLOPS,
        t_memory=traffic / HBM_BW,
        t_collective=wire / LINK_BW,
    )


def analyze_dir(art_dir: str) -> list:
    cells = []
    for p in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        c = analyze_cell(p)
        if c is not None:
            cells.append(c)
    return cells


def markdown_table(cells: list) -> str:
    hdr = ("| arch | shape | mesh | mem GiB (fits) | compute s | memory s | "
           "collective s | dominant | useful/HLO | roofline frac | next lever |")
    sep = "|" + "---|" * 11
    rows = [hdr, sep]
    for c in cells:
        rows.append(
            f"| {c.arch} | {c.shape} | {c.mesh} | "
            f"{c.mem_gib:.1f} ({'Y' if c.fits_16g else 'N'}) | "
            f"{c.t_compute:.3e} | {c.t_memory:.3e} | {c.t_collective:.3e} | "
            f"{c.dominant} | {c.useful_ratio:.2f} | "
            f"{c.roofline_fraction:.3f} | {improvement_hint(c)} |"
        )
    return "\n".join(rows)


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(
        os.path.dirname(__file__), "../../../artifacts/dryrun"))
    ap.add_argument("--json-out", default=None)
    args = ap.parse_args()
    cells = analyze_dir(os.path.abspath(args.dir))
    print(markdown_table(cells))
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump([dataclasses.asdict(c) for c in cells], f, indent=1)


if __name__ == "__main__":
    main()
